//! Perf bench: cross-session step fusion on the streaming hot path
//! (§Perf streaming) — N concurrent sessions each advancing an
//! 8-frame chunk, solo (`run_prefix_into` per session, the pre-fusion
//! serving path) vs fused (`run_steps_batched_into`, one step-major
//! batched run per window, gather/scatter included). Reported as
//! steps/s per concurrency level and dumped to `BENCH_streaming.json`
//! at the repo root.
//!
//! Self-contained: a synthetic on-disk artifact store (via the shared
//! `tests/common/` harness) with synthetic weights (no `make
//! artifacts` needed), and the fused path is bit-checked against the
//! solo path — and the vectorized fused path against a forced-scalar
//! twin executable — before any timing: the speedups can never come
//! from a kernel that drifted.
//!
//! Headline (ISSUE 5 acceptance): fused steps/s >= 3x solo at 16
//! concurrent sessions. Since the SIMD PR the dump (schema
//! `sharp-bench-streaming/v2`) also reports the per-level
//! `simd_multiplier_fused` — fused-on-the-dispatched-ISA over
//! fused-forced-scalar — isolating what vectorization adds on top of
//! fusion at each concurrency level.

mod util;

#[path = "../tests/common/mod.rs"]
mod common;

use std::collections::BTreeMap;
use std::path::PathBuf;

use common::seq_entry;
use sharp::runtime::{
    ArtifactStore, FusedBatch, Isa, LstmExecutable, LstmOutput, PlanMode, RuntimeConfig,
};
use sharp::util::json::{self, Json};
use sharp::util::rng::Rng;

const D: usize = 256;
const H: usize = 256;
const CHUNK: usize = 8;
const SESSIONS: [usize; 4] = [1, 4, 16, 64];

/// Synthetic store: one B=1 LSTM seq bucket, the streaming shape.
fn synth_store() -> (PathBuf, ArtifactStore) {
    common::synth_store(
        "bench_streaming",
        &seq_entry("seq_stream", "seq", CHUNK, 1, D, H),
    )
}

struct Lanes {
    chunks: Vec<Vec<f32>>,
    h0: Vec<Vec<f32>>,
    c0: Vec<Vec<f32>>,
}

fn lanes(n: usize, rng: &mut Rng) -> Lanes {
    Lanes {
        chunks: (0..n).map(|_| rng.vec_f32(CHUNK * D, -1.0, 1.0)).collect(),
        h0: (0..n).map(|_| rng.vec_f32(H, -1.0, 1.0)).collect(),
        c0: (0..n).map(|_| rng.vec_f32(H, -1.0, 1.0)).collect(),
    }
}

/// One solo pass: every session advances its chunk alone, the
/// pre-fusion serving pattern (N separate runs against the same packed
/// panels). Returns nothing; carries land in `outs`.
fn solo_pass(exe: &LstmExecutable, l: &Lanes, outs: &mut [LstmOutput]) {
    for (i, out) in outs.iter_mut().enumerate() {
        exe.run_prefix_into(&l.chunks[i], CHUNK, &l.h0[i], &l.c0[i], out)
            .expect("solo chunk runs");
    }
}

/// One fused pass: gather all lanes (the worker's per-window cost is
/// part of the fused path, so it is timed too), one batched run.
fn fused_pass(exe: &LstmExecutable, l: &Lanes, batch: &mut FusedBatch) {
    batch.begin(D, H);
    for i in 0..l.chunks.len() {
        batch.push_lane(&l.chunks[i], CHUNK, &l.h0[i], &l.c0[i]);
    }
    batch.finish();
    exe.run_steps_batched_into(batch).expect("fused window runs");
}

/// `BENCH_streaming.json` at the repo root by default; `--out <path>`
/// / `SHARP_BENCH_OUT` relocate it (see [`util::out_path`] — the old
/// bench-specific `SHARP_BENCH_STREAMING_OUT` knob is gone, one knob
/// moves every perf dump).
fn out_path() -> PathBuf {
    util::out_path("BENCH_streaming.json")
}

fn main() {
    let (_dir, store) = synth_store();
    let mut rng = Rng::new(0x57E9);
    let wx = rng.vec_f32(D * 4 * H, -0.2, 0.2);
    let wh = rng.vec_f32(H * 4 * H, -0.2, 0.2);
    let bias = rng.vec_f32(4 * H, -0.1, 0.1);
    let exe =
        LstmExecutable::with_weights(&store, "seq_stream", wx.clone(), wh.clone(), bias.clone())
            .unwrap();
    // A forced-scalar twin over the same weights: the fused-vs-fused
    // ratio isolates vectorization from fusion.
    let mut exe_scalar = LstmExecutable::with_weights(&store, "seq_stream", wx, wh, bias).unwrap();
    exe_scalar
        .set_runtime(RuntimeConfig {
            threads: 1,
            plan: PlanMode::Auto,
            force_kernel: Some(Isa::Scalar),
            ..RuntimeConfig::default()
        })
        .unwrap();
    let isa = RuntimeConfig::default()
        .resolve_isa()
        .expect("kernel ISA resolves");

    // FLOPs of one lane-step: the two fused-gate GEMM rows (mul+add).
    let flops_per_step = (2 * (D + H) * 4 * H) as f64;
    println!(
        "streaming fusion: D={D} H={H} chunk={CHUNK} frames ({:.2} MFLOP/lane-chunk), isa {}",
        flops_per_step * CHUNK as f64 / 1e6,
        isa.name()
    );

    let mut rows = Vec::new();
    let mut speedup_at_16 = 0.0f64;
    for &n in &SESSIONS {
        let l = lanes(n, &mut rng);
        let steps = (n * CHUNK) as f64;
        let pass_flops = flops_per_step * steps;
        let iters = (3e8 / pass_flops).ceil().clamp(3.0, 40.0) as usize;

        // Honesty guard: the fused carries must be bit-identical to the
        // solo carries — and the vectorized fused carries to the
        // forced-scalar fused carries — before any path is timed.
        let mut outs: Vec<LstmOutput> = (0..n).map(|_| LstmOutput::default()).collect();
        solo_pass(&exe, &l, &mut outs);
        let mut batch = FusedBatch::new();
        fused_pass(&exe, &l, &mut batch);
        let mut batch_scalar = FusedBatch::new();
        fused_pass(&exe_scalar, &l, &mut batch_scalar);
        for i in 0..n {
            assert_eq!(
                batch.lane_h(i),
                &outs[i].h_t[..],
                "lane {i} h drifted (n={n}) — refusing to time a wrong kernel"
            );
            assert_eq!(batch.lane_c(i), &outs[i].c_t[..], "lane {i} c drifted (n={n})");
            assert_eq!(
                batch_scalar.lane_h(i),
                batch.lane_h(i),
                "lane {i} h: scalar vs {} fused kernels drifted (n={n})",
                isa.name()
            );
        }

        let solo = util::bench(&format!("streaming::solo(n={n})"), iters, &mut || {
            solo_pass(&exe, &l, &mut outs);
            std::hint::black_box(outs[0].h_t.last());
        });
        let fused = util::bench(&format!("streaming::fused(n={n})"), iters, &mut || {
            fused_pass(&exe, &l, &mut batch);
            std::hint::black_box(batch.lane_h(0).last());
        });
        // The scalar twin is a distinct configuration whenever a vector
        // ISA is dispatched; on a scalar-only host the measurement is
        // shared (timing one configuration twice would be noise).
        let fused_scalar_min_s = if isa == Isa::Scalar {
            fused.min_s
        } else {
            util::bench(&format!("streaming::fused_scalar(n={n})"), iters, &mut || {
                fused_pass(&exe_scalar, &l, &mut batch_scalar);
                std::hint::black_box(batch_scalar.lane_h(0).last());
            })
            .min_s
        };
        let solo_sps = steps / solo.min_s;
        let fused_sps = steps / fused.min_s;
        let fused_scalar_sps = steps / fused_scalar_min_s;
        let speedup = fused_sps / solo_sps;
        let simd_mult = fused_sps / fused_scalar_sps;
        if n == 16 {
            speedup_at_16 = speedup;
        }
        println!(
            "    n={n:<3} solo {solo_sps:>9.0} steps/s | fused {fused_sps:>9.0} steps/s \
             ({speedup:.2}x) | fused_scalar {fused_scalar_sps:>9.0} steps/s \
             (simd {simd_mult:.2}x)\n"
        );

        let mut obj = BTreeMap::new();
        obj.insert("sessions".into(), Json::Num(n as f64));
        obj.insert("steps_per_pass".into(), Json::Num(steps));
        obj.insert("solo_steps_per_s".into(), Json::Num(solo_sps));
        obj.insert("fused_steps_per_s".into(), Json::Num(fused_sps));
        obj.insert("fused_scalar_steps_per_s".into(), Json::Num(fused_scalar_sps));
        obj.insert("speedup_fused_vs_solo".into(), Json::Num(speedup));
        obj.insert("simd_multiplier_fused".into(), Json::Num(simd_mult));
        rows.push(Json::Obj(obj));
    }

    println!("headline: fused vs solo at 16 sessions = {speedup_at_16:.2}x (target >= 3x)");

    let mut root = BTreeMap::new();
    root.insert("schema".into(), Json::Str("sharp-bench-streaming/v2".into()));
    for (key, v) in [("D", D), ("H", H), ("chunk_frames", CHUNK)] {
        root.insert(key.into(), Json::Num(v as f64));
    }
    let mut ij = BTreeMap::new();
    ij.insert("name".into(), Json::Str(isa.name().into()));
    ij.insert("lanes".into(), Json::Num(isa.lanes() as f64));
    root.insert("isa".into(), Json::Obj(ij));
    root.insert("flops_per_lane_step".into(), Json::Num(flops_per_step));
    root.insert("speedup_at_16".into(), Json::Num(speedup_at_16));
    root.insert("levels".into(), Json::Arr(rows));
    let path = out_path();
    match std::fs::write(&path, json::write(&Json::Obj(root))) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
