//! Bench: regenerate paper exhibit fig14 (see DESIGN.md §5 for the
//! exhibit index and experiments/fig14.rs for the generator).
mod util;

fn main() {
    util::exhibit_bench("fig14", 5);
}
