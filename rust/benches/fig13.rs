//! Bench: regenerate paper exhibit fig13 (see DESIGN.md §5 for the
//! exhibit index and experiments/fig13.rs for the generator).
mod util;

fn main() {
    util::exhibit_bench("fig13", 5);
}
