//! Bench: regenerate paper exhibit table4 (see DESIGN.md §5 for the
//! exhibit index and experiments/table4.rs for the generator).
mod util;

fn main() {
    util::exhibit_bench("table4", 5);
}
