//! Perf bench: the inter-layer step pipeline on stacked models
//! (§Stack) — depth L in {2, 3, 4} on the lstm_h1024_t16_b4 shape,
//! sequential layer-by-layer baseline vs the pipelined driver (one
//! worker per layer, double-buffered step-queues). Reported as
//! wall-time speedup per depth with the `sim::stack_pipeline_estimate`
//! prediction alongside, and dumped to `BENCH_stack.json` (schema
//! `sharp-bench-stack/v1`; `--out` / `SHARP_BENCH_OUT` relocate it).
//!
//! Self-contained: a synthetic on-disk artifact store (shared
//! `tests/common/` harness) with synthetic weights, and EVERY timed
//! pipelined variant is bit-checked against the sequential oracle
//! before timing — the speedups can never come from a driver that
//! drifted.
//!
//! Headline (PR 7 acceptance): pipelined >= 1.6x sequential at L=3
//! with threads >= L. The fill/drain ideal at (L=3, T=16) is
//! 48/18 ~ 2.67x; the measured number trails it by the non-uniform
//! layer-0 cost and queue overhead, which is exactly the gap the sim
//! estimate quantifies.

mod util;

#[path = "../tests/common/mod.rs"]
mod common;

use std::collections::BTreeMap;
use std::path::PathBuf;

use common::stack_entry;
use sharp::runtime::{
    ArtifactStore, DirWeights, RuntimeConfig, StackExecutable, StackLayerWeights, StackOutput,
};
use sharp::sim::{stack_pipeline_estimate, stack_step_flops};
use sharp::util::json::{self, Json};
use sharp::util::rng::Rng;

const T: usize = 16;
const B: usize = 4;
const D: usize = 1024;
const H: usize = 1024;
const LAYERS: [usize; 3] = [2, 3, 4];

fn stack_name(layers: usize) -> String {
    format!("stack{layers}_h{H}_t{T}_b{B}")
}

/// Synthetic store: one unidirectional LSTM stack entry per depth.
fn synth_store() -> (PathBuf, ArtifactStore) {
    let entries: Vec<String> = LAYERS
        .iter()
        .map(|&l| stack_entry(&stack_name(l), "seq", T, B, D, H, l, false, 0))
        .collect();
    common::synth_store("bench_stack", &entries.join(","))
}

/// Synthetic per-layer weights (D == H, so every layer shares dims).
fn weights(layers: usize, rng: &mut Rng) -> Vec<StackLayerWeights> {
    (0..layers)
        .map(|_| StackLayerWeights {
            fwd: DirWeights {
                wx: rng.vec_f32(D * 4 * H, -0.05, 0.05),
                wh: rng.vec_f32(H * 4 * H, -0.05, 0.05),
                bias: rng.vec_f32(4 * H, -0.05, 0.05),
                wp: Vec::new(),
            },
            bwd: None,
        })
        .collect()
}

fn main() {
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let isa = RuntimeConfig::default()
        .resolve_isa()
        .expect("kernel ISA resolves");
    println!(
        "kernel isa: {} ({} f32 lane{}), {threads} threads\n",
        isa.name(),
        isa.lanes(),
        if isa.lanes() == 1 { "" } else { "s" }
    );
    let (_dir, store) = synth_store();
    let mut rng = Rng::new(0x57AC);
    let xs = rng.vec_f32(T * B * D, -1.0, 1.0);

    let mut rows = Vec::new();
    let mut headline_l3 = 0.0f64;
    for &layers in &LAYERS {
        let cfg = RuntimeConfig {
            threads,
            ..RuntimeConfig::default()
        };
        let w = weights(layers, &mut rng);
        let exe = StackExecutable::with_weights(&store, &stack_name(layers), w, cfg)
            .expect("stack binds");
        let (h0, c0) = exe.zero_state();

        // Bit-check the timed variant against the sequential oracle
        // BEFORE timing it: identical bits or no numbers.
        let mut want = StackOutput::default();
        exe.run_sequential_into(&xs, &h0, &c0, &mut want).expect("sequential runs");
        let mut got = StackOutput::default();
        exe.run_pipelined_into(&xs, &h0, &c0, &mut got).expect("pipelined runs");
        common::assert_bits_eq(&got.out, &want.out, &format!("L={layers}: pipelined out"));
        common::assert_bits_eq(&got.h_t, &want.h_t, &format!("L={layers}: pipelined h_t"));
        common::assert_bits_eq(&got.c_t, &want.c_t, &format!("L={layers}: pipelined c_t"));

        let step_costs = stack_step_flops(D, H, B, 4, 0, layers);
        let run_flops: f64 = step_costs.iter().sum::<f64>() * T as f64;
        let iters = (3e8 / run_flops).ceil().clamp(3.0, 40.0) as usize;
        let est = stack_pipeline_estimate(&step_costs, T);

        let mut out = StackOutput::default();
        let seq = util::bench(&format!("stack::L{layers}::sequential"), iters, &mut || {
            exe.run_sequential_into(&xs, &h0, &c0, &mut out).expect("sequential runs");
        });
        let pipe = util::bench(&format!("stack::L{layers}::pipelined"), iters, &mut || {
            exe.run_pipelined_into(&xs, &h0, &c0, &mut out).expect("pipelined runs");
        });
        let speedup = seq.min_s / pipe.min_s;
        if layers == 3 {
            headline_l3 = speedup;
        }
        println!(
            "    L={layers} sequential {:.4}s | pipelined {:.4}s | {speedup:.2}x \
             (sim predicts {:.2}x)\n",
            seq.min_s, pipe.min_s, est.speedup
        );

        let mut obj = BTreeMap::new();
        obj.insert("layers".into(), Json::Num(layers as f64));
        obj.insert("iters".into(), Json::Num(iters as f64));
        obj.insert("sequential_s".into(), Json::Num(seq.min_s));
        obj.insert("pipelined_s".into(), Json::Num(pipe.min_s));
        obj.insert("speedup".into(), Json::Num(speedup));
        obj.insert("sim_speedup".into(), Json::Num(est.speedup));
        obj.insert("run_flops".into(), Json::Num(run_flops));
        rows.push(Json::Obj(obj));
    }

    println!("headline: pipelined vs sequential at L=3 = {headline_l3:.2}x (target >= 1.6x)");

    let mut root = BTreeMap::new();
    root.insert("schema".into(), Json::Str("sharp-bench-stack/v1".into()));
    for (key, v) in [("D", D), ("H", H), ("T", T), ("B", B), ("threads", threads)] {
        root.insert(key.into(), Json::Num(v as f64));
    }
    let mut ij = BTreeMap::new();
    ij.insert("name".into(), Json::Str(isa.name().into()));
    ij.insert("lanes".into(), Json::Num(isa.lanes() as f64));
    root.insert("isa".into(), Json::Obj(ij));
    root.insert("speedup_at_l3".into(), Json::Num(headline_l3));
    root.insert("levels".into(), Json::Arr(rows));
    let path = util::out_path("BENCH_stack.json");
    match std::fs::write(&path, json::write(&Json::Obj(root))) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
