//! Bench: regenerate paper exhibit fig15 (see DESIGN.md §5 for the
//! exhibit index and experiments/fig15.rs for the generator).
mod util;

fn main() {
    util::exhibit_bench("fig15", 5);
}
