//! Shared micro-bench harness (the offline registry has no criterion):
//! warmup + N timed iterations, reporting min/mean/p50 wall times.
//!
//! Each `[[bench]]` target is a `harness = false` main that (a) times the
//! generator that regenerates its paper exhibit and (b) prints the same
//! rows the paper reports, so `cargo bench | tee bench_output.txt` is the
//! reproduction record.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min_s: f64,
    pub mean_s: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<28} iters={:<3} min={:>10.3?} mean={:>10.3?}",
            self.name,
            self.iters,
            std::time::Duration::from_secs_f64(self.min_s),
            std::time::Duration::from_secs_f64(self.mean_s),
        );
    }
}

/// Time `f` with one warmup and `iters` measured runs.
pub fn bench<T, F: FnMut() -> T>(name: &str, iters: usize, mut f: F) -> BenchResult {
    let _warm = f();
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        let out = f();
        times.push(t0.elapsed().as_secs_f64());
        std::hint::black_box(out);
    }
    let min_s = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let mean_s = times.iter().sum::<f64>() / times.len() as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        min_s,
        mean_s,
    };
    r.report();
    r
}

/// Resolve where a perf bench dumps its `BENCH_*.json`: an explicit
/// `--out <path>` (or `--out=<path>`) argument wins, then the
/// `SHARP_BENCH_OUT` env fallback (a directory keeps the default file
/// name inside it, so one setting relocates EVERY perf bench without
/// them clobbering each other), then `default_name` at the repo root
/// (next to the workspace `Cargo.toml`). Unknown arguments are ignored
/// — `cargo bench` passes its own flags through to harness-false mains.
#[allow(dead_code)] // exhibit benches print rather than dump
pub fn out_path(default_name: &str) -> std::path::PathBuf {
    use std::path::PathBuf;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--out" {
            if let Some(p) = args.next() {
                return p.into();
            }
        } else if let Some(p) = a.strip_prefix("--out=") {
            return p.into();
        }
    }
    if let Ok(p) = std::env::var("SHARP_BENCH_OUT") {
        let p = PathBuf::from(p);
        return if p.is_dir() { p.join(default_name) } else { p };
    }
    let manifest =
        std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| env!("CARGO_MANIFEST_DIR").into());
    match PathBuf::from(&manifest).parent() {
        Some(root) => root.join(default_name),
        None => default_name.into(),
    }
}

/// Standard main body for an exhibit bench: time regeneration, then print
/// the exhibit itself.
#[allow(dead_code)] // benches that only measure perf do not call this
pub fn exhibit_bench(id: &str, iters: usize) {
    let result = bench(&format!("exhibit::{id}"), iters, || {
        sharp::experiments::run(id).expect("known exhibit id")
    });
    let _ = result;
    let e = sharp::experiments::run(id).expect("known exhibit id");
    println!("\n{}", e.render());
}
