//! Perf bench: the executor hot path (§Perf runtime) — scalar oracle vs
//! the tiled kernel layer vs tiled + row-parallel threads, per shape,
//! reported as wall time AND GFLOP/s, and dumped machine-readably to
//! `BENCH_runtime.json` at the repo root so the perf trajectory is
//! tracked across PRs.
//!
//! Self-contained: weights are synthetic (no `artifacts/` needed), and
//! every tiled measurement is guarded by a bit-equality check against
//! the scalar oracle so the speedup numbers can never come from a
//! kernel that drifted.

mod util;

use std::collections::BTreeMap;
use std::path::PathBuf;

use sharp::runtime::exec;
use sharp::runtime::kernel::{gru_seq_into, lstm_seq_into, ExecScratch};
use sharp::runtime::literal::assert_bits_eq;
use sharp::util::json::{self, Json};
use sharp::util::rng::Rng;

#[derive(Clone, Copy, PartialEq)]
enum Kind {
    Lstm,
    Gru,
}

struct Shape {
    name: &'static str,
    kind: Kind,
    t: usize,
    b: usize,
    d: usize,
    h: usize,
}

/// FLOPs of one full forward pass: the two fused GEMMs (mul + add each),
/// which dominate; activations are excluded like every GEMM bench does.
fn flops(s: &Shape) -> f64 {
    let gates = match s.kind {
        Kind::Lstm => 4,
        Kind::Gru => 3,
    };
    2.0 * (s.t * s.b * (s.d + s.h) * gates * s.h) as f64
}

struct Variant {
    label: &'static str,
    min_s: f64,
    gflops: f64,
}

fn bench_variant<F: FnMut()>(
    shape: &Shape,
    label: &'static str,
    iters: usize,
    mut f: F,
) -> Variant {
    let r = util::bench(&format!("runtime::{}::{label}", shape.name), iters, &mut f);
    let gflops = flops(shape) / r.min_s / 1e9;
    println!("    {label:<9} {gflops:8.2} GFLOP/s");
    Variant {
        label,
        min_s: r.min_s,
        gflops,
    }
}

fn bench_shape(shape: &Shape, mt_threads: usize) -> Vec<Variant> {
    let (t, b, d, h) = (shape.t, shape.b, shape.d, shape.h);
    let gates = match shape.kind {
        Kind::Lstm => 4,
        Kind::Gru => 3,
    };
    let mut rng = Rng::new(0xBEEF ^ (t as u64) ^ ((h as u64) << 16));
    let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
    let h0 = rng.vec_f32(b * h, -1.0, 1.0);
    let c0 = rng.vec_f32(b * h, -1.0, 1.0);
    let wx = rng.vec_f32(d * gates * h, -0.2, 0.2);
    let wh = rng.vec_f32(h * gates * h, -0.2, 0.2);
    let bias = rng.vec_f32(gates * h, -0.1, 0.1);

    // Honesty guard: BOTH tiled variants (serial and the mt fan-out
    // actually timed below) must bit-match the oracle on this exact
    // shape before their throughput counts. The oracle pass — the most
    // expensive computation here — runs once per shape.
    let hs_ref = match shape.kind {
        Kind::Lstm => exec::lstm_seq(&xs, &h0, &c0, &wx, &wh, &bias, t, b, d, h).0,
        Kind::Gru => exec::gru_seq(&xs, &h0, &wx, &wh, &bias, t, b, d, h).0,
    };
    let mut scr = ExecScratch::new();
    let (mut hs, mut h_t, mut c_t) = (Vec::new(), Vec::new(), Vec::new());
    for threads in [1, mt_threads] {
        match shape.kind {
            Kind::Lstm => {
                lstm_seq_into(
                    &xs,
                    &h0,
                    &c0,
                    &wx,
                    &wh,
                    &bias,
                    t,
                    b,
                    d,
                    h,
                    threads,
                    &mut scr,
                    &mut hs,
                    &mut h_t,
                    &mut c_t,
                );
            }
            Kind::Gru => {
                gru_seq_into(
                    &xs,
                    &h0,
                    &wx,
                    &wh,
                    &bias,
                    t,
                    b,
                    d,
                    h,
                    threads,
                    &mut scr,
                    &mut hs,
                    &mut h_t,
                );
            }
        }
        assert_bits_eq(&hs, &hs_ref, shape.name);
    }

    // ~0.3 GFLOP per timed pass keeps big shapes at a few iterations and
    // small ones statistically meaningful.
    let iters = (3e8 / flops(shape)).ceil().clamp(3.0, 40.0) as usize;
    let mut out = Vec::new();
    match shape.kind {
        Kind::Lstm => {
            out.push(bench_variant(shape, "scalar", iters, || {
                std::hint::black_box(exec::lstm_seq(&xs, &h0, &c0, &wx, &wh, &bias, t, b, d, h));
            }));
            for (label, threads) in [("tiled", 1), ("tiled_mt", mt_threads)] {
                let mut scr = ExecScratch::new();
                out.push(bench_variant(shape, label, iters, || {
                    lstm_seq_into(
                        &xs,
                        &h0,
                        &c0,
                        &wx,
                        &wh,
                        &bias,
                        t,
                        b,
                        d,
                        h,
                        threads,
                        &mut scr,
                        &mut hs,
                        &mut h_t,
                        &mut c_t,
                    );
                    std::hint::black_box(hs.last());
                }));
            }
        }
        Kind::Gru => {
            out.push(bench_variant(shape, "scalar", iters, || {
                std::hint::black_box(exec::gru_seq(&xs, &h0, &wx, &wh, &bias, t, b, d, h));
            }));
            for (label, threads) in [("tiled", 1), ("tiled_mt", mt_threads)] {
                let mut scr = ExecScratch::new();
                out.push(bench_variant(shape, label, iters, || {
                    gru_seq_into(
                        &xs,
                        &h0,
                        &wx,
                        &wh,
                        &bias,
                        t,
                        b,
                        d,
                        h,
                        threads,
                        &mut scr,
                        &mut hs,
                        &mut h_t,
                    );
                    std::hint::black_box(hs.last());
                }));
            }
        }
    }
    out
}

/// `BENCH_runtime.json` lands at the repo root (next to the workspace
/// `Cargo.toml`), overridable via `SHARP_BENCH_OUT`.
fn out_path() -> PathBuf {
    if let Ok(p) = std::env::var("SHARP_BENCH_OUT") {
        return p.into();
    }
    let manifest =
        std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| env!("CARGO_MANIFEST_DIR").into());
    match PathBuf::from(&manifest).parent() {
        Some(root) => root.join("BENCH_runtime.json"),
        None => "BENCH_runtime.json".into(),
    }
}

fn main() {
    let mt_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let shapes = [
        Shape {
            name: "lstm_h256_t16_b4",
            kind: Kind::Lstm,
            t: 16,
            b: 4,
            d: 256,
            h: 256,
        },
        // The acceptance shape: H=1024 LSTM, tiled vs scalar single-thread.
        Shape {
            name: "lstm_h1024_t16_b4",
            kind: Kind::Lstm,
            t: 16,
            b: 4,
            d: 1024,
            h: 1024,
        },
        Shape {
            name: "lstm_h256_t32_b1",
            kind: Kind::Lstm,
            t: 32,
            b: 1,
            d: 256,
            h: 256,
        },
        Shape {
            name: "gru_h512_t16_b4",
            kind: Kind::Gru,
            t: 16,
            b: 4,
            d: 512,
            h: 512,
        },
    ];

    let mut rows = Vec::new();
    for shape in &shapes {
        println!(
            "shape {} (T={} B={} D={} H={}, {:.2} GFLOP/pass)",
            shape.name,
            shape.t,
            shape.b,
            shape.d,
            shape.h,
            flops(shape) / 1e9
        );
        let variants = bench_shape(shape, mt_threads);
        let scalar_s = variants[0].min_s;
        let mut obj = BTreeMap::new();
        obj.insert("name".into(), Json::Str(shape.name.into()));
        obj.insert(
            "kind".into(),
            Json::Str(
                match shape.kind {
                    Kind::Lstm => "lstm",
                    Kind::Gru => "gru",
                }
                .into(),
            ),
        );
        for (key, v) in [("T", shape.t), ("B", shape.b), ("D", shape.d), ("H", shape.h)] {
            obj.insert(key.into(), Json::Num(v as f64));
        }
        obj.insert("flops_per_pass".into(), Json::Num(flops(shape)));
        for v in &variants {
            let mut vj = BTreeMap::new();
            vj.insert("min_s".into(), Json::Num(v.min_s));
            vj.insert("gflops".into(), Json::Num(v.gflops));
            vj.insert("speedup_vs_scalar".into(), Json::Num(scalar_s / v.min_s));
            obj.insert(v.label.into(), Json::Obj(vj));
            if v.label != "scalar" {
                println!(
                    "    {:<9} speedup vs scalar: {:.2}x",
                    v.label,
                    scalar_s / v.min_s
                );
            }
        }
        rows.push(Json::Obj(obj));
        println!();
    }

    let mut root = BTreeMap::new();
    root.insert("schema".into(), Json::Str("sharp-bench-runtime/v1".into()));
    root.insert("threads_mt".into(), Json::Num(mt_threads as f64));
    root.insert("shapes".into(), Json::Arr(rows));
    let path = out_path();
    match std::fs::write(&path, json::write(&Json::Obj(root))) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
