//! Perf bench: the executor hot path (§Perf runtime) — scalar oracle vs
//! the planned tiled kernel under the scalar ISA (`tiled_scalar`) vs
//! the same planner on the detected vector ISA (`tiled_simd`, serial
//! and threaded) vs the old fixed MR=4/NR=16 operating point, per
//! shape, reported as wall time AND GFLOP/s plus the per-shape
//! `simd_multiplier = tiled_scalar / tiled_simd`, and dumped
//! machine-readably to `BENCH_runtime.json` (schema
//! `sharp-bench-runtime/v3`) at the repo root so the perf trajectory is
//! tracked across PRs.
//!
//! Planner honesty ("planner regret"): every shape also sweeps the
//! tuner's ENTIRE candidate space (under the dispatched ISA), times
//! each candidate, and reports how far the auto plan's time sits above
//! the best-of-sweep — `regret = auto_time / best_time - 1`. Headline:
//! regret <= 10% on the swept shapes, and the auto plan never loses to
//! the old fixed default (ties expected on the fixed point's
//! sweet-spot shapes, where auto picks the same geometry — the
//! measurement is then shared, because timing one configuration twice
//! and reporting an inequality between the two runs would be noise,
//! not signal).
//!
//! Self-contained: weights are synthetic (no `artifacts/` needed), and
//! every measurement — including each swept candidate — is guarded by
//! a bit-equality check against the scalar oracle *under the exact
//! plan being timed*: the ISA rides on `plan.geometry.isa`, so the
//! guarded pass and the timed passes dispatch the same kernel variant
//! by construction. The speedup numbers can never come from a kernel
//! that drifted, nor from guarding one variant while timing another.

mod util;

#[path = "../tests/common/mod.rs"]
mod common;

use std::collections::BTreeMap;
use std::path::PathBuf;

use common::assert_bits_eq;
use sharp::runtime::exec;
use sharp::runtime::kernel::{gru_seq_into, lstm_seq_into, ExecScratch};
use sharp::runtime::plan::{tuner, ExecPlan, Isa, KernelGeometry, ModelDims, PlanMode};
use sharp::runtime::RuntimeConfig;
use sharp::util::json::{self, Json};
use sharp::util::rng::Rng;

#[derive(Clone, Copy, PartialEq)]
enum Kind {
    Lstm,
    Gru,
}

struct Shape {
    name: &'static str,
    kind: Kind,
    t: usize,
    b: usize,
    d: usize,
    h: usize,
}

impl Shape {
    fn dims(&self) -> ModelDims {
        match self.kind {
            Kind::Lstm => ModelDims::lstm(self.d, self.h, self.b, self.t),
            Kind::Gru => ModelDims::gru(self.d, self.h, self.b, self.t),
        }
    }
}

/// Synthetic tensors for one shape, plus the oracle output every tiled
/// measurement is checked against.
struct ShapeData {
    xs: Vec<f32>,
    h0: Vec<f32>,
    c0: Vec<f32>,
    wx: Vec<f32>,
    wh: Vec<f32>,
    bias: Vec<f32>,
    hs_ref: Vec<f32>,
}

impl ShapeData {
    fn new(shape: &Shape) -> ShapeData {
        let (t, b, d, h) = (shape.t, shape.b, shape.d, shape.h);
        let gates = match shape.kind {
            Kind::Lstm => 4,
            Kind::Gru => 3,
        };
        let mut rng = Rng::new(0xBEEF ^ (t as u64) ^ ((h as u64) << 16));
        let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
        let h0 = rng.vec_f32(b * h, -1.0, 1.0);
        let c0 = rng.vec_f32(b * h, -1.0, 1.0);
        let wx = rng.vec_f32(d * gates * h, -0.2, 0.2);
        let wh = rng.vec_f32(h * gates * h, -0.2, 0.2);
        let bias = rng.vec_f32(gates * h, -0.1, 0.1);
        let hs_ref = match shape.kind {
            Kind::Lstm => exec::lstm_seq(&xs, &h0, &c0, &wx, &wh, &bias, t, b, d, h).0,
            Kind::Gru => exec::gru_seq(&xs, &h0, &wx, &wh, &bias, t, b, d, h).0,
        };
        ShapeData {
            xs,
            h0,
            c0,
            wx,
            wh,
            bias,
            hs_ref,
        }
    }
}

/// One tiled forward pass under a plan, into reused buffers.
#[allow(clippy::too_many_arguments)]
fn forward(
    shape: &Shape,
    data: &ShapeData,
    plan: &ExecPlan,
    threads: usize,
    scr: &mut ExecScratch,
    hs: &mut Vec<f32>,
    h_t: &mut Vec<f32>,
    c_t: &mut Vec<f32>,
) {
    let (t, b, d, h) = (shape.t, shape.b, shape.d, shape.h);
    match shape.kind {
        Kind::Lstm => lstm_seq_into(
            &data.xs, &data.h0, &data.c0, &data.wx, &data.wh, &data.bias, t, b, d, h, plan,
            threads, scr, hs, h_t, c_t,
        ),
        Kind::Gru => gru_seq_into(
            &data.xs, &data.h0, &data.wx, &data.wh, &data.bias, t, b, d, h, plan, threads, scr,
            hs, h_t,
        ),
    }
}

/// FLOPs of one full forward pass: the two fused GEMMs (mul + add each),
/// which dominate; activations are excluded like every GEMM bench does.
fn flops(s: &Shape) -> f64 {
    let gates = match s.kind {
        Kind::Lstm => 4,
        Kind::Gru => 3,
    };
    2.0 * (s.t * s.b * (s.d + s.h) * gates * s.h) as f64
}

#[derive(Clone)]
struct Variant {
    label: &'static str,
    min_s: f64,
    gflops: f64,
}

/// Time one tiled configuration: bit-check first (which also packs the
/// panels, keeping one-time pack cost out of the timings), then run
/// `iters` measured passes.
fn bench_plan(
    shape: &Shape,
    data: &ShapeData,
    plan: &ExecPlan,
    threads: usize,
    label: &'static str,
    iters: usize,
) -> Variant {
    let mut scr = ExecScratch::new();
    let (mut hs, mut h_t, mut c_t) = (Vec::new(), Vec::new(), Vec::new());
    forward(shape, data, plan, threads, &mut scr, &mut hs, &mut h_t, &mut c_t);
    assert_bits_eq(
        &hs,
        &data.hs_ref,
        &format!("{}::{label} plan={}", shape.name, plan.describe()),
    );
    let r = util::bench(&format!("runtime::{}::{label}", shape.name), iters, &mut || {
        forward(shape, data, plan, threads, &mut scr, &mut hs, &mut h_t, &mut c_t);
        std::hint::black_box(hs.last());
    });
    let gflops = flops(shape) / r.min_s / 1e9;
    Variant {
        label,
        min_s: r.min_s,
        gflops,
    }
}

/// The planner-regret block for one shape: sweep every tuner candidate,
/// time each, and relate the auto plan to the best of the sweep.
struct Regret {
    auto_plan: ExecPlan,
    best_plan: ExecPlan,
    best_gflops: f64,
    regret: f64,
    swept: usize,
}

fn sweep_regret(
    shape: &Shape,
    data: &ShapeData,
    auto_plan: &ExecPlan,
    iters: usize,
    isa: Isa,
) -> Regret {
    let sweep_iters = (iters / 8).max(2);
    let cands = tuner::enumerate(&shape.dims(), isa);
    let mut best_s = f64::INFINITY;
    let mut best_plan = *auto_plan;
    let mut auto_s = f64::INFINITY;
    for c in &cands {
        let v = bench_plan(shape, data, &c.plan, 1, "sweep", sweep_iters);
        if c.plan == *auto_plan {
            auto_s = v.min_s;
        }
        if v.min_s < best_s {
            best_s = v.min_s;
            best_plan = c.plan;
        }
    }
    debug_assert!(auto_s.is_finite(), "auto plan is always a candidate");
    Regret {
        auto_plan: *auto_plan,
        best_plan,
        best_gflops: flops(shape) / best_s / 1e9,
        regret: auto_s / best_s - 1.0,
        swept: cands.len(),
    }
}

fn bench_shape(shape: &Shape, mt_threads: usize, isa: Isa) -> (Vec<Variant>, Regret, ExecPlan) {
    let data = ShapeData::new(shape);
    let dims = shape.dims();
    let auto_scalar = tuner::plan_auto(&dims, Isa::Scalar);
    let auto_simd = tuner::plan_auto(&dims, isa);
    let fixed_plan = tuner::plan_for(&dims, &PlanMode::Fixed(KernelGeometry::fixed_default()), isa);

    // ~0.3 GFLOP per timed pass keeps big shapes at a few iterations and
    // small ones statistically meaningful.
    let iters = (3e8 / flops(shape)).ceil().clamp(3.0, 40.0) as usize;

    let mut out = Vec::new();
    let scalar_iters = iters;
    let r = util::bench(&format!("runtime::{}::scalar", shape.name), scalar_iters, &mut || {
        match shape.kind {
            Kind::Lstm => {
                std::hint::black_box(exec::lstm_seq(
                    &data.xs, &data.h0, &data.c0, &data.wx, &data.wh, &data.bias, shape.t,
                    shape.b, shape.d, shape.h,
                ));
            }
            Kind::Gru => {
                std::hint::black_box(exec::gru_seq(
                    &data.xs, &data.h0, &data.wx, &data.wh, &data.bias, shape.t, shape.b,
                    shape.d, shape.h,
                ));
            }
        }
    });
    out.push(Variant {
        label: "scalar",
        min_s: r.min_s,
        gflops: flops(shape) / r.min_s / 1e9,
    });

    // "tiled_simd" is the shipped path: the auto plan on the dispatched
    // ISA, serial. "tiled_scalar" is the same planner pinned to the
    // scalar kernels — the pair isolates vectorization from tiling, and
    // their ratio is the per-shape simd_multiplier. "fixed" is the PR 3
    // operating point (on the dispatched ISA). Whenever two of these
    // resolve to the very same plan the configurations are identical,
    // so the measurement is shared (a delta between two timings of one
    // configuration would be pure timer noise) — in particular on a
    // scalar-only host, where tiled_simd IS tiled_scalar.
    let tiled_scalar = bench_plan(shape, &data, &auto_scalar, 1, "tiled_scalar", iters);
    let tiled_simd = if auto_simd == auto_scalar {
        Variant {
            label: "tiled_simd",
            ..tiled_scalar.clone()
        }
    } else {
        bench_plan(shape, &data, &auto_simd, 1, "tiled_simd", iters)
    };
    let fixed = if fixed_plan == auto_simd {
        Variant {
            label: "fixed",
            ..tiled_simd.clone()
        }
    } else if fixed_plan == auto_scalar {
        Variant {
            label: "fixed",
            ..tiled_scalar.clone()
        }
    } else {
        bench_plan(shape, &data, &fixed_plan, 1, "fixed", iters)
    };
    let tiled_mt = bench_plan(shape, &data, &auto_simd, mt_threads, "tiled_mt", iters);
    out.push(tiled_scalar);
    out.push(tiled_simd);
    out.push(fixed);
    out.push(tiled_mt);

    let regret = sweep_regret(shape, &data, &auto_simd, iters, isa);
    (out, regret, auto_simd)
}

/// `BENCH_runtime.json` lands at the repo root by default; `--out
/// <path>` / `SHARP_BENCH_OUT` relocate it (see [`util::out_path`]).
fn out_path() -> PathBuf {
    util::out_path("BENCH_runtime.json")
}

fn main() {
    let mt_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    // Honors SHARP_FORCE_KERNEL / detection, exactly like the serving
    // path — a forced-scalar run reports simd_multiplier = 1.0.
    let isa = RuntimeConfig::default()
        .resolve_isa()
        .expect("kernel ISA resolves");
    println!(
        "kernel isa: {} ({} f32 lane{})\n",
        isa.name(),
        isa.lanes(),
        if isa.lanes() == 1 { "" } else { "s" }
    );
    let shapes = [
        Shape {
            name: "lstm_h256_t16_b4",
            kind: Kind::Lstm,
            t: 16,
            b: 4,
            d: 256,
            h: 256,
        },
        // The acceptance shape: H=1024 LSTM, tiled vs scalar single-thread.
        Shape {
            name: "lstm_h1024_t16_b4",
            kind: Kind::Lstm,
            t: 16,
            b: 4,
            d: 1024,
            h: 1024,
        },
        Shape {
            name: "lstm_h256_t32_b1",
            kind: Kind::Lstm,
            t: 32,
            b: 1,
            d: 256,
            h: 256,
        },
        // Off the fixed point's sweet spot: a single streaming frame
        // (T=1, B=1) — the planner schedules it stepwise with an
        // M=1-shaped tile instead of the batch-oriented default.
        Shape {
            name: "lstm_h512_t1_b1",
            kind: Kind::Lstm,
            t: 1,
            b: 1,
            d: 512,
            h: 512,
        },
        Shape {
            name: "gru_h512_t16_b4",
            kind: Kind::Gru,
            t: 16,
            b: 4,
            d: 512,
            h: 512,
        },
    ];

    let mut rows = Vec::new();
    let mut simd_at_h1024 = 1.0f64;
    for shape in &shapes {
        println!(
            "shape {} (T={} B={} D={} H={}, {:.2} GFLOP/pass)",
            shape.name,
            shape.t,
            shape.b,
            shape.d,
            shape.h,
            flops(shape) / 1e9
        );
        let (variants, regret, auto_plan) = bench_shape(shape, mt_threads, isa);
        let scalar_s = variants[0].min_s;
        // variants = [scalar, tiled_scalar, tiled_simd, fixed, tiled_mt]
        let simd_multiplier = variants[1].min_s / variants[2].min_s;
        if shape.name == "lstm_h1024_t16_b4" {
            simd_at_h1024 = simd_multiplier;
        }
        let mut obj = BTreeMap::new();
        obj.insert("name".into(), Json::Str(shape.name.into()));
        obj.insert(
            "kind".into(),
            Json::Str(
                match shape.kind {
                    Kind::Lstm => "lstm",
                    Kind::Gru => "gru",
                }
                .into(),
            ),
        );
        for (key, v) in [("T", shape.t), ("B", shape.b), ("D", shape.d), ("H", shape.h)] {
            obj.insert(key.into(), Json::Num(v as f64));
        }
        obj.insert("flops_per_pass".into(), Json::Num(flops(shape)));
        for v in &variants {
            let mut vj = BTreeMap::new();
            vj.insert("min_s".into(), Json::Num(v.min_s));
            vj.insert("gflops".into(), Json::Num(v.gflops));
            vj.insert("speedup_vs_scalar".into(), Json::Num(scalar_s / v.min_s));
            obj.insert(v.label.into(), Json::Obj(vj));
            println!(
                "    {:<12} {:8.2} GFLOP/s ({:.2}x scalar)",
                v.label,
                v.gflops,
                scalar_s / v.min_s
            );
        }
        obj.insert("simd_multiplier".into(), Json::Num(simd_multiplier));
        println!("    simd         {simd_multiplier:.2}x tiled_scalar (isa {})", isa.name());
        let mut pj = BTreeMap::new();
        pj.insert("chosen".into(), Json::Str(auto_plan.describe()));
        pj.insert(
            "best_of_sweep".into(),
            Json::Str(regret.best_plan.describe()),
        );
        pj.insert("best_gflops".into(), Json::Num(regret.best_gflops));
        pj.insert("regret".into(), Json::Num(regret.regret));
        pj.insert("candidates_swept".into(), Json::Num(regret.swept as f64));
        obj.insert("planner".into(), Json::Obj(pj));
        println!(
            "    planner   chosen {} | regret {:+.1}% vs best-of-{} sweep ({})",
            regret.auto_plan.describe(),
            regret.regret * 100.0,
            regret.swept,
            regret.best_plan.describe()
        );
        rows.push(Json::Obj(obj));
        println!();
    }

    println!(
        "headline: tiled_simd vs tiled_scalar at lstm_h1024_t16_b4 = {simd_at_h1024:.2}x \
         (target >= 2x when a vector ISA is dispatched; this run: {})",
        isa.name()
    );

    let mut root = BTreeMap::new();
    root.insert("schema".into(), Json::Str("sharp-bench-runtime/v3".into()));
    root.insert("threads_mt".into(), Json::Num(mt_threads as f64));
    let mut ij = BTreeMap::new();
    ij.insert("name".into(), Json::Str(isa.name().into()));
    ij.insert("lanes".into(), Json::Num(isa.lanes() as f64));
    root.insert("isa".into(), Json::Obj(ij));
    root.insert("simd_multiplier_at_h1024".into(), Json::Num(simd_at_h1024));
    root.insert("shapes".into(), Json::Arr(rows));
    let path = out_path();
    match std::fs::write(&path, json::write(&Json::Obj(root))) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
