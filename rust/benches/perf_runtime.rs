//! Perf bench: the artifact-execution hot path (§Perf runtime). Measures
//! the end-to-end per-request cost of the AOT LSTM artifacts the
//! coordinator serves — load once (cached), then repeated execution.
//!
//! Skips gracefully when `artifacts/` has not been built.

mod util;

use sharp::runtime::{ArtifactStore, LstmExecutable};

fn main() {
    let store = match ArtifactStore::open_default() {
        Ok(s) => s,
        Err(e) => {
            println!("perf_runtime: skipped (no artifacts: {e:#})");
            return;
        }
    };

    for name in ["cell_h64_b1", "cell_h256_b1", "seq_h64_t8_b1", "seq_h256_t16_b4"] {
        if store.manifest.find(name).is_none() {
            println!("perf_runtime: {name} not in manifest, skipping");
            continue;
        }
        let exe = LstmExecutable::from_store_goldens(&store, name).expect("bind artifact");
        let entry = exe.entry.clone();
        let is_seq = entry.kind == "seq";
        let xs_meta = entry
            .inputs
            .iter()
            .find(|i| i.name == if is_seq { "xs" } else { "x" })
            .expect("xs input");
        let xs = store.golden(xs_meta).expect("golden xs");
        let h0 = store
            .golden(entry.inputs.iter().find(|i| i.name == "h0").unwrap())
            .unwrap();
        let c0 = store
            .golden(entry.inputs.iter().find(|i| i.name == "c0").unwrap())
            .unwrap();
        let iters = if is_seq { 10 } else { 30 };
        util::bench(&format!("runtime::{name}"), iters, || {
            exe.run(&xs, &h0, &c0).expect("execute")
        });
    }
}
