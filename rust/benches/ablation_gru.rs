//! Ablation bench: paper §8's generality claim — "the same improvement
//! can be achieved in other networks that have similar design, such as
//! GRU". Reruns the Fig. 11 scheduler comparison with GRU cells and
//! reports the Unfolded speedup side by side with the LSTM's.

mod util;

use sharp::config::presets::{HIDDEN_SWEEP, MAC_BUDGETS};
use sharp::config::{CellKind, LstmConfig, SharpConfig};
use sharp::sched::ScheduleKind;
use sharp::sim::simulate;
use sharp::util::table::{fnum, Table};

fn unfolded_speedup(cfg: &SharpConfig, model: &LstmConfig) -> f64 {
    let seq = simulate(cfg, model, ScheduleKind::Sequential).cycles as f64;
    let unf = simulate(cfg, model, ScheduleKind::Unfolded).cycles as f64;
    seq / unf
}

fn main() {
    util::bench("ablation::gru_grid", 10, || {
        let mut acc = 0u64;
        for &macs in &MAC_BUDGETS {
            let cfg = SharpConfig::with_macs(macs);
            for &h in &HIDDEN_SWEEP {
                let gru = LstmConfig::square(h).with_cell(CellKind::Gru);
                acc ^= simulate(&cfg, &gru, ScheduleKind::Unfolded).cycles;
            }
        }
        acc
    });

    let mut t = Table::new("Unfolded speedup vs Sequential: LSTM / GRU (T=25)")
        .header(&["hidden", "1K", "4K", "16K", "64K"]);
    for &h in &HIDDEN_SWEEP {
        let mut row = vec![h.to_string()];
        for &macs in &MAC_BUDGETS {
            let cfg = SharpConfig::with_macs(macs).with_k(32);
            let lstm = LstmConfig::square(h);
            let gru = LstmConfig::square(h).with_cell(CellKind::Gru);
            row.push(format!(
                "{}/{}",
                fnum(unfolded_speedup(&cfg, &lstm)),
                fnum(unfolded_speedup(&cfg, &gru))
            ));
        }
        t.row(&row);
    }
    println!("\n{}", t.render());
    println!(
        "paper §8: 'the same improvement can be achieved in other networks\n\
         that have similar design, such as GRU' — the GRU column should\n\
         track the LSTM column (same dependency structure, 3 gates)."
    );

    // Sanity assertion for `cargo bench` CI use: GRU speedups are within
    // 35% of LSTM's at every grid point.
    for &h in &HIDDEN_SWEEP {
        for &macs in &MAC_BUDGETS {
            let cfg = SharpConfig::with_macs(macs).with_k(32);
            let l = unfolded_speedup(&cfg, &LstmConfig::square(h));
            let g = unfolded_speedup(&cfg, &LstmConfig::square(h).with_cell(CellKind::Gru));
            assert!(
                (g / l - 1.0).abs() < 0.35,
                "h={h} macs={macs}: lstm {l:.2} vs gru {g:.2}"
            );
        }
    }
    println!("GRU-tracks-LSTM assertion: OK");
}
