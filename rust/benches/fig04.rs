//! Bench: regenerate paper exhibit fig04 (see DESIGN.md §5 for the
//! exhibit index and experiments/fig04.rs for the generator).
mod util;

fn main() {
    util::exhibit_bench("fig04", 5);
}
