//! Perf bench: the TCP serving front-end vs the in-process API
//! (DESIGN.md §13) — the same worker pool driven two ways at 1/8/32
//! concurrent connections: `Server::infer` straight from threads
//! (in-process baseline) vs `NetClient::request` over loopback framing
//! (length-prefix wire, per-request round trip). Reported as req/s plus
//! p50/p99 per level and dumped to `BENCH_net.json` at the repo root.
//!
//! Self-contained: a synthetic on-disk artifact store (via the shared
//! `tests/common/` harness) with seeded golden weights, no `make
//! artifacts` needed.
//!
//! Headline (ISSUE 10 acceptance): a chaos-ARMED front-end whose fault
//! plan never fires (it targets an accept ordinal that never arrives)
//! costs <= 2% req/s vs the unarmed front-end — arming the failure
//! matrix must be free enough to leave on everywhere.

mod util;

#[path = "../tests/common/mod.rs"]
mod common;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Barrier;
use std::time::{Duration, Instant};

use common::{seq_entry_goldens, synth_store, write_lstm_goldens};
use sharp::coordinator::net::{Listener, NetClient, NetConfig, NetRequest};
use sharp::coordinator::{FaultPlan, InferenceRequest, Server, ServerConfig};
use sharp::util::json::{self, Json};
use sharp::util::rng::Rng;
use sharp::util::stats::Samples;

const H: usize = 64;
const T: usize = 4;
const SEED: u64 = 0xBE7C_0E7;
const CONNS: [usize; 3] = [1, 8, 32];
/// Requests per connection in a measured pass.
const REQS: usize = 64;
/// Timed passes per configuration; req/s is the best pass (loopback
/// timing is scheduler-noisy), percentiles pool every pass.
const PASSES: usize = 3;

fn net_store(tag: &str) -> PathBuf {
    let (dir, _store) = synth_store(tag, &seq_entry_goldens("seq_h64_t4_b1", T, 1, H, H, "w"));
    write_lstm_goldens(&dir, "w", H, H, SEED);
    dir
}

fn pool(dir: &Path) -> Server {
    Server::start(ServerConfig {
        artifact_dir: Some(dir.to_path_buf()),
        hidden: vec![H],
        workers: 2,
        ..Default::default()
    })
    .expect("server start")
}

/// Per-connection request payload, fixed across passes and identical
/// for the in-process and TCP runs.
fn payloads(conns: usize) -> Vec<Vec<f32>> {
    (0..conns)
        .map(|c| Rng::new(SEED ^ c as u64).vec_f32(T * H, -1.0, 1.0))
        .collect()
}

/// One measured pass: `conns` threads, `REQS` requests each, clock
/// started at a barrier AFTER every thread has connected/warmed.
/// Returns (wall seconds, per-request latencies).
fn pass(conns: usize, run_conn: impl Fn(usize, &Barrier) -> Vec<f64> + Sync) -> (f64, Vec<f64>) {
    let barrier = Barrier::new(conns + 1);
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..conns {
            let barrier = &barrier;
            let run_conn = &run_conn;
            handles.push(scope.spawn(move || run_conn(c, barrier)));
        }
        barrier.wait();
        let t0 = Instant::now();
        let lat: Vec<f64> = handles.into_iter().flat_map(|h| h.join().expect("conn thread")).collect();
        (t0.elapsed().as_secs_f64(), lat)
    })
}

fn inproc_pass(server: &Server, conns: usize, pay: &[Vec<f32>]) -> (f64, Vec<f64>) {
    pass(conns, |c, barrier| {
        let req = |id: u64| InferenceRequest::new(id, T, pay[c].clone()).with_hidden(H);
        server.infer(req(u64::MAX)).expect("warm request");
        barrier.wait();
        let mut lat = Vec::with_capacity(REQS);
        for i in 0..REQS {
            let t0 = Instant::now();
            server
                .infer(req(((c as u64) << 32) | i as u64))
                .expect("in-process request");
            lat.push(t0.elapsed().as_secs_f64());
        }
        lat
    })
}

fn net_pass(addr: &str, conns: usize, pay: &[Vec<f32>]) -> (f64, Vec<f64>) {
    pass(conns, |c, barrier| {
        let mut client =
            NetClient::connect(addr.to_string(), Duration::from_secs(30)).expect("connect");
        let mut req = NetRequest::new(u64::MAX, T as u32, pay[c].clone());
        req.hidden = Some(H as u32);
        client.request(&req, 0).expect("warm transport").expect("warm verdict");
        barrier.wait();
        let mut lat = Vec::with_capacity(REQS);
        for i in 0..REQS {
            req.id = ((c as u64) << 32) | i as u64;
            let t0 = Instant::now();
            client
                .request(&req, 0)
                .expect("transport")
                .expect("verdict");
            lat.push(t0.elapsed().as_secs_f64());
        }
        lat
    })
}

/// Best-pass req/s plus pooled latency percentiles over `PASSES` runs.
fn measure(
    label: &str,
    conns: usize,
    mut one: impl FnMut() -> (f64, Vec<f64>),
) -> (f64, Samples) {
    let total = (conns * REQS) as f64;
    let mut best = f64::INFINITY;
    let mut lat = Samples::new();
    for _ in 0..PASSES {
        let (wall, l) = one();
        best = best.min(wall);
        for v in l {
            lat.push(v);
        }
    }
    let rps = total / best.max(1e-9);
    println!(
        "    {label:<18} {rps:>9.0} req/s | p50={:.3}ms p99={:.3}ms",
        lat.p50() * 1e3,
        lat.p99() * 1e3
    );
    (rps, lat)
}

fn main() {
    let dir = net_store("bench_net");
    // Two pools over the SAME store: one behind TCP, one driven
    // in-process — identical weights, identical kernels.
    let inproc = pool(&dir);
    let listener = Listener::start(pool(&dir), NetConfig::default()).expect("listener");
    let addr = listener.local_addr().to_string();
    // The armed twin: a real fault plan whose accept ordinal never
    // arrives, so every frame pays the arming check and nothing fires.
    let armed = Listener::start(
        pool(&dir),
        NetConfig {
            faults: Some(FaultPlan::parse("garble@conn999983:frame1").expect("plan")),
            ..NetConfig::default()
        },
    )
    .expect("armed listener");
    let armed_addr = armed.local_addr().to_string();

    println!(
        "net front-end: H={H} T={T}, {REQS} req/conn x {PASSES} passes, loopback {addr}"
    );

    let mut rows = Vec::new();
    let mut plain_at_8 = 0.0f64;
    for &conns in &CONNS {
        println!("  conns={conns}");
        let pay = payloads(conns);
        let (in_rps, mut in_lat) =
            measure("in-process", conns, || inproc_pass(&inproc, conns, &pay));
        let (net_rps, mut net_lat) =
            measure("tcp loopback", conns, || net_pass(&addr, conns, &pay));
        if conns == 8 {
            plain_at_8 = net_rps;
        }
        let mut obj = BTreeMap::new();
        obj.insert("conns".into(), Json::Num(conns as f64));
        obj.insert("requests".into(), Json::Num((conns * REQS) as f64));
        obj.insert("inproc_req_per_s".into(), Json::Num(in_rps));
        obj.insert("net_req_per_s".into(), Json::Num(net_rps));
        obj.insert("net_vs_inproc".into(), Json::Num(net_rps / in_rps.max(1e-9)));
        obj.insert("inproc_p50_s".into(), Json::Num(in_lat.p50()));
        obj.insert("inproc_p99_s".into(), Json::Num(in_lat.p99()));
        obj.insert("net_p50_s".into(), Json::Num(net_lat.p50()));
        obj.insert("net_p99_s".into(), Json::Num(net_lat.p99()));
        rows.push(Json::Obj(obj));
    }

    // Chaos-armed overhead at the middle level.
    println!("  chaos-armed (never fires), conns=8");
    let pay = payloads(8);
    let (armed_rps, _lat) = measure("tcp armed", 8, || net_pass(&armed_addr, 8, &pay));
    let overhead = (plain_at_8 / armed_rps.max(1e-9)) - 1.0;
    println!(
        "headline: chaos-armed-never-firing overhead = {:.2}% (target <= 2%)",
        overhead * 100.0
    );

    let mut root = BTreeMap::new();
    root.insert("schema".into(), Json::Str("sharp-bench-net/v1".into()));
    for (key, v) in [("H", H), ("T", T), ("reqs_per_conn", REQS), ("passes", PASSES)] {
        root.insert(key.into(), Json::Num(v as f64));
    }
    root.insert("levels".into(), Json::Arr(rows));
    let mut cj = BTreeMap::new();
    cj.insert("plain_req_per_s".into(), Json::Num(plain_at_8));
    cj.insert("armed_req_per_s".into(), Json::Num(armed_rps));
    cj.insert("overhead_frac".into(), Json::Num(overhead));
    root.insert("chaos_armed".into(), Json::Obj(cj));
    let path = util::out_path("BENCH_net.json");
    match std::fs::write(&path, json::write(&Json::Obj(root))) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }

    listener.drain();
    listener.wait().expect("drain");
    armed.drain();
    armed.wait().expect("drain armed");
    inproc.shutdown();
}
