//! Perf bench: coordinator machinery without model execution — batcher throughput,
//! routing/dispatch planning, adaptive-controller overhead, trace
//! generation — the L3 costs that must never rival the model-execution
//! time (§Perf L3: "L3 should not be the bottleneck") — plus, when
//! artifacts are present, end-to-end throughput scaling of the worker
//! pool from 1 to 4 replicas and the fault-machinery overhead guard:
//! with no `FaultPlan` and no deadlines the supervised dispatch path
//! must stay within 2% of the same path with the machinery armed (the
//! pre-supervision dispatch no longer exists, so armed-but-never-firing
//! vs disabled is the live A/B for "the hot path pays nothing").
//! Results land in `BENCH_coordinator.json` (`--out` / `SHARP_BENCH_OUT`
//! relocate it).

mod util;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use sharp::coordinator::adaptive::{AdaptiveConfig, AdaptiveController};
use sharp::coordinator::batcher::{Batcher, BatcherConfig};
use sharp::coordinator::request::InferenceRequest;
use sharp::coordinator::routing;
use sharp::coordinator::{FaultPlan, Server, ServerConfig};
use sharp::runtime::ArtifactStore;
use sharp::util::json::{self, Json};
use sharp::util::rng::Rng;
use sharp::workloads::{TraceConfig, TraceKind};

fn main() {
    let mut micro = BTreeMap::new();

    let r = util::bench("coordinator::batcher(10k reqs)", 50, || {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        });
        let mut batches = 0usize;
        for i in 0..10_000u64 {
            // Payload-free envelope: measures pure batching overhead.
            if b.push(InferenceRequest::new(i, 4, Vec::new())).is_some() {
                batches += 1;
            }
        }
        batches
    });
    micro.insert("batcher_10k_min_s".to_string(), Json::Num(r.min_s));

    let r = util::bench("coordinator::routing(10k plans)", 50, || {
        // The dispatcher's entire per-request decision: affinity hash
        // for sessions, queue-aware planning for stateless traffic.
        let depths = [3usize, 0, 7, 2];
        let mut acc = 0usize;
        for i in 0..10_000u64 {
            acc += if i % 4 == 0 {
                routing::session_worker(i, depths.len())
            } else {
                routing::plan_dispatch(&depths, 8, i as usize % depths.len())
            };
        }
        acc
    });
    micro.insert("routing_10k_min_s".to_string(), Json::Num(r.min_s));

    let r = util::bench("coordinator::adaptive(10k arrivals)", 50, || {
        // Controller cost per arrival (EWMA + two-field replan): must
        // stay negligible, mirroring the §6.2 reconfiguration contract.
        let mut c = AdaptiveController::new(
            AdaptiveConfig::default(),
            BatcherConfig::default(),
            8,
        );
        let t0 = Instant::now();
        for i in 0..10_000u32 {
            c.observe_arrival(t0 + Duration::from_micros(u64::from(i) * 37));
        }
        c.policy().max_batch
    });
    micro.insert("adaptive_10k_min_s".to_string(), Json::Num(r.min_s));

    let r = util::bench("workloads::trace(1k x T16 x D256)", 20, || {
        TraceConfig {
            kind: TraceKind::Poisson,
            n_requests: 1000,
            rate_rps: 500.0,
            seq_lens: vec![8, 16],
            input_dim: 256,
            seed: 42,
        }
        .generate()
        .len()
    });
    micro.insert("trace_1k_min_s".to_string(), Json::Num(r.min_s));

    let prologue_ns = fault_prologue();
    let fault = fault_overhead();
    let scaling = worker_scaling();

    let mut root = BTreeMap::new();
    root.insert(
        "schema".to_string(),
        Json::Str("sharp-bench-coordinator/v1".into()),
    );
    root.insert("micro".to_string(), Json::Obj(micro));
    let mut fo = BTreeMap::new();
    fo.insert(
        "prologue_ns_per_msg".to_string(),
        Json::Num(prologue_ns),
    );
    match fault {
        Some((disabled_rps, armed_rps)) => {
            fo.insert("disabled_rps".to_string(), Json::Num(disabled_rps));
            fo.insert("armed_rps".to_string(), Json::Num(armed_rps));
            fo.insert(
                "armed_over_disabled".to_string(),
                Json::Num(armed_rps / disabled_rps.max(1e-9)),
            );
        }
        None => {
            fo.insert("e2e".to_string(), Json::Str("skipped (no artifacts)".into()));
        }
    }
    root.insert("fault_overhead".to_string(), Json::Obj(fo));
    let mut sc = BTreeMap::new();
    match scaling {
        Some((w1, w4)) => {
            sc.insert("w1_rps".to_string(), Json::Num(w1));
            sc.insert("w4_rps".to_string(), Json::Num(w4));
            sc.insert("speedup".to_string(), Json::Num(w4 / w1.max(1e-9)));
        }
        None => {
            sc.insert("e2e".to_string(), Json::Str("skipped (no artifacts)".into()));
        }
    }
    root.insert("scaling".to_string(), Json::Obj(sc));

    let path = util::out_path("BENCH_coordinator.json");
    match std::fs::write(&path, json::write(&Json::Obj(root))) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}

/// Everything PR 8 added to the worker's per-message prologue, measured
/// in isolation with the machinery DISABLED (no plan, no deadline): a
/// heartbeat store (one clock read + one relaxed atomic store), a fault
/// ordinal bump, and two `Option` checks. Reported as ns/message — the
/// absolute price every dequeue pays for supervision.
fn fault_prologue() -> f64 {
    const N: u64 = 1_000_000;
    let heartbeat = AtomicU64::new(0);
    let epoch = Instant::now();
    let plan: Option<FaultPlan> = None;
    let deadline: Option<Duration> = None;
    let r = util::bench("coordinator::fault_prologue(1M)", 10, || {
        let mut acc = 0u64;
        for ordinal in 0..N {
            heartbeat.store(epoch.elapsed().as_millis() as u64, Ordering::Relaxed);
            if let Some(p) = &plan {
                // Never taken when disabled; kept so the branch is real.
                acc = acc.wrapping_add(p.faults.len() as u64);
            }
            if deadline.is_some() {
                acc = acc.wrapping_add(1);
            }
            acc = acc.wrapping_add(ordinal);
        }
        acc
    });
    r.min_s * 1e9 / N as f64
}

/// Closed-loop burst throughput, fault machinery disabled (default
/// config: no `FaultPlan`, no deadlines) vs armed with a plan that
/// never fires (ordinal far past the burst). Interleaved A/B/A/B bursts
/// on two live pools cancel thermal drift; min-wall throughputs must
/// agree within 2% — the guard that supervision costs nothing on the
/// hot path. Needs `make artifacts`; skips without.
fn fault_overhead() -> Option<(f64, f64)> {
    if ArtifactStore::open_default().is_err() {
        println!("bench coordinator::fault_overhead   SKIP (no artifacts; run `make artifacts`)");
        return None;
    }
    let hidden = 256usize;
    let n = 192usize;
    let mut rng = Rng::new(11);
    let reqs: Vec<(usize, Vec<f32>)> = (0..n)
        .map(|_| {
            let len = rng.range_usize(4, 16);
            (len, rng.vec_f32(len * hidden, -1.0, 1.0))
        })
        .collect();
    let base = ServerConfig {
        hidden: vec![hidden],
        workers: 2,
        ..Default::default()
    };
    let disabled = Server::start(base.clone()).expect("disabled pool");
    let armed = Server::start(ServerConfig {
        faults: Some(FaultPlan::parse("panic@worker0:req1000000").expect("static plan")),
        ..base
    })
    .expect("armed pool");
    let burst = |server: &Server| -> f64 {
        let t0 = Instant::now();
        let rxs: Vec<_> = reqs
            .iter()
            .enumerate()
            .map(|(i, (len, payload))| {
                server.submit(InferenceRequest::new(i as u64, *len, payload.clone()))
            })
            .collect();
        let ok = rxs
            .into_iter()
            .filter(|rx| rx.recv().map(|r| r.is_ok()).unwrap_or(false))
            .count();
        assert_eq!(ok, n, "overhead burst must be fully served");
        t0.elapsed().as_secs_f64()
    };
    // Warmup both pools, then interleave measured bursts.
    let _ = burst(&disabled);
    let _ = burst(&armed);
    let (mut wall_d, mut wall_a) = (f64::INFINITY, f64::INFINITY);
    for _ in 0..5 {
        wall_d = wall_d.min(burst(&disabled));
        wall_a = wall_a.min(burst(&armed));
    }
    disabled.shutdown();
    armed.shutdown();
    let (rps_d, rps_a) = (n as f64 / wall_d, n as f64 / wall_a);
    let ratio = rps_a / rps_d.max(1e-9);
    println!(
        "bench coordinator::fault_overhead   disabled={rps_d:>8.0} rps armed={rps_a:>8.0} rps \
         ({:.1}% delta)",
        (ratio - 1.0).abs() * 100.0
    );
    assert!(
        ratio > 0.98,
        "fault machinery must cost <2% on the hot path: \
         disabled {rps_d:.0} rps vs armed {rps_a:.0} rps"
    );
    Some((rps_d, rps_a))
}

/// End-to-end pool scaling: closed-loop burst of real requests through
/// 1 then 4 worker replicas (needs `make artifacts`; skips without).
fn worker_scaling() -> Option<(f64, f64)> {
    if ArtifactStore::open_default().is_err() {
        println!("bench coordinator::scaling          SKIP (no artifacts; run `make artifacts`)");
        return None;
    }
    let hidden = 256usize;
    let n = 256usize;
    let mut rng = Rng::new(7);
    let reqs: Vec<(usize, Vec<f32>)> = (0..n)
        .map(|_| {
            let len = rng.range_usize(4, 16);
            (len, rng.vec_f32(len * hidden, -1.0, 1.0))
        })
        .collect();
    let mut base_rps = 0.0f64;
    let mut w4_rps = 0.0f64;
    for workers in [1usize, 4] {
        let server = Server::start(ServerConfig {
            hidden: vec![hidden],
            workers,
            ..Default::default()
        })
        .expect("server start");
        // Warmup wave so compile caches and batcher state are hot.
        for (len, payload) in reqs.iter().take(8) {
            let _ = server.infer(InferenceRequest::new(0, *len, payload.clone()));
        }
        let t0 = Instant::now();
        let rxs: Vec<_> = reqs
            .iter()
            .enumerate()
            .map(|(i, (len, payload))| {
                server.submit(InferenceRequest::new(i as u64, *len, payload.clone()))
            })
            .collect();
        let ok = rxs
            .into_iter()
            .filter(|rx| rx.recv().map(|r| r.is_ok()).unwrap_or(false))
            .count();
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(ok, n, "scaling burst must be fully served");
        let rps = n as f64 / wall;
        if workers == 1 {
            base_rps = rps;
            println!("bench coordinator::scaling(w=1)     {rps:>10.0} rps");
        } else {
            w4_rps = rps;
            println!(
                "bench coordinator::scaling(w={workers})     {rps:>10.0} rps ({:.2}x vs 1 worker)",
                rps / base_rps.max(1e-9)
            );
        }
        server.shutdown();
    }
    Some((base_rps, w4_rps))
}
