//! Perf bench: coordinator machinery without model execution — batcher throughput,
//! routing/dispatch planning, adaptive-controller overhead, trace
//! generation — the L3 costs that must never rival the model-execution
//! time (§Perf L3: "L3 should not be the bottleneck") — plus, when
//! artifacts are present, end-to-end throughput scaling of the worker
//! pool from 1 to 4 replicas.

mod util;

use std::time::{Duration, Instant};

use sharp::coordinator::adaptive::{AdaptiveConfig, AdaptiveController};
use sharp::coordinator::batcher::{Batcher, BatcherConfig};
use sharp::coordinator::request::InferenceRequest;
use sharp::coordinator::routing;
use sharp::coordinator::{Server, ServerConfig};
use sharp::runtime::ArtifactStore;
use sharp::util::rng::Rng;
use sharp::workloads::{TraceConfig, TraceKind};

fn main() {
    util::bench("coordinator::batcher(10k reqs)", 50, || {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        });
        let mut batches = 0usize;
        for i in 0..10_000u64 {
            // Payload-free envelope: measures pure batching overhead.
            if b.push(InferenceRequest::new(i, 4, Vec::new())).is_some() {
                batches += 1;
            }
        }
        batches
    });

    util::bench("coordinator::routing(10k plans)", 50, || {
        // The dispatcher's entire per-request decision: affinity hash
        // for sessions, queue-aware planning for stateless traffic.
        let depths = [3usize, 0, 7, 2];
        let mut acc = 0usize;
        for i in 0..10_000u64 {
            acc += if i % 4 == 0 {
                routing::session_worker(i, depths.len())
            } else {
                routing::plan_dispatch(&depths, 8, i as usize % depths.len())
            };
        }
        acc
    });

    util::bench("coordinator::adaptive(10k arrivals)", 50, || {
        // Controller cost per arrival (EWMA + two-field replan): must
        // stay negligible, mirroring the §6.2 reconfiguration contract.
        let mut c = AdaptiveController::new(
            AdaptiveConfig::default(),
            BatcherConfig::default(),
            8,
        );
        let t0 = Instant::now();
        for i in 0..10_000u32 {
            c.observe_arrival(t0 + Duration::from_micros(u64::from(i) * 37));
        }
        c.policy().max_batch
    });

    util::bench("workloads::trace(1k x T16 x D256)", 20, || {
        TraceConfig {
            kind: TraceKind::Poisson,
            n_requests: 1000,
            rate_rps: 500.0,
            seq_lens: vec![8, 16],
            input_dim: 256,
            seed: 42,
        }
        .generate()
        .len()
    });

    worker_scaling();
}

/// End-to-end pool scaling: closed-loop burst of real requests through
/// 1 then 4 worker replicas (needs `make artifacts`; skips without).
fn worker_scaling() {
    if ArtifactStore::open_default().is_err() {
        println!("bench coordinator::scaling          SKIP (no artifacts; run `make artifacts`)");
        return;
    }
    let hidden = 256usize;
    let n = 256usize;
    let mut rng = Rng::new(7);
    let reqs: Vec<(usize, Vec<f32>)> = (0..n)
        .map(|_| {
            let len = rng.range_usize(4, 16);
            (len, rng.vec_f32(len * hidden, -1.0, 1.0))
        })
        .collect();
    let mut base_rps = 0.0f64;
    for workers in [1usize, 4] {
        let server = Server::start(ServerConfig {
            hidden: vec![hidden],
            workers,
            ..Default::default()
        })
        .expect("server start");
        // Warmup wave so compile caches and batcher state are hot.
        for (len, payload) in reqs.iter().take(8) {
            let _ = server.infer(InferenceRequest::new(0, *len, payload.clone()));
        }
        let t0 = Instant::now();
        let rxs: Vec<_> = reqs
            .iter()
            .enumerate()
            .map(|(i, (len, payload))| {
                server.submit(InferenceRequest::new(i as u64, *len, payload.clone()))
            })
            .collect();
        let ok = rxs
            .into_iter()
            .filter(|rx| rx.recv().map(|r| r.is_ok()).unwrap_or(false))
            .count();
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(ok, n, "scaling burst must be fully served");
        let rps = n as f64 / wall;
        if workers == 1 {
            base_rps = rps;
            println!("bench coordinator::scaling(w=1)     {rps:>10.0} rps");
        } else {
            println!(
                "bench coordinator::scaling(w={workers})     {rps:>10.0} rps ({:.2}x vs 1 worker)",
                rps / base_rps.max(1e-9)
            );
        }
        server.shutdown();
    }
}
