//! Perf bench: coordinator machinery without model execution — batcher throughput,
//! trace generation, routing — the L3 costs that must never rival the
//! model-execution time (§Perf L3: "L3 should not be the bottleneck").

mod util;

use std::time::Duration;

use sharp::coordinator::batcher::{Batcher, BatcherConfig};
use sharp::coordinator::request::InferenceRequest;
use sharp::workloads::{TraceConfig, TraceKind};

fn main() {
    util::bench("coordinator::batcher(10k reqs)", 50, || {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        });
        let mut batches = 0usize;
        for i in 0..10_000u64 {
            // Payload-free envelope: measures pure batching overhead.
            if b.push(InferenceRequest::new(i, 4, Vec::new())).is_some() {
                batches += 1;
            }
        }
        batches
    });

    util::bench("workloads::trace(1k x T16 x D256)", 20, || {
        TraceConfig {
            kind: TraceKind::Poisson,
            n_requests: 1000,
            rate_rps: 500.0,
            seq_lens: vec![8, 16],
            input_dim: 256,
            seed: 42,
        }
        .generate()
        .len()
    });
}
