//! Bench: regenerate paper exhibit fig10 (see DESIGN.md §5 for the
//! exhibit index and experiments/fig10.rs for the generator).
mod util;

fn main() {
    util::exhibit_bench("fig10", 5);
}
