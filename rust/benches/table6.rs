//! Bench: regenerate paper exhibit table6 (see DESIGN.md §5 for the
//! exhibit index and experiments/table6.rs for the generator).
mod util;

fn main() {
    util::exhibit_bench("table6", 5);
}
