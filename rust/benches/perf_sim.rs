//! Perf bench: the cycle-simulator hot path (§Perf L3). The Fig. 9 sweep
//! is the heaviest consumer — hundreds of `simulate` calls — so the
//! per-call cost here bounds the whole experiment harness.

mod util;

use sharp::config::presets::{HIDDEN_SWEEP, MAC_BUDGETS};
use sharp::config::{LstmConfig, SharpConfig};
use sharp::sched::ScheduleKind;
use sharp::sim::simulate;

fn main() {
    // Single simulate call on the paper's largest sweep point.
    util::bench("sim::simulate(64K,h1500)", 200, || {
        let cfg = SharpConfig::with_macs(65536);
        let model = LstmConfig::square(1500);
        simulate(&cfg, &model, ScheduleKind::Unfolded).cycles
    });

    // One full scheduler x budget x dim sweep (the Fig. 11 grid).
    util::bench("sim::fig11_grid(96 runs)", 20, || {
        let mut acc = 0u64;
        for &macs in &MAC_BUDGETS {
            let cfg = SharpConfig::with_macs(macs);
            for &h in &HIDDEN_SWEEP {
                let model = LstmConfig::square(h);
                for k in ScheduleKind::ALL {
                    acc ^= simulate(&cfg, &model, k).cycles;
                }
            }
        }
        acc
    });

    // Deep stacked network (Table 6's RLDRADSPR: 10 layers x 400 steps).
    util::bench("sim::rldradspr(10x400)", 50, || {
        let cfg = SharpConfig::with_macs(16384);
        let model = sharp::config::presets::rldradspr();
        simulate(&cfg, &model, ScheduleKind::Unfolded).cycles
    });
}
