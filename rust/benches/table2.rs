//! Bench: regenerate paper exhibit table2 (see DESIGN.md §5 for the
//! exhibit index and experiments/table2.rs for the generator).
mod util;

fn main() {
    util::exhibit_bench("table2", 5);
}
