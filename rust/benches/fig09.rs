//! Bench: regenerate paper exhibit fig09 (see DESIGN.md §5 for the
//! exhibit index and experiments/fig09.rs for the generator).
mod util;

fn main() {
    util::exhibit_bench("fig09", 5);
}
