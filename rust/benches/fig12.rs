//! Bench: regenerate paper exhibit fig12 (see DESIGN.md §5 for the
//! exhibit index and experiments/fig12.rs for the generator).
mod util;

fn main() {
    util::exhibit_bench("fig12", 5);
}
