//! Perf bench: quantized int8 inference vs the f32 path (§Perf quant).
//! For each shape the auto-planned f32 kernel and the auto-planned int8
//! kernel run the same sequence workload; the report shows wall time,
//! GFLOP/s, and the per-shape `int8_multiplier = f32_time / int8_time`.
//! Headline: the multiplier on `lstm_h1024_t16_b4` — the shape where
//! weight traffic dominates and the 4x-smaller int8 panels pay off.
//!
//! Honesty guards, in order, BEFORE any timing:
//!   1. the f32 plan's output is bit-identical to the scalar oracle;
//!   2. the int8 plan's output sits within the documented quantization
//!      budget (5e-2 on h for +-0.3-span weights, DESIGN.md §12) of
//!      that same oracle.
//! The guard runs also latch the packed/quantized weight panels in the
//! scratch, so pack and quantize cost stays out of the timed region —
//! matching the serving reality (both happen once, at bind).
//!
//! Dumps `BENCH_quant.json` (schema `sharp-bench-quant/v1`) at the repo
//! root (`--out`/`SHARP_BENCH_OUT` relocate it) so the quant speedup is
//! tracked across PRs alongside `BENCH_runtime.json`.

mod util;

#[path = "../tests/common/mod.rs"]
mod common;

use std::collections::BTreeMap;

use common::{assert_bits_eq, assert_close};
use sharp::runtime::exec;
use sharp::runtime::kernel::{gru_seq_into, lstm_seq_into, ExecScratch};
use sharp::runtime::plan::{tuner, Dtype, ExecPlan, ModelDims};
use sharp::runtime::RuntimeConfig;
use sharp::util::json::{self, Json};
use sharp::util::rng::Rng;

const BUDGET: f32 = 5e-2;

#[derive(Clone, Copy, PartialEq)]
enum Kind {
    Lstm,
    Gru,
}

struct Shape {
    name: &'static str,
    kind: Kind,
    t: usize,
    b: usize,
    d: usize,
    h: usize,
}

const SHAPES: &[Shape] = &[
    Shape { name: "lstm_h1024_t16_b4", kind: Kind::Lstm, t: 16, b: 4, d: 1024, h: 1024 },
    Shape { name: "lstm_h256_t16_b4", kind: Kind::Lstm, t: 16, b: 4, d: 256, h: 256 },
    Shape { name: "gru_h512_t16_b2", kind: Kind::Gru, t: 16, b: 2, d: 512, h: 512 },
];

/// 2*(D + H)*G*H*B FLOPs per step, T steps.
fn model_flops(s: &Shape) -> f64 {
    let gates = if s.kind == Kind::Gru { 3 } else { 4 };
    2.0 * (s.d + s.h) as f64 * (gates * s.h * s.b) as f64 * s.t as f64
}

struct Timed {
    secs: f64,
    gflops: f64,
}

fn main() {
    let iters = 8;
    let isa = RuntimeConfig::default()
        .resolve_isa()
        .expect("default ISA resolution never fails");
    let mut rows = Vec::new();
    let mut headline = f64::NAN;
    println!("quant perf: int8 vs f32 under auto plans @ {}", isa.name());

    for s in SHAPES {
        let gates = if s.kind == Kind::Gru { 3 } else { 4 };
        let mut rng = Rng::new(0xBE9C);
        let xs = rng.vec_f32(s.t * s.b * s.d, -1.0, 1.0);
        let h0 = rng.vec_f32(s.b * s.h, -1.0, 1.0);
        let c0 = rng.vec_f32(s.b * s.h, -1.0, 1.0);
        let wx = rng.vec_f32(s.d * gates * s.h, -0.3, 0.3);
        let wh = rng.vec_f32(s.h * gates * s.h, -0.3, 0.3);
        let bias = rng.vec_f32(gates * s.h, -0.2, 0.2);
        let dims = match s.kind {
            Kind::Lstm => ModelDims::lstm(s.d, s.h, s.b, s.t),
            Kind::Gru => ModelDims::gru(s.d, s.h, s.b, s.t),
        };
        let flops = model_flops(s);

        let h_ref = match s.kind {
            Kind::Lstm => exec::lstm_seq(&xs, &h0, &c0, &wx, &wh, &bias, s.t, s.b, s.d, s.h).1,
            Kind::Gru => exec::gru_seq(&xs, &h0, &wx, &wh, &bias, s.t, s.b, s.d, s.h).1,
        };

        let mut time_plan = |plan: &ExecPlan, label: &str| -> Timed {
            let mut scr = ExecScratch::new();
            let (mut hs, mut h_t, mut c_t) = (Vec::new(), Vec::new(), Vec::new());
            let mut run = |scr: &mut ExecScratch,
                           hs: &mut Vec<f32>,
                           h_t: &mut Vec<f32>,
                           c_t: &mut Vec<f32>| match s.kind {
                Kind::Lstm => lstm_seq_into(
                    &xs, &h0, &c0, &wx, &wh, &bias, s.t, s.b, s.d, s.h, plan, 1, scr, hs, h_t,
                    c_t,
                ),
                Kind::Gru => {
                    gru_seq_into(
                        &xs, &h0, &wx, &wh, &bias, s.t, s.b, s.d, s.h, plan, 1, scr, hs, h_t,
                    );
                }
            };
            // Guard BEFORE timing (this run also latches the resident
            // panels, so pack/quantize cost never lands in the loop).
            run(&mut scr, &mut hs, &mut h_t, &mut c_t);
            let ctx = format!("{} {label} {}", s.name, plan.describe());
            match plan.geometry.dtype {
                Dtype::F32 => assert_bits_eq(&h_t, &h_ref, &ctx),
                Dtype::Int8 => assert_close(&h_t, &h_ref, BUDGET, &ctx),
            }
            let r = util::bench(&format!("{}::{label}", s.name), iters, || {
                run(&mut scr, &mut hs, &mut h_t, &mut c_t);
                h_t.first().copied()
            });
            Timed { secs: r.min_s, gflops: flops / r.min_s / 1e9 }
        };

        let f32_plan = tuner::plan_auto_dtype(&dims, isa, Dtype::F32);
        let int8_plan = tuner::plan_auto_dtype(&dims, isa, Dtype::Int8);
        let f = time_plan(&f32_plan, "f32");
        let q = time_plan(&int8_plan, "int8");
        let mult = f.secs / q.secs;
        if s.name == "lstm_h1024_t16_b4" {
            headline = mult;
        }
        println!(
            "  {:<20} f32 {:>7.2} GFLOP/s | int8 {:>7.2} GFLOP/s | int8_multiplier {:.2}x",
            s.name, f.gflops, q.gflops, mult
        );

        let mut row = BTreeMap::new();
        row.insert("shape".into(), Json::Str(s.name.into()));
        row.insert("f32_secs".into(), Json::Num(f.secs));
        row.insert("f32_gflops".into(), Json::Num(f.gflops));
        row.insert("f32_plan".into(), Json::Str(f32_plan.describe()));
        row.insert("int8_secs".into(), Json::Num(q.secs));
        row.insert("int8_gflops".into(), Json::Num(q.gflops));
        row.insert("int8_plan".into(), Json::Str(int8_plan.describe()));
        row.insert("int8_multiplier".into(), Json::Num(mult));
        rows.push(Json::Obj(row));
    }

    println!("headline int8_multiplier (lstm_h1024_t16_b4): {headline:.2}x");

    let mut root = BTreeMap::new();
    root.insert("schema".into(), Json::Str("sharp-bench-quant/v1".into()));
    root.insert("isa".into(), Json::Str(isa.name().into()));
    root.insert("budget".into(), Json::Num(BUDGET as f64));
    root.insert("headline_int8_multiplier".into(), Json::Num(headline));
    root.insert("shapes".into(), Json::Arr(rows));
    let path = util::out_path("BENCH_quant.json");
    std::fs::write(&path, json::write(&Json::Obj(root))).expect("write BENCH_quant.json");
    println!("wrote {}", path.display());
}
