//! Bench: regenerate paper exhibit fig11 (see DESIGN.md §5 for the
//! exhibit index and experiments/fig11.rs for the generator).
mod util;

fn main() {
    util::exhibit_bench("fig11", 5);
}
