//! Bench: regenerate paper exhibit fig01 (see DESIGN.md §5 for the
//! exhibit index and experiments/fig01.rs for the generator).
mod util;

fn main() {
    util::exhibit_bench("fig01", 5);
}
