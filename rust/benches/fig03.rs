//! Bench: regenerate paper exhibit fig03 (see DESIGN.md §5 for the
//! exhibit index and experiments/fig03.rs for the generator).
mod util;

fn main() {
    util::exhibit_bench("fig03", 5);
}
