//! Property tests over the simulator's invariants, using the crate's own
//! deterministic PRNG (the offline registry has no proptest). Each test
//! samples a few hundred random design/model points and asserts a
//! relationship the architecture guarantees by construction.

use sharp::config::presets::K_RECONFIG;
use sharp::config::{LstmConfig, SharpConfig};
use sharp::sched::ScheduleKind;
use sharp::sim::simulate;
use sharp::tile::geometry::{mvm_cost_fixed, mvm_cost_reconfig, TileGeometry};
use sharp::util::rng::Rng;

const SAMPLES: usize = 300;

fn random_model(rng: &mut Rng) -> LstmConfig {
    LstmConfig::square(rng.range_u64(16, 2200))
        .with_seq_len(rng.range_u64(1, 120))
        .with_layers(rng.range_u64(1, 4))
}

fn random_cfg(rng: &mut Rng) -> SharpConfig {
    let macs = 1024u64 << rng.range_u64(0, 6); // 1K..64K
    let k = *rng.choose(&[32u64, 64, 128, 256]);
    let g = *rng.choose(&[1u64, 2, 4, 8]);
    let cfg = SharpConfig::with_macs(macs).with_k(k).with_row_groups(g);
    if cfg.n_vs() < g {
        SharpConfig::with_macs(macs).with_k(32)
    } else {
        cfg
    }
}

#[test]
fn prop_tiles_cover_matrix_exactly() {
    let mut rng = Rng::new(0xA11CE);
    for _ in 0..SAMPLES {
        let tile = TileGeometry {
            rows: 1 << rng.range_u64(3, 9),
            cols: 1 << rng.range_u64(0, 7),
        };
        let r = rng.range_u64(1, 5000);
        let c = rng.range_u64(1, 3000);
        let cost = mvm_cost_fixed(tile, r, c);
        // Useful lane-cycles are exactly the matrix volume; issued lanes
        // are cycles * tile lanes; padding is the difference.
        assert_eq!(cost.useful_lane_cycles, r * c);
        assert_eq!(
            cost.total_lane_cycles(),
            cost.cycles * tile.rows * tile.cols
        );
    }
}

#[test]
fn prop_reconfig_never_slower_never_changes_work() {
    let mut rng = Rng::new(0xB0B);
    for _ in 0..SAMPLES {
        let tile = TileGeometry {
            rows: *rng.choose(&[32u64, 64, 128, 256]),
            cols: 1 << rng.range_u64(2, 8),
        };
        let r = rng.range_u64(1, 9000);
        let c = rng.range_u64(1, 3000);
        let fixed = mvm_cost_fixed(tile, r, c);
        let rec = mvm_cost_reconfig(tile, &K_RECONFIG, r, c);
        assert!(rec.cycles <= fixed.cycles, "tile={tile:?} r={r} c={c}");
        assert_eq!(rec.useful_lane_cycles, fixed.useful_lane_cycles);
        assert!(rec.padded_lane_cycles <= fixed.padded_lane_cycles);
    }
}

#[test]
fn prop_schedule_dominance_holds_everywhere() {
    // Unfolded <= Intergate <= Batch <= Sequential for any design point.
    let mut rng = Rng::new(0xC0FFEE);
    for _ in 0..SAMPLES {
        let cfg = random_cfg(&mut rng);
        let model = random_model(&mut rng);
        let cyc = |k: ScheduleKind| simulate(&cfg, &model, k).cycles;
        let (un, ig, ba, sq) = (
            cyc(ScheduleKind::Unfolded),
            cyc(ScheduleKind::Intergate),
            cyc(ScheduleKind::Batch),
            cyc(ScheduleKind::Sequential),
        );
        assert!(un <= ig && ig <= ba && ba <= sq, "{cfg:?} {model:?}: {un} {ig} {ba} {sq}");
    }
}

#[test]
fn prop_utilization_is_a_probability() {
    let mut rng = Rng::new(0xDEAD);
    for _ in 0..SAMPLES {
        let cfg = random_cfg(&mut rng);
        let model = random_model(&mut rng);
        let r = simulate(&cfg, &model, ScheduleKind::Unfolded);
        let u = r.utilization();
        assert!(u > 0.0 && u <= 1.0, "{cfg:?} {model:?}: util {u}");
        // The MAC array can never be busy more cycles than exist.
        assert!(r.mac_issue_cycles <= r.cycles, "{cfg:?} {model:?}");
    }
}

#[test]
fn prop_cycles_scale_with_work() {
    // Doubling the sequence length roughly doubles the cycles (within
    // per-sequence overhead), and never shrinks them.
    let mut rng = Rng::new(0xFEED);
    for _ in 0..SAMPLES / 3 {
        let cfg = random_cfg(&mut rng);
        let base = random_model(&mut rng);
        let long = base.clone().with_seq_len(base.seq_len * 2);
        let c1 = simulate(&cfg, &base, ScheduleKind::Unfolded).cycles;
        let c2 = simulate(&cfg, &long, ScheduleKind::Unfolded).cycles;
        assert!(c2 >= c1, "{cfg:?} {base:?}");
        let ratio = c2 as f64 / c1 as f64;
        assert!(ratio < 2.3, "{cfg:?} h={} T={}: ratio {ratio}", base.hidden, base.seq_len);
    }
}

#[test]
fn prop_energy_positive_and_power_bounded() {
    let mut rng = Rng::new(0x5EED);
    for _ in 0..SAMPLES / 3 {
        let cfg = random_cfg(&mut rng);
        let model = random_model(&mut rng);
        let sim = simulate(&cfg, &model, ScheduleKind::Unfolded);
        let p = sharp::energy::power_report(&cfg, &sim);
        assert!(p.total_w() > 0.0);
        assert!(p.energy_j() > 0.0);
        // Sanity ceiling: no configuration of this design should ever
        // report a kilowatt (the paper's biggest design draws 47.7 W).
        assert!(p.total_w() < 250.0, "{cfg:?}: {} W", p.total_w());
        for s in p.shares() {
            assert!((0.0..=1.0).contains(&s));
        }
    }
}

#[test]
fn prop_batch_one_is_fastest_per_request() {
    // Larger batches amortize weights but each takes at least as many
    // cycles in total.
    let mut rng = Rng::new(0xBA7C4);
    for _ in 0..SAMPLES / 3 {
        let cfg = random_cfg(&mut rng);
        let m1 = random_model(&mut rng).with_batch(1);
        let m4 = m1.clone().with_batch(4);
        let c1 = simulate(&cfg, &m1, ScheduleKind::Unfolded).cycles;
        let c4 = simulate(&cfg, &m4, ScheduleKind::Unfolded).cycles;
        assert!(c4 >= c1, "batch must not be free");
        assert!(c4 <= 4 * c1 + 1000, "batching must amortize fills");
    }
}
