//! Chaos suite for the coordinator's fault-tolerance contract
//! (DESIGN.md §11): deterministic injected panics and stalls at exact
//! per-worker request ordinals (`FaultPlan`), driven through every
//! serving path — stateless solo, fused streaming windows, stacked
//! by-name sessions, mid-session kills. The invariants under test:
//!
//!   1. Every submitted request RESOLVES — a reply or a typed
//!      `SharpError` — within a bounded wait. No client ever hangs.
//!   2. A panicked worker is respawned and serves traffic again.
//!   3. Session carries recovered across a kill are bit-identical to an
//!      undisturbed reference pool (or, when unrecoverable, restart
//!      loudly via the `session_steps == 1` signal) — never silently
//!      corrupted.
//!
//! Every scenario builds its own tiny golden-weight artifact store, so
//! the suite is self-contained and seeds are shared between the faulted
//! pool and the reference pool (bit-exactness is checkable).

mod common;

use std::path::{Path, PathBuf};
use std::sync::mpsc::RecvTimeoutError;
use std::time::{Duration, Instant};

use common::{
    assert_bits_eq, seq_entry_goldens, stack_entry_goldens, synth_store, write_lstm_goldens,
    write_stack_goldens,
};
use sharp::coordinator::{
    routing, FaultPlan, InferenceRequest, InferenceResponse, Metrics, OverloadPolicy, Server,
    ServerConfig, SharpError,
};
use sharp::util::rng::Rng;

const H: usize = 32;
const SEED: u64 = 0xFA01;

/// A store with two flat LSTM buckets (T=4 B=1 solo, T=8 B=1 session
/// bucket) and optionally a 2-layer stack, all with seeded goldens —
/// two stores built from the same call serve bit-identical models.
fn chaos_store(tag: &str, with_stack: bool) -> PathBuf {
    let mut entries = vec![
        seq_entry_goldens("seq_h32_t4_b1", 4, 1, H, H, "w4"),
        seq_entry_goldens("seq_h32_t8_b1", 8, 1, H, H, "w8"),
    ];
    if with_stack {
        entries.push(stack_entry_goldens("stack2_h32_t4_b1", 4, 1, H, H, 2, "s"));
    }
    let (dir, _store) = synth_store(tag, &entries.join(","));
    write_lstm_goldens(&dir, "w4", H, H, SEED);
    write_lstm_goldens(&dir, "w8", H, H, SEED + 1);
    if with_stack {
        write_stack_goldens(&dir, "s", H, H, 2, SEED + 2);
    }
    dir
}

fn base_cfg(dir: &Path, workers: usize) -> ServerConfig {
    ServerConfig {
        artifact_dir: Some(dir.to_path_buf()),
        hidden: vec![H],
        workers,
        queue_cap: 8,
        watchdog: Duration::from_millis(300),
        ..Default::default()
    }
}

/// Poll merged metrics until `pred` holds; panics (with the last
/// snapshot) if it doesn't within `timeout`. Every supervisor claim in
/// this suite is awaited through here, so a broken recovery path shows
/// up as a clear timeout message, not a test hang.
fn wait_for(
    server: &Server,
    what: &str,
    timeout: Duration,
    pred: impl Fn(&Metrics) -> bool,
) -> Metrics {
    let t0 = Instant::now();
    loop {
        let mut m = server.metrics().expect("metrics snapshot");
        if pred(&m) {
            return m;
        }
        assert!(
            t0.elapsed() < timeout,
            "timed out waiting for {what}; last snapshot:\n{}",
            m.render()
        );
        std::thread::sleep(Duration::from_millis(15));
    }
}

/// Seeded chunk payload, identical across the faulted and reference
/// pools for a given (session, chunk) pair.
fn chunk_payload(sid: u64, chunk: u64, len: usize) -> Vec<f32> {
    Rng::new(sid.wrapping_mul(1000) + chunk).vec_f32(len * H, -1.0, 1.0)
}

/// One bounded chunk round-trip. `Err` is a typed refusal or a closed
/// reply channel (the worker died holding the request — the documented
/// resend case); a TIMEOUT is the one outcome the contract forbids, so
/// it panics the test.
fn send_chunk(
    server: &Server,
    sid: u64,
    id: u64,
    len: usize,
    payload: Vec<f32>,
    model: Option<&str>,
) -> Result<InferenceResponse, String> {
    let mut req = InferenceRequest::new(id, len, payload)
        .with_session(sid)
        .with_hidden(H);
    if let Some(m) = model {
        req = req.with_model(m);
    }
    let rx = server.submit(req);
    match rx.recv_timeout(Duration::from_secs(30)) {
        Ok(Ok(r)) => Ok(r),
        Ok(Err(e)) => Err(format!("{e}")),
        Err(RecvTimeoutError::Disconnected) => Err("reply channel closed".into()),
        Err(RecvTimeoutError::Timeout) => panic!("chunk {id} (session {sid}) HUNG for 30s"),
    }
}

/// [`send_chunk`] with bounded resends: the client-side recovery the
/// fault model prescribes (a failed chunk was never applied, so the
/// resend is safe). Panics if the chunk cannot land within ~15s.
fn send_chunk_retry(
    server: &Server,
    sid: u64,
    id: u64,
    len: usize,
    payload: Vec<f32>,
    model: Option<&str>,
) -> InferenceResponse {
    let mut last = String::new();
    for _ in 0..300 {
        match send_chunk(server, sid, id, len, payload.clone(), model) {
            Ok(r) => return r,
            Err(e) => last = e,
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    panic!("chunk {id} (session {sid}) never landed; last error: {last}");
}

/// The first session id at or after `start` owned by `worker` in an
/// `n`-worker pool (session affinity is a pure hash, so tests can aim
/// faults at the owner deterministically).
fn sid_owned_by(worker: usize, n: usize, start: u64) -> u64 {
    (start..start + 10_000)
        .find(|s| routing::session_worker(*s, n) == worker)
        .expect("an owned sid exists in any 10k range")
}

fn stateless_req(id: u64) -> InferenceRequest {
    InferenceRequest::new(id, 4, Rng::new(id + 9).vec_f32(4 * H, -1.0, 1.0)).with_hidden(H)
}

/// Injected panic mid-traffic: every request resolves (reply or typed
/// error), the dead worker respawns, and the pool serves new traffic
/// afterward — zero hangs.
#[test]
fn panicked_worker_respawns_and_every_request_resolves() {
    let dir = chaos_store("ft_panic", false);
    let server = Server::start(ServerConfig {
        faults: Some(FaultPlan::parse("panic@worker1:req3").unwrap()),
        ..base_cfg(&dir, 2)
    })
    .expect("server start");

    let receivers: Vec<_> = (0..12).map(|i| server.submit(stateless_req(i))).collect();
    let mut ok = 0usize;
    let mut failed = 0usize;
    for (i, rx) in receivers.into_iter().enumerate() {
        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(Ok(_)) => ok += 1,
            Ok(Err(e)) => {
                failed += 1;
                assert!(
                    matches!(e, SharpError::WorkerFailed { .. }),
                    "request {i}: unexpected refusal {e}"
                );
            }
            Err(RecvTimeoutError::Disconnected) => failed += 1, // died holding it
            Err(RecvTimeoutError::Timeout) => panic!("request {i} HUNG"),
        }
    }
    assert!(failed >= 1, "the injected panic must cost its request");
    assert!(
        failed <= 3,
        "salvage must confine the blast radius (lost {failed}/12)"
    );
    assert_eq!(ok + failed, 12, "every request resolved");

    let m = wait_for(&server, "respawn", Duration::from_secs(20), |m| {
        m.respawns >= 1 && m.worker_health.get("worker1").map(String::as_str) == Some("ok")
    });
    assert!(m.faults_injected >= 1, "injection must be counted");

    // The respawned replica serves again (generation 1 arms no faults).
    let after: Vec<_> = (100..106).map(|i| server.submit(stateless_req(i))).collect();
    for rx in after {
        let r = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("post-recovery reply");
        assert!(r.is_ok(), "post-recovery request refused: {r:?}");
    }
    server.shutdown();
}

/// Deadlines bound every wait: a request stuck behind an injected stall
/// resolves with typed `DeadlineExceeded` — quickly, not after the
/// stall clears, and never as a hang.
#[test]
fn deadline_exceeded_is_typed_not_a_hang() {
    let dir = chaos_store("ft_deadline", false);
    let server = Server::start(ServerConfig {
        faults: Some(FaultPlan::parse("stall@worker0:400ms:req1").unwrap()),
        ..base_cfg(&dir, 1)
    })
    .expect("server start");

    // First request trips the 400 ms stall (it still succeeds after).
    let stalled = server.submit(stateless_req(0));
    // Second request sits behind the stall with a 50 ms budget.
    let t0 = Instant::now();
    let verdict = server.try_infer(stateless_req(1).with_deadline(Duration::from_millis(50)));
    let waited = t0.elapsed();
    match verdict {
        Err(SharpError::DeadlineExceeded { .. }) => {}
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    assert!(
        waited < Duration::from_secs(5),
        "deadline verdict took {waited:?}"
    );
    let first = stalled
        .recv_timeout(Duration::from_secs(30))
        .expect("stalled request resolves");
    assert!(first.is_ok(), "the stalled request itself succeeds: {first:?}");

    // The worker sheds the expired request at dequeue too.
    let m = wait_for(&server, "worker-side deadline shed", Duration::from_secs(10), |m| {
        m.deadline_misses >= 1
    });
    assert!(m.faults_injected >= 1);

    // No-deadline traffic still flows.
    assert!(server.try_infer(stateless_req(2)).is_ok());
    server.shutdown();
}

/// Shed policy: past the watermark, admission resolves immediately with
/// typed `Overloaded` instead of blocking, and the sheds are counted.
#[test]
fn overload_shed_is_typed_and_counted() {
    let dir = chaos_store("ft_shed", false);
    let server = Server::start(ServerConfig {
        overload: OverloadPolicy::Shed,
        shed_watermark: Some(3),
        queue_cap: 4,
        faults: Some(FaultPlan::parse("stall@worker0:400ms:req1").unwrap()),
        ..base_cfg(&dir, 1)
    })
    .expect("server start");

    let receivers: Vec<_> = (0..24).map(|i| server.submit(stateless_req(i))).collect();
    let (mut ok, mut overloaded) = (0usize, 0usize);
    for (i, rx) in receivers.into_iter().enumerate() {
        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(Ok(_)) => ok += 1,
            Ok(Err(SharpError::Overloaded { watermark, .. })) => {
                overloaded += 1;
                assert_eq!(watermark, 3, "shed must report the configured watermark");
            }
            Ok(Err(e)) => panic!("request {i}: unexpected error {e}"),
            Err(e) => panic!("request {i} did not resolve: {e:?}"),
        }
    }
    assert!(ok >= 1, "the pool still serves under shed");
    assert!(overloaded >= 1, "the stall must push depth past watermark 3");
    let m = wait_for(&server, "shed counter", Duration::from_secs(10), |m| m.shed >= 1);
    assert!(m.shed as usize >= overloaded.min(1));
    server.shutdown();
}

/// The heartbeat watchdog: an injected stall marks the replica
/// `unresponsive` in the health gauge (satellite of the silently-
/// partial-snapshot fix), then the supervisor replaces it and the pool
/// recovers — while the stalled request itself still resolves.
#[test]
fn stall_marks_unresponsive_then_replaces_and_recovers() {
    let dir = chaos_store("ft_stall", false);
    let server = Server::start(ServerConfig {
        watchdog: Duration::from_millis(400),
        faults: Some(FaultPlan::parse("stall@worker0:2000ms:req1").unwrap()),
        ..base_cfg(&dir, 1)
    })
    .expect("server start");

    let stalled = server.submit(stateless_req(0));
    // Lag crosses the 400 ms watchdog well before the 800 ms replace
    // threshold: the gauge must say so instead of silently reporting a
    // partial snapshot.
    wait_for(&server, "unresponsive gauge", Duration::from_secs(5), |m| {
        matches!(
            m.worker_health.get("worker0").map(String::as_str),
            Some("unresponsive") | Some("respawning")
        )
    });
    // The detached incarnation finishes its sleep and still replies.
    let r = stalled
        .recv_timeout(Duration::from_secs(30))
        .expect("stalled request resolves");
    assert!(r.is_ok(), "stalled request failed: {r:?}");
    // The replacement takes over.
    wait_for(&server, "replacement healthy", Duration::from_secs(20), |m| {
        m.respawns >= 1 && m.worker_health.get("worker0").map(String::as_str) == Some("ok")
    });
    assert!(server.try_infer(stateless_req(1)).is_ok());
    server.shutdown();
}

/// The core carry-recovery claim, through the fused streaming path:
/// several concurrent sessions on the faulted worker (fused windows), a
/// panic mid-stream, resends after recovery — and every recovered
/// session's chunk states stay BIT-IDENTICAL to an undisturbed
/// single-worker reference pool, with `session_steps` continuing (no
/// silent restart).
#[test]
fn mid_session_panic_recovers_carries_bit_exact() {
    let dir = chaos_store("ft_carry", false);
    let reference = Server::start(base_cfg(&dir, 1)).expect("reference pool");
    // Three sessions owned by worker 1 (fused lanes on the victim) and
    // one on worker 0 (must ride through untouched). Ordinal 5 lands in
    // the victims' second round of chunks.
    let faulted = Server::start(ServerConfig {
        faults: Some(FaultPlan::parse("panic@worker1:req5").unwrap()),
        ..base_cfg(&dir, 2)
    })
    .expect("faulted pool");

    let mut victims = Vec::new();
    let mut next = 100;
    while victims.len() < 3 {
        let sid = sid_owned_by(1, 2, next);
        next = sid + 1;
        victims.push(sid);
    }
    let bystander = sid_owned_by(0, 2, 500);
    let sessions: Vec<u64> = victims.iter().copied().chain([bystander]).collect();
    for &sid in &sessions {
        reference.begin_session(sid, H).expect("reference begin");
        faulted.begin_session(sid, H).expect("faulted begin");
    }

    let len = 4usize;
    let mut ids = 0u64;
    for chunk in 1..=4u64 {
        // Reference states for this round, bit-exact oracle per session.
        let mut want: Vec<(u64, InferenceResponse)> = Vec::new();
        for &sid in &sessions {
            let payload = chunk_payload(sid, chunk, len);
            let r = send_chunk(&reference, sid, 10_000 + ids, len, payload, None)
                .expect("reference pool never faults");
            want.push((sid, r));
            ids += 1;
        }
        // Faulted pool, same payloads, whole round in flight at once so
        // the step-fusion dispatcher actually fuses the victims into
        // shared windows. Chunks hit by the panic — a closed reply
        // channel (died holding it) or a typed refusal (fuse waiter in
        // the obituary) — are resent; a salvaged queue message replays
        // and answers on its ORIGINAL channel. The fault model
        // guarantees a failed chunk was never applied, so the resend
        // continues the carry, not forks it.
        let inflight: Vec<_> = want
            .into_iter()
            .map(|(sid, want)| {
                let req = InferenceRequest::new(20_000 + ids, len, chunk_payload(sid, chunk, len))
                    .with_session(sid)
                    .with_hidden(H);
                ids += 1;
                (sid, want, faulted.submit(req))
            })
            .collect();
        for (sid, want, rx) in inflight {
            let got = match rx.recv_timeout(Duration::from_secs(30)) {
                Ok(Ok(r)) => r,
                Ok(Err(_)) | Err(RecvTimeoutError::Disconnected) => {
                    let payload = chunk_payload(sid, chunk, len);
                    send_chunk_retry(&faulted, sid, 30_000 + ids, len, payload, None)
                }
                Err(RecvTimeoutError::Timeout) => panic!("chunk {chunk} (session {sid}) HUNG"),
            };
            ids += 1;
            assert_eq!(
                got.session_steps,
                Some(chunk),
                "session {sid} chunk {chunk}: steps must CONTINUE across the \
                 kill (a silent restart would read 1)"
            );
            assert_bits_eq(
                &got.h_t,
                &want.h_t,
                &format!("session {sid} chunk {chunk} carry after recovery"),
            );
        }
    }

    let m = wait_for(&faulted, "recovery counters", Duration::from_secs(20), |m| {
        m.respawns >= 1
    });
    assert!(m.faults_injected >= 1);
    assert!(
        m.recovered_sessions >= 1,
        "the victim sessions' carries must ride the obituary"
    );
    // Closing both pools returns the same final states.
    for &sid in &sessions {
        let a = reference.end_session(sid).expect("reference end").expect("state");
        let b = faulted.end_session(sid).expect("faulted end").expect("state");
        assert_bits_eq(&a.h, &b.h, &format!("session {sid} final h"));
        assert_bits_eq(&a.c, &b.c, &format!("session {sid} final c"));
        assert_eq!(a.steps, b.steps, "session {sid} chunk count");
    }
    reference.shutdown();
    faulted.shutdown();
}

/// Same contract through the stacked by-name path: a 2-layer stack
/// session killed mid-stream recovers its full per-layer carry
/// bit-exact (the stack's state rows ride the obituary like flat ones).
#[test]
fn stacked_session_recovers_across_a_kill() {
    let dir = chaos_store("ft_stack", true);
    let model = "stack2_h32_t4_b1";
    let reference = Server::start(base_cfg(&dir, 1)).expect("reference pool");
    let faulted = Server::start(ServerConfig {
        faults: Some(FaultPlan::parse("panic@worker1:req2").unwrap()),
        ..base_cfg(&dir, 2)
    })
    .expect("faulted pool");

    let sid = sid_owned_by(1, 2, 7_000);
    let len = 4usize;
    for chunk in 1..=3u64 {
        let want = send_chunk(
            &reference,
            sid,
            40_000 + chunk,
            len,
            chunk_payload(sid, chunk, len),
            Some(model),
        )
        .expect("reference stack chunk");
        let got = match send_chunk(
            &faulted,
            sid,
            50_000 + chunk,
            len,
            chunk_payload(sid, chunk, len),
            Some(model),
        ) {
            Ok(r) => r,
            Err(_) => send_chunk_retry(
                &faulted,
                sid,
                60_000 + chunk,
                len,
                chunk_payload(sid, chunk, len),
                Some(model),
            ),
        };
        assert_eq!(
            got.session_steps,
            Some(chunk),
            "stack session steps must continue across the kill"
        );
        assert_bits_eq(
            &got.h_t,
            &want.h_t,
            &format!("stack chunk {chunk} output after recovery"),
        );
    }
    wait_for(&faulted, "stack respawn", Duration::from_secs(20), |m| {
        m.respawns >= 1 && m.recovered_sessions >= 1
    });
    reference.shutdown();
    faulted.shutdown();
}

/// Failing to start is a `Result`, not a crash (spawn-path satellite):
/// a store with no artifacts for the served dim reports a typed error
/// from `Server::start` — after the worker built and failed, not via a
/// panic or a poisoned pool.
#[test]
fn start_failure_is_a_result_not_a_panic() {
    let dir = chaos_store("ft_badstart", false);
    let err = match Server::start(ServerConfig {
        hidden: vec![4096], // no artifacts at this dim
        ..base_cfg(&dir, 2)
    }) {
        Ok(_) => panic!("start must fail for an unserved dim"),
        Err(e) => format!("{e:#}"),
    };
    assert!(err.contains("4096"), "unhelpful start error: {err}");
}

/// Config-driven fault plans parse from the CLI grammar; a malformed
/// spec is refused loudly at startup, not silently ignored.
#[test]
fn fault_plan_wiring_round_trips() {
    let plan = FaultPlan::parse("panic@worker1:req17,stall@worker0:40ms:req5").unwrap();
    assert_eq!(plan.faults.len(), 2);
    assert!(FaultPlan::parse("panic@worker1").is_err());
    assert!(FaultPlan::parse("melt@worker0:req1").is_err());
}
