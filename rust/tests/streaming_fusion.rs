//! The step-fusion contract: a fused multi-session window advances
//! every lane to EXACTLY the bits the solo `run_prefix_into` path
//! produces for that lane's chunk alone — across ragged chunk lengths
//! (lane retirement), sessions joining/leaving between windows, the
//! degenerate single-lane window, LRU-evicted-then-restarted carries,
//! GRU kinds, and serial vs threaded kernels. Self-contained: builds a
//! synthetic on-disk artifact store via the shared conformance harness
//! (`tests/common/`), so the suite runs everywhere (including CI,
//! which has no `make artifacts`). The SIMD-vs-scalar half of the
//! fused-path contract lives in `simd_conformance.rs`, on the same
//! harness.

mod common;

use std::path::PathBuf;

use common::seq_entry;
use sharp::coordinator::SessionStore;
use sharp::runtime::{ArtifactStore, FusedBatch, LstmExecutable, PlanMode, RuntimeConfig};
use sharp::util::rng::Rng;

/// Minimal on-disk store: one LSTM seq artifact and one GRU seq
/// artifact (weights are bound explicitly per test, so no goldens).
fn synth_store(tag: &str) -> (PathBuf, ArtifactStore) {
    common::synth_store(
        &format!("fusion_{tag}"),
        &format!(
            "{},{}",
            seq_entry("seq_h10_t8_b1", "seq", 8, 1, 6, 10),
            seq_entry("gru_seq_h7_t8_b1", "gru_seq", 8, 1, 5, 7),
        ),
    )
}

fn lstm_exe(store: &ArtifactStore, seed: u64, threads: usize) -> LstmExecutable {
    let (d, h) = (6usize, 10usize);
    let mut rng = Rng::new(seed);
    let wx = rng.vec_f32(d * 4 * h, -0.3, 0.3);
    let wh = rng.vec_f32(h * 4 * h, -0.3, 0.3);
    let bias = rng.vec_f32(4 * h, -0.2, 0.2);
    let mut exe = LstmExecutable::with_weights(store, "seq_h10_t8_b1", wx, wh, bias).unwrap();
    exe.set_runtime(RuntimeConfig {
        threads,
        plan: PlanMode::Auto,
        force_kernel: None,
        ..RuntimeConfig::default()
    })
    .unwrap();
    exe
}

/// Run one fused window over `(len, h0, c0, frames)` lanes (already
/// longest-first) and return each lane's (h, c) carry.
fn run_fused(
    exe: &LstmExecutable,
    lanes: &[(usize, Vec<f32>, Vec<f32>, Vec<f32>)],
) -> Vec<(Vec<f32>, Vec<f32>)> {
    let (d, h) = (exe.entry.d, exe.entry.h);
    let mut batch = FusedBatch::new();
    batch.begin(d, h);
    for (len, h0, c0, frames) in lanes {
        batch.push_lane(frames, *len, h0, c0);
    }
    batch.finish();
    exe.run_steps_batched_into(&mut batch).unwrap();
    (0..lanes.len())
        .map(|i| (batch.lane_h(i).to_vec(), batch.lane_c(i).to_vec()))
        .collect()
}

#[test]
fn fused_window_is_bit_identical_to_solo_across_ragged_lens() {
    let (_dir, store) = synth_store("ragged");
    for threads in [1usize, 4] {
        let exe = lstm_exe(&store, 7, threads);
        let (d, h) = (exe.entry.d, exe.entry.h);
        let mut rng = Rng::new(100 + threads as u64);
        let lens = [8usize, 6, 6, 3, 1];
        let lanes: Vec<(usize, Vec<f32>, Vec<f32>, Vec<f32>)> = lens
            .iter()
            .map(|&len| {
                (
                    len,
                    rng.vec_f32(h, -1.0, 1.0),
                    rng.vec_f32(h, -1.0, 1.0),
                    rng.vec_f32(len * d, -1.0, 1.0),
                )
            })
            .collect();
        let fused = run_fused(&exe, &lanes);
        for (i, (len, h0, c0, frames)) in lanes.iter().enumerate() {
            let solo = exe.run_prefix(frames, *len, h0, c0).unwrap();
            assert_eq!(fused[i].0, solo.h_t, "lane {i} h (threads={threads})");
            assert_eq!(fused[i].1, solo.c_t, "lane {i} c (threads={threads})");
        }
    }
}

#[test]
fn single_live_session_degenerates_to_solo() {
    let (_dir, store) = synth_store("single");
    let exe = lstm_exe(&store, 11, 1);
    let (d, h) = (exe.entry.d, exe.entry.h);
    let mut rng = Rng::new(42);
    let lanes = vec![(
        5usize,
        rng.vec_f32(h, -1.0, 1.0),
        rng.vec_f32(h, -1.0, 1.0),
        rng.vec_f32(5 * d, -1.0, 1.0),
    )];
    let fused = run_fused(&exe, &lanes);
    let solo = exe
        .run_prefix(&lanes[0].3, 5, &lanes[0].1, &lanes[0].2)
        .unwrap();
    assert_eq!(fused[0].0, solo.h_t);
    assert_eq!(fused[0].1, solo.c_t);
}

#[test]
fn sessions_joining_and_leaving_across_windows_carry_exactly() {
    // Three consecutive fuse windows with changing membership:
    //   window 1: A (3 steps), B (2)
    //   window 2: C (4), A (2)       — B left, C joined
    //   window 3: C (1)              — degenerate solo window
    // Every session's carry, threaded through the windows, must equal
    // its solo chunk-by-chunk chain.
    let (_dir, store) = synth_store("membership");
    let exe = lstm_exe(&store, 23, 1);
    let (d, h) = (exe.entry.d, exe.entry.h);
    let mut rng = Rng::new(5);
    let chunk = |rng: &mut Rng, len: usize| rng.vec_f32(len * d, -1.0, 1.0);
    let zero = vec![0.0f32; h];

    // Session chunk scripts (in window order).
    let a1 = chunk(&mut rng, 3);
    let a2 = chunk(&mut rng, 2);
    let b1 = chunk(&mut rng, 2);
    let c1 = chunk(&mut rng, 4);
    let c2 = chunk(&mut rng, 1);

    // Window 1: A and B from zero state.
    let w1 = run_fused(
        &exe,
        &[
            (3, zero.clone(), zero.clone(), a1.clone()),
            (2, zero.clone(), zero.clone(), b1.clone()),
        ],
    );
    // Window 2: C joins fresh; A continues from its window-1 carry.
    let w2 = run_fused(
        &exe,
        &[
            (4, zero.clone(), zero.clone(), c1.clone()),
            (2, w1[0].0.clone(), w1[0].1.clone(), a2.clone()),
        ],
    );
    // Window 3: only C remains.
    let w3 = run_fused(&exe, &[(1, w2[0].0.clone(), w2[0].1.clone(), c2.clone())]);

    // Solo chains.
    let a_solo1 = exe.run_prefix(&a1, 3, &zero, &zero).unwrap();
    let a_solo2 = exe.run_prefix(&a2, 2, &a_solo1.h_t, &a_solo1.c_t).unwrap();
    assert_eq!(w2[1].0, a_solo2.h_t, "A final h");
    assert_eq!(w2[1].1, a_solo2.c_t, "A final c");

    let b_solo = exe.run_prefix(&b1, 2, &zero, &zero).unwrap();
    assert_eq!(w1[1].0, b_solo.h_t, "B final h");

    let c_solo1 = exe.run_prefix(&c1, 4, &zero, &zero).unwrap();
    let c_solo2 = exe.run_prefix(&c2, 1, &c_solo1.h_t, &c_solo1.c_t).unwrap();
    assert_eq!(w3[0].0, c_solo2.h_t, "C final h");
    assert_eq!(w3[0].1, c_solo2.c_t, "C final c");
}

#[test]
fn evicted_then_restarted_carry_matches_solo_from_zero() {
    // An LRU-evicted session that comes back re-enters a fused window
    // with a freshly zeroed carry — exactly like the solo path's
    // restart — and must still be bit-identical to a solo run from
    // zero, fused alongside an unrelated live lane.
    let (_dir, store) = synth_store("evict");
    let exe = lstm_exe(&store, 31, 1);
    let (d, h) = (exe.entry.d, exe.entry.h);
    let mut rng = Rng::new(77);

    let mut sessions = SessionStore::with_capacity(h, 2);
    let chunk_a = rng.vec_f32(4 * d, -1.0, 1.0);
    let chunk_b = rng.vec_f32(3 * d, -1.0, 1.0);

    // Window 1: sessions 1 and 2 advance from zero.
    let s1 = sessions.get_or_init(1);
    let s2 = sessions.get_or_init(2);
    let w1 = run_fused(
        &exe,
        &[
            (4, s1.h, s1.c, chunk_a.clone()),
            (3, s2.h, s2.c, chunk_b.clone()),
        ],
    );
    assert_eq!(sessions.update(1, w1[0].0.clone(), w1[0].1.clone()), 1);
    assert_eq!(sessions.update(2, w1[1].0.clone(), w1[1].1.clone()), 1);

    // Session 3 arrives: capacity 2 evicts the coldest (session 1).
    sessions.get_or_init(3);
    assert!(!sessions.contains(1), "session 1 LRU-evicted");
    assert!(sessions.contains(2), "session 2 still live");

    // Session 1 returns with a restarted zero carry (this re-entry
    // itself evicts the now-coldest session 2 — capacity stays 2) and
    // fuses into a window with session 2's successor, session 3.
    let s1b = sessions.get_or_init(1);
    assert_eq!(s1b.steps, 0, "restarted carry");
    let s3 = sessions.get_or_init(3);
    let chunk_a2 = rng.vec_f32(2 * d, -1.0, 1.0);
    let chunk_c = rng.vec_f32(2 * d, -1.0, 1.0);
    let w2 = run_fused(
        &exe,
        &[
            (2, s1b.h, s1b.c, chunk_a2.clone()),
            (2, s3.h, s3.c, chunk_c.clone()),
        ],
    );
    assert_eq!(
        sessions.update(1, w2[0].0.clone(), w2[0].1.clone()),
        1,
        "restart detected: the chunk count begins again at 1"
    );

    // Session 1's restarted lane == solo from zero (NOT its old carry).
    let zero = vec![0.0f32; h];
    let restart_solo = exe.run_prefix(&chunk_a2, 2, &zero, &zero).unwrap();
    assert_eq!(w2[0].0, restart_solo.h_t);
    assert_eq!(w2[0].1, restart_solo.c_t);
    let old_carry_solo = exe.run_prefix(&chunk_a2, 2, &w1[0].0, &w1[0].1).unwrap();
    assert_ne!(
        w2[0].0, old_carry_solo.h_t,
        "the evicted carry must NOT leak into the restarted lane"
    );
    // Session 3's fresh lane is solo-from-zero too.
    let c_solo = exe.run_prefix(&chunk_c, 2, &zero, &zero).unwrap();
    assert_eq!(w2[1].0, c_solo.h_t);
}

#[test]
fn gru_fused_window_matches_solo() {
    let (_dir, store) = synth_store("gru");
    let (d, h) = (5usize, 7usize);
    let mut rng = Rng::new(12);
    let wx = rng.vec_f32(d * 3 * h, -0.3, 0.3);
    let wh = rng.vec_f32(h * 3 * h, -0.3, 0.3);
    let bias = rng.vec_f32(3 * h, -0.2, 0.2);
    let exe = LstmExecutable::with_weights(&store, "gru_seq_h7_t8_b1", wx, wh, bias).unwrap();

    let lens = [6usize, 4, 2];
    let lanes: Vec<(usize, Vec<f32>, Vec<f32>, Vec<f32>)> = lens
        .iter()
        .map(|&len| {
            let h0 = rng.vec_f32(h, -1.0, 1.0);
            // GRU kinds carry no cell state; c mirrors h by convention.
            (len, h0.clone(), h0, rng.vec_f32(len * d, -1.0, 1.0))
        })
        .collect();
    let fused = run_fused(&exe, &lanes);
    for (i, (len, h0, _c0, frames)) in lanes.iter().enumerate() {
        let solo = exe.run_prefix(frames, *len, h0, h0).unwrap();
        assert_eq!(fused[i].0, solo.h_t, "gru lane {i} h");
        assert_eq!(fused[i].1, solo.c_t, "gru lane {i} c mirrors h");
    }
}

#[test]
fn interleaved_fused_and_solo_calls_share_the_executable() {
    // The worker's real pattern: the same executable (one scratch, one
    // set of packed panels) alternates between fused windows and solo
    // prefix runs; neither contaminates the other.
    let (_dir, store) = synth_store("interleave");
    let exe = lstm_exe(&store, 55, 1);
    let (d, h) = (exe.entry.d, exe.entry.h);
    let mut rng = Rng::new(8);
    let zero = vec![0.0f32; h];
    for round in 0..3 {
        let ca = rng.vec_f32(4 * d, -1.0, 1.0);
        let cb = rng.vec_f32(2 * d, -1.0, 1.0);
        let fused = run_fused(
            &exe,
            &[
                (4, zero.clone(), zero.clone(), ca.clone()),
                (2, zero.clone(), zero.clone(), cb.clone()),
            ],
        );
        let sa = exe.run_prefix(&ca, 4, &zero, &zero).unwrap();
        let sb = exe.run_prefix(&cb, 2, &zero, &zero).unwrap();
        assert_eq!(fused[0].0, sa.h_t, "round {round} lane A");
        assert_eq!(fused[1].0, sb.h_t, "round {round} lane B");
    }
}
