//! Quantized-path conformance: the int8 inference path must be (a)
//! **tolerance-close** to the f32 scalar oracle within the documented
//! error budget (DESIGN.md §12), and (b) **bit-identical to itself**
//! across geometries, schedules, ISAs, thread counts, and solo-vs-fused
//! dispatch — integer dot products are exact and the dequant epilogue
//! is shared scalar code, so nothing about how an int8 GEMM is tiled or
//! vectorized may change a single output bit.
//!
//! The budget: with weights drawn from ±0.3 (the magnitude regime of
//! trained RNN weights; see EXPERIMENTS.md) the per-element error on
//! `h` stays under 5e-2 across every swept shape — measured headroom is
//! ~4x (worst observed ≈1.3e-2). The budget scales with the weight
//! span: per-gate symmetric scales put the max weight-rounding error at
//! `max|w|/254` per element, amplified by at most the gate dot length
//! and damped by the sigmoid/tanh Lipschitz constants (≤ 1, ≤ 1/4 for
//! the sigmoid gates) and the forget-gate contraction at every step.
//!
//! ISA coverage adapts to the host via `common::sweep_isas()`; CI runs
//! the suite in release under both default dispatch and
//! `SHARP_FORCE_KERNEL=scalar`.

mod common;

use common::{assert_bits_eq, assert_close, assert_close_ulp, sweep_isas, SplitMix64};
use sharp::runtime::kernel::{
    gru_seq_into, lstm_seq_into, lstm_steps_batched_into, ExecScratch,
};
use sharp::runtime::plan::{Dtype, ExecPlan, KernelGeometry, Schedule};
use sharp::runtime::{exec, Isa, RuntimeConfig, StackExecutable};
use sharp::util::rng::Rng;

/// The documented per-element budget on `h` for ±0.3-span weights.
const BUDGET: f32 = 5e-2;

/// Weight span the budget is calibrated for (DESIGN.md §12).
const WSPAN: f32 = 0.3;

fn int8_plan(mr: usize, nr: usize, isa: Isa, sched: Schedule) -> ExecPlan {
    ExecPlan {
        geometry: KernelGeometry::new(mr, nr).unwrap().with_isa(isa).with_dtype(Dtype::Int8),
        schedule: sched,
    }
}

struct Case {
    t: usize,
    b: usize,
    d: usize,
    hid: usize,
    seed: u64,
}

/// The seeded shape sweep: seam-heavy dims (lane straddles, B=1, T=1,
/// D != H) plus a few bulk shapes. Shared by the LSTM and GRU passes.
fn cases() -> Vec<Case> {
    let mut sm = SplitMix64::new(0x1A78_0A17);
    let mut out = vec![
        Case { t: 16, b: 4, d: 64, hid: 64, seed: 1 },
        Case { t: 8, b: 2, d: 96, hid: 160, seed: 2 },
        Case { t: 25, b: 4, d: 48, hid: 128, seed: 3 },
        Case { t: 1, b: 1, d: 33, hid: 47, seed: 4 },
        Case { t: 5, b: 8, d: 7, hid: 19, seed: 5 },
    ];
    for i in 0..8u64 {
        out.push(Case {
            t: sm.range_usize(1, 12),
            b: sm.range_usize(1, 6),
            d: sm.range_usize(1, 80),
            hid: sm.range_usize(1, 96),
            seed: 0x5EED + i,
        });
    }
    out
}

struct LstmData {
    xs: Vec<f32>,
    h0: Vec<f32>,
    c0: Vec<f32>,
    wx: Vec<f32>,
    wh: Vec<f32>,
    bias: Vec<f32>,
}

fn lstm_data(c: &Case, gates: usize) -> LstmData {
    let mut rng = Rng::new(c.seed);
    LstmData {
        xs: rng.vec_f32(c.t * c.b * c.d, -1.0, 1.0),
        h0: rng.vec_f32(c.b * c.hid, -1.0, 1.0),
        c0: rng.vec_f32(c.b * c.hid, -1.0, 1.0),
        wx: rng.vec_f32(c.d * gates * c.hid, -WSPAN, WSPAN),
        wh: rng.vec_f32(c.hid * gates * c.hid, -WSPAN, WSPAN),
        bias: rng.vec_f32(gates * c.hid, -0.2, 0.2),
    }
}

/// Geometry/schedule/thread grid every case runs under. Seam-heavy on
/// purpose: sub-vector panels, mr > m, the 8x32 bulk tile.
fn plan_grid(isa: Isa) -> Vec<(ExecPlan, usize)> {
    let mut out = Vec::new();
    for (mr, nr) in [(4usize, 16usize), (1, 4), (2, 8), (8, 32), (3, 5)] {
        for sched in [Schedule::Unfolded, Schedule::Stepwise] {
            for threads in [1usize, 4] {
                out.push((int8_plan(mr, nr, isa, sched), threads));
            }
        }
    }
    out
}

#[test]
fn int8_lstm_meets_the_budget_and_is_bitwise_self_consistent() {
    for c in cases() {
        let data = lstm_data(&c, 4);
        let (_, h_ref, c_ref) = exec::lstm_seq(
            &data.xs, &data.h0, &data.c0, &data.wx, &data.wh, &data.bias, c.t, c.b, c.d, c.hid,
        );
        let mut first: Option<(Vec<f32>, Vec<f32>, Vec<f32>)> = None;
        for isa in sweep_isas() {
            for (plan, threads) in plan_grid(isa) {
                let mut scr = ExecScratch::new();
                let (mut hs, mut h_t, mut c_t) = (Vec::new(), Vec::new(), Vec::new());
                lstm_seq_into(
                    &data.xs, &data.h0, &data.c0, &data.wx, &data.wh, &data.bias, c.t, c.b,
                    c.d, c.hid, &plan, threads, &mut scr, &mut hs, &mut h_t, &mut c_t,
                );
                let ctx = format!(
                    "lstm (T={} B={} D={} H={}) {} threads={threads}",
                    c.t,
                    c.b,
                    c.d,
                    c.hid,
                    plan.describe()
                );
                match &first {
                    None => {
                        // The budget gate runs once per case: every
                        // other variant must match these exact bits, so
                        // closeness is inherited.
                        assert_close(&h_t, &h_ref, BUDGET, &format!("{ctx}: h_t"));
                        assert_close(&c_t, &c_ref, 2.0 * BUDGET, &format!("{ctx}: c_t"));
                        first = Some((hs, h_t, c_t));
                    }
                    Some((f_hs, f_h, f_c)) => {
                        assert_bits_eq(&hs, f_hs, &format!("{ctx}: hs"));
                        assert_bits_eq(&h_t, f_h, &format!("{ctx}: h_t"));
                        assert_bits_eq(&c_t, f_c, &format!("{ctx}: c_t"));
                    }
                }
            }
        }
    }
}

#[test]
fn int8_gru_meets_the_budget_and_is_bitwise_self_consistent() {
    for c in cases() {
        let data = lstm_data(&c, 3);
        let (_, h_ref) = exec::gru_seq(
            &data.xs, &data.h0, &data.wx, &data.wh, &data.bias, c.t, c.b, c.d, c.hid,
        );
        let mut first: Option<(Vec<f32>, Vec<f32>)> = None;
        for isa in sweep_isas() {
            for (plan, threads) in plan_grid(isa) {
                let mut scr = ExecScratch::new();
                let (mut hs, mut h_t) = (Vec::new(), Vec::new());
                gru_seq_into(
                    &data.xs, &data.h0, &data.wx, &data.wh, &data.bias, c.t, c.b, c.d, c.hid,
                    &plan, threads, &mut scr, &mut hs, &mut h_t,
                );
                let ctx = format!(
                    "gru (T={} B={} D={} H={}) {} threads={threads}",
                    c.t,
                    c.b,
                    c.d,
                    c.hid,
                    plan.describe()
                );
                match &first {
                    None => {
                        assert_close(&h_t, &h_ref, BUDGET, &format!("{ctx}: h_t"));
                        first = Some((hs, h_t));
                    }
                    Some((f_hs, f_h)) => {
                        assert_bits_eq(&hs, f_hs, &format!("{ctx}: hs"));
                        assert_bits_eq(&h_t, f_h, &format!("{ctx}: h_t"));
                    }
                }
            }
        }
    }
}

#[test]
fn int8_fused_streaming_matches_int8_solo_bitwise_per_lane() {
    // Per-row activation scales depend only on the row's own content,
    // so a lane inside a fused int8 window must carry exactly the bits
    // its solo int8 run produces — the streaming-fusion transparency
    // claim, restated under quantization.
    let (d, hid) = (13usize, 29usize);
    let lens = [6usize, 4, 4, 1];
    let mut rng = Rng::new(0xF05E);
    let wx = rng.vec_f32(d * 4 * hid, -WSPAN, WSPAN);
    let wh = rng.vec_f32(hid * 4 * hid, -WSPAN, WSPAN);
    let bias = rng.vec_f32(4 * hid, -0.2, 0.2);
    let chunks: Vec<Vec<f32>> = lens.iter().map(|&l| rng.vec_f32(l * d, -1.0, 1.0)).collect();
    let h0 = rng.vec_f32(lens.len() * hid, -1.0, 1.0);
    let c0 = rng.vec_f32(lens.len() * hid, -1.0, 1.0);

    for isa in sweep_isas() {
        let plan = int8_plan(4, 16, isa, Schedule::Stepwise);
        let mut want_h = Vec::new();
        let mut want_c = Vec::new();
        for (i, chunk) in chunks.iter().enumerate() {
            let mut scr = ExecScratch::new();
            let (mut hs, mut h_t, mut c_t) = (Vec::new(), Vec::new(), Vec::new());
            lstm_seq_into(
                chunk,
                &h0[i * hid..(i + 1) * hid],
                &c0[i * hid..(i + 1) * hid],
                &wx,
                &wh,
                &bias,
                lens[i],
                1,
                d,
                hid,
                &plan,
                1,
                &mut scr,
                &mut hs,
                &mut h_t,
                &mut c_t,
            );
            want_h.extend_from_slice(&h_t);
            want_c.extend_from_slice(&c_t);
        }
        // Step-major ragged gather (longest lane first).
        let mut xs = Vec::new();
        for step in 0..lens[0] {
            for (i, &len) in lens.iter().enumerate() {
                if len > step {
                    xs.extend_from_slice(&chunks[i][step * d..(step + 1) * d]);
                }
            }
        }
        for threads in [1usize, 4] {
            let mut scr = ExecScratch::new();
            let mut h = h0.clone();
            let mut c = c0.clone();
            lstm_steps_batched_into(
                &xs, &lens, &wx, &wh, &bias, d, hid, &plan, threads, &mut scr, &mut h, &mut c,
            );
            let ctx = format!("int8 fused@{} threads={threads}", isa.name());
            assert_bits_eq(&h, &want_h, &format!("{ctx}: h"));
            assert_bits_eq(&c, &want_c, &format!("{ctx}: c"));
        }
    }
}

#[test]
fn int8_stack_meets_the_budget_and_pipelining_preserves_bits() {
    // Depth compounds the quant error (each layer consumes the previous
    // layer's already-perturbed output), but the gate nonlinearities
    // damp it: measured depth-2 error stays within the same budget the
    // solo sweep uses. The pipelined route must not move a bit.
    let (t, b, d, h, layers) = (8usize, 2usize, 24usize, 32usize, 2usize);
    let (dir, store) = common::synth_store(
        "quant_stack",
        &common::stack_entry_goldens("qstack", t, b, d, h, layers, "qs"),
    );
    // Goldens land after open; the store reads them lazily at bind.
    common::write_stack_goldens(&dir, "qs", d, h, layers, 0xCAFE);

    let f32_exe = StackExecutable::from_store_goldens(&store, "qstack").unwrap();
    let mut exe = StackExecutable::from_store_goldens_with(
        &store,
        "qstack",
        RuntimeConfig {
            dtype: Dtype::Int8,
            ..RuntimeConfig::default()
        },
    )
    .unwrap();
    let mut rng = Rng::new(77);
    let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
    let (h0, c0) = exe.zero_state();
    let oracle = f32_exe.run(&xs, &h0, &c0).unwrap();
    let got = exe.run(&xs, &h0, &c0).unwrap();
    assert_close(&got.out, &oracle.out, BUDGET, "int8 stack out");
    assert_close(&got.h_t, &oracle.h_t, BUDGET, "int8 stack h_t");

    exe.set_runtime(RuntimeConfig {
        threads: 4,
        dtype: Dtype::Int8,
        ..RuntimeConfig::default()
    })
    .unwrap();
    assert!(exe.pipelines());
    let piped = exe.run(&xs, &h0, &c0).unwrap();
    assert_close_ulp(&piped.out, &got.out, 0, "int8 pipelined out == sequential");
    assert_bits_eq(&piped.h_t, &got.h_t, "int8 pipelined h_t");
    assert_bits_eq(&piped.c_t, &got.c_t, "int8 pipelined c_t");
}

#[test]
fn f32_plans_are_unaffected_by_the_dtype_dimension() {
    // Guard the default path: an explicit F32-stamped plan must keep
    // the exact oracle bits (the dtype dimension is inert at f32).
    let (t, b, d, hid) = (4usize, 3usize, 10usize, 21usize);
    let mut rng = Rng::new(3);
    let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
    let h0 = rng.vec_f32(b * hid, -1.0, 1.0);
    let c0 = rng.vec_f32(b * hid, -1.0, 1.0);
    let wx = rng.vec_f32(d * 4 * hid, -WSPAN, WSPAN);
    let wh = rng.vec_f32(hid * 4 * hid, -WSPAN, WSPAN);
    let bias = rng.vec_f32(4 * hid, -0.2, 0.2);
    let (_, h_ref, c_ref) = exec::lstm_seq(&xs, &h0, &c0, &wx, &wh, &bias, t, b, d, hid);
    for isa in sweep_isas() {
        let plan = ExecPlan {
            geometry: KernelGeometry::new(4, 16).unwrap().with_isa(isa).with_dtype(Dtype::F32),
            schedule: Schedule::Unfolded,
        };
        let mut scr = ExecScratch::new();
        let (mut hs, mut h_t, mut c_t) = (Vec::new(), Vec::new(), Vec::new());
        lstm_seq_into(
            &xs, &h0, &c0, &wx, &wh, &bias, t, b, d, hid, &plan, 1, &mut scr, &mut hs,
            &mut h_t, &mut c_t,
        );
        assert_close_ulp(&h_t, &h_ref, 0, &format!("f32 dtype-stamped h_t @{}", isa.name()));
        assert_close_ulp(&c_t, &c_ref, 0, &format!("f32 dtype-stamped c_t @{}", isa.name()));
    }
}
