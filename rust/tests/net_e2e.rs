//! End-to-end suite for the TCP serving front-end (DESIGN.md §13):
//! wire-level robustness, graceful drain, reconnect-resume, and the
//! network fault grammar — all over real loopback sockets against real
//! worker pools with seeded golden-weight stores.
//!
//! The invariants under test:
//!
//!   1. Hostile or broken input (malformed frames, oversized frames,
//!      slowloris dribble) yields a *typed* wire error and bounded
//!      resource use — never a hang, never a crash, and a healthy
//!      connection survives its peer's bad frame.
//!   2. Graceful drain drops nothing in flight: admitted work resolves
//!      and flushes, new work is refused with a retryable verdict, and
//!      every live streaming session is fenced (End semantics).
//!   3. A client that reconnects mid-stream resumes its session and the
//!      hidden-state carry is bit-identical to an undisturbed in-process
//!      reference pool.
//!   4. `disconnect@connN:frameM` / `stall@connN:…` / `garble@connN:…`
//!      fire deterministically in the framing layer.

mod common;

use std::io::Write as _;
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use common::{assert_bits_eq, seq_entry_goldens, synth_store, write_lstm_goldens};
use sharp::coordinator::net::frame::{self, Frame, RawFrame, RawOutcome, WireError};
use sharp::coordinator::net::{Listener, NetClient, NetConfig, NetRequest, RetryPolicy};
use sharp::coordinator::{FaultPlan, Server, ServerConfig, SharpError};
use sharp::util::rng::Rng;

const H: usize = 32;
const SEED: u64 = 0x7E57_0E7;

/// Two flat LSTM buckets (T=4 and T=8, B=1) with seeded goldens — two
/// stores built with the same call serve bit-identical models, which is
/// what makes the reconnect-resume bit-compare meaningful.
fn net_store(tag: &str) -> PathBuf {
    let entries = [
        seq_entry_goldens("seq_h32_t4_b1", 4, 1, H, H, "w4"),
        seq_entry_goldens("seq_h32_t8_b1", 8, 1, H, H, "w8"),
    ];
    let (dir, _store) = synth_store(tag, &entries.join(","));
    write_lstm_goldens(&dir, "w4", H, H, SEED);
    write_lstm_goldens(&dir, "w8", H, H, SEED + 1);
    dir
}

fn pool_cfg(dir: &Path) -> ServerConfig {
    ServerConfig {
        artifact_dir: Some(dir.to_path_buf()),
        hidden: vec![H],
        workers: 1,
        queue_cap: 8,
        ..Default::default()
    }
}

fn net_cfg() -> NetConfig {
    NetConfig {
        read_timeout: Duration::from_secs(2),
        idle_timeout: Duration::from_secs(60),
        drain_linger: Duration::from_secs(2),
        ..Default::default()
    }
}

fn start_listener(tag: &str, cfg: NetConfig) -> (Listener, PathBuf) {
    let dir = net_store(tag);
    let server = Server::start(pool_cfg(&dir)).expect("server start");
    let listener = Listener::start(server, cfg).expect("listener start");
    (listener, dir)
}

/// Seeded chunk payload, identical across the TCP pool and the
/// in-process reference for a given (session, chunk) pair.
fn chunk_payload(sid: u64, chunk: u64, len: usize) -> Vec<f32> {
    Rng::new(sid.wrapping_mul(1000) + chunk).vec_f32(len * H, -1.0, 1.0)
}

fn stateless_req(id: u64) -> NetRequest {
    let mut r = NetRequest::new(id, 4, Rng::new(id + 9).vec_f32(4 * H, -1.0, 1.0));
    r.hidden = Some(H as u32);
    r
}

fn session_req(sid: u64, chunk: u64) -> NetRequest {
    let mut r = NetRequest::new(chunk, 4, chunk_payload(sid, chunk, 4));
    r.hidden = Some(H as u32);
    r.session = Some(sid);
    r
}

// ---------------------------------------------------------------------
// 1. Wire-level robustness
// ---------------------------------------------------------------------

#[test]
fn malformed_frame_gets_typed_error_and_connection_survives() {
    let (listener, _dir) = start_listener("net_malformed", net_cfg());
    let addr = listener.local_addr();

    let mut sock = TcpStream::connect(addr).expect("connect");
    sock.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

    // An unknown tag decodes as malformed; the body was consumed, so the
    // stream stays in sync.
    frame::write_raw(
        &mut sock,
        &RawFrame {
            tag: 0x41,
            payload: vec![1, 2, 3],
        },
    )
    .unwrap();
    match frame::read_raw(&mut sock, frame::DEFAULT_MAX_FRAME).unwrap() {
        RawOutcome::Frame(raw) => match frame::decode(&raw).unwrap() {
            Frame::Error { id, err } => {
                assert_eq!(id, 0);
                assert!(matches!(err, WireError::Malformed(_)), "{err}");
                assert!(!err.retryable());
            }
            other => panic!("expected ERROR, got {other:?}"),
        },
        other => panic!("expected a frame, got {other:?}"),
    }

    // Same connection, valid request: still served.
    let req = stateless_req(7);
    frame::write_frame(
        &mut sock,
        &Frame::Request {
            id: req.id,
            session: None,
            hidden: req.hidden,
            deadline_ms: None,
            attempt: 0,
            model: None,
            seq_len: req.seq_len,
            payload: req.payload.clone(),
        },
    )
    .unwrap();
    match frame::read_raw(&mut sock, frame::DEFAULT_MAX_FRAME).unwrap() {
        RawOutcome::Frame(raw) => match frame::decode(&raw).unwrap() {
            Frame::Response { id, h_t, .. } => {
                assert_eq!(id, 7);
                assert_eq!(h_t.len(), H);
            }
            other => panic!("expected RESPONSE after a malformed frame, got {other:?}"),
        },
        other => panic!("expected a frame, got {other:?}"),
    }

    let m = listener.metrics().expect("metrics");
    assert!(m.frames_malformed >= 1, "malformed counter:\n{:?}", m.frames_malformed);
    drop(sock);
    listener.drain();
    listener.wait().expect("drain");
}

#[test]
fn oversized_frame_is_rejected_with_too_large_and_closed() {
    let cfg = NetConfig {
        max_frame: 4096,
        ..net_cfg()
    };
    let (listener, _dir) = start_listener("net_oversize", cfg);
    let mut sock = TcpStream::connect(listener.local_addr()).expect("connect");
    sock.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

    // Header declares 1 MiB against a 4 KiB cap; the body never goes on
    // the wire, so the server must reject on the header alone.
    sock.write_all(&(1u32 << 20).to_be_bytes()).unwrap();
    sock.flush().unwrap();
    match frame::read_raw(&mut sock, frame::DEFAULT_MAX_FRAME).unwrap() {
        RawOutcome::Frame(raw) => match frame::decode(&raw).unwrap() {
            Frame::Error { err, .. } => {
                assert_eq!(
                    err,
                    WireError::TooLarge {
                        size: 1 << 20,
                        max: 4096
                    }
                );
                assert!(!err.retryable());
            }
            other => panic!("expected ERROR, got {other:?}"),
        },
        other => panic!("expected a frame, got {other:?}"),
    }
    // The stream is out of sync, so the server closes it.
    assert_eq!(
        frame::read_raw(&mut sock, frame::DEFAULT_MAX_FRAME).unwrap(),
        RawOutcome::Eof
    );
    listener.drain();
    listener.wait().expect("drain");
}

#[test]
fn slowloris_midframe_dribble_is_killed_with_deadline() {
    let cfg = NetConfig {
        read_timeout: Duration::from_millis(300),
        ..net_cfg()
    };
    let (listener, _dir) = start_listener("net_slowloris", cfg);
    let mut sock = TcpStream::connect(listener.local_addr()).expect("connect");
    sock.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

    // Open a frame (2 of 4 length-header bytes) and stall: the server's
    // mid-frame deadline must fire, with a typed verdict before close.
    sock.write_all(&[0, 0]).unwrap();
    sock.flush().unwrap();
    let t0 = Instant::now();
    match frame::read_raw(&mut sock, frame::DEFAULT_MAX_FRAME).unwrap() {
        RawOutcome::Frame(raw) => match frame::decode(&raw).unwrap() {
            Frame::Error { err, .. } => {
                assert!(
                    matches!(
                        err,
                        WireError::Sharp(SharpError::DeadlineExceeded { .. })
                    ),
                    "{err}"
                );
            }
            other => panic!("expected ERROR, got {other:?}"),
        },
        other => panic!("expected a frame, got {other:?}"),
    }
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "slowloris kill took {:?}",
        t0.elapsed()
    );
    assert_eq!(
        frame::read_raw(&mut sock, frame::DEFAULT_MAX_FRAME).unwrap(),
        RawOutcome::Eof
    );
    let m = listener.metrics().expect("metrics");
    assert!(m.conns_timed_out >= 1);
    listener.drain();
    listener.wait().expect("drain");
}

#[test]
fn connection_cap_rejects_with_retryable_overloaded() {
    let cfg = NetConfig {
        max_conns: 1,
        ..net_cfg()
    };
    let (listener, _dir) = start_listener("net_conncap", cfg);
    let addr = listener.local_addr();

    // First connection occupies the only slot (prove it with a request).
    let mut first = NetClient::connect(addr.to_string(), Duration::from_secs(30)).unwrap();
    let verdict = first.request(&stateless_req(1), 0).expect("transport");
    assert!(verdict.is_ok(), "{verdict:?}");

    // Second connection is over the cap: typed, retryable Overloaded.
    let mut sock = TcpStream::connect(addr).expect("connect");
    sock.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    match frame::read_raw(&mut sock, frame::DEFAULT_MAX_FRAME).unwrap() {
        RawOutcome::Frame(raw) => match frame::decode(&raw).unwrap() {
            Frame::Error { id, err } => {
                assert_eq!(id, 0);
                assert!(
                    matches!(err, WireError::Sharp(SharpError::Overloaded { .. })),
                    "{err}"
                );
                assert!(err.retryable(), "cap rejection must be retryable");
            }
            other => panic!("expected ERROR, got {other:?}"),
        },
        other => panic!("expected a frame, got {other:?}"),
    }
    let m = listener.metrics().expect("metrics");
    assert_eq!(m.conns_rejected, 1);
    assert!(m.conns_accepted >= 1);
    drop(first);
    drop(sock);
    listener.drain();
    listener.wait().expect("drain");
}

// ---------------------------------------------------------------------
// 2. Graceful drain
// ---------------------------------------------------------------------

#[test]
fn drain_under_load_drops_nothing_and_refuses_new_work_retryably() {
    let dir = net_store("net_drain");
    // The 3rd request on worker 0 stalls 300 ms — that is the in-flight
    // work the drain must not drop.
    let server = Server::start(ServerConfig {
        faults: Some(FaultPlan::parse("stall@worker0:300ms:req3").unwrap()),
        ..pool_cfg(&dir)
    })
    .expect("server start");
    let listener = Listener::start(server, net_cfg()).expect("listener start");
    let addr = listener.local_addr();

    let mut client = NetClient::connect(addr.to_string(), Duration::from_secs(30)).unwrap();
    let sid = 42u64;
    client.begin(sid, H as u32).unwrap().expect("begin");
    for chunk in 1..=2u64 {
        let resp = client.request(&session_req(sid, chunk), 0).unwrap().expect("chunk");
        assert_eq!(resp.session_steps, Some(chunk));
    }

    // Fire the stalled chunk from a second thread, then drain while it
    // is in flight.
    let handle = std::thread::spawn({
        let addr = addr.to_string();
        move || {
            let mut c = NetClient::connect(addr, Duration::from_secs(30)).unwrap();
            c.request(&session_req(sid, 3), 0)
        }
    });
    std::thread::sleep(Duration::from_millis(80));
    let mut ctl = NetClient::connect(addr.to_string(), Duration::from_secs(30)).unwrap();
    let reply = ctl.control(r#"{"cmd":"drain"}"#).expect("drain cmd");
    assert!(reply.contains("draining"), "{reply}");

    // Zero dropped in flight: the stalled chunk (admitted before the
    // drain) resolves OK and its reply was flushed.
    let inflight = handle.join().expect("thread").expect("transport");
    let resp = inflight.expect("in-flight chunk must resolve OK through a drain");
    assert_eq!(resp.session_steps, Some(3));

    // New work on a draining server: typed, retryable refusal.
    std::thread::sleep(Duration::from_millis(120)); // let conns see the flag
    match client.request(&session_req(sid, 4), 0) {
        Ok(Err(err)) => {
            assert_eq!(err, WireError::Draining);
            assert!(err.retryable());
        }
        other => panic!("expected a Draining verdict, got {other:?}"),
    }

    drop(client);
    drop(ctl);
    let summary = listener.wait().expect("drain teardown");
    // The live session was fenced (End semantics), not dropped.
    assert_eq!(summary.fenced, 1, "{summary:?}");
}

// ---------------------------------------------------------------------
// 3. Reconnect-resume, bit-exact vs an in-process reference
// ---------------------------------------------------------------------

#[test]
fn reconnect_resumes_session_bit_exact_vs_in_process_reference() {
    // Server-side abrupt kill: connection 1 dies right before its 5th
    // frame (begin + 3 chunks served, the 4th chunk never decodes).
    let cfg = NetConfig {
        faults: Some(FaultPlan::parse("disconnect@conn1:frame5").unwrap()),
        ..net_cfg()
    };
    let (listener, _dir) = start_listener("net_resume_tcp", cfg);
    let addr = listener.local_addr();

    // Undisturbed in-process reference over a bit-identical store.
    let ref_dir = net_store("net_resume_ref");
    let reference = Server::start(pool_cfg(&ref_dir)).expect("reference pool");

    let sid = 77u64;
    let policy = RetryPolicy {
        max_attempts: 4,
        base: Duration::from_millis(5),
        cap: Duration::from_millis(50),
        seed: 11,
    };
    let mut client = NetClient::connect(addr.to_string(), Duration::from_secs(30)).unwrap();
    client.begin(sid, H as u32).unwrap().expect("begin");
    reference.begin_session(sid, H).expect("reference begin");

    for chunk in 1..=6u64 {
        let (resp, tries) = client
            .infer_retry(&session_req(sid, chunk), &policy)
            .expect("chunk through chaos");
        let ref_resp = reference
            .chunk(sid, chunk, 4, chunk_payload(sid, chunk, 4))
            .expect("reference chunk");
        // The disconnect fired BEFORE decode, so the killed chunk never
        // executed: the retried resend lands exactly once and the step
        // count stays in lockstep with the reference.
        assert_eq!(resp.session_steps, Some(chunk), "chunk {chunk}");
        assert_eq!(ref_resp.session_steps, Some(chunk));
        assert_bits_eq(
            &resp.h_t,
            &ref_resp.h_t,
            &format!("chunk {chunk} h_t after reconnect"),
        );
        if chunk == 4 {
            assert_eq!(tries, 2, "chunk 4 must have needed a reconnect+resend");
        } else {
            assert_eq!(tries, 1, "chunk {chunk} should succeed first try");
        }
    }
    assert_eq!(client.reconnects, 1, "exactly one re-dial");

    // Final carries are bit-identical too (steps, h, c off the wire).
    let state = client.end(sid).unwrap().expect("end").expect("state");
    let ref_state = reference
        .end_session(sid)
        .expect("reference end")
        .expect("reference state");
    assert_eq!(state.0, ref_state.steps);
    assert_bits_eq(&state.1, &ref_state.h, "final h");
    assert_bits_eq(&state.2, &ref_state.c, "final c");

    reference.shutdown();
    drop(client);
    listener.drain();
    listener.wait().expect("drain");
}

#[test]
fn client_side_disconnect_resumes_against_server_kept_state() {
    let (listener, _dir) = start_listener("net_resume_client", net_cfg());
    let addr = listener.local_addr();
    let ref_dir = net_store("net_resume_client_ref");
    let reference = Server::start(pool_cfg(&ref_dir)).expect("reference pool");

    let sid = 5u64;
    let mut client = NetClient::connect(addr.to_string(), Duration::from_secs(30)).unwrap();
    client.begin(sid, H as u32).unwrap().expect("begin");
    reference.begin_session(sid, H).expect("reference begin");

    for chunk in 1..=2u64 {
        client.request(&session_req(sid, chunk), 0).unwrap().expect("chunk");
        reference
            .chunk(sid, chunk, 4, chunk_payload(sid, chunk, 4))
            .expect("reference chunk");
    }
    // The client link dies without ceremony; the session lives on the
    // server. The next request re-dials and picks up the carry.
    client.disconnect();
    let resp = client
        .request(&session_req(sid, 3), 1)
        .unwrap()
        .expect("resumed chunk");
    let ref_resp = reference
        .chunk(sid, 3, 4, chunk_payload(sid, 3, 4))
        .expect("reference chunk");
    assert_eq!(
        resp.session_steps,
        Some(3),
        "a resumed session continues, steps==1 would mean the carry was lost"
    );
    assert_bits_eq(&resp.h_t, &ref_resp.h_t, "resumed h_t");

    // The wire `attempt` field surfaces as observed retry pressure.
    let m = listener.metrics().expect("metrics");
    assert!(m.retries_observed >= 1);
    assert!(m.conns_accepted >= 2, "reconnect = a second accepted conn");

    reference.shutdown();
    drop(client);
    listener.drain();
    listener.wait().expect("drain");
}

// ---------------------------------------------------------------------
// 4. Fault grammar round-trip in the framing layer
// ---------------------------------------------------------------------

#[test]
fn garble_and_stall_faults_fire_at_exact_frame_ordinals() {
    let plan = FaultPlan::parse("garble@conn1:frame2,stall@conn1:10ms").unwrap();
    assert!(plan.targets_conn(1));
    assert!(!plan.targets_conn(2));
    let cfg = NetConfig {
        faults: Some(plan),
        ..net_cfg()
    };
    let (listener, _dir) = start_listener("net_garble", cfg);

    let mut client =
        NetClient::connect(listener.local_addr().to_string(), Duration::from_secs(30)).unwrap();
    // Frame 1: stalled (every-frame stall) but served.
    client.request(&stateless_req(1), 0).unwrap().expect("frame 1");
    // Frame 2: garbled server-side before decode — deterministic
    // malformed verdict, connection survives.
    match client.request(&stateless_req(2), 0).unwrap() {
        Err(WireError::Malformed(_)) => {}
        other => panic!("expected Malformed from the garbled frame, got {other:?}"),
    }
    // Frame 3: same connection, back to normal service.
    client.request(&stateless_req(3), 0).unwrap().expect("frame 3");

    let m = listener.metrics().expect("metrics");
    assert!(m.frames_malformed >= 1);
    drop(client);
    listener.drain();
    listener.wait().expect("drain");
}

// ---------------------------------------------------------------------
// 5. Control plane
// ---------------------------------------------------------------------

#[test]
fn control_plane_health_and_metrics_speak_json() {
    let (listener, _dir) = start_listener("net_control", net_cfg());
    let mut client =
        NetClient::connect(listener.local_addr().to_string(), Duration::from_secs(30)).unwrap();

    let health = client.control(r#"{"cmd":"health"}"#).expect("health");
    let h = sharp::util::json::parse(&health).expect("health is JSON");
    assert_eq!(h.get("ok").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(h.get("state").and_then(|v| v.as_str()), Some("running"));

    client.request(&stateless_req(1), 0).unwrap().expect("one request");
    let metrics = client.control(r#"{"cmd":"metrics"}"#).expect("metrics");
    let mj = sharp::util::json::parse(&metrics).expect("metrics is JSON");
    let snap = mj.get("metrics").expect("metrics body");
    assert_eq!(
        snap.get("schema").and_then(|v| v.as_str()),
        Some("sharp-serve-metrics/v4")
    );
    let net = snap.get("net").expect("net block");
    assert_eq!(net.get("conns_accepted").and_then(|v| v.as_u64()), Some(1));

    let bad = client.control(r#"{"cmd":"reboot"}"#).expect("reply");
    let bj = sharp::util::json::parse(&bad).expect("error is JSON");
    assert_eq!(bj.get("ok").and_then(|v| v.as_bool()), Some(false));

    drop(client);
    listener.drain();
    listener.wait().expect("drain");
}

// ---------------------------------------------------------------------
// 6. CLI loopback smoke: serve --listen + loadgen + drain
// ---------------------------------------------------------------------

#[test]
fn cli_serve_loadgen_drain_roundtrip() {
    use std::io::BufRead;
    use std::process::{Command, Stdio};

    let dir = net_store("net_cli");
    let mut child = Command::new(env!("CARGO_BIN_EXE_sharp"))
        .args([
            "serve",
            "--listen",
            "127.0.0.1:0",
            "--hidden",
            "32",
            "--workers",
            "1",
        ])
        .env("SHARP_ARTIFACTS", &dir)
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn serve --listen");

    // The bound address is announced on the first stdout line.
    let mut stdout = std::io::BufReader::new(child.stdout.take().expect("stdout"));
    let mut line = String::new();
    stdout.read_line(&mut line).expect("read listen line");
    let addr = line
        .trim()
        .strip_prefix("listening on ")
        .unwrap_or_else(|| panic!("unexpected first line: {line:?}"))
        .to_string();

    let loadgen = Command::new(env!("CARGO_BIN_EXE_sharp"))
        .args([
            "loadgen", "--addr", &addr, "--requests", "8", "--conns", "2", "--hidden", "32",
            "--seq", "4",
        ])
        .output()
        .expect("run loadgen");
    let lg_out = String::from_utf8_lossy(&loadgen.stdout);
    assert!(
        loadgen.status.success(),
        "loadgen failed:\n{lg_out}\n{}",
        String::from_utf8_lossy(&loadgen.stderr)
    );
    assert!(lg_out.contains("8/8 ok"), "{lg_out}");

    let drain = Command::new(env!("CARGO_BIN_EXE_sharp"))
        .args(["drain", "--addr", &addr])
        .output()
        .expect("run drain");
    assert!(
        drain.status.success(),
        "drain failed:\n{}",
        String::from_utf8_lossy(&drain.stderr)
    );
    assert!(
        String::from_utf8_lossy(&drain.stdout).contains("draining"),
        "{}",
        String::from_utf8_lossy(&drain.stdout)
    );

    // The server exits its wait() after the drain completes.
    let mut rest = String::new();
    std::io::Read::read_to_string(&mut stdout, &mut rest).expect("drain output");
    let status = child.wait().expect("serve exit");
    assert!(status.success(), "serve exited {status:?}:\n{rest}");
    assert!(rest.contains("drained:"), "{rest}");
}
