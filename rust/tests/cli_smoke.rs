//! Integration: the `sharp` CLI binary. Cargo exposes the built binary's
//! path to integration tests via `CARGO_BIN_EXE_sharp`, so these shell out
//! to the real executable — the same artifact users run.

mod common;

use std::process::Command;

fn sharp(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_sharp"))
        .args(args)
        .output()
        .expect("spawn sharp binary")
}

#[test]
fn list_names_all_13_exhibits() {
    let out = sharp(&["list"]);
    assert!(out.status.success(), "sharp list failed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    for id in sharp::experiments::ALL_IDS {
        assert!(stdout.contains(id), "sharp list missing '{id}':\n{stdout}");
    }
    assert_eq!(sharp::experiments::ALL_IDS.len(), 13);
}

#[test]
fn figure_fig01_renders_nonempty_exhibit() {
    let out = sharp(&["figure", "fig01"]);
    assert!(out.status.success(), "sharp figure fig01 failed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("fig01"), "no exhibit header:\n{stdout}");
    assert!(
        stdout.len() > 80,
        "suspiciously short exhibit output:\n{stdout}"
    );
}

#[test]
fn every_exhibit_id_renders_via_figure_or_table() {
    for id in sharp::experiments::ALL_IDS {
        // `figure` and `table` are aliases; exercise `table` for the
        // tableN ids the way the docs spell it.
        let cmd = if id.starts_with("table") { "table" } else { "figure" };
        let out = sharp(&[cmd, id]);
        assert!(out.status.success(), "sharp {cmd} {id} failed: {out:?}");
        let stdout = String::from_utf8_lossy(&out.stdout);
        assert!(stdout.contains(id), "{id}: header missing:\n{stdout}");
    }
}

#[test]
fn unknown_exhibit_exits_2_and_lists_known_ids() {
    let out = sharp(&["figure", "fig99"]);
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown exhibit"), "{stderr}");
    assert!(stderr.contains("fig09"), "should list known ids: {stderr}");
}

#[test]
fn plan_renders_candidate_table_and_json() {
    // Table form: candidates + the chosen plan, no artifacts needed.
    let out = sharp(&["plan", "--hidden", "340", "--d", "128", "--batch", "4", "--seq", "16"]);
    assert!(out.status.success(), "sharp plan failed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("execution plan candidates"), "{stdout}");
    assert!(stdout.contains("chosen plan:"), "{stdout}");
    assert!(stdout.contains("unfolded"), "T=16 should offer unfolded: {stdout}");

    // JSON form parses and marks exactly one candidate chosen.
    let out = sharp(&["plan", "--hidden", "340", "--batch", "4", "--seq", "16", "--json"]);
    assert!(out.status.success(), "sharp plan --json failed: {out:?}");
    let v = sharp::util::json::parse(&String::from_utf8_lossy(&out.stdout))
        .expect("plan --json emits valid JSON");
    assert_eq!(v.get("schema").and_then(|j| j.as_str()), Some("sharp-plan/v3"));
    assert!(v.get("chosen").and_then(|j| j.get("mr")).is_some());
    // v3: dtype and ISA render side by side, top-level and on the choice.
    assert_eq!(v.get("dtype").and_then(|j| j.as_str()), Some("f32"));
    assert_eq!(
        v.get("chosen").and_then(|j| j.get("dtype")).and_then(|j| j.as_str()),
        Some("f32")
    );
    assert!(v.get("chosen").and_then(|j| j.get("isa")).is_some());
    let cands = v.get("candidates").and_then(|j| j.as_arr()).unwrap();
    assert!(!cands.is_empty());
    let chosen_marks = cands
        .iter()
        .filter(|c| matches!(c.get("chosen"), Some(sharp::util::json::Json::Bool(true))))
        .count();
    assert_eq!(chosen_marks, 1, "exactly one candidate is the choice");

    // Missing dims and bad modes fail loudly with exit 2.
    assert_eq!(sharp(&["plan"]).status.code(), Some(2));
    assert_eq!(
        sharp(&["plan", "--hidden", "64", "--plan", "bogus"]).status.code(),
        Some(2)
    );
    // fixed:MRxNR parses and pins the geometry.
    let out = sharp(&["plan", "--hidden", "64", "--plan", "fixed:2x8"]);
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("mr2/nr8"));

    // --quant int8 stamps the dtype through the whole JSON document,
    // and a bogus dtype fails loudly.
    let out = sharp(&["plan", "--hidden", "64", "--quant", "int8", "--json"]);
    assert!(out.status.success(), "plan --quant int8 failed: {out:?}");
    let v = sharp::util::json::parse(&String::from_utf8_lossy(&out.stdout)).unwrap();
    assert_eq!(v.get("dtype").and_then(|j| j.as_str()), Some("int8"));
    assert_eq!(
        v.get("chosen").and_then(|j| j.get("dtype")).and_then(|j| j.as_str()),
        Some("int8")
    );
    assert_eq!(
        sharp(&["plan", "--hidden", "64", "--quant", "int4"]).status.code(),
        Some(2)
    );

    // Stacked shapes: per-layer rows carry the dtype too (v2 schema).
    let out = sharp(&[
        "plan", "--hidden", "64", "--layers", "2", "--quant", "int8", "--json",
    ]);
    assert!(out.status.success(), "stacked plan --quant failed: {out:?}");
    let v = sharp::util::json::parse(&String::from_utf8_lossy(&out.stdout)).unwrap();
    assert_eq!(
        v.get("schema").and_then(|j| j.as_str()),
        Some("sharp-plan-stack/v2")
    );
    assert_eq!(v.get("dtype").and_then(|j| j.as_str()), Some("int8"));
    let rows = v.get("layer_plans").and_then(|j| j.as_arr()).unwrap();
    assert!(rows
        .iter()
        .all(|r| r.get("plan").and_then(|p| p.as_str()).unwrap().ends_with("/int8")));

    // A pinned geometry OUTSIDE the tuner grid is appended as a scored
    // row, so exactly one candidate still carries the chosen mark.
    let out = sharp(&["plan", "--hidden", "64", "--plan", "fixed:3x5", "--json"]);
    assert!(out.status.success());
    let v = sharp::util::json::parse(&String::from_utf8_lossy(&out.stdout)).unwrap();
    let marks = v
        .get("candidates")
        .and_then(|j| j.as_arr())
        .unwrap()
        .iter()
        .filter(|c| matches!(c.get("chosen"), Some(sharp::util::json::Json::Bool(true))))
        .count();
    assert_eq!(marks, 1, "off-grid pinned plan gets its own chosen row");
}

#[test]
fn serve_json_snapshot_pins_v4_schema_with_net_block() {
    let entries = common::seq_entry_goldens("seq_h32_t4_b1", 4, 1, 32, 32, "w4");
    let (dir, _store) = common::synth_store("cli_serve_v4", &entries);
    common::write_lstm_goldens(&dir, "w4", 32, 32, 0xC11);
    let json_path = dir.join("metrics.json");
    let out = Command::new(env!("CARGO_BIN_EXE_sharp"))
        .args([
            "serve", "--hidden", "32", "--requests", "4", "--rate", "500", "--json",
            json_path.to_str().unwrap(),
        ])
        .env("SHARP_ARTIFACTS", &dir)
        .output()
        .expect("spawn sharp serve");
    assert!(
        out.status.success(),
        "sharp serve failed:\n{}\n{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    let text = std::fs::read_to_string(&json_path).expect("snapshot file");
    let v = sharp::util::json::parse(&text).expect("snapshot is valid JSON");
    assert_eq!(
        v.get("schema").and_then(|j| j.as_str()),
        Some("sharp-serve-metrics/v4"),
        "{text}"
    );
    // v4: the net block is always present, zeroed for an in-process
    // (non-TCP) run.
    let net = v.get("net").expect("v4 snapshot carries a net block");
    for key in [
        "conns_accepted",
        "conns_rejected",
        "conns_timed_out",
        "conns_drained",
        "frames_malformed",
        "retries_observed",
    ] {
        assert_eq!(
            net.get(key).and_then(|j| j.as_u64()),
            Some(0),
            "{key} in {text}"
        );
    }
}

#[test]
fn all_json_writes_one_file_per_exhibit_plus_summary() {
    let dir = std::env::temp_dir().join("sharp_cli_json_dump");
    let _ = std::fs::remove_dir_all(&dir);
    let out = sharp(&["all", "--json", dir.to_str().unwrap()]);
    assert!(out.status.success(), "sharp all --json failed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("EXPERIMENTS summary"), "summary missing");
    for id in sharp::experiments::ALL_IDS {
        let path = dir.join(format!("{id}.json"));
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("{path:?} missing: {e}"));
        let v = sharp::util::json::parse(&text)
            .unwrap_or_else(|e| panic!("{id}.json invalid: {e}"));
        assert_eq!(v.get("id").and_then(|j| j.as_str()), Some(id));
        assert!(
            !v.get("tables").unwrap().as_arr().unwrap().is_empty(),
            "{id}: no tables in JSON"
        );
    }
    assert!(dir.join("summary.txt").exists());
}
