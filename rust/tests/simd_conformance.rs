//! SIMD conformance: the vectorized micro-kernels (`runtime::kernel::
//! simd`) must be **bit-identical** to the scalar path — not merely
//! close — under every geometry, shape, schedule, thread count, and
//! dispatch route. The argument for why this can hold at all (one dot
//! product per vector lane, mul-then-add, k ascending) lives in the
//! simd module doc; this suite is the empirical half: a seeded
//! 200-case property sweep over `(T, B, D, H, mr, nr, threads)`, a
//! direct matmul-level panel sweep, explicit misaligned-tail shapes
//! (H not a lane multiple, panels narrower than one vector, B=1, T=1),
//! ragged fused-streaming occupancies, and the `SHARP_FORCE_KERNEL` /
//! `RuntimeConfig::force_kernel` dispatch knob.
//!
//! ISA coverage adapts to the host via `common::sweep_isas()`: scalar
//! always, plus the resolved vector ISA when one is dispatchable. CI
//! runs the suite twice in release — default dispatch and
//! `SHARP_FORCE_KERNEL=scalar` — so the scalar-pinned run proves the
//! fallback path end to end while the default run proves the vector
//! path (on AVX2 runners).

mod common;

use common::{
    assert_bits_eq, check_gru_threads, check_lstm_threads, seq_entry, sweep_isas, synth_store,
    SplitMix64,
};
use sharp::runtime::kernel::gemm::{matmul_packed, pack_b};
use sharp::runtime::plan::{ExecPlan, KernelGeometry, PlanMode, Schedule};
use sharp::runtime::{FusedBatch, Isa, LstmExecutable, RuntimeConfig};
use sharp::util::rng::Rng;

/// One matmul-level case: the vector-ISA geometry must reproduce the
/// scalar geometry's bits on the same packed panels and accumulation
/// base. This pins the kernel seam itself, below the RNN cell math.
fn check_matmul(m: usize, k: usize, n: usize, mr: usize, nr: usize, isa: Isa, seed: u64) {
    let mut rng = Rng::new(seed);
    let a = rng.vec_f32(m * k, -1.0, 1.0);
    let b = rng.vec_f32(k * n, -0.5, 0.5);
    let base = rng.vec_f32(m * n, -0.2, 0.2);
    let mut packed = Vec::new();
    pack_b(&b, k, n, nr, &mut packed);
    let geo = KernelGeometry::new(mr, nr).unwrap();

    let mut out_ref = base.clone();
    matmul_packed(&mut out_ref, &a, &packed, m, k, n, &geo);
    let mut out_v = base;
    matmul_packed(&mut out_v, &a, &packed, m, k, n, &geo.with_isa(isa));
    let ctx = format!("matmul m={m} k={k} n={n} mr{mr}/nr{nr}@{}", isa.name());
    assert_bits_eq(&out_v, &out_ref, &ctx);
}

#[test]
fn matmul_vector_kernels_match_scalar_on_random_panels() {
    // Random (m, k, n) with every candidate panel width, biased toward
    // the seams: n straddling lane multiples, panels narrower than one
    // vector (nr=4 under AVX2 -> scalar block), ragged last panels.
    let mut sm = SplitMix64::new(0x51AD_C0DE);
    for isa in sweep_isas() {
        for case in 0..40u64 {
            let m = sm.range_usize(1, 12);
            let k = sm.range_usize(1, 48);
            let n = sm.range_usize(1, 70);
            let mr = sm.range_usize(1, 8);
            let nr = sm.pick(&[1, 2, 3, 4, 5, 7, 8, 12, 16, 24, 31, 32]);
            check_matmul(m, k, n, mr, nr, isa, 0xA000 + case);
        }
    }
}

#[test]
fn unavailable_vector_isa_falls_back_to_scalar_cleanly() {
    // A hand-built geometry claiming the OTHER architecture's ISA (AVX2
    // and NEON are never both executable) must neither panic nor drift:
    // matmul downgrades it to the scalar kernels.
    let missing = Isa::ALL
        .into_iter()
        .find(|isa| !isa.available())
        .expect("avx2 and neon are never both available");
    check_matmul(5, 9, 33, 4, 16, missing, 0xFA11);
    check_matmul(1, 1, 1, 1, 4, missing, 0xFA12);
}

#[test]
fn property_sweep_200_cases_simd_matches_scalar() {
    // The headline sweep (satellite 2): 200 seeded random cases over
    // (T, B, D, H, mr, nr, schedule, threads, kind), each checked
    // bit-exactly against the scalar oracle under every dispatchable
    // ISA. SplitMix64 drives case *selection*; the tensor values come
    // from the shared harness generator keyed by the derived seed, so
    // the whole sweep replays from one literal.
    let isas = sweep_isas();
    let mut sm = SplitMix64::new(0x5EED_2026);
    for case in 0..200u64 {
        let t = sm.range_usize(1, 5);
        let b = sm.range_usize(1, 4);
        let d = sm.range_usize(1, 32);
        let h = sm.range_usize(1, 64);
        let mr = sm.range_usize(1, 8);
        let nr = sm.pick(&[1, 3, 4, 5, 8, 12, 16, 24, 32]);
        let schedule = sm.pick(&[Schedule::Unfolded, Schedule::Stepwise]);
        let threads = sm.pick(&[1usize, 2, 3, 4]);
        let gru = case % 3 == 2;
        let seed = sm.next_u64();
        for &isa in &isas {
            let plan = ExecPlan {
                geometry: KernelGeometry::new(mr, nr).unwrap().with_isa(isa),
                schedule,
            };
            if gru {
                check_gru_threads(t, b, d, h, &plan, &[threads], seed);
            } else {
                check_lstm_threads(t, b, d, h, &plan, &[threads], seed);
            }
        }
    }
}

#[test]
fn misaligned_tails_are_exact_under_every_panel_width() {
    // The shapes SIMD gets wrong first, pinned explicitly (the sweep
    // above also hits them probabilistically): gate matrices whose
    // width G*H is not a lane multiple, single-row and single-step
    // cases, and every candidate panel width over each — including
    // panels narrower than one vector.
    let shapes: &[(usize, usize, usize, usize)] = &[
        (1, 1, 1, 1),   // everything degenerate
        (1, 1, 3, 7),   // T=B=1, G*H=28: one ragged half-vector tail
        (2, 1, 5, 9),   // G*H=36: 4 full lanes + tail under AVX2
        (3, 2, 7, 17),  // prime-ish H, G*H=68
        (1, 4, 16, 31), // T=1 batch, G*H=124: 15 vectors + 4-wide tail
        (2, 2, 8, 33),  // just past a power of two
        (4, 1, 9, 63),  // B=1 stream, G*H=252
    ];
    for isa in sweep_isas() {
        for (i, &(t, b, d, h)) in shapes.iter().enumerate() {
            for (j, &nr) in [4usize, 8, 16, 32].iter().enumerate() {
                let plan = ExecPlan {
                    geometry: KernelGeometry::new(4, nr).unwrap().with_isa(isa),
                    schedule: Schedule::Unfolded,
                };
                check_lstm_threads(t, b, d, h, &plan, &[1, 4], 0xB000 + (i * 10 + j) as u64);
            }
        }
    }
}

/// Two executables over the same weights: one pinned to the scalar
/// kernels, one on default dispatch (the vector ISA when the host has
/// one).
fn scalar_and_default_exes(tag: &str) -> (std::path::PathBuf, LstmExecutable, LstmExecutable) {
    let (d, h, t) = (12usize, 20usize, 8usize);
    let (dir, store) = synth_store(tag, &seq_entry("seq_stream", "seq", t, 1, d, h));
    let mut rng = Rng::new(0xD15B);
    let wx = rng.vec_f32(d * 4 * h, -0.3, 0.3);
    let wh = rng.vec_f32(h * 4 * h, -0.3, 0.3);
    let bias = rng.vec_f32(4 * h, -0.2, 0.2);
    let mut scalar_exe =
        LstmExecutable::with_weights(&store, "seq_stream", wx.clone(), wh.clone(), bias.clone())
            .unwrap();
    scalar_exe
        .set_runtime(RuntimeConfig {
            threads: 1,
            plan: PlanMode::Auto,
            force_kernel: Some(Isa::Scalar),
            ..RuntimeConfig::default()
        })
        .unwrap();
    let default_exe = LstmExecutable::with_weights(&store, "seq_stream", wx, wh, bias).unwrap();
    (dir, scalar_exe, default_exe)
}

#[test]
fn ragged_fused_occupancies_match_between_scalar_and_default_dispatch() {
    // The fused-streaming path (run_steps_batched_into) re-plans per
    // window occupancy and inherits the bound ISA; ragged lane lengths
    // (lanes retiring mid-window, down to a single survivor) must give
    // the same bits whether the kernels are scalar or vectorized.
    let (_dir, scalar_exe, default_exe) = scalar_and_default_exes("fused_ragged");
    let (d, h) = (scalar_exe.entry.d, scalar_exe.entry.h);
    let mut rng = Rng::new(0xFE11);
    let lens = [8usize, 7, 5, 5, 2, 1];
    let lanes: Vec<(usize, Vec<f32>, Vec<f32>, Vec<f32>)> = lens
        .iter()
        .map(|&len| {
            (
                len,
                rng.vec_f32(h, -1.0, 1.0),
                rng.vec_f32(h, -1.0, 1.0),
                rng.vec_f32(len * d, -1.0, 1.0),
            )
        })
        .collect();
    let run = |exe: &LstmExecutable| {
        let mut batch = FusedBatch::new();
        batch.begin(d, h);
        for (len, h0, c0, frames) in &lanes {
            batch.push_lane(frames, *len, h0, c0);
        }
        batch.finish();
        exe.run_steps_batched_into(&mut batch).unwrap();
        (0..lanes.len())
            .map(|i| (batch.lane_h(i).to_vec(), batch.lane_c(i).to_vec()))
            .collect::<Vec<_>>()
    };
    let scalar_lanes = run(&scalar_exe);
    let default_lanes = run(&default_exe);
    for (i, (s, v)) in scalar_lanes.iter().zip(&default_lanes).enumerate() {
        assert_bits_eq(&v.0, &s.0, &format!("fused lane {i} h (len={})", lens[i]));
        assert_bits_eq(&v.1, &s.1, &format!("fused lane {i} c (len={})", lens[i]));
        // And both match the solo chain for that lane alone.
        let (len, h0, c0, frames) = &lanes[i];
        let solo = scalar_exe.run_prefix(frames, *len, h0, c0).unwrap();
        assert_bits_eq(&s.0, &solo.h_t, &format!("fused lane {i} vs solo h"));
        assert_bits_eq(&s.1, &solo.c_t, &format!("fused lane {i} vs solo c"));
    }
}

#[test]
fn forced_dispatch_routes_are_exercised_and_equal() {
    // Satellite 3, integration level: pinning the scalar kernels and
    // running default dispatch on the same weights/inputs produce the
    // same bits via genuinely different code paths (when the host has a
    // vector ISA; on a scalar-only host both pins resolve identically,
    // which is exactly the clean-fallback contract).
    let (_dir, scalar_exe, default_exe) = scalar_and_default_exes("forced");
    assert_eq!(scalar_exe.plan().geometry.isa, Isa::Scalar);
    let resolved = RuntimeConfig::default().resolve_isa().unwrap();
    assert_eq!(default_exe.plan().geometry.isa, resolved);

    let (d, t) = (scalar_exe.entry.d, scalar_exe.entry.t);
    let mut rng = Rng::new(0xF0CE);
    let xs = rng.vec_f32(t * d, -1.0, 1.0);
    let (h0, c0) = scalar_exe.zero_state();
    let a = scalar_exe.run(&xs, &h0, &c0).unwrap();
    let b = default_exe.run(&xs, &h0, &c0).unwrap();
    assert_bits_eq(&b.hs, &a.hs, "forced-scalar vs default dispatch: hs");
    assert_bits_eq(&b.h_t, &a.h_t, "forced-scalar vs default dispatch: h_t");
    assert_bits_eq(&b.c_t, &a.c_t, "forced-scalar vs default dispatch: c_t");
}

#[test]
fn forcing_an_unavailable_isa_is_a_loud_bind_error() {
    // The knob must never fall back silently: forcing the other
    // architecture's ISA fails at bind with both names in the message.
    let missing = Isa::ALL
        .into_iter()
        .find(|isa| !isa.available())
        .expect("avx2 and neon are never both available");
    let (_dir, store) = synth_store("forced_err", &seq_entry("seq_small", "seq", 2, 1, 3, 4));
    let mut rng = Rng::new(7);
    let wx = rng.vec_f32(3 * 4 * 4, -0.3, 0.3);
    let wh = rng.vec_f32(4 * 4 * 4, -0.3, 0.3);
    let bias = rng.vec_f32(4 * 4, -0.2, 0.2);
    let mut exe = LstmExecutable::with_weights(&store, "seq_small", wx, wh, bias).unwrap();
    let before = *exe.plan();
    let err = exe
        .set_runtime(RuntimeConfig {
            threads: 1,
            plan: PlanMode::Auto,
            force_kernel: Some(missing),
            ..RuntimeConfig::default()
        })
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains(missing.name()), "{msg}");
    assert!(msg.contains("not available"), "{msg}");
    // The failed re-plan left the executable untouched and runnable.
    assert_eq!(*exe.plan(), before);
    let (h0, c0) = exe.zero_state();
    let xs = Rng::new(8).vec_f32(2 * 3, -1.0, 1.0);
    exe.run(&xs, &h0, &c0).unwrap();
}
