//! Integration: the serving coordinator end-to-end over real compiled
//! artifacts — batching correctness (right answer per request id even
//! when batched with others), backpressure behaviour, and metric sanity.
//! Skips when `make artifacts` has not run.

use sharp::coordinator::{InferenceRequest, Server, ServerConfig};
use sharp::runtime::{ArtifactStore, LstmExecutable};
use sharp::util::rng::Rng;

fn artifacts_present() -> bool {
    match ArtifactStore::open_default() {
        Ok(_) => true,
        Err(e) => {
            eprintln!("SKIP: no artifacts ({e:#}); run `make artifacts`");
            false
        }
    }
}

#[test]
fn batched_responses_match_unbatched_reference() {
    if !artifacts_present() {
        return;
    }
    let hidden = 256usize;
    let server = Server::start(ServerConfig {
        hidden,
        ..Default::default()
    })
    .expect("server start");

    // Build 8 random requests of different lengths, submit concurrently
    // (so the batcher actually groups them), then compare each response
    // against a direct single-request execution on this thread.
    let mut rng = Rng::new(99);
    let reqs: Vec<(usize, Vec<f32>)> = (0..8)
        .map(|i| {
            let len = [4usize, 9, 16][i % 3];
            (len, rng.vec_f32(len * hidden, -1.0, 1.0))
        })
        .collect();
    let receivers: Vec<_> = reqs
        .iter()
        .enumerate()
        .map(|(i, (len, payload))| {
            server.submit(InferenceRequest::new(i as u64, *len, payload.clone()))
        })
        .collect();
    let responses: Vec<_> = receivers
        .into_iter()
        .map(|rx| rx.recv().expect("worker alive").expect("request ok"))
        .collect();

    // Reference: run each request alone through the runtime.
    let store = ArtifactStore::open_default().unwrap();
    for (i, (len, payload)) in reqs.iter().enumerate() {
        let entry = store.manifest.pick_seq(hidden, *len, 1).expect("bucket");
        let exe = LstmExecutable::from_store_goldens(&store, &entry.name).unwrap();
        // Pack (T, B, D) with this request in lane 0, zeros elsewhere.
        let (t, b, d) = (entry.t, entry.b, entry.d);
        let mut xs = vec![0.0f32; t * b * d];
        for step in 0..*len {
            xs[(step * b) * d..(step * b) * d + d]
                .copy_from_slice(&payload[step * d..(step + 1) * d]);
        }
        let (h0, c0) = exe.zero_state();
        let out = exe.run(&xs, &h0, &c0).unwrap();
        let step = len - 1;
        let want = &out.hs[(step * b) * entry.h..(step * b) * entry.h + entry.h];
        let got = &responses[i].h_t;
        let diff = sharp::runtime::literal::max_abs_diff(got, want);
        assert!(diff < 1e-4, "request {i} (len {len}): diff {diff}");
    }

    let mut metrics = server.metrics.lock().unwrap();
    assert_eq!(metrics.completed, 8);
    assert_eq!(metrics.errors, 0);
    assert!(metrics.latency_s.p99() > 0.0);
    drop(metrics);
    server.shutdown();
}

#[test]
fn oversized_request_is_rejected_not_dropped() {
    if !artifacts_present() {
        return;
    }
    let server = Server::start(ServerConfig {
        hidden: 256,
        ..Default::default()
    })
    .expect("server start");
    let too_long = 10_000usize;
    let resp = server
        .submit(InferenceRequest::new(0, too_long, vec![0.0; 256]))
        .recv()
        .expect("worker alive");
    assert!(resp.is_err(), "absurd seq_len must be rejected");
    assert_eq!(server.metrics.lock().unwrap().errors, 1);
    server.shutdown();
}

#[test]
fn server_survives_a_closed_burst() {
    if !artifacts_present() {
        return;
    }
    let server = Server::start(ServerConfig {
        hidden: 256,
        ..Default::default()
    })
    .expect("server start");
    let mut rng = Rng::new(5);
    let n = 20;
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let len = rng.range_usize(2, 16);
            server.submit(InferenceRequest::new(
                i as u64,
                len,
                rng.vec_f32(len * 256, -1.0, 1.0),
            ))
        })
        .collect();
    let ok = rxs
        .into_iter()
        .filter(|rx| rx.recv().map(|r| r.is_ok()).unwrap_or(false))
        .count();
    assert_eq!(ok, n, "burst must be fully served");
    assert!(server.metrics.lock().unwrap().batch_sizes.max() >= 2.0, "burst should batch");
    server.shutdown();
}
