//! Integration: the serving coordinator end-to-end over real compiled
//! artifacts — batching correctness (right answer per request id even
//! when batched with others), multi-worker/multi-dim routing, streaming
//! session carry-correctness, backpressure behaviour, and metric sanity.
//! Skips when `make artifacts` has not run.

use sharp::coordinator::{
    AdaptiveConfig, BatcherConfig, InferenceRequest, Server, ServerConfig,
};
use sharp::runtime::{ArtifactStore, LstmExecutable};
use sharp::util::rng::Rng;

fn artifacts_present() -> bool {
    match ArtifactStore::open_default() {
        Ok(_) => true,
        Err(e) => {
            eprintln!("SKIP: no artifacts ({e:#}); run `make artifacts`");
            false
        }
    }
}

/// Reference for a stateless request: run it alone on the artifact the
/// router binds (smallest fitting T, widest B), lane 0.
fn reference_h(store: &ArtifactStore, hidden: usize, len: usize, payload: &[f32]) -> Vec<f32> {
    let entry = store.manifest.pick_seq(hidden, len, 1).expect("bucket");
    let exe = LstmExecutable::from_store_goldens(store, &entry.name).unwrap();
    let (t, b, d) = (entry.t, entry.b, entry.d);
    let mut xs = vec![0.0f32; t * b * d];
    for step in 0..len {
        xs[(step * b) * d..(step * b) * d + d].copy_from_slice(&payload[step * d..(step + 1) * d]);
    }
    let (h0, c0) = exe.zero_state();
    let out = exe.run(&xs, &h0, &c0).unwrap();
    let step = len - 1;
    out.hs[(step * b) * entry.h..(step * b) * entry.h + entry.h].to_vec()
}

#[test]
fn batched_responses_match_unbatched_reference() {
    if !artifacts_present() {
        return;
    }
    let hidden = 256usize;
    let server = Server::start(ServerConfig {
        hidden: vec![hidden],
        ..Default::default()
    })
    .expect("server start");

    // Build 8 random requests of different lengths, submit concurrently
    // (so the batcher actually groups them), then compare each response
    // against a direct single-request execution on this thread.
    let mut rng = Rng::new(99);
    let reqs: Vec<(usize, Vec<f32>)> = (0..8)
        .map(|i| {
            let len = [4usize, 9, 16][i % 3];
            (len, rng.vec_f32(len * hidden, -1.0, 1.0))
        })
        .collect();
    let receivers: Vec<_> = reqs
        .iter()
        .enumerate()
        .map(|(i, (len, payload))| {
            server.submit(InferenceRequest::new(i as u64, *len, payload.clone()))
        })
        .collect();
    let responses: Vec<_> = receivers
        .into_iter()
        .map(|rx| rx.recv().expect("worker alive").expect("request ok"))
        .collect();

    let store = ArtifactStore::open_default().unwrap();
    for (i, (len, payload)) in reqs.iter().enumerate() {
        let want = reference_h(&store, hidden, *len, payload);
        let diff = sharp::runtime::literal::max_abs_diff(&responses[i].h_t, &want);
        assert!(diff < 1e-4, "request {i} (len {len}): diff {diff}");
    }

    assert!(
        responses.iter().all(|r| r.session_steps.is_none()),
        "stateless responses carry no session step count"
    );
    let mut metrics = server.metrics().expect("all workers report");
    assert_eq!(metrics.completed, 8);
    assert_eq!(metrics.errors, 0);
    assert!(metrics.latency_s.p99() > 0.0);
    server.shutdown();
}

#[test]
fn oversized_request_is_rejected_not_dropped() {
    if !artifacts_present() {
        return;
    }
    let server = Server::start(ServerConfig {
        hidden: vec![256],
        ..Default::default()
    })
    .expect("server start");
    let too_long = 10_000usize;
    let resp = server
        .submit(InferenceRequest::new(0, too_long, vec![0.0; 256]))
        .recv()
        .expect("worker alive");
    assert!(resp.is_err(), "absurd seq_len must be rejected");
    let zero = server
        .submit(InferenceRequest::new(1, 0, vec![]))
        .recv()
        .expect("worker alive");
    assert!(zero.is_err(), "zero-frame request must be rejected, not faked");
    assert_eq!(server.metrics().expect("all workers report").errors, 2);
    server.shutdown();
}

#[test]
fn server_survives_a_closed_burst() {
    if !artifacts_present() {
        return;
    }
    let server = Server::start(ServerConfig {
        hidden: vec![256],
        ..Default::default()
    })
    .expect("server start");
    let mut rng = Rng::new(5);
    let n = 20;
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let len = rng.range_usize(2, 16);
            server.submit(InferenceRequest::new(
                i as u64,
                len,
                rng.vec_f32(len * 256, -1.0, 1.0),
            ))
        })
        .collect();
    let ok = rxs
        .into_iter()
        .filter(|rx| rx.recv().map(|r| r.is_ok()).unwrap_or(false))
        .count();
    assert_eq!(ok, n, "burst must be fully served");
    // Adaptive acceptance shape: a closed burst is a high observed
    // arrival rate, so batches must have grown past singletons.
    assert!(
        server.metrics().expect("all workers report").batch_sizes.max() >= 2.0,
        "burst should batch"
    );
    server.shutdown();
}

#[test]
fn multi_worker_pool_routes_two_hidden_dims() {
    if !artifacts_present() {
        return;
    }
    let store = ArtifactStore::open_default().unwrap();
    let dims_avail = store.manifest.seq_hidden_dims();
    let dims: Vec<usize> = [64usize, 256]
        .into_iter()
        .filter(|d| dims_avail.contains(d))
        .collect();
    if dims.len() < 2 {
        eprintln!("SKIP: need seq artifacts for two hidden dims, have {dims_avail:?}");
        return;
    }
    let server = Server::start(ServerConfig {
        hidden: dims.clone(),
        workers: 4,
        ..Default::default()
    })
    .expect("server start");

    // Interleave requests for both dims with NO explicit hint: the
    // payload width must resolve the variant; spot-check numerics per
    // dim against the single-request reference.
    let mut rng = Rng::new(17);
    let reqs: Vec<(usize, usize, Vec<f32>)> = (0..12)
        .map(|i| {
            let h = dims[i % dims.len()];
            let len = 4usize + (i % 3);
            (h, len, rng.vec_f32(len * h, -1.0, 1.0))
        })
        .collect();
    let rxs: Vec<_> = reqs
        .iter()
        .enumerate()
        .map(|(i, (_, len, payload))| {
            server.submit(InferenceRequest::new(i as u64, *len, payload.clone()))
        })
        .collect();
    for (rx, (h, len, payload)) in rxs.into_iter().zip(&reqs) {
        let resp = rx.recv().expect("worker alive").expect("request ok");
        assert_eq!(resp.h_t.len(), *h, "response width names the variant");
        let want = reference_h(&store, *h, *len, payload);
        let diff = sharp::runtime::literal::max_abs_diff(&resp.h_t, &want);
        assert!(diff < 1e-4, "H={h} len={len}: diff {diff}");
    }
    let metrics = server.metrics().expect("all workers report");
    assert_eq!(metrics.completed, 12);
    assert_eq!(metrics.errors, 0);

    // An explicitly-requested unserved dim errors; an ambiguous payload
    // (width matching no served dim) errors too. Neither is dropped.
    let bad = server
        .submit(InferenceRequest::new(99, 4, vec![0.0; 4 * dims[0]]).with_hidden(100_000))
        .recv()
        .expect("worker alive");
    assert!(bad.is_err(), "unserved dim must be rejected");
    let ambiguous = server
        .submit(InferenceRequest::new(100, 4, vec![0.0; 4 * 100]))
        .recv()
        .expect("worker alive");
    assert!(ambiguous.is_err(), "unresolvable width must be rejected");
    server.shutdown();
}

#[test]
fn streaming_session_carry_matches_single_shot() {
    if !artifacts_present() {
        return;
    }
    let hidden = 256usize;
    let t = 16usize;
    let mut rng = Rng::new(4242);
    let utterance = rng.vec_f32(t * hidden, -1.0, 1.0);
    let chunks = [3usize, 5, 8];
    let session = 0xFEED_u64;

    let server = Server::start(ServerConfig {
        hidden: vec![hidden],
        workers: 4, // affinity must pin all chunks to one owner
        ..Default::default()
    })
    .expect("server start");
    server.begin_session(session, hidden).expect("begin");
    let mut consumed = 0usize;
    let mut last_h = Vec::new();
    for (ci, &len) in chunks.iter().enumerate() {
        let payload = utterance[consumed * hidden..(consumed + len) * hidden].to_vec();
        let resp = server
            .chunk(session, ci as u64, len, payload)
            .expect("chunk ok");
        assert_eq!(resp.batch_size, 1, "a lone session's chunks run solo");
        assert_eq!(
            resp.session_steps,
            Some(ci as u64 + 1),
            "step count tracks the carry (a reset here would mean eviction)"
        );
        consumed += len;
        last_h = resp.h_t;
    }
    assert_eq!(consumed, t);
    let final_state = server
        .end_session(session)
        .expect("server alive")
        .expect("session live");
    assert_eq!(final_state.steps, chunks.len() as u64);
    assert_eq!(final_state.h, last_h, "response carry == stored carry");
    assert!(
        server.end_session(session).expect("server alive").is_none(),
        "ended session is gone"
    );

    // begin_session RESETS a live id: a reused/abandoned session must
    // not leak its previous carry into the new stream.
    server.begin_session(session, hidden).expect("begin");
    server
        .chunk(session, 100, 4, utterance[..4 * hidden].to_vec())
        .expect("chunk ok");
    server.begin_session(session, hidden).expect("re-begin");
    let fresh = server
        .end_session(session)
        .expect("server alive")
        .expect("session live");
    assert_eq!(fresh.steps, 0, "re-begin must zero the carry");
    assert!(fresh.h.iter().all(|v| *v == 0.0));
    server.shutdown();

    // Single-shot equivalent on the SAME artifact sessions pin
    // (`Manifest::session_seq` — every artifact carries its own golden
    // weights). run_prefix stops at frame 16 exactly, like the chunks.
    let store = ArtifactStore::open_default().unwrap();
    let entry = store
        .manifest
        .session_seq(hidden)
        .expect("seq artifacts exist")
        .clone();
    assert!(entry.t >= t, "session bucket too small for this test");
    let exe = LstmExecutable::from_store_goldens(&store, &entry.name).unwrap();
    let (b, d) = (entry.b, entry.d);
    let mut xs = vec![0.0f32; t * b * d];
    for step in 0..t {
        xs[step * b * d..step * b * d + d]
            .copy_from_slice(&utterance[step * hidden..(step + 1) * hidden]);
    }
    let (h0, c0) = exe.zero_state();
    let full = exe.run_prefix(&xs, t, &h0, &c0).unwrap();
    let dh = sharp::runtime::literal::max_abs_diff(&final_state.h, &full.h_t[..hidden]);
    let dc = sharp::runtime::literal::max_abs_diff(&final_state.c, &full.c_t[..hidden]);
    assert!(dh < 1e-4 && dc < 1e-4, "carry diverged: dh={dh} dc={dc}");
}

#[test]
fn fused_streaming_windows_are_bit_identical_to_solo() {
    if !artifacts_present() {
        return;
    }
    let hidden = 256usize;
    let sessions = 6usize;
    // Force fuse windows deterministically: adaptive off, seed policy
    // waits up to 30 ms for 6 distinct sessions. All of a round's
    // chunks are submitted before any reply is awaited, so each round
    // closes on the size bound, not the clock.
    let server = Server::start(ServerConfig {
        hidden: vec![hidden],
        workers: 1, // every session on one worker: windows actually fuse
        batcher: BatcherConfig {
            max_batch: sessions,
            max_wait: std::time::Duration::from_millis(30),
        },
        adaptive: AdaptiveConfig {
            enabled: false,
            sla_wait: std::time::Duration::from_millis(50),
            ..Default::default()
        },
        ..Default::default()
    })
    .expect("server start");

    // Session i streams chunk lengths [4, (i % 3) + 1]: equal chunk
    // counts (full windows both rounds) but ragged lengths inside round
    // 2, so lanes retire mid-window. Session 0 gets a third chunk that
    // will ride alone — the degenerate solo window.
    let mut rng = Rng::new(0xF05E);
    let scripts: Vec<Vec<Vec<f32>>> = (0..sessions)
        .map(|i| {
            let mut lens = vec![4usize, (i % 3) + 1];
            if i == 0 {
                lens.push(2);
            }
            lens.iter()
                .map(|&len| rng.vec_f32(len * hidden, -1.0, 1.0))
                .collect()
        })
        .collect();

    // Solo reference: chain each session's chunks through run_prefix on
    // the artifact sessions pin, lane 0 — the pre-fusion solo path.
    let store = ArtifactStore::open_default().unwrap();
    let entry = store
        .manifest
        .session_seq(hidden)
        .expect("seq artifacts exist")
        .clone();
    let exe = LstmExecutable::from_store_goldens(&store, &entry.name).unwrap();
    let (b, d) = (entry.b, entry.d);
    let mut expected: Vec<Vec<Vec<f32>>> = Vec::new(); // [session][chunk] -> h_t
    for script in &scripts {
        let (mut h0, mut c0) = exe.zero_state();
        let mut outs = Vec::new();
        for chunk in script {
            let len = chunk.len() / d;
            let mut xs = vec![0.0f32; len * b * d];
            for step in 0..len {
                xs[step * b * d..step * b * d + d]
                    .copy_from_slice(&chunk[step * d..(step + 1) * d]);
            }
            let out = exe.run_prefix(&xs, len, &h0, &c0).unwrap();
            h0.clear();
            h0.extend_from_slice(&out.h_t);
            c0.clear();
            c0.extend_from_slice(&out.c_t);
            outs.push(out.h_t[..hidden].to_vec());
        }
        expected.push(outs);
    }

    for sid in 0..sessions {
        server.begin_session(sid as u64, hidden).expect("begin");
    }
    // Two full rounds: submit every session's chunk, then await all.
    for round in 0..2 {
        let rxs: Vec<_> = (0..sessions)
            .map(|sid| {
                let payload = scripts[sid][round].clone();
                let len = payload.len() / hidden;
                server.submit(
                    InferenceRequest::new((round * sessions + sid) as u64, len, payload)
                        .with_session(sid as u64),
                )
            })
            .collect();
        for (sid, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().expect("worker alive").expect("chunk ok");
            assert_eq!(
                resp.session_steps,
                Some(round as u64 + 1),
                "carry tracked (no surprise eviction)"
            );
            // BIT equality against the solo reference — fused windows
            // must not move a single bit of any session's stream.
            assert_eq!(
                resp.h_t, expected[sid][round],
                "session {sid} round {round} diverged under fusion"
            );
        }
    }
    // Session 0's third chunk rides alone: a single-session window that
    // closes on the clock and degenerates to the solo path.
    let resp = server
        .chunk(0, 99, 2, scripts[0][2].clone())
        .expect("solo chunk ok");
    assert_eq!(resp.batch_size, 1, "lone session executes solo");
    assert_eq!(resp.session_steps, Some(3));
    assert_eq!(resp.h_t, expected[0][2], "solo window h_t matches reference");

    let metrics = server.metrics().expect("all workers report");
    assert!(metrics.fused_steps > 0, "no window ever fused");
    assert!(metrics.solo_steps >= 2, "the lone chunk ran solo steps");
    assert!(
        metrics.lane_occupancy.max() >= 2.0,
        "fused occupancy never exceeded one lane"
    );
    // Lane-step conservation: however the rounds split into windows,
    // the occupancy histogram must account for every frame served.
    let lane_steps: f64 = metrics.lane_occupancy.mean() * metrics.lane_occupancy.len() as f64;
    let frames: usize = scripts.iter().flatten().map(|c| c.len() / hidden).sum::<usize>();
    assert_eq!(lane_steps.round() as usize, frames, "occupancy accounts for all frames");

    for sid in 0..sessions {
        let fin = server
            .end_session(sid as u64)
            .expect("server alive")
            .expect("session live");
        let last = expected[sid].last().expect("every session has chunks");
        assert_eq!(
            &fin.h, last,
            "session {sid} final carry == solo reference"
        );
    }
    server.shutdown();
}

#[test]
fn end_session_fences_queued_chunks() {
    if !artifacts_present() {
        return;
    }
    // A chunk parked in the fuse window when End arrives must execute
    // BEFORE the session ends: the final carry includes it, and no
    // ghost session is resurrected afterwards.
    let hidden = 256usize;
    let server = Server::start(ServerConfig {
        hidden: vec![hidden],
        workers: 1,
        // Disabled adaptive + a 4-session / 100 ms seed window: with a
        // second live session around, a lone chunk genuinely parks.
        batcher: BatcherConfig {
            max_batch: 4,
            max_wait: std::time::Duration::from_millis(100),
        },
        adaptive: AdaptiveConfig {
            enabled: false,
            sla_wait: std::time::Duration::from_millis(200),
            ..Default::default()
        },
        ..Default::default()
    })
    .expect("server start");
    server.begin_session(1, hidden).expect("begin A");
    server.begin_session(2, hidden).expect("begin B"); // keeps the window open
    let mut rng = Rng::new(99);
    let payload = rng.vec_f32(3 * hidden, -1.0, 1.0);
    // Non-blocking submit, then End races in behind it on the same
    // channel: the worker must fence, not overtake.
    let rx = server.submit(InferenceRequest::new(7, 3, payload).with_session(1));
    let fin = server
        .end_session(1)
        .expect("server alive")
        .expect("session still had state");
    let resp = rx.recv().expect("worker alive").expect("fenced chunk ok");
    assert_eq!(resp.session_steps, Some(1), "chunk executed before End");
    assert_eq!(fin.steps, 1, "final carry includes the fenced chunk");
    assert_eq!(fin.h, resp.h_t, "returned carry == the chunk's carry");
    assert!(
        server.end_session(1).expect("server alive").is_none(),
        "no ghost session resurrected after End"
    );
    server.shutdown();
}

#[test]
fn full_worker_queues_backpressure_not_drop() {
    if !artifacts_present() {
        return;
    }
    // Tiny bounded queues + a burst far larger than total capacity: the
    // dispatcher must block (backpressure) rather than shed load.
    let server = Server::start(ServerConfig {
        hidden: vec![256],
        workers: 2,
        queue_cap: 2,
        ..Default::default()
    })
    .expect("server start");
    let mut rng = Rng::new(31);
    let n = 48;
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let len = rng.range_usize(2, 16);
            server.submit(InferenceRequest::new(
                i as u64,
                len,
                rng.vec_f32(len * 256, -1.0, 1.0),
            ))
        })
        .collect();
    let ok = rxs
        .into_iter()
        .filter(|rx| rx.recv().map(|r| r.is_ok()).unwrap_or(false))
        .count();
    assert_eq!(ok, n, "every request must be served, none dropped");
    let metrics = server.metrics().expect("all workers report");
    assert_eq!(metrics.completed, n as u64);
    assert_eq!(metrics.errors, 0);
    server.shutdown();
}
