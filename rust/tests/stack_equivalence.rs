//! Stacked-vs-sequential equivalence sweep — the tentpole's bit-exactness
//! gate. Every stacked variant the runtime serves (depth 2..4, LSTM and
//! GRU, unidirectional and bidirectional, with and without the LSTMP
//! output projection) must be BIT-IDENTICAL to a layer-by-layer
//! composition of the scalar oracle (`runtime::exec`), under the
//! sequential driver, the inter-layer step pipeline at several thread
//! budgets, chunked streaming with carried state, and every vector ISA
//! this host can exercise. Identity, not tolerance: the pipeline moves
//! *which layer runs when*, never any dot product's k-order, and this
//! sweep is what enforces that claim (see DESIGN.md §10).
//!
//! The oracle here is deliberately INDEPENDENT of the stack drivers: it
//! chains full-sequence scalar kernel calls by hand (reverse/concat for
//! the bidirectional halves, a local k-ascending projection), so a bug
//! in the drivers' shared plumbing cannot cancel itself out.

mod common;

use common::{assert_bits_eq, stack_entry, sweep_isas, synth_store};
use sharp::runtime::{
    exec, DirWeights, RuntimeConfig, StackExecutable, StackLayerWeights, StackOutput,
};
use sharp::util::rng::Rng;

const T: usize = 6;
const B: usize = 2;
const D: usize = 5;
const H: usize = 7;
const P: usize = 3;

/// One sweep point. `proj > 0` only for LSTM (the LSTMP variant).
#[derive(Clone, Copy)]
struct Case {
    layers: usize,
    bi: bool,
    gru: bool,
    proj: usize,
}

impl Case {
    fn name(&self) -> String {
        format!(
            "stk{}_{}{}{}",
            self.layers,
            if self.bi { "bi" } else { "uni" },
            if self.proj > 0 { "_p" } else { "" },
            if self.gru { "_gru" } else { "" },
        )
    }

    fn kind(&self) -> &'static str {
        if self.gru {
            "gru_seq"
        } else {
            "seq"
        }
    }

    fn dirs(&self) -> usize {
        if self.bi {
            2
        } else {
            1
        }
    }

    /// Per-direction layer output width (`P` when projecting, else `H`).
    fn dir_w(&self) -> usize {
        if self.proj > 0 {
            self.proj
        } else {
            H
        }
    }

    fn out_w(&self) -> usize {
        self.dirs() * self.dir_w()
    }
}

/// The full sweep: L in {2, 3, 4} x {uni, bi} x {LSTM, LSTMP, GRU}.
fn cases() -> Vec<Case> {
    let mut v = Vec::new();
    for layers in [2usize, 3, 4] {
        for bi in [false, true] {
            v.push(Case { layers, bi, gru: false, proj: 0 });
            v.push(Case { layers, bi, gru: false, proj: P });
            v.push(Case { layers, bi, gru: true, proj: 0 });
        }
    }
    v
}

/// Manifest body covering every sweep case (weights bind explicitly).
fn all_entries() -> String {
    cases()
        .iter()
        .map(|c| stack_entry(&c.name(), c.kind(), T, B, D, H, c.layers, c.bi, c.proj))
        .collect::<Vec<_>>()
        .join(",")
}

fn gen_dir(rng: &mut Rng, d_l: usize, g: usize, p: usize) -> DirWeights {
    DirWeights {
        wx: rng.vec_f32(d_l * g * H, -0.35, 0.35),
        wh: rng.vec_f32(H * g * H, -0.35, 0.35),
        bias: rng.vec_f32(g * H, -0.2, 0.2),
        wp: rng.vec_f32(H * p, -0.4, 0.4),
    }
}

/// Per-case deterministic weights; callers clone a copy into `bind`
/// (which drops the dense `wx`/`wh`) and keep the raw set for the
/// oracle.
fn gen_weights(case: &Case, seed: u64) -> Vec<StackLayerWeights> {
    let mut rng = Rng::new(seed);
    let g = if case.gru { 3 } else { 4 };
    (0..case.layers)
        .map(|l| {
            let d_l = if l == 0 { D } else { case.out_w() };
            StackLayerWeights {
                fwd: gen_dir(&mut rng, d_l, g, case.proj),
                bwd: case.bi.then(|| gen_dir(&mut rng, d_l, g, case.proj)),
            }
        })
        .collect()
}

/// Deterministic inputs + initial state for one case.
fn gen_inputs(case: &Case, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let xs = rng.vec_f32(T * B * D, -1.0, 1.0);
    let state = case.layers * case.dirs() * B * H;
    let h0 = rng.vec_f32(state, -1.0, 1.0);
    // GRU kinds ignore c0 and mirror h; random is still valid input.
    let c0 = rng.vec_f32(state, -1.0, 1.0);
    (xs, h0, c0)
}

/// `x @ wp` with a k-ascending fold from 0.0 — per element the same
/// float-op sequence as the runtime's shared projection helper, but
/// restated independently of it.
fn project_ref(x: &[f32], wp: &[f32], rows: usize, p: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * p];
    for r in 0..rows {
        for j in 0..p {
            let mut acc = 0.0f32;
            for k in 0..H {
                acc += x[r * H + k] * wp[k * p + j];
            }
            out[r * p + j] = acc;
        }
    }
    out
}

fn reversed(src: &[f32], t: usize, row: usize) -> Vec<f32> {
    let mut dst = Vec::with_capacity(t * row);
    for s in (0..t).rev() {
        dst.extend_from_slice(&src[s * row..(s + 1) * row]);
    }
    dst
}

struct Oracle {
    out: Vec<f32>,
    h_t: Vec<f32>,
    c_t: Vec<f32>,
}

/// Layer-by-layer sequential reference built ONLY from the scalar
/// oracle kernels: each layer runs fwd (and time-reversed bwd) with
/// `exec::{lstm,gru}_seq`, projects, and concatenates per step with the
/// bwd half back in forward time order.
fn oracle_stack(
    case: &Case,
    xs: &[f32],
    h0: &[f32],
    c0: &[f32],
    raw: &[StackLayerWeights],
) -> Oracle {
    let (dirs, w, out_w) = (case.dirs(), case.dir_w(), case.out_w());
    let mut cur = xs.to_vec();
    let mut h_t = vec![0.0f32; case.layers * dirs * B * H];
    let mut c_t = vec![0.0f32; case.layers * dirs * B * H];
    for (l, lw) in raw.iter().enumerate() {
        let d_l = if l == 0 { D } else { out_w };
        let mut next = vec![0.0f32; T * B * out_w];
        for dirn in 0..dirs {
            let dw = if dirn == 0 {
                &lw.fwd
            } else {
                lw.bwd.as_ref().expect("bi case has bwd weights")
            };
            let srow = (l * dirs + dirn) * B * H;
            let x_dir = if dirn == 0 {
                cur.clone()
            } else {
                reversed(&cur, T, B * d_l)
            };
            let (hs, hr, cr) = if case.gru {
                let (hs, hr) = exec::gru_seq(
                    &x_dir, &h0[srow..srow + B * H], &dw.wx, &dw.wh, &dw.bias, T, B, d_l, H,
                );
                let cr = hr.clone();
                (hs, hr, cr)
            } else {
                exec::lstm_seq(
                    &x_dir,
                    &h0[srow..srow + B * H],
                    &c0[srow..srow + B * H],
                    &dw.wx,
                    &dw.wh,
                    &dw.bias,
                    T,
                    B,
                    d_l,
                    H,
                )
            };
            h_t[srow..srow + B * H].copy_from_slice(&hr);
            c_t[srow..srow + B * H].copy_from_slice(&cr);
            let rows = if case.proj > 0 {
                project_ref(&hs, &dw.wp, T * B, case.proj)
            } else {
                hs
            };
            for s in 0..T {
                let ds = if dirn == 0 { s } else { T - 1 - s };
                for bi in 0..B {
                    let from = (s * B + bi) * w;
                    let to = (ds * B + bi) * out_w + dirn * w;
                    next[to..to + w].copy_from_slice(&rows[from..from + w]);
                }
            }
        }
        cur = next;
    }
    Oracle { out: cur, h_t, c_t }
}

fn check(oracle: &Oracle, out: &StackOutput, ctx: &str) {
    assert_bits_eq(&out.out, &oracle.out, &format!("{ctx}: out"));
    assert_bits_eq(&out.h_t, &oracle.h_t, &format!("{ctx}: h_t"));
    assert_bits_eq(&out.c_t, &oracle.c_t, &format!("{ctx}: c_t"));
}

/// The headline sweep: every case, every exercisable ISA, sequential
/// AND pipelined routes (plus a mid-sweep `set_runtime` replan), all
/// bit-identical to the independent scalar-oracle composition.
#[test]
fn stacks_match_layer_by_layer_scalar_oracle() {
    let (_dir, store) = synth_store("stack_equiv_oracle", &all_entries());
    for isa in sweep_isas() {
        for (i, case) in cases().iter().enumerate() {
            let seed = 0x51AC + i as u64;
            let raw = gen_weights(case, seed);
            let (xs, h0, c0) = gen_inputs(case, seed ^ 0xDEAD);
            let oracle = oracle_stack(case, &xs, &h0, &c0, &raw);
            let name = case.name();

            // threads=1: the sequential layer-by-layer driver.
            let cfg = RuntimeConfig {
                threads: 1,
                force_kernel: Some(isa),
                ..RuntimeConfig::default()
            };
            let mut exe = StackExecutable::with_weights(&store, &name, raw.clone(), cfg).unwrap();
            let ctx = format!("{name} isa={isa:?}");
            let seq = exe.run(&xs, &h0, &c0).unwrap();
            assert!(!exe.pipelines(), "{ctx}: threads=1 must route sequential");
            check(&oracle, &seq, &format!("{ctx} threads=1"));

            // Replan in place at a pipelined thread budget; uni stacks
            // switch routes, bi stacks stay sequential — both keep bits.
            for threads in [2usize, case.layers, 2 * case.layers + 1] {
                let cfg = RuntimeConfig {
                    threads,
                    force_kernel: Some(isa),
                    ..RuntimeConfig::default()
                };
                exe.set_runtime(cfg).unwrap();
                assert_eq!(exe.pipelines(), !case.bi, "{ctx}: route at threads={threads}");
                let mut out = StackOutput::default();
                exe.run_into(&xs, &h0, &c0, &mut out).unwrap();
                check(&oracle, &out, &format!("{ctx} threads={threads}"));
                // Forced routes agree regardless of the auto choice.
                exe.run_sequential_into(&xs, &h0, &c0, &mut out).unwrap();
                check(&oracle, &out, &format!("{ctx} threads={threads} forced-seq"));
                if !case.bi {
                    exe.run_pipelined_into(&xs, &h0, &c0, &mut out).unwrap();
                    check(&oracle, &out, &format!("{ctx} threads={threads} forced-pipe"));
                }
            }
        }
    }
}

/// Chunked streaming: splitting T into prefix chunks and carrying the
/// `(L*dirs, B, H)` state across calls reproduces the uninterrupted
/// run bit-for-bit — every chunk's per-step outputs AND the final
/// carry. Unidirectional only (bi cannot stream).
#[test]
fn chunked_streaming_carry_is_bit_exact() {
    let (_dir, store) = synth_store("stack_equiv_chunk", &all_entries());
    for isa in sweep_isas() {
        for (i, case) in cases().iter().enumerate().filter(|(_, c)| !c.bi) {
            let seed = 0xC4A2 + i as u64;
            let raw = gen_weights(case, seed);
            let (xs, h0, c0) = gen_inputs(case, seed ^ 0xBEEF);
            let oracle = oracle_stack(case, &xs, &h0, &c0, &raw);
            let cfg = RuntimeConfig {
                threads: 4,
                force_kernel: Some(isa),
                ..RuntimeConfig::default()
            };
            let exe = StackExecutable::with_weights(&store, &case.name(), raw, cfg).unwrap();
            let ctx = format!("{} isa={isa:?} chunked", case.name());
            let out_w = case.out_w();

            let (mut h, mut c) = (h0.clone(), c0.clone());
            let mut out = StackOutput::default();
            let mut done = 0usize;
            for steps in [1usize, 2, T - 3] {
                let chunk = &xs[done * B * D..(done + steps) * B * D];
                exe.run_prefix_into(chunk, steps, &h, &c, &mut out).unwrap();
                let want = &oracle.out[done * B * out_w..(done + steps) * B * out_w];
                assert_bits_eq(
                    &out.out[..steps * B * out_w],
                    want,
                    &format!("{ctx}: steps {done}..{}", done + steps),
                );
                h.copy_from_slice(&out.h_t);
                c.copy_from_slice(&out.c_t);
                done += steps;
            }
            assert_eq!(done, T, "chunks cover the sequence");
            assert_bits_eq(&h, &oracle.h_t, &format!("{ctx}: final h carry"));
            assert_bits_eq(&c, &oracle.c_t, &format!("{ctx}: final c carry"));
        }
    }
}

/// Bidirectional stacks refuse the two step-ordered entry points with
/// actionable errors instead of silently computing the wrong thing.
#[test]
fn bidirectional_stacks_reject_streaming_and_pipelining() {
    let (_dir, store) = synth_store("stack_equiv_bi_err", &all_entries());
    let case = Case { layers: 2, bi: true, gru: false, proj: 0 };
    let raw = gen_weights(&case, 7);
    let (xs, h0, c0) = gen_inputs(&case, 8);
    let exe =
        StackExecutable::with_weights(&store, &case.name(), raw, RuntimeConfig::default()).unwrap();
    let mut out = StackOutput::default();

    let err = exe.run_prefix_into(&xs[..B * D], 1, &h0, &c0, &mut out).unwrap_err();
    assert!(
        format!("{err:#}").contains("cannot stream"),
        "prefix error names the streaming limit: {err:#}"
    );
    let err = exe.run_pipelined_into(&xs, &h0, &c0, &mut out).unwrap_err();
    assert!(
        format!("{err:#}").contains("cannot step-pipeline"),
        "pipeline error names the ordering limit: {err:#}"
    );
}
