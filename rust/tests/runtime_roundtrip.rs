//! Integration: the AOT -> runtime round trip. Every artifact in the
//! manifest is loaded, executed on its golden inputs, and checked
//! against the golden outputs that `aot.py` verified against the pure-jnp
//! oracle. Skips (with a message) when `make artifacts` has not run.

use sharp::runtime::literal::max_abs_diff;
use sharp::runtime::{ArtifactStore, LstmExecutable};

fn store_or_skip() -> Option<ArtifactStore> {
    match ArtifactStore::open_default() {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("SKIP: no artifacts ({e:#}); run `make artifacts`");
            None
        }
    }
}

#[test]
fn every_artifact_reproduces_its_goldens() {
    let Some(store) = store_or_skip() else { return };
    assert!(!store.manifest.entries.is_empty());
    for entry in store.manifest.entries.clone() {
        let exe = LstmExecutable::from_store_goldens(&store, &entry.name)
            .unwrap_or_else(|e| panic!("{}: bind failed: {e:#}", entry.name));
        let input = |n: &str| {
            store
                .golden(entry.inputs.iter().find(|i| i.name == n).unwrap())
                .unwrap()
        };
        let xs = input(if entry.kind.ends_with("seq") { "xs" } else { "x" });
        let h0 = input("h0");
        // GRU kinds carry no cell state; the runtime ignores c0 for them.
        let c0 = if entry.kind.starts_with("gru") {
            vec![0.0; h0.len()]
        } else {
            input("c0")
        };
        let out = exe
            .run(&xs, &h0, &c0)
            .unwrap_or_else(|e| panic!("{}: run failed: {e:#}", entry.name));

        // Outputs: seq = (hs, h_T, c_T); cell = (h, c). (GRU mirrors h
        // into the c slot — same tuple shapes by convention.)
        let outs = &entry.outputs;
        let (h_idx, c_idx) = if entry.kind.ends_with("seq") { (1, 2) } else { (0, 1) };
        let gh = store.golden(&outs[h_idx]).unwrap();
        let gc = store.golden(&outs[c_idx]).unwrap();
        let dh = max_abs_diff(&out.h_t, &gh);
        let dc = max_abs_diff(&out.c_t, &gc);
        assert!(dh < 1e-4, "{}: h_t diff {dh}", entry.name);
        assert!(dc < 1e-4, "{}: c_t diff {dc}", entry.name);
        if entry.kind.ends_with("seq") {
            let ghs = store.golden(&outs[0]).unwrap();
            let dhs = max_abs_diff(&out.hs, &ghs);
            assert!(dhs < 1e-4, "{}: hs diff {dhs}", entry.name);
        }
    }
}

#[test]
fn executable_cache_returns_same_compilation() {
    let Some(store) = store_or_skip() else { return };
    let name = &store.manifest.entries[0].name.clone();
    let a = store.executable(name).unwrap();
    let b = store.executable(name).unwrap();
    assert!(std::rc::Rc::ptr_eq(&a, &b), "second fetch must hit the cache");
}

#[test]
fn custom_weights_change_the_output() {
    let Some(store) = store_or_skip() else { return };
    let Some(entry) = store.manifest.find("cell_h64_b1").cloned() else {
        eprintln!("SKIP: cell_h64_b1 missing");
        return;
    };
    let d = entry.d;
    let h = entry.h;
    let golden = LstmExecutable::from_store_goldens(&store, &entry.name).unwrap();
    let zeros = LstmExecutable::with_weights(
        &store,
        &entry.name,
        vec![0.0; d * 4 * h],
        vec![0.0; h * 4 * h],
        vec![0.0; 4 * h],
    )
    .unwrap();
    let input = |n: &str| {
        store
            .golden(entry.inputs.iter().find(|i| i.name == n).unwrap())
            .unwrap()
    };
    let (xs, h0, c0) = (input("x"), input("h0"), input("c0"));
    let out_g = golden.run(&xs, &h0, &c0).unwrap();
    let out_z = zeros.run(&xs, &h0, &c0).unwrap();
    assert!(
        max_abs_diff(&out_g.h_t, &out_z.h_t) > 1e-3,
        "zero weights must change the output"
    );
    // Zero weights: gates are sigmoid(0)=0.5, g=tanh(0)=0 ->
    // c' = 0.5*c0, h' = 0.5*tanh(0.5*c0).
    for (i, (&c_new, &c_old)) in out_z.c_t.iter().zip(&c0).enumerate() {
        assert!(
            (c_new - 0.5 * c_old).abs() < 1e-5,
            "cell {i}: {c_new} vs 0.5*{c_old}"
        );
    }
}

#[test]
fn pad_sequence_contract() {
    let Some(store) = store_or_skip() else { return };
    let Some(entry) = store
        .manifest
        .entries
        .iter()
        .find(|e| e.kind == "seq")
        .cloned()
    else {
        return;
    };
    let exe = LstmExecutable::from_store_goldens(&store, &entry.name).unwrap();
    let short = entry.t - 1;
    let payload = vec![1.0f32; short * entry.b * entry.d];
    let padded = exe.pad_sequence(&payload, short).unwrap();
    assert_eq!(padded.len(), entry.t * entry.b * entry.d);
    assert!(padded[short * entry.b * entry.d..].iter().all(|&v| v == 0.0));
    // Over-long sequences are rejected.
    assert!(exe
        .pad_sequence(&vec![0.0; (entry.t + 1) * entry.b * entry.d], entry.t + 1)
        .is_err());
}
