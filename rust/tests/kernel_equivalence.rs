//! The kernel-layer bit-exactness contract: the tiled kernels
//! (`runtime::kernel`) must be **bit-identical** to the scalar oracle
//! (`runtime::exec`) — not merely close — for LSTM, GRU, and the
//! streaming `run_prefix` path, across a sweep of `(T, B, D, H)` shapes
//! AND across the execution planner's whole candidate space: every
//! `(geometry, schedule, isa)` plan the tuner can emit (plus
//! deliberately oversized fixed geometries: NR wider than the gate
//! matrix, MR larger than the batch) must produce the same bits, serial
//! and threaded. That is what makes adaptive planning safe: a plan can
//! only ever move wall time.
//!
//! The oracle/checker/case plumbing lives in `tests/common/` (shared
//! with `simd_conformance.rs`, `streaming_fusion.rs`, and the benches);
//! this suite owns the planner-facing sweeps. CI runs it in release
//! mode twice — default dispatch and `SHARP_FORCE_KERNEL=scalar` —
//! because tiling bugs (edge-panel indexing, accumulation-order drift)
//! love optimized builds.
//!
//! No artifacts needed: weights are synthetic; the `run_prefix` cases
//! build a tiny on-disk manifest so the executables exercise the real
//! serving entry points (scratch reuse and all).

mod common;

use common::{assert_bits_eq, check_gru, check_lstm, seq_entry, sweep_isas, synth_store};
use sharp::runtime::plan::{tuner, ExecPlan, Isa, KernelGeometry, ModelDims, PlanMode, Schedule};
use sharp::runtime::{exec, LstmExecutable, LstmOutput, RuntimeConfig};
use sharp::util::rng::Rng;

#[test]
fn lstm_tiled_bit_identical_across_edge_shapes() {
    // Tile-aligned, sub-tile, ragged, B=1, T=1, H prime / not a multiple
    // of the default nr=16 or mr=4 — under the default (fixed) plan.
    let shapes: &[(usize, usize, usize, usize)] = &[
        (1, 1, 1, 1),
        (1, 4, 16, 16),
        (2, 1, 3, 17),
        (3, 2, 8, 16),
        (5, 3, 7, 31),
        (4, 2, 5, 64),
        (2, 2, 33, 40),
        (8, 1, 16, 16),
        (7, 4, 19, 23),
        (1, 2, 64, 48),
    ];
    for (i, &(t, b, d, h)) in shapes.iter().enumerate() {
        check_lstm(t, b, d, h, &ExecPlan::fixed_default(), 1000 + i as u64);
    }
}

#[test]
fn gru_tiled_bit_identical_across_edge_shapes() {
    let shapes: &[(usize, usize, usize, usize)] = &[
        (1, 1, 1, 1),
        (1, 3, 16, 16),
        (4, 1, 5, 17),
        (2, 2, 9, 31),
        (6, 2, 12, 33),
        (3, 4, 21, 19),
    ];
    for (i, &(t, b, d, h)) in shapes.iter().enumerate() {
        check_gru(t, b, d, h, &ExecPlan::fixed_default(), 2000 + i as u64);
    }
}

#[test]
fn every_tuner_candidate_is_bit_identical() {
    // The planner contract: for shapes that stress the candidate space
    // (H=1 so the gate matrix is narrower than every standard panel,
    // B=1, T=1, ragged everything), EVERY plan the tuner can emit — not
    // just the winner — produces the oracle's bits, serial and threaded,
    // under every ISA this process can dispatch.
    let lstm_shapes: &[(usize, usize, usize, usize)] =
        &[(1, 1, 2, 5), (2, 1, 3, 1), (4, 2, 7, 9), (3, 3, 17, 5), (6, 4, 16, 16)];
    for isa in sweep_isas() {
        for (i, &(t, b, d, h)) in lstm_shapes.iter().enumerate() {
            let dims = ModelDims::lstm(d, h, b, t);
            for (j, cand) in tuner::enumerate(&dims, isa).iter().enumerate() {
                check_lstm(t, b, d, h, &cand.plan, 5000 + (i * 100 + j) as u64);
            }
        }
        let gru_shapes: &[(usize, usize, usize, usize)] = &[(2, 1, 4, 1), (3, 2, 5, 7)];
        for (i, &(t, b, d, h)) in gru_shapes.iter().enumerate() {
            let dims = ModelDims::gru(d, h, b, t);
            for (j, cand) in tuner::enumerate(&dims, isa).iter().enumerate() {
                check_gru(t, b, d, h, &cand.plan, 6000 + (i * 100 + j) as u64);
            }
        }
    }
}

#[test]
fn oversized_fixed_geometries_stay_bit_identical() {
    // A fixed plan may pin tiles LARGER than the matrices (NR=32 > G*H,
    // MR=8 > B·T): every block then runs the ragged edge path, which
    // must still be exact — including when the geometry claims a vector
    // ISA whose kernels never fire on these sub-width panels.
    for isa in sweep_isas() {
        for schedule in [Schedule::Unfolded, Schedule::Stepwise] {
            for (mr, nr) in [(8, 32), (8, 4), (1, 32), (5, 7)] {
                let plan = ExecPlan {
                    geometry: KernelGeometry::new(mr, nr).unwrap().with_isa(isa),
                    schedule,
                };
                check_lstm(1, 1, 1, 1, &plan, 7000 + (mr * 40 + nr) as u64);
                check_lstm(2, 1, 3, 2, &plan, 7300 + (mr * 40 + nr) as u64);
                check_gru(1, 1, 2, 1, &plan, 7600 + (mr * 40 + nr) as u64);
            }
        }
    }
}

#[test]
fn random_shape_sweep_stays_bit_identical_under_auto_plans() {
    // Property-style: random shapes, each run under its own Auto plan
    // (what the serving path actually does, for each dispatchable ISA),
    // deterministic seed.
    let isas = sweep_isas();
    let mut rng = Rng::new(0xC0FFEE);
    for case in 0..24 {
        let t = rng.range_usize(1, 8);
        let b = rng.range_usize(1, 4);
        let d = rng.range_usize(1, 40);
        let h = rng.range_usize(1, 70);
        for &isa in &isas {
            let lstm = tuner::plan_auto(&ModelDims::lstm(d, h, b, t), isa);
            check_lstm(t, b, d, h, &lstm, 3000 + case);
            let gru = tuner::plan_auto(&ModelDims::gru(d, h, b, t), isa);
            check_gru(t, b, d, h, &gru, 4000 + case);
        }
    }
}

#[test]
fn auto_planning_is_deterministic_and_dim_bounded() {
    // The two planner properties the serving layer relies on: replicas
    // planning independently must agree (determinism), and no plan may
    // pick a tile exceeding the matrices it sweeps. Planning is pure
    // arithmetic, so every ISA (even one this host cannot execute) is
    // checked.
    let mut rng = Rng::new(0x9A7);
    for _ in 0..100 {
        let dims = ModelDims {
            d: rng.range_usize(1, 200),
            h: rng.range_usize(1, 200),
            b: rng.range_usize(1, 8),
            t: rng.range_usize(1, 32),
            gates: if rng.range_usize(0, 1) == 0 { 4 } else { 3 },
        };
        for isa in Isa::ALL {
            let plan = tuner::plan_auto(&dims, isa);
            for _ in 0..3 {
                assert_eq!(tuner::plan_auto(&dims, isa), plan, "{dims:?}");
            }
            assert_eq!(plan.geometry.isa, isa, "{dims:?} picked {plan:?}");
            assert!(
                plan.geometry.mr <= dims.max_rows(plan.schedule),
                "{dims:?} picked {plan:?}"
            );
            assert!(plan.geometry.nr <= dims.gh().max(1), "{dims:?} picked {plan:?}");
        }
    }
}

fn equiv_store(tag: &str) -> (std::path::PathBuf, sharp::runtime::ArtifactStore) {
    synth_store(
        &format!("kernel_equiv_{tag}"),
        &format!(
            "{},{}",
            seq_entry("seq_h5_t6_b2", "seq", 6, 2, 3, 5),
            seq_entry("gru_seq_h5_t6_b2", "gru_seq", 6, 2, 3, 5),
        ),
    )
}

#[test]
fn run_prefix_matches_scalar_oracle_with_scratch_reuse() {
    let (_dir, store) = equiv_store("prefix");
    let (t, b, d, hid) = (6usize, 2usize, 3usize, 5usize);
    let mut rng = Rng::new(99);
    let wx = rng.vec_f32(d * 4 * hid, -0.4, 0.4);
    let wh = rng.vec_f32(hid * 4 * hid, -0.4, 0.4);
    let bias = rng.vec_f32(4 * hid, -0.3, 0.3);
    let exe = LstmExecutable::with_weights(
        &store,
        "seq_h5_t6_b2",
        wx.clone(),
        wh.clone(),
        bias.clone(),
    )
    .unwrap();
    let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
    let (h0, c0) = exe.zero_state();

    // Interleave prefix lengths on ONE executable — the serving pattern
    // that reuses the scratch across differently-sized chunks. steps=1
    // exercises the stepwise override inside run_prefix.
    for &steps in &[t, 2, 5, 1, t] {
        let (hs_ref, h_ref, c_ref) = exec::lstm_seq(
            &xs[..steps * b * d],
            &h0,
            &c0,
            &wx,
            &wh,
            &bias,
            steps,
            b,
            d,
            hid,
        );
        let out = exe.run_prefix(&xs[..steps * b * d], steps, &h0, &c0).unwrap();
        let ctx = format!("run_prefix steps={steps}");
        assert_bits_eq(&out.hs, &hs_ref, &format!("{ctx}: hs"));
        assert_bits_eq(&out.h_t, &h_ref, &format!("{ctx}: h_t"));
        assert_bits_eq(&out.c_t, &c_ref, &format!("{ctx}: c_t"));
    }

    // Chunked 3+3 with the carry threaded through still bit-matches the
    // one-shot run (schedule invariance, the streaming-session claim).
    let full = exe.run(&xs, &h0, &c0).unwrap();
    let a = exe.run_prefix(&xs[..3 * b * d], 3, &h0, &c0).unwrap();
    let z = exe.run_prefix(&xs[3 * b * d..], 3, &a.h_t, &a.c_t).unwrap();
    assert_bits_eq(&z.h_t, &full.h_t, "chunked h_t");
    assert_bits_eq(&z.c_t, &full.c_t, "chunked c_t");

    // One-frame chunks all the way through — the streaming T=1 override
    // path — still reconstructs the one-shot bits exactly.
    let (mut h, mut c) = (h0.clone(), c0.clone());
    for step in 0..t {
        let o = exe
            .run_prefix(&xs[step * b * d..(step + 1) * b * d], 1, &h, &c)
            .unwrap();
        h = o.h_t;
        c = o.c_t;
    }
    assert_bits_eq(&h, &full.h_t, "frame-by-frame h_t");
    assert_bits_eq(&c, &full.c_t, "frame-by-frame c_t");
}

#[test]
fn gru_run_prefix_matches_scalar_oracle() {
    let (_dir, store) = equiv_store("gru_prefix");
    let (t, b, d, hid) = (6usize, 2usize, 3usize, 5usize);
    let mut rng = Rng::new(17);
    let wx = rng.vec_f32(d * 3 * hid, -0.4, 0.4);
    let wh = rng.vec_f32(hid * 3 * hid, -0.4, 0.4);
    let bias = rng.vec_f32(3 * hid, -0.3, 0.3);
    let exe = LstmExecutable::with_weights(
        &store,
        "gru_seq_h5_t6_b2",
        wx.clone(),
        wh.clone(),
        bias.clone(),
    )
    .unwrap();
    let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
    let (h0, c0) = exe.zero_state();

    for &steps in &[t, 4, 1] {
        let (hs_ref, h_ref) =
            exec::gru_seq(&xs[..steps * b * d], &h0, &wx, &wh, &bias, steps, b, d, hid);
        let out = exe.run_prefix(&xs[..steps * b * d], steps, &h0, &c0).unwrap();
        let ctx = format!("gru run_prefix steps={steps}");
        assert_bits_eq(&out.hs, &hs_ref, &format!("{ctx}: hs"));
        assert_bits_eq(&out.h_t, &h_ref, &format!("{ctx}: h_t"));
        // GRU mirrors h into the c slot.
        assert_bits_eq(&out.c_t, &h_ref, &format!("{ctx}: c_t"));
    }
}

#[test]
fn run_into_reuses_output_buffers_identically_across_plan_modes() {
    // The zero-allocation entry point: repeated run_into calls on one
    // reused LstmOutput must match fresh run() calls bit-for-bit, and a
    // --threads / re-planned / ISA-pinned executable must match the
    // default one.
    let (_dir, store) = equiv_store("run_into");
    let (t, b, d, hid) = (6usize, 2usize, 3usize, 5usize);
    let mut rng = Rng::new(41);
    let wx = rng.vec_f32(d * 4 * hid, -0.4, 0.4);
    let wh = rng.vec_f32(hid * 4 * hid, -0.4, 0.4);
    let bias = rng.vec_f32(4 * hid, -0.3, 0.3);
    let exe = LstmExecutable::with_weights(
        &store,
        "seq_h5_t6_b2",
        wx.clone(),
        wh.clone(),
        bias.clone(),
    )
    .unwrap();
    let mut exe_mt =
        LstmExecutable::with_weights(&store, "seq_h5_t6_b2", wx.clone(), wh.clone(), bias.clone())
            .unwrap();
    exe_mt
        .set_runtime(RuntimeConfig {
            threads: 4,
            ..Default::default()
        })
        .unwrap();
    assert_eq!(exe_mt.runtime().threads, 4);
    // A third binding pinned to a deliberately different geometry AND
    // the scalar ISA: the repacked panels must still produce identical
    // bits even when the default binding dispatched a vector kernel.
    let mut exe_fixed = LstmExecutable::with_weights(&store, "seq_h5_t6_b2", wx, wh, bias).unwrap();
    exe_fixed
        .set_runtime(RuntimeConfig {
            threads: 1,
            plan: PlanMode::Fixed(KernelGeometry::new(2, 8).unwrap()),
            force_kernel: Some(Isa::Scalar),
            ..RuntimeConfig::default()
        })
        .unwrap();

    let (h0, c0) = exe.zero_state();
    let mut out = LstmOutput::default();
    let mut rng2 = Rng::new(43);
    for trial in 0..3 {
        let xs = rng2.vec_f32(t * b * d, -1.0, 1.0);
        exe.run_into(&xs, &h0, &c0, &mut out).unwrap();
        let fresh = exe.run(&xs, &h0, &c0).unwrap();
        let ctx = format!("trial {trial}");
        assert_bits_eq(&out.hs, &fresh.hs, &format!("{ctx}: hs"));
        assert_bits_eq(&out.h_t, &fresh.h_t, &format!("{ctx}: h_t"));
        assert_bits_eq(&out.c_t, &fresh.c_t, &format!("{ctx}: c_t"));
        let mt = exe_mt.run(&xs, &h0, &c0).unwrap();
        assert_bits_eq(&mt.hs, &fresh.hs, &format!("{ctx}: threaded hs"));
        let fixed = exe_fixed.run(&xs, &h0, &c0).unwrap();
        assert_bits_eq(&fixed.hs, &fresh.hs, &format!("{ctx}: repacked hs"));
    }
}
