//! Integration: every paper exhibit renders with non-empty tables, and
//! the cross-exhibit consistency claims hold (e.g. the headline numbers
//! quoted in the paper's abstract emerge from the same machinery the
//! individual figures use).

use sharp::experiments;

#[test]
fn every_exhibit_renders_nonempty() {
    for id in experiments::ALL_IDS {
        let e = experiments::run(id).unwrap_or_else(|| panic!("{id} missing"));
        assert_eq!(e.id, id);
        assert!(!e.tables.is_empty(), "{id}: no tables");
        for t in &e.tables {
            assert!(t.n_rows() > 0, "{id}: empty table");
        }
        let rendered = e.render();
        assert!(rendered.len() > 80, "{id}: suspiciously short output");
    }
}

#[test]
fn abstract_headline_speedups_emerge() {
    // Abstract: "2x, 2.8x, and 82x speedups on average... compared to the
    // state-of-the-art ASIC, FPGA, and GPU implementations" (at 64K).
    // Shape check: ASIC speedup in [1.3, 4], FPGA in [1.5, 9], GPU > 25.
    use sharp::util::stats::geomean;

    let t6 = experiments::table6::rows();
    let asic: Vec<f64> = t6.iter().map(|r| r.speedups[3]).collect();
    let asic_avg = geomean(&asic);
    assert!(
        (1.3..4.0).contains(&asic_avg),
        "ASIC avg speedup {asic_avg} (paper: 2x)"
    );

    let t4 = experiments::table4::rows();
    let fpga: Vec<f64> = t4.iter().map(|r| r.speedup).collect();
    let fpga_avg = geomean(&fpga);
    assert!(
        (1.5..9.0).contains(&fpga_avg),
        "FPGA avg speedup {fpga_avg} (paper: 2.8x)"
    );

    let f13 = experiments::fig13::rows();
    let gpu: Vec<f64> = f13
        .iter()
        .filter(|r| r.macs == 65536)
        .map(|r| r.vs_grnn)
        .collect();
    let gpu_avg = geomean(&gpu);
    assert!(gpu_avg > 25.0, "GPU avg speedup {gpu_avg} (paper: 82x)");
}

#[test]
fn conclusion_efficiency_band() {
    // Conclusion: "an average utilization of 50% for a peak throughput of
    // 30 TFLOPs/s, resulting in 0.32 TFLOPS/Watt".
    use sharp::config::presets::HIDDEN_SWEEP;
    use sharp::config::LstmConfig;
    use sharp::energy::power_report;
    use sharp::experiments::common::{k_opt_config, sharp_tuned};

    let mut effs = Vec::new();
    for &h in &HIDDEN_SWEEP {
        let model = LstmConfig::square(h);
        let cfg = k_opt_config(65536, &model);
        let sim = sharp_tuned(65536, &model);
        let p = power_report(&cfg, &sim);
        effs.push(p.flops_per_watt(sim.achieved_flops()) / 1e9);
    }
    let avg = effs.iter().sum::<f64>() / effs.len() as f64;
    // Paper: 321 GFLOPS/W (we count 2 flops/MAC where the paper counts
    // differently; accept the same order of magnitude and the >100 bar
    // that separates SHARP from the GPU's ~10 GFLOPS/W).
    assert!(avg > 150.0 && avg < 2500.0, "avg {avg} GFLOPS/W");
}

#[test]
fn fig10_consistent_with_fig09_optima() {
    // The K_opt chosen in fig09's exploration must match what fig10's
    // fixed-baseline uses: both derive from the same explore_k machinery,
    // so the reconfig speedup must never dip below 1.
    for r in experiments::fig10::rows() {
        assert!(r.speedup >= 0.999, "macs={} h={}", r.macs, r.hidden);
        assert!(r.k_opt >= 32 && r.k_opt <= 256);
    }
}
