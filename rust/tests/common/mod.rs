//! Shared kernel-conformance harness (satellite of the SIMD PR): ONE
//! scalar-oracle reference, ONE bitwise-comparison entry point, and ONE
//! seeded case generator, used by `kernel_equivalence.rs`,
//! `simd_conformance.rs`, `streaming_fusion.rs`, and the perf benches
//! (via `#[path = "../tests/common/mod.rs"]`). Every claim of the form
//! "variant X equals the reference" in this repo funnels through here,
//! so a drifted kernel cannot pass one suite while failing another.
//!
//! The oracle is `runtime::exec` — the unfused, untiled, unvectorized
//! scalar forward pass. Tiled plans (any geometry/schedule/ISA/thread
//! count) are checked against it with [`assert_bits_eq`]: bit identity,
//! not tolerance. See `runtime/kernel/simd` for why SIMD preserves bits.
//! The quantized (int8) path is the one deliberate exception: it is
//! bit-identical *within* a dtype but only tolerance-close to the f32
//! oracle, so `quant_conformance.rs` uses [`assert_close`] /
//! [`assert_close_ulp`] against the documented budget instead.
//!
//! Each consumer compiles this file into its own crate, so helpers used
//! by one suite look dead to another — hence the blanket allow.
#![allow(dead_code)]

use std::path::{Path, PathBuf};

pub use sharp::runtime::literal::assert_bits_eq;

use sharp::runtime::kernel::{gru_seq_into, lstm_seq_into, ExecScratch};
use sharp::runtime::literal::write_f32_file;
use sharp::runtime::plan::ExecPlan;
use sharp::runtime::{exec, ArtifactStore, Isa, RuntimeConfig};
use sharp::util::rng::Rng;

/// Tolerance twin of [`assert_bits_eq`] for the quantized path, where
/// "equals the reference" is a budget, not bit identity: every element
/// must sit within `tol` (absolute) of the oracle. Panics with the
/// worst offender's index, values, and the observed max error — the
/// number to compare against the documented budget (DESIGN.md §12).
pub fn assert_close(got: &[f32], want: &[f32], tol: f32, ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length {} vs {}", got.len(), want.len());
    let mut worst = 0.0f32;
    let mut at = 0usize;
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            g.is_finite() && w.is_finite(),
            "{ctx}: non-finite at [{i}]: got {g}, want {w}"
        );
        let e = (g - w).abs();
        if e > worst {
            worst = e;
            at = i;
        }
    }
    assert!(
        worst <= tol,
        "{ctx}: max |err| {worst:.3e} > budget {tol:.3e} at [{at}] (got {}, want {})",
        got[at],
        want[at]
    );
}

/// [`assert_close`] in units-in-the-last-place: every element must be
/// within `ulps` representable f32 steps of the oracle. The right gauge
/// when the compared values span magnitudes (an absolute budget is too
/// loose near zero and too tight far from it). Equal bits pass at
/// `ulps = 0`; a sign flip across non-zero values never passes.
pub fn assert_close_ulp(got: &[f32], want: &[f32], ulps: u32, ctx: &str) {
    fn ulp_distance(a: f32, b: f32) -> u64 {
        // Map the float line monotonically onto i64 (sign-magnitude to
        // two's-complement bias), then ULPs = integer distance.
        fn key(x: f32) -> i64 {
            let b = x.to_bits() as i32;
            (if b < 0 { i32::MIN.wrapping_sub(b) } else { b }) as i64
        }
        key(a).abs_diff(key(b))
    }
    assert_eq!(got.len(), want.len(), "{ctx}: length {} vs {}", got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            g.is_finite() && w.is_finite(),
            "{ctx}: non-finite at [{i}]: got {g}, want {w}"
        );
        let d = ulp_distance(*g, *w);
        assert!(
            d <= u64::from(ulps),
            "{ctx}: {d} ULPs > budget {ulps} at [{i}] (got {g}, want {w})"
        );
    }
}

/// SplitMix64 (Steele et al., the `java.util.SplittableRandom` mixer):
/// a one-word PRNG whose every output is a bijective hash of the
/// counter, so any seed gives a full-period, statistically solid
/// sequence — ideal for deriving independent per-case seeds in the
/// property sweeps. Kept separate from `util::rng::Rng` (xorshift64*,
/// which powers tensor *values*) so conformance case selection and data
/// generation can never correlate.
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> SplitMix64 {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[lo, hi]`, both ends inclusive.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + (self.next_u64() % (hi - lo + 1) as u64) as usize
    }

    pub fn pick<T: Copy>(&mut self, options: &[T]) -> T {
        options[self.range_usize(0, options.len() - 1)]
    }
}

/// One LSTM shape under one plan: scalar oracle vs tiled kernel, serial
/// and threaded. The plan carries its own ISA (`plan.geometry.isa`), so
/// this single checker covers scalar, AVX2, and NEON dispatch alike.
pub fn check_lstm(t: usize, b: usize, d: usize, hid: usize, plan: &ExecPlan, seed: u64) {
    check_lstm_threads(t, b, d, hid, plan, &[1, 4], seed);
}

/// [`check_lstm`] with an explicit thread sweep (the conformance suite
/// randomizes thread counts; the fixed suites pin `[1, 4]`).
pub fn check_lstm_threads(
    t: usize,
    b: usize,
    d: usize,
    hid: usize,
    plan: &ExecPlan,
    threads: &[usize],
    seed: u64,
) {
    let mut rng = Rng::new(seed);
    let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
    let h0 = rng.vec_f32(b * hid, -1.0, 1.0);
    let c0 = rng.vec_f32(b * hid, -1.0, 1.0);
    let wx = rng.vec_f32(d * 4 * hid, -0.4, 0.4);
    let wh = rng.vec_f32(hid * 4 * hid, -0.4, 0.4);
    let bias = rng.vec_f32(4 * hid, -0.3, 0.3);
    let ctx = format!("lstm (T={t}, B={b}, D={d}, H={hid}) plan={}", plan.describe());

    let (hs_ref, h_ref, c_ref) = exec::lstm_seq(&xs, &h0, &c0, &wx, &wh, &bias, t, b, d, hid);
    for &threads in threads {
        let mut scr = ExecScratch::new();
        let (mut hs, mut h_t, mut c_t) = (Vec::new(), Vec::new(), Vec::new());
        lstm_seq_into(
            &xs,
            &h0,
            &c0,
            &wx,
            &wh,
            &bias,
            t,
            b,
            d,
            hid,
            plan,
            threads,
            &mut scr,
            &mut hs,
            &mut h_t,
            &mut c_t,
        );
        assert_bits_eq(&hs, &hs_ref, &format!("{ctx} threads={threads}: hs"));
        assert_bits_eq(&h_t, &h_ref, &format!("{ctx} threads={threads}: h_t"));
        assert_bits_eq(&c_t, &c_ref, &format!("{ctx} threads={threads}: c_t"));
    }
}

/// One GRU shape under one plan: scalar oracle vs tiled kernel, serial
/// and threaded.
pub fn check_gru(t: usize, b: usize, d: usize, hid: usize, plan: &ExecPlan, seed: u64) {
    check_gru_threads(t, b, d, hid, plan, &[1, 4], seed);
}

/// [`check_gru`] with an explicit thread sweep.
pub fn check_gru_threads(
    t: usize,
    b: usize,
    d: usize,
    hid: usize,
    plan: &ExecPlan,
    threads: &[usize],
    seed: u64,
) {
    let mut rng = Rng::new(seed);
    let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
    let h0 = rng.vec_f32(b * hid, -1.0, 1.0);
    let wx = rng.vec_f32(d * 3 * hid, -0.4, 0.4);
    let wh = rng.vec_f32(hid * 3 * hid, -0.4, 0.4);
    let bias = rng.vec_f32(3 * hid, -0.3, 0.3);
    let ctx = format!("gru (T={t}, B={b}, D={d}, H={hid}) plan={}", plan.describe());

    let (hs_ref, h_ref) = exec::gru_seq(&xs, &h0, &wx, &wh, &bias, t, b, d, hid);
    for &threads in threads {
        let mut scr = ExecScratch::new();
        let (mut hs, mut h_t) = (Vec::new(), Vec::new());
        gru_seq_into(
            &xs,
            &h0,
            &wx,
            &wh,
            &bias,
            t,
            b,
            d,
            hid,
            plan,
            threads,
            &mut scr,
            &mut hs,
            &mut h_t,
        );
        assert_bits_eq(&hs, &hs_ref, &format!("{ctx} threads={threads}: hs"));
        assert_bits_eq(&h_t, &h_ref, &format!("{ctx} threads={threads}: h_t"));
    }
}

/// The vector ISAs this process can actually exercise: always the
/// scalar reference, plus the resolved default when it differs. Under
/// CI's `SHARP_FORCE_KERNEL=scalar` job this narrows to `[Scalar]`
/// coherently (the pin applies process-wide, so sweeping a vector ISA
/// there would test a path the process refuses to dispatch); under the
/// default job on x86 it is `[Scalar, Avx2]`.
pub fn sweep_isas() -> Vec<Isa> {
    let resolved = RuntimeConfig::default()
        .resolve_isa()
        .expect("default ISA resolution never fails");
    let mut isas = vec![Isa::Scalar];
    if resolved != Isa::Scalar {
        isas.push(resolved);
    }
    isas
}

/// Minimal on-disk artifact store for self-contained suites: writes a
/// manifest holding `artifacts_json` (a comma-joined list of artifact
/// objects whose `"hlo"` is `m.hlo.txt`) plus the dummy HLO module, and
/// opens it. Weights are bound explicitly per test (`with_weights`), so
/// no goldens are materialized. Returns the dir to keep it alive.
pub fn synth_store(tag: &str, artifacts_json: &str) -> (PathBuf, ArtifactStore) {
    let dir = std::env::temp_dir().join(format!("sharp_conformance_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = format!(
        r#"{{"version":1,"gate_order":"ifgo","artifacts":[{}]}}"#,
        artifacts_json
    );
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    std::fs::write(dir.join("m.hlo.txt"), "HloModule conformance_synth\n").unwrap();
    let store = ArtifactStore::open(&dir).unwrap();
    (dir, store)
}

/// One artifact object for [`synth_store`]'s manifest list.
pub fn seq_entry(name: &str, kind: &str, t: usize, b: usize, d: usize, h: usize) -> String {
    format!(
        r#"{{"name":"{name}","kind":"{kind}","hlo":"m.hlo.txt","T":{t},"B":{b},"D":{d},"H":{h},"inputs":[],"outputs":[]}}"#
    )
}

/// [`seq_entry`] whose inputs carry golden `wx`/`wh`/`b` tensors — the
/// binding a full `Server` performs at worker startup
/// (`from_store_goldens_with`), so suites that exercise the coordinator
/// end to end (chaos, e2e) can serve from a synth store. LSTM gate
/// layout (4 fused gates). Pair with [`write_lstm_goldens`] using the
/// same `prefix` AFTER [`synth_store`] created the dir.
pub fn seq_entry_goldens(
    name: &str,
    t: usize,
    b: usize,
    d: usize,
    h: usize,
    prefix: &str,
) -> String {
    let gh = 4 * h;
    format!(
        r#"{{"name":"{name}","kind":"seq","hlo":"m.hlo.txt","T":{t},"B":{b},"D":{d},"H":{h},"inputs":[{{"name":"wx","shape":[{d},{gh}],"file":"{prefix}_wx.f32"}},{{"name":"wh","shape":[{h},{gh}],"file":"{prefix}_wh.f32"}},{{"name":"b","shape":[{gh}],"file":"{prefix}_b.f32"}}],"outputs":[]}}"#
    )
}

/// Write the golden weight files [`seq_entry_goldens`] references:
/// seeded, so two stores built with the same seed serve bit-identical
/// models (the chaos suite compares a faulted pool against an
/// undisturbed reference pool this way).
pub fn write_lstm_goldens(dir: &Path, prefix: &str, d: usize, h: usize, seed: u64) {
    let mut rng = Rng::new(seed);
    write_f32_file(
        &dir.join(format!("{prefix}_wx.f32")),
        &rng.vec_f32(d * 4 * h, -0.4, 0.4),
    )
    .unwrap();
    write_f32_file(
        &dir.join(format!("{prefix}_wh.f32")),
        &rng.vec_f32(h * 4 * h, -0.4, 0.4),
    )
    .unwrap();
    write_f32_file(
        &dir.join(format!("{prefix}_b.f32")),
        &rng.vec_f32(4 * h, -0.3, 0.3),
    )
    .unwrap();
}

/// [`stack_entry`] (unidirectional, no projection) whose inputs carry
/// golden per-layer weights `wx{l}`/`wh{l}`/`b{l}` — what
/// `StackExecutable::from_store_goldens_with` binds. Pair with
/// [`write_stack_goldens`] using the same `prefix`.
#[allow(clippy::too_many_arguments)]
pub fn stack_entry_goldens(
    name: &str,
    t: usize,
    b: usize,
    d: usize,
    h: usize,
    layers: usize,
    prefix: &str,
) -> String {
    let gh = 4 * h;
    let mut inputs = Vec::new();
    for l in 0..layers {
        let dl = if l == 0 { d } else { h };
        inputs.push(format!(
            r#"{{"name":"wx{l}","shape":[{dl},{gh}],"file":"{prefix}_wx{l}.f32"}},{{"name":"wh{l}","shape":[{h},{gh}],"file":"{prefix}_wh{l}.f32"}},{{"name":"b{l}","shape":[{gh}],"file":"{prefix}_b{l}.f32"}}"#
        ));
    }
    format!(
        r#"{{"name":"{name}","kind":"seq","hlo":"m.hlo.txt","T":{t},"B":{b},"D":{d},"H":{h},"layers":{layers},"bidirectional":false,"P":0,"inputs":[{}],"outputs":[]}}"#,
        inputs.join(",")
    )
}

/// Golden weight files for [`stack_entry_goldens`], seeded per layer.
pub fn write_stack_goldens(dir: &Path, prefix: &str, d: usize, h: usize, layers: usize, seed: u64) {
    for l in 0..layers {
        let dl = if l == 0 { d } else { h };
        let mut rng = Rng::new(seed.wrapping_add(l as u64).wrapping_mul(0x9E37_79B9));
        write_f32_file(
            &dir.join(format!("{prefix}_wx{l}.f32")),
            &rng.vec_f32(dl * 4 * h, -0.4, 0.4),
        )
        .unwrap();
        write_f32_file(
            &dir.join(format!("{prefix}_wh{l}.f32")),
            &rng.vec_f32(h * 4 * h, -0.4, 0.4),
        )
        .unwrap();
        write_f32_file(
            &dir.join(format!("{prefix}_b{l}.f32")),
            &rng.vec_f32(4 * h, -0.3, 0.3),
        )
        .unwrap();
    }
}

/// One STACKED artifact object for [`synth_store`]'s manifest list:
/// `layers` deep, optionally bidirectional, `proj`-wide output
/// projection (0 = none). Weights still bind explicitly per test.
#[allow(clippy::too_many_arguments)]
pub fn stack_entry(
    name: &str,
    kind: &str,
    t: usize,
    b: usize,
    d: usize,
    h: usize,
    layers: usize,
    bidirectional: bool,
    proj: usize,
) -> String {
    format!(
        r#"{{"name":"{name}","kind":"{kind}","hlo":"m.hlo.txt","T":{t},"B":{b},"D":{d},"H":{h},"layers":{layers},"bidirectional":{bidirectional},"P":{proj},"inputs":[],"outputs":[]}}"#
    )
}
