//! Failure injection: the runtime must reject corrupt artifacts loudly
//! instead of serving wrong numbers — truncated goldens, malformed
//! manifests, missing files, mismatched shapes.

use std::fs;
use std::path::PathBuf;

use sharp::runtime::{ArtifactStore, Manifest};

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sharp_fail_{name}"));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn missing_manifest_is_a_clear_error() {
    let dir = tmpdir("missing");
    let msg = match ArtifactStore::open(&dir) {
        Ok(_) => panic!("must fail without a manifest"),
        Err(e) => format!("{e:#}"),
    };
    assert!(msg.contains("manifest"), "unhelpful error: {msg}");
    assert!(msg.contains("make artifacts"), "should tell the user the fix: {msg}");
}

#[test]
fn malformed_manifest_rejected() {
    let dir = tmpdir("malformed");
    fs::write(dir.join("manifest.json"), "{ not json ").unwrap();
    assert!(ArtifactStore::open(&dir).is_err());

    // Valid JSON, wrong schema.
    fs::write(dir.join("manifest.json"), r#"{"artifacts": 42}"#).unwrap();
    assert!(ArtifactStore::open(&dir).is_err());

    // Artifact entry missing required dims.
    fs::write(
        dir.join("manifest.json"),
        r#"{"artifacts":[{"name":"x","hlo":"x.hlo.txt","inputs":[],"outputs":[]}]}"#,
    )
    .unwrap();
    assert!(ArtifactStore::open(&dir).is_err());
}

#[test]
fn truncated_golden_file_rejected() {
    let dir = tmpdir("truncated");
    let manifest = r#"{"version":1,"artifacts":[
      {"name":"a","kind":"cell","hlo":"a.hlo.txt","T":1,"B":1,"D":4,"H":4,
       "inputs":[{"name":"x","shape":[1,4],"file":"a.x.f32"}],
       "outputs":[]}]}"#;
    fs::write(dir.join("manifest.json"), manifest).unwrap();
    // 3 floats where the shape wants 4.
    fs::write(dir.join("a.x.f32"), [0u8; 12]).unwrap();
    let store = ArtifactStore::open(&dir).unwrap();
    let meta = &store.manifest.entries[0].inputs[0];
    let err = store.golden(meta).unwrap_err();
    assert!(format!("{err:#}").contains("shape wants"), "{err:#}");

    // Non-multiple-of-4 byte length.
    fs::write(dir.join("a.x.f32"), [0u8; 13]).unwrap();
    assert!(store.golden(meta).is_err());
}

#[test]
fn corrupt_hlo_text_fails_at_compile_not_execute() {
    let dir = tmpdir("badhlo");
    let manifest = r#"{"version":1,"artifacts":[
      {"name":"bad","kind":"cell","hlo":"bad.hlo.txt","T":1,"B":1,"D":4,"H":4,
       "inputs":[],"outputs":[]}]}"#;
    fs::write(dir.join("manifest.json"), manifest).unwrap();
    fs::write(dir.join("bad.hlo.txt"), "this is not HLO").unwrap();
    let store = ArtifactStore::open(&dir).unwrap();
    assert!(store.executable("bad").is_err());
    // Unknown names are reported as such.
    let msg = match store.executable("nope") {
        Ok(_) => panic!("unknown artifact must fail"),
        Err(e) => format!("{e:#}"),
    };
    assert!(msg.contains("unknown artifact"), "{msg}");
}

#[test]
fn manifest_parse_rejects_non_numeric_dims() {
    let doc = r#"{"artifacts":[{"name":"x","kind":"seq","hlo":"h","T":"big",
        "B":1,"D":1,"H":1,"inputs":[],"outputs":[]}]}"#;
    assert!(Manifest::parse(doc).is_err());
}
