//! E-PUR baseline (Silfa et al., PACT'18) — modeled per the paper's own
//! methodology: SHARP's pipeline substrate restricted to E-PUR's design
//! choices.
//!
//! Differences from SHARP that the model encodes:
//! * **Intergate schedule** (E-PUR computes all gates together) — but no
//!   *unfolding*: the across-sequence dependency stays exposed.
//! * **Fixed dot-product tiling**: E-PUR's DPUs consume whole rows
//!   column-wise; the tile cannot be re-fused at matrix edges (no padding
//!   reconfiguration) and its dot-product reduction is not tapped at
//!   intermediate levels (fixed K = 64 lanes per DPU class).
//! * A less aggressive cell-update pipeline: E-PUR's MFU processes the
//!   serial tail without SHARP's K/4-per-cycle output streaming, leaving
//!   the full drain exposed (this is what flattens Fig. 4's scaling).

use crate::config::{LstmConfig, SharpConfig};
use crate::sched::{Schedule, ScheduleKind, StepInputs};
use crate::sim::engine::SimResult;
use crate::sim::memory::{self, MemTraffic};
use crate::sim::mfu;
use crate::sim::pipeline::step_inputs;

/// E-PUR's fixed DPU vector width (64 fp16 lanes per dot-product unit in
/// the published design's compute units).
pub const EPUR_K: u64 = 64;

/// Build the E-PUR-like configuration at a MAC budget: fixed K, no
/// reconfiguration, same frequency (the paper compares both at 500 MHz).
pub fn epur_config(macs: u64) -> SharpConfig {
    SharpConfig::with_macs(macs)
        .with_k(EPUR_K)
        .with_reconfig(false)
}

/// The E-PUR step timing: Intergate MVM issue, but the cell/hidden update
/// drains serially after it (no output-streamed overlap), and nothing of
/// step t+1 starts before h_t is written back.
struct EpurSchedule;

impl Schedule for EpurSchedule {
    fn kind(&self) -> ScheduleKind {
        ScheduleKind::Intergate
    }

    fn tail(&self, s: &StepInputs) -> u64 {
        // Full drain exposed (vs SHARP-Intergate's 1/4): E-PUR overlaps
        // activation under the MVM but the update loop runs after.
        s.red_fill + s.act_fill + s.cu_drain + s.cu_fill
    }
}

/// Simulate one inference on the E-PUR model.
pub fn epur_simulate(macs: u64, model: &LstmConfig) -> SimResult {
    let cfg = epur_config(macs);
    let sched = EpurSchedule;
    let mut cycles = 0u64;
    let mut mac_issue = 0u64;
    let mut useful = 0u64;
    let mut padded = 0u64;
    let mut tails = 0u64;
    let mut act_ops = 0u64;
    let mut cu_ops = 0u64;
    let mut traffic = MemTraffic::default();
    let mut prev_layer_cycles = 0u64;

    for layer in 0..model.layers {
        let d = model.layer_input_dim(layer);
        let h = model.hidden;
        let t = model.seq_len;
        let b = model.batch;
        let s = step_inputs(&cfg, d, h, b);
        // Same on-chip-weights assumption as SHARP (and as the E-PUR
        // paper itself): layer 0 preloaded, later layers overlapped.
        let layer_weights = model.dirs() * 4 * h * (d + h) * 2;
        let fill = if layer == 0 {
            0
        } else {
            memory::exposed_fill_cycles(&cfg, layer_weights, prev_layer_cycles)
        };

        let mut layer_cycles = fill;
        for _dir in 0..model.dirs() {
            let step = sched.step(&s);
            layer_cycles += sched.sequence_overhead(&s) + t * step.cycles;
            mac_issue += t * step.mac_busy;
            useful += t * (s.mx.useful_lane_cycles + s.mh.useful_lane_cycles);
            padded += t * (s.mx.padded_lane_cycles + s.mh.padded_lane_cycles);
            tails += t * step.exposed_tail;
            act_ops += t * b * mfu::ops_per_step(h);
            cu_ops += t * b * 5 * h;
            for _ in 0..t {
                traffic.add(&memory::step_traffic(h, d, b));
            }
        }
        traffic.dram_bytes += layer_weights;
        cycles += layer_cycles;
        prev_layer_cycles = layer_cycles;
    }

    SimResult {
        cycles,
        mac_issue_cycles: mac_issue,
        useful_lane_cycles: useful,
        padded_lane_cycles: padded,
        exposed_tail_cycles: tails,
        act_ops,
        cu_ops,
        traffic,
        freq_hz: cfg.freq_hz,
        macs: cfg.macs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    

    #[test]
    fn sharp_beats_epur_modestly_at_1k_strongly_at_64k() {
        // Table 6 shape: ~1.0-1.1x at 1K MACs, ~1.7-2.3x at 64K. The
        // paper's SHARP runs at its explored K_opt + reconfiguration
        // (a fixed K=32 SHARP would waste its 64K lanes on padding —
        // which is exactly the adaptability argument).
        use crate::experiments::common::sharp_tuned;
        let net = presets::eesen();
        let e1 = epur_simulate(1024, &net);
        let r1 = e1.cycles as f64 / sharp_tuned(1024, &net).cycles as f64;
        assert!((0.9..1.5).contains(&r1), "1K speedup {r1}");

        let e64 = epur_simulate(65536, &net);
        let r64 = e64.cycles as f64 / sharp_tuned(65536, &net).cycles as f64;
        assert!(r64 > r1, "speedup must grow with resources");
        assert!((1.3..4.5).contains(&r64), "64K speedup {r64}");
    }

    #[test]
    fn epur_scaling_saturates() {
        // Fig. 4: E-PUR speedup is sub-linear beyond 4K MACs on EESEN.
        let net = presets::eesen();
        let base = epur_simulate(1024, &net).cycles as f64;
        let at_4k = base / epur_simulate(4096, &net).cycles as f64;
        let at_64k = base / epur_simulate(65536, &net).cycles as f64;
        assert!(at_4k > 2.0, "4K speedup {at_4k}");
        assert!(at_64k < 40.0, "64K speedup should be far below ideal 64x");
    }

    #[test]
    fn epur_utilization_matches_paper_band() {
        // Paper §8: E-PUR utilization 95% / 74% / 49% / 24% for 1K..64K
        // (AVG across models); allow a generous band on our single model.
        let net = crate::config::LstmConfig::square(512);
        let u1 = epur_simulate(1024, &net).utilization();
        let u64k = epur_simulate(65536, &net).utilization();
        assert!(u1 > 0.8, "1K util {u1}");
        assert!(u64k < 0.5, "64K util {u64k}");
    }
}
