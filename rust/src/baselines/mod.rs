//! Baseline accelerator models the paper compares against (§7):
//!
//! * `epur` — the state-of-the-art ASIC (E-PUR), modeled the way the paper
//!   itself did: "we implemented E-PUR scheduling by modifying SHARP's
//!   architecture" — same pipeline substrate, Intergate schedule, fixed
//!   dot-product tiling, no reconfiguration, no unfolding.
//! * `brainwave` — a cycle-level performance model of the BrainWave FPGA
//!   NPU (the paper also built one, validating against the cycles in the
//!   BrainWave ISCA paper); large fixed native tile + deep pipeline.
//! * `gpu` — analytical Titan V model for cuDNN and GRNN implementations:
//!   per-step kernel overheads + memory-bandwidth-bound GEMV at low batch.

pub mod brainwave;
pub mod epur;
pub mod gpu;

pub use brainwave::BrainWave;
pub use epur::epur_simulate;
pub use gpu::{GpuImpl, GpuModel};
