//! Analytical Titan V GPU model for the cuDNN and GRNN LSTM
//! implementations (Figs. 1, 13 and the §5 Unfolded-on-GPU experiment).
//!
//! The paper's GPU claims are about *mechanism*, not silicon: at low
//! batch, per-step GEMV is memory-bandwidth bound (weights re-read from
//! HBM every step) and per-step kernel/synchronization overheads dominate
//! small models. The model reproduces those mechanisms with published
//! Titan V parameters; absolute times are calibrated only to the
//! utilization bands of Fig. 1.

use crate::config::LstmConfig;

/// Which GPU software stack is modeled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GpuImpl {
    /// cuDNN persistent-less LSTM path: kernel launches per step.
    Cudnn,
    /// GRNN (EuroSys'19): persistent kernels, cheaper per-step sync.
    Grnn,
    /// cuDNN path re-ordered with SHARP's Unfolded schedule (the paper's
    /// §5 GPU experiment: two streams, TCU GEMM + CUDA-core cell update).
    CudnnUnfolded,
}

/// Titan V hardware + software-stack timing model.
#[derive(Debug, Clone)]
pub struct GpuModel {
    /// Peak mixed-precision TCU throughput, FLOP/s (Table 3: 29.8 TFLOPS).
    pub peak_flops: f64,
    /// HBM2 bandwidth, bytes/s (Titan V: 653 GB/s).
    pub mem_bw: f64,
    /// Per-time-step software overhead, seconds (launch + dependency
    /// sync). cuDNN's non-persistent path pays this every step.
    pub step_overhead_s: f64,
    /// Fraction of peak the GEMV/GEMM actually achieves when compute
    /// bound (TCU efficiency on the fused gate GEMM).
    pub gemm_efficiency: f64,
    pub imp: GpuImpl,
}

impl GpuModel {
    pub fn titan_v(imp: GpuImpl) -> Self {
        let (step_overhead_s, gemm_efficiency) = match imp {
            // cuDNN: kernel launch + inter-kernel dependency ~10 us/step
            // at batch 1 (launch, pointer setup, grid sync). The GEMM
            // efficiency is capped by recurrent serialization even at
            // batch 64 (Fig. 1 tops out at 28% of peak).
            GpuImpl::Cudnn => (10e-6, 0.30),
            // GRNN: persistent kernel amortizes launches into grid-wide
            // syncs (~2.5 us/step); weights stay resident only for models
            // that fit the register/SMEM budget.
            GpuImpl::Grnn => (2.5e-6, 0.50),
            // Unfolded on GPU: hoisted input GEMM amortizes launches, but
            // two-stream resource contention caps the win (~20% measured
            // in the paper over Sequential/cuDNN).
            GpuImpl::CudnnUnfolded => (7.6e-6, 0.36),
        };
        GpuModel {
            peak_flops: 29.8e12,
            mem_bw: 653e9,
            step_overhead_s,
            gemm_efficiency,
            imp,
        }
    }

    /// Time for one recurrent step of one layer at batch `b`.
    pub fn step_time_s(&self, hidden: u64, input_dim: u64, b: u64) -> f64 {
        let h = hidden as f64;
        let d = input_dim as f64;
        let b = b as f64;
        // The fused gate GEMM: (b x (d+h)) @ ((d+h) x 4h).
        let flops = 2.0 * b * (d + h) * 4.0 * h;
        let compute_s = flops / (self.peak_flops * self.gemm_efficiency);
        // Weights stream from HBM each step unless persistent (GRNN keeps
        // them in registers/SMEM for models that fit).
        let weight_bytes = (d + h) * 4.0 * h * 2.0;
        // Titan V register files total ~20 MB, but a persistent LSTM can
        // devote only a fraction to weights; ~4 MB is the practical cap
        // GRNN's paper sustains.
        let resident = matches!(self.imp, GpuImpl::Grnn) && weight_bytes < 4e6;
        let mem_s = if resident {
            // Activations only.
            (b * (d + 5.0 * h) * 2.0) / self.mem_bw
        } else {
            (weight_bytes + b * (d + 5.0 * h) * 2.0) / self.mem_bw
        };
        self.step_overhead_s + compute_s.max(mem_s)
    }

    /// Full-network inference latency.
    pub fn latency_s(&self, model: &LstmConfig) -> f64 {
        let mut t = 0.0;
        for layer in 0..model.layers {
            let d = model.layer_input_dim(layer);
            let per_step = self.step_time_s(model.hidden, d, model.batch);
            let steps = (model.dirs() * model.seq_len) as f64;
            // Unfolded hoists the input GEMM: model it as ~20% fewer
            // exposed step cycles (the paper's measured GPU gain).
            let sched_factor = match self.imp {
                GpuImpl::CudnnUnfolded => 0.84,
                _ => 1.0,
            };
            t += steps * per_step * sched_factor;
        }
        t
    }

    /// FLOP efficiency: achieved / peak (Fig. 1's metric).
    pub fn flop_efficiency(&self, model: &LstmConfig) -> f64 {
        let achieved = model.total_flops() / self.latency_s(model);
        achieved / self.peak_flops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn fig1_batch1_efficiency_under_4_percent() {
        // Fig. 1: batch-1 efficiency is extremely low for all four apps.
        let gpu = GpuModel::titan_v(GpuImpl::Cudnn);
        for app in presets::fig1_apps() {
            let e = gpu.flop_efficiency(&app);
            assert!(e < 0.04, "{}: batch-1 efficiency {e}", app.name);
        }
    }

    #[test]
    fn fig1_batch64_efficiency_in_4_to_30_percent() {
        // Fig. 1: batch 64 reaches "between 4% to 28% of peak".
        let gpu = GpuModel::titan_v(GpuImpl::Cudnn);
        let mut lo = f64::MAX;
        let mut hi: f64 = 0.0;
        for app in presets::fig1_apps() {
            let e = gpu.flop_efficiency(&app.clone().with_batch(64));
            lo = lo.min(e);
            hi = hi.max(e);
        }
        assert!(lo > 0.02, "min batch-64 efficiency {lo}");
        assert!(hi < 0.40, "max batch-64 efficiency {hi}");
        assert!(hi / lo > 2.0, "apps must spread, got {lo}..{hi}");
    }

    #[test]
    fn grnn_faster_than_cudnn_at_batch1() {
        // Fig. 13: GRNN is the stronger GPU baseline (72-93x vs 172-625x).
        let cudnn = GpuModel::titan_v(GpuImpl::Cudnn);
        let grnn = GpuModel::titan_v(GpuImpl::Grnn);
        for h in [128u64, 512, 1024] {
            let m = crate::config::LstmConfig::square(h);
            assert!(grnn.latency_s(&m) < cudnn.latency_s(&m), "h={h}");
        }
    }

    #[test]
    fn unfolded_on_gpu_gains_about_20_percent() {
        // §5: "around 20% performance improvement compared to Sequential".
        let seq = GpuModel::titan_v(GpuImpl::Cudnn);
        let unf = GpuModel::titan_v(GpuImpl::CudnnUnfolded);
        let m = crate::config::LstmConfig::square(1024);
        let gain = seq.latency_s(&m) / unf.latency_s(&m);
        assert!((1.1..1.45).contains(&gain), "gain {gain}");
    }

    #[test]
    fn step_time_has_overhead_floor() {
        let gpu = GpuModel::titan_v(GpuImpl::Cudnn);
        assert!(gpu.step_time_s(16, 16, 1) >= gpu.step_overhead_s);
    }
}
