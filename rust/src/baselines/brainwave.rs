//! BrainWave FPGA NPU performance model (Fowers et al., ISCA'18).
//!
//! BrainWave is not open source; like the paper ("we developed a
//! cycle-accurate performance model for the BrainWave FPGA
//! implementation... validated against the number of cycles reported"),
//! we model its published architecture: a matrix-vector unit with a large
//! *fixed* native tile, a deep pipeline whose dependent writeback delays
//! the recurrent step, and a Sequential-style gate order. Network latency
//! is excluded (the paper's comparison does the same).

use crate::config::LstmConfig;
use crate::util::ceil_div;

/// The BrainWave-like design point used in Table 4 / Fig. 3 comparisons.
#[derive(Debug, Clone)]
pub struct BrainWave {
    /// MAC lanes (the Stratix-10 deploy: ~96K at the paper's comparison).
    pub macs: u64,
    /// Clock (250 MHz for the Stratix-10 BrainWave).
    pub freq_hz: f64,
    /// Native tile rows (matrix-vector unit's fixed row dimension —
    /// lanes are ganged into wide dot products over the native dim).
    pub native_rows: u64,
    /// Deep-pipeline latency in cycles: time from issuing the last MVM
    /// tile to the dependent hidden vector being written back (the paper
    /// blames exactly this for small-model inefficiency).
    pub pipeline_depth: u64,
}

impl BrainWave {
    /// The Stratix-10 configuration of the paper's Table 3/4.
    pub fn stratix10() -> Self {
        BrainWave {
            macs: 96 * 1024,
            freq_hz: 250e6,
            native_rows: 2048,
            pipeline_depth: 300,
        }
    }

    /// Native tile columns: lanes / native_rows.
    pub fn native_cols(&self) -> u64 {
        (self.macs / self.native_rows).max(1)
    }

    /// Cycles for one time step of a layer (Sequential gate order on the
    /// fixed native tile + the deep writeback).
    pub fn step_cycles(&self, hidden: u64, input_dim: u64, batch: u64) -> u64 {
        let rows = 4 * hidden; // fused gate output dim
        let cols = input_dim + hidden;
        let tiles = ceil_div(rows, self.native_rows) * ceil_div(cols, self.native_cols());
        batch * tiles + self.pipeline_depth
    }

    /// Full-network latency in seconds.
    pub fn latency_s(&self, model: &LstmConfig) -> f64 {
        let mut cycles = 0u64;
        for layer in 0..model.layers {
            let d = model.layer_input_dim(layer);
            cycles += model.dirs()
                * model.seq_len
                * self.step_cycles(model.hidden, d, model.batch);
        }
        cycles as f64 / self.freq_hz
    }

    /// Hardware utilization for a model: useful MACs over lane capacity
    /// for the run's duration (the quantity Fig. 3's right axis shows).
    pub fn utilization(&self, model: &LstmConfig) -> f64 {
        let useful = model.total_macs() as f64;
        let lane_cycles = self.macs as f64 * self.latency_s(model) * self.freq_hz;
        useful / lane_cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LstmConfig;

    #[test]
    fn latency_flat_as_hidden_shrinks() {
        // Fig. 3: "as the size of the hidden layers decreases, utilization
        // drops drastically, whereas the latency remains the same".
        let bw = BrainWave::stratix10();
        let lat_256 = bw.latency_s(&LstmConfig::square(256));
        let lat_1024 = bw.latency_s(&LstmConfig::square(1024));
        // One step is pipeline-depth bound in both cases: latencies within ~2.2x
        // while the workload differs by 16x.
        assert!(lat_1024 / lat_256 < 2.2, "ratio {}", lat_1024 / lat_256);
    }

    #[test]
    fn utilization_falls_with_model_size() {
        let bw = BrainWave::stratix10();
        let u_small = bw.utilization(&LstmConfig::square(256));
        let u_large = bw.utilization(&LstmConfig::square(2048));
        assert!(u_small < u_large);
        // Paper: ~18% average utilization for LSTMs, single digits small.
        assert!(u_small < 0.05, "small-model util {u_small}");
        assert!(u_large < 0.6, "large-model util {u_large}");
    }

    #[test]
    fn native_tile_conserves_lanes() {
        let bw = BrainWave::stratix10();
        assert_eq!(bw.native_rows * bw.native_cols(), bw.macs);
    }

    #[test]
    fn batch_scales_tile_issue_only() {
        let bw = BrainWave::stratix10();
        let b1 = bw.step_cycles(1024, 1024, 1);
        let b4 = bw.step_cycles(1024, 1024, 4);
        assert!(b4 < 4 * b1, "pipeline depth amortizes over batch");
        assert!(b4 > b1);
    }
}
