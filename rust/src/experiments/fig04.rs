//! Fig. 4 — E-PUR's scalability wall: speedup on EESEN versus MAC count
//! is far from proportional beyond 4K units.

use crate::baselines::epur_simulate;
use crate::config::presets::{budget_label, eesen, MAC_BUDGETS};
use crate::report::Exhibit;
use crate::util::table::{fnum, fx, Table};

#[derive(Debug, Clone)]
pub struct Row {
    pub macs: u64,
    pub speedup_vs_1k: f64,
    pub ideal: f64,
}

pub fn rows() -> Vec<Row> {
    let net = eesen();
    let base = epur_simulate(1024, &net).cycles as f64;
    MAC_BUDGETS
        .iter()
        .map(|&m| Row {
            macs: m,
            speedup_vs_1k: base / epur_simulate(m, &net).cycles as f64,
            ideal: m as f64 / 1024.0,
        })
        .collect()
}

pub fn run() -> Exhibit {
    let rows = rows();
    let mut t = Table::new("E-PUR on EESEN: speedup vs MAC units (norm. to 1K)")
        .header(&["MACs", "speedup", "ideal", "efficiency"]);
    for r in &rows {
        t.row(&[
            budget_label(r.macs),
            fx(r.speedup_vs_1k),
            fx(r.ideal),
            fnum(r.speedup_vs_1k / r.ideal * 100.0) + "%",
        ]);
    }
    let eff_64k = rows.last().unwrap().speedup_vs_1k / rows.last().unwrap().ideal;
    Exhibit {
        id: "fig04",
        title: "E-PUR scaling saturates with resources",
        tables: vec![t],
        notes: vec![format!(
            "64K-MAC scaling efficiency {:.0}% (paper: 'above 4K not proportional')",
            eff_64k * 100.0
        )],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaling_saturates() {
        let rows = rows();
        // Near-ideal at 4K, clearly sub-linear at 64K.
        let eff = |r: &Row| r.speedup_vs_1k / r.ideal;
        assert!(eff(&rows[1]) > 0.55, "4K eff {}", eff(&rows[1]));
        assert!(eff(&rows[3]) < 0.55, "64K eff {}", eff(&rows[3]));
        assert!(eff(&rows[3]) < eff(&rows[1]));
    }

    #[test]
    fn speedup_monotone() {
        let rows = rows();
        for w in rows.windows(2) {
            assert!(w[1].speedup_vs_1k >= w[0].speedup_vs_1k);
        }
    }
}
