//! Fig. 10 — speedup from dynamic padding reconfiguration (§6.2.1):
//! fixed K_opt tile vs. the same tile with edge re-fusion. Paper shape:
//! up to ~1.22x, exactly 1.0x at h=512 (4H is a multiple of every K).

use crate::config::presets::{budget_label, HIDDEN_SWEEP, K_RECONFIG, MAC_BUDGETS};
use crate::config::{LstmConfig, SharpConfig};
use crate::report::Exhibit;
use crate::sched::ScheduleKind;
use crate::sim::simulate;
use crate::tile::explore_k;
use crate::util::table::{fnum, Table};

#[derive(Debug, Clone)]
pub struct Row {
    pub macs: u64,
    pub hidden: u64,
    pub k_opt: u64,
    pub speedup: f64,
}

pub fn rows() -> Vec<Row> {
    let mut out = Vec::new();
    for &macs in &MAC_BUDGETS {
        for &h in &HIDDEN_SWEEP {
            let model = LstmConfig::square(h);
            // K_opt chosen for the *fixed* engine (paper: "we configure
            // K_opt for each combination of LSTM dimension and MACs").
            let base = SharpConfig::with_macs(macs).with_reconfig(false);
            let entry = explore_k(&base, h, &K_RECONFIG, |cfg| {
                simulate(cfg, &model, ScheduleKind::Unfolded).cycles
            });
            let fixed_cfg = base.clone().with_k(entry.k).with_row_groups(entry.row_groups);
            let recfg = fixed_cfg.clone().with_reconfig(true);
            let fixed = simulate(&fixed_cfg, &model, ScheduleKind::Unfolded).cycles;
            let rec = simulate(&recfg, &model, ScheduleKind::Unfolded).cycles;
            out.push(Row {
                macs,
                hidden: h,
                k_opt: entry.k,
                speedup: fixed as f64 / rec as f64,
            });
        }
    }
    out
}

pub fn run() -> Exhibit {
    let rows = rows();
    let mut t = Table::new("padding-reconfiguration speedup (fixed K_opt -> reconfig)")
        .header(&["hidden", "1K", "4K", "16K", "64K"]);
    for &h in &HIDDEN_SWEEP {
        let mut cells = vec![h.to_string()];
        for &m in &MAC_BUDGETS {
            let r = rows.iter().find(|r| r.macs == m && r.hidden == h).unwrap();
            cells.push(fnum(r.speedup));
        }
        t.row(&cells);
    }
    let max = rows.iter().map(|r| r.speedup).fold(0.0, f64::max);
    let h512_max = rows
        .iter()
        .filter(|r| r.hidden == 512)
        .map(|r| r.speedup)
        .fold(0.0, f64::max);
    Exhibit {
        id: "fig10",
        title: "dynamic tile reconfiguration recovers MVM padding",
        tables: vec![t],
        notes: vec![
            format!("max speedup {} (paper: up to 1.22x)", fnum(max)),
            format!(
                "h=512 speedup {} (paper: 1.0 — no padding when 4H % K == 0); budgets: {}",
                fnum(h512_max),
                MAC_BUDGETS.map(budget_label).join("/")
            ),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reconfig_never_hurts_and_helps_somewhere() {
        let rows = rows();
        assert!(rows.iter().all(|r| r.speedup >= 0.999));
        let max = rows.iter().map(|r| r.speedup).fold(0.0, f64::max);
        assert!(max > 1.02, "some dim must benefit, max {max}");
        assert!(max < 1.5, "benefit bounded (paper: <=1.22x), max {max}");
    }

    #[test]
    fn h512_sees_no_benefit() {
        // 2048 rows divide evenly by every K in {32..256}.
        for r in rows().iter().filter(|r| r.hidden == 512) {
            assert!((r.speedup - 1.0).abs() < 1e-6, "h=512 macs={}", r.macs);
        }
    }
}
