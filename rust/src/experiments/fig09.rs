//! Fig. 9 — K-width exploration for the VS units of the Compute Unit:
//! four charts (1K/4K/16K/64K MACs), each sweeping K in {32..512} over
//! LSTM hidden dims. The paper's point: there is no single best K — the
//! optimum shifts with both model dimension and resource budget, which is
//! the case for reconfigurability.

use crate::config::presets::{budget_label, HIDDEN_SWEEP, K_SWEEP, MAC_BUDGETS};
use crate::config::{LstmConfig, SharpConfig};
use crate::report::Exhibit;
use crate::sched::ScheduleKind;
use crate::sim::simulate;
use crate::util::table::{fnum, Table};

/// Speedup of (macs, k) on hidden dim h, normalized to the 1K-MAC K=32
/// design (the paper normalizes each chart to the 1K design).
#[derive(Debug, Clone)]
pub struct Cell {
    pub macs: u64,
    pub k: u64,
    pub hidden: u64,
    pub speedup: f64,
}

/// Simulate with a fixed tile (exploration happens before reconfiguration
/// is applied, so padding is whatever the fixed K incurs).
fn cycles(macs: u64, k: u64, h: u64) -> u64 {
    let cfg = SharpConfig::with_macs(macs).with_k(k).with_reconfig(false);
    simulate(&cfg, &LstmConfig::square(h), ScheduleKind::Unfolded).cycles
}

pub fn cells() -> Vec<Cell> {
    let mut out = Vec::new();
    for &h in &HIDDEN_SWEEP {
        let base = cycles(1024, 32, h) as f64;
        for &macs in &MAC_BUDGETS {
            for &k in &K_SWEEP {
                if k > macs {
                    continue;
                }
                out.push(Cell {
                    macs,
                    k,
                    hidden: h,
                    speedup: base / cycles(macs, k, h) as f64,
                });
            }
        }
    }
    out
}

/// Best K per (macs, hidden) — the offline table the controller preloads.
pub fn best_k(cells: &[Cell]) -> Vec<(u64, u64, u64)> {
    let mut out = Vec::new();
    for &macs in &MAC_BUDGETS {
        for &h in &HIDDEN_SWEEP {
            let best = cells
                .iter()
                .filter(|c| c.macs == macs && c.hidden == h)
                .max_by(|a, b| a.speedup.partial_cmp(&b.speedup).unwrap())
                .unwrap();
            out.push((macs, h, best.k));
        }
    }
    out
}

pub fn run() -> Exhibit {
    let cells = cells();
    let mut tables = Vec::new();
    for &macs in &MAC_BUDGETS {
        let mut t = Table::new(&format!(
            "{} MACs: speedup vs 1K-MAC baseline, per K",
            budget_label(macs)
        ))
        .header(&["hidden", "K=32", "K=64", "K=128", "K=256", "K=512", "best"]);
        for &h in &HIDDEN_SWEEP {
            let mut row = vec![h.to_string()];
            let mut best_k = 0u64;
            let mut best_s = 0.0f64;
            for &k in &K_SWEEP {
                match cells
                    .iter()
                    .find(|c| c.macs == macs && c.hidden == h && c.k == k)
                {
                    Some(c) => {
                        if c.speedup > best_s {
                            best_s = c.speedup;
                            best_k = k;
                        }
                        row.push(fnum(c.speedup));
                    }
                    None => row.push("-".into()),
                }
            }
            row.push(format!("K={best_k}"));
            t.row(&row);
        }
        tables.push(t);
    }
    let bests = best_k(&cells);
    let distinct: std::collections::BTreeSet<u64> = bests.iter().map(|b| b.2).collect();
    Exhibit {
        id: "fig09",
        title: "K-width exploration: no single best tile configuration",
        tables,
        notes: vec![format!(
            "distinct optimal K values across (budget, dim): {:?} (paper: 'there is not just one best configuration')",
            distinct
        )],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_single_best_k() {
        // The paper's core observation: optimal K differs across models
        // and budgets.
        let cells = cells();
        let bests = best_k(&cells);
        let distinct: std::collections::BTreeSet<u64> = bests.iter().map(|b| b.2).collect();
        assert!(distinct.len() >= 2, "expected multiple optima, got {distinct:?}");
    }

    #[test]
    fn speedup_grows_with_budget() {
        let cells = cells();
        // For each (hidden, K) the speedup should not shrink with MACs.
        for &h in &HIDDEN_SWEEP {
            for &k in &K_SWEEP {
                let series: Vec<f64> = MAC_BUDGETS
                    .iter()
                    .filter_map(|&m| {
                        cells
                            .iter()
                            .find(|c| c.macs == m && c.hidden == h && c.k == k)
                            .map(|c| c.speedup)
                    })
                    .collect();
                // Tail/tree-fill effects can cost a few percent for tiny
                // models on huge arrays (the utilization collapse of
                // Fig. 12); the series must still be near-monotone.
                for w in series.windows(2) {
                    assert!(w[1] >= w[0] * 0.94, "h={h} k={k}: {series:?}");
                }
            }
        }
    }
}
