//! Fig. 12 — SHARP's latency and resource utilization across budgets and
//! LSTM dims (K_opt tile + dynamic reconfiguration + Unfolded schedule).
//! Paper shape: latency scales ~linearly with MACs on average; utilization
//! ranges ~98% (1K) down to ~50% (64K).

use crate::config::presets::{budget_label, HIDDEN_SWEEP, MAC_BUDGETS};
use crate::config::LstmConfig;
use crate::experiments::common::sharp_tuned;
use crate::report::Exhibit;
use crate::util::stats::geomean;
use crate::util::table::{fnum, fpct, Table};

#[derive(Debug, Clone)]
pub struct Row {
    pub macs: u64,
    pub hidden: u64,
    pub latency_us: f64,
    pub utilization: f64,
}

pub fn rows() -> Vec<Row> {
    let mut out = Vec::new();
    for &macs in &MAC_BUDGETS {
        for &h in &HIDDEN_SWEEP {
            let r = sharp_tuned(macs, &LstmConfig::square(h));
            out.push(Row {
                macs,
                hidden: h,
                latency_us: r.time_s() * 1e6,
                utilization: r.utilization(),
            });
        }
    }
    out
}

pub fn run() -> Exhibit {
    let rows = rows();
    let mut lat = Table::new("SHARP latency (us), T=25, K_opt + reconfig")
        .header(&["hidden", "1K", "4K", "16K", "64K"]);
    let mut util = Table::new("SHARP MAC utilization")
        .header(&["hidden", "1K", "4K", "16K", "64K"]);
    for &h in &HIDDEN_SWEEP {
        let pick = |m: u64| rows.iter().find(|r| r.macs == m && r.hidden == h).unwrap();
        lat.row(&[
            h.to_string(),
            fnum(pick(1024).latency_us),
            fnum(pick(4096).latency_us),
            fnum(pick(16384).latency_us),
            fnum(pick(65536).latency_us),
        ]);
        util.row(&[
            h.to_string(),
            fpct(pick(1024).utilization),
            fpct(pick(4096).utilization),
            fpct(pick(16384).utilization),
            fpct(pick(65536).utilization),
        ]);
    }
    // AVG rows (the paper's AVG case scales ~linearly).
    let avg_lat: Vec<f64> = MAC_BUDGETS
        .iter()
        .map(|&m| {
            geomean(
                &rows
                    .iter()
                    .filter(|r| r.macs == m)
                    .map(|r| r.latency_us)
                    .collect::<Vec<_>>(),
            )
        })
        .collect();
    let avg_util: Vec<f64> = MAC_BUDGETS
        .iter()
        .map(|&m| {
            let us: Vec<f64> = rows
                .iter()
                .filter(|r| r.macs == m)
                .map(|r| r.utilization)
                .collect();
            us.iter().sum::<f64>() / us.len() as f64
        })
        .collect();
    lat.row(&[
        "AVG".to_string(),
        fnum(avg_lat[0]),
        fnum(avg_lat[1]),
        fnum(avg_lat[2]),
        fnum(avg_lat[3]),
    ]);
    util.row(&[
        "AVG".to_string(),
        fpct(avg_util[0]),
        fpct(avg_util[1]),
        fpct(avg_util[2]),
        fpct(avg_util[3]),
    ]);
    Exhibit {
        id: "fig12",
        title: "SHARP latency scaling and utilization",
        tables: vec![lat, util],
        notes: vec![
            format!(
                "AVG latency scaling 1K->64K: {:.1}x (ideal 64x; paper: 'linearly reduces')",
                avg_lat[0] / avg_lat[3]
            ),
            format!(
                "AVG utilization {} -> {} across {} budgets (paper: 98% -> 50%)",
                fpct(avg_util[0]),
                fpct(avg_util[3]),
                MAC_BUDGETS.map(budget_label).join("/")
            ),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_monotone_in_macs() {
        let rows = rows();
        for &h in &HIDDEN_SWEEP {
            let mut prev = f64::MAX;
            for &m in &MAC_BUDGETS {
                let r = rows.iter().find(|r| r.macs == m && r.hidden == h).unwrap();
                assert!(r.latency_us <= prev * 1.001, "h={h} m={m}");
                prev = r.latency_us;
            }
        }
    }

    #[test]
    fn utilization_band_matches_paper() {
        let rows = rows();
        let avg = |m: u64| {
            let us: Vec<f64> = rows
                .iter()
                .filter(|r| r.macs == m)
                .map(|r| r.utilization)
                .collect();
            us.iter().sum::<f64>() / us.len() as f64
        };
        assert!(avg(1024) > 0.85, "1K avg util {}", avg(1024));
        assert!(avg(65536) > 0.35 && avg(65536) < 0.95, "64K avg util {}", avg(65536));
        assert!(avg(65536) < avg(1024));
    }

    #[test]
    fn better_than_epur_utilization() {
        // Paper: SHARP 50-98% vs E-PUR 24-95% across budgets.
        use crate::baselines::epur_simulate;
        let model = LstmConfig::square(512);
        for &m in &MAC_BUDGETS {
            let s = sharp_tuned(m, &model).utilization();
            let e = epur_simulate(m, &model).utilization();
            assert!(s >= e * 0.98, "macs={m}: sharp {s} vs epur {e}");
        }
    }
}
