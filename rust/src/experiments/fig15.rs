//! Fig. 15 — power breakdown of SHARP across the four budgets, averaged
//! over applications. Paper shape: SRAM dominates small designs, the
//! compute unit dominates large ones, main-memory power grows with MACs,
//! activation stays roughly constant, controller <1%; totals 8.11 / 11.36
//! / 22.13 / 47.7 W.

use crate::config::presets::{budget_label, HIDDEN_SWEEP, MAC_BUDGETS};
use crate::config::LstmConfig;
use crate::energy::{power_report, PowerReport};
use crate::experiments::common::{k_opt_config, sharp_tuned};
use crate::report::Exhibit;
use crate::util::table::{fnum, fpct, Table};

#[derive(Debug, Clone)]
pub struct Row {
    pub macs: u64,
    /// Average shares (compute, sram, dram, activation, controller).
    pub shares: [f64; 5],
    pub total_w: f64,
}

pub fn rows() -> Vec<Row> {
    MAC_BUDGETS
        .iter()
        .map(|&macs| {
            // Average over the application sweep like the paper does.
            let reports: Vec<PowerReport> = HIDDEN_SWEEP
                .iter()
                .map(|&h| {
                    let model = LstmConfig::square(h);
                    let cfg = k_opt_config(macs, &model);
                    power_report(&cfg, &sharp_tuned(macs, &model))
                })
                .collect();
            let n = reports.len() as f64;
            let mut shares = [0.0; 5];
            let mut total = 0.0;
            for r in &reports {
                let s = r.shares();
                for i in 0..5 {
                    shares[i] += s[i] / n;
                }
                total += r.total_w() / n;
            }
            Row {
                macs,
                shares,
                total_w: total,
            }
        })
        .collect()
}

pub fn run() -> Exhibit {
    let rows = rows();
    let mut t = Table::new("power breakdown (avg across LSTM dims)")
        .header(&["MACs", "compute", "SRAM", "DRAM", "activation", "ctrl", "total_W"]);
    for r in &rows {
        t.row(&[
            budget_label(r.macs),
            fpct(r.shares[0]),
            fpct(r.shares[1]),
            fpct(r.shares[2]),
            fpct(r.shares[3]),
            fpct(r.shares[4]),
            fnum(r.total_w),
        ]);
    }
    Exhibit {
        id: "fig15",
        title: "power dissipation by component",
        tables: vec![t],
        notes: vec![
            format!(
                "totals {} W (paper: 8.11/11.36/22.13/47.7 W)",
                rows.iter().map(|r| fnum(r.total_w)).collect::<Vec<_>>().join("/")
            ),
            "SRAM dominant at 1K/4K; compute dominant at 16K/64K; controller <1%".into(),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_flips_with_budget() {
        let rows = rows();
        assert!(rows[0].shares[1] > rows[0].shares[0], "1K: SRAM > compute");
        assert!(rows[3].shares[0] > rows[3].shares[1], "64K: compute > SRAM");
    }

    #[test]
    fn totals_monotone_and_in_band() {
        let rows = rows();
        for w in rows.windows(2) {
            assert!(w[1].total_w > w[0].total_w);
        }
        // Paper totals within a generous modeling band.
        let paper = [8.11, 11.36, 22.13, 47.7];
        for (r, p) in rows.iter().zip(paper) {
            let err = (r.total_w - p).abs() / p;
            assert!(err < 0.40, "{}: {} W vs paper {} W", r.macs, r.total_w, p);
        }
    }

    #[test]
    fn controller_below_one_percent_dram_grows() {
        let rows = rows();
        for r in &rows {
            assert!(r.shares[4] < 0.01);
        }
        assert!(rows[3].shares[2] > rows[0].shares[2], "DRAM share grows");
    }
}
