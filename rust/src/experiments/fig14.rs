//! Fig. 14 — energy consumption of SHARP across hidden dims and budgets,
//! normalized to E-PUR at 1K MACs. Paper shape: SHARP reduces energy on
//! average by 7.3% / 18.2% / 34.8% / 40.5% vs same-budget E-PUR for
//! 1K..64K (bigger savings at bigger budgets, where its scheduling and
//! reconfiguration keep the larger MAC array busy).

use crate::baselines::epur::{epur_config, epur_simulate};
use crate::config::presets::{budget_label, HIDDEN_SWEEP, MAC_BUDGETS};
use crate::config::LstmConfig;
use crate::energy::power_report;
use crate::experiments::common::{k_opt_config, sharp_tuned};
use crate::report::Exhibit;
use crate::util::table::{fnum, fpct, Table};

#[derive(Debug, Clone)]
pub struct Row {
    pub macs: u64,
    pub hidden: u64,
    /// SHARP energy normalized to E-PUR-1K on the same model.
    pub sharp_norm: f64,
    /// E-PUR (same budget) energy normalized to E-PUR-1K.
    pub epur_norm: f64,
}

fn sharp_energy(macs: u64, model: &LstmConfig) -> f64 {
    let cfg = k_opt_config(macs, model);
    let sim = sharp_tuned(macs, model);
    power_report(&cfg, &sim).energy_j()
}

fn epur_energy(macs: u64, model: &LstmConfig) -> f64 {
    let sim = epur_simulate(macs, model);
    power_report(&epur_config(macs), &sim).energy_j()
}

pub fn rows() -> Vec<Row> {
    let mut out = Vec::new();
    for &h in &HIDDEN_SWEEP {
        let model = LstmConfig::square(h);
        let base = epur_energy(1024, &model);
        for &macs in &MAC_BUDGETS {
            out.push(Row {
                macs,
                hidden: h,
                sharp_norm: sharp_energy(macs, &model) / base,
                epur_norm: epur_energy(macs, &model) / base,
            });
        }
    }
    out
}

/// Average energy reduction of SHARP vs same-budget E-PUR, per budget.
pub fn avg_reduction(rows: &[Row]) -> Vec<(u64, f64)> {
    MAC_BUDGETS
        .iter()
        .map(|&m| {
            let rs: Vec<&Row> = rows.iter().filter(|r| r.macs == m).collect();
            let red: f64 = rs
                .iter()
                .map(|r| 1.0 - r.sharp_norm / r.epur_norm)
                .sum::<f64>()
                / rs.len() as f64;
            (m, red)
        })
        .collect()
}

pub fn run() -> Exhibit {
    let rows = rows();
    let mut t = Table::new("energy normalized to E-PUR@1K (SHARP / E-PUR per budget)")
        .header(&["hidden", "1K", "4K", "16K", "64K"]);
    for &h in &HIDDEN_SWEEP {
        let mut cells = vec![h.to_string()];
        for &m in &MAC_BUDGETS {
            let r = rows.iter().find(|r| r.macs == m && r.hidden == h).unwrap();
            cells.push(format!("{}/{}", fnum(r.sharp_norm), fnum(r.epur_norm)));
        }
        t.row(&cells);
    }
    let reds = avg_reduction(&rows);
    Exhibit {
        id: "fig14",
        title: "energy vs E-PUR (normalized to E-PUR@1K)",
        tables: vec![t],
        notes: vec![format!(
            "avg energy reduction vs same-budget E-PUR: {} (paper: 7.3%/18.2%/34.8%/40.5%)",
            reds.iter()
                .map(|(m, r)| format!("{}:{}", budget_label(*m), fpct(*r)))
                .collect::<Vec<_>>()
                .join(" ")
        )],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sharp_never_uses_more_energy_than_epur() {
        for r in rows() {
            assert!(
                r.sharp_norm <= r.epur_norm * 1.02,
                "macs={} h={}: {} vs {}",
                r.macs,
                r.hidden,
                r.sharp_norm,
                r.epur_norm
            );
        }
    }

    #[test]
    fn savings_grow_with_budget() {
        let rows = rows();
        let reds = avg_reduction(&rows);
        assert!(
            reds[3].1 > reds[0].1,
            "64K saving {} should exceed 1K saving {}",
            reds[3].1,
            reds[0].1
        );
        // Band check vs paper's 7.3%..40.5% (allow slack; our substrate
        // is a recalibrated model).
        assert!(reds[0].1 < 0.30, "1K reduction {}", reds[0].1);
        assert!(reds[3].1 > 0.10, "64K reduction {}", reds[3].1);
    }
}
