//! Table 4 — DeepBench LSTM inference speedup over BrainWave. For a fair
//! comparison the paper clocks SHARP down to 250 MHz and grows it to 96K
//! MACs (equal budget). Paper: 5.39x / 3.57x / 1.85x / 1.73x — larger
//! speedups for smaller hidden dims (the adaptability claim).

use crate::baselines::BrainWave;
use crate::config::presets::deepbench;
use crate::config::SharpConfig;
use crate::experiments::common::k_opt_config;
use crate::report::Exhibit;
use crate::sched::ScheduleKind;
use crate::sim::simulate;
use crate::util::table::{fnum, Table};

#[derive(Debug, Clone)]
pub struct Row {
    pub hidden: u64,
    pub steps: u64,
    pub speedup: f64,
}

/// SHARP at BrainWave-parity: 96K MACs, 250 MHz.
fn sharp_bw_parity(model: &crate::config::LstmConfig) -> SharpConfig {
    k_opt_config(96 * 1024, model).with_freq(250e6)
}

pub fn rows() -> Vec<Row> {
    let bw = BrainWave::stratix10();
    deepbench()
        .into_iter()
        .map(|model| {
            let cfg = sharp_bw_parity(&model);
            let sharp = simulate(&cfg, &model, ScheduleKind::Unfolded);
            Row {
                hidden: model.hidden,
                steps: model.seq_len,
                speedup: bw.latency_s(&model) / sharp.time_s(),
            }
        })
        .collect()
}

pub fn run() -> Exhibit {
    let rows = rows();
    let mut t = Table::new("DeepBench LSTM speedup over BrainWave (250 MHz, 96K MACs)")
        .header(&["hidden", "time-steps", "speedup"]);
    for r in &rows {
        t.row(&[r.hidden.to_string(), r.steps.to_string(), fnum(r.speedup) + "x"]);
    }
    Exhibit {
        id: "table4",
        title: "SHARP vs BrainWave on DeepBench",
        tables: vec![t],
        notes: vec![
            format!(
                "speedups {} (paper: 5.39/3.57/1.85/1.73x)",
                rows.iter().map(|r| fnum(r.speedup)).collect::<Vec<_>>().join("/")
            ),
            "largest for the smallest dims — SHARP fixes BrainWave's adaptability gap (Fig. 3)".into(),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_models_beat_brainwave() {
        // Paper: "more than 1.65x speedup for all the LSTM models".
        for r in rows() {
            assert!(r.speedup > 1.3, "h={}: {}", r.hidden, r.speedup);
        }
    }

    #[test]
    fn smaller_dims_win_bigger() {
        let rows = rows();
        // h=256 speedup must exceed h=1024 and h=1536.
        assert!(rows[0].speedup > rows[2].speedup);
        assert!(rows[0].speedup > rows[3].speedup);
    }
}
