//! Table 6 — SHARP speedups over E-PUR on four real-world networks
//! (Table 5) at equal clock (500 MHz) and equal MAC budgets. Paper:
//! 1.01-1.07x at 1K rising to 1.66-2.3x at 64K — the scalability claim.

use crate::baselines::epur_simulate;
use crate::config::presets::{budget_label, table5_networks, MAC_BUDGETS};
use crate::experiments::common::sharp_tuned;
use crate::report::Exhibit;
use crate::util::table::{fnum, Table};

#[derive(Debug, Clone)]
pub struct Row {
    pub network: String,
    pub speedups: [f64; 4],
}

pub fn rows() -> Vec<Row> {
    table5_networks()
        .into_iter()
        .map(|net| {
            let mut speedups = [0.0; 4];
            for (i, &macs) in MAC_BUDGETS.iter().enumerate() {
                let sharp = sharp_tuned(macs, &net);
                let epur = epur_simulate(macs, &net);
                speedups[i] = epur.time_s() / sharp.time_s();
            }
            Row {
                network: net.name,
                speedups,
            }
        })
        .collect()
}

pub fn run() -> Exhibit {
    let rows = rows();
    let mut t = Table::new("SHARP speedup vs E-PUR (500 MHz both)")
        .header(&["network", "1K", "4K", "16K", "64K"]);
    for r in &rows {
        t.row(&[
            r.network.clone(),
            fnum(r.speedups[0]),
            fnum(r.speedups[1]),
            fnum(r.speedups[2]),
            fnum(r.speedups[3]),
        ]);
    }
    Exhibit {
        id: "table6",
        title: "speedup over E-PUR on real networks",
        tables: vec![t],
        notes: vec![
            "paper bands: EESEN 1.07-1.9x, GMAT 1.01-1.66x, BYSDNE 1.05-2.22x, RLDRADSPR 1.03-2.3x".into(),
            format!(
                "speedups grow with resources for every network (budgets {})",
                MAC_BUDGETS.map(budget_label).join("/")
            ),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedups_grow_with_resources() {
        for r in rows() {
            assert!(
                r.speedups[3] > r.speedups[0],
                "{}: {:?}",
                r.network,
                r.speedups
            );
            // Modest at 1K (paper 1.01-1.07x)...
            assert!(
                (0.95..1.6).contains(&r.speedups[0]),
                "{} 1K {}",
                r.network,
                r.speedups[0]
            );
            // ...meaningful at 64K (paper 1.66-2.3x).
            assert!(
                (1.2..4.0).contains(&r.speedups[3]),
                "{} 64K {}",
                r.network,
                r.speedups[3]
            );
        }
    }

    #[test]
    fn covers_all_four_networks() {
        let names: Vec<String> = rows().into_iter().map(|r| r.network).collect();
        for n in ["EESEN", "GMAT", "BYSDNE", "RLDRADSPR"] {
            assert!(names.contains(&n.to_string()), "{n} missing");
        }
    }
}
