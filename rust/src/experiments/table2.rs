//! Table 2 — area breakdown of the four SHARP configurations. Paper:
//! compute unit grows from 7.4% to 80.9% of area while SRAM shrinks from
//! 86.2% to 17.6%; totals 101.1 / 133.3 / 227.6 / 591.9 mm^2.

use crate::config::presets::{budget_label, MAC_BUDGETS};
use crate::config::SharpConfig;
use crate::energy::{area_breakdown, AreaBreakdown};
use crate::report::Exhibit;
use crate::util::table::{fnum, fpct, Table};

#[derive(Debug, Clone)]
pub struct Row {
    pub macs: u64,
    pub breakdown: AreaBreakdown,
}

pub fn rows() -> Vec<Row> {
    MAC_BUDGETS
        .iter()
        .map(|&m| Row {
            macs: m,
            breakdown: area_breakdown(&SharpConfig::with_macs(m)),
        })
        .collect()
}

pub fn run() -> Exhibit {
    let rows = rows();
    let mut t = Table::new("area breakdown (shares, 32 nm)").header(&[
        "component", "1K", "4K", "16K", "64K",
    ]);
    let share = |i: usize| -> Vec<String> {
        rows.iter().map(|r| fpct(r.breakdown.shares()[i])).collect()
    };
    let labels = ["compute-unit", "SRAM buffers", "MFUs", "add-reduce/mux", "controller"];
    for (i, label) in labels.iter().enumerate() {
        let s = share(i);
        t.row(&[label.to_string(), s[0].clone(), s[1].clone(), s[2].clone(), s[3].clone()]);
    }
    t.row(&[
        "total mm^2".to_string(),
        fnum(rows[0].breakdown.total_mm2()),
        fnum(rows[1].breakdown.total_mm2()),
        fnum(rows[2].breakdown.total_mm2()),
        fnum(rows[3].breakdown.total_mm2()),
    ]);
    Exhibit {
        id: "table2",
        title: "area breakdown of SHARP configurations",
        tables: vec![t],
        notes: vec![
            format!(
                "totals {} mm^2 (paper: 101.1/133.3/227.6/591.9); budgets {}",
                rows.iter()
                    .map(|r| fnum(r.breakdown.total_mm2()))
                    .collect::<Vec<_>>()
                    .join("/"),
                MAC_BUDGETS.map(budget_label).join("/")
            ),
            "reconfiguration adds <2% to add-reduce, <0.1% to total (paper §7)".into(),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shares_shift_from_sram_to_compute() {
        let rows = rows();
        let s1 = rows[0].breakdown.shares();
        let s64 = rows[3].breakdown.shares();
        assert!(s1[1] > 0.7, "1K SRAM share {}", s1[1]);
        assert!(s64[0] > 0.7, "64K compute share {}", s64[0]);
    }

    #[test]
    fn totals_close_to_paper() {
        let paper = [101.1, 133.3, 227.6, 591.9];
        for (r, p) in rows().iter().zip(paper) {
            let err = (r.breakdown.total_mm2() - p).abs() / p;
            assert!(err < 0.10, "{}: {:.1} vs {}", r.macs, r.breakdown.total_mm2(), p);
        }
    }
}
