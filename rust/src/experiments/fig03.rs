//! Fig. 3 — BrainWave's latency and resource utilization across LSTM
//! hidden sizes: latency stays roughly flat as the model shrinks while
//! utilization collapses (the adaptability problem SHARP solves).

use crate::baselines::BrainWave;
use crate::config::LstmConfig;
use crate::report::Exhibit;
use crate::util::table::{fnum, fpct, Table};

pub const DIMS: [u64; 5] = [256, 512, 1024, 1536, 2048];

#[derive(Debug, Clone)]
pub struct Row {
    pub hidden: u64,
    pub latency_us: f64,
    pub utilization: f64,
}

pub fn rows() -> Vec<Row> {
    let bw = BrainWave::stratix10();
    DIMS.iter()
        .map(|&h| {
            let model = LstmConfig::square(h);
            Row {
                hidden: h,
                latency_us: bw.latency_s(&model) * 1e6,
                utilization: bw.utilization(&model),
            }
        })
        .collect()
}

pub fn run() -> Exhibit {
    let rows = rows();
    let mut t = Table::new("BrainWave (Stratix-10 model), T=25, batch 1")
        .header(&["hidden", "latency_us", "utilization"]);
    for r in &rows {
        t.row(&[r.hidden.to_string(), fnum(r.latency_us), fpct(r.utilization)]);
    }
    let lat_spread = rows.last().unwrap().latency_us / rows[0].latency_us;
    let util_drop = rows.last().unwrap().utilization / rows[0].utilization;
    Exhibit {
        id: "fig03",
        title: "BrainWave latency flat / utilization collapsing on small LSTMs",
        tables: vec![t],
        notes: vec![
            format!(
                "16x less work changes latency only {:.1}x (paper: 'latency remains the same')",
                lat_spread
            ),
            format!("utilization grows {util_drop:.1}x from h=256 to h=2048"),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_flat_utilization_falls() {
        let rows = rows();
        let lat_ratio = rows.last().unwrap().latency_us / rows[0].latency_us;
        assert!(lat_ratio < 2.5, "latency nearly flat, got {lat_ratio}");
        assert!(rows[0].utilization < rows.last().unwrap().utilization / 4.0);
    }
}
