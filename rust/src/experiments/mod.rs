//! Experiment generators — one module per paper exhibit (see DESIGN.md §5
//! for the index). Each produces typed rows and a rendered `Exhibit`;
//! the CLI (`sharp figure <id>`) and `benches/` both call these.

pub mod common;
pub mod fig01;
pub mod fig03;
pub mod fig04;
pub mod fig09;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod fig14;
pub mod fig15;
pub mod table2;
pub mod table4;
pub mod table6;

use crate::report::Exhibit;

/// All exhibit ids in paper order.
pub const ALL_IDS: [&str; 13] = [
    "fig01", "fig03", "fig04", "fig09", "fig10", "fig11", "fig12", "fig13",
    "fig14", "fig15", "table2", "table4", "table6",
];

/// Run one exhibit by id.
pub fn run(id: &str) -> Option<Exhibit> {
    match id {
        "fig01" => Some(fig01::run()),
        "fig03" => Some(fig03::run()),
        "fig04" => Some(fig04::run()),
        "fig09" => Some(fig09::run()),
        "fig10" => Some(fig10::run()),
        "fig11" => Some(fig11::run()),
        "fig12" => Some(fig12::run()),
        "fig13" => Some(fig13::run()),
        "fig14" => Some(fig14::run()),
        "fig15" => Some(fig15::run()),
        "table2" => Some(table2::run()),
        "table4" => Some(table4::run()),
        "table6" => Some(table6::run()),
        _ => None,
    }
}

/// Run every exhibit in paper order.
pub fn run_all() -> Vec<Exhibit> {
    ALL_IDS.iter().map(|id| run(id).unwrap()).collect()
}

#[cfg(test)]
mod tests {
    #[test]
    fn unknown_id_is_none() {
        assert!(super::run("fig99").is_none());
    }
}
