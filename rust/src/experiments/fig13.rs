//! Fig. 13 — SHARP versus the most recent GPU implementations (cuDNN and
//! GRNN on Titan V). Paper shape: 1-2 orders of magnitude across budgets;
//! at 64K MACs (equal peak throughput to Titan V) 172-625x over cuDNN and
//! 72-93x over GRNN, larger for smaller dims.

use crate::baselines::{GpuImpl, GpuModel};
use crate::config::presets::{budget_label, HIDDEN_SWEEP, MAC_BUDGETS};
use crate::config::LstmConfig;
use crate::experiments::common::sharp_tuned;
use crate::report::Exhibit;
use crate::util::table::{fnum, Table};

#[derive(Debug, Clone)]
pub struct Row {
    pub macs: u64,
    pub hidden: u64,
    pub vs_cudnn: f64,
    pub vs_grnn: f64,
}

pub fn rows() -> Vec<Row> {
    let cudnn = GpuModel::titan_v(GpuImpl::Cudnn);
    let grnn = GpuModel::titan_v(GpuImpl::Grnn);
    let mut out = Vec::new();
    for &macs in &MAC_BUDGETS {
        for &h in &HIDDEN_SWEEP {
            let model = LstmConfig::square(h);
            let sharp_s = sharp_tuned(macs, &model).time_s();
            out.push(Row {
                macs,
                hidden: h,
                vs_cudnn: cudnn.latency_s(&model) / sharp_s,
                vs_grnn: grnn.latency_s(&model) / sharp_s,
            });
        }
    }
    out
}

pub fn run() -> Exhibit {
    let rows = rows();
    let mut tables = Vec::new();
    for &macs in &MAC_BUDGETS {
        let mut t = Table::new(&format!(
            "{} MACs: SHARP speedup over GPU (T=25, batch 1)",
            budget_label(macs)
        ))
        .header(&["hidden", "vs cuDNN", "vs GRNN"]);
        for r in rows.iter().filter(|r| r.macs == macs) {
            t.row(&[r.hidden.to_string(), fnum(r.vs_cudnn), fnum(r.vs_grnn)]);
        }
        tables.push(t);
    }
    let r64: Vec<&Row> = rows.iter().filter(|r| r.macs == 65536).collect();
    let cud = (
        r64.iter().map(|r| r.vs_cudnn).fold(f64::MAX, f64::min),
        r64.iter().map(|r| r.vs_cudnn).fold(0.0, f64::max),
    );
    let grn = (
        r64.iter().map(|r| r.vs_grnn).fold(f64::MAX, f64::min),
        r64.iter().map(|r| r.vs_grnn).fold(0.0, f64::max),
    );
    Exhibit {
        id: "fig13",
        title: "SHARP vs GPU LSTM implementations",
        tables,
        notes: vec![
            format!(
                "64K (peak parity with Titan V): cuDNN {}x..{}x (paper 172-625x), GRNN {}x..{}x (paper 72-93x)",
                fnum(cud.0),
                fnum(cud.1),
                fnum(grn.0),
                fnum(grn.1)
            ),
            "speedups are largest for small hidden dims (launch/sync overheads dominate the GPU)".into(),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_of_magnitude_at_64k() {
        let rows = rows();
        for r in rows.iter().filter(|r| r.macs == 65536) {
            assert!(r.vs_cudnn > 30.0, "h={}: cudnn {}", r.hidden, r.vs_cudnn);
            assert!(r.vs_grnn > 10.0, "h={}: grnn {}", r.hidden, r.vs_grnn);
            // GRNN is the stronger baseline everywhere.
            assert!(r.vs_grnn < r.vs_cudnn, "h={}", r.hidden);
        }
    }

    #[test]
    fn speedup_shrinks_with_hidden_dim() {
        // Small models: GPU pays overhead per step; SHARP doesn't.
        use crate::config::presets::HIDDEN_SWEEP;
        let rows = rows();
        let at = |h: u64| {
            rows.iter()
                .find(|r| r.macs == 65536 && r.hidden == h)
                .unwrap()
                .vs_cudnn
        };
        let small = HIDDEN_SWEEP[0];
        let large = *HIDDEN_SWEEP.last().unwrap();
        assert!(
            at(small) > at(large),
            "{small}: {} vs {large}: {}",
            at(small),
            at(large)
        );
    }

    #[test]
    fn all_budgets_beat_gpu() {
        for r in rows() {
            assert!(r.vs_cudnn > 1.0, "macs={} h={}", r.macs, r.hidden);
        }
    }
}
