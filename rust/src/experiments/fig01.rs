//! Fig. 1 — Titan V FLOP efficiency on four sequence-processing apps
//! (cuDNN, TCUs enabled), batch 1 and batch 64.
//!
//! Paper shape: batch-1 efficiency is vanishingly small for every app;
//! batch 64 reaches "between 4% to 28% of peak".

use crate::baselines::{GpuImpl, GpuModel};
use crate::config::presets::fig1_apps;
use crate::report::Exhibit;
use crate::util::table::{fpct, Table};

/// One row of the figure: app, batch-1 and batch-64 efficiency.
#[derive(Debug, Clone)]
pub struct Row {
    pub app: String,
    pub eff_b1: f64,
    pub eff_b64: f64,
}

pub fn rows() -> Vec<Row> {
    let gpu = GpuModel::titan_v(GpuImpl::Cudnn);
    fig1_apps()
        .into_iter()
        .map(|app| {
            let eff_b1 = gpu.flop_efficiency(&app);
            let eff_b64 = gpu.flop_efficiency(&app.clone().with_batch(64));
            Row {
                app: app.name,
                eff_b1,
                eff_b64,
            }
        })
        .collect()
}

pub fn run() -> Exhibit {
    let rows = rows();
    let mut t = Table::new("Titan V FLOP efficiency (cuDNN, mixed precision)")
        .header(&["app", "batch=1", "batch=64"]);
    for r in &rows {
        t.row(&[r.app.clone(), fpct(r.eff_b1), fpct(r.eff_b64)]);
    }
    let max64 = rows.iter().map(|r| r.eff_b64).fold(0.0, f64::max);
    let min64 = rows.iter().map(|r| r.eff_b64).fold(1.0, f64::min);
    Exhibit {
        id: "fig01",
        title: "GPU under-utilization on RNN inference",
        tables: vec![t],
        notes: vec![
            "batch-1 efficiency stays under 4% for all apps (paper: 'extremely under-utilized')"
                .to_string(),
            format!(
                "batch-64 spans {}..{} (paper: 4%..28% of peak)",
                fpct(min64),
                fpct(max64)
            ),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_paper() {
        for r in rows() {
            assert!(r.eff_b1 < 0.04, "{}: b1 {}", r.app, r.eff_b1);
            assert!(r.eff_b64 > r.eff_b1 * 3.0, "{}: batching must help", r.app);
            assert!(r.eff_b64 < 0.40, "{}: b64 {}", r.app, r.eff_b64);
        }
    }

    #[test]
    fn renders_all_apps() {
        let e = run();
        assert_eq!(e.tables[0].n_rows(), 4);
    }
}
