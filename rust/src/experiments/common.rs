//! Shared machinery for the experiment generators.

use crate::config::presets::K_RECONFIG;
use crate::config::{LstmConfig, SharpConfig};
use crate::sched::ScheduleKind;
use crate::sim::{simulate, SimResult};
use crate::tile::explore_k;

/// Pick the best K (and row-group stacking) for a model at a MAC budget —
/// the controller's offline exploration (§6.2.2) — and return the tuned
/// configuration with padding reconfiguration enabled.
pub fn k_opt_config(macs: u64, model: &LstmConfig) -> SharpConfig {
    let base = SharpConfig::with_macs(macs);
    let entry = explore_k(&base, model.hidden, &K_RECONFIG, |cfg| {
        simulate(cfg, model, ScheduleKind::Unfolded).cycles
    });
    base.with_k(entry.k).with_row_groups(entry.row_groups)
}

/// Simulate SHARP at its tuned configuration (Unfolded + reconfig + K_opt).
pub fn sharp_tuned(macs: u64, model: &LstmConfig) -> SimResult {
    let cfg = k_opt_config(macs, model);
    simulate(&cfg, model, ScheduleKind::Unfolded)
}

/// Sweep label helper, e.g. "h512".
pub fn hlabel(h: u64) -> String {
    format!("h={h}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tuned_never_worse_than_base_k32() {
        for h in [128u64, 340, 512] {
            let model = LstmConfig::square(h);
            let base = simulate(
                &SharpConfig::with_macs(4096),
                &model,
                ScheduleKind::Unfolded,
            );
            let tuned = sharp_tuned(4096, &model);
            assert!(tuned.cycles <= base.cycles, "h={h}");
        }
    }
}
