//! Fig. 11 — the four schedulers compared across models and budgets,
//! normalized to Sequential. Paper shape: Unfolded always best; its edge
//! shrinks as the hidden dim grows or the MAC count drops (the MVM becomes
//! the bottleneck); Batch ~ Sequential; Intergate in between.

use crate::config::presets::{budget_label, HIDDEN_SWEEP, MAC_BUDGETS};
use crate::config::{LstmConfig, SharpConfig};
use crate::report::Exhibit;
use crate::sched::ScheduleKind;
use crate::sim::simulate;
use crate::util::table::{fnum, Table};

#[derive(Debug, Clone)]
pub struct Row {
    pub macs: u64,
    pub hidden: u64,
    /// Speedups vs Sequential in ALL order (seq, batch, intergate, unfolded).
    pub speedups: [f64; 4],
}

pub fn rows() -> Vec<Row> {
    let mut out = Vec::new();
    for &macs in &MAC_BUDGETS {
        // Paper setup for this figure: K=32 rows, all VS units column-wise.
        let cfg = SharpConfig::with_macs(macs).with_k(32);
        for &h in &HIDDEN_SWEEP {
            let model = LstmConfig::square(h);
            let base = simulate(&cfg, &model, ScheduleKind::Sequential).cycles as f64;
            let mut speedups = [0.0; 4];
            for (i, k) in ScheduleKind::ALL.iter().enumerate() {
                speedups[i] = base / simulate(&cfg, &model, *k).cycles as f64;
            }
            out.push(Row {
                macs,
                hidden: h,
                speedups,
            });
        }
    }
    out
}

pub fn run() -> Exhibit {
    let rows = rows();
    let mut tables = Vec::new();
    for &macs in &MAC_BUDGETS {
        let mut t = Table::new(&format!(
            "{} MACs: scheduler speedup vs Sequential (T=25)",
            budget_label(macs)
        ))
        .header(&["hidden", "Sequential", "Batch", "Intergate", "Unfolded"]);
        for r in rows.iter().filter(|r| r.macs == macs) {
            t.row(&[
                r.hidden.to_string(),
                fnum(r.speedups[0]),
                fnum(r.speedups[1]),
                fnum(r.speedups[2]),
                fnum(r.speedups[3]),
            ]);
        }
        tables.push(t);
    }
    let max_unfolded = rows.iter().map(|r| r.speedups[3]).fold(0.0, f64::max);
    Exhibit {
        id: "fig11",
        title: "scheduling schemes: Unfolded removes both dependencies",
        tables,
        notes: vec![
            format!("max Unfolded speedup {} (largest at small dims / many MACs)", fnum(max_unfolded)),
            "Batch tracks Sequential within a few percent (paper: 'almost similar execution')".into(),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unfolded_always_best() {
        for r in rows() {
            assert!(r.speedups[3] >= r.speedups[2]);
            assert!(r.speedups[2] >= r.speedups[1] * 0.999);
            assert!(r.speedups[1] >= 0.999); // never below Sequential
        }
    }

    #[test]
    fn benefit_diminishes_with_hidden_dim() {
        // Paper: "the benefit diminishes by increasing the LSTM dimension".
        let rows = rows();
        for &macs in &MAC_BUDGETS {
            let series: Vec<f64> = HIDDEN_SWEEP
                .iter()
                .map(|&h| {
                    rows.iter()
                        .find(|r| r.macs == macs && r.hidden == h)
                        .unwrap()
                        .speedups[3]
                })
                .collect();
            assert!(
                series.first().unwrap() >= series.last().unwrap(),
                "macs={macs}: {series:?}"
            );
        }
    }

    #[test]
    fn benefit_grows_with_macs() {
        // ...and "by reducing the number of MACs" the benefit shrinks.
        let rows = rows();
        for &h in &HIDDEN_SWEEP {
            let s1k = rows
                .iter()
                .find(|r| r.macs == 1024 && r.hidden == h)
                .unwrap()
                .speedups[3];
            let s64k = rows
                .iter()
                .find(|r| r.macs == 65536 && r.hidden == h)
                .unwrap()
                .speedups[3];
            assert!(s64k >= s1k * 0.999, "h={h}: 1K {s1k} vs 64K {s64k}");
        }
    }
}
