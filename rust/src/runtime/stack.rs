//! Typed execution of stacked multi-layer models (N-deep LSTM/GRU,
//! bidirectional, and LSTM-with-projection variants) over a compiled
//! artifact: one weight set per (layer, direction), validated at bind,
//! packed into per-layer tile panels (raw `wx`/`wh` dropped — one
//! resident copy, like [`super::LstmExecutable`]), and dispatched onto
//! the stacked kernel drivers ([`super::kernel::stack`]).
//!
//! The planner scores geometry **per layer**: layer 0's GEMMs are
//! `(D, G*H)`-shaped, deeper layers see `(H, G*H)` — or `(P, G*H)`
//! when the stack projects, `(2P, G*H)`/`(2H, G*H)` bidirectional —
//! so each layer binds the tile the cost model picks for ITS input
//! width ([`Self::layer_plans`] is what `sharp plan`/`sharp infer`
//! render as the per-layer table).
//!
//! Execution routes by [`RuntimeConfig::threads`]: depth > 1 with a
//! thread budget runs the inter-layer step pipeline
//! ([`kernel::stack_pipelined_into`]); everything else — including
//! every bidirectional stack, which cannot step-pipeline — runs the
//! sequential layer-by-layer driver. Both are bit-identical by
//! construction (`tests/stack_equivalence.rs` sweeps the claim), so
//! the route only moves wall time.

use std::cell::RefCell;
use std::rc::Rc;

use crate::error::{anyhow, bail, Result};

use super::artifact::{ArtifactStore, CompiledArtifact, ManifestEntry};
use super::kernel::stack::{
    stack_pipelined_into, stack_seq_into, CellKind, DirParams, LayerParams, StackScratch,
    StackShape,
};
use super::plan::{tuner, Dtype, ExecPlan, ModelDims};
use super::RuntimeConfig;

/// One direction's weights, as supplied to [`StackExecutable::bind`].
/// After bind the dense `wx`/`wh` live only as packed panels in the
/// scratch; `bias` (and `wp`, which the shared scalar projection
/// helper reads directly) stay raw.
#[derive(Debug, Clone, Default)]
pub struct DirWeights {
    /// Input weights `(D_l, G*H)`.
    pub wx: Vec<f32>,
    /// Recurrent weights `(H, G*H)` — full H even under projection.
    pub wh: Vec<f32>,
    /// Fused gate bias `(G*H)`.
    pub bias: Vec<f32>,
    /// Output projection `(H, P)`; empty when the stack has none.
    pub wp: Vec<f32>,
}

/// One stack layer's weights: forward, plus reverse when the entry is
/// bidirectional.
#[derive(Debug, Clone, Default)]
pub struct StackLayerWeights {
    pub fwd: DirWeights,
    pub bwd: Option<DirWeights>,
}

/// Output of one stacked execution; `Default` + `run_into` reuse
/// buffers exactly like [`super::LstmOutput`].
#[derive(Debug, Clone, Default)]
pub struct StackOutput {
    /// Final layer's per-step output `(T, B, out_w)` where
    /// `out_w = dirs * (P | H)` (bidirectional steps are
    /// `[h_fwd | h_bwd]`, both in forward time order).
    pub out: Vec<f32>,
    /// Final hidden states `(L*dirs, B, H)`, row `l*dirs + dir`.
    pub h_t: Vec<f32>,
    /// Final cell states, same layout; mirrors `h_t` for GRU kinds
    /// (uniform-interface convention).
    pub c_t: Vec<f32>,
}

/// A compiled stacked variant bound to per-layer parameter sets.
pub struct StackExecutable {
    pub entry: ManifestEntry,
    exe: Rc<CompiledArtifact>,
    kind: CellKind,
    /// Per-layer weights with `wx`/`wh` emptied at bind (panels are
    /// the resident copy); `bias`/`wp` raw.
    weights: Vec<StackLayerWeights>,
    runtime: RuntimeConfig,
    /// One plan per layer, scored against that layer's input width.
    plans: Vec<ExecPlan>,
    scratch: RefCell<StackScratch>,
}

impl StackExecutable {
    /// Bind a stacked artifact to its golden weights. Per-layer inputs
    /// follow the `wx{l}`/`wh{l}`/`b{l}` naming convention (layer
    /// index 0-based), with a `_r` suffix for the reverse direction
    /// and `wp{l}` for the projection matrix.
    pub fn from_store_goldens(store: &ArtifactStore, name: &str) -> Result<StackExecutable> {
        Self::from_store_goldens_with(store, name, RuntimeConfig::default())
    }

    /// [`from_store_goldens`] with explicit runtime knobs.
    ///
    /// [`from_store_goldens`]: StackExecutable::from_store_goldens
    pub fn from_store_goldens_with(
        store: &ArtifactStore,
        name: &str,
        cfg: RuntimeConfig,
    ) -> Result<StackExecutable> {
        let entry = store
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
            .clone();
        let find = |n: &str| -> Result<Vec<f32>> {
            let meta = entry
                .inputs
                .iter()
                .find(|i| i.name == n)
                .ok_or_else(|| anyhow!("{name}: no input '{n}'"))?;
            store.golden(meta)
        };
        let dir = |l: usize, suffix: &str| -> Result<DirWeights> {
            Ok(DirWeights {
                wx: find(&format!("wx{l}{suffix}"))?,
                wh: find(&format!("wh{l}{suffix}"))?,
                bias: find(&format!("b{l}{suffix}"))?,
                wp: if entry.proj > 0 {
                    find(&format!("wp{l}{suffix}"))?
                } else {
                    Vec::new()
                },
            })
        };
        let mut weights = Vec::with_capacity(entry.layers);
        for l in 0..entry.layers {
            weights.push(StackLayerWeights {
                fwd: dir(l, "")?,
                bwd: if entry.bidirectional {
                    Some(dir(l, "_r")?)
                } else {
                    None
                },
            });
        }
        let exe = store.executable(name)?;
        Self::bind(exe, entry, weights, cfg)
    }

    /// Bind with explicit weights (tests, benches, synthetic stacks).
    pub fn with_weights(
        store: &ArtifactStore,
        name: &str,
        weights: Vec<StackLayerWeights>,
        cfg: RuntimeConfig,
    ) -> Result<StackExecutable> {
        let entry = store
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
            .clone();
        let exe = store.executable(name)?;
        Self::bind(exe, entry, weights, cfg)
    }

    /// Common bind: validate every (layer, direction) weight set
    /// against the entry's shape, resolve one plan per layer under the
    /// config's mode, pack each direction's panels eagerly at its
    /// layer's panel width, and drop the raw `wx`/`wh`.
    fn bind(
        exe: Rc<CompiledArtifact>,
        entry: ManifestEntry,
        mut weights: Vec<StackLayerWeights>,
        runtime: RuntimeConfig,
    ) -> Result<StackExecutable> {
        let kind = CellKind::of_kind(&entry.kind);
        let g = kind.gates();
        let (h, p) = (entry.h, entry.proj);
        let dirs = if entry.bidirectional { 2 } else { 1 };
        if weights.len() != entry.layers {
            bail!(
                "{}: {} layer weight sets for a depth-{} stack",
                entry.name,
                weights.len(),
                entry.layers
            );
        }
        if p >= h && p > 0 {
            bail!("{}: projection P={p} must narrow H={h}", entry.name);
        }
        let isa = runtime.resolve_isa()?;
        let mut plans = Vec::with_capacity(entry.layers);
        let mut scratch = StackScratch::new(entry.layers, entry.bidirectional);
        for (l, lw) in weights.iter().enumerate() {
            let d_l = entry.layer_input_dim(l);
            let dims = match kind {
                CellKind::Lstm => ModelDims::lstm(d_l, h, entry.b, entry.t),
                CellKind::Gru => ModelDims::gru(d_l, h, entry.b, entry.t),
            };
            let plan = tuner::plan_for_dtype(&dims, &runtime.plan, isa, runtime.dtype);
            if lw.bwd.is_some() != entry.bidirectional {
                bail!(
                    "{}: layer {l} {} reverse-direction weights",
                    entry.name,
                    if entry.bidirectional { "missing" } else { "has unexpected" }
                );
            }
            for (dirn, dw) in [Some(&lw.fwd), lw.bwd.as_ref()]
                .into_iter()
                .flatten()
                .enumerate()
            {
                let tag = if dirn == 0 { "fwd" } else { "bwd" };
                if dw.wx.len() != d_l * g * h || dw.wh.len() != h * g * h || dw.bias.len() != g * h
                {
                    bail!(
                        "{}: layer {l} {tag} weight shapes do not match D_l={d_l} H={h} gates={g}",
                        entry.name
                    );
                }
                if dw.wp.len() != h * p {
                    bail!(
                        "{}: layer {l} {tag} projection is {} elements, want H*P = {}",
                        entry.name,
                        dw.wp.len(),
                        h * p
                    );
                }
                let scr = &mut scratch.scratches()[l * dirs + dirn];
                let nr = plan.geometry.nr;
                match runtime.dtype {
                    Dtype::Int8 => scr.ensure_quant(&dw.wx, &dw.wh, d_l, h, g * h, nr),
                    Dtype::F32 => scr.ensure_packed(&dw.wx, &dw.wh, d_l, h, g * h, nr),
                }
            }
            plans.push(plan);
        }
        // Panels are resident; drop the raw dense matrices.
        for lw in &mut weights {
            lw.fwd.wx = Vec::new();
            lw.fwd.wh = Vec::new();
            if let Some(bw) = &mut lw.bwd {
                bw.wx = Vec::new();
                bw.wh = Vec::new();
            }
        }
        Ok(StackExecutable {
            exe,
            kind,
            weights,
            entry,
            runtime,
            plans,
            scratch: RefCell::new(scratch),
        })
    }

    /// The compiled artifact this executable is bound to.
    pub fn artifact(&self) -> &CompiledArtifact {
        &self.exe
    }

    /// Current kernel knobs.
    pub fn runtime(&self) -> &RuntimeConfig {
        &self.runtime
    }

    /// The per-layer execution plans (layer 0 first) — what the CLI
    /// and serve metrics render as `layer{l}: <plan>` rows.
    pub fn layer_plans(&self) -> &[ExecPlan] {
        &self.plans
    }

    /// True when [`Self::run_into`] takes the inter-layer pipelined
    /// path under the current config (depth > 1, unidirectional, and a
    /// thread budget to spend on layer workers).
    pub fn pipelines(&self) -> bool {
        self.entry.layers > 1 && !self.entry.bidirectional && self.runtime.threads > 1
    }

    /// Re-resolve knobs: one plan per layer again, repacking any
    /// direction whose panel width changed. Bit-identical before/after.
    pub fn set_runtime(&mut self, cfg: RuntimeConfig) -> Result<()> {
        if cfg.dtype != self.runtime.dtype {
            // Raw dense weights were dropped at bind; no representation
            // to re-quantize from.
            bail!(
                "{}: dtype change ({} -> {}) requires rebinding",
                self.entry.name,
                self.runtime.dtype.name(),
                cfg.dtype.name()
            );
        }
        let isa = cfg.resolve_isa()?;
        let e = &self.entry;
        let g = self.kind.gates();
        let dirs = if e.bidirectional { 2 } else { 1 };
        let mut plans = Vec::with_capacity(e.layers);
        for l in 0..e.layers {
            let d_l = e.layer_input_dim(l);
            let dims = match self.kind {
                CellKind::Lstm => ModelDims::lstm(d_l, e.h, e.b, e.t),
                CellKind::Gru => ModelDims::gru(d_l, e.h, e.b, e.t),
            };
            plans.push(tuner::plan_for_dtype(&dims, &cfg.plan, isa, cfg.dtype));
        }
        let mut scratch = self.scratch.borrow_mut();
        for l in 0..e.layers {
            let d_l = e.layer_input_dim(l);
            for dirn in 0..dirs {
                let scr = &mut scratch.scratches()[l * dirs + dirn];
                let nr = plans[l].geometry.nr;
                match cfg.dtype {
                    Dtype::Int8 => scr.ensure_quant(&[], &[], d_l, e.h, g * e.h, nr),
                    Dtype::F32 => scr.repack(d_l, e.h, g * e.h, nr),
                }
            }
        }
        drop(scratch);
        self.plans = plans;
        self.runtime = cfg;
        Ok(())
    }

    /// Rows of recurrent state this stack carries: `L * dirs` rows of
    /// `(B, H)` each (the layout of `h0`/`c0` and `h_t`/`c_t`).
    pub fn state_rows(&self) -> usize {
        self.entry.layers * if self.entry.bidirectional { 2 } else { 1 }
    }

    /// Zero initial state sized for this stack.
    pub fn zero_state(&self) -> (Vec<f32>, Vec<f32>) {
        let n = self.state_rows() * self.entry.b * self.entry.h;
        (vec![0.0; n], vec![0.0; n])
    }

    /// Per-step output width of the final layer (`dirs * (P | H)`).
    pub fn out_width(&self) -> usize {
        self.entry.out_width()
    }

    fn shape(&self, steps: usize) -> StackShape {
        StackShape {
            t: steps,
            b: self.entry.b,
            d: self.entry.d,
            hid: self.entry.h,
            proj: self.entry.proj,
        }
    }

    fn layer_params(&self) -> Vec<LayerParams<'_>> {
        self.weights
            .iter()
            .zip(&self.plans)
            .map(|(lw, plan)| LayerParams {
                fwd: DirParams {
                    wx: &lw.fwd.wx,
                    wh: &lw.fwd.wh,
                    bias: &lw.fwd.bias,
                    wp: &lw.fwd.wp,
                },
                bwd: lw.bwd.as_ref().map(|bw| DirParams {
                    wx: &bw.wx,
                    wh: &bw.wh,
                    bias: &bw.bias,
                    wp: &bw.wp,
                }),
                plan: *plan,
            })
            .collect()
    }

    fn validate(&self, xs: &[f32], steps: usize, h0: &[f32], c0: &[f32]) -> Result<()> {
        let e = &self.entry;
        if !e.kind.ends_with("seq") {
            bail!("{}: stacked execution needs a seq artifact", e.name);
        }
        if steps == 0 || steps > e.t {
            bail!("{}: {steps} steps outside 1..={}", e.name, e.t);
        }
        let state = self.state_rows() * e.b * e.h;
        if xs.len() != steps * e.b * e.d || h0.len() != state || c0.len() != state {
            bail!(
                "{}: bad input sizes xs={} (want {}) h0={} c0={} (want {state})",
                e.name,
                xs.len(),
                steps * e.b * e.d,
                h0.len(),
                c0.len()
            );
        }
        Ok(())
    }

    fn execute(&self, xs: &[f32], steps: usize, h0: &[f32], c0: &[f32], out: &mut StackOutput) {
        let layers = self.layer_params();
        let shape = self.shape(steps);
        let mut scr = self.scratch.borrow_mut();
        if self.pipelines() {
            stack_pipelined_into(
                self.kind,
                xs,
                h0,
                c0,
                &layers,
                shape,
                self.runtime.threads,
                &mut scr,
                &mut out.out,
                &mut out.h_t,
                &mut out.c_t,
            );
        } else {
            stack_seq_into(
                self.kind,
                xs,
                h0,
                c0,
                &layers,
                shape,
                self.runtime.threads,
                &mut scr,
                &mut out.out,
                &mut out.h_t,
                &mut out.c_t,
            );
        }
    }

    /// Run the full sequence. `xs` is `(T, B, D)`; `h0`/`c0` are
    /// `(L*dirs, B, H)` (GRU kinds ignore `c0`; the returned `c_t`
    /// mirrors `h_t`). Routes per [`Self::pipelines`].
    pub fn run(&self, xs: &[f32], h0: &[f32], c0: &[f32]) -> Result<StackOutput> {
        let mut out = StackOutput::default();
        self.run_into(xs, h0, c0, &mut out)?;
        Ok(out)
    }

    /// [`run`] into caller-reused buffers — the allocation-free entry.
    ///
    /// [`run`]: StackExecutable::run
    pub fn run_into(
        &self,
        xs: &[f32],
        h0: &[f32],
        c0: &[f32],
        out: &mut StackOutput,
    ) -> Result<()> {
        self.validate(xs, self.entry.t, h0, c0)?;
        self.execute(xs, self.entry.t, h0, c0, out);
        Ok(())
    }

    /// Force the sequential layer-by-layer path (the oracle/baseline),
    /// regardless of the thread budget.
    pub fn run_sequential_into(
        &self,
        xs: &[f32],
        h0: &[f32],
        c0: &[f32],
        out: &mut StackOutput,
    ) -> Result<()> {
        self.validate(xs, self.entry.t, h0, c0)?;
        let layers = self.layer_params();
        let shape = self.shape(self.entry.t);
        let mut scr = self.scratch.borrow_mut();
        stack_seq_into(
            self.kind,
            xs,
            h0,
            c0,
            &layers,
            shape,
            self.runtime.threads,
            &mut scr,
            &mut out.out,
            &mut out.h_t,
            &mut out.c_t,
        );
        Ok(())
    }

    /// Force the inter-layer pipelined path (errors on bidirectional
    /// stacks, which cannot step-pipeline).
    pub fn run_pipelined_into(
        &self,
        xs: &[f32],
        h0: &[f32],
        c0: &[f32],
        out: &mut StackOutput,
    ) -> Result<()> {
        if self.entry.bidirectional {
            bail!(
                "{}: bidirectional stacks cannot step-pipeline (reverse direction \
                 consumes reversed time)",
                self.entry.name
            );
        }
        self.validate(xs, self.entry.t, h0, c0)?;
        let layers = self.layer_params();
        let shape = self.shape(self.entry.t);
        let mut scr = self.scratch.borrow_mut();
        stack_pipelined_into(
            self.kind,
            xs,
            h0,
            c0,
            &layers,
            shape,
            self.runtime.threads.max(self.entry.layers),
            &mut scr,
            &mut out.out,
            &mut out.h_t,
            &mut out.c_t,
        );
        Ok(())
    }

    /// Run only the first `steps` frames with explicit initial state —
    /// the streaming-chunk primitive, stopping EXACTLY at `steps` so a
    /// session's per-layer carries persist bit-exactly across chunks.
    /// Bidirectional stacks cannot stream (the reverse direction needs
    /// the whole sequence before its first step).
    pub fn run_prefix_into(
        &self,
        xs: &[f32],
        steps: usize,
        h0: &[f32],
        c0: &[f32],
        out: &mut StackOutput,
    ) -> Result<()> {
        if self.entry.bidirectional {
            bail!(
                "{}: bidirectional stacks cannot stream chunked prefixes",
                self.entry.name
            );
        }
        self.validate(xs, steps, h0, c0)?;
        self.execute(xs, steps, h0, c0, out);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::exec;
    use crate::runtime::literal::{assert_bits_eq, write_f32_file};
    use crate::util::rng::Rng;
    use std::path::PathBuf;

    /// On-disk store with one 2-layer LSTM stack (goldens for layer 0
    /// and 1) plus a 3-layer GRU stack entry bound via with_weights.
    fn synth_store(tag: &str) -> (PathBuf, ArtifactStore) {
        let dir = std::env::temp_dir().join(format!("sharp_stack_unit_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = r#"{"version":1,"gate_order":"ifgo","artifacts":[
          {"name":"stack2_h3_t4_b2","kind":"seq","hlo":"m.hlo.txt","T":4,"B":2,"D":2,"H":3,
           "layers":2,
           "inputs":[{"name":"wx0","shape":[2,12],"file":"wx0.f32"},
                     {"name":"wh0","shape":[3,12],"file":"wh0.f32"},
                     {"name":"b0","shape":[12],"file":"b0.f32"},
                     {"name":"wx1","shape":[3,12],"file":"wx1.f32"},
                     {"name":"wh1","shape":[3,12],"file":"wh1.f32"},
                     {"name":"b1","shape":[12],"file":"b1.f32"}],
           "outputs":[]},
          {"name":"gstack3_h3_t4_b1","kind":"gru_seq","hlo":"m.hlo.txt","T":4,"B":1,"D":2,
           "H":3,"layers":3,"inputs":[],"outputs":[]}]}"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        std::fs::write(dir.join("m.hlo.txt"), "HloModule stack_unit\n").unwrap();
        let mut rng = Rng::new(31337);
        for (name, len) in [
            ("wx0", 2 * 12),
            ("wh0", 3 * 12),
            ("b0", 12),
            ("wx1", 3 * 12),
            ("wh1", 3 * 12),
            ("b1", 12),
        ] {
            let v = rng.vec_f32(len, -0.3, 0.3);
            write_f32_file(&dir.join(format!("{name}.f32")), &v).unwrap();
        }
        let store = ArtifactStore::open(&dir).unwrap();
        (dir, store)
    }

    #[test]
    fn golden_bound_stack_matches_scalar_composition() {
        let (_dir, store) = synth_store("goldens");
        let exe = StackExecutable::from_store_goldens(&store, "stack2_h3_t4_b2").unwrap();
        assert_eq!(exe.layer_plans().len(), 2);
        assert_eq!(exe.state_rows(), 2);
        assert!(!exe.pipelines(), "threads=1 routes sequentially");
        let e = &exe.entry;
        let (t, b, d, h) = (e.t, e.b, e.d, e.h);
        let mut rng = Rng::new(99);
        let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
        let (h0, c0) = exe.zero_state();
        let out = exe.run(&xs, &h0, &c0).unwrap();

        let find = |n: &str| {
            let meta = e.inputs.iter().find(|i| i.name == n).unwrap();
            store.golden(meta).unwrap()
        };
        let z = vec![0.0f32; b * h];
        let (hs0, h0t, c0t) = exec::lstm_seq(
            &xs,
            &z,
            &z,
            &find("wx0"),
            &find("wh0"),
            &find("b0"),
            t,
            b,
            d,
            h,
        );
        let (hs1, h1t, c1t) = exec::lstm_seq(
            &hs0,
            &z,
            &z,
            &find("wx1"),
            &find("wh1"),
            &find("b1"),
            t,
            b,
            h,
            h,
        );
        assert_bits_eq(&out.out, &hs1, "stack out");
        assert_bits_eq(&out.h_t[..b * h], &h0t, "layer0 h_t");
        assert_bits_eq(&out.h_t[b * h..], &h1t, "layer1 h_t");
        assert_bits_eq(&out.c_t[..b * h], &c0t, "layer0 c_t");
        assert_bits_eq(&out.c_t[b * h..], &c1t, "layer1 c_t");
    }

    #[test]
    fn pipelined_route_matches_sequential_and_chunked_carry() {
        let (_dir, store) = synth_store("routes");
        let mut exe = StackExecutable::from_store_goldens(&store, "stack2_h3_t4_b2").unwrap();
        let e = exe.entry.clone();
        let mut rng = Rng::new(7);
        let xs = rng.vec_f32(e.t * e.b * e.d, -1.0, 1.0);
        let (h0, c0) = exe.zero_state();
        let mut seq = StackOutput::default();
        exe.run_sequential_into(&xs, &h0, &c0, &mut seq).unwrap();

        exe.set_runtime(RuntimeConfig {
            threads: 4,
            ..RuntimeConfig::default()
        })
        .unwrap();
        assert!(exe.pipelines());
        let piped = exe.run(&xs, &h0, &c0).unwrap();
        assert_bits_eq(&piped.out, &seq.out, "pipelined out");
        assert_bits_eq(&piped.h_t, &seq.h_t, "pipelined h_t");
        assert_bits_eq(&piped.c_t, &seq.c_t, "pipelined c_t");

        // Streaming: 2+2 chunks with per-layer carries threaded through
        // equal the one-shot run bit-for-bit.
        let row = e.b * e.d;
        let mut a = StackOutput::default();
        exe.run_prefix_into(&xs[..2 * row], 2, &h0, &c0, &mut a).unwrap();
        let mut bo = StackOutput::default();
        exe.run_prefix_into(&xs[2 * row..], 2, &a.h_t, &a.c_t, &mut bo).unwrap();
        assert_bits_eq(&bo.h_t, &piped.h_t, "chunked h_t");
        assert_bits_eq(&bo.c_t, &piped.c_t, "chunked c_t");
        assert_bits_eq(&bo.out, &piped.out[2 * e.b * exe.out_width()..], "chunk 2 out");
    }

    #[test]
    fn int8_stack_tracks_f32_and_rejects_dtype_flips() {
        let (_dir, store) = synth_store("int8");
        let f32_exe = StackExecutable::from_store_goldens(&store, "stack2_h3_t4_b2").unwrap();
        let mut exe = StackExecutable::from_store_goldens_with(
            &store,
            "stack2_h3_t4_b2",
            RuntimeConfig {
                dtype: Dtype::Int8,
                ..RuntimeConfig::default()
            },
        )
        .unwrap();
        for plan in exe.layer_plans() {
            assert_eq!(plan.geometry.dtype, Dtype::Int8);
        }
        let e = exe.entry.clone();
        let mut rng = Rng::new(23);
        let xs = rng.vec_f32(e.t * e.b * e.d, -1.0, 1.0);
        let (h0, c0) = exe.zero_state();
        let oracle = f32_exe.run(&xs, &h0, &c0).unwrap();
        let got = exe.run(&xs, &h0, &c0).unwrap();
        // Depth-2 composition: the layer-1 error compounds through
        // layer 2, so the budget here is loose; the pinned budget lives
        // in tests/quant_conformance.rs.
        for (g, o) in got.out.iter().zip(&oracle.out) {
            assert!((g - o).abs() < 0.1, "int8 stack {g} vs f32 {o}");
        }

        // The pipelined route must carry the identical int8 bits.
        exe.set_runtime(RuntimeConfig {
            threads: 4,
            dtype: Dtype::Int8,
            ..RuntimeConfig::default()
        })
        .unwrap();
        assert!(exe.pipelines());
        let piped = exe.run(&xs, &h0, &c0).unwrap();
        assert_bits_eq(&piped.out, &got.out, "int8 pipelined out");
        assert_bits_eq(&piped.h_t, &got.h_t, "int8 pipelined h_t");
        assert_bits_eq(&piped.c_t, &got.c_t, "int8 pipelined c_t");

        let err = exe.set_runtime(RuntimeConfig::default()).unwrap_err();
        assert!(err.to_string().contains("requires rebinding"), "{err}");
    }

    #[test]
    fn gru_stack_with_weights_runs_and_mirrors_cell_state() {
        let (_dir, store) = synth_store("gru");
        let mut rng = Rng::new(5);
        let (d, h, g) = (2usize, 3usize, 3usize);
        let weights: Vec<StackLayerWeights> = (0..3)
            .map(|l| {
                let d_l = if l == 0 { d } else { h };
                StackLayerWeights {
                    fwd: DirWeights {
                        wx: rng.vec_f32(d_l * g * h, -0.3, 0.3),
                        wh: rng.vec_f32(h * g * h, -0.3, 0.3),
                        bias: rng.vec_f32(g * h, -0.2, 0.2),
                        wp: Vec::new(),
                    },
                    bwd: None,
                }
            })
            .collect();
        let exe = StackExecutable::with_weights(
            &store,
            "gstack3_h3_t4_b1",
            weights,
            RuntimeConfig::default(),
        )
        .unwrap();
        let e = &exe.entry;
        let xs = rng.vec_f32(e.t * e.b * e.d, -1.0, 1.0);
        let (h0, c0) = exe.zero_state();
        let out = exe.run(&xs, &h0, &c0).unwrap();
        assert_eq!(out.out.len(), e.t * e.b * h);
        assert_bits_eq(&out.c_t, &out.h_t, "GRU c_t mirrors h_t");
    }

    #[test]
    fn bind_validates_layer_shapes_and_variants() {
        let (_dir, store) = synth_store("validate");
        let mk = |wx_len: usize| {
            vec![
                StackLayerWeights {
                    fwd: DirWeights {
                        wx: vec![0.0; wx_len],
                        wh: vec![0.0; 36],
                        bias: vec![0.0; 12],
                        wp: Vec::new(),
                    },
                    bwd: None,
                },
                StackLayerWeights {
                    fwd: DirWeights {
                        wx: vec![0.0; 36],
                        wh: vec![0.0; 36],
                        bias: vec![0.0; 12],
                        wp: Vec::new(),
                    },
                    bwd: None,
                },
            ]
        };
        let cfg = RuntimeConfig::default;
        assert!(
            StackExecutable::with_weights(&store, "stack2_h3_t4_b2", mk(24), cfg()).is_ok()
        );
        // Layer 0 wx must be D*G*H = 2*4*3 = 24.
        let err = StackExecutable::with_weights(&store, "stack2_h3_t4_b2", mk(23), cfg())
            .unwrap_err();
        assert!(format!("{err:#}").contains("layer 0"), "{err:#}");
        // Wrong layer count.
        let two = mk(24);
        let err =
            StackExecutable::with_weights(&store, "stack2_h3_t4_b2", two[..1].to_vec(), cfg())
                .unwrap_err();
        assert!(format!("{err:#}").contains("depth-2"), "{err:#}");
        // Unexpected reverse weights on a unidirectional entry.
        let mut bad = mk(24);
        bad[0].bwd = Some(bad[0].fwd.clone());
        let err = StackExecutable::with_weights(&store, "stack2_h3_t4_b2", bad, cfg())
            .unwrap_err();
        assert!(format!("{err:#}").contains("reverse"), "{err:#}");
    }

    #[test]
    fn layer_plans_score_per_layer_widths() {
        // Layer 0 (D=2) and layer 1 (D=3) get independently scored
        // plans; both exist and describe() renders.
        let (_dir, store) = synth_store("plans");
        let exe = StackExecutable::from_store_goldens(&store, "stack2_h3_t4_b2").unwrap();
        let descs: Vec<String> = exe.layer_plans().iter().map(|p| p.describe()).collect();
        assert_eq!(descs.len(), 2);
        assert!(descs.iter().all(|s| !s.is_empty()));
    }
}
