//! The execution planner: the runtime analogue of the paper's dynamic
//! reconfiguration controller (§6.2). Where PR 3's kernel layer ran one
//! fixed operating point (MR=4, NR=16, a hard-coded thread gate), this
//! module makes the kernel geometry **data**: an [`ExecPlan`] carries
//! the register-tile shape, the thread-gate threshold, and the sequence
//! schedule, and a tuner ([`tuner`]) chooses it per bound model from the
//! same tile cost arithmetic the cycle simulator uses
//! ([`crate::tile::geometry::mvm_cost_fixed`] — one cost model, two
//! consumers).
//!
//! Every candidate the tuner can emit is bit-identical to the scalar
//! oracle: tiling stays M/N-only (each output element's k-loop runs
//! ascending inside one micro-kernel call) and both schedules issue the
//! per-gate accumulations in the oracle's order (bias, then x
//! contributions k = 0..D, then h contributions k = 0..H). Planning
//! therefore only ever changes wall time, never a single output bit —
//! `tests/kernel_equivalence.rs` sweeps the whole candidate space to
//! enforce it.

pub mod cost;
pub mod tuner;

use crate::error::{bail, Result};
use crate::runtime::artifact::ManifestEntry;

pub use crate::runtime::kernel::simd::Isa;

/// Capacity bound on micro-kernel rows: the accumulator block is sized
/// `[[f32; NR_MAX]; MR_MAX]` at most, and monomorphized fast paths exist
/// for every candidate `mr` up to this. A *bound*, not an operating
/// point — the tile actually run is [`KernelGeometry::mr`].
pub const MR_MAX: usize = 8;
/// Capacity bound on micro-kernel columns (packed-panel width). See
/// [`MR_MAX`]; the tile actually run is [`KernelGeometry::nr`].
pub const NR_MAX: usize = 32;

/// The numeric format the GEMM weight path runs in — the planner's
/// precision dimension (ROADMAP item 2 / paper §9: SHARP's energy story
/// leans on narrow weights). Unlike [`Isa`], this is NOT
/// output-identical across variants: `Int8` trades a bounded output
/// error (documented in DESIGN.md §12, enforced by
/// `tests/quant_conformance.rs`) for ~4x less weight-load traffic.
/// Within one dtype every kernel path (scalar/SIMD, solo/fused,
/// sequential/pipelined) remains bit-identical: i32 accumulation is
/// exact and the dequant epilogue is per-element deterministic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Dtype {
    /// Dense f32 weights — the reference path, bit-exact vs the scalar
    /// oracle.
    #[default]
    F32,
    /// Per-gate symmetric int8 weights with i32 accumulation and a
    /// fused dequant epilogue; activations quantized per row on the fly.
    Int8,
}

impl Dtype {
    /// Stable lowercase name (CLI/JSON vocabulary).
    pub fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::Int8 => "int8",
        }
    }

    /// Parse the [`Dtype::name`] vocabulary (case-insensitive).
    pub fn parse(s: &str) -> Result<Dtype> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "fp32" => Ok(Dtype::F32),
            "int8" | "i8" => Ok(Dtype::Int8),
            other => bail!("unknown dtype '{other}' (expected f32|int8)"),
        }
    }

    /// Weight bytes per element: the factor the cost model discounts
    /// weight-panel load traffic by ([`cost`]).
    pub fn weight_bytes(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::Int8 => 1,
        }
    }
}

/// Default work gate for row-parallel GEMM fan-out: a thread must have
/// at least this many FLOPs (2·M·K·N split across threads) to be worth a
/// scoped spawn. 2^22 ≈ 4 MFLOP ≈ a few hundred microseconds of scalar
/// work against tens of microseconds of spawn+join overhead — so the
/// crossover sits where the spawn cost is ≲10% of the work. Exposed as a
/// [`KernelGeometry`] field (planner/`RuntimeConfig` knob) instead of
/// the magic constant it used to be.
pub const DEFAULT_MIN_FLOPS_PER_THREAD: usize = 1 << 22;

/// The register-tile shape and threading gate one GEMM runs with.
///
/// `mr x nr` is the accumulator block the micro-kernel keeps live:
/// each packed `b` element is reused `mr` times and each `a` element
/// `nr` times per k-step. Raising either improves register reuse until
/// the block spills the register file — the trade the cost model
/// ([`cost`]) scores per model shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelGeometry {
    /// Micro-kernel rows (1..=[`MR_MAX`]).
    pub mr: usize,
    /// Micro-kernel columns / packed-panel width (1..=[`NR_MAX`]).
    pub nr: usize,
    /// The vector ISA the micro-kernel dispatches to — the planner's
    /// vector-width dimension ([`Isa::lanes`] f32 per op). Constructors
    /// are deterministic and start at [`Isa::Scalar`]; the *resolved*
    /// ISA (detection / `SHARP_FORCE_KERNEL` /
    /// [`crate::runtime::RuntimeConfig::force_kernel`]) is stamped by
    /// the tuner at plan time. Every ISA is bit-identical to scalar
    /// (see [`crate::runtime::kernel::simd`]), so this field only ever
    /// moves wall time.
    pub isa: Isa,
    /// The weight-path numeric format this geometry's kernels run in.
    /// Constructors default to [`Dtype::F32`]; the quantized executables
    /// stamp [`Dtype::Int8`] via [`Self::with_dtype`] before planning,
    /// so the cost model can discount int8 weight-load traffic.
    pub dtype: Dtype,
    /// Minimum FLOPs of GEMM work per thread before the row-parallel
    /// path fans out (see [`DEFAULT_MIN_FLOPS_PER_THREAD`]).
    pub min_flops_per_thread: usize,
}

impl KernelGeometry {
    /// Validated construction: the kernel layer clamps defensively, but
    /// planners and CLI parsing should reject out-of-range tiles loudly.
    /// ISA-neutral (scalar) so construction never depends on the host;
    /// planners stamp the resolved ISA with [`Self::with_isa`].
    pub fn new(mr: usize, nr: usize) -> Result<KernelGeometry> {
        if mr == 0 || mr > MR_MAX || nr == 0 || nr > NR_MAX {
            bail!("kernel geometry {mr}x{nr} outside 1..={MR_MAX} x 1..={NR_MAX}");
        }
        Ok(KernelGeometry {
            mr,
            nr,
            isa: Isa::Scalar,
            dtype: Dtype::F32,
            min_flops_per_thread: DEFAULT_MIN_FLOPS_PER_THREAD,
        })
    }

    /// Same tile, dispatched to `isa`'s micro-kernels.
    pub fn with_isa(mut self, isa: Isa) -> KernelGeometry {
        self.isa = isa;
        self
    }

    /// Same tile, run on `dtype`'s weight path.
    pub fn with_dtype(mut self, dtype: Dtype) -> KernelGeometry {
        self.dtype = dtype;
        self
    }

    /// The PR 3 fixed operating point (MR=4, NR=16, scalar) — kept as
    /// the `PlanMode::Fixed` default and as the bench baseline the
    /// planner must never lose to.
    pub fn fixed_default() -> KernelGeometry {
        KernelGeometry {
            mr: 4,
            nr: 16,
            isa: Isa::Scalar,
            dtype: Dtype::F32,
            min_flops_per_thread: DEFAULT_MIN_FLOPS_PER_THREAD,
        }
    }
}

impl Default for KernelGeometry {
    fn default() -> Self {
        KernelGeometry::fixed_default()
    }
}

/// How the sequence loop is issued. Both schedules are bit-identical to
/// the scalar oracle and to each other (same per-dot accumulation
/// order); they differ in GEMM granularity and scratch footprint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// Paper §5: hoist the whole input projection out of the recurrence
    /// — `xs (T*B, D) @ Wx` as ONE GEMM into a `(T*B, G*H)` buffer, then
    /// only the small recurrent MVM per step. Best amortization when
    /// `T*B` is large.
    Unfolded,
    /// One step at a time: `x_t (B, D) @ Wx` per step into a `(B, G*H)`
    /// buffer. Same cost when T=1 (a cell artifact or a single streaming
    /// frame) but skips the unfolded projection buffer entirely — the
    /// schedule streaming chunks and cell artifacts want.
    Stepwise,
}

impl Schedule {
    pub fn name(&self) -> &'static str {
        match self {
            Schedule::Unfolded => "unfolded",
            Schedule::Stepwise => "stepwise",
        }
    }
}

/// The executable-level decision the planner hands the kernel layer:
/// which register tile, which thread gate, which schedule. Carried by
/// every [`crate::runtime::LstmExecutable`]; all candidates are
/// output-identical, so swapping plans is always safe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecPlan {
    pub geometry: KernelGeometry,
    pub schedule: Schedule,
}

impl ExecPlan {
    /// The PR 3 behavior: fixed MR=4/NR=16 under the unfolded schedule.
    pub fn fixed_default() -> ExecPlan {
        ExecPlan {
            geometry: KernelGeometry::fixed_default(),
            schedule: Schedule::Unfolded,
        }
    }

    /// Same plan with the schedule swapped (used by the T=1 / streaming
    /// override in the executable).
    pub fn with_schedule(mut self, schedule: Schedule) -> ExecPlan {
        self.schedule = schedule;
        self
    }

    /// Compact human-readable form for metrics/CLI:
    /// `mr4/nr16/unfolded@avx2/f32`. The ISA and dtype suffixes are the
    /// dispatch actually planned, rendered TOGETHER, so the
    /// coordinator's per-bucket plan metrics and `sharp plan` snapshots
    /// can tell a forced-scalar int8 run from a SIMD int8 run.
    pub fn describe(&self) -> String {
        format!(
            "mr{}/nr{}/{}@{}/{}",
            self.geometry.mr,
            self.geometry.nr,
            self.schedule.name(),
            self.geometry.isa.name(),
            self.geometry.dtype.name()
        )
    }
}

impl Default for ExecPlan {
    fn default() -> Self {
        ExecPlan::fixed_default()
    }
}

/// How an executable obtains its plan ([`crate::runtime::RuntimeConfig`]
/// knob, CLI `--plan auto|calibrated|fixed[:MRxNR]`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlanMode {
    /// Pin one geometry (schedule still follows the model's T). The PR 3
    /// operating point is `Fixed(KernelGeometry::fixed_default())`.
    Fixed(KernelGeometry),
    /// Cost-model choice per bound model — deterministic, zero runtime
    /// probing. The default.
    #[default]
    Auto,
    /// Cost-model shortlist, then a timed warmup GEMM per finalist at
    /// bind time picks the winner on the actual hardware.
    Calibrated,
}

impl PlanMode {
    pub fn name(&self) -> &'static str {
        match self {
            PlanMode::Fixed(_) => "fixed",
            PlanMode::Auto => "auto",
            PlanMode::Calibrated => "calibrated",
        }
    }
}

/// The model-shape tuple the planner adapts to — the paper's (D, H, B, T)
/// plus the gate fan-out (4 for LSTM, 3 for GRU).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelDims {
    /// Input feature dim.
    pub d: usize,
    /// Hidden dim.
    pub h: usize,
    /// Batch lanes per executable invocation.
    pub b: usize,
    /// Sequence steps per invocation (1 for cell artifacts).
    pub t: usize,
    /// Fused gate count: the weight matrices are `(.., gates*H)`.
    pub gates: usize,
}

impl ModelDims {
    /// The planner-visible shape of a manifest entry — THE single
    /// mapping from artifact kinds to (D, H, B, T, gates), shared by
    /// the executable bind path and `sharp plan --artifact`: seq
    /// artifacts run their full T per invocation, cell artifacts one
    /// step; `gru*` kinds have 3 fused gates, LSTM kinds 4 (paper §8).
    pub fn of_entry(e: &ManifestEntry) -> ModelDims {
        ModelDims {
            d: e.d,
            h: e.h,
            b: e.b,
            t: if e.kind.ends_with("seq") { e.t } else { 1 },
            gates: if e.kind.starts_with("gru") { 3 } else { 4 },
        }
    }

    pub fn lstm(d: usize, h: usize, b: usize, t: usize) -> ModelDims {
        ModelDims { d, h, b, t, gates: 4 }
    }

    pub fn gru(d: usize, h: usize, b: usize, t: usize) -> ModelDims {
        ModelDims { d, h, b, t, gates: 3 }
    }

    /// Fused gate-matrix width `G*H` — the N of both GEMMs.
    pub fn gh(&self) -> usize {
        self.gates * self.h
    }

    /// The largest GEMM row count a schedule issues: `T*B` for the
    /// unfolded input projection, `B` stepwise. The tuner never picks
    /// `mr` above this (the "tile never exceeds the matrix" property).
    pub fn max_rows(&self, schedule: Schedule) -> usize {
        match schedule {
            Schedule::Unfolded => self.t * self.b,
            Schedule::Stepwise => self.b,
        }
        .max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_validation_bounds() {
        assert!(KernelGeometry::new(4, 16).is_ok());
        assert!(KernelGeometry::new(1, 1).is_ok());
        assert!(KernelGeometry::new(MR_MAX, NR_MAX).is_ok());
        assert!(KernelGeometry::new(0, 16).is_err());
        assert!(KernelGeometry::new(4, 0).is_err());
        assert!(KernelGeometry::new(MR_MAX + 1, 16).is_err());
        assert!(KernelGeometry::new(4, NR_MAX + 1).is_err());
    }

    #[test]
    fn describe_is_compact_and_names_isa_and_dtype() {
        // fixed_default() is deterministically scalar/f32 (constructors
        // never probe the host); the planner stamps detected ISAs and
        // the runtime's dtype.
        assert_eq!(
            ExecPlan::fixed_default().describe(),
            "mr4/nr16/unfolded@scalar/f32"
        );
        let p = ExecPlan::fixed_default().with_schedule(Schedule::Stepwise);
        assert_eq!(p.describe(), "mr4/nr16/stepwise@scalar/f32");
        let mut v = ExecPlan::fixed_default();
        v.geometry = v.geometry.with_isa(Isa::Avx2);
        assert_eq!(v.describe(), "mr4/nr16/unfolded@avx2/f32");
        // The satellite fix: dtype and ISA render TOGETHER, so a
        // forced-scalar int8 plan is distinguishable from a SIMD one.
        v.geometry = v.geometry.with_dtype(Dtype::Int8);
        assert_eq!(v.describe(), "mr4/nr16/unfolded@avx2/int8");
        v.geometry = v.geometry.with_isa(Isa::Scalar);
        assert_eq!(v.describe(), "mr4/nr16/unfolded@scalar/int8");
    }

    #[test]
    fn with_isa_changes_only_the_isa() {
        let g = KernelGeometry::new(2, 8).unwrap();
        assert_eq!(g.isa, Isa::Scalar);
        let v = g.with_isa(Isa::Neon);
        assert_eq!(v.isa, Isa::Neon);
        assert_eq!(
            (v.mr, v.nr, v.dtype, v.min_flops_per_thread),
            (g.mr, g.nr, g.dtype, g.min_flops_per_thread)
        );
    }

    #[test]
    fn with_dtype_changes_only_the_dtype() {
        let g = KernelGeometry::new(4, 16).unwrap().with_isa(Isa::Avx2);
        assert_eq!(g.dtype, Dtype::F32);
        let q = g.with_dtype(Dtype::Int8);
        assert_eq!(q.dtype, Dtype::Int8);
        assert_eq!(
            (q.mr, q.nr, q.isa, q.min_flops_per_thread),
            (g.mr, g.nr, g.isa, g.min_flops_per_thread)
        );
    }

    #[test]
    fn dtype_names_parse_and_weight_bytes() {
        assert_eq!(Dtype::F32.name(), "f32");
        assert_eq!(Dtype::Int8.name(), "int8");
        assert_eq!(Dtype::parse("f32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse(" INT8 ").unwrap(), Dtype::Int8);
        assert_eq!(Dtype::parse("i8").unwrap(), Dtype::Int8);
        assert!(Dtype::parse("fp8").is_err());
        assert_eq!(Dtype::F32.weight_bytes(), 4);
        assert_eq!(Dtype::Int8.weight_bytes(), 1);
        assert_eq!(Dtype::default(), Dtype::F32);
    }

    #[test]
    fn dims_helpers() {
        let d = ModelDims::lstm(128, 340, 4, 16);
        assert_eq!(d.gh(), 1360);
        assert_eq!(d.max_rows(Schedule::Unfolded), 64);
        assert_eq!(d.max_rows(Schedule::Stepwise), 4);
        assert_eq!(ModelDims::gru(8, 8, 1, 1).max_rows(Schedule::Unfolded), 1);
    }
}
