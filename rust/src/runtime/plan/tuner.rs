//! Candidate enumeration and selection — the runtime's version of the
//! paper's offline K-exploration (§6.2.2): enumerate a small set of tile
//! configurations for a model's (D, H, B, T), score them with the shared
//! cost arithmetic ([`super::cost`]), and hand the executable a winner.
//!
//! `Auto` is a pure function of the dims — deterministic, no probing —
//! which is what lets every worker replica derive the identical plan
//! without coordination. `Calibrated` keeps the cost model as a filter
//! (top-[`CALIB_TOP_K`] shortlist) and then times a truncated warmup
//! GEMM per finalist on the actual hardware, so machines whose register
//! file or vector width the static model underestimates still land on
//! their best tile. Either way the choice only moves wall time: every
//! candidate is bit-identical to the scalar oracle by construction.

use crate::runtime::kernel::gemm;
use crate::util::rng::Rng;

use super::cost::{score, PlanScore};
use super::{Dtype, ExecPlan, Isa, KernelGeometry, ModelDims, PlanMode, Schedule};

/// Candidate micro-kernel rows; filtered per schedule so the tile never
/// exceeds the GEMM it sweeps.
const MR_CANDIDATES: [usize; 4] = [1, 2, 4, 8];
/// Candidate panel widths; filtered to the gate-matrix width.
const NR_CANDIDATES: [usize; 4] = [4, 8, 16, 32];
/// Finalists the calibrated mode actually times.
const CALIB_TOP_K: usize = 3;

/// One scored candidate, as enumerated for a model shape.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Candidate {
    pub plan: ExecPlan,
    pub score: PlanScore,
}

/// Enumerate every plan the tuner may select for `dims` under the
/// resolved vector ISA, best first.
///
/// Ordering is total and deterministic: ascending cost, then smaller
/// scratch (which makes T=1 prefer stepwise on the cost tie), then
/// stepwise before unfolded, then smaller `mr`/`nr`. Clamping rules:
/// `mr` never exceeds the schedule's GEMM row count and `nr` never
/// exceeds the gate-matrix width `G*H` — a tile larger than the matrix
/// would be pure padding. Under a vector ISA the `nr` grid is
/// additionally clamped to lane multiples *when any fit*: a panel the
/// dispatch would run scalar (`nr = 4` under AVX2) is never chosen
/// over a vectorizable one, but a gate matrix too narrow for a single
/// vector keeps its scalar-width candidates rather than none.
pub fn enumerate(dims: &ModelDims, isa: Isa) -> Vec<Candidate> {
    enumerate_dtype(dims, isa, Dtype::F32)
}

/// [`enumerate`] on an explicit weight dtype: every candidate geometry
/// is stamped with it, so the cost model's int8 weight-load discount
/// participates in the ranking (an int8 plan may prefer a different
/// tile than its f32 twin — the load term it amortizes is 4x lighter).
pub fn enumerate_dtype(dims: &ModelDims, isa: Isa, dtype: Dtype) -> Vec<Candidate> {
    let gh = dims.gh();
    let mut nrs: Vec<usize> = NR_CANDIDATES.iter().copied().filter(|&nr| nr <= gh).collect();
    let lanes = isa.lanes();
    if lanes > 1 {
        let aligned: Vec<usize> = nrs.iter().copied().filter(|nr| nr % lanes == 0).collect();
        if !aligned.is_empty() {
            nrs = aligned;
        }
    }
    if nrs.is_empty() {
        // Gate matrix narrower than every candidate (tiny H): one panel
        // exactly as wide as the matrix.
        nrs.push(gh.min(super::NR_MAX).max(1));
    }
    let mut out = Vec::new();
    for schedule in [Schedule::Unfolded, Schedule::Stepwise] {
        let max_rows = dims.max_rows(schedule);
        for &mr in MR_CANDIDATES.iter().filter(|&&mr| mr <= max_rows.max(1)) {
            for &nr in &nrs {
                let plan = ExecPlan {
                    geometry: KernelGeometry::new(mr, nr)
                        .expect("candidate sets stay within MR_MAX/NR_MAX")
                        .with_isa(isa)
                        .with_dtype(dtype),
                    schedule,
                };
                out.push(Candidate {
                    plan,
                    score: score(&plan, dims),
                });
            }
        }
    }
    out.sort_by(|a, b| {
        let unfolded = |c: &Candidate| c.plan.schedule == Schedule::Unfolded;
        a.score
            .cost
            .total_cmp(&b.score.cost)
            .then(a.score.scratch_f32.cmp(&b.score.scratch_f32))
            .then(unfolded(a).cmp(&unfolded(b)))
            .then(a.plan.geometry.mr.cmp(&b.plan.geometry.mr))
            .then(a.plan.geometry.nr.cmp(&b.plan.geometry.nr))
    });
    out
}

/// Cost-model winner: the head of [`enumerate`]. Pure and
/// deterministic for a given (dims, isa).
pub fn plan_auto(dims: &ModelDims, isa: Isa) -> ExecPlan {
    plan_auto_dtype(dims, isa, Dtype::F32)
}

/// [`plan_auto`] on an explicit weight dtype.
pub fn plan_auto_dtype(dims: &ModelDims, isa: Isa, dtype: Dtype) -> ExecPlan {
    enumerate_dtype(dims, isa, dtype)
        .first()
        .expect("candidate set is never empty")
        .plan
}

/// Cost-model shortlist + timed warmup: times each of the top
/// [`CALIB_TOP_K`] candidates' truncated GEMMs on this machine and keeps
/// the fastest. Falls back to the auto winner on a timing tie. The
/// warmup GEMMs run under the candidates' stamped ISA, so calibration
/// times the dispatch that will actually serve.
pub fn plan_calibrated(dims: &ModelDims, isa: Isa) -> ExecPlan {
    plan_calibrated_dtype(dims, isa, Dtype::F32)
}

/// [`plan_calibrated`] on an explicit weight dtype. The warmup GEMMs
/// always time the f32 panel sweep: it shares the candidate's tile
/// geometry and memory access pattern, so it ranks the finalists the
/// same way while keeping calibration independent of the quantized
/// weights (which don't exist until bind packs them).
pub fn plan_calibrated_dtype(dims: &ModelDims, isa: Isa, dtype: Dtype) -> ExecPlan {
    let ranked = enumerate_dtype(dims, isa, dtype);
    let finalists = &ranked[..CALIB_TOP_K.min(ranked.len())];
    let mut best = finalists[0].plan;
    let mut best_s = f64::INFINITY;
    for c in finalists {
        let s = calibrate(&c.plan, dims);
        if s < best_s {
            best_s = s;
            best = c.plan;
        }
    }
    best
}

/// Resolve a [`PlanMode`] to a concrete plan for one model shape under
/// the resolved vector ISA (the executable's
/// [`crate::runtime::RuntimeConfig::resolve_isa`] decision — detection
/// or an explicit force). Fixed mode pins the register tile but still
/// schedules by shape (T=1 and cell artifacts skip the unfolded
/// projection buffer) and still dispatches to the resolved ISA: pinning
/// `mr x nr` and forcing the kernel path are independent knobs.
pub fn plan_for(dims: &ModelDims, mode: &PlanMode, isa: Isa) -> ExecPlan {
    plan_for_dtype(dims, mode, isa, Dtype::F32)
}

/// [`plan_for`] on an explicit weight dtype: like the ISA, the dtype is
/// resolved by the executable at bind and stamped over whatever tile the
/// mode picks — pinning `mr x nr` and choosing the precision are
/// independent knobs.
pub fn plan_for_dtype(dims: &ModelDims, mode: &PlanMode, isa: Isa, dtype: Dtype) -> ExecPlan {
    match mode {
        PlanMode::Fixed(geo) => ExecPlan {
            geometry: geo.with_isa(isa).with_dtype(dtype),
            schedule: if dims.t <= 1 {
                Schedule::Stepwise
            } else {
                Schedule::Unfolded
            },
        },
        PlanMode::Auto => plan_auto_dtype(dims, isa, dtype),
        PlanMode::Calibrated => plan_calibrated_dtype(dims, isa, dtype),
    }
}

/// Re-score the register tile for one fused streaming step: a window of
/// `rows` live lanes runs `(rows, D) @ Wx` and `(rows, H) @ Wh` per
/// step, so the M-side tile that was right for the solo plan (B=1 ⇒
/// `mr = 1`-shaped work) leaves register reuse on the table once the
/// fuse dispatcher batches sessions. `nr` stays pinned to `base`'s
/// packed panel width — a width change would repack the resident weight
/// panels per window, dwarfing the step — while `mr` re-scores against
/// the live occupancy with the same cost model. Deterministic and
/// O(|MR_CANDIDATES|) arithmetic per call, cheap enough to run per fuse
/// window (the controller's "cheap lookup before each layer" rule);
/// like every plan, each candidate is bit-identical, so occupancy
/// adaptation only moves wall time.
pub fn plan_batched_step(base: &ExecPlan, dims: &ModelDims, rows: usize) -> ExecPlan {
    let rows = rows.max(1);
    let step_dims = ModelDims {
        b: rows,
        t: 1,
        ..*dims
    };
    let candidate = |mr: usize| ExecPlan {
        geometry: KernelGeometry {
            mr,
            nr: base.geometry.nr,
            // The fused window keeps the solo plan's dispatch: the ISA
            // and dtype were resolved at bind and the panels it sweeps
            // are shared.
            isa: base.geometry.isa,
            dtype: base.geometry.dtype,
            min_flops_per_thread: base.geometry.min_flops_per_thread,
        },
        schedule: Schedule::Stepwise,
    };
    // mr = 1 is always admissible; strict < keeps ties on the smaller
    // mr (candidates ascend).
    let mut best = candidate(1);
    let mut best_cost = score(&best, &step_dims).cost;
    for &mr in MR_CANDIDATES.iter().filter(|&&mr| mr > 1 && mr <= rows) {
        let plan = candidate(mr);
        let cost = score(&plan, &step_dims).cost;
        if cost < best_cost {
            best = plan;
            best_cost = cost;
        }
    }
    best
}

/// Time one candidate's warmup GEMMs: the schedule's input projection
/// plus a few recurrent MVMs, on synthetic data with the contraction
/// depth truncated ([`CALIB_MAX_K`]) — K scales every candidate's time
/// by the same factor, so truncating it cuts bind-time cost without
/// reordering the ranking. Returns the best-of-[`CALIB_REPS`] seconds.
fn calibrate(plan: &ExecPlan, dims: &ModelDims) -> f64 {
    /// Contraction-depth cap for warmup GEMMs (see above).
    const CALIB_MAX_K: usize = 128;
    /// Row cap on the unfolded projection warmup.
    const CALIB_MAX_M: usize = 64;
    /// Recurrent steps sampled.
    const CALIB_MAX_T: usize = 4;
    /// Timed repetitions (after one untimed warmup); min is reported.
    const CALIB_REPS: usize = 2;

    let gh = dims.gh();
    let geo = &plan.geometry;
    let m_in = dims.max_rows(plan.schedule).min(CALIB_MAX_M);
    let k_in = dims.d.clamp(1, CALIB_MAX_K);
    let k_rec = dims.h.clamp(1, CALIB_MAX_K);
    let t_rec = dims.t.clamp(1, CALIB_MAX_T);

    let mut rng = Rng::new(0x5EED ^ ((geo.mr as u64) << 8) ^ geo.nr as u64);
    let a_in = rng.vec_f32(m_in * k_in, -1.0, 1.0);
    let a_rec = rng.vec_f32(dims.b * k_rec, -1.0, 1.0);
    let wx = rng.vec_f32(k_in * gh, -0.5, 0.5);
    let wh = rng.vec_f32(k_rec * gh, -0.5, 0.5);
    let (mut px, mut ph) = (Vec::new(), Vec::new());
    gemm::pack_b(&wx, k_in, gh, geo.nr, &mut px);
    gemm::pack_b(&wh, k_rec, gh, geo.nr, &mut ph);
    let mut out_in = vec![0.0f32; m_in * gh];
    let mut out_rec = vec![0.0f32; dims.b * gh];

    let mut pass = || {
        gemm::matmul_packed(&mut out_in, &a_in, &px, m_in, k_in, gh, geo);
        for _ in 0..t_rec {
            gemm::matmul_packed(&mut out_rec, &a_rec, &ph, dims.b, k_rec, gh, geo);
        }
        std::hint::black_box(out_rec.last());
    };
    pass(); // warmup: page in the panels, settle the frequency governor
    let mut best = f64::INFINITY;
    for _ in 0..CALIB_REPS {
        let t0 = std::time::Instant::now();
        pass();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_is_deterministic() {
        for dims in [
            ModelDims::lstm(256, 256, 4, 16),
            ModelDims::gru(80, 17, 1, 3),
            ModelDims::lstm(1, 1, 1, 1),
        ] {
            for isa in Isa::ALL {
                let first = plan_auto(&dims, isa);
                for _ in 0..4 {
                    assert_eq!(plan_auto(&dims, isa), first, "{dims:?} {isa:?}");
                }
            }
        }
    }

    #[test]
    fn candidates_carry_the_requested_isa_and_lane_aligned_panels() {
        let dims = ModelDims::lstm(256, 256, 4, 16);
        for isa in [Isa::Avx2, Isa::Neon] {
            let cands = enumerate(&dims, isa);
            assert!(!cands.is_empty());
            for c in &cands {
                assert_eq!(c.plan.geometry.isa, isa);
                assert_eq!(
                    c.plan.geometry.nr % isa.lanes(),
                    0,
                    "vector ISA must clamp nr to lane multiples: {:?}",
                    c.plan
                );
            }
        }
        // Scalar keeps the full grid, including nr = 4.
        assert!(enumerate(&dims, Isa::Scalar)
            .iter()
            .any(|c| c.plan.geometry.nr == 4));
        // Under AVX2 (8 lanes) the scalar-only nr = 4 disappears.
        assert!(!enumerate(&dims, Isa::Avx2)
            .iter()
            .any(|c| c.plan.geometry.nr == 4));
    }

    #[test]
    fn narrow_gate_matrix_keeps_scalar_widths_under_a_vector_isa() {
        // G*H = 7 fits no AVX2 lane multiple: the grid must fall back
        // to the scalar-width candidates (nr = 4), not go empty — the
        // dispatch just runs those blocks scalar, bit-identical.
        let dims = ModelDims {
            d: 5,
            h: 7,
            b: 2,
            t: 2,
            gates: 1,
        };
        let cands = enumerate(&dims, Isa::Avx2);
        assert!(!cands.is_empty());
        assert!(cands.iter().all(|c| c.plan.geometry.nr == 4));
        assert!(cands.iter().all(|c| c.plan.geometry.isa == Isa::Avx2));
    }

    #[test]
    fn chosen_tile_never_exceeds_matrix_dims() {
        let mut rng = Rng::new(0xDA7A);
        for _ in 0..200 {
            let dims = ModelDims {
                d: rng.range_usize(1, 300),
                h: rng.range_usize(1, 300),
                b: rng.range_usize(1, 8),
                t: rng.range_usize(1, 32),
                gates: if rng.range_usize(0, 1) == 0 { 4 } else { 3 },
            };
            for isa in Isa::ALL {
                for c in enumerate(&dims, isa) {
                    assert!(
                        c.plan.geometry.mr <= dims.max_rows(c.plan.schedule),
                        "{dims:?} emitted {:?}",
                        c.plan
                    );
                    assert!(c.plan.geometry.nr <= dims.gh().max(1), "{dims:?}");
                }
                let chosen = plan_auto(&dims, isa);
                assert!(chosen.geometry.mr <= dims.max_rows(chosen.schedule));
                assert!(chosen.geometry.nr <= dims.gh().max(1));
            }
        }
    }

    #[test]
    fn t1_prefers_stepwise_and_long_seqs_unfold() {
        let cell = plan_auto(&ModelDims::lstm(512, 512, 1, 1), Isa::Scalar);
        assert_eq!(cell.schedule, Schedule::Stepwise, "T=1 skips the pre buffer");
        let seq = plan_auto(&ModelDims::lstm(256, 256, 4, 16), Isa::Scalar);
        assert_eq!(seq.schedule, Schedule::Unfolded);
    }

    #[test]
    fn tiny_gate_matrix_gets_a_matching_panel() {
        // GRU with H=1: G*H = 3, below every NR candidate (and below
        // one vector of any ISA — the fallback panel must survive lane
        // clamping too).
        let dims = ModelDims::gru(5, 1, 2, 2);
        for isa in Isa::ALL {
            let cands = enumerate(&dims, isa);
            assert!(!cands.is_empty());
            assert!(cands.iter().all(|c| c.plan.geometry.nr == 3), "{isa:?}");
        }
    }

    #[test]
    fn fixed_mode_pins_geometry_but_schedules_by_shape() {
        let geo = KernelGeometry::new(2, 8).unwrap();
        let seq = plan_for(&ModelDims::lstm(64, 64, 4, 16), &PlanMode::Fixed(geo), Isa::Scalar);
        assert_eq!((seq.geometry, seq.schedule), (geo, Schedule::Unfolded));
        let cell = plan_for(&ModelDims::lstm(64, 64, 4, 1), &PlanMode::Fixed(geo), Isa::Scalar);
        assert_eq!((cell.geometry, cell.schedule), (geo, Schedule::Stepwise));
        // Fixed pins the tile, not the dispatch: the resolved ISA is
        // stamped over the pinned geometry.
        let v = plan_for(&ModelDims::lstm(64, 64, 4, 16), &PlanMode::Fixed(geo), Isa::Avx2);
        assert_eq!((v.geometry.mr, v.geometry.nr), (2, 8));
        assert_eq!(v.geometry.isa, Isa::Avx2);
    }

    #[test]
    fn batched_step_plan_adapts_mr_to_occupancy_with_nr_pinned() {
        // The solo streaming plan is shaped for B=1; a fused window of
        // 16 lanes must get a taller register tile, but NEVER a new
        // panel width (the resident packed panels are pinned).
        let dims = ModelDims::lstm(512, 512, 1, 1);
        let base = plan_auto(&dims, Isa::Scalar);
        let solo = plan_batched_step(&base, &dims, 1);
        assert_eq!(solo.geometry.mr, 1, "one lane stays single-row");
        assert_eq!(solo.geometry.nr, base.geometry.nr);
        assert_eq!(solo.schedule, Schedule::Stepwise);
        let fused = plan_batched_step(&base, &dims, 16);
        assert!(
            fused.geometry.mr > 1,
            "16 live lanes should amortize panel loads: {:?}",
            fused
        );
        assert_eq!(fused.geometry.nr, base.geometry.nr, "nr stays pinned");
        assert_eq!(
            fused.geometry.min_flops_per_thread,
            base.geometry.min_flops_per_thread
        );

        // The fused re-score inherits the solo plan's dispatch: a base
        // planned for AVX2 keeps AVX2 at every occupancy.
        let vbase = plan_auto(&dims, Isa::Avx2);
        for rows in [1, 5, 16] {
            assert_eq!(
                plan_batched_step(&vbase, &dims, rows).geometry.isa,
                Isa::Avx2,
                "rows={rows}"
            );
        }
    }

    #[test]
    fn batched_step_plan_is_deterministic_and_bounded() {
        let mut rng = Rng::new(0xF05E);
        for _ in 0..100 {
            let dims = ModelDims {
                d: rng.range_usize(1, 300),
                h: rng.range_usize(1, 300),
                b: 1,
                t: rng.range_usize(1, 32),
                gates: if rng.range_usize(0, 1) == 0 { 4 } else { 3 },
            };
            let base = plan_auto(&dims, Isa::Scalar);
            let rows = rng.range_usize(1, 80);
            let first = plan_batched_step(&base, &dims, rows);
            assert_eq!(plan_batched_step(&base, &dims, rows), first);
            assert!(first.geometry.mr <= rows.max(1), "{dims:?} rows={rows}");
            assert_eq!(first.geometry.nr, base.geometry.nr);
            assert_eq!(first.schedule, Schedule::Stepwise);
        }
        // rows = 0 is degenerate but must not panic (empty window guard
        // lives in the caller; the planner clamps to one row).
        let dims = ModelDims::lstm(8, 8, 1, 1);
        let base = plan_auto(&dims, Isa::Scalar);
        assert_eq!(plan_batched_step(&base, &dims, 0).geometry.mr, 1);
    }

    #[test]
    fn enumerate_stamps_the_requested_dtype_on_every_candidate() {
        let dims = ModelDims::lstm(256, 256, 4, 16);
        for isa in Isa::ALL {
            for dtype in [Dtype::F32, Dtype::Int8] {
                let cands = enumerate_dtype(&dims, isa, dtype);
                assert!(!cands.is_empty());
                assert!(cands.iter().all(|c| c.plan.geometry.dtype == dtype));
            }
            // The 3-arg entry points stay the f32 path.
            assert!(enumerate(&dims, isa)
                .iter()
                .all(|c| c.plan.geometry.dtype == Dtype::F32));
            assert_eq!(plan_auto(&dims, isa).geometry.dtype, Dtype::F32);
        }
    }

    #[test]
    fn fixed_mode_stamps_the_resolved_dtype_over_the_pinned_tile() {
        let geo = KernelGeometry::new(2, 8).unwrap();
        let dims = ModelDims::lstm(64, 64, 4, 16);
        let q = plan_for_dtype(&dims, &PlanMode::Fixed(geo), Isa::Scalar, Dtype::Int8);
        assert_eq!((q.geometry.mr, q.geometry.nr), (2, 8));
        assert_eq!(q.geometry.dtype, Dtype::Int8);
        assert_eq!(
            plan_for(&dims, &PlanMode::Fixed(geo), Isa::Scalar).geometry.dtype,
            Dtype::F32
        );
    }

    #[test]
    fn batched_step_plan_preserves_the_base_dtype() {
        let dims = ModelDims::lstm(512, 512, 1, 1);
        let base = plan_auto_dtype(&dims, Isa::Scalar, Dtype::Int8);
        for rows in [1, 4, 16] {
            let p = plan_batched_step(&base, &dims, rows);
            assert_eq!(p.geometry.dtype, Dtype::Int8, "rows={rows}");
            assert_eq!(p.geometry.nr, base.geometry.nr);
        }
    }

    #[test]
    fn calibrated_returns_a_shortlisted_candidate() {
        let dims = ModelDims::lstm(64, 48, 2, 4);
        let isa = Isa::detect();
        let ranked = enumerate(&dims, isa);
        let chosen = plan_calibrated(&dims, isa);
        assert!(ranked[..CALIB_TOP_K.min(ranked.len())]
            .iter()
            .any(|c| c.plan == chosen));
    }
}
