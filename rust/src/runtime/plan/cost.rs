//! Geometry scoring on the simulator's tile cost arithmetic.
//!
//! The sim answers "what block grid does a `rows x cols` tile impose on
//! an `r x c` sweep?" via [`mvm_cost_fixed`] (§6.1.1): `row_segments =
//! ceil(r/rows)` segments times `ceil(c/cols)` column passes. The
//! runtime kernel has exactly the same structure one level down — an
//! `mr x nr` register tile sweeping an `M x N` GEMM output, K steps per
//! block — so the planner reuses that arithmetic verbatim
//! (`TileGeometry { rows: mr, cols: nr }` over the output) for the
//! block-grid counts, then weighs the grid with the CPU terms silicon
//! doesn't have:
//!
//! * **FMA work** — `M*N*K`, geometry-independent: unlike the silicon
//!   tile (fixed lanes, §6.1.1 padding), the software tile *clamps* at
//!   edges (`mre = min(mr, m-row)`, ragged panels), so overhanging
//!   lanes are never issued and there is no padding charge.
//! * **load traffic** — per k-step, a block of `r_i x c_j` loads `r_i`
//!   a-elements and `c_j` b-elements for `r_i*c_j` FMAs; summed over
//!   the grid that is `ceil(M/mr)*N` b-loads plus `ceil(N/nr)*M`
//!   a-loads per k-step. Bigger tiles amortize loads — the whole reason
//!   register blocking wins.
//! * **register spill** — the accumulator block must stay in registers
//!   for the reuse to exist. Past the register-file budget every k-step
//!   round-trips through the stack; the model scales the FMA term by
//!   the overflow ratio of the *effective* block
//!   (`min(mr,M) x min(nr,N)` — a single-row GEMM never spills however
//!   large the plan's tile).
//! * **vector width** — the geometry's ISA ([`Isa::lanes`]) divides
//!   both the FMA and the b-load charge for lane-multiple panel widths
//!   (every full panel the tuner emits is covered by
//!   [`crate::runtime::kernel::simd`]'s dispatch table; see
//!   [`row_ops`] for the one ragged-tail approximation). A width the
//!   dispatch would run scalar — `nr = 4` under AVX2, a lane-unaligned
//!   ragged tail — is charged one op per element, which is what makes
//!   the tuner prefer lane-multiple panels once a vector ISA is in
//!   play. At 1 lane (scalar) every formula reduces exactly to the
//!   pre-SIMD model. `a`-loads stay scalar: each k-step broadcasts one
//!   element per block row regardless of width.
//!
//! One cost model, two consumers (sim and runtime), as the paper's
//! controller table is one table serving every model.

use crate::tile::geometry::{mvm_cost_fixed, MvmCost, TileGeometry};

use super::{Dtype, ExecPlan, Isa, KernelGeometry, ModelDims, Schedule};

/// Per-lane load overhead weight (the `1/mr + 1/nr` term). 1.0 = one
/// load costs one FMA lane — deliberately pessimistic so small tiles are
/// only chosen when the matrix truly is small.
const LOAD_WEIGHT: f64 = 1.0;

/// Weighted lane-cycles charged per GEMM *call* (loop prologue, panel
/// setup, the threading gate check). Geometry-independent, so it never
/// distorts the tile choice — it only separates the schedules: unfolded
/// issues `1 + T` calls where stepwise issues `2T`, which is exactly why
/// hoisting the input projection wins for T > 1 and ties at T = 1
/// (where the scratch tie-breaker then prefers stepwise).
const GEMM_CALL_OVERHEAD: f64 = 512.0;

/// f32 accumulator lanes that fit the register file before spilling.
/// Sized for the narrowest common target: 16 architectural vector
/// registers x 8 f32 lanes (AVX2) = 128, minus ~4 registers the kernel
/// streams `a` broadcasts and `b` panel rows through -> 96 accumulator
/// lanes. AVX-512 machines have headroom the model leaves on the table;
/// `PlanMode::Calibrated` recovers it by timing the shortlist.
const ACC_F32_BUDGET: f64 = 96.0;

/// Everything the tuner (and `sharp plan`) wants to show per candidate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanScore {
    /// Weighted lane-cycles for one full forward pass — lower is better.
    pub cost: f64,
    /// MACs per weighted op-cycle (call overhead excluded): the
    /// runtime's figure of merit. 1.0 = every modeled cycle multiplies
    /// on the scalar path; a vector ISA can push this past 1.0 (up to
    /// [`Isa::lanes`] MACs retire per vector op).
    pub utilization: f64,
    /// Pre-activation scratch the schedule needs, in f32 elements
    /// (`T*B*G*H` unfolded, `B*G*H` stepwise) — the tie-breaker that
    /// makes T=1 prefer [`Schedule::Stepwise`].
    pub scratch_f32: usize,
}

/// The sim-view sweep of one `out (M, N) += a (M, K) @ b (K, N)` under
/// a register tile: the output block grid as an [`MvmCost`], repeated K
/// times — the same arithmetic [`gemm_cost`] derives its grid counts
/// from. Tests pin the invariant that its useful lanes are exactly the
/// GEMM's MACs for every geometry.
pub fn gemm_sweep(geo: &KernelGeometry, m: usize, k: usize, n: usize) -> MvmCost {
    let tile = TileGeometry::new(geo.mr as u64, geo.nr as u64);
    mvm_cost_fixed(tile, m as u64, n as u64).scale(k as u64)
}

/// Per-k-step op count for one register-block row spanning `w` output
/// columns under an ISA with `lanes` f32 per vector: lane-multiple
/// widths issue `w / lanes` vector ops; any other width runs the
/// scalar block, one op per element. At `lanes = 1` this is `w` — the
/// pre-SIMD charge. Slight approximation: the dispatch table covers
/// 1/2/4(/8)-vector panels, so a rare odd-multiple ragged tail (24
/// columns under AVX2) is charged vector here but dispatched scalar —
/// it only ever skews the last panel's charge, never a full one, and
/// never affects bit-exactness.
fn row_ops(w: usize, lanes: usize) -> f64 {
    if lanes > 1 && w > 0 && w % lanes == 0 {
        (w / lanes) as f64
    } else {
        w as f64
    }
}

/// Ops per k-step for one output row swept panel by panel: `n / nr`
/// full panels of width `nr` plus the ragged tail — each charged at
/// its own vector-or-scalar rate. Reduces to `n` at 1 lane.
fn sweep_row_ops(n: usize, nr: usize, lanes: usize) -> f64 {
    let nr = nr.max(1);
    (n / nr) as f64 * row_ops(nr, lanes) + row_ops(n % nr, lanes)
}

/// Weighted lane-cycle cost of one GEMM under a geometry: exact FMA
/// work (spill-scaled, vector-charged per [`row_ops`]) plus load
/// traffic derived from the block grid.
pub fn gemm_cost(geo: &KernelGeometry, m: usize, k: usize, n: usize) -> f64 {
    if m == 0 || k == 0 || n == 0 {
        return 0.0;
    }
    let grid = mvm_cost_fixed(
        TileGeometry::new(geo.mr as u64, geo.nr as u64),
        m as u64,
        n as u64,
    );
    // ceil(m/mr) row blocks; cycles = row blocks x column passes.
    let row_blocks = grid.row_segments as f64;
    let col_passes = (grid.cycles / grid.row_segments.max(1)) as f64;
    let spill = ((geo.mr.min(m) * geo.nr.min(n)) as f64 / ACC_F32_BUDGET).max(1.0);
    // Vector ops per row per k-step across the panel sweep; `n` scalar
    // ops when the ISA is scalar or no panel width is lane-aligned.
    let ops_n = sweep_row_ops(n, geo.nr, geo.isa.lanes());
    let fma = m as f64 * ops_n * spill;
    // b-panel rows stream through the same vectors as the FMAs; `a`
    // broadcasts stay one scalar load per block row per k-step. The
    // b-panel IS the weight matrix on both RNN GEMMs, so its charge
    // scales with the dtype's weight bytes: int8 panels move 1/4 the
    // bytes of f32 per element (the RNNAccel bandwidth argument — the
    // whole point of the quantized path). Activation (`a`) loads stay
    // f32-charged: rows are quantized on the fly from f32 buffers.
    let wload = geo.dtype.weight_bytes() as f64 / Dtype::F32.weight_bytes() as f64;
    let loads = LOAD_WEIGHT * (wload * row_blocks * ops_n + col_passes * m as f64);
    k as f64 * (fma + loads)
}

/// Score one (geometry, schedule) pair for one model shape: the sum of
/// the schedule's weighted GEMM costs plus per-call overhead.
pub fn score(plan: &ExecPlan, dims: &ModelDims) -> PlanScore {
    let (gh, t) = (dims.gh(), dims.t.max(1));
    let geo = &plan.geometry;
    let (weighted, calls) = match plan.schedule {
        Schedule::Unfolded => {
            // One hoisted input projection + T recurrent MVMs.
            let w = gemm_cost(geo, t * dims.b, dims.d, gh)
                + t as f64 * gemm_cost(geo, dims.b, dims.h, gh);
            (w, 1 + t)
        }
        Schedule::Stepwise => {
            // T per-step input projections + T recurrent MVMs.
            let w = t as f64
                * (gemm_cost(geo, dims.b, dims.d, gh) + gemm_cost(geo, dims.b, dims.h, gh));
            (w, 2 * t)
        }
    };
    let scratch_f32 = match plan.schedule {
        Schedule::Unfolded => t * dims.b * gh,
        Schedule::Stepwise => dims.b * gh,
    };
    let macs = (t * dims.b * (dims.d + dims.h) * gh) as f64;
    PlanScore {
        cost: weighted + calls as f64 * GEMM_CALL_OVERHEAD,
        utilization: if weighted > 0.0 { macs / weighted } else { 0.0 },
        scratch_f32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(mr: usize, nr: usize, schedule: Schedule) -> ExecPlan {
        ExecPlan {
            geometry: KernelGeometry::new(mr, nr).unwrap(),
            schedule,
        }
    }

    #[test]
    fn useful_lanes_equal_true_macs_for_every_geometry() {
        // The invariant inherited from the sim: useful lane-cycles are
        // the matrix MACs, independent of tile choice.
        let (m, k, n) = (13, 21, 50);
        for mr in [1, 2, 4, 8] {
            for nr in [4, 8, 16, 32] {
                let geo = KernelGeometry::new(mr, nr).unwrap();
                let c = gemm_sweep(&geo, m, k, n);
                assert_eq!(c.useful_lane_cycles, (m * k * n) as u64, "{mr}x{nr}");
            }
        }
    }

    #[test]
    fn bigger_tiles_amortize_until_they_spill() {
        let d = ModelDims::lstm(1024, 1024, 4, 16);
        let c2 = score(&plan(2, 16, Schedule::Unfolded), &d).cost;
        let c4 = score(&plan(4, 16, Schedule::Unfolded), &d).cost;
        let c8x32 = score(&plan(8, 32, Schedule::Unfolded), &d).cost;
        assert!(c4 < c2, "mr4 amortizes loads better than mr2");
        assert!(c8x32 > c4, "8x32 = 256 accumulator lanes spills");
        let u4 = score(&plan(4, 16, Schedule::Unfolded), &d).utilization;
        let u1 = score(&plan(1, 4, Schedule::Unfolded), &d).utilization;
        assert!(u4 > u1, "bigger tiles spend more of the cost on FMAs");
    }

    #[test]
    fn single_row_gemms_are_mr_neutral_and_spill_free() {
        // The software tile clamps: on M=1 work an mr=8 plan runs the
        // same single-row blocks as mr=1 (no padded lanes, no spill), so
        // the model must score them identically — the tuner's tie-break
        // (smallest mr) then decides, not a phantom padding charge.
        let d = ModelDims::lstm(256, 256, 1, 1);
        let wide = score(&plan(8, 16, Schedule::Stepwise), &d);
        let slim = score(&plan(1, 16, Schedule::Stepwise), &d);
        assert_eq!(wide.cost, slim.cost);
        assert_eq!(wide.utilization, slim.utilization);
    }

    #[test]
    fn scalar_isa_reproduces_the_pre_simd_charges() {
        // row_ops at 1 lane is the identity, so a scalar-ISA geometry
        // must score exactly as the model did before the vector term.
        let geo = KernelGeometry::new(4, 16).unwrap();
        let (m, k, n) = (64, 256, 1024);
        let grid = mvm_cost_fixed(TileGeometry::new(4, 16), m as u64, n as u64);
        let row_blocks = grid.row_segments as f64;
        let col_passes = (grid.cycles / grid.row_segments) as f64;
        let spill = ((4.0 * 16.0) / ACC_F32_BUDGET).max(1.0);
        let expected = k as f64
            * ((m * n) as f64 * spill
                + LOAD_WEIGHT * (row_blocks * n as f64 + col_passes * m as f64));
        assert_eq!(gemm_cost(&geo, m, k, n), expected);
    }

    #[test]
    fn vector_isa_discounts_lane_aligned_widths_only() {
        let scalar = KernelGeometry::new(4, 16).unwrap();
        let avx2 = scalar.with_isa(Isa::Avx2);
        let neon = scalar.with_isa(Isa::Neon);
        // nr=16 is a lane multiple of both 8 and 4: the wider ISA is
        // cheaper, both beat scalar.
        let (m, k, n) = (64, 256, 1024);
        let cs = gemm_cost(&scalar, m, k, n);
        let c8 = gemm_cost(&avx2, m, k, n);
        let c4 = gemm_cost(&neon, m, k, n);
        assert!(c8 < c4 && c4 < cs, "c8={c8} c4={c4} cs={cs}");
        // nr=4 under AVX2 has no vector instantiation: charged scalar.
        let narrow = KernelGeometry::new(4, 4).unwrap();
        assert_eq!(
            gemm_cost(&narrow.with_isa(Isa::Avx2), m, k, n),
            gemm_cost(&narrow, m, k, n),
            "a width the dispatch runs scalar must be charged scalar"
        );
        // ...but it IS one NEON vector wide.
        assert!(gemm_cost(&narrow.with_isa(Isa::Neon), m, k, n) < gemm_cost(&narrow, m, k, n));
    }

    #[test]
    fn vector_charge_covers_the_ragged_tail_at_its_own_rate() {
        // n = 40 under nr = 16, AVX2: two vector panels of 16 plus a
        // lane-aligned tail of 8 — every column vector-charged. n = 44
        // leaves a tail of 12, which the dispatch runs scalar.
        assert_eq!(sweep_row_ops(40, 16, 8), 2.0 * 2.0 + 1.0);
        assert_eq!(sweep_row_ops(44, 16, 8), 2.0 * 2.0 + 12.0);
        // Scalar identity for arbitrary shapes.
        assert_eq!(sweep_row_ops(44, 16, 1), 44.0);
        assert_eq!(sweep_row_ops(7, 32, 1), 7.0);
    }

    #[test]
    fn int8_discounts_only_the_weight_load_term() {
        // Int8 charges the b-panel (weight) stream at 1/4 the bytes;
        // FMA work, spill, and activation loads are dtype-neutral. So
        // the exact delta between f32 and int8 cost is 3/4 of the
        // weight-load charge — pin it.
        let geo = KernelGeometry::new(4, 16).unwrap();
        let q = geo.with_dtype(Dtype::Int8);
        let (m, k, n) = (64, 256, 1024);
        let grid = mvm_cost_fixed(TileGeometry::new(4, 16), m as u64, n as u64);
        let row_blocks = grid.row_segments as f64;
        let wload_full = LOAD_WEIGHT * row_blocks * n as f64; // ops_n == n at 1 lane
        let delta = gemm_cost(&geo, m, k, n) - gemm_cost(&q, m, k, n);
        assert!(
            (delta - k as f64 * 0.75 * wload_full).abs() < 1e-6,
            "delta {delta}"
        );
        // The discount composes with the vector charge: int8 stays
        // cheaper than f32 under AVX2 too, and never more expensive.
        let v = geo.with_isa(Isa::Avx2);
        assert!(gemm_cost(&v.with_dtype(Dtype::Int8), m, k, n) < gemm_cost(&v, m, k, n));
        // Degenerate shapes still cost zero for both dtypes.
        assert_eq!(gemm_cost(&q, 0, k, n), 0.0);
    }

    #[test]
    fn unfolded_never_costs_more_than_stepwise_and_ties_at_t1() {
        // ceil(T*B/mr) <= T*ceil(B/mr): hoisting only merges edges.
        for (d, h, b, t) in [(64, 96, 3, 7), (128, 128, 1, 4), (32, 17, 2, 1)] {
            let dims = ModelDims::lstm(d, h, b, t);
            let u = score(&plan(4, 16, Schedule::Unfolded), &dims);
            let s = score(&plan(4, 16, Schedule::Stepwise), &dims);
            assert!(u.cost <= s.cost, "({d},{h},{b},{t})");
            if t == 1 {
                assert_eq!(u.cost, s.cost, "t=1 schedules tie on cost");
                assert!(s.scratch_f32 <= u.scratch_f32);
            } else {
                assert!(s.scratch_f32 < u.scratch_f32, "stepwise buffer is 1/T");
            }
        }
    }
}
