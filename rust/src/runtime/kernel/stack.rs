//! Stacked multi-layer RNN drivers: the sequential layer-by-layer
//! reference and the inter-layer **step pipeline** — SHARP's scheduling
//! thesis applied across layers. A depth-L stack has a true dependence
//! only along each layer's own recurrence; layer l+1's step t needs
//! layer l's step t, NOT layer l's step t+1. The pipelined driver
//! exploits exactly that: one thread per layer, layer l+1 consuming
//! step t while layer l computes step t+1, so steady state keeps L
//! lanes busy and the wall clock drops from `L*T` step-slots toward
//! `T + L - 1` (fill + steady state + drain — the same fill/drain
//! arithmetic `sim::pipeline::stack_pipeline_estimate` predicts).
//!
//! ```text
//!   sequential (oracle)            pipelined (threads >= L)
//!   step:  1 2 3 4 .. T            step:  1 2 3 4 .. T
//!   L0     ############            L0     ####
//!   L1                 ##########  L1      ####        <- lags 1 step
//!   L2                       ####  L2       ####       <- lags 2 steps
//! ```
//!
//! Layer boundaries are SPSC step-queues built from two bounded
//! channels each: a *data* channel carrying filled `(B, W)` slabs
//! downstream and a *free* channel recycling them upstream — a ring of
//! two slabs per boundary (double buffering), so the warm path moves
//! zero heap allocations per step and the producer can run at most two
//! steps ahead (bounded skew, bounded memory).
//!
//! Bit-exactness is by construction, not by tolerance: both drivers
//! run the SAME per-layer kernels ([`rnn::lstm_seq_into`] /
//! [`rnn::gru_seq_into`] — the pipelined driver calls them with T=1
//! under the stepwise schedule, which is literally the scalar
//! reference's issue order) and the SAME projection helper
//! ([`exec::project`], row-independent), and pipelining reorders only
//! *which layer runs when*, never any dot product's k-order. The
//! equivalence sweep in `tests/stack_equivalence.rs` enforces it
//! across depth, kind, direction, projection, threading, and ISA.
//!
//! Bidirectional stacks cannot step-pipeline — the reverse direction
//! consumes time back-to-front, so a layer's output at step t depends
//! on its input at EVERY step — and are routed through the sequential
//! driver unconditionally (documented in DESIGN.md §10).

// Driver entry points mirror the kernel calling convention (tensors +
// shape dims + knobs) — same clippy waiver as `runtime::exec` and
// `kernel::rnn`.
#![allow(clippy::too_many_arguments)]

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};

use super::rnn;
use super::scratch::{self, ExecScratch};
use crate::runtime::exec;
use crate::runtime::plan::{ExecPlan, Schedule};

/// Which recurrent cell a stack runs (every layer shares the kind).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellKind {
    Lstm,
    Gru,
}

impl CellKind {
    /// Map a manifest `kind` string ("seq", "cell", "gru_seq", ...) to
    /// the cell family, mirroring `ModelDims::of_entry`'s convention.
    pub fn of_kind(kind: &str) -> CellKind {
        if kind.starts_with("gru") {
            CellKind::Gru
        } else {
            CellKind::Lstm
        }
    }

    /// Fused gate count: 4 ("ifgo") for LSTM, 3 ("rzn") for GRU.
    pub fn gates(self) -> usize {
        match self {
            CellKind::Lstm => 4,
            CellKind::Gru => 3,
        }
    }
}

/// Borrowed weights of one direction of one stack layer. An executable
/// that packed its panels eagerly may pass empty `wx`/`wh` (the scratch
/// pack latch ignores them); `wp` stays dense because the projection
/// runs through the shared scalar helper.
#[derive(Clone, Copy)]
pub struct DirParams<'a> {
    /// Input weights `(D_l, G*H)`.
    pub wx: &'a [f32],
    /// Recurrent weights `(H, G*H)` — always full H, even under
    /// projection (the projection narrows the *output*, not the
    /// recurrence).
    pub wh: &'a [f32],
    /// Fused gate bias `(G*H)`.
    pub bias: &'a [f32],
    /// Output projection `(H, P)`; empty = no projection.
    pub wp: &'a [f32],
}

/// One layer of a stack: forward direction, the reverse direction when
/// bidirectional, and the geometry the planner scored for THIS layer's
/// `(D_l, G*H)` GEMMs.
#[derive(Clone, Copy)]
pub struct LayerParams<'a> {
    pub fwd: DirParams<'a>,
    pub bwd: Option<DirParams<'a>>,
    pub plan: ExecPlan,
}

/// Stack-invariant shape: every layer shares `H` (and `P`); only layer
/// 0's input width differs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StackShape {
    pub t: usize,
    pub b: usize,
    /// Layer 0 input width.
    pub d: usize,
    pub hid: usize,
    /// Output projection width; 0 = none.
    pub proj: usize,
}

impl StackShape {
    /// Per-direction output width of a layer: `P` when projecting,
    /// else `H`.
    pub fn dir_width(&self) -> usize {
        if self.proj > 0 {
            self.proj
        } else {
            self.hid
        }
    }

    /// Full per-step layer output width (`dirs` = 1 or 2).
    pub fn out_width(&self, dirs: usize) -> usize {
        self.dir_width() * dirs
    }

    /// Input width seen by layer `l`.
    pub fn layer_input_dim(&self, l: usize, dirs: usize) -> usize {
        if l == 0 {
            self.d
        } else {
            self.out_width(dirs)
        }
    }
}

/// Workspace for one stack executable (or one bench/test run): one
/// [`ExecScratch`] per (layer, direction) — each bound to that weight
/// set, per the one-weight-set-per-scratch rule — plus the inter-layer
/// sequence buffers of the sequential driver, the per-layer carry /
/// step buffers of the pipelined driver, and the slab ring homes the
/// pipeline reclaims its boundary slabs into between runs. Everything
/// reuses capacity, so both drivers are allocation-free once warm.
#[derive(Debug, Default)]
pub struct StackScratch {
    /// Per-(layer, direction) kernel workspace, layer-major:
    /// `dir[l * dirs + dirn]`.
    dir: Vec<ExecScratch>,
    /// Sequential driver: alternating layer-output sequence buffers.
    io_a: Vec<f32>,
    io_b: Vec<f32>,
    /// Sequential driver: time-reversed input staging (bwd direction).
    rev: Vec<f32>,
    /// Sequential driver: one direction's raw `(T, B, H)` output.
    hs: Vec<f32>,
    /// Sequential driver: projected `(T*B, P)` output.
    proj_buf: Vec<f32>,
    /// Sequential driver: per-call final-state staging `(B, H)`.
    h_row: Vec<f32>,
    c_row: Vec<f32>,
    /// Pipelined driver, per layer: recurrent carries and step outputs.
    carry_h: Vec<Vec<f32>>,
    carry_c: Vec<Vec<f32>>,
    step_hs: Vec<Vec<f32>>,
    step_h: Vec<Vec<f32>>,
    step_c: Vec<Vec<f32>>,
    step_proj: Vec<Vec<f32>>,
    /// Pipelined driver: reclaimed boundary slabs (2 per boundary),
    /// owned by the producer layer's index.
    slab_homes: Vec<Vec<Vec<f32>>>,
}

impl StackScratch {
    pub fn new(layers: usize, bidirectional: bool) -> StackScratch {
        let dirs = if bidirectional { 2 } else { 1 };
        StackScratch {
            dir: (0..layers * dirs).map(|_| ExecScratch::new()).collect(),
            carry_h: vec![Vec::new(); layers],
            carry_c: vec![Vec::new(); layers],
            step_hs: vec![Vec::new(); layers],
            step_h: vec![Vec::new(); layers],
            step_c: vec![Vec::new(); layers],
            step_proj: vec![Vec::new(); layers],
            slab_homes: vec![Vec::new(); layers],
            ..StackScratch::default()
        }
    }

    /// The per-(layer, direction) kernel workspaces, layer-major — the
    /// seam an executable uses to pack panels eagerly at bind time and
    /// repack on a plan change.
    pub fn scratches(&mut self) -> &mut [ExecScratch] {
        &mut self.dir
    }
}

/// Dispatch one direction of one layer to the cell-matched sequence
/// kernel. For GRU the cell buffer mirrors the hidden state (the
/// repo-wide uniform-interface convention) and is never read back.
fn run_dir_seq(
    kind: CellKind,
    xs: &[f32],
    h0: &[f32],
    c0: &[f32],
    p: DirParams<'_>,
    t: usize,
    b: usize,
    d: usize,
    hid: usize,
    plan: &ExecPlan,
    threads: usize,
    scr: &mut ExecScratch,
    hs: &mut Vec<f32>,
    h_t: &mut Vec<f32>,
    c_t: &mut Vec<f32>,
) {
    match kind {
        CellKind::Lstm => rnn::lstm_seq_into(
            xs, h0, c0, p.wx, p.wh, p.bias, t, b, d, hid, plan, threads, scr, hs, h_t, c_t,
        ),
        CellKind::Gru => {
            rnn::gru_seq_into(
                xs, h0, p.wx, p.wh, p.bias, t, b, d, hid, plan, threads, scr, hs, h_t,
            );
            scratch::fill_from(c_t, h_t);
        }
    }
}

/// `dst = src` with the T axis reversed (`src` is `(T, row)` flat).
fn reverse_time(dst: &mut Vec<f32>, src: &[f32], t: usize, row: usize) {
    debug_assert_eq!(src.len(), t * row);
    dst.clear();
    dst.reserve(t * row);
    for s in (0..t).rev() {
        dst.extend_from_slice(&src[s * row..(s + 1) * row]);
    }
}

/// Sequential layer-by-layer stacked forward — the stack's **oracle**
/// and the bench baseline: each layer runs one full-sequence kernel
/// call (fwd, then time-reversed bwd when bidirectional), the output
/// is optionally projected and becomes the next layer's input.
///
/// Layout contract (shared with [`stack_pipelined_into`]):
/// * `h0`/`c0` and `h_t`/`c_t` are `(L*dirs, B, H)`, row
///   `l * dirs + dirn` (fwd = 0); GRU mirrors `c` onto `h`.
/// * `out` is `(T, B, out_w)` where `out_w = dirs * (P | H)`; a
///   bidirectional layer emits `[h_fwd_t | h_bwd_t]` per step, with
///   the bwd half un-reversed back into forward time order.
pub fn stack_seq_into(
    kind: CellKind,
    xs: &[f32],
    h0: &[f32],
    c0: &[f32],
    layers: &[LayerParams],
    shape: StackShape,
    threads: usize,
    scr: &mut StackScratch,
    out: &mut Vec<f32>,
    h_t: &mut Vec<f32>,
    c_t: &mut Vec<f32>,
) {
    let l_count = layers.len();
    assert!(l_count >= 1, "stack needs at least one layer");
    let dirs = if layers[0].bwd.is_some() { 2 } else { 1 };
    debug_assert!(
        layers.iter().all(|l| l.bwd.is_some() == (dirs == 2)),
        "every stack layer must agree on directionality"
    );
    let StackShape { t, b, hid, proj, .. } = shape;
    let w = shape.dir_width();
    let out_w = shape.out_width(dirs);
    debug_assert_eq!(xs.len(), t * b * shape.d);
    debug_assert_eq!(h0.len(), l_count * dirs * b * hid);
    debug_assert_eq!(c0.len(), l_count * dirs * b * hid);
    assert_eq!(scr.dir.len(), l_count * dirs, "scratch built for another stack");

    h_t.clear();
    h_t.resize(l_count * dirs * b * hid, 0.0);
    c_t.clear();
    c_t.resize(l_count * dirs * b * hid, 0.0);

    let StackScratch {
        dir,
        io_a,
        io_b,
        rev,
        hs,
        proj_buf,
        h_row,
        c_row,
        ..
    } = scr;

    for (l, lp) in layers.iter().enumerate() {
        let d_l = shape.layer_input_dim(l, dirs);
        let src: &[f32] = if l == 0 { xs } else { io_a };
        scratch::fill_zero(io_b, t * b * out_w);
        for dirn in 0..dirs {
            let p = if dirn == 0 {
                lp.fwd
            } else {
                lp.bwd.expect("dirs == 2 implies bwd params")
            };
            let srow = (l * dirs + dirn) * b * hid;
            let h0_row = &h0[srow..srow + b * hid];
            let c0_row = &c0[srow..srow + b * hid];
            let x_dir: &[f32] = if dirn == 0 {
                &src[..t * b * d_l]
            } else {
                reverse_time(rev, &src[..t * b * d_l], t, b * d_l);
                rev
            };
            run_dir_seq(
                kind,
                x_dir,
                h0_row,
                c0_row,
                p,
                t,
                b,
                d_l,
                hid,
                &lp.plan,
                threads,
                &mut dir[l * dirs + dirn],
                hs,
                h_row,
                c_row,
            );
            h_t[srow..srow + b * hid].copy_from_slice(h_row);
            c_t[srow..srow + b * hid].copy_from_slice(c_row);
            // Project all T*B rows in one call — row-independent, so
            // bit-identical to the pipelined driver's per-step calls.
            let rows: &[f32] = if proj > 0 {
                scratch::fill_zero(proj_buf, t * b * proj);
                exec::project(proj_buf, hs, p.wp, t * b, hid, proj);
                proj_buf
            } else {
                hs
            };
            if dirs == 1 && proj == 0 {
                // Unidirectional, no projection: the layer output IS
                // the kernel output.
                io_b.copy_from_slice(rows);
            } else {
                // Scatter the direction's column block, un-reversing
                // the bwd direction back into forward time order.
                for s in 0..t {
                    let ds = if dirn == 0 { s } else { t - 1 - s };
                    for bi in 0..b {
                        let from = (s * b + bi) * w;
                        let to = (ds * b + bi) * out_w + dirn * w;
                        io_b[to..to + w].copy_from_slice(&rows[from..from + w]);
                    }
                }
            }
        }
        std::mem::swap(io_a, io_b);
    }
    scratch::fill_from(out, &io_a[..t * b * out_w]);
}

/// One layer's private mutable state inside the pipelined driver.
struct Lane<'a> {
    scr: &'a mut ExecScratch,
    h: &'a mut Vec<f32>,
    c: &'a mut Vec<f32>,
    hs: &'a mut Vec<f32>,
    h_nxt: &'a mut Vec<f32>,
    c_nxt: &'a mut Vec<f32>,
    pj: &'a mut Vec<f32>,
    home: &'a mut Vec<Vec<f32>>,
}

/// The per-layer pipeline worker: recv step slab (layer 0 reads `xs`
/// directly), advance one recurrent step, forward the (projected)
/// output downstream, recycle the input slab upstream. After the last
/// step a producer reclaims its boundary's two slabs into `home` so
/// the next run reallocates nothing.
fn pipeline_worker(
    kind: CellKind,
    xs: &[f32],
    d_l: usize,
    t: usize,
    b: usize,
    hid: usize,
    proj: usize,
    w: usize,
    plan: &ExecPlan,
    params: DirParams<'_>,
    lane: Lane<'_>,
    input: Option<(Receiver<Vec<f32>>, SyncSender<Vec<f32>>)>,
    output: Option<(SyncSender<Vec<f32>>, Receiver<Vec<f32>>)>,
    mut final_out: Option<&mut [f32]>,
    threads: usize,
) {
    let Lane {
        scr,
        h,
        c,
        hs,
        h_nxt,
        c_nxt,
        pj,
        home,
    } = lane;
    for step in 0..t {
        let in_slab = input
            .as_ref()
            .map(|(rx, _)| rx.recv().expect("stack pipeline: upstream hung up"));
        let x: &[f32] = match &in_slab {
            Some(s) => s,
            None => &xs[step * b * d_l..(step + 1) * b * d_l],
        };
        run_dir_seq(
            kind, x, h, c, params, 1, b, d_l, hid, plan, threads, scr, hs, h_nxt, c_nxt,
        );
        std::mem::swap(h, h_nxt);
        std::mem::swap(c, c_nxt);
        if let (Some((_, free_tx)), Some(s)) = (&input, in_slab) {
            free_tx.send(s).expect("stack pipeline: free return");
        }
        let row: &[f32] = if proj > 0 {
            scratch::fill_zero(pj, b * proj);
            exec::project(pj, hs, params.wp, b, hid, proj);
            pj
        } else {
            hs
        };
        if let Some((data_tx, free_rx)) = &output {
            let mut slab = free_rx.recv().expect("stack pipeline: slab ring");
            slab.clear();
            slab.extend_from_slice(row);
            data_tx.send(slab).expect("stack pipeline: downstream hung up");
        } else if let Some(dst) = final_out.as_mut() {
            dst[step * b * w..(step + 1) * b * w].copy_from_slice(row);
        }
    }
    if let Some((_, free_rx)) = &output {
        // Both ring slabs eventually return on the free channel (the
        // consumer frees every slab it receives); park them for reuse.
        for _ in 0..2 {
            home.push(free_rx.recv().expect("stack pipeline: slab reclaim"));
        }
    }
}

/// Inter-layer pipelined stacked forward: one scoped thread per layer,
/// layer l+1 consuming step t while layer l computes step t+1. Each
/// worker calls the sequence kernel with T=1 under the stepwise
/// schedule — the scalar reference's own issue order — so the result is
/// bit-identical to [`stack_seq_into`] for the same inputs. `threads`
/// is the total budget: L goes to layer workers, the remainder
/// (`threads / L`, min 1) to each worker's inner GEMM row-parallelism.
///
/// Unidirectional only — a bidirectional layer needs its whole input
/// sequence before step 0 of the reverse direction, which is exactly
/// the dependence the step pipeline assumes away. Callers route
/// bidirectional stacks through [`stack_seq_into`].
pub fn stack_pipelined_into(
    kind: CellKind,
    xs: &[f32],
    h0: &[f32],
    c0: &[f32],
    layers: &[LayerParams],
    shape: StackShape,
    threads: usize,
    scr: &mut StackScratch,
    out: &mut Vec<f32>,
    h_t: &mut Vec<f32>,
    c_t: &mut Vec<f32>,
) {
    let l_count = layers.len();
    assert!(l_count >= 1, "stack needs at least one layer");
    assert!(
        layers.iter().all(|l| l.bwd.is_none()),
        "bidirectional stacks cannot step-pipeline; use stack_seq_into"
    );
    let StackShape { t, b, hid, proj, .. } = shape;
    let w = shape.dir_width();
    debug_assert_eq!(xs.len(), t * b * shape.d);
    debug_assert_eq!(h0.len(), l_count * b * hid);
    debug_assert_eq!(c0.len(), l_count * b * hid);
    assert_eq!(scr.dir.len(), l_count, "scratch built for another stack");
    let inner = (threads / l_count).max(1);

    out.clear();
    out.resize(t * b * w, 0.0);
    h_t.clear();
    h_t.resize(l_count * b * hid, 0.0);
    c_t.clear();
    c_t.resize(l_count * b * hid, 0.0);

    let StackScratch {
        dir,
        carry_h,
        carry_c,
        step_hs,
        step_h,
        step_c,
        step_proj,
        slab_homes,
        ..
    } = scr;

    for l in 0..l_count {
        scratch::fill_from(&mut carry_h[l], &h0[l * b * hid..(l + 1) * b * hid]);
        scratch::fill_from(&mut carry_c[l], &c0[l * b * hid..(l + 1) * b * hid]);
    }

    let mut lanes: Vec<Lane> = dir
        .iter_mut()
        .zip(carry_h.iter_mut())
        .zip(carry_c.iter_mut())
        .zip(step_hs.iter_mut())
        .zip(step_h.iter_mut())
        .zip(step_c.iter_mut())
        .zip(step_proj.iter_mut())
        .zip(slab_homes.iter_mut())
        .map(|(((((((scr, h), c), hs), h_nxt), c_nxt), pj), home)| Lane {
            scr,
            h,
            c,
            hs,
            h_nxt,
            c_nxt,
            pj,
            home,
        })
        .collect();

    // Boundary step-queues: data downstream + free upstream, two slabs
    // per ring, preloaded from the producer's reclaim home.
    type Ep = (Receiver<Vec<f32>>, SyncSender<Vec<f32>>);
    type OutEp = (SyncSender<Vec<f32>>, Receiver<Vec<f32>>);
    let mut in_ep: Vec<Option<Ep>> = (0..l_count).map(|_| None).collect();
    let mut out_ep: Vec<Option<OutEp>> = (0..l_count).map(|_| None).collect();
    for bi in 0..l_count.saturating_sub(1) {
        let (data_tx, data_rx) = sync_channel::<Vec<f32>>(2);
        let (free_tx, free_rx) = sync_channel::<Vec<f32>>(2);
        for _ in 0..2 {
            let mut slab = lanes[bi].home.pop().unwrap_or_default();
            slab.clear();
            slab.resize(b * w, 0.0);
            free_tx.send(slab).expect("slab preload");
        }
        out_ep[bi] = Some((data_tx, free_rx));
        in_ep[bi + 1] = Some((data_rx, free_tx));
    }

    std::thread::scope(|s| {
        let mut final_out = Some(&mut out[..]);
        for (l, (lane, lp)) in lanes.drain(..).zip(layers).enumerate() {
            let d_l = shape.layer_input_dim(l, 1);
            let input = in_ep[l].take();
            let output = out_ep[l].take();
            let dst = if l == l_count - 1 {
                final_out.take()
            } else {
                None
            };
            let plan = lp.plan.with_schedule(Schedule::Stepwise);
            let params = lp.fwd;
            s.spawn(move || {
                pipeline_worker(
                    kind, xs, d_l, t, b, hid, proj, w, &plan, params, lane, input, output, dst,
                    inner,
                );
            });
        }
    });

    for l in 0..l_count {
        h_t[l * b * hid..(l + 1) * b * hid].copy_from_slice(&carry_h[l]);
        c_t[l * b * hid..(l + 1) * b * hid].copy_from_slice(&carry_c[l]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::literal::assert_bits_eq;
    use crate::util::rng::Rng;

    fn dir_weights(rng: &mut Rng, d: usize, hid: usize, g: usize, p: usize) -> Vec<Vec<f32>> {
        vec![
            rng.vec_f32(d * g * hid, -0.3, 0.3),
            rng.vec_f32(hid * g * hid, -0.3, 0.3),
            rng.vec_f32(g * hid, -0.2, 0.2),
            rng.vec_f32(hid * p, -0.3, 0.3),
        ]
    }

    fn params(w: &[Vec<f32>]) -> DirParams<'_> {
        DirParams {
            wx: &w[0],
            wh: &w[1],
            bias: &w[2],
            wp: &w[3],
        }
    }

    #[test]
    fn seq_stack_matches_manual_layer_composition() {
        // L=2 unidirectional LSTM: the driver must equal two chained
        // scalar-oracle lstm_seq calls bit-for-bit.
        let (t, b, d, hid) = (5usize, 2usize, 6usize, 9usize);
        let mut rng = Rng::new(404);
        let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
        let h0 = rng.vec_f32(2 * b * hid, -1.0, 1.0);
        let c0 = rng.vec_f32(2 * b * hid, -1.0, 1.0);
        let w0 = dir_weights(&mut rng, d, hid, 4, 0);
        let w1 = dir_weights(&mut rng, hid, hid, 4, 0);

        let (hs0, h0_t, c0_t) = exec::lstm_seq(
            &xs,
            &h0[..b * hid],
            &c0[..b * hid],
            &w0[0],
            &w0[1],
            &w0[2],
            t,
            b,
            d,
            hid,
        );
        let (hs1, h1_t, c1_t) = exec::lstm_seq(
            &hs0,
            &h0[b * hid..],
            &c0[b * hid..],
            &w1[0],
            &w1[1],
            &w1[2],
            t,
            b,
            hid,
            hid,
        );

        let plan = ExecPlan::fixed_default();
        let layers = [
            LayerParams {
                fwd: params(&w0),
                bwd: None,
                plan,
            },
            LayerParams {
                fwd: params(&w1),
                bwd: None,
                plan,
            },
        ];
        let shape = StackShape {
            t,
            b,
            d,
            hid,
            proj: 0,
        };
        let mut scr = StackScratch::new(2, false);
        let (mut out, mut h_t, mut c_t) = (Vec::new(), Vec::new(), Vec::new());
        stack_seq_into(
            CellKind::Lstm,
            &xs,
            &h0,
            &c0,
            &layers,
            shape,
            1,
            &mut scr,
            &mut out,
            &mut h_t,
            &mut c_t,
        );
        assert_bits_eq(&out, &hs1, "stack out");
        assert_bits_eq(&h_t[..b * hid], &h0_t, "layer0 h_t");
        assert_bits_eq(&h_t[b * hid..], &h1_t, "layer1 h_t");
        assert_bits_eq(&c_t[..b * hid], &c0_t, "layer0 c_t");
        assert_bits_eq(&c_t[b * hid..], &c1_t, "layer1 c_t");
    }

    #[test]
    fn pipelined_matches_sequential_bitwise() {
        // L=3 LSTM + GRU, several thread budgets: the pipeline reorders
        // scheduling only, never bits. Runs twice per config to cover
        // the warm path (reclaimed slab ring, latched packs).
        let (t, b, d, hid) = (7usize, 3usize, 5usize, 8usize);
        let mut rng = Rng::new(1717);
        for kind in [CellKind::Lstm, CellKind::Gru] {
            let g = kind.gates();
            let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
            let h0 = rng.vec_f32(3 * b * hid, -1.0, 1.0);
            let c0 = match kind {
                CellKind::Lstm => rng.vec_f32(3 * b * hid, -1.0, 1.0),
                CellKind::Gru => h0.clone(),
            };
            let ws: Vec<Vec<Vec<f32>>> = (0..3)
                .map(|l| {
                    let d_l = if l == 0 { d } else { hid };
                    dir_weights(&mut rng, d_l, hid, g, 0)
                })
                .collect();
            let layers: Vec<LayerParams> = ws
                .iter()
                .map(|w| LayerParams {
                    fwd: params(w),
                    bwd: None,
                    plan: ExecPlan::fixed_default(),
                })
                .collect();
            let shape = StackShape {
                t,
                b,
                d,
                hid,
                proj: 0,
            };
            let mut scr = StackScratch::new(3, false);
            let (mut want, mut want_h, mut want_c) = (Vec::new(), Vec::new(), Vec::new());
            stack_seq_into(
                kind, &xs, &h0, &c0, &layers, shape, 1, &mut scr, &mut want, &mut want_h,
                &mut want_c,
            );
            for threads in [1usize, 3, 6] {
                let mut pscr = StackScratch::new(3, false);
                let (mut out, mut h_t, mut c_t) = (Vec::new(), Vec::new(), Vec::new());
                for round in 0..2 {
                    stack_pipelined_into(
                        kind, &xs, &h0, &c0, &layers, shape, threads, &mut pscr, &mut out,
                        &mut h_t, &mut c_t,
                    );
                    let ctx = format!("{kind:?} threads={threads} round={round}");
                    assert_bits_eq(&out, &want, &format!("{ctx}: out"));
                    assert_bits_eq(&h_t, &want_h, &format!("{ctx}: h_t"));
                    assert_bits_eq(&c_t, &want_c, &format!("{ctx}: c_t"));
                }
            }
        }
    }

    #[test]
    fn bidirectional_stack_matches_reversed_scalar_composition() {
        // L=1 bi LSTM: fwd on xs, bwd on reversed xs, outputs
        // concatenated per step with the bwd half back in forward time.
        let (t, b, d, hid) = (4usize, 2usize, 3usize, 5usize);
        let mut rng = Rng::new(88);
        let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
        let h0 = rng.vec_f32(2 * b * hid, -1.0, 1.0);
        let c0 = rng.vec_f32(2 * b * hid, -1.0, 1.0);
        let wf = dir_weights(&mut rng, d, hid, 4, 0);
        let wb = dir_weights(&mut rng, d, hid, 4, 0);

        let (hs_f, _, _) = exec::lstm_seq(
            &xs,
            &h0[..b * hid],
            &c0[..b * hid],
            &wf[0],
            &wf[1],
            &wf[2],
            t,
            b,
            d,
            hid,
        );
        let mut xs_rev = Vec::new();
        reverse_time(&mut xs_rev, &xs, t, b * d);
        let (hs_b, _, _) = exec::lstm_seq(
            &xs_rev,
            &h0[b * hid..],
            &c0[b * hid..],
            &wb[0],
            &wb[1],
            &wb[2],
            t,
            b,
            d,
            hid,
        );
        let mut want = vec![0.0f32; t * b * 2 * hid];
        for s in 0..t {
            for bi in 0..b {
                let dst = (s * b + bi) * 2 * hid;
                let f = (s * b + bi) * hid;
                let r = ((t - 1 - s) * b + bi) * hid;
                want[dst..dst + hid].copy_from_slice(&hs_f[f..f + hid]);
                want[dst + hid..dst + 2 * hid].copy_from_slice(&hs_b[r..r + hid]);
            }
        }

        let layers = [LayerParams {
            fwd: params(&wf),
            bwd: Some(params(&wb)),
            plan: ExecPlan::fixed_default(),
        }];
        let shape = StackShape {
            t,
            b,
            d,
            hid,
            proj: 0,
        };
        let mut scr = StackScratch::new(1, true);
        let (mut out, mut h_t, mut c_t) = (Vec::new(), Vec::new(), Vec::new());
        stack_seq_into(
            CellKind::Lstm,
            &xs,
            &h0,
            &c0,
            &layers,
            shape,
            1,
            &mut scr,
            &mut out,
            &mut h_t,
            &mut c_t,
        );
        assert_bits_eq(&out, &want, "bi concat output");
    }

    #[test]
    fn projected_stack_narrows_interlayer_width() {
        // L=2 LSTMP: layer 1 consumes layer 0's (B, P) projection; the
        // result must equal the manual project-then-feed composition.
        let (t, b, d, hid, p) = (3usize, 2usize, 4usize, 6usize, 2usize);
        let mut rng = Rng::new(5150);
        let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
        let h0 = vec![0.0f32; 2 * b * hid];
        let c0 = vec![0.0f32; 2 * b * hid];
        let w0 = dir_weights(&mut rng, d, hid, 4, p);
        let w1 = dir_weights(&mut rng, p, hid, 4, p);

        let (hs0, _, _) = exec::lstm_seq(
            &xs,
            &h0[..b * hid],
            &c0[..b * hid],
            &w0[0],
            &w0[1],
            &w0[2],
            t,
            b,
            d,
            hid,
        );
        let mut r0 = vec![0.0f32; t * b * p];
        exec::project(&mut r0, &hs0, &w0[3], t * b, hid, p);
        let (hs1, _, _) = exec::lstm_seq(
            &r0,
            &h0[b * hid..],
            &c0[b * hid..],
            &w1[0],
            &w1[1],
            &w1[2],
            t,
            b,
            p,
            hid,
        );
        let mut want = vec![0.0f32; t * b * p];
        exec::project(&mut want, &hs1, &w1[3], t * b, hid, p);

        let plan = ExecPlan::fixed_default();
        let layers = [
            LayerParams {
                fwd: params(&w0),
                bwd: None,
                plan,
            },
            LayerParams {
                fwd: params(&w1),
                bwd: None,
                plan,
            },
        ];
        let shape = StackShape {
            t,
            b,
            d,
            hid,
            proj: p,
        };
        let mut scr = StackScratch::new(2, false);
        let (mut out, mut h_t, mut c_t) = (Vec::new(), Vec::new(), Vec::new());
        stack_seq_into(
            CellKind::Lstm,
            &xs,
            &h0,
            &c0,
            &layers,
            shape,
            1,
            &mut scr,
            &mut out,
            &mut h_t,
            &mut c_t,
        );
        assert_bits_eq(&out, &want, "projected stack output");

        // And the pipelined path agrees bit-for-bit.
        let mut pscr = StackScratch::new(2, false);
        let (mut pout, mut ph, mut pc) = (Vec::new(), Vec::new(), Vec::new());
        stack_pipelined_into(
            CellKind::Lstm,
            &xs,
            &h0,
            &c0,
            &layers,
            shape,
            2,
            &mut pscr,
            &mut pout,
            &mut ph,
            &mut pc,
        );
        assert_bits_eq(&pout, &out, "pipelined projected out");
        assert_bits_eq(&ph, &h_t, "pipelined projected h_t");
    }
}
