//! Per-executable kernel workspace: packed weight panels, the
//! pre-activation buffer, and double-buffered recurrent state.
//!
//! One `ExecScratch` binds to ONE weight set (the executable that owns
//! it): the packed `wx`/`wh` panels are built on first use — at the
//! panel width of the executable's [`crate::runtime::plan::ExecPlan`] —
//! and reused for every subsequent request and timestep. Callers
//! driving the kernel free functions directly (benches, tests) must
//! give each weight set its own scratch: the pack guard is a one-shot
//! latch on the weight *content*, though the panel **width** may change
//! later ([`ExecScratch::repack`] re-derives the panels in place when a
//! re-plan picks a different `nr` after the dense weights were
//! dropped).
//!
//! Every buffer is grown with `clear` + `extend`/`resize`, so once an
//! executable has served one request of its (fixed) shape, the
//! steady-state path performs **zero heap allocations per request**:
//! capacity is retained and only lengths change.

use super::gemm;
use crate::runtime::exec;

/// Reusable workspace owned by one executable (or one bench/test run).
#[derive(Debug, Default)]
pub struct ExecScratch {
    /// `wx (D, G*H)` packed into `packed_nr`-column panels (one-shot).
    pub(super) packed_wx: Vec<f32>,
    /// `wh (H, G*H)` packed into `packed_nr`-column panels (one-shot).
    pub(super) packed_wh: Vec<f32>,
    /// One-shot pack latch (see the module doc's one-weight-set rule).
    pub(super) packed: bool,
    /// Panel width the resident panels were packed at.
    pub(super) packed_nr: usize,
    /// Pre-activations: `(T*B, G*H)` under the unfolded schedule,
    /// `(B, G*H)` stepwise.
    pub(super) pre: Vec<f32>,
    /// GRU hidden-half pre-activations for one step: `(B, G*H)`.
    pub(super) hpre: Vec<f32>,
    /// Double-buffered hidden state, `(B, H)` each.
    pub(super) state_a: Vec<f32>,
    pub(super) state_b: Vec<f32>,
    /// Double-buffered cell state (LSTM only), `(B, H)` each.
    pub(super) cell_a: Vec<f32>,
    pub(super) cell_b: Vec<f32>,
}

impl ExecScratch {
    pub fn new() -> ExecScratch {
        ExecScratch::default()
    }

    /// Pack the weight panels on first use at width `nr`; afterwards a
    /// content no-op (one-shot latch), but a *width* change repacks in
    /// place from the resident panels (the raw arguments are ignored
    /// then — an executable that dropped its dense weights passes
    /// `&[]`). Public so an executable can pack eagerly at bind time and
    /// then DROP its raw dense weights — the panels become the only
    /// resident copy, halving steady-state weight memory; the kernel
    /// entry points still accept the raw matrices so standalone callers
    /// (benches, tests) self-pack on first call.
    pub fn ensure_packed(
        &mut self,
        wx: &[f32],
        wh: &[f32],
        d: usize,
        hid: usize,
        gh: usize,
        nr: usize,
    ) {
        if !self.packed {
            gemm::pack_b(wx, d, gh, nr, &mut self.packed_wx);
            gemm::pack_b(wh, hid, gh, nr, &mut self.packed_wh);
            self.packed = true;
            self.packed_nr = nr;
        } else if self.packed_nr != nr {
            self.repack(d, hid, gh, nr);
        }
    }

    /// Re-derive the resident panels at a new width (geometry change
    /// after bind): unpack with the recorded width, re-pack with the new
    /// one. Runs at plan/config time, never on the request hot path; a
    /// no-op when unpacked or already at `nr`.
    pub fn repack(&mut self, d: usize, hid: usize, gh: usize, nr: usize) {
        if !self.packed || self.packed_nr == nr {
            return;
        }
        let mut dense = Vec::new();
        gemm::unpack_b(&self.packed_wx, d, gh, self.packed_nr, &mut dense);
        gemm::pack_b(&dense, d, gh, nr, &mut self.packed_wx);
        gemm::unpack_b(&self.packed_wh, hid, gh, self.packed_nr, &mut dense);
        gemm::pack_b(&dense, hid, gh, nr, &mut self.packed_wh);
        self.packed_nr = nr;
    }
}

/// `buf = bias` broadcast over `rows` rows (zeros when `bias` is empty),
/// reusing the buffer's capacity. Delegates to the ORACLE's
/// [`exec::broadcast_bias`] so the accumulation base — the first term of
/// the "bias, then x, then h" bit-exactness contract — has exactly one
/// definition, like `assert_bits_eq` has for the comparison side.
pub(super) fn fill_bias(buf: &mut Vec<f32>, bias: &[f32], rows: usize, width: usize) {
    buf.clear();
    buf.resize(rows * width, 0.0);
    exec::broadcast_bias(buf, bias, rows, width);
}

/// `buf = src` (length included), reusing capacity.
pub(super) fn fill_from(buf: &mut Vec<f32>, src: &[f32]) {
    buf.clear();
    buf.extend_from_slice(src);
}

/// `buf = [0.0; len]`, reusing capacity.
pub(super) fn fill_zero(buf: &mut Vec<f32>, len: usize) {
    buf.clear();
    buf.resize(len, 0.0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn repack_changes_width_without_raw_weights() {
        let (d, hid, gh) = (5usize, 7usize, 12usize);
        let mut rng = Rng::new(21);
        let wx = rng.vec_f32(d * gh, -1.0, 1.0);
        let wh = rng.vec_f32(hid * gh, -1.0, 1.0);
        let mut scr = ExecScratch::new();
        scr.ensure_packed(&wx, &wh, d, hid, gh, 16);
        let mut want_8 = Vec::new();
        gemm::pack_b(&wx, d, gh, 8, &mut want_8);
        // Width change with EMPTY raw args: must repack from residents.
        scr.ensure_packed(&[], &[], d, hid, gh, 8);
        assert_eq!(scr.packed_wx, want_8);
        assert_eq!(scr.packed_nr, 8);
        // Round-trip back to the original width restores the panels.
        let mut want_16 = Vec::new();
        gemm::pack_b(&wh, hid, gh, 16, &mut want_16);
        scr.repack(d, hid, gh, 16);
        assert_eq!(scr.packed_wh, want_16);
        // Same-width repack is a no-op.
        scr.repack(d, hid, gh, 16);
        assert_eq!(scr.packed_wh, want_16);
    }
}
