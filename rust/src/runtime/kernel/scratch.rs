//! Per-executable kernel workspace: packed weight panels, the
//! pre-activation buffer, and double-buffered recurrent state.
//!
//! One `ExecScratch` binds to ONE weight set (the executable that owns
//! it): the packed `wx`/`wh` panels are built on first use — at the
//! panel width of the executable's [`crate::runtime::plan::ExecPlan`] —
//! and reused for every subsequent request and timestep. Callers
//! driving the kernel free functions directly (benches, tests) must
//! give each weight set its own scratch: the pack guard is a one-shot
//! latch on the weight *content*, though the panel **width** may change
//! later ([`ExecScratch::repack`] re-derives the panels in place when a
//! re-plan picks a different `nr` after the dense weights were
//! dropped).
//!
//! Every buffer is grown with `clear` + `extend`/`resize`, so once an
//! executable has served one request of its (fixed) shape, the
//! steady-state path performs **zero heap allocations per request**:
//! capacity is retained and only lengths change.

use super::gemm;
use crate::runtime::exec;
use crate::runtime::quant::{self, QuantWeights};

/// Reusable workspace owned by one executable (or one bench/test run).
#[derive(Debug, Default)]
pub struct ExecScratch {
    /// `wx (D, G*H)` packed into `packed_nr`-column panels (one-shot).
    pub(super) packed_wx: Vec<f32>,
    /// `wh (H, G*H)` packed into `packed_nr`-column panels (one-shot).
    pub(super) packed_wh: Vec<f32>,
    /// One-shot pack latch (see the module doc's one-weight-set rule).
    pub(super) packed: bool,
    /// Panel width the resident panels were packed at.
    pub(super) packed_nr: usize,
    /// Pre-activations: `(T*B, G*H)` under the unfolded schedule,
    /// `(B, G*H)` stepwise.
    pub(super) pre: Vec<f32>,
    /// GRU hidden-half pre-activations for one step: `(B, G*H)`.
    pub(super) hpre: Vec<f32>,
    /// Double-buffered hidden state, `(B, H)` each.
    pub(super) state_a: Vec<f32>,
    pub(super) state_b: Vec<f32>,
    /// Double-buffered cell state (LSTM only), `(B, H)` each.
    pub(super) cell_a: Vec<f32>,
    pub(super) cell_b: Vec<f32>,
    /// Quantized `wx` panels + per-column scales (int8 dtype only; the
    /// one-shot latch is the `Option` itself, mirroring `packed`).
    pub(super) qwx: Option<QuantWeights>,
    /// Quantized `wh` panels + per-column scales (int8 dtype only).
    pub(super) qwh: Option<QuantWeights>,
    /// Per-GEMM quantized activation rows (int8 dtype only; transient,
    /// rewritten by every quant GEMM call).
    pub(super) qa: Vec<i8>,
    /// Per-GEMM activation row scales, one per row of `qa`.
    pub(super) sa: Vec<f32>,
}

impl ExecScratch {
    pub fn new() -> ExecScratch {
        ExecScratch::default()
    }

    /// Pack the weight panels on first use at width `nr`; afterwards a
    /// content no-op (one-shot latch), but a *width* change repacks in
    /// place from the resident panels (the raw arguments are ignored
    /// then — an executable that dropped its dense weights passes
    /// `&[]`). Public so an executable can pack eagerly at bind time and
    /// then DROP its raw dense weights — the panels become the only
    /// resident copy, halving steady-state weight memory; the kernel
    /// entry points still accept the raw matrices so standalone callers
    /// (benches, tests) self-pack on first call.
    pub fn ensure_packed(
        &mut self,
        wx: &[f32],
        wh: &[f32],
        d: usize,
        hid: usize,
        gh: usize,
        nr: usize,
    ) {
        if !self.packed {
            gemm::pack_b(wx, d, gh, nr, &mut self.packed_wx);
            gemm::pack_b(wh, hid, gh, nr, &mut self.packed_wh);
            self.packed = true;
            self.packed_nr = nr;
        } else if self.packed_nr != nr {
            self.repack(d, hid, gh, nr);
        }
    }

    /// Re-derive the resident panels at a new width (geometry change
    /// after bind): unpack with the recorded width, re-pack with the new
    /// one. Runs at plan/config time, never on the request hot path; a
    /// no-op when unpacked or already at `nr`.
    pub fn repack(&mut self, d: usize, hid: usize, gh: usize, nr: usize) {
        if !self.packed || self.packed_nr == nr {
            return;
        }
        let mut dense = Vec::new();
        gemm::unpack_b(&self.packed_wx, d, gh, self.packed_nr, &mut dense);
        gemm::pack_b(&dense, d, gh, nr, &mut self.packed_wx);
        gemm::unpack_b(&self.packed_wh, hid, gh, self.packed_nr, &mut dense);
        gemm::pack_b(&dense, hid, gh, nr, &mut self.packed_wh);
        self.packed_nr = nr;
    }

    /// Int8 twin of [`ensure_packed`](Self::ensure_packed): quantize
    /// both weight matrices per gate and pack the codes at width `nr` on
    /// first use; afterwards a content no-op (the `Option` is the
    /// latch), but a width change re-packs the resident int8 panels in
    /// place ([`QuantWeights::repack`] — scales never move, so like the
    /// f32 path the dense weights can be dropped after bind). The gate
    /// count is `gh / hid`, the same split the cell update slices by.
    pub fn ensure_quant(
        &mut self,
        wx: &[f32],
        wh: &[f32],
        d: usize,
        hid: usize,
        gh: usize,
        nr: usize,
    ) {
        debug_assert!(hid > 0 && gh % hid == 0, "gate width {gh} must split by H={hid}");
        match (&mut self.qwx, &mut self.qwh) {
            (Some(qx), Some(qh)) => {
                qx.repack(nr);
                qh.repack(nr);
            }
            _ => {
                let gates = gh / hid;
                self.qwx = Some(quant::quantize_weights(wx, d, gh, gates, nr));
                self.qwh = Some(quant::quantize_weights(wh, hid, gh, gates, nr));
            }
        }
    }
}

/// Gather/scatter workspace for one fused streaming window: N live
/// sessions' chunks become one step-major batch the fused steppers
/// ([`super::rnn::lstm_steps_batched_into`]) advance together, one
/// batched GEMM pair per step instead of N solo MVMs.
///
/// Lifecycle per window: [`begin`] (reset to this window's `(D, H)`),
/// then one [`push_lane`] per session **longest chunk first** (the
/// retirement invariant: lane lengths descend, so a finished lane is
/// always a suffix and live lanes stay a contiguous prefix), then
/// [`finish`] (transpose the staged lane-major frames into the
/// step-major ragged `xs` the stepper consumes). After the run each
/// lane's carry sits in its `h`/`c` rows ([`lane_h`]/[`lane_c`]) — the
/// scatter is just reading the row back, because retired lanes' rows
/// stop being touched the step they retire.
///
/// Every buffer reuses capacity across windows, so a warmed worker's
/// fuse path allocates nothing per window.
///
/// [`begin`]: FusedBatch::begin
/// [`push_lane`]: FusedBatch::push_lane
/// [`finish`]: FusedBatch::finish
/// [`lane_h`]: FusedBatch::lane_h
/// [`lane_c`]: FusedBatch::lane_c
#[derive(Debug, Default)]
pub struct FusedBatch {
    /// Lane-major staging: each pushed lane's `(steps, D)` frames,
    /// concatenated in push order; transposed into `xs` by `finish`.
    stage: Vec<f32>,
    /// Per-lane step counts, descending (checked at push).
    pub(crate) lens: Vec<usize>,
    /// Step-major ragged input after `finish`: step `s` holds one `(D)`
    /// row for every lane with `lens[i] > s`, in lane order.
    pub(crate) xs: Vec<f32>,
    /// Lane carries `(L, H)`, updated in place by the fused stepper.
    pub(crate) h: Vec<f32>,
    pub(crate) c: Vec<f32>,
    /// Input width D of this window's lanes.
    width: usize,
    /// State width H of this window's lanes.
    hid: usize,
}

impl FusedBatch {
    pub fn new() -> FusedBatch {
        FusedBatch::default()
    }

    /// Reset for a new window of `(D, H)`-shaped lanes (capacity kept).
    pub fn begin(&mut self, d: usize, hid: usize) {
        self.width = d;
        self.hid = hid;
        self.stage.clear();
        self.lens.clear();
        self.xs.clear();
        self.h.clear();
        self.c.clear();
    }

    /// Append one lane: `steps` frames of width D plus the lane's
    /// incoming `(h, c)` carry. Lanes must arrive longest-first so that
    /// retirement shrinks the live set from the tail.
    pub fn push_lane(&mut self, frames: &[f32], steps: usize, h: &[f32], c: &[f32]) {
        assert!(steps >= 1, "fused lane needs at least one step");
        assert_eq!(frames.len(), steps * self.width, "lane frames != steps x D");
        assert_eq!(h.len(), self.hid, "lane h carry != H");
        assert_eq!(c.len(), self.hid, "lane c carry != H");
        if let Some(&prev) = self.lens.last() {
            assert!(steps <= prev, "lanes must be pushed longest-first");
        }
        self.lens.push(steps);
        self.stage.extend_from_slice(frames);
        self.h.extend_from_slice(h);
        self.c.extend_from_slice(c);
    }

    /// Lanes pushed into this window.
    pub fn lanes(&self) -> usize {
        self.lens.len()
    }

    /// Per-lane step counts (descending).
    pub fn lens(&self) -> &[usize] {
        &self.lens
    }

    /// Steps the longest lane runs (the window's step count).
    pub fn max_steps(&self) -> usize {
        self.lens.first().copied().unwrap_or(0)
    }

    /// Total lane-steps across the window (`sum(lens)`).
    pub fn total_steps(&self) -> usize {
        self.lens.iter().sum()
    }

    /// Lanes still live at `step` — a prefix count, by the
    /// descending-length invariant.
    pub fn active_lanes(&self, step: usize) -> usize {
        self.lens.iter().take_while(|&&l| l > step).count()
    }

    /// Transpose the staged lane-major frames into the step-major
    /// ragged layout: step `s` holds the `active_lanes(s)` live rows.
    pub fn finish(&mut self) {
        let d = self.width;
        self.xs.clear();
        self.xs.reserve(self.stage.len());
        for step in 0..self.max_steps() {
            let mut lane_off = 0usize;
            for &len in &self.lens {
                if len <= step {
                    // Descending lens: every later lane is retired too.
                    break;
                }
                let row = lane_off + step * d;
                self.xs.extend_from_slice(&self.stage[row..row + d]);
                lane_off += len * d;
            }
        }
    }

    /// Lane `i`'s hidden carry row (after a run: its state at its own
    /// last frame).
    pub fn lane_h(&self, lane: usize) -> &[f32] {
        &self.h[lane * self.hid..(lane + 1) * self.hid]
    }

    /// Lane `i`'s cell carry row (mirrors `lane_h` for GRU kinds).
    pub fn lane_c(&self, lane: usize) -> &[f32] {
        &self.c[lane * self.hid..(lane + 1) * self.hid]
    }
}

/// `buf = bias` broadcast over `rows` rows (zeros when `bias` is empty),
/// reusing the buffer's capacity. Delegates to the ORACLE's
/// [`exec::broadcast_bias`] so the accumulation base — the first term of
/// the "bias, then x, then h" bit-exactness contract — has exactly one
/// definition, like `assert_bits_eq` has for the comparison side.
pub(super) fn fill_bias(buf: &mut Vec<f32>, bias: &[f32], rows: usize, width: usize) {
    buf.clear();
    buf.resize(rows * width, 0.0);
    exec::broadcast_bias(buf, bias, rows, width);
}

/// `buf = src` (length included), reusing capacity.
pub(super) fn fill_from(buf: &mut Vec<f32>, src: &[f32]) {
    buf.clear();
    buf.extend_from_slice(src);
}

/// `buf = [0.0; len]`, reusing capacity.
pub(super) fn fill_zero(buf: &mut Vec<f32>, len: usize) {
    buf.clear();
    buf.resize(len, 0.0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn fused_batch_packs_step_major_with_prefix_retirement() {
        let (d, hid) = (2usize, 3usize);
        let mut b = FusedBatch::new();
        b.begin(d, hid);
        // Lane 0: 3 steps (frames 10x), lane 1: 3 steps (20x), lane 2: 1
        // step (30x) — descending lens, ties allowed.
        b.push_lane(
            &[10.0, 11.0, 12.0, 13.0, 14.0, 15.0],
            3,
            &[0.1; 3],
            &[0.2; 3],
        );
        b.push_lane(
            &[20.0, 21.0, 22.0, 23.0, 24.0, 25.0],
            3,
            &[1.1; 3],
            &[1.2; 3],
        );
        b.push_lane(&[30.0, 31.0], 1, &[2.1; 3], &[2.2; 3]);
        assert_eq!(b.lanes(), 3);
        assert_eq!(b.max_steps(), 3);
        assert_eq!(b.total_steps(), 7);
        assert_eq!(
            (b.active_lanes(0), b.active_lanes(1), b.active_lanes(2)),
            (3, 2, 2)
        );
        assert_eq!(b.active_lanes(3), 0);
        b.finish();
        // Step 0: all three lanes; steps 1..3: lanes 0 and 1 only.
        assert_eq!(
            b.xs,
            vec![
                10.0, 11.0, 20.0, 21.0, 30.0, 31.0, // step 0
                12.0, 13.0, 22.0, 23.0, // step 1 (lane 2 retired)
                14.0, 15.0, 24.0, 25.0, // step 2
            ]
        );
        assert_eq!(b.xs.len(), b.total_steps() * d);
        assert_eq!(b.lane_h(1), &[1.1; 3]);
        assert_eq!(b.lane_c(2), &[2.2; 3]);
        // begin() resets the window (capacity reuse is invisible here).
        b.begin(d, hid);
        assert_eq!(b.lanes(), 0);
        assert_eq!(b.max_steps(), 0);
    }

    #[test]
    #[should_panic]
    fn fused_batch_rejects_ascending_lanes() {
        let mut b = FusedBatch::new();
        b.begin(1, 1);
        b.push_lane(&[1.0], 1, &[0.0], &[0.0]);
        b.push_lane(&[1.0, 2.0], 2, &[0.0], &[0.0]); // longer than prev
    }

    #[test]
    #[should_panic]
    fn fused_batch_rejects_bad_frame_width() {
        let mut b = FusedBatch::new();
        b.begin(2, 1);
        b.push_lane(&[1.0], 1, &[0.0], &[0.0]); // 1 != steps * D = 2
    }

    #[test]
    fn repack_changes_width_without_raw_weights() {
        let (d, hid, gh) = (5usize, 7usize, 12usize);
        let mut rng = Rng::new(21);
        let wx = rng.vec_f32(d * gh, -1.0, 1.0);
        let wh = rng.vec_f32(hid * gh, -1.0, 1.0);
        let mut scr = ExecScratch::new();
        scr.ensure_packed(&wx, &wh, d, hid, gh, 16);
        let mut want_8 = Vec::new();
        gemm::pack_b(&wx, d, gh, 8, &mut want_8);
        // Width change with EMPTY raw args: must repack from residents.
        scr.ensure_packed(&[], &[], d, hid, gh, 8);
        assert_eq!(scr.packed_wx, want_8);
        assert_eq!(scr.packed_nr, 8);
        // Round-trip back to the original width restores the panels.
        let mut want_16 = Vec::new();
        gemm::pack_b(&wh, hid, gh, 16, &mut want_16);
        scr.repack(d, hid, gh, 16);
        assert_eq!(scr.packed_wh, want_16);
        // Same-width repack is a no-op.
        scr.repack(d, hid, gh, 16);
        assert_eq!(scr.packed_wh, want_16);
    }

    #[test]
    fn ensure_quant_latches_once_and_repacks_without_raw_weights() {
        let (d, hid, gh) = (5usize, 3usize, 12usize); // 4 gates
        let mut rng = Rng::new(33);
        let wx = rng.vec_f32(d * gh, -1.0, 1.0);
        let wh = rng.vec_f32(hid * gh, -1.0, 1.0);
        let mut scr = ExecScratch::new();
        scr.ensure_quant(&wx, &wh, d, hid, gh, 16);
        let want = quant::quantize_weights(&wx, d, gh, 4, 16);
        assert_eq!(scr.qwx.as_ref().unwrap(), &want);
        // Width change with EMPTY raw args: repacked from residents,
        // scales untouched.
        let scales = scr.qwh.as_ref().unwrap().scales().to_vec();
        scr.ensure_quant(&[], &[], d, hid, gh, 8);
        assert_eq!(scr.qwx.as_ref().unwrap().nr, 8);
        assert_eq!(scr.qwh.as_ref().unwrap().scales(), &scales[..]);
        // Round-trip restores the original packing.
        scr.ensure_quant(&[], &[], d, hid, gh, 16);
        assert_eq!(scr.qwx.as_ref().unwrap(), &want);
        // The f32 latch stays independent: quantizing never packs f32.
        assert!(!scr.packed);
    }
}
