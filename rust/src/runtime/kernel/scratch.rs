//! Per-executable kernel workspace: packed weight panels, the unfolded
//! pre-activation buffer, and double-buffered recurrent state.
//!
//! One `ExecScratch` binds to ONE weight set (the executable that owns
//! it): the packed `wx`/`wh` panels are built on first use and reused
//! for every subsequent request and timestep. Callers driving the
//! kernel free functions directly (benches, tests) must give each
//! weight set its own scratch — the pack guard is a one-shot latch, not
//! a content hash.
//!
//! Every buffer is grown with `clear` + `extend`/`resize`, so once an
//! executable has served one request of its (fixed) shape, the
//! steady-state path performs **zero heap allocations per request**:
//! capacity is retained and only lengths change.

use super::gemm;

/// Reusable workspace owned by one executable (or one bench/test run).
#[derive(Debug, Default)]
pub struct ExecScratch {
    /// `wx (D, G*H)` packed into NR-column panels (one-shot).
    pub(super) packed_wx: Vec<f32>,
    /// `wh (H, G*H)` packed into NR-column panels (one-shot).
    pub(super) packed_wh: Vec<f32>,
    /// One-shot pack latch (see the module doc's one-weight-set rule).
    pub(super) packed: bool,
    /// Unfolded pre-activations: `(T*B, G*H)` for the whole sequence.
    pub(super) pre: Vec<f32>,
    /// GRU hidden-half pre-activations for one step: `(B, G*H)`.
    pub(super) hpre: Vec<f32>,
    /// Double-buffered hidden state, `(B, H)` each.
    pub(super) state_a: Vec<f32>,
    pub(super) state_b: Vec<f32>,
    /// Double-buffered cell state (LSTM only), `(B, H)` each.
    pub(super) cell_a: Vec<f32>,
    pub(super) cell_b: Vec<f32>,
}

impl ExecScratch {
    pub fn new() -> ExecScratch {
        ExecScratch::default()
    }

    /// Pack the weight panels on first use; no-op afterwards (one-shot
    /// latch). Public so an executable can pack eagerly at bind time
    /// and then DROP its raw dense weights — the panels become the only
    /// resident copy, halving steady-state weight memory; the kernel
    /// entry points still accept the raw matrices so standalone callers
    /// (benches, tests) self-pack on first call.
    pub fn ensure_packed(&mut self, wx: &[f32], wh: &[f32], d: usize, hid: usize, gh: usize) {
        if !self.packed {
            gemm::pack_b(wx, d, gh, &mut self.packed_wx);
            gemm::pack_b(wh, hid, gh, &mut self.packed_wh);
            self.packed = true;
        }
    }
}

/// `buf = bias` broadcast over `rows` rows (zeros when `bias` is empty),
/// reusing the buffer's capacity.
pub(super) fn fill_bias(buf: &mut Vec<f32>, bias: &[f32], rows: usize, width: usize) {
    buf.clear();
    if bias.is_empty() {
        buf.resize(rows * width, 0.0);
    } else {
        debug_assert_eq!(bias.len(), width);
        buf.reserve(rows * width);
        for _ in 0..rows {
            buf.extend_from_slice(bias);
        }
    }
}

/// `buf = src` (length included), reusing capacity.
pub(super) fn fill_from(buf: &mut Vec<f32>, src: &[f32]) {
    buf.clear();
    buf.extend_from_slice(src);
}

/// `buf = [0.0; len]`, reusing capacity.
pub(super) fn fill_zero(buf: &mut Vec<f32>, len: usize) {
    buf.clear();
    buf.resize(len, 0.0);
}
