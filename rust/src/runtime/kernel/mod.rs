//! The tiled kernel layer: the executor's hot path, rebuilt the way the
//! paper builds SHARP's dispatch (§4–5) — a cache-blocked,
//! register-tiled GEMM ([`gemm`]) whose tile shape is **runtime data**
//! (a [`crate::runtime::plan::KernelGeometry`] chosen per model by the
//! execution planner, not a compile-time constant), under a
//! plan-selected sequence schedule ([`rnn`]: unfolded or stepwise),
//! with a per-executable workspace ([`ExecScratch`]) that makes the
//! steady-state serving path allocation-free. The [`stack`] drivers
//! compose these same kernels into deep stacked models (bidirectional
//! and projection variants included) and pipeline the layers across
//! scoped threads, one layer per thread with double-buffered
//! step-queues between them.
//!
//! The scalar kernels in [`crate::runtime::exec`] remain the reference
//! semantics: everything here is bit-identical to them by construction
//! for EVERY geometry, schedule, and vector ISA the planner can emit
//! (M/N-only tiling preserves each dot product's accumulation order,
//! the SIMD micro-kernels ([`simd`]) vectorize across columns only —
//! one dot per lane, mul-then-add, never FMA — and the activation
//! stage is literally shared code). The equivalence is enforced across
//! a shape x geometry x ISA sweep by `tests/kernel_equivalence.rs` and
//! `tests/simd_conformance.rs`, in release mode in CI, under both
//! default and `SHARP_FORCE_KERNEL=scalar` dispatch — tiling bugs love
//! optimized builds.
//!
//! Zero external deps, like the rest of the crate: row-parallelism uses
//! `std::thread::scope`, gated by the `threads` knob on
//! [`crate::runtime::RuntimeConfig`] and the plan's
//! `min_flops_per_thread` threshold.

pub mod gemm;
pub mod rnn;
pub mod scratch;
pub mod simd;
pub mod stack;

pub use rnn::{gru_seq_into, gru_steps_batched_into, lstm_seq_into, lstm_steps_batched_into};
pub use scratch::{ExecScratch, FusedBatch};
pub use simd::Isa;
pub use stack::{
    stack_pipelined_into, stack_seq_into, CellKind, DirParams, LayerParams, StackScratch,
    StackShape,
};
