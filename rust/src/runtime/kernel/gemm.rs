//! Cache-blocked, register-tiled dense matmul with B-panel packing.
//!
//! The kernel tiles over M and N **only**: for every output element the
//! contraction axis runs k = 0..K sequentially inside one micro-kernel
//! invocation, so the per-dot accumulation order — and therefore the
//! f32 rounding — is exactly the scalar reference's (`exec::matmul_acc`
//! also accumulates k-ascending into each element). That is the whole
//! bit-exactness argument: same adds, same order, no FMA contraction
//! (rustc does not fuse `a * b + c`), no k-splitting, no reassociation.
//!
//! Layout: `b (K, N)` row-major is packed once into column panels of
//! `NR` columns (`pack_b`), so the micro-kernel streams one contiguous
//! `NR`-wide row of the panel per k-step and keeps an `MR x NR`
//! accumulator block in registers. Each packed element is reused `MR`
//! times from registers and each `a` element `NR` times, which is what
//! removes the load/store-per-FLOP overhead of the scalar axpy loop.
//! Weight matrices are packed once per executable (`ExecScratch`) and
//! reused across every request and timestep.

/// Micro-kernel rows: `a` rows held broadcast in registers.
pub const MR: usize = 4;
/// Micro-kernel columns: one packed-panel row, vectorizable width.
pub const NR: usize = 16;

/// Pack row-major `b (K, N)` into column panels of `NR` columns.
///
/// Panel `p` covers columns `[p*NR, min(N, (p+1)*NR))` and stores them
/// k-major: element `(k, j)` of a width-`w` panel sits at `k*w + j`.
/// Panels are laid out back to back, so `packed.len() == K * N`.
pub fn pack_b(b: &[f32], k: usize, n: usize, packed: &mut Vec<f32>) {
    debug_assert_eq!(b.len(), k * n);
    packed.clear();
    packed.reserve(k * n);
    let mut col = 0;
    while col < n {
        let w = NR.min(n - col);
        for row in 0..k {
            packed.extend_from_slice(&b[row * n + col..row * n + col + w]);
        }
        col += w;
    }
}

/// `out (M, N) += a (M, K) @ b (K, N)` with `b` pre-packed by [`pack_b`].
///
/// `out` arrives holding the accumulation base (bias broadcast or a
/// partial sum); element `(m, n)` then receives `a[m][k] * b[k][n]` for
/// k ascending — the scalar reference order.
pub fn matmul_packed(out: &mut [f32], a: &[f32], packed_b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(out.len(), m * n);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(packed_b.len(), k * n);
    let mut col = 0;
    let mut poff = 0;
    while col < n {
        let w = NR.min(n - col);
        let panel = &packed_b[poff..poff + k * w];
        let mut row = 0;
        while row < m {
            let mr = MR.min(m - row);
            if mr == MR && w == NR {
                kern_full(out, a, panel, row, col, k, n);
            } else {
                kern_edge(out, a, panel, row, col, k, n, mr, w);
            }
            row += mr;
        }
        poff += k * w;
        col += w;
    }
}

/// Full `MR x NR` register block: the only code the hot loop runs when
/// shapes are tile-aligned.
#[inline]
fn kern_full(
    out: &mut [f32],
    a: &[f32],
    panel: &[f32],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for (i, acc_row) in acc.iter_mut().enumerate() {
        let base = (row + i) * n + col;
        acc_row.copy_from_slice(&out[base..base + NR]);
    }
    for kk in 0..k {
        let bp = &panel[kk * NR..kk * NR + NR];
        for (i, acc_row) in acc.iter_mut().enumerate() {
            let av = a[(row + i) * k + kk];
            for (o, bv) in acc_row.iter_mut().zip(bp) {
                *o += av * bv;
            }
        }
    }
    for (i, acc_row) in acc.iter().enumerate() {
        let base = (row + i) * n + col;
        out[base..base + NR].copy_from_slice(acc_row);
    }
}

/// Edge block: `mr <= MR` rows by `w <= NR` panel columns, same
/// k-ascending accumulation as [`kern_full`].
#[allow(clippy::too_many_arguments)] // micro-kernel ABI: block coords + dims
fn kern_edge(
    out: &mut [f32],
    a: &[f32],
    panel: &[f32],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
    mr: usize,
    w: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for (i, acc_row) in acc.iter_mut().enumerate().take(mr) {
        let base = (row + i) * n + col;
        acc_row[..w].copy_from_slice(&out[base..base + w]);
    }
    for kk in 0..k {
        let bp = &panel[kk * w..kk * w + w];
        for (i, acc_row) in acc.iter_mut().enumerate().take(mr) {
            let av = a[(row + i) * k + kk];
            for (o, bv) in acc_row.iter_mut().zip(bp) {
                *o += av * bv;
            }
        }
    }
    for (i, acc_row) in acc.iter().enumerate().take(mr) {
        let base = (row + i) * n + col;
        out[base..base + w].copy_from_slice(&acc_row[..w]);
    }
}

/// Row-parallel [`matmul_packed`]: M is split into `threads` contiguous
/// row chunks executed under `std::thread::scope`. Every output element
/// is still produced by exactly one serial micro-kernel call, so the
/// result is bit-identical to the serial path for any thread count.
pub fn matmul_packed_mt(
    out: &mut [f32],
    a: &[f32],
    packed_b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    let t = threads.clamp(1, m.max(1));
    if t <= 1 {
        matmul_packed(out, a, packed_b, m, k, n);
        return;
    }
    let rows_per = m.div_ceil(t);
    std::thread::scope(|s| {
        for (oc, ac) in out.chunks_mut(rows_per * n).zip(a.chunks(rows_per * k)) {
            s.spawn(move || {
                matmul_packed(oc, ac, packed_b, oc.len() / n, k, n);
            });
        }
    });
}

/// How many threads a `(M, K, N)` GEMM is actually worth: capped so every
/// thread gets at least two rows and at least ~4 MFLOP of work (scoped
/// thread spawns cost tens of microseconds; a tiny recurrent MVM must
/// stay serial or the spawn overhead eats the win).
pub fn effective_threads(threads: usize, m: usize, k: usize, n: usize) -> usize {
    const MIN_FLOPS_PER_THREAD: usize = 1 << 22;
    if threads <= 1 || m < 4 {
        return 1;
    }
    let flops = 2usize.saturating_mul(m).saturating_mul(k).saturating_mul(n);
    threads
        .min(m / 2)
        .min((flops / MIN_FLOPS_PER_THREAD).max(1))
        .max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::exec::matmul_acc;
    use crate::util::rng::Rng;

    fn check_shape(m: usize, k: usize, n: usize, threads: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let a = rng.vec_f32(m * k, -1.0, 1.0);
        let b = rng.vec_f32(k * n, -1.0, 1.0);
        let base = rng.vec_f32(m * n, -0.5, 0.5);

        let mut want = base.clone();
        matmul_acc(&mut want, &a, &b, m, k, n);

        let mut packed = Vec::new();
        pack_b(&b, k, n, &mut packed);
        assert_eq!(packed.len(), k * n);
        let mut got = base.clone();
        matmul_packed_mt(&mut got, &a, &packed, m, k, n, threads);

        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                g.to_bits(),
                w.to_bits(),
                "({m},{k},{n}) threads={threads} element {i}: {g} vs {w}"
            );
        }
    }

    #[test]
    fn packed_matches_scalar_bitwise_over_edge_shapes() {
        // Aligned, sub-tile, and ragged M/N/K, serial and threaded.
        for &(m, k, n) in &[
            (1, 1, 1),
            (1, 7, 16),
            (4, 8, 16),
            (8, 16, 32),
            (3, 5, 7),
            (5, 3, 17),
            (6, 9, 31),
            (9, 2, 33),
            (13, 21, 50),
            (2, 40, 15),
        ] {
            check_shape(m, k, n, 1, 11 + m as u64);
            check_shape(m, k, n, 4, 23 + n as u64);
        }
    }

    #[test]
    fn pack_b_is_panel_major() {
        // 2x3 matrix with NR=16: one ragged panel of width 3, k-major.
        let b = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut packed = Vec::new();
        pack_b(&b, 2, 3, &mut packed);
        assert_eq!(packed, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn effective_threads_gates_small_work() {
        // Tiny recurrent MVM stays serial.
        assert_eq!(effective_threads(8, 1, 256, 1024), 1);
        assert_eq!(effective_threads(8, 2, 256, 1024), 1);
        // Big input GEMM fans out, capped at m/2.
        assert!(effective_threads(8, 64, 1024, 4096) > 1);
        assert_eq!(effective_threads(16, 8, 4096, 4096), 4);
        // threads=1 is always serial.
        assert_eq!(effective_threads(1, 1000, 1000, 1000), 1);
    }
}
