//! Cache-blocked, register-tiled dense matmul with B-panel packing,
//! parameterized by a runtime [`KernelGeometry`] (the execution
//! planner's choice) instead of compile-time tile constants.
//!
//! The kernel tiles over M and N **only**: for every output element the
//! contraction axis runs k = 0..K sequentially inside one micro-kernel
//! invocation, so the per-dot accumulation order — and therefore the
//! f32 rounding — is exactly the scalar reference's (`exec::matmul_acc`
//! also accumulates k-ascending into each element). That argument is
//! geometry-independent: `mr`/`nr` only decide how the M x N output is
//! partitioned into blocks, never how a dot product is ordered, which
//! is why **every** plan the tuner can emit is bit-identical to the
//! oracle (same adds, same order, no FMA contraction, no k-splitting).
//!
//! Layout: `b (K, N)` row-major is packed once into column panels of
//! `nr` columns (`pack_b`), so the micro-kernel streams one contiguous
//! `nr`-wide row of the panel per k-step and keeps an `mr x nr`
//! accumulator block in registers. Each packed element is reused `mr`
//! times from registers and each `a` element `nr` times — the knobs the
//! planner trades against register-file capacity per model shape.
//!
//! The micro-kernel is **monomorphized over the candidate set**: the
//! `(mr, nr)` pairs the tuner can emit dispatch to const-generic
//! instantiations (`kern`) whose accumulator block is a true
//! compile-time array — full unroll, registers, no spill from dynamic
//! indexing — while ragged edges and out-of-set tiles take the
//! dynamic-width fallback (`kern_dyn`). The *choice* of tile is runtime
//! data on every path; the instantiations are vectorization vehicles
//! the geometry selects, not operating points.
//!
//! The geometry also carries a vector ISA ([`super::simd::Isa`]): each
//! accumulator block is first offered to that ISA's column-vectorized
//! micro-kernel ([`super::simd`]) and runs the scalar instantiations
//! only when the block has no vector form (scalar ISA, lane-unaligned
//! width) — bit-identical either way, since the vector kernels keep
//! one dot per lane with the same mul-then-add per k-step. A geometry
//! claiming an ISA this host cannot execute (hand-built, or resolved
//! on another machine) downgrades to scalar once per GEMM call.

use crate::runtime::kernel::simd::{self, Isa};
use crate::runtime::plan::{KernelGeometry, MR_MAX, NR_MAX};

/// Pack row-major `b (K, N)` into column panels of `nr` columns.
///
/// Panel `p` covers columns `[p*nr, min(N, (p+1)*nr))` and stores them
/// k-major: element `(k, j)` of a width-`w` panel sits at `k*w + j`.
/// Panels are laid out back to back, so `packed.len() == K * N` for any
/// panel width.
pub fn pack_b(b: &[f32], k: usize, n: usize, nr: usize, packed: &mut Vec<f32>) {
    debug_assert_eq!(b.len(), k * n);
    let nr = nr.clamp(1, NR_MAX);
    packed.clear();
    packed.reserve(k * n);
    let mut col = 0;
    while col < n {
        let w = nr.min(n - col);
        for row in 0..k {
            packed.extend_from_slice(&b[row * n + col..row * n + col + w]);
        }
        col += w;
    }
}

/// Invert [`pack_b`]: recover the row-major `b (K, N)` from panels of
/// width `nr`. Used when a re-plan changes the panel width after the
/// dense weights were dropped (the packed panels are the only resident
/// copy, so a geometry change re-derives them from themselves).
pub fn unpack_b(packed: &[f32], k: usize, n: usize, nr: usize, out: &mut Vec<f32>) {
    debug_assert_eq!(packed.len(), k * n);
    let nr = nr.clamp(1, NR_MAX);
    out.clear();
    out.resize(k * n, 0.0);
    let mut col = 0;
    let mut poff = 0;
    while col < n {
        let w = nr.min(n - col);
        for row in 0..k {
            out[row * n + col..row * n + col + w]
                .copy_from_slice(&packed[poff + row * w..poff + (row + 1) * w]);
        }
        poff += k * w;
        col += w;
    }
}

/// `out (M, N) += a (M, K) @ b (K, N)` with `b` pre-packed by [`pack_b`]
/// at the same `geo.nr`.
///
/// `out` arrives holding the accumulation base (bias broadcast or a
/// partial sum); element `(m, n)` then receives `a[m][k] * b[k][n]` for
/// k ascending — the scalar reference order, for every geometry.
pub fn matmul_packed(
    out: &mut [f32],
    a: &[f32],
    packed_b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    geo: &KernelGeometry,
) {
    debug_assert_eq!(out.len(), m * n);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(packed_b.len(), k * n);
    // Defensive clamp: planners validate, but a hand-built geometry must
    // not index past the accumulator capacity — and must not reach a
    // vector kernel its host cannot execute (downgrade, never UB).
    let mr = geo.mr.clamp(1, MR_MAX);
    let nr = geo.nr.clamp(1, NR_MAX);
    let isa = if geo.isa.available() {
        geo.isa
    } else {
        Isa::Scalar
    };
    let mut col = 0;
    let mut poff = 0;
    while col < n {
        let w = nr.min(n - col);
        let panel = &packed_b[poff..poff + k * w];
        let mut row = 0;
        while row < m {
            let mre = mr.min(m - row);
            kern_block(out, a, panel, row, col, k, n, mre, w, isa);
            row += mre;
        }
        poff += k * w;
        col += w;
    }
}

/// Dispatch one accumulator block: the geometry's vector ISA first
/// (when the `(rows, width)` pair has a vector instantiation), then the
/// monomorphized scalar micro-kernel for candidate-set pairs, then the
/// dynamic fallback (ragged edges, exotic fixed geometries). All three
/// produce identical bits; only the issue width differs.
#[inline]
#[allow(clippy::too_many_arguments)] // micro-kernel ABI: block coords + dims
fn kern_block(
    out: &mut [f32],
    a: &[f32],
    panel: &[f32],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
    mre: usize,
    w: usize,
    isa: Isa,
) {
    if isa != Isa::Scalar && simd::kern_block_simd(isa, out, a, panel, row, col, k, n, mre, w) {
        return;
    }
    match (mre, w) {
        (1, 4) => kern::<1, 4>(out, a, panel, row, col, k, n),
        (1, 8) => kern::<1, 8>(out, a, panel, row, col, k, n),
        (1, 16) => kern::<1, 16>(out, a, panel, row, col, k, n),
        (1, 32) => kern::<1, 32>(out, a, panel, row, col, k, n),
        (2, 4) => kern::<2, 4>(out, a, panel, row, col, k, n),
        (2, 8) => kern::<2, 8>(out, a, panel, row, col, k, n),
        (2, 16) => kern::<2, 16>(out, a, panel, row, col, k, n),
        (2, 32) => kern::<2, 32>(out, a, panel, row, col, k, n),
        (4, 4) => kern::<4, 4>(out, a, panel, row, col, k, n),
        (4, 8) => kern::<4, 8>(out, a, panel, row, col, k, n),
        (4, 16) => kern::<4, 16>(out, a, panel, row, col, k, n),
        (4, 32) => kern::<4, 32>(out, a, panel, row, col, k, n),
        (8, 4) => kern::<8, 4>(out, a, panel, row, col, k, n),
        (8, 8) => kern::<8, 8>(out, a, panel, row, col, k, n),
        (8, 16) => kern::<8, 16>(out, a, panel, row, col, k, n),
        (8, 32) => kern::<8, 32>(out, a, panel, row, col, k, n),
        _ => kern_dyn(out, a, panel, row, col, k, n, mre, w),
    }
}

/// Fully-unrolled `MR x W` register block (compile-time instantiation
/// selected at runtime by [`kern_block`]). Same k-ascending accumulation
/// as the fallback and the scalar oracle.
#[inline]
fn kern<const MR: usize, const W: usize>(
    out: &mut [f32],
    a: &[f32],
    panel: &[f32],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(panel.len(), k * W);
    let mut acc = [[0.0f32; W]; MR];
    for (i, acc_row) in acc.iter_mut().enumerate() {
        let base = (row + i) * n + col;
        acc_row.copy_from_slice(&out[base..base + W]);
    }
    for (kk, bp) in panel.chunks_exact(W).enumerate() {
        for (i, acc_row) in acc.iter_mut().enumerate() {
            let av = a[(row + i) * k + kk];
            for (o, bv) in acc_row.iter_mut().zip(bp) {
                *o += av * bv;
            }
        }
    }
    for (i, acc_row) in acc.iter().enumerate() {
        let base = (row + i) * n + col;
        out[base..base + W].copy_from_slice(acc_row);
    }
}

/// Dynamic block: `mre <= MR_MAX` rows by `w <= NR_MAX` panel columns,
/// same k-ascending accumulation as [`kern`].
#[allow(clippy::too_many_arguments)] // micro-kernel ABI: block coords + dims
fn kern_dyn(
    out: &mut [f32],
    a: &[f32],
    panel: &[f32],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
    mre: usize,
    w: usize,
) {
    debug_assert!(mre <= MR_MAX && w <= NR_MAX);
    let mut acc = [[0.0f32; NR_MAX]; MR_MAX];
    for (i, acc_row) in acc.iter_mut().enumerate().take(mre) {
        let base = (row + i) * n + col;
        acc_row[..w].copy_from_slice(&out[base..base + w]);
    }
    for (kk, bp) in panel.chunks_exact(w).enumerate() {
        for (i, acc_row) in acc.iter_mut().enumerate().take(mre) {
            let av = a[(row + i) * k + kk];
            for (o, bv) in acc_row.iter_mut().zip(bp) {
                *o += av * bv;
            }
        }
    }
    for (i, acc_row) in acc.iter().enumerate().take(mre) {
        let base = (row + i) * n + col;
        out[base..base + w].copy_from_slice(&acc_row[..w]);
    }
}

/// Row-parallel [`matmul_packed`]: M is split into `threads` contiguous
/// row chunks executed under `std::thread::scope`. Every output element
/// is still produced by exactly one serial micro-kernel call, so the
/// result is bit-identical to the serial path for any thread count and
/// any geometry.
#[allow(clippy::too_many_arguments)] // GEMM ABI + the two runtime knobs
pub fn matmul_packed_mt(
    out: &mut [f32],
    a: &[f32],
    packed_b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    geo: &KernelGeometry,
    threads: usize,
) {
    let t = threads.clamp(1, m.max(1));
    if t <= 1 {
        matmul_packed(out, a, packed_b, m, k, n, geo);
        return;
    }
    let rows_per = m.div_ceil(t);
    std::thread::scope(|s| {
        for (oc, ac) in out.chunks_mut(rows_per * n).zip(a.chunks(rows_per * k)) {
            s.spawn(move || {
                matmul_packed(oc, ac, packed_b, oc.len() / n, k, n, geo);
            });
        }
    });
}

/// How many threads a `(M, K, N)` GEMM is actually worth: capped so every
/// thread gets at least two rows and at least `min_flops_per_thread`
/// FLOPs of work (scoped thread spawns cost tens of microseconds; a tiny
/// recurrent MVM must stay serial or the spawn overhead eats the win).
/// The threshold is the planner knob [`KernelGeometry::min_flops_per_thread`]
/// — no longer a buried constant; default and rationale at
/// [`crate::runtime::plan::DEFAULT_MIN_FLOPS_PER_THREAD`].
pub fn effective_threads(
    threads: usize,
    m: usize,
    k: usize,
    n: usize,
    min_flops_per_thread: usize,
) -> usize {
    if threads <= 1 || m < 4 {
        return 1;
    }
    let flops = 2usize.saturating_mul(m).saturating_mul(k).saturating_mul(n);
    threads
        .min(m / 2)
        .min((flops / min_flops_per_thread.max(1)).max(1))
        .max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::exec::matmul_acc;
    use crate::runtime::plan::DEFAULT_MIN_FLOPS_PER_THREAD;
    use crate::util::rng::Rng;

    fn check_shape(m: usize, k: usize, n: usize, geo: &KernelGeometry, threads: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let a = rng.vec_f32(m * k, -1.0, 1.0);
        let b = rng.vec_f32(k * n, -1.0, 1.0);
        let base = rng.vec_f32(m * n, -0.5, 0.5);

        let mut want = base.clone();
        matmul_acc(&mut want, &a, &b, m, k, n);

        let mut packed = Vec::new();
        pack_b(&b, k, n, geo.nr, &mut packed);
        assert_eq!(packed.len(), k * n);
        let mut got = base.clone();
        matmul_packed_mt(&mut got, &a, &packed, m, k, n, geo, threads);

        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                g.to_bits(),
                w.to_bits(),
                "({m},{k},{n}) geo={}x{} threads={threads} element {i}: {g} vs {w}",
                geo.mr,
                geo.nr
            );
        }
    }

    #[test]
    fn packed_matches_scalar_bitwise_over_edge_shapes_and_geometries() {
        // Aligned, sub-tile, and ragged M/N/K, serial and threaded, across
        // the whole geometry candidate grid (incl. tiles larger than the
        // matrix: every block then runs the edge path), under every ISA
        // this host can execute (vector blocks where the width aligns,
        // scalar fallback on the lane-unaligned remainder).
        let shapes = [
            (1, 1, 1),
            (1, 7, 16),
            (4, 8, 16),
            (8, 16, 32),
            (3, 5, 7),
            (5, 3, 17),
            (6, 9, 31),
            (9, 2, 33),
            (13, 21, 50),
            (2, 40, 15),
        ];
        for isa in Isa::supported() {
            for &(m, k, n) in &shapes {
                for &(mr, nr) in &[(4, 16), (1, 4), (2, 8), (8, 32), (8, 4), (1, 32), (3, 5)] {
                    let geo = KernelGeometry::new(mr, nr).unwrap().with_isa(isa);
                    check_shape(m, k, n, &geo, 1, 11 + (m * mr) as u64);
                    check_shape(m, k, n, &geo, 4, 23 + (n * nr) as u64);
                }
            }
        }
    }

    #[test]
    fn unavailable_isa_downgrades_to_scalar_without_panicking() {
        // A hand-built geometry claiming the vector ISA of the *other*
        // architecture must run (scalar) and still match the oracle —
        // the defensive downgrade in `matmul_packed`, not UB.
        let missing = Isa::ALL
            .into_iter()
            .find(|isa| !isa.available())
            .expect("avx2 and neon are never both available");
        let geo = KernelGeometry::new(4, 16).unwrap().with_isa(missing);
        check_shape(13, 21, 50, &geo, 1, 77);
        check_shape(13, 21, 50, &geo, 4, 78);
    }

    #[test]
    fn pack_b_is_panel_major_and_unpack_inverts_it() {
        // 2x3 matrix with nr=16: one ragged panel of width 3, k-major.
        let b = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut packed = Vec::new();
        pack_b(&b, 2, 3, 16, &mut packed);
        assert_eq!(packed, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        // nr=2: panels [cols 0..2] then [col 2], k-major within each.
        pack_b(&b, 2, 3, 2, &mut packed);
        assert_eq!(packed, vec![1.0, 2.0, 4.0, 5.0, 3.0, 6.0]);
        // Round-trip across widths on a bigger matrix.
        let mut rng = Rng::new(3);
        let big = rng.vec_f32(7 * 45, -1.0, 1.0);
        let mut dense = Vec::new();
        for nr in [1, 3, 8, 16, 32] {
            pack_b(&big, 7, 45, nr, &mut packed);
            unpack_b(&packed, 7, 45, nr, &mut dense);
            assert_eq!(dense, big, "nr={nr}");
        }
    }

    #[test]
    fn effective_threads_gates_small_work() {
        let gate = DEFAULT_MIN_FLOPS_PER_THREAD;
        // Tiny recurrent MVM stays serial.
        assert_eq!(effective_threads(8, 1, 256, 1024, gate), 1);
        assert_eq!(effective_threads(8, 2, 256, 1024, gate), 1);
        // Big input GEMM fans out, capped at m/2.
        assert!(effective_threads(8, 64, 1024, 4096, gate) > 1);
        assert_eq!(effective_threads(16, 8, 4096, 4096, gate), 4);
        // threads=1 is always serial.
        assert_eq!(effective_threads(1, 1000, 1000, 1000, gate), 1);
    }

    #[test]
    fn thread_gate_knob_moves_the_serial_parallel_crossover() {
        // The satellite contract: the gate is a knob, not magic. A GEMM
        // right at the default boundary flips serial<->parallel as the
        // threshold moves around its FLOP count (2*m*k*n = 2^23 here,
        // i.e. two default-gate units of work).
        let (m, k, n) = (64, 256, 256);
        let flops = 2 * m * k * n;
        assert_eq!(flops, 1 << 23);
        // Default gate (2^22): exactly 2 threads' worth of work.
        assert_eq!(effective_threads(8, m, k, n, DEFAULT_MIN_FLOPS_PER_THREAD), 2);
        // Gate raised above the total work: serial again.
        assert_eq!(effective_threads(8, m, k, n, flops + 1), 1);
        // Gate lowered: the fan-out is released up to the other caps.
        assert_eq!(effective_threads(8, m, k, n, 1 << 20), 8);
        // Degenerate knob value must not divide by zero.
        assert_eq!(effective_threads(8, m, k, n, 0), 8);
    }
}
