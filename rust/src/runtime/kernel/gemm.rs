//! Cache-blocked, register-tiled dense matmul with B-panel packing,
//! parameterized by a runtime [`KernelGeometry`] (the execution
//! planner's choice) instead of compile-time tile constants.
//!
//! The kernel tiles over M and N **only**: for every output element the
//! contraction axis runs k = 0..K sequentially inside one micro-kernel
//! invocation, so the per-dot accumulation order — and therefore the
//! f32 rounding — is exactly the scalar reference's (`exec::matmul_acc`
//! also accumulates k-ascending into each element). That argument is
//! geometry-independent: `mr`/`nr` only decide how the M x N output is
//! partitioned into blocks, never how a dot product is ordered, which
//! is why **every** plan the tuner can emit is bit-identical to the
//! oracle (same adds, same order, no FMA contraction, no k-splitting).
//!
//! Layout: `b (K, N)` row-major is packed once into column panels of
//! `nr` columns (`pack_b`), so the micro-kernel streams one contiguous
//! `nr`-wide row of the panel per k-step and keeps an `mr x nr`
//! accumulator block in registers. Each packed element is reused `mr`
//! times from registers and each `a` element `nr` times — the knobs the
//! planner trades against register-file capacity per model shape.
//!
//! The micro-kernel is **monomorphized over the candidate set**: the
//! `(mr, nr)` pairs the tuner can emit dispatch to const-generic
//! instantiations (`kern`) whose accumulator block is a true
//! compile-time array — full unroll, registers, no spill from dynamic
//! indexing — while ragged edges and out-of-set tiles take the
//! dynamic-width fallback (`kern_dyn`). The *choice* of tile is runtime
//! data on every path; the instantiations are vectorization vehicles
//! the geometry selects, not operating points.
//!
//! The geometry also carries a vector ISA ([`super::simd::Isa`]): each
//! accumulator block is first offered to that ISA's column-vectorized
//! micro-kernel ([`super::simd`]) and runs the scalar instantiations
//! only when the block has no vector form (scalar ISA, lane-unaligned
//! width) — bit-identical either way, since the vector kernels keep
//! one dot per lane with the same mul-then-add per k-step. A geometry
//! claiming an ISA this host cannot execute (hand-built, or resolved
//! on another machine) downgrades to scalar once per GEMM call.
//!
//! **One panel format, many element types.** The packed-panel layout
//! and the M/N tiling loop are element-type-independent, so they are
//! written once: [`pack_panels`]/[`unpack_panels`] pack any `Copy`
//! element and [`matmul_panels`] drives any [`PanelKernel`] — the trait
//! that binds an element type, an accumulator type, and a per-block
//! micro-kernel dispatch. [`F32Panel`] is the dense path ([`pack_b`] /
//! [`matmul_packed`] are its thin wrappers, kept for the existing call
//! sites); [`I8Panel`] is the quantized path: i8 operands, exact i32
//! accumulation, and the same vector-first block dispatch via
//! [`simd::kern_block_simd_i8`]. The quantized driver
//! ([`matmul_quant`]) adds a fused dequant epilogue — each register
//! tile drains into the f32 output through the per-row activation scale
//! and per-column weight scale before the next block runs, so no
//! `(M, N)` i32 buffer ever exists.

use crate::runtime::kernel::simd::{self, Isa};
use crate::runtime::plan::{KernelGeometry, MR_MAX, NR_MAX};

/// Pack row-major `b (K, N)` into column panels of `nr` columns, for
/// any element type.
///
/// Panel `p` covers columns `[p*nr, min(N, (p+1)*nr))` and stores them
/// k-major: element `(k, j)` of a width-`w` panel sits at `k*w + j`.
/// Panels are laid out back to back, so `packed.len() == K * N` for any
/// panel width.
pub fn pack_panels<T: Copy>(b: &[T], k: usize, n: usize, nr: usize, packed: &mut Vec<T>) {
    debug_assert_eq!(b.len(), k * n);
    let nr = nr.clamp(1, NR_MAX);
    packed.clear();
    packed.reserve(k * n);
    let mut col = 0;
    while col < n {
        let w = nr.min(n - col);
        for row in 0..k {
            packed.extend_from_slice(&b[row * n + col..row * n + col + w]);
        }
        col += w;
    }
}

/// Invert [`pack_panels`]: recover the row-major `b (K, N)` from panels
/// of width `nr`. Used when a re-plan changes the panel width after the
/// dense weights were dropped (the packed panels are the only resident
/// copy, so a geometry change re-derives them from themselves).
pub fn unpack_panels<T: Copy + Default>(
    packed: &[T],
    k: usize,
    n: usize,
    nr: usize,
    out: &mut Vec<T>,
) {
    debug_assert_eq!(packed.len(), k * n);
    let nr = nr.clamp(1, NR_MAX);
    out.clear();
    out.resize(k * n, T::default());
    let mut col = 0;
    let mut poff = 0;
    while col < n {
        let w = nr.min(n - col);
        for row in 0..k {
            out[row * n + col..row * n + col + w]
                .copy_from_slice(&packed[poff + row * w..poff + (row + 1) * w]);
        }
        poff += k * w;
        col += w;
    }
}

/// [`pack_panels`] for the dense f32 path (the original entry point;
/// the tuner's calibration and the benches call it by this name).
pub fn pack_b(b: &[f32], k: usize, n: usize, nr: usize, packed: &mut Vec<f32>) {
    pack_panels(b, k, n, nr, packed)
}

/// [`unpack_panels`] for the dense f32 path.
pub fn unpack_b(packed: &[f32], k: usize, n: usize, nr: usize, out: &mut Vec<f32>) {
    unpack_panels(packed, k, n, nr, out)
}

/// One packed-panel element type + accumulator type + per-block
/// micro-kernel dispatch. The M/N tiling driver ([`matmul_panels`]) and
/// the panel layout ([`pack_panels`]) are shared across implementations;
/// only the innermost block differs — which is exactly the surface the
/// dense f32, SIMD, and quantized int8 kernels need to share.
pub trait PanelKernel {
    /// Element type of the A operand and the packed B-panels.
    type Elem: Copy + Default;
    /// Accumulator/output element type.
    type Acc: Copy + Default;

    /// Run one `mre x w` accumulator block:
    /// `out[row.., col..] += a[row.., :] @ panel`, contraction ascending
    /// k = 0..K. Must offer the block to `isa`'s vector kernel first and
    /// fall back to a scalar block with identical results.
    #[allow(clippy::too_many_arguments)] // micro-kernel ABI: block coords + dims
    fn block(
        out: &mut [Self::Acc],
        a: &[Self::Elem],
        panel: &[Self::Elem],
        row: usize,
        col: usize,
        k: usize,
        n: usize,
        mre: usize,
        w: usize,
        isa: Isa,
    );
}

/// The dense f32 panel kernel: f32 operands, f32 accumulation, the
/// bit-exactness-by-construction block dispatch.
pub struct F32Panel;

impl PanelKernel for F32Panel {
    type Elem = f32;
    type Acc = f32;

    #[inline]
    fn block(
        out: &mut [f32],
        a: &[f32],
        panel: &[f32],
        row: usize,
        col: usize,
        k: usize,
        n: usize,
        mre: usize,
        w: usize,
        isa: Isa,
    ) {
        kern_block(out, a, panel, row, col, k, n, mre, w, isa);
    }
}

/// The quantized int8 panel kernel: i8 operands, exact i32
/// accumulation. SIMD/scalar agreement is trivial (integer arithmetic
/// has no rounding), so every dispatch choice is bit-identical within
/// the int8 path.
pub struct I8Panel;

impl PanelKernel for I8Panel {
    type Elem = i8;
    type Acc = i32;

    #[inline]
    fn block(
        out: &mut [i32],
        a: &[i8],
        panel: &[i8],
        row: usize,
        col: usize,
        k: usize,
        n: usize,
        mre: usize,
        w: usize,
        isa: Isa,
    ) {
        kern_block_i8(out, a, panel, row, col, k, n, mre, w, isa);
    }
}

/// `out (M, N) += a (M, K) @ b (K, N)` with `b` pre-packed by [`pack_b`]
/// at the same `geo.nr`.
///
/// `out` arrives holding the accumulation base (bias broadcast or a
/// partial sum); element `(m, n)` then receives `a[m][k] * b[k][n]` for
/// k ascending — the scalar reference order, for every geometry.
pub fn matmul_packed(
    out: &mut [f32],
    a: &[f32],
    packed_b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    geo: &KernelGeometry,
) {
    matmul_panels::<F32Panel>(out, a, packed_b, m, k, n, geo);
}

/// The shared M/N tiling driver: `out (M, N) += a (M, K) @ b (K, N)`
/// for any [`PanelKernel`], with `b` pre-packed by [`pack_panels`] at
/// the same `geo.nr`. Column panels sweep outermost (one resident panel
/// per pass), `mr`-row register blocks innermost; the contraction never
/// splits, so each output element is produced by exactly one block call.
pub fn matmul_panels<P: PanelKernel>(
    out: &mut [P::Acc],
    a: &[P::Elem],
    packed_b: &[P::Elem],
    m: usize,
    k: usize,
    n: usize,
    geo: &KernelGeometry,
) {
    debug_assert_eq!(out.len(), m * n);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(packed_b.len(), k * n);
    // Defensive clamp: planners validate, but a hand-built geometry must
    // not index past the accumulator capacity — and must not reach a
    // vector kernel its host cannot execute (downgrade, never UB).
    let mr = geo.mr.clamp(1, MR_MAX);
    let nr = geo.nr.clamp(1, NR_MAX);
    let isa = if geo.isa.available() {
        geo.isa
    } else {
        Isa::Scalar
    };
    let mut col = 0;
    let mut poff = 0;
    while col < n {
        let w = nr.min(n - col);
        let panel = &packed_b[poff..poff + k * w];
        let mut row = 0;
        while row < m {
            let mre = mr.min(m - row);
            P::block(out, a, panel, row, col, k, n, mre, w, isa);
            row += mre;
        }
        poff += k * w;
        col += w;
    }
}

/// Dispatch one accumulator block: the geometry's vector ISA first
/// (when the `(rows, width)` pair has a vector instantiation), then the
/// monomorphized scalar micro-kernel for candidate-set pairs, then the
/// dynamic fallback (ragged edges, exotic fixed geometries). All three
/// produce identical bits; only the issue width differs.
#[inline]
#[allow(clippy::too_many_arguments)] // micro-kernel ABI: block coords + dims
fn kern_block(
    out: &mut [f32],
    a: &[f32],
    panel: &[f32],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
    mre: usize,
    w: usize,
    isa: Isa,
) {
    if isa != Isa::Scalar && simd::kern_block_simd(isa, out, a, panel, row, col, k, n, mre, w) {
        return;
    }
    match (mre, w) {
        (1, 4) => kern::<1, 4>(out, a, panel, row, col, k, n),
        (1, 8) => kern::<1, 8>(out, a, panel, row, col, k, n),
        (1, 16) => kern::<1, 16>(out, a, panel, row, col, k, n),
        (1, 32) => kern::<1, 32>(out, a, panel, row, col, k, n),
        (2, 4) => kern::<2, 4>(out, a, panel, row, col, k, n),
        (2, 8) => kern::<2, 8>(out, a, panel, row, col, k, n),
        (2, 16) => kern::<2, 16>(out, a, panel, row, col, k, n),
        (2, 32) => kern::<2, 32>(out, a, panel, row, col, k, n),
        (4, 4) => kern::<4, 4>(out, a, panel, row, col, k, n),
        (4, 8) => kern::<4, 8>(out, a, panel, row, col, k, n),
        (4, 16) => kern::<4, 16>(out, a, panel, row, col, k, n),
        (4, 32) => kern::<4, 32>(out, a, panel, row, col, k, n),
        (8, 4) => kern::<8, 4>(out, a, panel, row, col, k, n),
        (8, 8) => kern::<8, 8>(out, a, panel, row, col, k, n),
        (8, 16) => kern::<8, 16>(out, a, panel, row, col, k, n),
        (8, 32) => kern::<8, 32>(out, a, panel, row, col, k, n),
        _ => kern_dyn(out, a, panel, row, col, k, n, mre, w),
    }
}

/// Fully-unrolled `MR x W` register block (compile-time instantiation
/// selected at runtime by [`kern_block`]). Same k-ascending accumulation
/// as the fallback and the scalar oracle.
#[inline]
fn kern<const MR: usize, const W: usize>(
    out: &mut [f32],
    a: &[f32],
    panel: &[f32],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(panel.len(), k * W);
    let mut acc = [[0.0f32; W]; MR];
    for (i, acc_row) in acc.iter_mut().enumerate() {
        let base = (row + i) * n + col;
        acc_row.copy_from_slice(&out[base..base + W]);
    }
    for (kk, bp) in panel.chunks_exact(W).enumerate() {
        for (i, acc_row) in acc.iter_mut().enumerate() {
            let av = a[(row + i) * k + kk];
            for (o, bv) in acc_row.iter_mut().zip(bp) {
                *o += av * bv;
            }
        }
    }
    for (i, acc_row) in acc.iter().enumerate() {
        let base = (row + i) * n + col;
        out[base..base + W].copy_from_slice(acc_row);
    }
}

/// Dynamic block: `mre <= MR_MAX` rows by `w <= NR_MAX` panel columns,
/// same k-ascending accumulation as [`kern`].
#[allow(clippy::too_many_arguments)] // micro-kernel ABI: block coords + dims
fn kern_dyn(
    out: &mut [f32],
    a: &[f32],
    panel: &[f32],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
    mre: usize,
    w: usize,
) {
    debug_assert!(mre <= MR_MAX && w <= NR_MAX);
    let mut acc = [[0.0f32; NR_MAX]; MR_MAX];
    for (i, acc_row) in acc.iter_mut().enumerate().take(mre) {
        let base = (row + i) * n + col;
        acc_row[..w].copy_from_slice(&out[base..base + w]);
    }
    for (kk, bp) in panel.chunks_exact(w).enumerate() {
        for (i, acc_row) in acc.iter_mut().enumerate().take(mre) {
            let av = a[(row + i) * k + kk];
            for (o, bv) in acc_row.iter_mut().zip(bp) {
                *o += av * bv;
            }
        }
    }
    for (i, acc_row) in acc.iter().enumerate().take(mre) {
        let base = (row + i) * n + col;
        out[base..base + w].copy_from_slice(&acc_row[..w]);
    }
}

/// Int8 twin of [`kern_block`]: vector ISA first (via
/// [`simd::kern_block_simd_i8`]), then the monomorphized scalar int8
/// blocks for candidate-set pairs, then the dynamic fallback. All paths
/// are exactly equal — integer accumulation has no rounding to order.
#[inline]
#[allow(clippy::too_many_arguments)] // micro-kernel ABI: block coords + dims
fn kern_block_i8(
    out: &mut [i32],
    a: &[i8],
    panel: &[i8],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
    mre: usize,
    w: usize,
    isa: Isa,
) {
    if isa != Isa::Scalar && simd::kern_block_simd_i8(isa, out, a, panel, row, col, k, n, mre, w) {
        return;
    }
    match (mre, w) {
        (1, 4) => kern_i8::<1, 4>(out, a, panel, row, col, k, n),
        (1, 8) => kern_i8::<1, 8>(out, a, panel, row, col, k, n),
        (1, 16) => kern_i8::<1, 16>(out, a, panel, row, col, k, n),
        (1, 32) => kern_i8::<1, 32>(out, a, panel, row, col, k, n),
        (2, 4) => kern_i8::<2, 4>(out, a, panel, row, col, k, n),
        (2, 8) => kern_i8::<2, 8>(out, a, panel, row, col, k, n),
        (2, 16) => kern_i8::<2, 16>(out, a, panel, row, col, k, n),
        (2, 32) => kern_i8::<2, 32>(out, a, panel, row, col, k, n),
        (4, 4) => kern_i8::<4, 4>(out, a, panel, row, col, k, n),
        (4, 8) => kern_i8::<4, 8>(out, a, panel, row, col, k, n),
        (4, 16) => kern_i8::<4, 16>(out, a, panel, row, col, k, n),
        (4, 32) => kern_i8::<4, 32>(out, a, panel, row, col, k, n),
        (8, 4) => kern_i8::<8, 4>(out, a, panel, row, col, k, n),
        (8, 8) => kern_i8::<8, 8>(out, a, panel, row, col, k, n),
        (8, 16) => kern_i8::<8, 16>(out, a, panel, row, col, k, n),
        (8, 32) => kern_i8::<8, 32>(out, a, panel, row, col, k, n),
        _ => kern_dyn_i8(out, a, panel, row, col, k, n, mre, w),
    }
}

/// Fully-unrolled `MR x W` int8 register block: i32 accumulators,
/// k-ascending. With |q| <= 127 each product fits i16 and the i32 sum
/// cannot overflow for any realistic contraction depth (K < 2^17).
#[inline]
fn kern_i8<const MR: usize, const W: usize>(
    out: &mut [i32],
    a: &[i8],
    panel: &[i8],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(panel.len(), k * W);
    let mut acc = [[0i32; W]; MR];
    for (i, acc_row) in acc.iter_mut().enumerate() {
        let base = (row + i) * n + col;
        acc_row.copy_from_slice(&out[base..base + W]);
    }
    for (kk, bp) in panel.chunks_exact(W).enumerate() {
        for (i, acc_row) in acc.iter_mut().enumerate() {
            let av = a[(row + i) * k + kk] as i32;
            for (o, bv) in acc_row.iter_mut().zip(bp) {
                *o += av * *bv as i32;
            }
        }
    }
    for (i, acc_row) in acc.iter().enumerate() {
        let base = (row + i) * n + col;
        out[base..base + W].copy_from_slice(acc_row);
    }
}

/// Dynamic int8 block (ragged edges, exotic fixed geometries), same
/// exact i32 accumulation as [`kern_i8`].
#[allow(clippy::too_many_arguments)] // micro-kernel ABI: block coords + dims
fn kern_dyn_i8(
    out: &mut [i32],
    a: &[i8],
    panel: &[i8],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
    mre: usize,
    w: usize,
) {
    debug_assert!(mre <= MR_MAX && w <= NR_MAX);
    let mut acc = [[0i32; NR_MAX]; MR_MAX];
    for (i, acc_row) in acc.iter_mut().enumerate().take(mre) {
        let base = (row + i) * n + col;
        acc_row[..w].copy_from_slice(&out[base..base + w]);
    }
    for (kk, bp) in panel.chunks_exact(w).enumerate() {
        for (i, acc_row) in acc.iter_mut().enumerate().take(mre) {
            let av = a[(row + i) * k + kk] as i32;
            for (o, bv) in acc_row.iter_mut().zip(bp) {
                *o += av * *bv as i32;
            }
        }
    }
    for (i, acc_row) in acc.iter().enumerate().take(mre) {
        let base = (row + i) * n + col;
        out[base..base + w].copy_from_slice(&acc_row[..w]);
    }
}

/// Quantized GEMM with fused dequant:
/// `out (M, N) += dequant(qa (M, K) @ qb (K, N))`, where `qb` is packed
/// by [`pack_panels`] at `geo.nr`, `sa[m]` is row `m`'s activation
/// scale, and `wscale[n]` is column `n`'s weight scale.
///
/// Accumulation is exact i32 inside one register-tile-sized scratch
/// per block; the dequant epilogue drains that tile straight into the
/// f32 `out` (`out += tile * sa[row] * wscale[col]`), so `out` keeps
/// the same "arrives holding the accumulation base" contract as
/// [`matmul_packed`] — bias preloads and two-GEMM accumulation work
/// unchanged — and no `(M, N)` i32 buffer ever exists. The epilogue is
/// shared scalar code, so the whole quant path is bit-identical across
/// ISAs, geometries, and thread counts (integer dots are exact; the
/// epilogue rounds identically in the same order per element).
#[allow(clippy::too_many_arguments)] // GEMM ABI + the two scale vectors
pub fn matmul_quant(
    out: &mut [f32],
    qa: &[i8],
    sa: &[f32],
    qpanels: &[i8],
    wscale: &[f32],
    m: usize,
    k: usize,
    n: usize,
    geo: &KernelGeometry,
) {
    debug_assert_eq!(out.len(), m * n);
    debug_assert_eq!(qa.len(), m * k);
    debug_assert_eq!(sa.len(), m);
    debug_assert_eq!(qpanels.len(), k * n);
    debug_assert_eq!(wscale.len(), n);
    let mr = geo.mr.clamp(1, MR_MAX);
    let nr = geo.nr.clamp(1, NR_MAX);
    let isa = if geo.isa.available() {
        geo.isa
    } else {
        Isa::Scalar
    };
    // One register-tile-sized i32 scratch, reused for every block: the
    // fused dequant drains it before the next block runs.
    let mut tile = [0i32; MR_MAX * NR_MAX];
    let mut col = 0;
    let mut poff = 0;
    while col < n {
        let w = nr.min(n - col);
        let panel = &qpanels[poff..poff + k * w];
        let mut row = 0;
        while row < m {
            let mre = mr.min(m - row);
            let t = &mut tile[..mre * w];
            t.fill(0);
            // The block ABI addresses `out` at row-stride `n` from
            // (row, col); re-basing both operands onto the tile's origin
            // lets the shared block kernels serve the i32 scratch.
            let a_sub = &qa[row * k..(row + mre) * k];
            I8Panel::block(t, a_sub, panel, 0, 0, k, w, mre, w, isa);
            for i in 0..mre {
                let s = sa[row + i];
                let obase = (row + i) * n + col;
                for j in 0..w {
                    out[obase + j] += t[i * w + j] as f32 * (s * wscale[col + j]);
                }
            }
            row += mre;
        }
        poff += k * w;
        col += w;
    }
}

/// Row-parallel [`matmul_quant`], split exactly like
/// [`matmul_packed_mt`]: contiguous row chunks, each output element
/// produced by one serial block + epilogue, bit-identical to the serial
/// quant path for any thread count.
#[allow(clippy::too_many_arguments)] // GEMM ABI + scales + the thread knob
pub fn matmul_quant_mt(
    out: &mut [f32],
    qa: &[i8],
    sa: &[f32],
    qpanels: &[i8],
    wscale: &[f32],
    m: usize,
    k: usize,
    n: usize,
    geo: &KernelGeometry,
    threads: usize,
) {
    let t = threads.clamp(1, m.max(1));
    if t <= 1 {
        matmul_quant(out, qa, sa, qpanels, wscale, m, k, n, geo);
        return;
    }
    let rows_per = m.div_ceil(t);
    std::thread::scope(|s| {
        for ((oc, ac), sc) in out
            .chunks_mut(rows_per * n)
            .zip(qa.chunks(rows_per * k))
            .zip(sa.chunks(rows_per))
        {
            s.spawn(move || {
                matmul_quant(oc, ac, sc, qpanels, wscale, oc.len() / n, k, n, geo);
            });
        }
    });
}

/// Row-parallel [`matmul_packed`]: M is split into `threads` contiguous
/// row chunks executed under `std::thread::scope`. Every output element
/// is still produced by exactly one serial micro-kernel call, so the
/// result is bit-identical to the serial path for any thread count and
/// any geometry.
#[allow(clippy::too_many_arguments)] // GEMM ABI + the two runtime knobs
pub fn matmul_packed_mt(
    out: &mut [f32],
    a: &[f32],
    packed_b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    geo: &KernelGeometry,
    threads: usize,
) {
    let t = threads.clamp(1, m.max(1));
    if t <= 1 {
        matmul_packed(out, a, packed_b, m, k, n, geo);
        return;
    }
    let rows_per = m.div_ceil(t);
    std::thread::scope(|s| {
        for (oc, ac) in out.chunks_mut(rows_per * n).zip(a.chunks(rows_per * k)) {
            s.spawn(move || {
                matmul_packed(oc, ac, packed_b, oc.len() / n, k, n, geo);
            });
        }
    });
}

/// How many threads a `(M, K, N)` GEMM is actually worth: capped so every
/// thread gets at least two rows and at least `min_flops_per_thread`
/// FLOPs of work (scoped thread spawns cost tens of microseconds; a tiny
/// recurrent MVM must stay serial or the spawn overhead eats the win).
/// The threshold is the planner knob [`KernelGeometry::min_flops_per_thread`]
/// — no longer a buried constant; default and rationale at
/// [`crate::runtime::plan::DEFAULT_MIN_FLOPS_PER_THREAD`].
pub fn effective_threads(
    threads: usize,
    m: usize,
    k: usize,
    n: usize,
    min_flops_per_thread: usize,
) -> usize {
    if threads <= 1 || m < 4 {
        return 1;
    }
    let flops = 2usize.saturating_mul(m).saturating_mul(k).saturating_mul(n);
    threads
        .min(m / 2)
        .min((flops / min_flops_per_thread.max(1)).max(1))
        .max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::exec::matmul_acc;
    use crate::runtime::plan::DEFAULT_MIN_FLOPS_PER_THREAD;
    use crate::util::rng::Rng;

    fn check_shape(m: usize, k: usize, n: usize, geo: &KernelGeometry, threads: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let a = rng.vec_f32(m * k, -1.0, 1.0);
        let b = rng.vec_f32(k * n, -1.0, 1.0);
        let base = rng.vec_f32(m * n, -0.5, 0.5);

        let mut want = base.clone();
        matmul_acc(&mut want, &a, &b, m, k, n);

        let mut packed = Vec::new();
        pack_b(&b, k, n, geo.nr, &mut packed);
        assert_eq!(packed.len(), k * n);
        let mut got = base.clone();
        matmul_packed_mt(&mut got, &a, &packed, m, k, n, geo, threads);

        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                g.to_bits(),
                w.to_bits(),
                "({m},{k},{n}) geo={}x{} threads={threads} element {i}: {g} vs {w}",
                geo.mr,
                geo.nr
            );
        }
    }

    #[test]
    fn packed_matches_scalar_bitwise_over_edge_shapes_and_geometries() {
        // Aligned, sub-tile, and ragged M/N/K, serial and threaded, across
        // the whole geometry candidate grid (incl. tiles larger than the
        // matrix: every block then runs the edge path), under every ISA
        // this host can execute (vector blocks where the width aligns,
        // scalar fallback on the lane-unaligned remainder).
        let shapes = [
            (1, 1, 1),
            (1, 7, 16),
            (4, 8, 16),
            (8, 16, 32),
            (3, 5, 7),
            (5, 3, 17),
            (6, 9, 31),
            (9, 2, 33),
            (13, 21, 50),
            (2, 40, 15),
        ];
        for isa in Isa::supported() {
            for &(m, k, n) in &shapes {
                for &(mr, nr) in &[(4, 16), (1, 4), (2, 8), (8, 32), (8, 4), (1, 32), (3, 5)] {
                    let geo = KernelGeometry::new(mr, nr).unwrap().with_isa(isa);
                    check_shape(m, k, n, &geo, 1, 11 + (m * mr) as u64);
                    check_shape(m, k, n, &geo, 4, 23 + (n * nr) as u64);
                }
            }
        }
    }

    #[test]
    fn unavailable_isa_downgrades_to_scalar_without_panicking() {
        // A hand-built geometry claiming the vector ISA of the *other*
        // architecture must run (scalar) and still match the oracle —
        // the defensive downgrade in `matmul_packed`, not UB.
        let missing = Isa::ALL
            .into_iter()
            .find(|isa| !isa.available())
            .expect("avx2 and neon are never both available");
        let geo = KernelGeometry::new(4, 16).unwrap().with_isa(missing);
        check_shape(13, 21, 50, &geo, 1, 77);
        check_shape(13, 21, 50, &geo, 4, 78);
    }

    #[test]
    fn pack_b_is_panel_major_and_unpack_inverts_it() {
        // 2x3 matrix with nr=16: one ragged panel of width 3, k-major.
        let b = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut packed = Vec::new();
        pack_b(&b, 2, 3, 16, &mut packed);
        assert_eq!(packed, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        // nr=2: panels [cols 0..2] then [col 2], k-major within each.
        pack_b(&b, 2, 3, 2, &mut packed);
        assert_eq!(packed, vec![1.0, 2.0, 4.0, 5.0, 3.0, 6.0]);
        // Round-trip across widths on a bigger matrix.
        let mut rng = Rng::new(3);
        let big = rng.vec_f32(7 * 45, -1.0, 1.0);
        let mut dense = Vec::new();
        for nr in [1, 3, 8, 16, 32] {
            pack_b(&big, 7, 45, nr, &mut packed);
            unpack_b(&packed, 7, 45, nr, &mut dense);
            assert_eq!(dense, big, "nr={nr}");
        }
    }

    /// Naive reference for the quant path: plain i32 dots, then the
    /// exact dequant expression the fused epilogue uses
    /// (`base + dot_f32 * (sa * wscale)`), so agreement is per-bit.
    fn quant_ref(
        base: &[f32],
        qa: &[i8],
        sa: &[f32],
        qb: &[i8],
        wscale: &[f32],
        m: usize,
        k: usize,
        n: usize,
    ) -> Vec<f32> {
        let mut out = base.to_vec();
        for i in 0..m {
            for j in 0..n {
                let mut dot = 0i32;
                for kk in 0..k {
                    dot += qa[i * k + kk] as i32 * qb[kk * n + j] as i32;
                }
                out[i * n + j] += dot as f32 * (sa[i] * wscale[j]);
            }
        }
        out
    }

    #[test]
    fn quant_matmul_matches_the_integer_reference_per_bit() {
        // The int8 path's internal bit-exactness claim: every ISA,
        // geometry, and thread count produces the identical f32 output,
        // because the i32 dots are exact and the dequant epilogue is one
        // shared scalar expression per element.
        let shapes = [(1, 1, 1), (3, 5, 7), (4, 8, 16), (9, 2, 33), (13, 21, 50)];
        let mut rng = Rng::new(0x0108);
        for &(m, k, n) in &shapes {
            let qa: Vec<i8> = (0..m * k).map(|_| rng.range_usize(0, 254) as i8).collect();
            let qb: Vec<i8> = (0..k * n).map(|_| rng.range_usize(0, 254) as i8).collect();
            let sa = rng.vec_f32(m, 0.001, 0.02);
            let wscale = rng.vec_f32(n, 0.001, 0.02);
            let base = rng.vec_f32(m * n, -0.5, 0.5);
            let want = quant_ref(&base, &qa, &sa, &qb, &wscale, m, k, n);
            for isa in Isa::supported() {
                for &(mr, nr) in &[(4, 16), (1, 4), (2, 8), (8, 32), (3, 5)] {
                    let geo = KernelGeometry::new(mr, nr).unwrap().with_isa(isa);
                    let mut packed = Vec::new();
                    pack_panels(&qb, k, n, geo.nr, &mut packed);
                    for threads in [1, 4] {
                        let mut got = base.clone();
                        matmul_quant_mt(
                            &mut got, &qa, &sa, &packed, &wscale, m, k, n, &geo, threads,
                        );
                        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                            assert_eq!(
                                g.to_bits(),
                                w.to_bits(),
                                "({m},{k},{n}) {isa:?} geo={mr}x{nr} t={threads} elt {i}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn quant_extremes_survive_the_whole_dispatch() {
        // Saturated weights/activations (±127) at the widest tile: the
        // products hit ±16129 and must accumulate exactly on every path.
        let (m, k, n) = (8, 64, 32);
        let qa = vec![127i8; m * k];
        let mut qb = vec![-127i8; k * n];
        for (i, v) in qb.iter_mut().enumerate() {
            if i % 2 == 0 {
                *v = 127;
            }
        }
        let sa = vec![0.01f32; m];
        let wscale = vec![0.02f32; n];
        let base = vec![0.0f32; m * n];
        let want = quant_ref(&base, &qa, &sa, &qb, &wscale, m, k, n);
        for isa in Isa::supported() {
            let geo = KernelGeometry::new(8, 32).unwrap().with_isa(isa);
            let mut packed = Vec::new();
            pack_panels(&qb, k, n, geo.nr, &mut packed);
            let mut got = base.clone();
            matmul_quant(&mut got, &qa, &sa, &packed, &wscale, m, k, n, &geo);
            assert_eq!(got, want, "{isa:?}");
        }
    }

    #[test]
    fn pack_panels_roundtrips_i8() {
        let mut rng = Rng::new(9);
        let b: Vec<i8> = (0..7 * 45).map(|_| rng.range_usize(0, 254) as i8).collect();
        let mut packed = Vec::new();
        let mut dense = Vec::new();
        for nr in [1, 3, 8, 16, 32] {
            pack_panels(&b, 7, 45, nr, &mut packed);
            assert_eq!(packed.len(), b.len());
            unpack_panels(&packed, 7, 45, nr, &mut dense);
            assert_eq!(dense, b, "nr={nr}");
        }
    }

    #[test]
    fn effective_threads_gates_small_work() {
        let gate = DEFAULT_MIN_FLOPS_PER_THREAD;
        // Tiny recurrent MVM stays serial.
        assert_eq!(effective_threads(8, 1, 256, 1024, gate), 1);
        assert_eq!(effective_threads(8, 2, 256, 1024, gate), 1);
        // Big input GEMM fans out, capped at m/2.
        assert!(effective_threads(8, 64, 1024, 4096, gate) > 1);
        assert_eq!(effective_threads(16, 8, 4096, 4096, gate), 4);
        // threads=1 is always serial.
        assert_eq!(effective_threads(1, 1000, 1000, 1000, gate), 1);
    }

    #[test]
    fn thread_gate_knob_moves_the_serial_parallel_crossover() {
        // The satellite contract: the gate is a knob, not magic. A GEMM
        // right at the default boundary flips serial<->parallel as the
        // threshold moves around its FLOP count (2*m*k*n = 2^23 here,
        // i.e. two default-gate units of work).
        let (m, k, n) = (64, 256, 256);
        let flops = 2 * m * k * n;
        assert_eq!(flops, 1 << 23);
        // Default gate (2^22): exactly 2 threads' worth of work.
        assert_eq!(effective_threads(8, m, k, n, DEFAULT_MIN_FLOPS_PER_THREAD), 2);
        // Gate raised above the total work: serial again.
        assert_eq!(effective_threads(8, m, k, n, flops + 1), 1);
        // Gate lowered: the fan-out is released up to the other caps.
        assert_eq!(effective_threads(8, m, k, n, 1 << 20), 8);
        // Degenerate knob value must not divide by zero.
        assert_eq!(effective_threads(8, m, k, n, 0), 8);
    }
}
