//! Sequence schedules over the tiled GEMM, selected by the execution
//! plan: the paper-§5 *Unfolded* schedule (hoist the input MVM out of
//! the recurrence) and the *Stepwise* schedule (per-step projection, no
//! sequence-sized buffer — what T=1 cell artifacts and streaming chunks
//! want).
//!
//! ```text
//!   scalar reference (exec.rs)        unfolded            stepwise
//!   --------------------------        ------------------  ------------------
//!   for t in 0..T:                    pre (T*B,G*H)=bias  for t in 0..T:
//!     pre = bias                      pre += xs@Wx  ONE     pre (B,G*H)=bias
//!     pre += x_t (B,D) @ Wx           for t in 0..T:        pre += x_t@Wx
//!     pre += h  (B,H) @ Wh              pre_t += h@Wh       pre += h@Wh
//!     h, c = activate(pre, c)           activate            activate
//! ```
//!
//! Bit-exactness: under EITHER schedule, for every gate element the
//! accumulation is `bias`, then `x` contributions k = 0..D ascending,
//! then `h` contributions k = 0..H ascending — hoisting the input GEMM
//! batches rows (independent dot products), never reorders a dot, and
//! the stepwise schedule is literally the scalar reference's issue
//! order. The GEMM itself tiles over M/N only for every planner
//! geometry (`gemm`), and the activation code is the SAME function the
//! scalar reference calls (`exec::lstm_cell_update`/`gru_cell_update`),
//! so every (geometry, schedule) candidate is bit-identical to the
//! scalar oracle by construction; `tests/kernel_equivalence.rs` sweeps
//! the candidate space to enforce it.
//!
//! All outputs are written into caller-owned buffers (`clear` +
//! `extend`), so the steady-state serving path allocates nothing: the
//! executable's `ExecScratch` plus a reused `LstmOutput` cover every
//! intermediate.

// Kernel entry points mirror the executor calling convention (tensors +
// shape dims + knobs), which runs past clippy's 7-argument heuristic by
// design — same waiver as `runtime::exec`.
#![allow(clippy::too_many_arguments)]

use super::gemm;
use super::scratch::{self, ExecScratch};
use crate::runtime::exec;
use crate::runtime::plan::{ExecPlan, Schedule};

/// Full-sequence LSTM on the tiled kernel. `xs` is `(T, B, D)`; writes
/// `hs (T, B, H)`, `h_T (B, H)`, `c_T (B, H)` into the caller's buffers.
/// `plan` carries the register-tile geometry, thread gate, and schedule;
/// `threads` bounds the row-parallel fan-out (1 = serial; the effective
/// count is work-gated per GEMM, see [`gemm::effective_threads`]).
pub fn lstm_seq_into(
    xs: &[f32],
    h0: &[f32],
    c0: &[f32],
    wx: &[f32],
    wh: &[f32],
    bias: &[f32],
    t: usize,
    b: usize,
    d: usize,
    hid: usize,
    plan: &ExecPlan,
    threads: usize,
    scr: &mut ExecScratch,
    hs: &mut Vec<f32>,
    h_t: &mut Vec<f32>,
    c_t: &mut Vec<f32>,
) {
    let gh = 4 * hid;
    debug_assert_eq!(xs.len(), t * b * d);
    debug_assert_eq!(h0.len(), b * hid);
    debug_assert_eq!(c0.len(), b * hid);
    let geo = &plan.geometry;
    scr.ensure_packed(wx, wh, d, hid, gh, geo.nr);
    let ExecScratch {
        packed_wx,
        packed_wh,
        pre,
        state_a,
        state_b,
        cell_a,
        cell_b,
        ..
    } = scr;

    scratch::fill_from(state_a, h0);
    scratch::fill_from(cell_a, c0);
    scratch::fill_zero(state_b, b * hid);
    scratch::fill_zero(cell_b, b * hid);
    hs.clear();
    hs.reserve(t * b * hid);

    let gate = geo.min_flops_per_thread;
    let nt_rec = gemm::effective_threads(threads, b, hid, gh, gate);
    match plan.schedule {
        Schedule::Unfolded => {
            // Unfolded input projection: the whole sequence in one GEMM.
            scratch::fill_bias(pre, bias, t * b, gh);
            let nt = gemm::effective_threads(threads, t * b, d, gh, gate);
            gemm::matmul_packed_mt(pre, xs, packed_wx, t * b, d, gh, geo, nt);
            // What remains of the dependent serialization: one small
            // (B, H) x (H, G*H) MVM plus the cell update per step.
            for step in 0..t {
                let pre_t = &mut pre[step * b * gh..(step + 1) * b * gh];
                gemm::matmul_packed_mt(pre_t, state_a, packed_wh, b, hid, gh, geo, nt_rec);
                exec::lstm_cell_update(pre_t, cell_a, state_b, cell_b, b, hid);
                hs.extend_from_slice(state_b);
                std::mem::swap(state_a, state_b);
                std::mem::swap(cell_a, cell_b);
            }
        }
        Schedule::Stepwise => {
            // Per-step projection into a (B, G*H) buffer — the scalar
            // reference's own issue order, without the sequence-sized
            // scratch.
            let nt_in = gemm::effective_threads(threads, b, d, gh, gate);
            for step in 0..t {
                let x_t = &xs[step * b * d..(step + 1) * b * d];
                scratch::fill_bias(pre, bias, b, gh);
                gemm::matmul_packed_mt(pre, x_t, packed_wx, b, d, gh, geo, nt_in);
                gemm::matmul_packed_mt(pre, state_a, packed_wh, b, hid, gh, geo, nt_rec);
                exec::lstm_cell_update(pre, cell_a, state_b, cell_b, b, hid);
                hs.extend_from_slice(state_b);
                std::mem::swap(state_a, state_b);
                std::mem::swap(cell_a, cell_b);
            }
        }
    }
    scratch::fill_from(h_t, state_a);
    scratch::fill_from(c_t, cell_a);
}

/// Full-sequence GRU on the tiled kernel ("linear before reset", so the
/// input half hoists exactly like the LSTM's). Writes `hs (T, B, H)`
/// and `h_T (B, H)` into the caller's buffers.
pub fn gru_seq_into(
    xs: &[f32],
    h0: &[f32],
    wx: &[f32],
    wh: &[f32],
    bias: &[f32],
    t: usize,
    b: usize,
    d: usize,
    hid: usize,
    plan: &ExecPlan,
    threads: usize,
    scr: &mut ExecScratch,
    hs: &mut Vec<f32>,
    h_t: &mut Vec<f32>,
) {
    let gh = 3 * hid;
    debug_assert_eq!(xs.len(), t * b * d);
    debug_assert_eq!(h0.len(), b * hid);
    let geo = &plan.geometry;
    scr.ensure_packed(wx, wh, d, hid, gh, geo.nr);
    let ExecScratch {
        packed_wx,
        packed_wh,
        pre,
        hpre,
        state_a,
        state_b,
        ..
    } = scr;

    scratch::fill_from(state_a, h0);
    scratch::fill_zero(state_b, b * hid);
    hs.clear();
    hs.reserve(t * b * hid);

    let gate = geo.min_flops_per_thread;
    let nt_rec = gemm::effective_threads(threads, b, hid, gh, gate);
    match plan.schedule {
        Schedule::Unfolded => {
            scratch::fill_bias(pre, bias, t * b, gh);
            let nt = gemm::effective_threads(threads, t * b, d, gh, gate);
            gemm::matmul_packed_mt(pre, xs, packed_wx, t * b, d, gh, geo, nt);
            for step in 0..t {
                let xpre_t = &pre[step * b * gh..(step + 1) * b * gh];
                scratch::fill_zero(hpre, b * gh);
                gemm::matmul_packed_mt(hpre, state_a, packed_wh, b, hid, gh, geo, nt_rec);
                exec::gru_cell_update(xpre_t, hpre, state_a, state_b, b, hid);
                hs.extend_from_slice(state_b);
                std::mem::swap(state_a, state_b);
            }
        }
        Schedule::Stepwise => {
            let nt_in = gemm::effective_threads(threads, b, d, gh, gate);
            for step in 0..t {
                let x_t = &xs[step * b * d..(step + 1) * b * d];
                scratch::fill_bias(pre, bias, b, gh);
                gemm::matmul_packed_mt(pre, x_t, packed_wx, b, d, gh, geo, nt_in);
                scratch::fill_zero(hpre, b * gh);
                gemm::matmul_packed_mt(hpre, state_a, packed_wh, b, hid, gh, geo, nt_rec);
                exec::gru_cell_update(pre, hpre, state_a, state_b, b, hid);
                hs.extend_from_slice(state_b);
                std::mem::swap(state_a, state_b);
            }
        }
    }
    scratch::fill_from(h_t, state_a);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::literal::assert_bits_eq;
    use crate::runtime::plan::KernelGeometry;
    use crate::util::rng::Rng;

    fn plans_under_test() -> Vec<ExecPlan> {
        let mut out = Vec::new();
        for schedule in [Schedule::Unfolded, Schedule::Stepwise] {
            for (mr, nr) in [(4, 16), (1, 8), (8, 32)] {
                out.push(ExecPlan {
                    geometry: KernelGeometry::new(mr, nr).unwrap(),
                    schedule,
                });
            }
        }
        out
    }

    #[test]
    fn lstm_schedules_match_scalar_oracle() {
        let (t, b, d, hid) = (5usize, 3usize, 7usize, 17usize);
        let mut rng = Rng::new(77);
        let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
        let h0 = rng.vec_f32(b * hid, -1.0, 1.0);
        let c0 = rng.vec_f32(b * hid, -1.0, 1.0);
        let wx = rng.vec_f32(d * 4 * hid, -0.3, 0.3);
        let wh = rng.vec_f32(hid * 4 * hid, -0.3, 0.3);
        let bias = rng.vec_f32(4 * hid, -0.2, 0.2);

        let (hs_ref, h_ref, c_ref) = exec::lstm_seq(&xs, &h0, &c0, &wx, &wh, &bias, t, b, d, hid);
        for plan in plans_under_test() {
            for threads in [1usize, 3] {
                let mut scr = ExecScratch::new();
                let (mut hs, mut h_t, mut c_t) = (Vec::new(), Vec::new(), Vec::new());
                lstm_seq_into(
                    &xs,
                    &h0,
                    &c0,
                    &wx,
                    &wh,
                    &bias,
                    t,
                    b,
                    d,
                    hid,
                    &plan,
                    threads,
                    &mut scr,
                    &mut hs,
                    &mut h_t,
                    &mut c_t,
                );
                let ctx = format!("{} threads={threads}", plan.describe());
                assert_bits_eq(&hs, &hs_ref, &format!("{ctx}: hs"));
                assert_bits_eq(&h_t, &h_ref, &format!("{ctx}: h_t"));
                assert_bits_eq(&c_t, &c_ref, &format!("{ctx}: c_t"));
            }
        }
    }

    #[test]
    fn t1_cell_case_matches_scalar_step_under_both_schedules() {
        // The cell-artifact path runs the same kernel with T=1.
        let (b, d, hid) = (2usize, 4usize, 13usize);
        let mut rng = Rng::new(31);
        let x = rng.vec_f32(b * d, -1.0, 1.0);
        let h0 = rng.vec_f32(b * hid, -1.0, 1.0);
        let c0 = rng.vec_f32(b * hid, -1.0, 1.0);
        let wx = rng.vec_f32(d * 4 * hid, -0.3, 0.3);
        let wh = rng.vec_f32(hid * 4 * hid, -0.3, 0.3);
        let bias = rng.vec_f32(4 * hid, -0.2, 0.2);

        let (h_ref, c_ref) = exec::lstm_step(&x, &h0, &c0, &wx, &wh, &bias, b, d, hid);
        for schedule in [Schedule::Unfolded, Schedule::Stepwise] {
            let plan = ExecPlan::fixed_default().with_schedule(schedule);
            let mut scr = ExecScratch::new();
            let (mut hs, mut h_t, mut c_t) = (Vec::new(), Vec::new(), Vec::new());
            lstm_seq_into(
                &x,
                &h0,
                &c0,
                &wx,
                &wh,
                &bias,
                1,
                b,
                d,
                hid,
                &plan,
                1,
                &mut scr,
                &mut hs,
                &mut h_t,
                &mut c_t,
            );
            assert_bits_eq(&hs, &h_ref, "hs");
            assert_bits_eq(&h_t, &h_ref, "h_t");
            assert_bits_eq(&c_t, &c_ref, "c_t");
        }
    }

    #[test]
    fn gru_schedules_match_scalar_oracle() {
        let (t, b, d, hid) = (4usize, 2usize, 5usize, 19usize);
        let mut rng = Rng::new(123);
        let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
        let h0 = rng.vec_f32(b * hid, -1.0, 1.0);
        let wx = rng.vec_f32(d * 3 * hid, -0.3, 0.3);
        let wh = rng.vec_f32(hid * 3 * hid, -0.3, 0.3);
        let bias = rng.vec_f32(3 * hid, -0.2, 0.2);

        let (hs_ref, h_ref) = exec::gru_seq(&xs, &h0, &wx, &wh, &bias, t, b, d, hid);
        for plan in plans_under_test() {
            let mut scr = ExecScratch::new();
            let (mut hs, mut h_t) = (Vec::new(), Vec::new());
            gru_seq_into(
                &xs,
                &h0,
                &wx,
                &wh,
                &bias,
                t,
                b,
                d,
                hid,
                &plan,
                1,
                &mut scr,
                &mut hs,
                &mut h_t,
            );
            let ctx = plan.describe();
            assert_bits_eq(&hs, &hs_ref, &format!("{ctx}: hs"));
            assert_bits_eq(&h_t, &h_ref, &format!("{ctx}: h_t"));
        }
    }

    #[test]
    fn scratch_reuse_across_calls_and_schedules_is_stable() {
        // The serving pattern: one executable, many requests — later
        // calls reuse packed panels and warmed buffers and must still be
        // bit-identical (including a SHORTER prefix after a longer run,
        // and a schedule flip mid-stream, which is what the streaming
        // T=1 override does).
        let (t, b, d, hid) = (6usize, 2usize, 4usize, 9usize);
        let mut rng = Rng::new(5);
        let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
        let h0 = rng.vec_f32(b * hid, -1.0, 1.0);
        let c0 = rng.vec_f32(b * hid, -1.0, 1.0);
        let wx = rng.vec_f32(d * 4 * hid, -0.3, 0.3);
        let wh = rng.vec_f32(hid * 4 * hid, -0.3, 0.3);
        let bias = rng.vec_f32(4 * hid, -0.2, 0.2);

        let mut scr = ExecScratch::new();
        let (mut hs, mut h_t, mut c_t) = (Vec::new(), Vec::new(), Vec::new());
        let base = ExecPlan::fixed_default();
        for (steps, schedule) in [
            (t, Schedule::Unfolded),
            (2, Schedule::Stepwise),
            (t, Schedule::Unfolded),
            (1, Schedule::Stepwise),
        ] {
            let (hs_ref, h_ref, c_ref) =
                exec::lstm_seq(&xs[..steps * b * d], &h0, &c0, &wx, &wh, &bias, steps, b, d, hid);
            lstm_seq_into(
                &xs[..steps * b * d],
                &h0,
                &c0,
                &wx,
                &wh,
                &bias,
                steps,
                b,
                d,
                hid,
                &base.with_schedule(schedule),
                1,
                &mut scr,
                &mut hs,
                &mut h_t,
                &mut c_t,
            );
            assert_bits_eq(&hs, &hs_ref, "hs");
            assert_bits_eq(&h_t, &h_ref, "h_t");
            assert_bits_eq(&c_t, &c_ref, "c_t");
        }
    }
}
