//! The unfolded sequence schedule over the tiled GEMM (paper §5: hoist
//! the input MVM out of the recurrence, pipeline what remains).
//!
//! ```text
//!   scalar reference (exec.rs)          unfolded kernel (this module)
//!   ---------------------------         -----------------------------
//!   for t in 0..T:                      pre (T*B, G*H) = bias
//!     pre = bias                        pre += xs (T*B, D) @ Wx   ONE GEMM
//!     pre += x_t (B, D)  @ Wx           for t in 0..T:
//!     pre += h   (B, H)  @ Wh             pre_t += h (B, H) @ Wh  small MVM
//!     h, c = activate(pre, c)             h, c = activate(pre_t, c)
//!   ```
//!
//! Bit-exactness: for every gate element the accumulation is still
//! `bias`, then `x` contributions k = 0..D ascending, then `h`
//! contributions k = 0..H ascending — hoisting the input GEMM batches
//! rows (independent dot products), never reorders a dot. The GEMM
//! itself tiles over M/N only (`gemm`), and the activation code is the
//! SAME function the scalar reference calls (`exec::lstm_cell_update`/
//! `gru_cell_update`), so the tiled path is bit-identical to the scalar
//! oracle by construction; `tests/kernel_equivalence.rs` sweeps shapes
//! to enforce it.
//!
//! All outputs are written into caller-owned buffers (`clear` +
//! `extend`), so the steady-state serving path allocates nothing: the
//! executable's `ExecScratch` plus a reused `LstmOutput` cover every
//! intermediate.

// Kernel entry points mirror the executor calling convention (tensors +
// shape dims + knobs), which runs past clippy's 7-argument heuristic by
// design — same waiver as `runtime::exec`.
#![allow(clippy::too_many_arguments)]

use super::gemm;
use super::scratch::{self, ExecScratch};
use crate::runtime::exec;

/// Full-sequence LSTM on the tiled kernel. `xs` is `(T, B, D)`; writes
/// `hs (T, B, H)`, `h_T (B, H)`, `c_T (B, H)` into the caller's buffers.
/// `threads` bounds the row-parallel fan-out (1 = serial; the effective
/// count is work-gated per GEMM, see [`gemm::effective_threads`]).
pub fn lstm_seq_into(
    xs: &[f32],
    h0: &[f32],
    c0: &[f32],
    wx: &[f32],
    wh: &[f32],
    bias: &[f32],
    t: usize,
    b: usize,
    d: usize,
    hid: usize,
    threads: usize,
    scr: &mut ExecScratch,
    hs: &mut Vec<f32>,
    h_t: &mut Vec<f32>,
    c_t: &mut Vec<f32>,
) {
    let gh = 4 * hid;
    debug_assert_eq!(xs.len(), t * b * d);
    debug_assert_eq!(h0.len(), b * hid);
    debug_assert_eq!(c0.len(), b * hid);
    scr.ensure_packed(wx, wh, d, hid, gh);
    let ExecScratch {
        packed_wx,
        packed_wh,
        pre,
        state_a,
        state_b,
        cell_a,
        cell_b,
        ..
    } = scr;

    // Unfolded input projection: the whole sequence in one GEMM.
    scratch::fill_bias(pre, bias, t * b, gh);
    let nt = gemm::effective_threads(threads, t * b, d, gh);
    gemm::matmul_packed_mt(pre, xs, packed_wx, t * b, d, gh, nt);

    scratch::fill_from(state_a, h0);
    scratch::fill_from(cell_a, c0);
    scratch::fill_zero(state_b, b * hid);
    scratch::fill_zero(cell_b, b * hid);
    hs.clear();
    hs.reserve(t * b * hid);

    // What remains of the dependent serialization: one small (B, H) x
    // (H, G*H) MVM plus the cell update per step.
    let nt = gemm::effective_threads(threads, b, hid, gh);
    for step in 0..t {
        let pre_t = &mut pre[step * b * gh..(step + 1) * b * gh];
        gemm::matmul_packed_mt(pre_t, state_a, packed_wh, b, hid, gh, nt);
        exec::lstm_cell_update(pre_t, cell_a, state_b, cell_b, b, hid);
        hs.extend_from_slice(state_b);
        std::mem::swap(state_a, state_b);
        std::mem::swap(cell_a, cell_b);
    }
    scratch::fill_from(h_t, state_a);
    scratch::fill_from(c_t, cell_a);
}

/// Full-sequence GRU on the tiled kernel ("linear before reset", so the
/// input half hoists exactly like the LSTM's). Writes `hs (T, B, H)`
/// and `h_T (B, H)` into the caller's buffers.
pub fn gru_seq_into(
    xs: &[f32],
    h0: &[f32],
    wx: &[f32],
    wh: &[f32],
    bias: &[f32],
    t: usize,
    b: usize,
    d: usize,
    hid: usize,
    threads: usize,
    scr: &mut ExecScratch,
    hs: &mut Vec<f32>,
    h_t: &mut Vec<f32>,
) {
    let gh = 3 * hid;
    debug_assert_eq!(xs.len(), t * b * d);
    debug_assert_eq!(h0.len(), b * hid);
    scr.ensure_packed(wx, wh, d, hid, gh);
    let ExecScratch {
        packed_wx,
        packed_wh,
        pre,
        hpre,
        state_a,
        state_b,
        ..
    } = scr;

    scratch::fill_bias(pre, bias, t * b, gh);
    let nt = gemm::effective_threads(threads, t * b, d, gh);
    gemm::matmul_packed_mt(pre, xs, packed_wx, t * b, d, gh, nt);

    scratch::fill_from(state_a, h0);
    scratch::fill_zero(state_b, b * hid);
    hs.clear();
    hs.reserve(t * b * hid);

    let nt = gemm::effective_threads(threads, b, hid, gh);
    for step in 0..t {
        let xpre_t = &pre[step * b * gh..(step + 1) * b * gh];
        scratch::fill_zero(hpre, b * gh);
        gemm::matmul_packed_mt(hpre, state_a, packed_wh, b, hid, gh, nt);
        exec::gru_cell_update(xpre_t, hpre, state_a, state_b, b, hid);
        hs.extend_from_slice(state_b);
        std::mem::swap(state_a, state_b);
    }
    scratch::fill_from(h_t, state_a);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::literal::assert_bits_eq;
    use crate::util::rng::Rng;

    #[test]
    fn lstm_unfolded_matches_scalar_oracle() {
        let (t, b, d, hid) = (5usize, 3usize, 7usize, 17usize);
        let mut rng = Rng::new(77);
        let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
        let h0 = rng.vec_f32(b * hid, -1.0, 1.0);
        let c0 = rng.vec_f32(b * hid, -1.0, 1.0);
        let wx = rng.vec_f32(d * 4 * hid, -0.3, 0.3);
        let wh = rng.vec_f32(hid * 4 * hid, -0.3, 0.3);
        let bias = rng.vec_f32(4 * hid, -0.2, 0.2);

        let (hs_ref, h_ref, c_ref) = exec::lstm_seq(&xs, &h0, &c0, &wx, &wh, &bias, t, b, d, hid);
        for threads in [1usize, 3] {
            let mut scr = ExecScratch::new();
            let (mut hs, mut h_t, mut c_t) = (Vec::new(), Vec::new(), Vec::new());
            lstm_seq_into(
                &xs,
                &h0,
                &c0,
                &wx,
                &wh,
                &bias,
                t,
                b,
                d,
                hid,
                threads,
                &mut scr,
                &mut hs,
                &mut h_t,
                &mut c_t,
            );
            assert_bits_eq(&hs, &hs_ref, "hs");
            assert_bits_eq(&h_t, &h_ref, "h_t");
            assert_bits_eq(&c_t, &c_ref, "c_t");
        }
    }

    #[test]
    fn t1_cell_case_matches_scalar_step() {
        // The cell-artifact path runs the same kernel with T=1.
        let (b, d, hid) = (2usize, 4usize, 13usize);
        let mut rng = Rng::new(31);
        let x = rng.vec_f32(b * d, -1.0, 1.0);
        let h0 = rng.vec_f32(b * hid, -1.0, 1.0);
        let c0 = rng.vec_f32(b * hid, -1.0, 1.0);
        let wx = rng.vec_f32(d * 4 * hid, -0.3, 0.3);
        let wh = rng.vec_f32(hid * 4 * hid, -0.3, 0.3);
        let bias = rng.vec_f32(4 * hid, -0.2, 0.2);

        let (h_ref, c_ref) = exec::lstm_step(&x, &h0, &c0, &wx, &wh, &bias, b, d, hid);
        let mut scr = ExecScratch::new();
        let (mut hs, mut h_t, mut c_t) = (Vec::new(), Vec::new(), Vec::new());
        lstm_seq_into(
            &x,
            &h0,
            &c0,
            &wx,
            &wh,
            &bias,
            1,
            b,
            d,
            hid,
            1,
            &mut scr,
            &mut hs,
            &mut h_t,
            &mut c_t,
        );
        assert_bits_eq(&hs, &h_ref, "hs");
        assert_bits_eq(&h_t, &h_ref, "h_t");
        assert_bits_eq(&c_t, &c_ref, "c_t");
    }

    #[test]
    fn gru_unfolded_matches_scalar_oracle() {
        let (t, b, d, hid) = (4usize, 2usize, 5usize, 19usize);
        let mut rng = Rng::new(123);
        let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
        let h0 = rng.vec_f32(b * hid, -1.0, 1.0);
        let wx = rng.vec_f32(d * 3 * hid, -0.3, 0.3);
        let wh = rng.vec_f32(hid * 3 * hid, -0.3, 0.3);
        let bias = rng.vec_f32(3 * hid, -0.2, 0.2);

        let (hs_ref, h_ref) = exec::gru_seq(&xs, &h0, &wx, &wh, &bias, t, b, d, hid);
        let mut scr = ExecScratch::new();
        let (mut hs, mut h_t) = (Vec::new(), Vec::new());
        gru_seq_into(
            &xs,
            &h0,
            &wx,
            &wh,
            &bias,
            t,
            b,
            d,
            hid,
            1,
            &mut scr,
            &mut hs,
            &mut h_t,
        );
        assert_bits_eq(&hs, &hs_ref, "hs");
        assert_bits_eq(&h_t, &h_ref, "h_t");
    }

    #[test]
    fn scratch_reuse_across_calls_is_stable() {
        // The serving pattern: one executable, many requests — the second
        // call reuses packed panels and warmed buffers and must still be
        // bit-identical (including a SHORTER prefix after a longer run).
        let (t, b, d, hid) = (6usize, 2usize, 4usize, 9usize);
        let mut rng = Rng::new(5);
        let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
        let h0 = rng.vec_f32(b * hid, -1.0, 1.0);
        let c0 = rng.vec_f32(b * hid, -1.0, 1.0);
        let wx = rng.vec_f32(d * 4 * hid, -0.3, 0.3);
        let wh = rng.vec_f32(hid * 4 * hid, -0.3, 0.3);
        let bias = rng.vec_f32(4 * hid, -0.2, 0.2);

        let mut scr = ExecScratch::new();
        let (mut hs, mut h_t, mut c_t) = (Vec::new(), Vec::new(), Vec::new());
        for steps in [t, 2, t, 1] {
            let (hs_ref, h_ref, c_ref) =
                exec::lstm_seq(&xs[..steps * b * d], &h0, &c0, &wx, &wh, &bias, steps, b, d, hid);
            lstm_seq_into(
                &xs[..steps * b * d],
                &h0,
                &c0,
                &wx,
                &wh,
                &bias,
                steps,
                b,
                d,
                hid,
                1,
                &mut scr,
                &mut hs,
                &mut h_t,
                &mut c_t,
            );
            assert_bits_eq(&hs, &hs_ref, "hs");
            assert_bits_eq(&h_t, &h_ref, "h_t");
            assert_bits_eq(&c_t, &c_ref, "c_t");
        }
    }
}
