//! Sequence schedules over the tiled GEMM, selected by the execution
//! plan: the paper-§5 *Unfolded* schedule (hoist the input MVM out of
//! the recurrence) and the *Stepwise* schedule (per-step projection, no
//! sequence-sized buffer — what T=1 cell artifacts and streaming chunks
//! want).
//!
//! ```text
//!   scalar reference (exec.rs)        unfolded            stepwise
//!   --------------------------        ------------------  ------------------
//!   for t in 0..T:                    pre (T*B,G*H)=bias  for t in 0..T:
//!     pre = bias                      pre += xs@Wx  ONE     pre (B,G*H)=bias
//!     pre += x_t (B,D) @ Wx           for t in 0..T:        pre += x_t@Wx
//!     pre += h  (B,H) @ Wh              pre_t += h@Wh       pre += h@Wh
//!     h, c = activate(pre, c)           activate            activate
//! ```
//!
//! Bit-exactness: under EITHER schedule, for every gate element the
//! accumulation is `bias`, then `x` contributions k = 0..D ascending,
//! then `h` contributions k = 0..H ascending — hoisting the input GEMM
//! batches rows (independent dot products), never reorders a dot, and
//! the stepwise schedule is literally the scalar reference's issue
//! order. The GEMM itself tiles over M/N only for every planner
//! geometry (`gemm`), and the activation code is the SAME function the
//! scalar reference calls (`exec::lstm_cell_update`/`gru_cell_update`),
//! so every (geometry, schedule) candidate is bit-identical to the
//! scalar oracle by construction; `tests/kernel_equivalence.rs` sweeps
//! the candidate space to enforce it.
//!
//! All outputs are written into caller-owned buffers (`clear` +
//! `extend`), so the steady-state serving path allocates nothing: the
//! executable's `ExecScratch` plus a reused `LstmOutput` cover every
//! intermediate.
//!
//! **Dtype.** The plan geometry's [`Dtype`] selects the GEMM at every
//! site through one helper ([`mm`]): f32 runs the bit-exact dense path;
//! int8 quantizes activation rows on the fly and runs the fused-dequant
//! quantized GEMM against the scratch's resident int8 panels. The
//! schedules, cell updates, state plumbing, and fusion/retirement logic
//! are completely dtype-independent — which is also why stacked models
//! (whose layers delegate to these steppers) inherit the quant path for
//! free. The int8 outputs differ from the f32 oracle by a documented
//! quantization budget (`tests/quant_conformance.rs`) but are
//! bit-identical *within* the int8 path across schedules, fusion,
//! ISAs, and threads — per-row activation scales depend only on the
//! row, and the integer dots are exact.

// Kernel entry points mirror the executor calling convention (tensors +
// shape dims + knobs), which runs past clippy's 7-argument heuristic by
// design — same waiver as `runtime::exec`.
#![allow(clippy::too_many_arguments)]

use super::gemm;
use super::scratch::{self, ExecScratch};
use crate::runtime::exec;
use crate::runtime::plan::{Dtype, ExecPlan, KernelGeometry, Schedule};
use crate::runtime::quant::{self, QuantWeights};

/// One schedule GEMM site, dispatched by dtype: the dense f32 path
/// (`matmul_packed_mt`) or the quantized path — quantize the activation
/// rows into the scratch's `qa`/`sa`, then run the fused-dequant int8
/// GEMM against the resident quantized panels. Both paths keep the
/// "out arrives holding the accumulation base" contract, so the
/// bias-then-x-then-h accumulation order of the schedules above is
/// dtype-independent; only the arithmetic precision changes.
fn mm(
    out: &mut [f32],
    a: &[f32],
    packed: &[f32],
    qw: Option<&QuantWeights>,
    qa: &mut Vec<i8>,
    sa: &mut Vec<f32>,
    m: usize,
    k: usize,
    n: usize,
    geo: &KernelGeometry,
    threads: usize,
) {
    match qw {
        Some(q) => {
            quant::quantize_rows(a, m, k, qa, sa);
            gemm::matmul_quant_mt(out, qa, sa, q.panels(), q.scales(), m, k, n, geo, threads);
        }
        None => gemm::matmul_packed_mt(out, a, packed, m, k, n, geo, threads),
    }
}

/// Full-sequence LSTM on the tiled kernel. `xs` is `(T, B, D)`; writes
/// `hs (T, B, H)`, `h_T (B, H)`, `c_T (B, H)` into the caller's buffers.
/// `plan` carries the register-tile geometry, thread gate, and schedule;
/// `threads` bounds the row-parallel fan-out (1 = serial; the effective
/// count is work-gated per GEMM, see [`gemm::effective_threads`]).
pub fn lstm_seq_into(
    xs: &[f32],
    h0: &[f32],
    c0: &[f32],
    wx: &[f32],
    wh: &[f32],
    bias: &[f32],
    t: usize,
    b: usize,
    d: usize,
    hid: usize,
    plan: &ExecPlan,
    threads: usize,
    scr: &mut ExecScratch,
    hs: &mut Vec<f32>,
    h_t: &mut Vec<f32>,
    c_t: &mut Vec<f32>,
) {
    let gh = 4 * hid;
    debug_assert_eq!(xs.len(), t * b * d);
    debug_assert_eq!(h0.len(), b * hid);
    debug_assert_eq!(c0.len(), b * hid);
    let geo = &plan.geometry;
    if geo.dtype == Dtype::Int8 {
        scr.ensure_quant(wx, wh, d, hid, gh, geo.nr);
    } else {
        scr.ensure_packed(wx, wh, d, hid, gh, geo.nr);
    }
    let ExecScratch {
        packed_wx,
        packed_wh,
        pre,
        state_a,
        state_b,
        cell_a,
        cell_b,
        qwx,
        qwh,
        qa,
        sa,
        ..
    } = scr;
    let (qx, qh) = if geo.dtype == Dtype::Int8 {
        (qwx.as_ref(), qwh.as_ref())
    } else {
        (None, None)
    };

    scratch::fill_from(state_a, h0);
    scratch::fill_from(cell_a, c0);
    scratch::fill_zero(state_b, b * hid);
    scratch::fill_zero(cell_b, b * hid);
    hs.clear();
    hs.reserve(t * b * hid);

    let gate = geo.min_flops_per_thread;
    let nt_rec = gemm::effective_threads(threads, b, hid, gh, gate);
    match plan.schedule {
        Schedule::Unfolded => {
            // Unfolded input projection: the whole sequence in one GEMM.
            scratch::fill_bias(pre, bias, t * b, gh);
            let nt = gemm::effective_threads(threads, t * b, d, gh, gate);
            mm(pre, xs, packed_wx, qx, qa, sa, t * b, d, gh, geo, nt);
            // What remains of the dependent serialization: one small
            // (B, H) x (H, G*H) MVM plus the cell update per step.
            for step in 0..t {
                let pre_t = &mut pre[step * b * gh..(step + 1) * b * gh];
                mm(pre_t, state_a, packed_wh, qh, qa, sa, b, hid, gh, geo, nt_rec);
                exec::lstm_cell_update(pre_t, cell_a, state_b, cell_b, b, hid);
                hs.extend_from_slice(state_b);
                std::mem::swap(state_a, state_b);
                std::mem::swap(cell_a, cell_b);
            }
        }
        Schedule::Stepwise => {
            // Per-step projection into a (B, G*H) buffer — the scalar
            // reference's own issue order, without the sequence-sized
            // scratch.
            let nt_in = gemm::effective_threads(threads, b, d, gh, gate);
            for step in 0..t {
                let x_t = &xs[step * b * d..(step + 1) * b * d];
                scratch::fill_bias(pre, bias, b, gh);
                mm(pre, x_t, packed_wx, qx, qa, sa, b, d, gh, geo, nt_in);
                mm(pre, state_a, packed_wh, qh, qa, sa, b, hid, gh, geo, nt_rec);
                exec::lstm_cell_update(pre, cell_a, state_b, cell_b, b, hid);
                hs.extend_from_slice(state_b);
                std::mem::swap(state_a, state_b);
                std::mem::swap(cell_a, cell_b);
            }
        }
    }
    scratch::fill_from(h_t, state_a);
    scratch::fill_from(c_t, cell_a);
}

/// Full-sequence GRU on the tiled kernel ("linear before reset", so the
/// input half hoists exactly like the LSTM's). Writes `hs (T, B, H)`
/// and `h_T (B, H)` into the caller's buffers.
pub fn gru_seq_into(
    xs: &[f32],
    h0: &[f32],
    wx: &[f32],
    wh: &[f32],
    bias: &[f32],
    t: usize,
    b: usize,
    d: usize,
    hid: usize,
    plan: &ExecPlan,
    threads: usize,
    scr: &mut ExecScratch,
    hs: &mut Vec<f32>,
    h_t: &mut Vec<f32>,
) {
    let gh = 3 * hid;
    debug_assert_eq!(xs.len(), t * b * d);
    debug_assert_eq!(h0.len(), b * hid);
    let geo = &plan.geometry;
    if geo.dtype == Dtype::Int8 {
        scr.ensure_quant(wx, wh, d, hid, gh, geo.nr);
    } else {
        scr.ensure_packed(wx, wh, d, hid, gh, geo.nr);
    }
    let ExecScratch {
        packed_wx,
        packed_wh,
        pre,
        hpre,
        state_a,
        state_b,
        qwx,
        qwh,
        qa,
        sa,
        ..
    } = scr;
    let (qx, qh) = if geo.dtype == Dtype::Int8 {
        (qwx.as_ref(), qwh.as_ref())
    } else {
        (None, None)
    };

    scratch::fill_from(state_a, h0);
    scratch::fill_zero(state_b, b * hid);
    hs.clear();
    hs.reserve(t * b * hid);

    let gate = geo.min_flops_per_thread;
    let nt_rec = gemm::effective_threads(threads, b, hid, gh, gate);
    match plan.schedule {
        Schedule::Unfolded => {
            scratch::fill_bias(pre, bias, t * b, gh);
            let nt = gemm::effective_threads(threads, t * b, d, gh, gate);
            mm(pre, xs, packed_wx, qx, qa, sa, t * b, d, gh, geo, nt);
            for step in 0..t {
                let xpre_t = &pre[step * b * gh..(step + 1) * b * gh];
                scratch::fill_zero(hpre, b * gh);
                mm(hpre, state_a, packed_wh, qh, qa, sa, b, hid, gh, geo, nt_rec);
                exec::gru_cell_update(xpre_t, hpre, state_a, state_b, b, hid);
                hs.extend_from_slice(state_b);
                std::mem::swap(state_a, state_b);
            }
        }
        Schedule::Stepwise => {
            let nt_in = gemm::effective_threads(threads, b, d, gh, gate);
            for step in 0..t {
                let x_t = &xs[step * b * d..(step + 1) * b * d];
                scratch::fill_bias(pre, bias, b, gh);
                mm(pre, x_t, packed_wx, qx, qa, sa, b, d, gh, geo, nt_in);
                scratch::fill_zero(hpre, b * gh);
                mm(hpre, state_a, packed_wh, qh, qa, sa, b, hid, gh, geo, nt_rec);
                exec::gru_cell_update(pre, hpre, state_a, state_b, b, hid);
                hs.extend_from_slice(state_b);
                std::mem::swap(state_a, state_b);
            }
        }
    }
    scratch::fill_from(h_t, state_a);
}

/// Advance many independent streaming lanes through one shared-weight
/// LSTM, step-major: each iteration runs ONE batched `(M, D) @ Wx` and
/// ONE `(M, H) @ Wh` over every lane still live, where the solo path
/// would issue M separate single-row MVMs against the same packed
/// panels — the cross-session step fusion that turns the dominant
/// memory-bound recurrent MVM into a panel-reusing GEMM.
///
/// `xs` is the step-major ragged gather a [`scratch::FusedBatch`]
/// produces: `lens` (one entry per lane, SORTED DESCENDING) gives each
/// lane's step count, and step `s` of `xs` holds `active(s)` rows — one
/// per lane with `lens[i] > s`, in lane order. Lane retirement is a
/// prefix shrink: when a lane's chunk ends its rows stop appearing in
/// `xs` and its carry rows in `h`/`c` (shape `(L, H)`, updated in
/// place) stop being touched, so each retired lane's final state is
/// already scattered where it belongs.
///
/// Bit-exactness: every lane row's gate accumulation is still `bias`,
/// then `x` contributions k = 0..D, then `h` contributions k = 0..H —
/// the GEMM tiles over M/N only, so batching rows never reorders a dot
/// product, and the activation is the shared `exec::lstm_cell_update`,
/// which is row-independent. A lane therefore computes exactly the bits
/// the solo `run_prefix_into` path computes for the same chunk, no
/// matter which other lanes share the window or in which order lanes
/// retire (`tests/streaming_fusion.rs` enforces the contract).
pub fn lstm_steps_batched_into(
    xs: &[f32],
    lens: &[usize],
    wx: &[f32],
    wh: &[f32],
    bias: &[f32],
    d: usize,
    hid: usize,
    plan: &ExecPlan,
    threads: usize,
    scr: &mut ExecScratch,
    h: &mut [f32],
    c: &mut [f32],
) {
    let gh = 4 * hid;
    let lanes = lens.len();
    let total: usize = lens.iter().sum();
    debug_assert!(lens.windows(2).all(|w| w[0] >= w[1]), "lens must descend");
    debug_assert_eq!(xs.len(), total * d);
    debug_assert_eq!(h.len(), lanes * hid);
    debug_assert_eq!(c.len(), lanes * hid);
    let geo = &plan.geometry;
    if geo.dtype == Dtype::Int8 {
        scr.ensure_quant(wx, wh, d, hid, gh, geo.nr);
    } else {
        scr.ensure_packed(wx, wh, d, hid, gh, geo.nr);
    }
    let ExecScratch {
        packed_wx,
        packed_wh,
        pre,
        state_b,
        cell_b,
        qwx,
        qwh,
        qa,
        sa,
        ..
    } = scr;
    let (qx, qh) = if geo.dtype == Dtype::Int8 {
        (qwx.as_ref(), qwh.as_ref())
    } else {
        (None, None)
    };

    let gate = geo.min_flops_per_thread;
    let mut off = 0usize;
    let mut m = lanes;
    for step in 0..lens.first().copied().unwrap_or(0) {
        // Retire lanes whose chunk ended (a suffix, by the descending
        // invariant); their carry rows beyond m keep their final state.
        while m > 0 && lens[m - 1] <= step {
            m -= 1;
        }
        let x_s = &xs[off..off + m * d];
        off += m * d;
        scratch::fill_bias(pre, bias, m, gh);
        let nt_in = gemm::effective_threads(threads, m, d, gh, gate);
        mm(pre, x_s, packed_wx, qx, qa, sa, m, d, gh, geo, nt_in);
        let nt_rec = gemm::effective_threads(threads, m, hid, gh, gate);
        mm(pre, &h[..m * hid], packed_wh, qh, qa, sa, m, hid, gh, geo, nt_rec);
        scratch::fill_zero(state_b, m * hid);
        scratch::fill_zero(cell_b, m * hid);
        exec::lstm_cell_update(pre, &c[..m * hid], state_b, cell_b, m, hid);
        h[..m * hid].copy_from_slice(state_b);
        c[..m * hid].copy_from_slice(cell_b);
    }
}

/// GRU twin of [`lstm_steps_batched_into`] ("linear before reset", so
/// the hidden half stays a separate pre-activation buffer). `h` is the
/// `(L, H)` lane carry block, updated in place; GRU kinds have no cell
/// state.
pub fn gru_steps_batched_into(
    xs: &[f32],
    lens: &[usize],
    wx: &[f32],
    wh: &[f32],
    bias: &[f32],
    d: usize,
    hid: usize,
    plan: &ExecPlan,
    threads: usize,
    scr: &mut ExecScratch,
    h: &mut [f32],
) {
    let gh = 3 * hid;
    let lanes = lens.len();
    let total: usize = lens.iter().sum();
    debug_assert!(lens.windows(2).all(|w| w[0] >= w[1]), "lens must descend");
    debug_assert_eq!(xs.len(), total * d);
    debug_assert_eq!(h.len(), lanes * hid);
    let geo = &plan.geometry;
    if geo.dtype == Dtype::Int8 {
        scr.ensure_quant(wx, wh, d, hid, gh, geo.nr);
    } else {
        scr.ensure_packed(wx, wh, d, hid, gh, geo.nr);
    }
    let ExecScratch {
        packed_wx,
        packed_wh,
        pre,
        hpre,
        state_b,
        qwx,
        qwh,
        qa,
        sa,
        ..
    } = scr;
    let (qx, qh) = if geo.dtype == Dtype::Int8 {
        (qwx.as_ref(), qwh.as_ref())
    } else {
        (None, None)
    };

    let gate = geo.min_flops_per_thread;
    let mut off = 0usize;
    let mut m = lanes;
    for step in 0..lens.first().copied().unwrap_or(0) {
        while m > 0 && lens[m - 1] <= step {
            m -= 1;
        }
        let x_s = &xs[off..off + m * d];
        off += m * d;
        scratch::fill_bias(pre, bias, m, gh);
        let nt_in = gemm::effective_threads(threads, m, d, gh, gate);
        mm(pre, x_s, packed_wx, qx, qa, sa, m, d, gh, geo, nt_in);
        scratch::fill_zero(hpre, m * gh);
        let nt_rec = gemm::effective_threads(threads, m, hid, gh, gate);
        mm(hpre, &h[..m * hid], packed_wh, qh, qa, sa, m, hid, gh, geo, nt_rec);
        scratch::fill_zero(state_b, m * hid);
        exec::gru_cell_update(pre, hpre, &h[..m * hid], state_b, m, hid);
        h[..m * hid].copy_from_slice(state_b);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::literal::assert_bits_eq;
    use crate::runtime::plan::KernelGeometry;
    use crate::util::rng::Rng;

    fn plans_under_test() -> Vec<ExecPlan> {
        let mut out = Vec::new();
        for schedule in [Schedule::Unfolded, Schedule::Stepwise] {
            for (mr, nr) in [(4, 16), (1, 8), (8, 32)] {
                out.push(ExecPlan {
                    geometry: KernelGeometry::new(mr, nr).unwrap(),
                    schedule,
                });
            }
        }
        out
    }

    #[test]
    fn lstm_schedules_match_scalar_oracle() {
        let (t, b, d, hid) = (5usize, 3usize, 7usize, 17usize);
        let mut rng = Rng::new(77);
        let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
        let h0 = rng.vec_f32(b * hid, -1.0, 1.0);
        let c0 = rng.vec_f32(b * hid, -1.0, 1.0);
        let wx = rng.vec_f32(d * 4 * hid, -0.3, 0.3);
        let wh = rng.vec_f32(hid * 4 * hid, -0.3, 0.3);
        let bias = rng.vec_f32(4 * hid, -0.2, 0.2);

        let (hs_ref, h_ref, c_ref) = exec::lstm_seq(&xs, &h0, &c0, &wx, &wh, &bias, t, b, d, hid);
        for plan in plans_under_test() {
            for threads in [1usize, 3] {
                let mut scr = ExecScratch::new();
                let (mut hs, mut h_t, mut c_t) = (Vec::new(), Vec::new(), Vec::new());
                lstm_seq_into(
                    &xs,
                    &h0,
                    &c0,
                    &wx,
                    &wh,
                    &bias,
                    t,
                    b,
                    d,
                    hid,
                    &plan,
                    threads,
                    &mut scr,
                    &mut hs,
                    &mut h_t,
                    &mut c_t,
                );
                let ctx = format!("{} threads={threads}", plan.describe());
                assert_bits_eq(&hs, &hs_ref, &format!("{ctx}: hs"));
                assert_bits_eq(&h_t, &h_ref, &format!("{ctx}: h_t"));
                assert_bits_eq(&c_t, &c_ref, &format!("{ctx}: c_t"));
            }
        }
    }

    #[test]
    fn t1_cell_case_matches_scalar_step_under_both_schedules() {
        // The cell-artifact path runs the same kernel with T=1.
        let (b, d, hid) = (2usize, 4usize, 13usize);
        let mut rng = Rng::new(31);
        let x = rng.vec_f32(b * d, -1.0, 1.0);
        let h0 = rng.vec_f32(b * hid, -1.0, 1.0);
        let c0 = rng.vec_f32(b * hid, -1.0, 1.0);
        let wx = rng.vec_f32(d * 4 * hid, -0.3, 0.3);
        let wh = rng.vec_f32(hid * 4 * hid, -0.3, 0.3);
        let bias = rng.vec_f32(4 * hid, -0.2, 0.2);

        let (h_ref, c_ref) = exec::lstm_step(&x, &h0, &c0, &wx, &wh, &bias, b, d, hid);
        for schedule in [Schedule::Unfolded, Schedule::Stepwise] {
            let plan = ExecPlan::fixed_default().with_schedule(schedule);
            let mut scr = ExecScratch::new();
            let (mut hs, mut h_t, mut c_t) = (Vec::new(), Vec::new(), Vec::new());
            lstm_seq_into(
                &x,
                &h0,
                &c0,
                &wx,
                &wh,
                &bias,
                1,
                b,
                d,
                hid,
                &plan,
                1,
                &mut scr,
                &mut hs,
                &mut h_t,
                &mut c_t,
            );
            assert_bits_eq(&hs, &h_ref, "hs");
            assert_bits_eq(&h_t, &h_ref, "h_t");
            assert_bits_eq(&c_t, &c_ref, "c_t");
        }
    }

    #[test]
    fn gru_schedules_match_scalar_oracle() {
        let (t, b, d, hid) = (4usize, 2usize, 5usize, 19usize);
        let mut rng = Rng::new(123);
        let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
        let h0 = rng.vec_f32(b * hid, -1.0, 1.0);
        let wx = rng.vec_f32(d * 3 * hid, -0.3, 0.3);
        let wh = rng.vec_f32(hid * 3 * hid, -0.3, 0.3);
        let bias = rng.vec_f32(3 * hid, -0.2, 0.2);

        let (hs_ref, h_ref) = exec::gru_seq(&xs, &h0, &wx, &wh, &bias, t, b, d, hid);
        for plan in plans_under_test() {
            let mut scr = ExecScratch::new();
            let (mut hs, mut h_t) = (Vec::new(), Vec::new());
            gru_seq_into(
                &xs,
                &h0,
                &wx,
                &wh,
                &bias,
                t,
                b,
                d,
                hid,
                &plan,
                1,
                &mut scr,
                &mut hs,
                &mut h_t,
            );
            let ctx = plan.describe();
            assert_bits_eq(&hs, &hs_ref, &format!("{ctx}: hs"));
            assert_bits_eq(&h_t, &h_ref, &format!("{ctx}: h_t"));
        }
    }

    #[test]
    fn fused_lanes_match_solo_runs_bitwise() {
        // The step-fusion contract at the kernel level: every lane of a
        // fused window carries exactly the bits a solo sequence run of
        // that lane's chunk produces, across ragged lens (retirement),
        // geometries, and thread counts.
        let (d, hid) = (5usize, 11usize);
        let lens = [6usize, 4, 4, 1];
        let lanes = lens.len();
        let total: usize = lens.iter().sum();
        let mut rng = Rng::new(2024);
        let wx = rng.vec_f32(d * 4 * hid, -0.3, 0.3);
        let wh = rng.vec_f32(hid * 4 * hid, -0.3, 0.3);
        let bias = rng.vec_f32(4 * hid, -0.2, 0.2);
        let chunks: Vec<Vec<f32>> = lens.iter().map(|&l| rng.vec_f32(l * d, -1.0, 1.0)).collect();
        let h0 = rng.vec_f32(lanes * hid, -1.0, 1.0);
        let c0 = rng.vec_f32(lanes * hid, -1.0, 1.0);

        // Solo reference: each lane alone, via the sequence kernel
        // (B=1), which is itself oracle-proven.
        let mut want_h = Vec::new();
        let mut want_c = Vec::new();
        for (i, chunk) in chunks.iter().enumerate() {
            let mut scr = ExecScratch::new();
            let (mut hs, mut h_t, mut c_t) = (Vec::new(), Vec::new(), Vec::new());
            lstm_seq_into(
                chunk,
                &h0[i * hid..(i + 1) * hid],
                &c0[i * hid..(i + 1) * hid],
                &wx,
                &wh,
                &bias,
                lens[i],
                1,
                d,
                hid,
                &ExecPlan::fixed_default(),
                1,
                &mut scr,
                &mut hs,
                &mut h_t,
                &mut c_t,
            );
            want_h.extend_from_slice(&h_t);
            want_c.extend_from_slice(&c_t);
        }

        // Step-major ragged gather of the same chunks.
        let mut xs = Vec::with_capacity(total * d);
        for step in 0..lens[0] {
            for (i, &len) in lens.iter().enumerate() {
                if len > step {
                    xs.extend_from_slice(&chunks[i][step * d..(step + 1) * d]);
                }
            }
        }

        for (mr, nr) in [(4, 16), (1, 8), (8, 32)] {
            for threads in [1usize, 3] {
                let plan = ExecPlan {
                    geometry: KernelGeometry::new(mr, nr).unwrap(),
                    schedule: Schedule::Stepwise,
                };
                let mut scr = ExecScratch::new();
                let mut h = h0.clone();
                let mut c = c0.clone();
                lstm_steps_batched_into(
                    &xs, &lens, &wx, &wh, &bias, d, hid, &plan, threads, &mut scr, &mut h,
                    &mut c,
                );
                let ctx = format!("fused {mr}x{nr} threads={threads}");
                assert_bits_eq(&h, &want_h, &format!("{ctx}: h"));
                assert_bits_eq(&c, &want_c, &format!("{ctx}: c"));
            }
        }
    }

    #[test]
    fn fused_gru_lanes_match_solo_runs_bitwise() {
        let (d, hid) = (4usize, 9usize);
        let lens = [3usize, 2];
        let lanes = lens.len();
        let mut rng = Rng::new(909);
        let wx = rng.vec_f32(d * 3 * hid, -0.3, 0.3);
        let wh = rng.vec_f32(hid * 3 * hid, -0.3, 0.3);
        let bias = rng.vec_f32(3 * hid, -0.2, 0.2);
        let chunks: Vec<Vec<f32>> = lens.iter().map(|&l| rng.vec_f32(l * d, -1.0, 1.0)).collect();
        let h0 = rng.vec_f32(lanes * hid, -1.0, 1.0);

        let mut want_h = Vec::new();
        for (i, chunk) in chunks.iter().enumerate() {
            let mut scr = ExecScratch::new();
            let (mut hs, mut h_t) = (Vec::new(), Vec::new());
            gru_seq_into(
                chunk,
                &h0[i * hid..(i + 1) * hid],
                &wx,
                &wh,
                &bias,
                lens[i],
                1,
                d,
                hid,
                &ExecPlan::fixed_default(),
                1,
                &mut scr,
                &mut hs,
                &mut h_t,
            );
            want_h.extend_from_slice(&h_t);
        }

        let mut xs = Vec::new();
        for step in 0..lens[0] {
            for (i, &len) in lens.iter().enumerate() {
                if len > step {
                    xs.extend_from_slice(&chunks[i][step * d..(step + 1) * d]);
                }
            }
        }
        let mut scr = ExecScratch::new();
        let mut h = h0.clone();
        let plan = ExecPlan::fixed_default().with_schedule(Schedule::Stepwise);
        gru_steps_batched_into(&xs, &lens, &wx, &wh, &bias, d, hid, &plan, 1, &mut scr, &mut h);
        assert_bits_eq(&h, &want_h, "fused gru carries");
    }

    #[test]
    fn int8_schedules_geometries_and_threads_agree_bitwise() {
        // The int8 path's own equivalence claim: every (schedule,
        // geometry, threads) combination produces the identical bits —
        // the quantization is per-row/per-gate (dispatch-independent),
        // the i32 dots are exact, and the dequant epilogue is shared
        // scalar code. The f32 oracle comparison (with a tolerance
        // budget) lives in tests/quant_conformance.rs.
        let (t, b, d, hid) = (5usize, 3usize, 7usize, 17usize);
        let mut rng = Rng::new(88);
        let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
        let h0 = rng.vec_f32(b * hid, -1.0, 1.0);
        let c0 = rng.vec_f32(b * hid, -1.0, 1.0);
        let wx = rng.vec_f32(d * 4 * hid, -0.3, 0.3);
        let wh = rng.vec_f32(hid * 4 * hid, -0.3, 0.3);
        let bias = rng.vec_f32(4 * hid, -0.2, 0.2);

        let mut want: Option<(Vec<f32>, Vec<f32>, Vec<f32>)> = None;
        for plan in plans_under_test() {
            let plan = ExecPlan {
                geometry: plan.geometry.with_dtype(Dtype::Int8),
                schedule: plan.schedule,
            };
            for threads in [1usize, 3] {
                let mut scr = ExecScratch::new();
                let (mut hs, mut h_t, mut c_t) = (Vec::new(), Vec::new(), Vec::new());
                lstm_seq_into(
                    &xs, &h0, &c0, &wx, &wh, &bias, t, b, d, hid, &plan, threads, &mut scr,
                    &mut hs, &mut h_t, &mut c_t,
                );
                let ctx = format!("{} threads={threads}", plan.describe());
                match &want {
                    None => {
                        // Loose sanity on the first variant: the quant
                        // error stays small on this well-conditioned
                        // shape (the pinned budget lives in the
                        // conformance sweep).
                        let (_, h_ref, _) =
                            exec::lstm_seq(&xs, &h0, &c0, &wx, &wh, &bias, t, b, d, hid);
                        let worst = h_t
                            .iter()
                            .zip(&h_ref)
                            .map(|(a, r)| (a - r).abs())
                            .fold(0.0f32, f32::max);
                        assert!(worst < 0.05, "int8 drifted {worst} from the f32 oracle");
                        want = Some((hs, h_t, c_t));
                    }
                    Some((w_hs, w_h, w_c)) => {
                        assert_bits_eq(&hs, w_hs, &format!("{ctx}: hs"));
                        assert_bits_eq(&h_t, w_h, &format!("{ctx}: h_t"));
                        assert_bits_eq(&c_t, w_c, &format!("{ctx}: c_t"));
                    }
                }
            }
        }
    }

    #[test]
    fn int8_fused_lanes_match_int8_solo_runs_bitwise() {
        // Step fusion must stay transparent under quantization:
        // per-row activation scales depend only on the row, so a lane
        // in a fused int8 window carries exactly the solo int8 bits.
        let (d, hid) = (5usize, 11usize);
        let lens = [4usize, 2, 1];
        let lanes = lens.len();
        let mut rng = Rng::new(404);
        let wx = rng.vec_f32(d * 4 * hid, -0.3, 0.3);
        let wh = rng.vec_f32(hid * 4 * hid, -0.3, 0.3);
        let bias = rng.vec_f32(4 * hid, -0.2, 0.2);
        let chunks: Vec<Vec<f32>> = lens.iter().map(|&l| rng.vec_f32(l * d, -1.0, 1.0)).collect();
        let h0 = rng.vec_f32(lanes * hid, -1.0, 1.0);
        let c0 = rng.vec_f32(lanes * hid, -1.0, 1.0);

        let solo_plan = ExecPlan {
            geometry: KernelGeometry::new(4, 16).unwrap().with_dtype(Dtype::Int8),
            schedule: Schedule::Stepwise,
        };
        let mut want_h = Vec::new();
        let mut want_c = Vec::new();
        for (i, chunk) in chunks.iter().enumerate() {
            let mut scr = ExecScratch::new();
            let (mut hs, mut h_t, mut c_t) = (Vec::new(), Vec::new(), Vec::new());
            lstm_seq_into(
                chunk,
                &h0[i * hid..(i + 1) * hid],
                &c0[i * hid..(i + 1) * hid],
                &wx,
                &wh,
                &bias,
                lens[i],
                1,
                d,
                hid,
                &solo_plan,
                1,
                &mut scr,
                &mut hs,
                &mut h_t,
                &mut c_t,
            );
            want_h.extend_from_slice(&h_t);
            want_c.extend_from_slice(&c_t);
        }

        let mut xs = Vec::new();
        for step in 0..lens[0] {
            for (i, &len) in lens.iter().enumerate() {
                if len > step {
                    xs.extend_from_slice(&chunks[i][step * d..(step + 1) * d]);
                }
            }
        }
        for threads in [1usize, 3] {
            let mut scr = ExecScratch::new();
            let mut h = h0.clone();
            let mut c = c0.clone();
            lstm_steps_batched_into(
                &xs, &lens, &wx, &wh, &bias, d, hid, &solo_plan, threads, &mut scr, &mut h,
                &mut c,
            );
            assert_bits_eq(&h, &want_h, &format!("int8 fused h threads={threads}"));
            assert_bits_eq(&c, &want_c, &format!("int8 fused c threads={threads}"));
        }
    }

    #[test]
    fn scratch_reuse_across_calls_and_schedules_is_stable() {
        // The serving pattern: one executable, many requests — later
        // calls reuse packed panels and warmed buffers and must still be
        // bit-identical (including a SHORTER prefix after a longer run,
        // and a schedule flip mid-stream, which is what the streaming
        // T=1 override does).
        let (t, b, d, hid) = (6usize, 2usize, 4usize, 9usize);
        let mut rng = Rng::new(5);
        let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
        let h0 = rng.vec_f32(b * hid, -1.0, 1.0);
        let c0 = rng.vec_f32(b * hid, -1.0, 1.0);
        let wx = rng.vec_f32(d * 4 * hid, -0.3, 0.3);
        let wh = rng.vec_f32(hid * 4 * hid, -0.3, 0.3);
        let bias = rng.vec_f32(4 * hid, -0.2, 0.2);

        let mut scr = ExecScratch::new();
        let (mut hs, mut h_t, mut c_t) = (Vec::new(), Vec::new(), Vec::new());
        let base = ExecPlan::fixed_default();
        for (steps, schedule) in [
            (t, Schedule::Unfolded),
            (2, Schedule::Stepwise),
            (t, Schedule::Unfolded),
            (1, Schedule::Stepwise),
        ] {
            let (hs_ref, h_ref, c_ref) =
                exec::lstm_seq(&xs[..steps * b * d], &h0, &c0, &wx, &wh, &bias, steps, b, d, hid);
            lstm_seq_into(
                &xs[..steps * b * d],
                &h0,
                &c0,
                &wx,
                &wh,
                &bias,
                steps,
                b,
                d,
                hid,
                &base.with_schedule(schedule),
                1,
                &mut scr,
                &mut hs,
                &mut h_t,
                &mut c_t,
            );
            assert_bits_eq(&hs, &hs_ref, "hs");
            assert_bits_eq(&h_t, &h_ref, "h_t");
            assert_bits_eq(&c_t, &c_ref, "c_t");
        }
    }
}
