//! NEON column-vectorized micro-kernels (aarch64).
//!
//! Structurally identical to the AVX2 kernels ([`super::x86`]) at half
//! the vector width: each `float32x4_t` spans 4 consecutive panel
//! columns, one output dot per lane, k ascending, and a **separate**
//! `vmulq_f32` + `vaddq_f32` per step — never `vfmaq_f32`, whose single
//! rounding would break bit-identity with the scalar oracle. NEON is
//! baseline on aarch64, so availability is a compile-time fact rather
//! than a runtime probe.
//!
//! Instantiations cover block rows 1..=MR_MAX and panel widths
//! {4, 8, 16, 32} (1, 2, 4, or 8 vectors per row); every candidate
//! panel width the tuner emits is a multiple of the 4-lane vector, so
//! only ragged lane-unaligned tails fall back to the scalar block.

use std::arch::aarch64::{
    float32x4_t, vaddq_f32, vdupq_n_f32, vld1q_f32, vmulq_f32, vst1q_f32,
};

/// f32 lanes per 128-bit vector.
const LANES: usize = 4;

/// Dispatch one accumulator block to its NEON instantiation, or refuse
/// (`false`) if the `(mre, w)` pair has none.
#[allow(clippy::too_many_arguments)] // micro-kernel ABI: block coords + dims
pub(super) fn kern_block_neon(
    out: &mut [f32],
    a: &[f32],
    panel: &[f32],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
    mre: usize,
    w: usize,
) -> bool {
    match w {
        4 => by_rows::<1>(out, a, panel, row, col, k, n, mre),
        8 => by_rows::<2>(out, a, panel, row, col, k, n, mre),
        16 => by_rows::<4>(out, a, panel, row, col, k, n, mre),
        32 => by_rows::<8>(out, a, panel, row, col, k, n, mre),
        _ => false,
    }
}

/// Second dispatch level: monomorphize over the block row count.
#[allow(clippy::too_many_arguments)]
fn by_rows<const WV: usize>(
    out: &mut [f32],
    a: &[f32],
    panel: &[f32],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
    mre: usize,
) -> bool {
    // SAFETY: NEON is baseline on aarch64 (this module only compiles
    // there); slice bounds are the scalar block's own (checked by the
    // debug asserts inside `kern`).
    unsafe {
        match mre {
            1 => kern::<1, WV>(out, a, panel, row, col, k, n),
            2 => kern::<2, WV>(out, a, panel, row, col, k, n),
            3 => kern::<3, WV>(out, a, panel, row, col, k, n),
            4 => kern::<4, WV>(out, a, panel, row, col, k, n),
            5 => kern::<5, WV>(out, a, panel, row, col, k, n),
            6 => kern::<6, WV>(out, a, panel, row, col, k, n),
            7 => kern::<7, WV>(out, a, panel, row, col, k, n),
            8 => kern::<8, WV>(out, a, panel, row, col, k, n),
            _ => return false,
        }
    }
    true
}

/// `MR x (WV*4)` register block: WV accumulator vectors per row, one
/// dot product per lane, k ascending, mul-then-add per step.
///
/// # Safety
/// The block must lie inside `out`/`a`/`panel` exactly as for the
/// scalar `kern` (same caller, same bounds). NEON is baseline here.
#[target_feature(enable = "neon")]
#[allow(clippy::needless_range_loop)] // explicit lane/row indices mirror the math
unsafe fn kern<const MR: usize, const WV: usize>(
    out: &mut [f32],
    a: &[f32],
    panel: &[f32],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
) {
    let w = WV * LANES;
    debug_assert_eq!(panel.len(), k * w);
    debug_assert!(a.len() >= (row + MR) * k);
    debug_assert!(out.len() >= (row + MR - 1) * n + col + w);
    let op = out.as_mut_ptr();
    let ap = a.as_ptr();
    let pp = panel.as_ptr();

    // Load the accumulation base (bias broadcast or partial sum).
    let mut acc = [[vdupq_n_f32(0.0); WV]; MR];
    for i in 0..MR {
        let base = (row + i) * n + col;
        for v in 0..WV {
            acc[i][v] = vld1q_f32(op.add(base + v * LANES));
        }
    }
    for kk in 0..k {
        // One contiguous panel row: the packed layout puts columns
        // (k, col..col+w) at panel[k*w..(k+1)*w].
        let prow = pp.add(kk * w);
        let mut bv: [float32x4_t; WV] = [vdupq_n_f32(0.0); WV];
        for v in 0..WV {
            bv[v] = vld1q_f32(prow.add(v * LANES));
        }
        for i in 0..MR {
            let av = vdupq_n_f32(*ap.add((row + i) * k + kk));
            for v in 0..WV {
                // Separate mul and add — NOT vfmaq — so every lane
                // rounds twice per step, exactly like the scalar path.
                acc[i][v] = vaddq_f32(acc[i][v], vmulq_f32(av, bv[v]));
            }
        }
    }
    for i in 0..MR {
        let base = (row + i) * n + col;
        for v in 0..WV {
            vst1q_f32(op.add(base + v * LANES), acc[i][v]);
        }
    }
}
