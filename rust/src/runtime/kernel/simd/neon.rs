//! NEON column-vectorized micro-kernels (aarch64).
//!
//! Structurally identical to the AVX2 kernels ([`super::x86`]) at half
//! the vector width: each `float32x4_t` spans 4 consecutive panel
//! columns, one output dot per lane, k ascending, and a **separate**
//! `vmulq_f32` + `vaddq_f32` per step — never `vfmaq_f32`, whose single
//! rounding would break bit-identity with the scalar oracle. NEON is
//! baseline on aarch64, so availability is a compile-time fact rather
//! than a runtime probe.
//!
//! Instantiations cover block rows 1..=MR_MAX and panel widths
//! {4, 8, 16, 32} (1, 2, 4, or 8 vectors per row); every candidate
//! panel width the tuner emits is a multiple of the 4-lane vector, so
//! only ragged lane-unaligned tails fall back to the scalar block.

use std::arch::aarch64::{
    float32x4_t, int32x4_t, vaddq_f32, vdupq_n_f32, vdupq_n_s32, vget_high_s16, vget_low_s16,
    vld1_s8, vld1q_f32, vld1q_s32, vmlaq_s32, vmovl_s16, vmovl_s8, vmulq_f32, vst1q_f32,
    vst1q_s32,
};

/// f32 lanes per 128-bit vector.
const LANES: usize = 4;

/// Dispatch one accumulator block to its NEON instantiation, or refuse
/// (`false`) if the `(mre, w)` pair has none.
#[allow(clippy::too_many_arguments)] // micro-kernel ABI: block coords + dims
pub(super) fn kern_block_neon(
    out: &mut [f32],
    a: &[f32],
    panel: &[f32],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
    mre: usize,
    w: usize,
) -> bool {
    match w {
        4 => by_rows::<1>(out, a, panel, row, col, k, n, mre),
        8 => by_rows::<2>(out, a, panel, row, col, k, n, mre),
        16 => by_rows::<4>(out, a, panel, row, col, k, n, mre),
        32 => by_rows::<8>(out, a, panel, row, col, k, n, mre),
        _ => false,
    }
}

/// Second dispatch level: monomorphize over the block row count.
#[allow(clippy::too_many_arguments)]
fn by_rows<const WV: usize>(
    out: &mut [f32],
    a: &[f32],
    panel: &[f32],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
    mre: usize,
) -> bool {
    // SAFETY: NEON is baseline on aarch64 (this module only compiles
    // there); slice bounds are the scalar block's own (checked by the
    // debug asserts inside `kern`).
    unsafe {
        match mre {
            1 => kern::<1, WV>(out, a, panel, row, col, k, n),
            2 => kern::<2, WV>(out, a, panel, row, col, k, n),
            3 => kern::<3, WV>(out, a, panel, row, col, k, n),
            4 => kern::<4, WV>(out, a, panel, row, col, k, n),
            5 => kern::<5, WV>(out, a, panel, row, col, k, n),
            6 => kern::<6, WV>(out, a, panel, row, col, k, n),
            7 => kern::<7, WV>(out, a, panel, row, col, k, n),
            8 => kern::<8, WV>(out, a, panel, row, col, k, n),
            _ => return false,
        }
    }
    true
}

/// `MR x (WV*4)` register block: WV accumulator vectors per row, one
/// dot product per lane, k ascending, mul-then-add per step.
///
/// # Safety
/// The block must lie inside `out`/`a`/`panel` exactly as for the
/// scalar `kern` (same caller, same bounds). NEON is baseline here.
#[target_feature(enable = "neon")]
#[allow(clippy::needless_range_loop)] // explicit lane/row indices mirror the math
unsafe fn kern<const MR: usize, const WV: usize>(
    out: &mut [f32],
    a: &[f32],
    panel: &[f32],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
) {
    let w = WV * LANES;
    debug_assert_eq!(panel.len(), k * w);
    debug_assert!(a.len() >= (row + MR) * k);
    debug_assert!(out.len() >= (row + MR - 1) * n + col + w);
    let op = out.as_mut_ptr();
    let ap = a.as_ptr();
    let pp = panel.as_ptr();

    // Load the accumulation base (bias broadcast or partial sum).
    let mut acc = [[vdupq_n_f32(0.0); WV]; MR];
    for i in 0..MR {
        let base = (row + i) * n + col;
        for v in 0..WV {
            acc[i][v] = vld1q_f32(op.add(base + v * LANES));
        }
    }
    for kk in 0..k {
        // One contiguous panel row: the packed layout puts columns
        // (k, col..col+w) at panel[k*w..(k+1)*w].
        let prow = pp.add(kk * w);
        let mut bv: [float32x4_t; WV] = [vdupq_n_f32(0.0); WV];
        for v in 0..WV {
            bv[v] = vld1q_f32(prow.add(v * LANES));
        }
        for i in 0..MR {
            let av = vdupq_n_f32(*ap.add((row + i) * k + kk));
            for v in 0..WV {
                // Separate mul and add — NOT vfmaq — so every lane
                // rounds twice per step, exactly like the scalar path.
                acc[i][v] = vaddq_f32(acc[i][v], vmulq_f32(av, bv[v]));
            }
        }
    }
    for i in 0..MR {
        let base = (row + i) * n + col;
        for v in 0..WV {
            vst1q_f32(op.add(base + v * LANES), acc[i][v]);
        }
    }
}

/// Dispatch one **int8** accumulator block to its NEON instantiation,
/// or refuse (`false`) if the `(mre, w)` pair has none. Same contract as
/// [`kern_block_neon`], on i8 operands and i32 accumulators. Integer
/// arithmetic is exact, so SIMD/scalar agreement here is trivial — no
/// rounding-order argument needed.
#[allow(clippy::too_many_arguments)] // micro-kernel ABI: block coords + dims
pub(super) fn kern_block_neon_i8(
    out: &mut [i32],
    a: &[i8],
    panel: &[i8],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
    mre: usize,
    w: usize,
) -> bool {
    match w {
        4 => by_rows_i8::<1>(out, a, panel, row, col, k, n, mre),
        8 => by_rows_i8::<2>(out, a, panel, row, col, k, n, mre),
        16 => by_rows_i8::<4>(out, a, panel, row, col, k, n, mre),
        32 => by_rows_i8::<8>(out, a, panel, row, col, k, n, mre),
        _ => false,
    }
}

/// Second dispatch level for the int8 block: monomorphize over rows.
#[allow(clippy::too_many_arguments)]
fn by_rows_i8<const WV: usize>(
    out: &mut [i32],
    a: &[i8],
    panel: &[i8],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
    mre: usize,
) -> bool {
    // SAFETY: NEON is baseline on aarch64 (this module only compiles
    // there); slice bounds are the scalar block's own (checked by the
    // debug asserts inside `kern_i8`).
    unsafe {
        match mre {
            1 => kern_i8::<1, WV>(out, a, panel, row, col, k, n),
            2 => kern_i8::<2, WV>(out, a, panel, row, col, k, n),
            3 => kern_i8::<3, WV>(out, a, panel, row, col, k, n),
            4 => kern_i8::<4, WV>(out, a, panel, row, col, k, n),
            5 => kern_i8::<5, WV>(out, a, panel, row, col, k, n),
            6 => kern_i8::<6, WV>(out, a, panel, row, col, k, n),
            7 => kern_i8::<7, WV>(out, a, panel, row, col, k, n),
            8 => kern_i8::<8, WV>(out, a, panel, row, col, k, n),
            _ => return false,
        }
    }
    true
}

/// `MR x (WV*4)` int8 register block: i32 accumulator vectors, one dot
/// per lane, k ascending. Panel vectors widen in pairs — one 8-byte
/// `vld1_s8` load feeds `vmovl_s8`/`vmovl_s16` into two 4-lane i32
/// vectors — except a lone `w = 4` vector, which widens lane-by-lane
/// (an 8-byte vector load would read past the panel row). The
/// accumulate uses `vmlaq_s32`: integer multiply-add is exact, so the
/// fused form cannot break agreement with the scalar int8 block (unlike
/// the f32 path, where `vfmaq_f32` is banned for its single rounding).
///
/// # Safety
/// The block must lie inside `out`/`a`/`panel` exactly as for the
/// scalar block (same caller, same bounds). NEON is baseline here.
#[target_feature(enable = "neon")]
#[allow(clippy::needless_range_loop)] // explicit lane/row indices mirror the math
unsafe fn kern_i8<const MR: usize, const WV: usize>(
    out: &mut [i32],
    a: &[i8],
    panel: &[i8],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
) {
    let w = WV * LANES;
    debug_assert_eq!(panel.len(), k * w);
    debug_assert!(a.len() >= (row + MR) * k);
    debug_assert!(out.len() >= (row + MR - 1) * n + col + w);
    let op = out.as_mut_ptr();
    let ap = a.as_ptr();
    let pp = panel.as_ptr();

    // Load the accumulation base (zeroed i32 tile from the caller).
    let mut acc = [[vdupq_n_s32(0); WV]; MR];
    for i in 0..MR {
        let base = (row + i) * n + col;
        for v in 0..WV {
            acc[i][v] = vld1q_s32(op.add(base + v * LANES));
        }
    }
    for kk in 0..k {
        let prow = pp.add(kk * w);
        let mut bv: [int32x4_t; WV] = [vdupq_n_s32(0); WV];
        let mut v = 0;
        while v + 2 <= WV {
            // 8 packed i8 columns widened to two 4-lane i32 vectors.
            let b16 = vmovl_s8(vld1_s8(prow.add(v * LANES)));
            bv[v] = vmovl_s16(vget_low_s16(b16));
            bv[v + 1] = vmovl_s16(vget_high_s16(b16));
            v += 2;
        }
        if v < WV {
            let mut wide = [0i32; LANES];
            for l in 0..LANES {
                wide[l] = *prow.add(v * LANES + l) as i32;
            }
            bv[v] = vld1q_s32(wide.as_ptr());
        }
        for i in 0..MR {
            let av = vdupq_n_s32(*ap.add((row + i) * k + kk) as i32);
            for vv in 0..WV {
                acc[i][vv] = vmlaq_s32(acc[i][vv], av, bv[vv]);
            }
        }
    }
    for i in 0..MR {
        let base = (row + i) * n + col;
        for v in 0..WV {
            vst1q_s32(op.add(base + v * LANES), acc[i][v]);
        }
    }
}
