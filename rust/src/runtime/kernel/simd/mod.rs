//! Runtime ISA dispatch for the GEMM micro-kernel: the software
//! analogue of SHARP's reconfigurable datapath width (§4). The register
//! tile chosen by the planner is *data*; this module makes the vector
//! width data too — an [`Isa`] rides on every
//! [`crate::runtime::plan::KernelGeometry`] and selects, per
//! accumulator block, between the scalar micro-kernel and a
//! column-vectorized one ([`x86`] AVX2, [`neon`] on aarch64).
//!
//! **Bit-exactness by construction.** The vector kernels vectorize
//! *across the NR columns of the packed B-panel only*: each SIMD lane
//! owns one output dot product end to end. The contraction loop still
//! runs k = 0..K ascending, and every k-step issues a separate vector
//! multiply then a separate vector add (never an FMA, never a
//! horizontal reduction), so each lane performs exactly the two IEEE
//! f32 roundings per step that the scalar `*o += av * bv` performs, in
//! the same order. A lane therefore computes bit-for-bit the number the
//! scalar oracle computes for its column — for every geometry, shape,
//! and tail. The conformance sweep in `tests/` enforces this, but the
//! argument above is why it can never be violated by a lucky shape.
//!
//! **Dispatch.** [`Isa::detect`] picks the best ISA the host supports
//! (`is_x86_feature_detected!("avx2")` on x86_64; NEON is baseline on
//! aarch64); `SHARP_FORCE_KERNEL=scalar|avx2|neon` (read once per
//! process) or [`crate::runtime::RuntimeConfig::force_kernel`] pins it.
//! Forcing an unavailable ISA is a loud bind-time error, never a silent
//! fallback; an *unforced* geometry that reaches the kernel claiming an
//! unavailable ISA (hand-built, or deserialized on another machine)
//! downgrades defensively to scalar — output-identical either way.
//!
//! Dispatch table (block rows `mre` x panel width `w` → vector kernel;
//! everything else runs the scalar block, bit-identical):
//!
//! | ISA  | lanes | vectorized widths `w`   | rows `mre` |
//! |------|-------|-------------------------|------------|
//! | avx2 | 8     | 8, 16, 32               | 1..=8      |
//! | neon | 4     | 4, 8, 16, 32            | 1..=8      |
//!
//! Lane-unaligned panel widths (an `nr = 4` plan under AVX2, or the
//! ragged last panel when `G*H % nr` is not a lane multiple) take the
//! scalar path for that block — the cost model charges them
//! accordingly ([`crate::runtime::plan::cost`]).
//!
//! The **int8** quantized path ([`kern_block_simd_i8`]) mirrors the
//! dispatch table exactly (same widths, same rows, i32 accumulators).
//! Its exactness argument is simpler: integer multiply-add has no
//! rounding, so the vector and scalar int8 blocks agree bit-for-bit by
//! construction, and the NEON variant may even use the fused
//! `vmlaq_s32`.

#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(target_arch = "x86_64")]
mod x86;

use std::sync::OnceLock;

use crate::error::{bail, Result};

/// Environment knob pinning the micro-kernel ISA for the whole process:
/// `scalar`, `avx2`, or `neon` (empty/unset = auto-detect). Read once
/// and cached; see [`forced_from_env`].
pub const FORCE_KERNEL_ENV: &str = "SHARP_FORCE_KERNEL";

/// A micro-kernel instruction-set choice. Carried by
/// [`crate::runtime::plan::KernelGeometry`]; every variant is
/// bit-identical to [`Isa::Scalar`] (see the module docs), so the
/// choice only ever moves wall time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Isa {
    /// The portable reference path — always available, and the oracle
    /// every vector path must match bit-for-bit.
    #[default]
    Scalar,
    /// 256-bit AVX2 on x86_64 (8 f32 lanes), runtime-detected.
    Avx2,
    /// 128-bit NEON on aarch64 (4 f32 lanes), baseline for the arch.
    Neon,
}

impl Isa {
    /// Every variant, best-vectorized first (the [`Isa::detect`] probe
    /// order).
    pub const ALL: [Isa; 3] = [Isa::Avx2, Isa::Neon, Isa::Scalar];

    /// f32 lanes per vector op: the planner's vector-width dimension.
    /// Architecture-independent (an AVX2 *plan* scores the same
    /// everywhere; only [`Isa::available`] is host-dependent).
    pub fn lanes(self) -> usize {
        match self {
            Isa::Scalar => 1,
            Isa::Avx2 => 8,
            Isa::Neon => 4,
        }
    }

    /// Stable lowercase name (CLI/JSON/`SHARP_FORCE_KERNEL` vocabulary).
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// Parse the [`Isa::name`] vocabulary (case-insensitive).
    pub fn parse(s: &str) -> Result<Isa> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Ok(Isa::Scalar),
            "avx2" => Ok(Isa::Avx2),
            "neon" => Ok(Isa::Neon),
            other => bail!("unknown kernel ISA '{other}' (expected scalar|avx2|neon)"),
        }
    }

    /// Can this host actually execute the variant's kernels?
    pub fn available(self) -> bool {
        match self {
            Isa::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => std::is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => true,
            // The vector ISA of the *other* architecture (and both of
            // them on anything else) is never executable here.
            _ => false,
        }
    }

    /// The best ISA this host supports (never fails: scalar is the
    /// universal floor).
    pub fn detect() -> Isa {
        Isa::ALL
            .into_iter()
            .find(|isa| isa.available())
            .unwrap_or(Isa::Scalar)
    }

    /// Every ISA this host can execute, best-vectorized first. The
    /// conformance tests sweep this so a CI machine exercises exactly
    /// the paths it can prove.
    pub fn supported() -> Vec<Isa> {
        Isa::ALL.into_iter().filter(|isa| isa.available()).collect()
    }
}

/// Parse a `SHARP_FORCE_KERNEL`-style spec: empty means "no forcing".
/// Split from [`forced_from_env`] so tests can cover the parse without
/// racing on process-global environment state.
pub fn parse_force(spec: &str) -> Result<Option<Isa>> {
    let s = spec.trim();
    if s.is_empty() {
        return Ok(None);
    }
    Isa::parse(s).map(Some)
}

/// The process-wide [`FORCE_KERNEL_ENV`] pin, read **once** and cached
/// (a knob that silently changed mid-process would let two executables
/// of the same model disagree on dispatch). An unparseable value is a
/// loud error on every call, not a silent fallback.
pub fn forced_from_env() -> Result<Option<Isa>> {
    static FORCED: OnceLock<Result<Option<Isa>, String>> = OnceLock::new();
    FORCED
        .get_or_init(|| match std::env::var(FORCE_KERNEL_ENV) {
            Ok(spec) => parse_force(&spec).map_err(|e| format!("{FORCE_KERNEL_ENV}: {e:#}")),
            Err(_) => Ok(None),
        })
        .clone()
        .map_err(crate::error::Error::msg)
}

/// Try to run one accumulator block through `isa`'s vector micro-kernel.
/// Returns `false` when the `(isa, rows, width)` triple has no vector
/// instantiation (scalar ISA, lane-unaligned width, off-table rows, or
/// an ISA this host cannot execute) — the caller then runs the scalar
/// block, which is bit-identical by the module-level argument.
#[inline]
#[allow(clippy::too_many_arguments)] // micro-kernel ABI: block coords + dims
pub(super) fn kern_block_simd(
    isa: Isa,
    out: &mut [f32],
    a: &[f32],
    panel: &[f32],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
    mre: usize,
    w: usize,
) -> bool {
    // Soundness gate: `available()` is checked HERE, immediately before
    // the `#[target_feature]` calls, so this stays a safe fn even for a
    // hand-built geometry claiming an ISA the host lacks (the feature
    // detector caches in an atomic; the check is one relaxed load).
    if !isa.available() {
        return false;
    }
    match isa {
        Isa::Scalar => false,
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => x86::kern_block_avx2(out, a, panel, row, col, k, n, mre, w),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => neon::kern_block_neon(out, a, panel, row, col, k, n, mre, w),
        // Cross-architecture variants: `available()` above already said
        // no, but the match must still be exhaustive per target.
        _ => {
            let _ = (out, a, panel, row, col, k, n, mre, w);
            false
        }
    }
}

/// Int8 twin of [`kern_block_simd`]: one i8 accumulator block (i32
/// accumulation) through `isa`'s vector micro-kernel, or `false` when
/// the `(isa, rows, width)` triple has no vector instantiation. The
/// vector and scalar int8 blocks agree exactly — integer arithmetic has
/// no rounding to order — so this dispatch, like the f32 one, only ever
/// moves wall time.
#[inline]
#[allow(clippy::too_many_arguments)] // micro-kernel ABI: block coords + dims
pub(super) fn kern_block_simd_i8(
    isa: Isa,
    out: &mut [i32],
    a: &[i8],
    panel: &[i8],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
    mre: usize,
    w: usize,
) -> bool {
    // Same soundness gate as the f32 dispatch: `available()` is checked
    // immediately before any `#[target_feature]` call.
    if !isa.available() {
        return false;
    }
    match isa {
        Isa::Scalar => false,
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => x86::kern_block_avx2_i8(out, a, panel, row, col, k, n, mre, w),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => neon::kern_block_neon_i8(out, a, panel, row, col, k, n, mre, w),
        _ => {
            let _ = (out, a, panel, row, col, k, n, mre, w);
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lanes_and_names_are_stable() {
        assert_eq!(Isa::Scalar.lanes(), 1);
        assert_eq!(Isa::Avx2.lanes(), 8);
        assert_eq!(Isa::Neon.lanes(), 4);
        assert_eq!(Isa::Scalar.name(), "scalar");
        assert_eq!(Isa::Avx2.name(), "avx2");
        assert_eq!(Isa::Neon.name(), "neon");
    }

    #[test]
    fn parse_roundtrips_names_and_rejects_garbage() {
        for isa in Isa::ALL {
            assert_eq!(Isa::parse(isa.name()).unwrap(), isa);
        }
        assert_eq!(Isa::parse(" AVX2 ").unwrap(), Isa::Avx2);
        assert!(Isa::parse("avx512").is_err());
        assert!(Isa::parse("").is_err());
    }

    #[test]
    fn parse_force_treats_empty_as_unforced() {
        assert_eq!(parse_force("").unwrap(), None);
        assert_eq!(parse_force("  ").unwrap(), None);
        assert_eq!(parse_force("scalar").unwrap(), Some(Isa::Scalar));
        assert_eq!(parse_force("neon").unwrap(), Some(Isa::Neon));
        assert!(parse_force("sse9").is_err());
    }

    #[test]
    fn scalar_is_always_available_and_detect_never_fails() {
        assert!(Isa::Scalar.available());
        let detected = Isa::detect();
        assert!(detected.available());
        let supported = Isa::supported();
        assert!(supported.contains(&Isa::Scalar));
        assert!(supported.contains(&detected));
        // At most one *vector* ISA can be available: the two are on
        // disjoint architectures. The unavailable one is what the
        // forced-dispatch error tests force.
        assert!(!(Isa::Avx2.available() && Isa::Neon.available()));
    }

    #[test]
    fn unavailable_isa_never_dispatches() {
        // Whichever vector ISA this host lacks must hit the soundness
        // gate and report "not handled", leaving the scalar path to run.
        let missing = Isa::ALL
            .into_iter()
            .find(|isa| !isa.available())
            .expect("avx2 and neon are never both available");
        let mut out = [0.0f32; 8];
        let a = [1.0f32; 4];
        let panel = [1.0f32; 32];
        assert!(!kern_block_simd(
            missing, &mut out, &a, &panel, 0, 0, 4, 8, 1, 8
        ));
        assert_eq!(out, [0.0f32; 8], "a refused dispatch must not write");

        let mut qout = [0i32; 8];
        let qa = [1i8; 4];
        let qpanel = [1i8; 32];
        assert!(!kern_block_simd_i8(
            missing, &mut qout, &qa, &qpanel, 0, 0, 4, 8, 1, 8
        ));
        assert_eq!(qout, [0i32; 8], "a refused i8 dispatch must not write");
    }
}
