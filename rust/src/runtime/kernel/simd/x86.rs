//! AVX2 column-vectorized micro-kernels (x86_64).
//!
//! Each `__m256` vector spans 8 consecutive columns of the packed
//! B-panel, so each of its 8 lanes owns one output dot product: lane
//! `j` of accumulator vector `v` for block row `i` is exactly the
//! scalar kernel's `acc[i][v*8 + j]`. Per k-step the kernel issues one
//! broadcast of `a[row+i][k]`, one aligned-width panel load per vector,
//! and a **separate** `_mm256_mul_ps` + `_mm256_add_ps` — two IEEE f32
//! roundings per lane per step, the same two the scalar `*o += av * bv`
//! performs, in the same k-ascending order. No `_mm256_fmadd_ps` (a
//! fused multiply-add rounds once, not twice, and would break
//! bit-identity), no horizontal reductions (a dot never splits across
//! lanes). That is the entire bit-exactness argument; the conformance
//! sweep enforces it per-bit.
//!
//! Instantiations cover block rows 1..=MR_MAX and panel widths
//! {8, 16, 32} (1, 2, or 4 vectors per row). Other widths — `nr = 4`
//! plans, lane-unaligned ragged tails — are refused (`false`) and run
//! the scalar block instead.

use std::arch::x86_64::{
    __m128i, __m256, __m256i, _mm256_add_epi32, _mm256_add_ps, _mm256_cvtepi8_epi32,
    _mm256_loadu_ps, _mm256_loadu_si256, _mm256_mul_ps, _mm256_mullo_epi32, _mm256_set1_epi32,
    _mm256_set1_ps, _mm256_setzero_ps, _mm256_setzero_si256, _mm256_storeu_ps,
    _mm256_storeu_si256, _mm_loadl_epi64,
};

/// f32 lanes per 256-bit vector.
const LANES: usize = 8;

/// Dispatch one accumulator block to its AVX2 instantiation, or refuse
/// (`false`) if the `(mre, w)` pair has none. Caller contract: AVX2 was
/// verified available (the soundness gate in [`super::kern_block_simd`]).
#[allow(clippy::too_many_arguments)] // micro-kernel ABI: block coords + dims
pub(super) fn kern_block_avx2(
    out: &mut [f32],
    a: &[f32],
    panel: &[f32],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
    mre: usize,
    w: usize,
) -> bool {
    match w {
        8 => by_rows::<1>(out, a, panel, row, col, k, n, mre),
        16 => by_rows::<2>(out, a, panel, row, col, k, n, mre),
        32 => by_rows::<4>(out, a, panel, row, col, k, n, mre),
        _ => false,
    }
}

/// Second dispatch level: monomorphize over the block row count.
#[allow(clippy::too_many_arguments)]
fn by_rows<const WV: usize>(
    out: &mut [f32],
    a: &[f32],
    panel: &[f32],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
    mre: usize,
) -> bool {
    debug_assert!(std::is_x86_feature_detected!("avx2"));
    // SAFETY: the caller of `kern_block_avx2` verified AVX2 is available
    // on this host; slice bounds are the scalar block's own (checked by
    // the debug asserts inside `kern`).
    unsafe {
        match mre {
            1 => kern::<1, WV>(out, a, panel, row, col, k, n),
            2 => kern::<2, WV>(out, a, panel, row, col, k, n),
            3 => kern::<3, WV>(out, a, panel, row, col, k, n),
            4 => kern::<4, WV>(out, a, panel, row, col, k, n),
            5 => kern::<5, WV>(out, a, panel, row, col, k, n),
            6 => kern::<6, WV>(out, a, panel, row, col, k, n),
            7 => kern::<7, WV>(out, a, panel, row, col, k, n),
            8 => kern::<8, WV>(out, a, panel, row, col, k, n),
            _ => return false,
        }
    }
    true
}

/// `MR x (WV*8)` register block: WV accumulator vectors per row, one
/// dot product per lane, k ascending, mul-then-add per step.
///
/// # Safety
/// AVX2 must be available, and the block must lie inside `out`/`a`/
/// `panel` exactly as for the scalar `kern` (same caller, same bounds).
#[target_feature(enable = "avx2")]
#[allow(clippy::needless_range_loop)] // explicit lane/row indices mirror the math
unsafe fn kern<const MR: usize, const WV: usize>(
    out: &mut [f32],
    a: &[f32],
    panel: &[f32],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
) {
    let w = WV * LANES;
    debug_assert_eq!(panel.len(), k * w);
    debug_assert!(a.len() >= (row + MR) * k);
    debug_assert!(out.len() >= (row + MR - 1) * n + col + w);
    let op = out.as_mut_ptr();
    let ap = a.as_ptr();
    let pp = panel.as_ptr();

    // Load the accumulation base (bias broadcast or partial sum).
    let mut acc = [[_mm256_setzero_ps(); WV]; MR];
    for i in 0..MR {
        let base = (row + i) * n + col;
        for v in 0..WV {
            acc[i][v] = _mm256_loadu_ps(op.add(base + v * LANES));
        }
    }
    for kk in 0..k {
        // One contiguous panel row: the packed layout puts columns
        // (k, col..col+w) at panel[k*w..(k+1)*w].
        let prow = pp.add(kk * w);
        let mut bv: [__m256; WV] = [_mm256_setzero_ps(); WV];
        for v in 0..WV {
            bv[v] = _mm256_loadu_ps(prow.add(v * LANES));
        }
        for i in 0..MR {
            let av = _mm256_set1_ps(*ap.add((row + i) * k + kk));
            for v in 0..WV {
                // Separate mul and add — NOT fmadd — so every lane
                // rounds twice per step, exactly like the scalar path.
                acc[i][v] = _mm256_add_ps(acc[i][v], _mm256_mul_ps(av, bv[v]));
            }
        }
    }
    for i in 0..MR {
        let base = (row + i) * n + col;
        for v in 0..WV {
            _mm256_storeu_ps(op.add(base + v * LANES), acc[i][v]);
        }
    }
}

/// Dispatch one **int8** accumulator block to its AVX2 instantiation,
/// or refuse (`false`) if the `(mre, w)` pair has none. Same contract as
/// [`kern_block_avx2`], on i8 operands and i32 accumulators. Integer
/// arithmetic is exact, so SIMD/scalar agreement here is trivial — no
/// rounding-order argument needed.
#[allow(clippy::too_many_arguments)] // micro-kernel ABI: block coords + dims
pub(super) fn kern_block_avx2_i8(
    out: &mut [i32],
    a: &[i8],
    panel: &[i8],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
    mre: usize,
    w: usize,
) -> bool {
    match w {
        8 => by_rows_i8::<1>(out, a, panel, row, col, k, n, mre),
        16 => by_rows_i8::<2>(out, a, panel, row, col, k, n, mre),
        32 => by_rows_i8::<4>(out, a, panel, row, col, k, n, mre),
        _ => false,
    }
}

/// Second dispatch level for the int8 block: monomorphize over rows.
#[allow(clippy::too_many_arguments)]
fn by_rows_i8<const WV: usize>(
    out: &mut [i32],
    a: &[i8],
    panel: &[i8],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
    mre: usize,
) -> bool {
    debug_assert!(std::is_x86_feature_detected!("avx2"));
    // SAFETY: the caller of `kern_block_avx2_i8` verified AVX2 is
    // available on this host; slice bounds are the scalar block's own
    // (checked by the debug asserts inside `kern_i8`).
    unsafe {
        match mre {
            1 => kern_i8::<1, WV>(out, a, panel, row, col, k, n),
            2 => kern_i8::<2, WV>(out, a, panel, row, col, k, n),
            3 => kern_i8::<3, WV>(out, a, panel, row, col, k, n),
            4 => kern_i8::<4, WV>(out, a, panel, row, col, k, n),
            5 => kern_i8::<5, WV>(out, a, panel, row, col, k, n),
            6 => kern_i8::<6, WV>(out, a, panel, row, col, k, n),
            7 => kern_i8::<7, WV>(out, a, panel, row, col, k, n),
            8 => kern_i8::<8, WV>(out, a, panel, row, col, k, n),
            _ => return false,
        }
    }
    true
}

/// `MR x (WV*8)` int8 register block: i32 accumulator vectors, one dot
/// per lane, k ascending. Each panel vector loads 8 packed i8 columns
/// (`_mm_loadl_epi64`) and sign-extends them to 8 i32 lanes
/// (`_mm256_cvtepi8_epi32`); the broadcast A value is sign-extended the
/// same way. With |q| <= 127 every product fits i16 and the running i32
/// sum is exact for any realistic K, so this path is bit-identical to
/// the scalar int8 block by integer exactness alone.
///
/// # Safety
/// AVX2 must be available, and the block must lie inside `out`/`a`/
/// `panel` exactly as for the scalar block (same caller, same bounds).
#[target_feature(enable = "avx2")]
#[allow(clippy::needless_range_loop)] // explicit lane/row indices mirror the math
unsafe fn kern_i8<const MR: usize, const WV: usize>(
    out: &mut [i32],
    a: &[i8],
    panel: &[i8],
    row: usize,
    col: usize,
    k: usize,
    n: usize,
) {
    let w = WV * LANES;
    debug_assert_eq!(panel.len(), k * w);
    debug_assert!(a.len() >= (row + MR) * k);
    debug_assert!(out.len() >= (row + MR - 1) * n + col + w);
    let op = out.as_mut_ptr();
    let ap = a.as_ptr();
    let pp = panel.as_ptr();

    // Load the accumulation base (zeroed i32 tile from the caller).
    let mut acc = [[_mm256_setzero_si256(); WV]; MR];
    for i in 0..MR {
        let base = (row + i) * n + col;
        for v in 0..WV {
            acc[i][v] = _mm256_loadu_si256(op.add(base + v * LANES) as *const __m256i);
        }
    }
    for kk in 0..k {
        let prow = pp.add(kk * w);
        let mut bv: [__m256i; WV] = [_mm256_setzero_si256(); WV];
        for v in 0..WV {
            // 8 packed i8 panel columns, sign-extended to 8 i32 lanes.
            let b8 = _mm_loadl_epi64(prow.add(v * LANES) as *const __m128i);
            bv[v] = _mm256_cvtepi8_epi32(b8);
        }
        for i in 0..MR {
            let av = _mm256_set1_epi32(*ap.add((row + i) * k + kk) as i32);
            for v in 0..WV {
                acc[i][v] = _mm256_add_epi32(acc[i][v], _mm256_mullo_epi32(av, bv[v]));
            }
        }
    }
    for i in 0..MR {
        let base = (row + i) * n + col;
        for v in 0..WV {
            _mm256_storeu_si256(op.add(base + v * LANES) as *mut __m256i, acc[i][v]);
        }
    }
}
