//! The artifact runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json` produced by `python/compile/aot.py`) and executes them
//! with the crate's built-in executor — the tiled, allocation-free
//! kernel layer (`kernel`), bit-identical to the scalar reference
//! kernels (`exec`) that remain the test oracle. Python never runs at
//! serve time.
//!
//! Interchange is HLO *text* plus raw little-endian `.f32` goldens. The
//! offline crate registry carries no XLA/PJRT binding, so execution does
//! not FFI into a compiler: `ArtifactStore::executable` validates and
//! "compiles" the HLO text into a [`artifact::CompiledArtifact`] handle
//! (checking it really is an `HloModule`, caching per name), and
//! [`LstmExecutable::run`] evaluates the model with `exec`'s reference
//! LSTM/GRU forward passes — the same math `aot.py` cross-checks its
//! goldens against (`python/compile/kernels/ref.py`). A real PJRT backend
//! can slot in behind the same `executable()`/`run()` seam later without
//! touching callers. Manifest entries carrying `layers`/`bidirectional`/
//! `P` bind through [`StackExecutable`] instead, which plans each layer
//! independently and pipelines the stack across threads.
//!
//! Thread-confinement: the store's compile cache is `Rc`/`RefCell`-based,
//! so an `ArtifactStore` (and executables bound from it) stays on the
//! thread that created it. The coordinator's worker thread owns its own
//! store + executables; only plain request/response data crosses threads.

pub mod artifact;
pub mod exec;
pub mod kernel;
pub mod literal;
pub mod lstm;
pub mod plan;
pub mod quant;
pub mod stack;

pub use artifact::{ArtifactStore, CompiledArtifact, Manifest, ManifestEntry};
pub use kernel::{ExecScratch, FusedBatch, Isa};
pub use lstm::{LstmExecutable, LstmOutput};
pub use plan::{Dtype, ExecPlan, KernelGeometry, ModelDims, PlanMode, Schedule};
pub use stack::{DirWeights, StackExecutable, StackLayerWeights, StackOutput};

use crate::error::{bail, Result};

/// Executor tuning knobs, plumbed from the CLI (`sharp serve/infer
/// --threads/--plan`) and [`crate::coordinator::ServerConfig`] down to
/// each executable's kernel calls.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RuntimeConfig {
    /// Upper bound on row-parallel fan-out inside one GEMM
    /// (`std::thread::scope` over contiguous row chunks). `1` keeps
    /// every kernel serial; the effective count per call is work-gated
    /// by [`kernel::gemm::effective_threads`] against the plan's
    /// `min_flops_per_thread` threshold, so small recurrent MVMs never
    /// pay spawn overhead. Results are bit-identical for any value —
    /// threading only changes which thread computes which rows.
    pub threads: usize,
    /// How each executable derives its [`ExecPlan`] (register-tile
    /// geometry, thread gate, schedule): pin one geometry, let the cost
    /// model choose per model shape (`Auto`, the default — deterministic,
    /// matches the old fixed MR=4/NR=16 point on its sweet-spot shapes
    /// and adapts off it), or additionally time a shortlist at bind
    /// (`Calibrated`). Every mode is bit-identical to every other; only
    /// wall time changes.
    pub plan: PlanMode,
    /// Pin the micro-kernel vector ISA instead of auto-detecting.
    /// `None` defers to the `SHARP_FORCE_KERNEL` environment knob (read
    /// once per process) and then to [`Isa::detect`]. Forcing an ISA
    /// this host cannot execute is a loud error at plan resolution
    /// ([`Self::resolve_isa`]), never a silent fallback — the knob
    /// exists so tests and benches can *prove* which path ran. Every
    /// ISA is bit-identical; only wall time changes.
    pub force_kernel: Option<Isa>,
    /// Weight precision for the kernel path: [`Dtype::F32`] (default)
    /// runs the dense bit-exact path; [`Dtype::Int8`] quantizes weights
    /// per gate at bind ([`quant`]) and runs the fused-dequant GEMMs.
    /// **Unlike** every other knob in this struct, int8 changes the
    /// numbers — outputs carry a documented quantization error against
    /// the f32 oracle (`tests/quant_conformance.rs`) — but the int8
    /// path is itself bit-identical across ISAs/threads/plans, so the
    /// error budget is a property of the dtype, not the dispatch.
    pub dtype: Dtype,
}

impl RuntimeConfig {
    /// Resolve the micro-kernel ISA this config dispatches to:
    /// [`Self::force_kernel`], else the process-wide
    /// `SHARP_FORCE_KERNEL` pin, else the best detected ISA. Errors
    /// loudly when forced (either way) to an ISA this host cannot
    /// execute, or when the environment value is unparseable.
    pub fn resolve_isa(&self) -> Result<Isa> {
        let forced = match self.force_kernel {
            Some(isa) => Some(isa),
            None => kernel::simd::forced_from_env()?,
        };
        match forced {
            Some(isa) if isa.available() => Ok(isa),
            Some(isa) => bail!(
                "forced kernel ISA '{}' is not available on this host (best detected: '{}')",
                isa.name(),
                Isa::detect().name()
            ),
            None => Ok(Isa::detect()),
        }
    }
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        RuntimeConfig {
            threads: 1,
            plan: PlanMode::Auto,
            force_kernel: None,
            dtype: Dtype::F32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_isa_defaults_to_detection() {
        // No explicit force: the config resolves to a host-executable
        // ISA. (CI's SHARP_FORCE_KERNEL=scalar run narrows this to
        // scalar; either way the result must be available.)
        let isa = RuntimeConfig::default().resolve_isa().unwrap();
        assert!(isa.available());
    }

    #[test]
    fn explicit_force_wins_when_available() {
        let cfg = RuntimeConfig {
            force_kernel: Some(Isa::Scalar),
            ..Default::default()
        };
        assert_eq!(cfg.resolve_isa().unwrap(), Isa::Scalar);
        let detected = Isa::detect();
        let cfg = RuntimeConfig {
            force_kernel: Some(detected),
            ..Default::default()
        };
        assert_eq!(cfg.resolve_isa().unwrap(), detected);
    }

    #[test]
    fn forcing_an_unavailable_isa_errors_loudly() {
        // AVX2 and NEON live on disjoint architectures, so one of them
        // is always unavailable here — forcing it must name the problem.
        let missing = Isa::ALL
            .into_iter()
            .find(|isa| !isa.available())
            .expect("avx2 and neon are never both available");
        let cfg = RuntimeConfig {
            force_kernel: Some(missing),
            ..Default::default()
        };
        let err = cfg.resolve_isa().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains(missing.name()), "{msg}");
        assert!(msg.contains("not available"), "{msg}");
    }
}
