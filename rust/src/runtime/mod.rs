//! The PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json` produced by `python/compile/aot.py`) and executes them
//! on the XLA CPU client from the L3 hot path. Python never runs here.
//!
//! Interchange is HLO *text* (not serialized protos — jax>=0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids).
//!
//! Thread-confinement: the `xla` crate's client/executable handles are
//! `!Send` (Rc-based FFI wrappers), so every PJRT object lives on the
//! thread that created it. The coordinator's worker thread owns its own
//! client + executables; this module provides a thread-local client.

pub mod artifact;
pub mod literal;
pub mod lstm;

pub use artifact::{ArtifactStore, Manifest, ManifestEntry};
pub use lstm::{LstmExecutable, LstmOutput};

use std::cell::RefCell;
use std::rc::Rc;

thread_local! {
    static CLIENT: RefCell<Option<Rc<xla::PjRtClient>>> = const { RefCell::new(None) };
}

/// Get (or lazily create) this thread's PJRT CPU client.
pub fn client() -> anyhow::Result<Rc<xla::PjRtClient>> {
    CLIENT.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            let c = xla::PjRtClient::cpu()
                .map_err(|e| anyhow::anyhow!("PJRT CPU client init failed: {e:?}"))?;
            *slot = Some(Rc::new(c));
        }
        Ok(slot.as_ref().expect("set above").clone())
    })
}
