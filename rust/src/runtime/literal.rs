//! Tensor-literal helpers: raw little-endian `.f32` golden files (written
//! by `aot.py`) and flat-buffer shape checks for the built-in executor.

use std::path::Path;

use crate::error::{bail, Context, Result};

/// Check that a flat row-major buffer matches a shape (the executor's
/// stand-in for building a device literal of that shape).
pub fn check_shape(data: &[f32], shape: &[usize]) -> Result<()> {
    let expect: usize = shape.iter().product();
    if expect != data.len() {
        bail!("shape {shape:?} wants {expect} elements, got {}", data.len());
    }
    Ok(())
}

/// Read a raw little-endian f32 file (the golden format).
pub fn read_f32_file(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    if bytes.len() % 4 != 0 {
        bail!("{path:?}: length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write a raw little-endian f32 file (round-trips `read_f32_file`).
pub fn write_f32_file(path: &Path, data: &[f32]) -> Result<()> {
    let bytes: Vec<u8> = data.iter().flat_map(|f| f.to_le_bytes()).collect();
    std::fs::write(path, bytes).with_context(|| format!("writing {path:?}"))
}

/// Max absolute difference between two vectors (golden comparison).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// Panic unless `got` equals `want` down to the f32 bit pattern — the
/// kernel-vs-oracle contract (stricter than `==`: distinguishes ±0.0
/// and treats identical NaNs as equal). Shared by the kernel unit
/// tests, `tests/kernel_equivalence.rs`, and `benches/perf_runtime.rs`
/// so the comparison that defines "bit-identical" has one definition.
pub fn assert_bits_eq(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{ctx}: element {i}: {g} vs {w}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_check() {
        let data: Vec<f32> = (0..12).map(|i| i as f32).collect();
        assert!(check_shape(&data, &[3, 4]).is_ok());
        assert!(check_shape(&data, &[3]).is_err());
        assert!(check_shape(&[], &[0]).is_ok());
    }

    #[test]
    fn f32_file_roundtrip() {
        let dir = std::env::temp_dir().join("sharp_lit_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.f32");
        let data = [1.5f32, -2.25, 0.0, 1e9];
        write_f32_file(&p, &data).unwrap();
        assert_eq!(read_f32_file(&p).unwrap(), data);
    }

    #[test]
    fn truncated_file_rejected() {
        let dir = std::env::temp_dir().join("sharp_lit_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.f32");
        std::fs::write(&p, [0u8; 7]).unwrap();
        assert!(read_f32_file(&p).is_err());
    }

    #[test]
    fn max_abs_diff_basics() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.0, 2.5]), 0.5);
        assert_eq!(max_abs_diff(&[], &[]), 0.0);
    }
}
