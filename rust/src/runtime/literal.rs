//! Literal conversion helpers: `Vec<f32>` + shape ⇄ `xla::Literal`, and
//! raw little-endian `.f32` golden files (written by `aot.py`).

use std::path::Path;

use anyhow::{bail, Context, Result};

/// Build an f32 literal of the given shape from a flat row-major vec.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let expect: usize = shape.iter().product();
    if expect != data.len() {
        bail!("shape {shape:?} wants {expect} elements, got {}", data.len());
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape to {shape:?} failed: {e:?}"))
}

/// Flatten a literal back to f32s.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("literal -> Vec<f32> failed: {e:?}"))
}

/// Read a raw little-endian f32 file (the golden format).
pub fn read_f32_file(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    if bytes.len() % 4 != 0 {
        bail!("{path:?}: length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Max absolute difference between two vectors (golden comparison).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let data: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let lit = literal_f32(&data, &[3, 4]).unwrap();
        assert_eq!(to_vec_f32(&lit).unwrap(), data);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
    }

    #[test]
    fn f32_file_roundtrip() {
        let dir = std::env::temp_dir().join("sharp_lit_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.f32");
        let data = [1.5f32, -2.25, 0.0, 1e9];
        let bytes: Vec<u8> = data.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(&p, bytes).unwrap();
        assert_eq!(read_f32_file(&p).unwrap(), data);
    }

    #[test]
    fn max_abs_diff_basics() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.0, 2.5]), 0.5);
        assert_eq!(max_abs_diff(&[], &[]), 0.0);
    }
}
