//! Artifact manifest + HLO loading + compile cache.
//!
//! `aot.py` writes `artifacts/manifest.json` describing every lowered
//! model variant (shapes, golden input/output files, HLO text path).
//! `ArtifactStore` parses it, compiles HLO on first use, and caches the
//! loaded executables for the serving hot path.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// One named tensor in the manifest (input or output golden).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub file: String,
}

/// One AOT-compiled model variant.
#[derive(Debug, Clone, PartialEq)]
pub struct ManifestEntry {
    pub name: String,
    /// "cell" (one step) or "seq" (full unfolded sequence).
    pub kind: String,
    pub hlo_file: String,
    pub t: usize,
    pub b: usize,
    pub d: usize,
    pub h: usize,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub gate_order: String,
    pub entries: Vec<ManifestEntry>,
}

fn tensor_meta(v: &Json, default_name: &str) -> Result<TensorMeta> {
    let shape = v
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("tensor missing shape"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    Ok(TensorMeta {
        name: v
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or(default_name)
            .to_string(),
        shape,
        file: v
            .get("file")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("tensor missing file"))?
            .to_string(),
    })
}

impl Manifest {
    /// Parse `manifest.json` text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let root = json::parse(text).context("manifest.json parse")?;
        let gate_order = root
            .get("gate_order")
            .and_then(Json::as_str)
            .unwrap_or("ifgo")
            .to_string();
        let arts = root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        let mut entries = Vec::new();
        for a in arts {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let get_dim = |k: &str| -> Result<usize> {
                a.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("{name}: missing {k}"))
            };
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: missing inputs"))?
                .iter()
                .map(|v| tensor_meta(v, "?"))
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: missing outputs"))?
                .iter()
                .enumerate()
                .map(|(i, v)| tensor_meta(v, &format!("out{i}")))
                .collect::<Result<Vec<_>>>()?;
            entries.push(ManifestEntry {
                name: name.clone(),
                kind: a
                    .get("kind")
                    .and_then(Json::as_str)
                    .unwrap_or("seq")
                    .to_string(),
                hlo_file: a
                    .get("hlo")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("{name}: missing hlo"))?
                    .to_string(),
                t: get_dim("T")?,
                b: get_dim("B")?,
                d: get_dim("D")?,
                h: get_dim("H")?,
                inputs,
                outputs,
            });
        }
        Ok(Manifest {
            gate_order,
            entries,
        })
    }

    pub fn find(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Pick the best `seq` artifact for a request: same hidden dim, the
    /// smallest T bucket that fits (least padding); at equal T prefer the
    /// widest batch bucket (matches the coordinator's router, so batched
    /// and unbatched paths bind the same artifact + weights).
    pub fn pick_seq(&self, hidden: usize, seq_len: usize, batch: usize) -> Option<&ManifestEntry> {
        self.entries
            .iter()
            .filter(|e| e.kind == "seq" && e.h == hidden && e.t >= seq_len && e.b >= batch)
            .min_by_key(|e| (e.t, std::cmp::Reverse(e.b)))
    }
}

/// Compiled-executable cache over a manifest directory.
///
/// PJRT handles are `!Send`; an `ArtifactStore` (and everything compiled
/// from it) must stay on the thread that created it.
pub struct ArtifactStore {
    pub dir: PathBuf,
    pub manifest: Manifest,
    compiled: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl ArtifactStore {
    /// Open `artifacts/` (reads + parses the manifest; compiles lazily).
    pub fn open(dir: &Path) -> Result<ArtifactStore> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?} (run `make artifacts`)"))?;
        Ok(ArtifactStore {
            dir: dir.to_path_buf(),
            manifest: Manifest::parse(&text)?,
            compiled: RefCell::new(HashMap::new()),
        })
    }

    /// Default location: `$SHARP_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<ArtifactStore> {
        let dir = std::env::var("SHARP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::open(Path::new(&dir))
    }

    /// Compile (or fetch the cached) executable for an artifact.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.compiled.borrow().get(name) {
            return Ok(e.clone());
        }
        let entry = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let path = self.dir.join(&entry.hlo_file);
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| anyhow!("HLO text load {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let client = super::client()?;
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("PJRT compile of {name}: {e:?}"))?;
        let exe = Rc::new(exe);
        self.compiled
            .borrow_mut()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Load a golden tensor file of an entry.
    pub fn golden(&self, meta: &TensorMeta) -> Result<Vec<f32>> {
        let v = super::literal::read_f32_file(&self.dir.join(&meta.file))?;
        let expect: usize = meta.shape.iter().product();
        if v.len() != expect {
            bail!("{}: {} elements, shape wants {expect}", meta.file, v.len());
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{"version":1,"gate_order":"ifgo","artifacts":[
      {"name":"seq_h64_t8_b1","kind":"seq","hlo":"a.hlo.txt","T":8,"B":1,"D":64,"H":64,
       "inputs":[{"name":"xs","shape":[8,1,64],"file":"xs.f32"}],
       "outputs":[{"shape":[8,1,64],"file":"o.f32"}]},
      {"name":"seq_h64_t16_b4","kind":"seq","hlo":"b.hlo.txt","T":16,"B":4,"D":64,"H":64,
       "inputs":[],"outputs":[]},
      {"name":"cell_h64_b1","kind":"cell","hlo":"c.hlo.txt","T":1,"B":1,"D":64,"H":64,
       "inputs":[],"outputs":[]}]}"#;

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(DOC).unwrap();
        assert_eq!(m.gate_order, "ifgo");
        assert_eq!(m.entries.len(), 3);
        let e = m.find("seq_h64_t8_b1").unwrap();
        assert_eq!(e.t, 8);
        assert_eq!(e.inputs[0].shape, vec![8, 1, 64]);
    }

    #[test]
    fn pick_seq_smallest_fitting_bucket() {
        let m = Manifest::parse(DOC).unwrap();
        // Fits in the T=8 bucket (smallest T wins even though T=16 has
        // a wider batch).
        assert_eq!(m.pick_seq(64, 5, 1).unwrap().name, "seq_h64_t8_b1");
        // Needs batch 2 -> only the b4 bucket fits.
        assert_eq!(m.pick_seq(64, 8, 2).unwrap().name, "seq_h64_t16_b4");
        // Nothing fits T=40.
        assert!(m.pick_seq(64, 40, 1).is_none());
        // Cell artifacts are never picked for sequences.
        assert!(m.pick_seq(64, 1, 1).unwrap().kind == "seq");
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"artifacts":[{"name":"x"}]}"#).is_err());
    }
}
