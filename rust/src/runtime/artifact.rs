//! Artifact manifest + HLO loading + compile cache.
//!
//! `aot.py` writes `artifacts/manifest.json` describing every lowered
//! model variant (shapes, golden input/output files, HLO text path).
//! `ArtifactStore` parses it, "compiles" HLO text on first use (validating
//! that the file really is an `HloModule` and recording its entry
//! computation), and caches the loaded handles for the serving hot path.
//! Execution itself happens in [`crate::runtime::exec`]; a real PJRT
//! backend can replace [`CompiledArtifact`] behind the same `executable()`
//! seam without touching callers.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use crate::error::{anyhow, bail, Context, Result};
use crate::util::json::{self, Json};

/// One named tensor in the manifest (input or output golden).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub file: String,
}

/// One AOT-compiled model variant.
#[derive(Debug, Clone, PartialEq)]
pub struct ManifestEntry {
    pub name: String,
    /// "cell" (one step) or "seq" (full unfolded sequence); GRU variants
    /// use the "gru_cell" / "gru_seq" kinds.
    pub kind: String,
    pub hlo_file: String,
    pub t: usize,
    pub b: usize,
    pub d: usize,
    pub h: usize,
    /// Stack depth (manifest key `layers`, default 1). Entries deeper
    /// than 1 bind one weight set per layer (`wx{l}`/`wh{l}`/`b{l}`) and
    /// execute through [`crate::runtime::StackExecutable`].
    pub layers: usize,
    /// Bidirectional stack (manifest key `bidirectional`, default
    /// false): every layer runs a forward and a reverse direction
    /// (reverse weights carry an `_r` suffix) and emits the
    /// concatenation `[h_fwd | h_bwd]` per step.
    pub bidirectional: bool,
    /// Output-projection width (manifest key `P`, default 0 = none):
    /// each layer's hidden output is projected `(B,H) x (H,P)` through
    /// `wp{l}` before feeding the next layer. The recurrence itself
    /// keeps the full H.
    pub proj: usize,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
}

impl ManifestEntry {
    /// Per-step, per-direction output width of one layer: `P` when the
    /// layer projects, `H` otherwise.
    pub fn dir_width(&self) -> usize {
        if self.proj > 0 {
            self.proj
        } else {
            self.h
        }
    }

    /// Per-step output width of one full layer (both directions when
    /// bidirectional): what the next layer consumes as its input dim.
    pub fn out_width(&self) -> usize {
        self.dir_width() * if self.bidirectional { 2 } else { 1 }
    }

    /// Input dim seen by layer `l` of the stack: `D` at layer 0, the
    /// previous layer's [`Self::out_width`] above it.
    pub fn layer_input_dim(&self, l: usize) -> usize {
        if l == 0 {
            self.d
        } else {
            self.out_width()
        }
    }

    /// True for depth>1, bidirectional, or projecting entries — the ones
    /// that execute through the stacked driver rather than the
    /// single-layer [`crate::runtime::LstmExecutable`].
    pub fn is_stacked(&self) -> bool {
        self.layers > 1 || self.bidirectional || self.proj > 0
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub gate_order: String,
    pub entries: Vec<ManifestEntry>,
}

fn tensor_meta(v: &Json, default_name: &str) -> Result<TensorMeta> {
    let shape = v
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("tensor missing shape"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    Ok(TensorMeta {
        name: v
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or(default_name)
            .to_string(),
        shape,
        file: v
            .get("file")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("tensor missing file"))?
            .to_string(),
    })
}

impl Manifest {
    /// Parse `manifest.json` text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let root = json::parse(text).context("manifest.json parse")?;
        let gate_order = root
            .get("gate_order")
            .and_then(Json::as_str)
            .unwrap_or("ifgo")
            .to_string();
        let arts = root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        let mut entries = Vec::new();
        for a in arts {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let get_dim = |k: &str| -> Result<usize> {
                a.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("{name}: missing {k}"))
            };
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: missing inputs"))?
                .iter()
                .map(|v| tensor_meta(v, "?"))
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: missing outputs"))?
                .iter()
                .enumerate()
                .map(|(i, v)| tensor_meta(v, &format!("out{i}")))
                .collect::<Result<Vec<_>>>()?;
            entries.push(ManifestEntry {
                name: name.clone(),
                kind: a
                    .get("kind")
                    .and_then(Json::as_str)
                    .unwrap_or("seq")
                    .to_string(),
                hlo_file: a
                    .get("hlo")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("{name}: missing hlo"))?
                    .to_string(),
                t: get_dim("T")?,
                b: get_dim("B")?,
                d: get_dim("D")?,
                h: get_dim("H")?,
                layers: a.get("layers").and_then(Json::as_usize).unwrap_or(1).max(1),
                bidirectional: a
                    .get("bidirectional")
                    .and_then(Json::as_bool)
                    .unwrap_or(false),
                proj: a.get("P").and_then(Json::as_usize).unwrap_or(0),
                inputs,
                outputs,
            });
        }
        Ok(Manifest {
            gate_order,
            entries,
        })
    }

    pub fn find(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// All FLAT `seq`-kind entries of one hidden dim — the batched
    /// bucket inventory a serving worker compiles for that model
    /// variant. Stacked entries (layers/bidirectional/projection) bind
    /// a different executable and serve solo; they are listed by
    /// [`Self::stacked_entries`] instead.
    pub fn seq_entries(&self, hidden: usize) -> impl Iterator<Item = &ManifestEntry> {
        self.entries
            .iter()
            .filter(move |e| e.kind == "seq" && e.h == hidden && !e.is_stacked())
    }

    /// Stacked seq entries (any kind) of one hidden dim — what a worker
    /// binds through `StackExecutable` and serves by artifact name.
    pub fn stacked_entries(&self, hidden: usize) -> impl Iterator<Item = &ManifestEntry> {
        self.entries
            .iter()
            .filter(move |e| e.kind.ends_with("seq") && e.h == hidden && e.is_stacked())
    }

    /// The artifact streaming sessions pin for a hidden dim: the
    /// largest-T `seq` bucket (narrowest B at equal T). Every chunk of a
    /// session must bind ONE weight set, so the serving worker, the
    /// examples, and the carry-correctness tests all resolve it here.
    pub fn session_seq(&self, hidden: usize) -> Option<&ManifestEntry> {
        self.seq_entries(hidden)
            .max_by_key(|e| (e.t, std::cmp::Reverse(e.b)))
    }

    /// Hidden dims with at least one FLAT `seq` artifact (sorted,
    /// deduped) — what a multi-variant server can offer to serve. A dim
    /// carrying only stacked entries cannot seed the batched buckets,
    /// so it is not offered here.
    pub fn seq_hidden_dims(&self) -> Vec<usize> {
        let mut dims: Vec<usize> = self
            .entries
            .iter()
            .filter(|e| e.kind == "seq" && !e.is_stacked())
            .map(|e| e.h)
            .collect();
        dims.sort_unstable();
        dims.dedup();
        dims
    }

    /// Pick the best `seq` artifact for a request: same hidden dim, the
    /// smallest T bucket that fits (least padding); at equal T prefer the
    /// widest batch bucket (matches the coordinator's router, so batched
    /// and unbatched paths bind the same artifact + weights).
    pub fn pick_seq(&self, hidden: usize, seq_len: usize, batch: usize) -> Option<&ManifestEntry> {
        self.entries
            .iter()
            .filter(|e| {
                e.kind == "seq" && !e.is_stacked() && e.h == hidden && e.t >= seq_len
                    && e.b >= batch
            })
            .min_by_key(|e| (e.t, std::cmp::Reverse(e.b)))
    }
}

/// A loaded, validated HLO artifact — the built-in executor's stand-in for
/// a PJRT loaded executable. Loading checks the text is really an
/// `HloModule` dump, so corrupt artifacts fail at "compile" time, not at
/// execute time.
#[derive(Debug, Clone, PartialEq)]
pub struct CompiledArtifact {
    /// Artifact name (manifest key).
    pub name: String,
    /// Name of the `HloModule` declared in the text.
    pub module_name: String,
    /// Full HLO text as lowered by `aot.py`.
    pub hlo_text: String,
}

impl CompiledArtifact {
    /// Validate and wrap HLO text (the "compile" step of the built-in
    /// backend: cheap, but it enforces the same artifact hygiene a real
    /// compiler would).
    pub fn from_hlo_text(name: &str, text: &str) -> Result<CompiledArtifact> {
        let header = text
            .lines()
            .find(|l| !l.trim().is_empty())
            .unwrap_or_default();
        if !header.trim_start().starts_with("HloModule") {
            bail!("{name}: not an HloModule text dump (first line: {header:?})");
        }
        let module_name = header
            .trim_start()
            .trim_start_matches("HloModule")
            .trim()
            .split(|c: char| c == ',' || c.is_whitespace())
            .next()
            .unwrap_or("")
            .to_string();
        if module_name.is_empty() {
            bail!("{name}: HloModule header carries no module name");
        }
        Ok(CompiledArtifact {
            name: name.to_string(),
            module_name,
            hlo_text: text.to_string(),
        })
    }
}

/// Compiled-artifact cache over a manifest directory.
///
/// The cache is `Rc`/`RefCell`-based, so an `ArtifactStore` (and handles
/// loaded from it) stays on the thread that created it — the same
/// confinement a PJRT-backed store would need. This is the per-worker
/// open seam of the serving pool: every coordinator worker opens its OWN
/// store on its own thread (`coordinator::worker::build_groups`), holds
/// the executables it loaded for its lifetime, and nothing store-derived
/// ever crosses a thread boundary.
pub struct ArtifactStore {
    pub dir: PathBuf,
    pub manifest: Manifest,
    compiled: RefCell<HashMap<String, Rc<CompiledArtifact>>>,
}

impl ArtifactStore {
    /// Open `artifacts/` (reads + parses the manifest; compiles lazily).
    pub fn open(dir: &Path) -> Result<ArtifactStore> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?} (run `make artifacts`)"))?;
        Ok(ArtifactStore {
            dir: dir.to_path_buf(),
            manifest: Manifest::parse(&text)?,
            compiled: RefCell::new(HashMap::new()),
        })
    }

    /// Default location: `$SHARP_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<ArtifactStore> {
        let dir = std::env::var("SHARP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::open(Path::new(&dir))
    }

    /// Load (or fetch the cached) compiled handle for an artifact.
    pub fn executable(&self, name: &str) -> Result<Rc<CompiledArtifact>> {
        if let Some(e) = self.compiled.borrow().get(name) {
            return Ok(e.clone());
        }
        let entry = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let path = self.dir.join(&entry.hlo_file);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("HLO text load {path:?}"))?;
        let exe = Rc::new(CompiledArtifact::from_hlo_text(name, &text)?);
        self.compiled
            .borrow_mut()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Load a golden tensor file of an entry.
    pub fn golden(&self, meta: &TensorMeta) -> Result<Vec<f32>> {
        let v = super::literal::read_f32_file(&self.dir.join(&meta.file))?;
        let expect: usize = meta.shape.iter().product();
        if v.len() != expect {
            bail!("{}: {} elements, shape wants {expect}", meta.file, v.len());
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{"version":1,"gate_order":"ifgo","artifacts":[
      {"name":"seq_h64_t8_b1","kind":"seq","hlo":"a.hlo.txt","T":8,"B":1,"D":64,"H":64,
       "inputs":[{"name":"xs","shape":[8,1,64],"file":"xs.f32"}],
       "outputs":[{"shape":[8,1,64],"file":"o.f32"}]},
      {"name":"seq_h64_t16_b4","kind":"seq","hlo":"b.hlo.txt","T":16,"B":4,"D":64,"H":64,
       "inputs":[],"outputs":[]},
      {"name":"cell_h64_b1","kind":"cell","hlo":"c.hlo.txt","T":1,"B":1,"D":64,"H":64,
       "inputs":[],"outputs":[]},
      {"name":"stack3_h80_t8_b1","kind":"seq","hlo":"d.hlo.txt","T":8,"B":1,"D":32,"H":80,
       "layers":3,"bidirectional":true,"P":16,"inputs":[],"outputs":[]}]}"#;

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(DOC).unwrap();
        assert_eq!(m.gate_order, "ifgo");
        assert_eq!(m.entries.len(), 4);
        let e = m.find("seq_h64_t8_b1").unwrap();
        assert_eq!(e.t, 8);
        assert_eq!(e.inputs[0].shape, vec![8, 1, 64]);
        // Stack fields default to a plain single-layer entry.
        assert_eq!((e.layers, e.bidirectional, e.proj), (1, false, 0));
        assert!(!e.is_stacked());
        assert_eq!(e.out_width(), 64);
        assert_eq!(e.layer_input_dim(0), 64);
        assert_eq!(e.layer_input_dim(1), 64);
    }

    #[test]
    fn parses_stacked_entry() {
        let m = Manifest::parse(DOC).unwrap();
        let e = m.find("stack3_h80_t8_b1").unwrap();
        assert_eq!((e.layers, e.bidirectional, e.proj), (3, true, 16));
        assert!(e.is_stacked());
        // Projection narrows each direction to P; bi doubles it.
        assert_eq!(e.dir_width(), 16);
        assert_eq!(e.out_width(), 32);
        // Layer 0 reads the model input; deeper layers read the concat
        // of the previous layer's (projected) directions.
        assert_eq!(e.layer_input_dim(0), 32);
        assert_eq!(e.layer_input_dim(2), 32);
    }

    #[test]
    fn pick_seq_smallest_fitting_bucket() {
        let m = Manifest::parse(DOC).unwrap();
        // Fits in the T=8 bucket (smallest T wins even though T=16 has
        // a wider batch).
        assert_eq!(m.pick_seq(64, 5, 1).unwrap().name, "seq_h64_t8_b1");
        // Needs batch 2 -> only the b4 bucket fits.
        assert_eq!(m.pick_seq(64, 8, 2).unwrap().name, "seq_h64_t16_b4");
        // Nothing fits T=40.
        assert!(m.pick_seq(64, 40, 1).is_none());
        // Cell artifacts are never picked for sequences.
        assert!(m.pick_seq(64, 1, 1).unwrap().kind == "seq");
    }

    #[test]
    fn seq_inventory_helpers() {
        let m = Manifest::parse(DOC).unwrap();
        let names: Vec<&str> = m.seq_entries(64).map(|e| e.name.as_str()).collect();
        assert_eq!(names, vec!["seq_h64_t8_b1", "seq_h64_t16_b4"]);
        assert!(m.seq_entries(999).next().is_none());
        // Stacked entries live in their own inventory, not the flat one.
        assert!(m.seq_entries(80).next().is_none());
        let stacked: Vec<&str> = m.stacked_entries(80).map(|e| e.name.as_str()).collect();
        assert_eq!(stacked, vec!["stack3_h80_t8_b1"]);
        assert!(m.stacked_entries(64).next().is_none());
        // Cell artifacts never appear in the serving inventory, and a
        // dim with only stacked entries is not offered for flat serving.
        assert_eq!(m.seq_hidden_dims(), vec![64]);
        // Sessions pin the largest-T bucket.
        assert_eq!(m.session_seq(64).unwrap().name, "seq_h64_t16_b4");
        assert!(m.session_seq(999).is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"artifacts":[{"name":"x"}]}"#).is_err());
    }

    #[test]
    fn compile_accepts_hlo_and_rejects_garbage() {
        let good = "HloModule lstm_seq_h64, entry_computation_layout={()->f32[]}\n\nENTRY main {}\n";
        let c = CompiledArtifact::from_hlo_text("a", good).unwrap();
        assert_eq!(c.module_name, "lstm_seq_h64");
        assert!(CompiledArtifact::from_hlo_text("b", "this is not HLO").is_err());
        assert!(CompiledArtifact::from_hlo_text("c", "HloModule").is_err());
    }
}
