//! The scalar reference executor: dense f32 LSTM / GRU forward passes
//! matching the L2 JAX models bit-for-shape (`python/compile/model.py`,
//! oracle in `python/compile/kernels/ref.py`).
//!
//! Since the tiled kernel layer landed ([`crate::runtime::kernel`]),
//! these step-at-a-time scalar kernels are the **test oracle**: the
//! serving path runs the unfolded tiled schedule, and
//! `tests/kernel_equivalence.rs` asserts it stays bit-identical to the
//! functions here. The activation stages ([`lstm_cell_update`],
//! [`gru_cell_update`]) are shared with the kernel layer so the two
//! paths can only diverge in GEMM strategy — which M/N-only tiling
//! makes rounding-neutral.
//!
//! Gate conventions (shared repo-wide, recorded in `manifest.json`):
//! * LSTM — fused matrices are `(.., 4H)` with column blocks
//!   `[input | forget | cell(g) | output]` ("ifgo"):
//!   `c' = sigmoid(f)*c + sigmoid(i)*tanh(g)`, `h' = sigmoid(o)*tanh(c')`.
//! * GRU — `(.., 3H)` with blocks `[reset | update | candidate]`
//!   (cuDNN-style "linear before reset", so the input MVM hoists out of
//!   the recurrence exactly like the Unfolded schedule requires):
//!   `r = sig(xr+hr)`, `z = sig(xz+hz)`, `n = tanh(xn + r*hn)`,
//!   `h' = (1-z)*n + z*h`. The bias is applied on the input half only,
//!   mirroring `gru_cell_ref`.
//!
//! All tensors are row-major flat `&[f32]`: `x (B, D)`, `xs (T, B, D)`,
//! `h/c (B, H)`, `wx (D, G*H)`, `wh (H, G*H)`, `bias (G*H)`.

// The executor entry points mirror the artifact calling convention
// (tensors + the four shape dims), which runs past clippy's 7-argument
// heuristic by design.
#![allow(clippy::too_many_arguments)]

/// `out[m][n] += a[m][k] * b[k][n]` — row-major dense matmul accumulate.
///
/// Dense on purpose: the old `*ak == 0.0` skip branch tested the INPUT
/// operand (`x_t`/`h`), so on dense activations it was a data-dependent
/// branch per k-iteration in the hottest loop that inhibited
/// vectorization; the case it did help — the zero-padded tail of a
/// short sequence in a bucketed batch — is better served by not issuing
/// those steps at all (`run_prefix` stops exactly at the chunk's last
/// frame, and the tiled layer hoists the input GEMM so padding cost is
/// amortized). Sparsity support, when it lands, should be an explicit
/// sparse-aware kernel, not a branch buried here (DESIGN.md §6).
/// Accumulation runs k-ascending into each output element — the
/// ordering contract the tiled kernel layer
/// ([`crate::runtime::kernel`]) preserves for bit-exactness.
pub(crate) fn matmul_acc(out: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let out_row = &mut out[i * n..(i + 1) * n];
        for (ak, b_row) in a_row.iter().zip(b.chunks_exact(n)) {
            for (o, bv) in out_row.iter_mut().zip(b_row) {
                *o += ak * bv;
            }
        }
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// The LSTM activation stage: gates `(B, 4H)` in "ifgo" order + previous
/// cell state -> new `(h, c)`. Shared by the scalar reference path and
/// the tiled kernel layer, so the two can only differ in GEMM strategy
/// (the bit-exactness seam). `h_out`/`c_out` must not alias the inputs.
pub(crate) fn lstm_cell_update(
    pre: &[f32],
    c_prev: &[f32],
    h_out: &mut [f32],
    c_out: &mut [f32],
    b: usize,
    hid: usize,
) {
    debug_assert_eq!(pre.len(), b * 4 * hid);
    for bi in 0..b {
        let row = &pre[bi * 4 * hid..(bi + 1) * 4 * hid];
        for j in 0..hid {
            let (i_g, f_g, g_g, o_g) = (row[j], row[hid + j], row[2 * hid + j], row[3 * hid + j]);
            let cv = sigmoid(f_g) * c_prev[bi * hid + j] + sigmoid(i_g) * g_g.tanh();
            c_out[bi * hid + j] = cv;
            h_out[bi * hid + j] = sigmoid(o_g) * cv.tanh();
        }
    }
}

/// The GRU activation stage ("linear before reset"): input-half and
/// hidden-half gates `(B, 3H)` in "rzn" order + previous hidden state ->
/// new `h`. Shared by the scalar and tiled paths like
/// [`lstm_cell_update`]. `h_out` must not alias `h_prev`.
pub(crate) fn gru_cell_update(
    xpre: &[f32],
    hpre: &[f32],
    h_prev: &[f32],
    h_out: &mut [f32],
    b: usize,
    hid: usize,
) {
    debug_assert_eq!(xpre.len(), b * 3 * hid);
    debug_assert_eq!(hpre.len(), b * 3 * hid);
    for bi in 0..b {
        let xr = &xpre[bi * 3 * hid..(bi + 1) * 3 * hid];
        let hr = &hpre[bi * 3 * hid..(bi + 1) * 3 * hid];
        for j in 0..hid {
            let r = sigmoid(xr[j] + hr[j]);
            let z = sigmoid(xr[hid + j] + hr[hid + j]);
            let n = (xr[2 * hid + j] + r * hr[2 * hid + j]).tanh();
            h_out[bi * hid + j] = (1.0 - z) * n + z * h_prev[bi * hid + j];
        }
    }
}

/// Broadcast `bias` over every row of `buf` (zeros when `bias` is empty).
/// `pub(crate)` because the tiled kernel layer's `scratch::fill_bias`
/// delegates here: the accumulation base of every gate element has ONE
/// definition across the oracle and the planned kernels.
pub(crate) fn broadcast_bias(buf: &mut [f32], bias: &[f32], rows: usize, width: usize) {
    debug_assert_eq!(buf.len(), rows * width);
    if bias.is_empty() {
        buf.fill(0.0);
    } else {
        debug_assert_eq!(bias.len(), width);
        for row in buf.chunks_exact_mut(width) {
            row.copy_from_slice(bias);
        }
    }
}

/// Output projection for stacked LSTMP layers: `out = x @ wp` over
/// `rows` rows, `(rows, H) x (H, P)`. Zeroes `out`, then accumulates
/// k-ascending through [`matmul_acc`] — the ONE definition of the
/// projection shared by the sequential stacked driver and the pipelined
/// stack ([`crate::runtime::kernel::stack`]), so the two paths execute
/// literally the same float ops and cannot diverge bit-wise.
pub(crate) fn project(out: &mut [f32], x: &[f32], wp: &[f32], rows: usize, hid: usize, p: usize) {
    debug_assert_eq!(x.len(), rows * hid);
    debug_assert_eq!(wp.len(), hid * p);
    debug_assert_eq!(out.len(), rows * p);
    out.fill(0.0);
    matmul_acc(out, x, wp, rows, hid, p);
}

/// Pre-activations for one step: `x @ w + bias_broadcast` with shape
/// `(B, G*H)`; pass `bias = &[]` to skip the bias add.
fn preact(x: &[f32], w: &[f32], bias: &[f32], b: usize, d: usize, gh: usize) -> Vec<f32> {
    let mut out = vec![0.0; b * gh];
    broadcast_bias(&mut out, bias, b, gh);
    matmul_acc(&mut out, x, w, b, d, gh);
    out
}

/// One LSTM step. Returns `(h_new, c_new)`, each `(B, H)`.
pub fn lstm_step(
    x: &[f32],
    h: &[f32],
    c: &[f32],
    wx: &[f32],
    wh: &[f32],
    bias: &[f32],
    b: usize,
    d: usize,
    hid: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut pre = preact(x, wx, bias, b, d, 4 * hid);
    matmul_acc(&mut pre, h, wh, b, hid, 4 * hid);
    let mut h_new = vec![0.0; b * hid];
    let mut c_new = vec![0.0; b * hid];
    lstm_cell_update(&pre, c, &mut h_new, &mut c_new, b, hid);
    (h_new, c_new)
}

/// Full-sequence LSTM. `xs` is `(T, B, D)`; returns `(hs (T, B, H), h_T, c_T)`.
///
/// The carry is double-buffered: the pre-activation buffer and both
/// `(h, c)` buffers are allocated once and swapped per step instead of
/// reallocated — same op sequence, no per-step `Vec` churn.
pub fn lstm_seq(
    xs: &[f32],
    h0: &[f32],
    c0: &[f32],
    wx: &[f32],
    wh: &[f32],
    bias: &[f32],
    t: usize,
    b: usize,
    d: usize,
    hid: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let gh = 4 * hid;
    let mut hs = Vec::with_capacity(t * b * hid);
    let mut h = h0.to_vec();
    let mut c = c0.to_vec();
    let mut h_nxt = vec![0.0; b * hid];
    let mut c_nxt = vec![0.0; b * hid];
    let mut pre = vec![0.0; b * gh];
    for step in 0..t {
        let x_t = &xs[step * b * d..(step + 1) * b * d];
        broadcast_bias(&mut pre, bias, b, gh);
        matmul_acc(&mut pre, x_t, wx, b, d, gh);
        matmul_acc(&mut pre, &h, wh, b, hid, gh);
        lstm_cell_update(&pre, &c, &mut h_nxt, &mut c_nxt, b, hid);
        hs.extend_from_slice(&h_nxt);
        std::mem::swap(&mut h, &mut h_nxt);
        std::mem::swap(&mut c, &mut c_nxt);
    }
    (hs, h, c)
}

/// One GRU step. Returns `h_new` of shape `(B, H)`.
pub fn gru_step(
    x: &[f32],
    h: &[f32],
    wx: &[f32],
    wh: &[f32],
    bias: &[f32],
    b: usize,
    d: usize,
    hid: usize,
) -> Vec<f32> {
    let xpre = preact(x, wx, bias, b, d, 3 * hid);
    let hpre = preact(h, wh, &[], b, hid, 3 * hid);
    let mut h_new = vec![0.0; b * hid];
    gru_cell_update(&xpre, &hpre, h, &mut h_new, b, hid);
    h_new
}

/// Full-sequence GRU. Returns `(hs (T, B, H), h_T)`.
///
/// Double-buffered like [`lstm_seq`]: both pre-activation buffers and
/// the `h` carry are allocated once and reused across steps.
pub fn gru_seq(
    xs: &[f32],
    h0: &[f32],
    wx: &[f32],
    wh: &[f32],
    bias: &[f32],
    t: usize,
    b: usize,
    d: usize,
    hid: usize,
) -> (Vec<f32>, Vec<f32>) {
    let gh = 3 * hid;
    let mut hs = Vec::with_capacity(t * b * hid);
    let mut h = h0.to_vec();
    let mut h_nxt = vec![0.0; b * hid];
    let mut xpre = vec![0.0; b * gh];
    let mut hpre = vec![0.0; b * gh];
    for step in 0..t {
        let x_t = &xs[step * b * d..(step + 1) * b * d];
        broadcast_bias(&mut xpre, bias, b, gh);
        matmul_acc(&mut xpre, x_t, wx, b, d, gh);
        hpre.fill(0.0);
        matmul_acc(&mut hpre, &h, wh, b, hid, gh);
        gru_cell_update(&xpre, &hpre, &h, &mut h_nxt, b, hid);
        hs.extend_from_slice(&h_nxt);
        std::mem::swap(&mut h, &mut h_nxt);
    }
    (hs, h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matmul_identity() {
        // 2x2 identity times arbitrary matrix.
        let eye = [1.0, 0.0, 0.0, 1.0];
        let m = [3.0, -1.0, 0.5, 2.0];
        let mut out = vec![0.0; 4];
        matmul_acc(&mut out, &eye, &m, 2, 2, 2);
        assert_eq!(out, m);
    }

    #[test]
    fn matmul_known_product() {
        // [[1,2],[3,4]] @ [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut out = vec![0.0; 4];
        matmul_acc(&mut out, &a, &b, 2, 2, 2);
        assert_eq!(out, [19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn lstm_zero_weights_halve_cell_state() {
        // All-zero weights: every gate pre-activation is 0, so
        // i=f=o=sigmoid(0)=0.5, g=tanh(0)=0 ->
        // c' = 0.5*c0, h' = 0.5*tanh(0.5*c0).
        let (b, d, h) = (2usize, 3usize, 4usize);
        let mut rng = Rng::new(7);
        let x = rng.vec_f32(b * d, -1.0, 1.0);
        let h0 = rng.vec_f32(b * h, -1.0, 1.0);
        let c0 = rng.vec_f32(b * h, -1.0, 1.0);
        let wx = vec![0.0; d * 4 * h];
        let wh = vec![0.0; h * 4 * h];
        let bias = vec![0.0; 4 * h];
        let (h1, c1) = lstm_step(&x, &h0, &c0, &wx, &wh, &bias, b, d, h);
        for i in 0..b * h {
            assert!((c1[i] - 0.5 * c0[i]).abs() < 1e-6, "cell {i}");
            assert!((h1[i] - 0.5 * (0.5 * c0[i]).tanh()).abs() < 1e-6, "hidden {i}");
        }
    }

    #[test]
    fn gru_zero_weights_halve_hidden() {
        // Zero weights + zero bias: r=z=sigmoid(0)=0.5, n=tanh(0)=0 ->
        // h' = 0.5*h.
        let (b, d, h) = (1usize, 2usize, 3usize);
        let x = vec![0.3; b * d];
        let h0 = vec![0.8, -0.4, 0.1];
        let wx = vec![0.0; d * 3 * h];
        let wh = vec![0.0; h * 3 * h];
        let bias = vec![0.0; 3 * h];
        let h1 = gru_step(&x, &h0, &wx, &wh, &bias, b, d, h);
        for i in 0..b * h {
            assert!((h1[i] - 0.5 * h0[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn seq_equals_repeated_cell_steps() {
        // The schedule-invariance argument behind streaming sessions: a
        // seq run must equal stepping the cell T times with carried state.
        let (t, b, d, h) = (5usize, 2usize, 4usize, 4usize);
        let mut rng = Rng::new(42);
        let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
        let h0 = rng.vec_f32(b * h, -1.0, 1.0);
        let c0 = rng.vec_f32(b * h, -1.0, 1.0);
        let wx = rng.vec_f32(d * 4 * h, -0.2, 0.2);
        let wh = rng.vec_f32(h * 4 * h, -0.2, 0.2);
        let bias = rng.vec_f32(4 * h, -0.2, 0.2);

        let (hs, h_t, c_t) = lstm_seq(&xs, &h0, &c0, &wx, &wh, &bias, t, b, d, h);
        let (mut hc, mut cc) = (h0.clone(), c0.clone());
        for step in 0..t {
            let x_t = &xs[step * b * d..(step + 1) * b * d];
            let (hn, cn) = lstm_step(x_t, &hc, &cc, &wx, &wh, &bias, b, d, h);
            for i in 0..b * h {
                assert!((hs[step * b * h + i] - hn[i]).abs() < 1e-6);
            }
            hc = hn;
            cc = cn;
        }
        for i in 0..b * h {
            assert!((h_t[i] - hc[i]).abs() < 1e-6);
            assert!((c_t[i] - cc[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn gru_seq_matches_stepping() {
        let (t, b, d, h) = (4usize, 1usize, 3usize, 5usize);
        let mut rng = Rng::new(9);
        let xs = rng.vec_f32(t * b * d, -1.0, 1.0);
        let h0 = rng.vec_f32(b * h, -1.0, 1.0);
        let wx = rng.vec_f32(d * 3 * h, -0.2, 0.2);
        let wh = rng.vec_f32(h * 3 * h, -0.2, 0.2);
        let bias = rng.vec_f32(3 * h, -0.2, 0.2);
        let (hs, h_t) = gru_seq(&xs, &h0, &wx, &wh, &bias, t, b, d, h);
        let mut hc = h0.clone();
        for step in 0..t {
            let x_t = &xs[step * b * d..(step + 1) * b * d];
            hc = gru_step(x_t, &hc, &wx, &wh, &bias, b, d, h);
            for i in 0..b * h {
                assert!((hs[step * b * h + i] - hc[i]).abs() < 1e-6);
            }
        }
        assert_eq!(&hs[(t - 1) * b * h..], &h_t[..]);
    }

    #[test]
    fn outputs_bounded_by_activations() {
        // h is a product of sigmoids and tanhs -> |h| < 1 always.
        let (t, b, d, h) = (8usize, 2usize, 6usize, 6usize);
        let mut rng = Rng::new(1234);
        let xs = rng.vec_f32(t * b * d, -5.0, 5.0);
        let h0 = rng.vec_f32(b * h, -1.0, 1.0);
        let c0 = rng.vec_f32(b * h, -1.0, 1.0);
        let wx = rng.vec_f32(d * 4 * h, -2.0, 2.0);
        let wh = rng.vec_f32(h * 4 * h, -2.0, 2.0);
        let bias = rng.vec_f32(4 * h, -2.0, 2.0);
        let (hs, h_t, _) = lstm_seq(&xs, &h0, &c0, &wx, &wh, &bias, t, b, d, h);
        assert!(hs.iter().chain(&h_t).all(|v| v.abs() < 1.0));
    }
}
