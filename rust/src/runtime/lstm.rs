//! Typed LSTM execution over a compiled artifact: weights held as
//! literals, requests supply the input sequence and recurrent state.

use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use super::artifact::{ArtifactStore, ManifestEntry};
use super::literal::{literal_f32, to_vec_f32};

/// Gates of an artifact kind: 4 for LSTM, 3 for GRU (paper §8).
fn gates_of(kind: &str) -> usize {
    if kind.starts_with("gru") {
        3
    } else {
        4
    }
}

/// Output of one LSTM execution.
#[derive(Debug, Clone)]
pub struct LstmOutput {
    /// Hidden outputs for every step: (T, B, H) flattened (seq artifacts)
    /// or (B, H) (cell artifacts: the single step's h).
    pub hs: Vec<f32>,
    /// Final hidden state (B, H).
    pub h_t: Vec<f32>,
    /// Final cell state (B, H).
    pub c_t: Vec<f32>,
}

/// A compiled LSTM variant bound to a parameter set.
pub struct LstmExecutable {
    pub entry: ManifestEntry,
    exe: Rc<xla::PjRtLoadedExecutable>,
    /// Weights kept as host literals, uploaded per call (weights-stationary
    /// buffer donation is not exposed by this PJRT wrapper; see §Perf).
    wx: Vec<f32>,
    wh: Vec<f32>,
    bias: Vec<f32>,
}

impl LstmExecutable {
    /// Bind an artifact to its golden weights (the shipped parameter set).
    pub fn from_store_goldens(store: &ArtifactStore, name: &str) -> Result<LstmExecutable> {
        let entry = store
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
            .clone();
        let exe = store.executable(name)?;
        let find = |n: &str| -> Result<Vec<f32>> {
            let meta = entry
                .inputs
                .iter()
                .find(|i| i.name == n)
                .ok_or_else(|| anyhow!("{name}: no input '{n}'"))?;
            store.golden(meta)
        };
        Ok(LstmExecutable {
            exe,
            wx: find("wx")?,
            wh: find("wh")?,
            bias: find("b")?,
            entry,
        })
    }

    /// Bind with explicit weights. The fused gate matrix is `gates()*H`
    /// columns wide (4 for LSTM kinds, 3 for GRU kinds).
    pub fn with_weights(
        store: &ArtifactStore,
        name: &str,
        wx: Vec<f32>,
        wh: Vec<f32>,
        bias: Vec<f32>,
    ) -> Result<LstmExecutable> {
        let entry = store
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
            .clone();
        let (d, h) = (entry.d, entry.h);
        let g = gates_of(&entry.kind);
        if wx.len() != d * g * h || wh.len() != h * g * h || bias.len() != g * h {
            bail!("{name}: weight shapes do not match D={d} H={h} gates={g}");
        }
        Ok(LstmExecutable {
            exe: store.executable(name)?,
            wx,
            wh,
            bias,
            entry,
        })
    }

    /// Run the artifact. `xs` is (T, B, D) for seq artifacts (zero-pad the
    /// tail beyond the real sequence) or (B, D) for cell artifacts; `h0`,
    /// `c0` are (B, H). GRU kinds take no cell state: `c0` is ignored and
    /// the returned `c_t` mirrors `h_t` (the uniform-interface convention
    /// documented in python/compile/model.py).
    pub fn run(&self, xs: &[f32], h0: &[f32], c0: &[f32]) -> Result<LstmOutput> {
        let e = &self.entry;
        let (t, b, d, h) = (e.t, e.b, e.d, e.h);
        let is_seq = e.kind.ends_with("seq");
        let is_gru = e.kind.starts_with("gru");
        let g = gates_of(&e.kind);
        let want_xs = if is_seq { t * b * d } else { b * d };
        if xs.len() != want_xs || h0.len() != b * h || c0.len() != b * h {
            bail!(
                "{}: bad input sizes xs={} (want {want_xs}) h0={} c0={}",
                e.name,
                xs.len(),
                h0.len(),
                c0.len()
            );
        }
        let xs_lit = if is_seq {
            literal_f32(xs, &[t, b, d])?
        } else {
            literal_f32(xs, &[b, d])?
        };
        let mut args = vec![xs_lit, literal_f32(h0, &[b, h])?];
        if !is_gru {
            args.push(literal_f32(c0, &[b, h])?);
        }
        args.push(literal_f32(&self.wx, &[d, g * h])?);
        args.push(literal_f32(&self.wh, &[h, g * h])?);
        args.push(literal_f32(&self.bias, &[g * h])?);
        let bufs = self
            .exe
            .execute::<xla::Literal>(&args)
            .map_err(|err| anyhow!("{}: execute failed: {err:?}", e.name))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .map_err(|err| anyhow!("{}: readback failed: {err:?}", e.name))?;
        // aot.py lowers with return_tuple=True.
        let parts = result
            .to_tuple()
            .map_err(|err| anyhow!("{}: tuple unwrap failed: {err:?}", e.name))?;
        if is_seq {
            if parts.len() != 3 {
                bail!("{}: expected 3 outputs, got {}", e.name, parts.len());
            }
            Ok(LstmOutput {
                hs: to_vec_f32(&parts[0])?,
                h_t: to_vec_f32(&parts[1])?,
                c_t: to_vec_f32(&parts[2])?,
            })
        } else {
            if parts.len() != 2 {
                bail!("{}: expected 2 outputs, got {}", e.name, parts.len());
            }
            let h_new = to_vec_f32(&parts[0])?;
            Ok(LstmOutput {
                hs: h_new.clone(),
                h_t: h_new,
                c_t: to_vec_f32(&parts[1])?,
            })
        }
    }

    /// Zero initial state sized for this artifact.
    pub fn zero_state(&self) -> (Vec<f32>, Vec<f32>) {
        let n = self.entry.b * self.entry.h;
        (vec![0.0; n], vec![0.0; n])
    }

    /// Pad a (seq_len, B, D) payload out to this artifact's (T, B, D).
    pub fn pad_sequence(&self, xs: &[f32], seq_len: usize) -> Result<Vec<f32>> {
        let e = &self.entry;
        if !e.kind.ends_with("seq") {
            bail!("{} is not a seq artifact", e.name);
        }
        if seq_len > e.t {
            bail!("{}: seq_len {} exceeds bucket T={}", e.name, seq_len, e.t);
        }
        if xs.len() != seq_len * e.b * e.d {
            bail!("{}: payload len {} != {}", e.name, xs.len(), seq_len * e.b * e.d);
        }
        let mut out = xs.to_vec();
        out.resize(e.t * e.b * e.d, 0.0);
        Ok(out)
    }
}

// Integration tests against real artifacts live in rust/tests/ (they need
// `make artifacts` to have run); unit tests here cover the pure helpers.
#[cfg(test)]
mod tests {
    #[test]
    fn padding_math() {
        // pad_sequence requires a live store; the pure padding rule is
        // resize(T*B*D) with zeros — checked indirectly in integration
        // tests. Here we only pin the zero-state sizing contract.
        // (See rust/tests/runtime_roundtrip.rs.)
    }
}
