//! Typed LSTM/GRU execution over a compiled artifact: weights are
//! packed into tile panels at bind time (the raw dense copies are
//! dropped — one resident weight copy), requests supply the input
//! sequence and recurrent state. Execution runs on the tiled kernel layer
//! ([`crate::runtime::kernel`]) under the unfolded schedule —
//! bit-identical to the scalar reference ([`crate::runtime::exec`]) by
//! construction and by test; the artifact handle pins the HLO the
//! weights were lowered against.
//!
//! Each executable owns an [`ExecScratch`] (packed weight panels +
//! unfolded pre-activation and state buffers) and the `*_into` entry
//! points write into caller-reused [`LstmOutput`] buffers, so the
//! steady-state serving path performs zero heap allocations per
//! request. The store (and everything bound from it) is thread-confined
//! anyway (`Rc`), so the interior `RefCell` never contends.

use std::cell::RefCell;
use std::rc::Rc;

use crate::error::{anyhow, bail, Result};

use super::artifact::{ArtifactStore, CompiledArtifact, ManifestEntry};
use super::kernel::{self, ExecScratch, FusedBatch};
use super::plan::{tuner, Dtype, ExecPlan, ModelDims, Schedule};
use super::RuntimeConfig;

/// Output of one LSTM execution. `Default` gives empty buffers sized on
/// first use — keep one around and pass it to [`LstmExecutable::run_into`]
/// to amortize the allocations away entirely.
#[derive(Debug, Clone, Default)]
pub struct LstmOutput {
    /// Hidden outputs for every step: (T, B, H) flattened (seq artifacts)
    /// or (B, H) (cell artifacts: the single step's h).
    pub hs: Vec<f32>,
    /// Final hidden state (B, H).
    pub h_t: Vec<f32>,
    /// Final cell state (B, H). GRU kinds have no cell state; by the
    /// uniform-interface convention (python/compile/model.py) this mirrors
    /// `h_t` for them.
    pub c_t: Vec<f32>,
}

/// A compiled LSTM variant bound to a parameter set.
pub struct LstmExecutable {
    pub entry: ManifestEntry,
    exe: Rc<CompiledArtifact>,
    /// The dense `wx`/`wh` are packed into the scratch's panels at bind
    /// time and dropped — the panels are the only resident copy of the
    /// weight matrices; `bias (G*H)` is kept raw for the per-row
    /// broadcast. Gate order per the manifest.
    bias: Vec<f32>,
    /// Kernel knobs (thread fan-out, plan mode); see [`RuntimeConfig`].
    runtime: RuntimeConfig,
    /// The execution plan resolved from `runtime.plan` for THIS model's
    /// (D, H, B, T): register-tile geometry, thread gate, schedule.
    /// Derived at bind, re-derived by [`Self::set_runtime`]; every
    /// candidate is bit-identical, so the plan only moves wall time.
    plan: ExecPlan,
    /// Kernel workspace bound to THIS weight set: packed panels plus
    /// pre-activation/state buffers, reused across requests.
    scratch: RefCell<ExecScratch>,
}

impl LstmExecutable {
    /// Bind an artifact to its golden weights (the shipped parameter set)
    /// under the default runtime config (serial, Auto plan).
    pub fn from_store_goldens(store: &ArtifactStore, name: &str) -> Result<LstmExecutable> {
        Self::from_store_goldens_with(store, name, RuntimeConfig::default())
    }

    /// [`from_store_goldens`] with explicit runtime knobs: the plan is
    /// resolved under `cfg.plan` BEFORE the weight panels are packed, so
    /// the panels are built once at the right width (no plan-then-repack
    /// round-trip at startup).
    ///
    /// [`from_store_goldens`]: LstmExecutable::from_store_goldens
    pub fn from_store_goldens_with(
        store: &ArtifactStore,
        name: &str,
        cfg: RuntimeConfig,
    ) -> Result<LstmExecutable> {
        let entry = store
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
            .clone();
        let exe = store.executable(name)?;
        let find = |n: &str| -> Result<Vec<f32>> {
            let meta = entry
                .inputs
                .iter()
                .find(|i| i.name == n)
                .ok_or_else(|| anyhow!("{name}: no input '{n}'"))?;
            store.golden(meta)
        };
        let (wx, wh, bias) = (find("wx")?, find("wh")?, find("b")?);
        Self::bind(exe, entry, wx, wh, bias, cfg)
    }

    /// Bind with explicit weights. The fused gate matrix is `gates()*H`
    /// columns wide (4 for LSTM kinds, 3 for GRU kinds).
    pub fn with_weights(
        store: &ArtifactStore,
        name: &str,
        wx: Vec<f32>,
        wh: Vec<f32>,
        bias: Vec<f32>,
    ) -> Result<LstmExecutable> {
        Self::with_weights_with(store, name, wx, wh, bias, RuntimeConfig::default())
    }

    /// [`with_weights`] with explicit runtime knobs — the entry point
    /// that lets callers bind a quantized (int8) executable over their
    /// own parameter set.
    ///
    /// [`with_weights`]: LstmExecutable::with_weights
    pub fn with_weights_with(
        store: &ArtifactStore,
        name: &str,
        wx: Vec<f32>,
        wh: Vec<f32>,
        bias: Vec<f32>,
        cfg: RuntimeConfig,
    ) -> Result<LstmExecutable> {
        let entry = store
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
            .clone();
        let exe = store.executable(name)?;
        Self::bind(exe, entry, wx, wh, bias, cfg)
    }

    /// Common bind step: validate the weight shapes against the entry
    /// (a manifest whose golden shapes disagree with its D/H/kind must
    /// fail HERE with a named error, not panic inside `pack_b`), resolve
    /// the execution plan for this model's (D, H, B, T) under the given
    /// config's plan mode, then pack the dense weights into panels ONCE
    /// — at the plan's panel width — and drop the raw copies: the panels
    /// are the only resident weight memory from here on; the bias stays
    /// raw. (A later `set_runtime` that changes the geometry repacks the
    /// panels in place from themselves.)
    fn bind(
        exe: Rc<CompiledArtifact>,
        entry: ManifestEntry,
        wx: Vec<f32>,
        wh: Vec<f32>,
        bias: Vec<f32>,
        runtime: RuntimeConfig,
    ) -> Result<LstmExecutable> {
        let (d, h) = (entry.d, entry.h);
        let dims = ModelDims::of_entry(&entry);
        let g = dims.gates;
        if wx.len() != d * g * h || wh.len() != h * g * h || bias.len() != g * h {
            bail!(
                "{}: weight shapes do not match D={d} H={h} gates={g}",
                entry.name
            );
        }
        // Resolve the kernel ISA (force knob / env pin / detection)
        // BEFORE planning: a forced-but-unavailable ISA must fail the
        // bind loudly, and the tuner scores candidates per vector width.
        let isa = runtime.resolve_isa()?;
        let plan = tuner::plan_for_dtype(&dims, &runtime.plan, isa, runtime.dtype);
        let mut scratch = ExecScratch::new();
        // Latch the one resident weight representation the plan's dtype
        // will read — quantizing HERE, from the raw dense weights, is
        // what makes dropping them safe (int8 scales cannot be
        // recovered from f32 panels, and vice versa).
        match runtime.dtype {
            Dtype::Int8 => scratch.ensure_quant(&wx, &wh, d, h, g * h, plan.geometry.nr),
            Dtype::F32 => scratch.ensure_packed(&wx, &wh, d, h, g * h, plan.geometry.nr),
        }
        Ok(LstmExecutable {
            exe,
            bias,
            entry,
            runtime,
            plan,
            scratch: RefCell::new(scratch),
        })
    }

    /// The compiled artifact this executable is bound to.
    pub fn artifact(&self) -> &CompiledArtifact {
        &self.exe
    }

    /// Set the kernel knobs (thread fan-out, plan mode, forced ISA) and
    /// re-resolve the execution plan for this model. A geometry change
    /// repacks the resident weight panels in place (config-time cost,
    /// never on the request path); an ISA change alone does not touch
    /// the panels (the vector kernels read the same packed layout with
    /// unaligned loads). Errors if the config forces an ISA this host
    /// cannot execute. Output is bit-identical for any setting; only
    /// wall time changes.
    pub fn set_runtime(&mut self, cfg: RuntimeConfig) -> Result<()> {
        if cfg.dtype != self.runtime.dtype {
            // The raw dense weights were dropped at bind; the resident
            // representation cannot change dtype in place.
            bail!(
                "{}: dtype change ({} -> {}) requires rebinding",
                self.entry.name,
                self.runtime.dtype.name(),
                cfg.dtype.name()
            );
        }
        let isa = cfg.resolve_isa()?;
        let e = &self.entry;
        let dims = ModelDims::of_entry(e);
        let plan = tuner::plan_for_dtype(&dims, &cfg.plan, isa, cfg.dtype);
        let gh = dims.gates * e.h;
        let mut scr = self.scratch.borrow_mut();
        match cfg.dtype {
            // The quant latch is already set; this only re-widths the
            // resident int8 panels (raw args are never read).
            Dtype::Int8 => scr.ensure_quant(&[], &[], e.d, e.h, gh, plan.geometry.nr),
            Dtype::F32 => scr.repack(e.d, e.h, gh, plan.geometry.nr),
        }
        drop(scr);
        self.plan = plan;
        self.runtime = cfg;
        Ok(())
    }

    /// Current kernel knobs.
    pub fn runtime(&self) -> &RuntimeConfig {
        &self.runtime
    }

    /// The execution plan this executable resolved for its model shape.
    pub fn plan(&self) -> &ExecPlan {
        &self.plan
    }

    /// Run the artifact. `xs` is (T, B, D) for seq artifacts (zero-pad the
    /// tail beyond the real sequence) or (B, D) for cell artifacts; `h0`,
    /// `c0` are (B, H). GRU kinds take no cell state: `c0` is ignored and
    /// the returned `c_t` mirrors `h_t` (the uniform-interface convention
    /// documented in python/compile/model.py).
    pub fn run(&self, xs: &[f32], h0: &[f32], c0: &[f32]) -> Result<LstmOutput> {
        let mut out = LstmOutput::default();
        self.run_into(xs, h0, c0, &mut out)?;
        Ok(out)
    }

    /// [`run`], but writing into a caller-owned output whose buffer
    /// capacity is reused — the allocation-free serving entry point
    /// (the coordinator worker keeps one `LstmOutput` per bucket).
    ///
    /// [`run`]: LstmExecutable::run
    pub fn run_into(
        &self,
        xs: &[f32],
        h0: &[f32],
        c0: &[f32],
        out: &mut LstmOutput,
    ) -> Result<()> {
        let e = &self.entry;
        let (t, b, d, h) = (e.t, e.b, e.d, e.h);
        let is_seq = e.kind.ends_with("seq");
        let want_xs = if is_seq { t * b * d } else { b * d };
        if xs.len() != want_xs || h0.len() != b * h || c0.len() != b * h {
            bail!(
                "{}: bad input sizes xs={} (want {want_xs}) h0={} c0={}",
                e.name,
                xs.len(),
                h0.len(),
                c0.len()
            );
        }
        // Cell artifacts are the T=1 case of the same unfolded schedule:
        // hs comes out as (1, B, H) == the step's h.
        self.execute(xs, h0, c0, if is_seq { t } else { 1 }, out);
        Ok(())
    }

    /// Dispatch the (validated) tensors onto the tiled kernel layer.
    /// The raw-weight arguments are `&[]`: [`Self::bind`] packed the
    /// dense weights into the scratch and dropped them, and the
    /// kernel's one-shot pack latch means those arguments are never
    /// read on this path.
    fn execute(&self, xs: &[f32], h0: &[f32], c0: &[f32], steps: usize, out: &mut LstmOutput) {
        let e = &self.entry;
        let (b, d, h) = (e.b, e.d, e.h);
        // Single-step invocations (cell artifacts, one-frame streaming
        // chunks) always run stepwise: identical bits either way, but the
        // stepwise path skips the unfolded projection-buffer bookkeeping.
        let plan = if steps == 1 {
            self.plan.with_schedule(Schedule::Stepwise)
        } else {
            self.plan
        };
        let mut scr = self.scratch.borrow_mut();
        if e.kind.starts_with("gru") {
            kernel::gru_seq_into(
                xs,
                h0,
                &[],
                &[],
                &self.bias,
                steps,
                b,
                d,
                h,
                &plan,
                self.runtime.threads,
                &mut scr,
                &mut out.hs,
                &mut out.h_t,
            );
            // GRU kinds have no cell state; c_t mirrors h_t by the
            // uniform-interface convention.
            out.c_t.clear();
            out.c_t.extend_from_slice(&out.h_t);
        } else {
            kernel::lstm_seq_into(
                xs,
                h0,
                c0,
                &[],
                &[],
                &self.bias,
                steps,
                b,
                d,
                h,
                &plan,
                self.runtime.threads,
                &mut scr,
                &mut out.hs,
                &mut out.h_t,
                &mut out.c_t,
            );
        }
    }

    /// Run only the first `steps` of a seq artifact with explicit initial
    /// state. `xs` is (steps, B, D); `h0`, `c0` are (B, H). Unlike [`run`]
    /// (which always walks the artifact's full T, so padded tail steps
    /// keep evolving the carry), this stops EXACTLY at `steps`, returning
    /// the true (h, c) there — the streaming-chunk primitive: a session's
    /// recurrent state must persist across chunks bit-exactly.
    ///
    /// [`run`]: LstmExecutable::run
    pub fn run_prefix(
        &self,
        xs: &[f32],
        steps: usize,
        h0: &[f32],
        c0: &[f32],
    ) -> Result<LstmOutput> {
        let mut out = LstmOutput::default();
        self.run_prefix_into(xs, steps, h0, c0, &mut out)?;
        Ok(out)
    }

    /// [`run_prefix`], writing into a caller-reused output — the
    /// allocation-free streaming-chunk entry point.
    ///
    /// [`run_prefix`]: LstmExecutable::run_prefix
    pub fn run_prefix_into(
        &self,
        xs: &[f32],
        steps: usize,
        h0: &[f32],
        c0: &[f32],
        out: &mut LstmOutput,
    ) -> Result<()> {
        let e = &self.entry;
        if !e.kind.ends_with("seq") {
            bail!("{}: run_prefix needs a seq artifact", e.name);
        }
        let (b, d, h) = (e.b, e.d, e.h);
        if steps == 0 || steps > e.t {
            bail!("{}: prefix of {steps} steps outside 1..={}", e.name, e.t);
        }
        if xs.len() != steps * b * d || h0.len() != b * h || c0.len() != b * h {
            bail!(
                "{}: bad prefix sizes xs={} (want {}) h0={} c0={}",
                e.name,
                xs.len(),
                steps * b * d,
                h0.len(),
                c0.len()
            );
        }
        self.execute(xs, h0, c0, steps, out);
        Ok(())
    }

    /// Advance a fused window of streaming lanes: every live session
    /// sharing this executable's weights moves one step per iteration,
    /// so each step runs ONE batched `(M, D)`/`(M, H)` GEMM pair where
    /// the solo path would run M separate single-row MVMs — the
    /// cross-session step fusion of the serving hot path. The batch
    /// must be [`FusedBatch::finish`]ed with lanes pushed longest-first;
    /// on return each lane's carry rows hold its state at its own last
    /// frame, bit-identical to running that lane's chunk alone through
    /// [`run_prefix_into`] (ragged lengths retire lanes mid-window
    /// without touching their carries again).
    ///
    /// The register tile re-scores against the window's occupancy
    /// ([`tuner::plan_batched_step`]): a 16-lane window runs a taller
    /// `mr` than this executable's B=1 solo plan, while `nr` stays
    /// pinned to the packed panel width, so no repack ever happens on
    /// the fuse path.
    ///
    /// [`run_prefix_into`]: LstmExecutable::run_prefix_into
    pub fn run_steps_batched_into(&self, batch: &mut FusedBatch) -> Result<()> {
        let e = &self.entry;
        if !e.kind.ends_with("seq") {
            bail!("{}: fused steps need a seq artifact", e.name);
        }
        let (d, h) = (e.d, e.h);
        if batch.lanes() == 0 {
            bail!("{}: fused window has no lanes", e.name);
        }
        for &len in batch.lens() {
            if len == 0 || len > e.t {
                bail!("{}: fused lane of {len} steps outside 1..={}", e.name, e.t);
            }
        }
        // These also catch a batch begun at the wrong (D, H) — the
        // per-lane push asserts sized everything against begin()'s dims.
        if batch.xs.len() != batch.total_steps() * d {
            bail!(
                "{}: fused batch xs {} != total steps {} x D {d} (finish() not called?)",
                e.name,
                batch.xs.len(),
                batch.total_steps()
            );
        }
        if batch.h.len() != batch.lanes() * h {
            bail!(
                "{}: fused batch carries {} != lanes {} x H {h}",
                e.name,
                batch.h.len(),
                batch.lanes()
            );
        }
        let dims = ModelDims::of_entry(e);
        let plan = tuner::plan_batched_step(&self.plan, &dims, batch.lanes());
        let mut scr = self.scratch.borrow_mut();
        let FusedBatch { xs, lens, h: bh, c: bc, .. } = batch;
        if e.kind.starts_with("gru") {
            kernel::gru_steps_batched_into(
                xs,
                lens,
                &[],
                &[],
                &self.bias,
                d,
                h,
                &plan,
                self.runtime.threads,
                &mut scr,
                bh,
            );
            // GRU kinds have no cell state; the carry's c mirrors h by
            // the uniform-interface convention.
            bc.copy_from_slice(bh);
        } else {
            kernel::lstm_steps_batched_into(
                xs,
                lens,
                &[],
                &[],
                &self.bias,
                d,
                h,
                &plan,
                self.runtime.threads,
                &mut scr,
                bh,
                bc,
            );
        }
        Ok(())
    }

    /// Zero initial state sized for this artifact.
    pub fn zero_state(&self) -> (Vec<f32>, Vec<f32>) {
        let n = self.entry.b * self.entry.h;
        (vec![0.0; n], vec![0.0; n])
    }

    /// Pad a (seq_len, B, D) payload out to this artifact's (T, B, D).
    pub fn pad_sequence(&self, xs: &[f32], seq_len: usize) -> Result<Vec<f32>> {
        let e = &self.entry;
        if !e.kind.ends_with("seq") {
            bail!("{} is not a seq artifact", e.name);
        }
        if seq_len > e.t {
            bail!("{}: seq_len {} exceeds bucket T={}", e.name, seq_len, e.t);
        }
        if xs.len() != seq_len * e.b * e.d {
            bail!("{}: payload len {} != {}", e.name, xs.len(), seq_len * e.b * e.d);
        }
        let mut out = xs.to_vec();
        out.resize(e.t * e.b * e.d, 0.0);
        Ok(out)
    }
}

// Integration tests against real artifacts live in rust/tests/ (they need
// `make artifacts` to have run); unit tests here cover the store-free
// paths via a synthetic on-disk manifest.
#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::literal::write_f32_file;
    use std::path::PathBuf;

    /// Build a minimal on-disk artifact set: one LSTM cell with zero
    /// golden weights, H=D=2, B=1.
    fn synth_store(tag: &str) -> (PathBuf, ArtifactStore) {
        let dir = std::env::temp_dir().join(format!("sharp_lstm_unit_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = r#"{"version":1,"gate_order":"ifgo","artifacts":[
          {"name":"cell_h2_b1","kind":"cell","hlo":"cell.hlo.txt","T":1,"B":1,"D":2,"H":2,
           "inputs":[{"name":"x","shape":[1,2],"file":"x.f32"},
                     {"name":"h0","shape":[1,2],"file":"h0.f32"},
                     {"name":"c0","shape":[1,2],"file":"c0.f32"},
                     {"name":"wx","shape":[2,8],"file":"wx.f32"},
                     {"name":"wh","shape":[2,8],"file":"wh.f32"},
                     {"name":"b","shape":[8],"file":"b.f32"}],
           "outputs":[{"name":"h","shape":[1,2],"file":"gh.f32"},
                      {"name":"c","shape":[1,2],"file":"gc.f32"}]},
          {"name":"seq_h2_t4_b1","kind":"seq","hlo":"cell.hlo.txt","T":4,"B":1,"D":2,"H":2,
           "inputs":[{"name":"wx","shape":[2,8],"file":"wx.f32"},
                     {"name":"wh","shape":[2,8],"file":"wh.f32"},
                     {"name":"b","shape":[8],"file":"b.f32"}],
           "outputs":[]}]}"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        std::fs::write(dir.join("cell.hlo.txt"), "HloModule cell_h2_b1\n").unwrap();
        write_f32_file(&dir.join("x.f32"), &[0.1, -0.2]).unwrap();
        write_f32_file(&dir.join("h0.f32"), &[0.3, 0.4]).unwrap();
        write_f32_file(&dir.join("c0.f32"), &[0.5, -0.6]).unwrap();
        write_f32_file(&dir.join("wx.f32"), &[0.0; 16]).unwrap();
        write_f32_file(&dir.join("wh.f32"), &[0.0; 16]).unwrap();
        write_f32_file(&dir.join("b.f32"), &[0.0; 8]).unwrap();
        // Goldens for zero weights: c' = 0.5*c0, h' = 0.5*tanh(0.5*c0).
        let c0 = [0.5f32, -0.6];
        let gc: Vec<f32> = c0.iter().map(|v| 0.5 * v).collect();
        let gh: Vec<f32> = gc.iter().map(|v| 0.5 * v.tanh()).collect();
        write_f32_file(&dir.join("gc.f32"), &gc).unwrap();
        write_f32_file(&dir.join("gh.f32"), &gh).unwrap();
        let store = ArtifactStore::open(&dir).unwrap();
        (dir, store)
    }

    #[test]
    fn golden_bound_cell_reproduces_closed_form() {
        let (_dir, store) = synth_store("goldens");
        let exe = LstmExecutable::from_store_goldens(&store, "cell_h2_b1").unwrap();
        assert_eq!(exe.artifact().module_name, "cell_h2_b1");
        let x = store.golden(&exe.entry.inputs[0]).unwrap();
        let h0 = store.golden(&exe.entry.inputs[1]).unwrap();
        let c0 = store.golden(&exe.entry.inputs[2]).unwrap();
        let out = exe.run(&x, &h0, &c0).unwrap();
        let gh = store.golden(&exe.entry.outputs[0]).unwrap();
        let gc = store.golden(&exe.entry.outputs[1]).unwrap();
        assert!(super::super::literal::max_abs_diff(&out.h_t, &gh) < 1e-6);
        assert!(super::super::literal::max_abs_diff(&out.c_t, &gc) < 1e-6);
    }

    #[test]
    fn wrong_sizes_rejected() {
        let (_dir, store) = synth_store("sizes");
        let exe = LstmExecutable::from_store_goldens(&store, "cell_h2_b1").unwrap();
        assert!(exe.run(&[0.0; 3], &[0.0; 2], &[0.0; 2]).is_err());
        assert!(exe.run(&[0.0; 2], &[0.0; 1], &[0.0; 2]).is_err());
        // Non-seq artifacts cannot pad sequences.
        assert!(exe.pad_sequence(&[0.0; 2], 1).is_err());
    }

    #[test]
    fn run_prefix_carries_state_exactly_across_chunks() {
        let (_dir, store) = synth_store("prefix");
        // Nonzero weights so the inputs actually drive the gates.
        let wx: Vec<f32> = (0..16).map(|i| 0.1 * ((i % 7) as f32 - 3.0)).collect();
        let wh: Vec<f32> = (0..16).map(|i| 0.05 * ((i % 5) as f32 - 2.0)).collect();
        let bias: Vec<f32> = (0..8).map(|i| 0.01 * i as f32).collect();
        let exe =
            LstmExecutable::with_weights(&store, "seq_h2_t4_b1", wx, wh, bias).unwrap();
        let xs: Vec<f32> = (0..8).map(|i| 0.2 * ((i % 3) as f32 - 1.0)).collect();
        let (h0, c0) = exe.zero_state();

        // One-shot over the full T equals run() (no padding involved).
        let full = exe.run(&xs, &h0, &c0).unwrap();
        let pre = exe.run_prefix(&xs, 4, &h0, &c0).unwrap();
        assert_eq!(pre.h_t, full.h_t);
        assert_eq!(pre.c_t, full.c_t);

        // Chunked 2+2 with the carry threaded through matches one-shot:
        // the same op sequence, just split — so bit-exact.
        let a = exe.run_prefix(&xs[..4], 2, &h0, &c0).unwrap();
        let b = exe.run_prefix(&xs[4..], 2, &a.h_t, &a.c_t).unwrap();
        assert_eq!(b.h_t, full.h_t);
        assert_eq!(b.c_t, full.c_t);

        // Bounds enforced: zero, past-T, and bad payload sizes.
        assert!(exe.run_prefix(&[], 0, &h0, &c0).is_err());
        assert!(exe.run_prefix(&xs, 5, &h0, &c0).is_err());
        assert!(exe.run_prefix(&xs[..6], 2, &h0, &c0).is_err());
    }

    #[test]
    fn fused_window_matches_per_lane_run_prefix() {
        let (_dir, store) = synth_store("fused");
        let wx: Vec<f32> = (0..16).map(|i| 0.1 * ((i % 7) as f32 - 3.0)).collect();
        let wh: Vec<f32> = (0..16).map(|i| 0.05 * ((i % 5) as f32 - 2.0)).collect();
        let bias: Vec<f32> = (0..8).map(|i| 0.01 * i as f32).collect();
        let exe =
            LstmExecutable::with_weights(&store, "seq_h2_t4_b1", wx, wh, bias).unwrap();
        let (d, h) = (exe.entry.d, exe.entry.h);

        // Three lanes with ragged lengths and distinct carries.
        let lens = [4usize, 2, 1];
        let chunks: Vec<Vec<f32>> = lens
            .iter()
            .enumerate()
            .map(|(i, &l)| (0..l * d).map(|j| 0.2 * ((i + j) % 5) as f32 - 0.3).collect())
            .collect();
        let carries: Vec<(Vec<f32>, Vec<f32>)> = (0..lens.len())
            .map(|i| {
                let f = i as f32;
                (vec![0.1 * f, -0.2 * f], vec![0.3 * f, 0.05 * f])
            })
            .collect();

        let mut batch = FusedBatch::new();
        batch.begin(d, h);
        for (i, &len) in lens.iter().enumerate() {
            batch.push_lane(&chunks[i], len, &carries[i].0, &carries[i].1);
        }
        batch.finish();
        exe.run_steps_batched_into(&mut batch).unwrap();

        for (i, &len) in lens.iter().enumerate() {
            let solo = exe
                .run_prefix(&chunks[i], len, &carries[i].0, &carries[i].1)
                .unwrap();
            assert_eq!(batch.lane_h(i), &solo.h_t[..], "lane {i} h");
            assert_eq!(batch.lane_c(i), &solo.c_t[..], "lane {i} c");
        }
    }

    #[test]
    fn fused_window_validates_shape_and_kind() {
        let (_dir, store) = synth_store("fused_val");
        let seq = LstmExecutable::with_weights(
            &store,
            "seq_h2_t4_b1",
            vec![0.0; 16],
            vec![0.0; 16],
            vec![0.0; 8],
        )
        .unwrap();
        // Empty window.
        let mut batch = FusedBatch::new();
        batch.begin(2, 2);
        assert!(seq.run_steps_batched_into(&mut batch).is_err());
        // Lane longer than the bucket T.
        batch.begin(2, 2);
        batch.push_lane(&[0.0; 10], 5, &[0.0; 2], &[0.0; 2]);
        batch.finish();
        assert!(seq.run_steps_batched_into(&mut batch).is_err());
        // finish() forgotten: xs is not the step-major gather yet.
        batch.begin(2, 2);
        batch.push_lane(&[0.0; 4], 2, &[0.0; 2], &[0.0; 2]);
        assert!(seq.run_steps_batched_into(&mut batch).is_err());
        // Cell artifacts cannot run fused streaming steps.
        let cell = LstmExecutable::from_store_goldens(&store, "cell_h2_b1").unwrap();
        batch.begin(2, 2);
        batch.push_lane(&[0.0; 2], 1, &[0.0; 2], &[0.0; 2]);
        batch.finish();
        assert!(cell.run_steps_batched_into(&mut batch).is_err());
    }

    #[test]
    fn replan_repacks_panels_and_stays_bit_identical() {
        use crate::runtime::plan::{KernelGeometry, PlanMode, Schedule};
        let (_dir, store) = synth_store("replan");
        let wx: Vec<f32> = (0..16).map(|i| 0.1 * ((i % 7) as f32 - 3.0)).collect();
        let wh: Vec<f32> = (0..16).map(|i| 0.05 * ((i % 5) as f32 - 2.0)).collect();
        let bias: Vec<f32> = (0..8).map(|i| 0.01 * i as f32).collect();
        let mut exe =
            LstmExecutable::with_weights(&store, "seq_h2_t4_b1", wx, wh, bias).unwrap();
        let xs: Vec<f32> = (0..8).map(|i| 0.2 * ((i % 3) as f32 - 1.0)).collect();
        let (h0, c0) = exe.zero_state();
        let baseline = exe.run(&xs, &h0, &c0).unwrap();

        // Re-plan onto a different geometry: the resident panels repack
        // in place (the raw weights are long gone) and every output bit
        // survives.
        let geo = KernelGeometry::new(2, 8).unwrap();
        exe.set_runtime(RuntimeConfig {
            threads: 1,
            plan: PlanMode::Fixed(geo),
            force_kernel: Some(crate::runtime::Isa::Scalar),
            ..RuntimeConfig::default()
        })
        .unwrap();
        assert_eq!(exe.plan().geometry, geo);
        assert_eq!(exe.plan().schedule, Schedule::Unfolded, "T=4 stays unfolded");
        let replanned = exe.run(&xs, &h0, &c0).unwrap();
        assert_eq!(baseline.hs, replanned.hs);
        assert_eq!(baseline.h_t, replanned.h_t);
        assert_eq!(baseline.c_t, replanned.c_t);

        // And back to Auto (the default, detected ISA), still identical.
        exe.set_runtime(RuntimeConfig::default()).unwrap();
        let auto = exe.run(&xs, &h0, &c0).unwrap();
        assert_eq!(baseline.hs, auto.hs);
    }

    #[test]
    fn int8_bind_runs_close_to_f32_and_rejects_dtype_flips() {
        use crate::runtime::plan::{KernelGeometry, PlanMode};
        let (_dir, store) = synth_store("int8_bind");
        let wx: Vec<f32> = (0..16).map(|i| 0.1 * ((i % 7) as f32 - 3.0)).collect();
        let wh: Vec<f32> = (0..16).map(|i| 0.05 * ((i % 5) as f32 - 2.0)).collect();
        let bias: Vec<f32> = (0..8).map(|i| 0.01 * i as f32).collect();
        let f32_exe = LstmExecutable::with_weights(
            &store,
            "seq_h2_t4_b1",
            wx.clone(),
            wh.clone(),
            bias.clone(),
        )
        .unwrap();
        let mut exe = LstmExecutable::with_weights_with(
            &store,
            "seq_h2_t4_b1",
            wx,
            wh,
            bias,
            RuntimeConfig {
                dtype: Dtype::Int8,
                ..RuntimeConfig::default()
            },
        )
        .unwrap();
        assert_eq!(exe.plan().geometry.dtype, Dtype::Int8);

        let xs: Vec<f32> = (0..8).map(|i| 0.2 * ((i % 3) as f32 - 1.0)).collect();
        let (h0, c0) = exe.zero_state();
        let oracle = f32_exe.run(&xs, &h0, &c0).unwrap();
        let got = exe.run(&xs, &h0, &c0).unwrap();
        for (g, o) in got.h_t.iter().zip(&oracle.h_t) {
            assert!((g - o).abs() < 0.05, "int8 h {g} vs f32 {o}");
        }

        // Re-planning within int8 repacks the resident codes and keeps
        // the exact bits (integer dots are geometry-invariant).
        exe.set_runtime(RuntimeConfig {
            plan: PlanMode::Fixed(KernelGeometry::new(2, 8).unwrap()),
            dtype: Dtype::Int8,
            ..RuntimeConfig::default()
        })
        .unwrap();
        assert_eq!(exe.plan().geometry.dtype, Dtype::Int8);
        let replanned = exe.run(&xs, &h0, &c0).unwrap();
        assert_eq!(got.hs, replanned.hs);
        assert_eq!(got.h_t, replanned.h_t);
        assert_eq!(got.c_t, replanned.c_t);

        // The raw weights are gone: a dtype flip must fail loudly, and
        // the executable must stay usable afterwards.
        let err = exe.set_runtime(RuntimeConfig::default()).unwrap_err();
        assert!(err.to_string().contains("requires rebinding"), "{err}");
        let still = exe.run(&xs, &h0, &c0).unwrap();
        assert_eq!(got.h_t, still.h_t);
    }

    #[test]
    fn binding_with_a_forced_unavailable_isa_fails_loudly() {
        use crate::runtime::Isa;
        let missing = Isa::ALL
            .into_iter()
            .find(|isa| !isa.available())
            .expect("avx2 and neon are never both available");
        let (_dir, store) = synth_store("forced_isa");
        let err = LstmExecutable::from_store_goldens_with(
            &store,
            "seq_h2_t4_b1",
            RuntimeConfig {
                force_kernel: Some(missing),
                ..Default::default()
            },
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains(missing.name()));
        // And the same force through set_runtime on a healthy
        // executable: loud error, plan unchanged.
        let mut exe = LstmExecutable::from_store_goldens(&store, "seq_h2_t4_b1").unwrap();
        let before = *exe.plan();
        assert!(exe
            .set_runtime(RuntimeConfig {
                force_kernel: Some(missing),
                ..Default::default()
            })
            .is_err());
        assert_eq!(*exe.plan(), before, "a failed re-plan must not corrupt state");
    }

    #[test]
    fn cell_artifacts_plan_stepwise() {
        let (_dir, store) = synth_store("cell_plan");
        let exe = LstmExecutable::from_store_goldens(&store, "cell_h2_b1").unwrap();
        assert_eq!(
            exe.plan().schedule,
            crate::runtime::plan::Schedule::Stepwise,
            "T=1 artifacts skip the unfolded projection buffer"
        );
    }

    #[test]
    fn run_prefix_rejects_cell_artifacts() {
        let (_dir, store) = synth_store("prefix_cell");
        let exe = LstmExecutable::from_store_goldens(&store, "cell_h2_b1").unwrap();
        assert!(exe.run_prefix(&[0.0; 2], 1, &[0.0; 2], &[0.0; 2]).is_err());
    }

    #[test]
    fn with_weights_validates_shapes() {
        let (_dir, store) = synth_store("weights");
        assert!(LstmExecutable::with_weights(
            &store,
            "cell_h2_b1",
            vec![0.0; 16],
            vec![0.0; 16],
            vec![0.0; 8]
        )
        .is_ok());
        assert!(LstmExecutable::with_weights(
            &store,
            "cell_h2_b1",
            vec![0.0; 15],
            vec![0.0; 16],
            vec![0.0; 8]
        )
        .is_err());
    }
}
