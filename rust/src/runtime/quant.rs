//! Int8 weight quantization for the runtime (DESIGN.md §12): the
//! software analogue of the low-precision MAC datapath the paper's
//! energy numbers assume, and the RNNAccel-style 8-bit weight
//! compression PAPERS.md motivates (~4x weight bandwidth).
//!
//! **Scheme.** Weights quantize per gate, symmetric, no zero point:
//! gate `g`'s scale is `max|w| / 127` over the gate's `H` output
//! columns, and `q = round(w / s)` clamped to `[-127, 127]`. Per-gate
//! granularity matches how the runtime consumes the gate matrix — the
//! cell update slices `pre` by gate, and gates have very different
//! dynamic ranges (forget-gate biases push sigmoid inputs far from
//! candidate-gate tanh inputs) — while staying coarse enough that the
//! scale vector (`G` distinct values broadcast over `G*H` columns) costs
//! nothing against the 4x weight shrink. The machinery below is
//! per-*column* (`scales.len() == n`), so finer granularities are a
//! quantizer change, not a kernel change.
//!
//! **Activations** quantize dynamically per row (`max|row| / 127`),
//! computed on the fly each GEMM call — activations are transient, so
//! there is nothing to precompute at load, and per-row symmetric keeps
//! the dequant a rank-1 scale: `out[i][j] += dot_i32 * sa[i] * ws[j]`,
//! which is what lets [`crate::runtime::kernel::gemm::matmul_quant`]
//! fuse dequant into the register-tile epilogue.
//!
//! **Exactness within the path.** `round` is `f32::round` (half away
//! from zero) everywhere, a zero scale short-circuits to `q = 0` (and
//! dequant-by-0.0 stays exactly 0.0), and the i32 dots are exact, so
//! the whole int8 path is bit-identical across ISAs, geometries, and
//! thread counts — the tolerance budget in `tests/quant_conformance.rs`
//! is spent once, against the f32 oracle, not per dispatch variant.

use crate::runtime::kernel::gemm;

/// One weight matrix quantized to int8 packed panels plus its
/// per-column dequant scales. Produced once at bind
/// ([`quantize_weights`]); the dense f32 weights are dropped after, so
/// like the f32 packed panels this is the only resident copy — a
/// re-plan that changes the panel width re-derives the panels from
/// themselves ([`QuantWeights::repack`]); the scales never change.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct QuantWeights {
    /// Int8 panels packed by [`gemm::pack_panels`] at width `nr`.
    pub(crate) panels: Vec<i8>,
    /// Per-output-column dequant scale (gate scales broadcast to their
    /// columns), length `n`.
    pub(crate) scales: Vec<f32>,
    /// Contraction depth (weight rows).
    pub(crate) k: usize,
    /// Output width (weight columns, `G*H`).
    pub(crate) n: usize,
    /// Panel width the panels are currently packed at.
    pub(crate) nr: usize,
}

impl QuantWeights {
    /// Re-pack the resident panels at a new width (a re-plan changed
    /// `nr` after the dense weights were dropped). Scales are
    /// per-column and layout-independent, so only the panels move.
    pub fn repack(&mut self, nr: usize) {
        if nr == self.nr {
            return;
        }
        let mut dense = Vec::new();
        gemm::unpack_panels(&self.panels, self.k, self.n, self.nr, &mut dense);
        gemm::pack_panels(&dense, self.k, self.n, nr, &mut self.panels);
        self.nr = nr;
    }

    /// The packed int8 panels (for the GEMM call).
    pub fn panels(&self) -> &[i8] {
        &self.panels
    }

    /// The per-column dequant scales (for the GEMM call).
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }
}

/// Quantize one row-major weight matrix `w (k, n)` — `n = gates * h`
/// output columns — to int8 panels packed at `nr`, with one symmetric
/// scale per gate broadcast to the gate's columns.
///
/// A gate whose weights are all zero gets scale 0.0 and all-zero codes:
/// `0i32 as f32 * 0.0 == 0.0` exactly, so zero weights stay exact
/// through the quant path (the synthetic-manifest goldens rely on it).
pub fn quantize_weights(w: &[f32], k: usize, n: usize, gates: usize, nr: usize) -> QuantWeights {
    debug_assert_eq!(w.len(), k * n);
    debug_assert!(gates > 0 && n % gates == 0, "n = {n} must split into {gates} gates");
    let h = n / gates;
    let mut scales = vec![0.0f32; n];
    let mut q = vec![0i8; k * n];
    for g in 0..gates {
        let cols = g * h..(g + 1) * h;
        let mut amax = 0.0f32;
        for row in 0..k {
            for c in cols.clone() {
                amax = amax.max(w[row * n + c].abs());
            }
        }
        let s = amax / 127.0;
        if s > 0.0 {
            let inv = 1.0 / s;
            for row in 0..k {
                for c in cols.clone() {
                    let r = (w[row * n + c] * inv).round().clamp(-127.0, 127.0);
                    q[row * n + c] = r as i8;
                }
            }
        }
        for c in cols {
            scales[c] = s;
        }
    }
    let mut panels = Vec::new();
    gemm::pack_panels(&q, k, n, nr, &mut panels);
    QuantWeights {
        panels,
        scales,
        k,
        n,
        nr,
    }
}

/// Quantize activation rows `a (m, k)` symmetrically per row into
/// `qa`/`sa` (resized in place; the caller keeps them as reusable
/// scratch). Row `i`'s scale is `max|a[i, :]| / 127`; an all-zero row
/// gets scale 0.0 and zero codes, exact by the same argument as a zero
/// gate.
pub fn quantize_rows(a: &[f32], m: usize, k: usize, qa: &mut Vec<i8>, sa: &mut Vec<f32>) {
    debug_assert_eq!(a.len(), m * k);
    qa.clear();
    qa.resize(m * k, 0);
    sa.clear();
    sa.resize(m, 0.0);
    for i in 0..m {
        let row = &a[i * k..(i + 1) * k];
        let mut amax = 0.0f32;
        for v in row {
            amax = amax.max(v.abs());
        }
        let s = amax / 127.0;
        sa[i] = s;
        if s > 0.0 {
            let inv = 1.0 / s;
            for (o, v) in qa[i * k..(i + 1) * k].iter_mut().zip(row) {
                *o = (v * inv).round().clamp(-127.0, 127.0) as i8;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn per_gate_scales_broadcast_and_bound_the_roundtrip_error() {
        let (k, gates, h) = (13, 4, 5);
        let n = gates * h;
        let mut rng = Rng::new(0x5CA1E);
        // Give each gate a distinct dynamic range.
        let mut w = vec![0.0f32; k * n];
        for (idx, v) in w.iter_mut().enumerate() {
            let g = (idx % n) / h;
            let span = [0.1f32, 1.0, 3.0, 0.02][g];
            *v = rng.uniform_f32(-span, span);
        }
        let qw = quantize_weights(&w, k, n, gates, 8);
        assert_eq!(qw.scales.len(), n);
        for g in 0..gates {
            let cols = g * h..(g + 1) * h;
            let mut amax = 0.0f32;
            for row in 0..k {
                for c in cols.clone() {
                    amax = amax.max(w[row * n + c].abs());
                }
            }
            for c in cols {
                assert_eq!(qw.scales[c], amax / 127.0, "gate {g} col {c}");
            }
        }
        // Dequantized weights land within half a step of the original.
        let mut dense = Vec::new();
        gemm::unpack_panels(&qw.panels, k, n, qw.nr, &mut dense);
        for row in 0..k {
            for c in 0..n {
                let deq = dense[row * n + c] as f32 * qw.scales[c];
                let err = (deq - w[row * n + c]).abs();
                assert!(
                    err <= qw.scales[c] * 0.5 + 1e-7,
                    "({row},{c}): {deq} vs {} (scale {})",
                    w[row * n + c],
                    qw.scales[c]
                );
            }
        }
    }

    #[test]
    fn zero_weights_quantize_exactly_to_zero() {
        let qw = quantize_weights(&vec![0.0f32; 6 * 8], 6, 8, 4, 4);
        assert!(qw.panels.iter().all(|&q| q == 0));
        assert!(qw.scales.iter().all(|&s| s == 0.0));
        // And a mixed matrix where only one gate is zero.
        let (k, gates, h) = (3, 2, 2);
        let n = gates * h;
        let mut w = vec![0.0f32; k * n];
        for row in 0..k {
            w[row * n + 2] = 1.0; // gate 1 only
            w[row * n + 3] = -0.5;
        }
        let qw = quantize_weights(&w, k, n, gates, 4);
        assert_eq!(&qw.scales[..2], &[0.0, 0.0]);
        assert!(qw.scales[2] > 0.0);
    }

    #[test]
    fn saturated_weights_hit_exactly_127() {
        // The max-|w| element must code to ±127, never wrap to -128.
        let w = [3.0f32, -3.0, 1.5, 0.0];
        let qw = quantize_weights(&w, 1, 4, 1, 4);
        let mut dense = Vec::new();
        gemm::unpack_panels(&qw.panels, 1, 4, qw.nr, &mut dense);
        assert_eq!(dense, vec![127, -127, 64, 0]);
    }

    #[test]
    fn row_quantization_is_per_row_and_zero_safe() {
        let a = [0.5f32, -1.0, 0.25, 0.0, 0.0, 0.0, 2.0, 2.0, -2.0];
        let (mut qa, mut sa) = (Vec::new(), Vec::new());
        quantize_rows(&a, 3, 3, &mut qa, &mut sa);
        assert_eq!(sa.len(), 3);
        assert_eq!(sa[0], 1.0 / 127.0);
        assert_eq!(sa[1], 0.0);
        assert_eq!(sa[2], 2.0 / 127.0);
        assert_eq!(&qa[3..6], &[0, 0, 0], "zero row stays zero");
        assert_eq!(&qa[6..9], &[127, 127, -127]);
    }

    #[test]
    fn repack_preserves_the_dense_weights_across_widths() {
        let (k, gates, h) = (7, 3, 11);
        let n = gates * h;
        let mut rng = Rng::new(42);
        let w = rng.vec_f32(k * n, -0.8, 0.8);
        let mut qw = quantize_weights(&w, k, n, gates, 16);
        let mut want = Vec::new();
        gemm::unpack_panels(&qw.panels, k, n, qw.nr, &mut want);
        let scales = qw.scales.clone();
        for nr in [4, 32, 1, 8, 16] {
            qw.repack(nr);
            assert_eq!(qw.nr, nr);
            let mut dense = Vec::new();
            gemm::unpack_panels(&qw.panels, k, n, qw.nr, &mut dense);
            assert_eq!(dense, want, "nr={nr}");
            assert_eq!(qw.scales, scales, "scales are layout-independent");
        }
    }
}
