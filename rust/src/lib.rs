//! # sharp — an adaptable, energy-efficient RNN accelerator, reproduced
//!
//! Reproduction of *"SHARP: An Adaptable, Energy-Efficient Accelerator for
//! Recurrent Neural Network"* (Yazdani et al.) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the cycle-level SHARP simulator with the four
//!   dispatch schedules and the reconfigurable MVM tile engine, the
//!   energy/power/area models, the E-PUR / BrainWave / GPU baseline
//!   models, the experiment harness regenerating every paper table and
//!   figure (the `sharp` CLI: `sharp list` / `sharp figure <id>` /
//!   `sharp all --json <dir>`), and a serving coordinator that runs
//!   functional LSTM inference on AOT-compiled artifacts through the
//!   built-in dense executor (`runtime::exec`).
//! * **L2/L1 (python/, build-time only)** — the JAX LSTM decomposed the
//!   way the *Unfolded* schedule decomposes it, with Pallas kernels for
//!   the Compute-Unit tile MVM and the Cell-Updater stage, AOT-lowered to
//!   HLO text that `runtime` loads; python never runs at serve time.
//!
//! See `DESIGN.md` for the system inventory and the per-exhibit index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod error;
pub mod experiments;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod tile;
pub mod util;
pub mod workloads;
