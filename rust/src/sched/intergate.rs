//! Intergate scheduling (Fig. 8.c) — the schedule of E-PUR: all four
//! gates' MVMs issue together sharing the MAC array in output-based tiling,
//! so the cell/hidden update streams alongside the MVM and only the last
//! quarter of its drain stays exposed ("decrease the latency for the cell
//! and hidden update by four times").
//!
//! Like every Fig. 8 schedule this prices one layer's step in
//! isolation; on stacked models the runtime additionally overlaps
//! whole layers against each other (the inter-layer step pipeline,
//! `runtime::kernel::stack`), and `sim::pipeline::stack_pipeline_estimate`
//! predicts that stack-level speedup on top of the per-step schedule
//! modeled here.

use super::{Schedule, ScheduleKind, StepInputs};

pub struct Intergate;

impl Schedule for Intergate {
    fn kind(&self) -> ScheduleKind {
        ScheduleKind::Intergate
    }

    /// Intra-sequence dependency hidden: the Cell Updater consumed gate
    /// groups as they completed, so after the MVM ends only ~1/4 of the
    /// drain (the trailing gate groups) plus fills remain. Activation of
    /// intermediate groups streams under the MVM like Batch's, so only
    /// half the A-MFU fill stays exposed.
    fn tail(&self, s: &StepInputs) -> u64 {
        s.red_fill + s.act_fill.div_ceil(2) + s.cu_drain.div_ceil(4) + s.cu_fill
    }
}

#[cfg(test)]
mod tests {
    use super::super::batch::Batch;
    use super::super::tests::toy_inputs;
    use super::*;

    #[test]
    fn quarter_drain_exposed() {
        let s = toy_inputs(10, 10, 40);
        assert_eq!(Intergate.tail(&s), 5 + 8 + 10 + 6);
    }

    #[test]
    fn beats_batch_when_update_bound() {
        // Small model, large MAC array: the update drain dominates and
        // intergate's 4x reduction shows (the Fig. 11 small-dim regime).
        let s = toy_inputs(4, 4, 256);
        let ig = Intergate.step(&s).cycles;
        let ba = Batch.step(&s).cycles;
        assert!((ba as f64) / (ig as f64) > 1.5, "ig={ig} ba={ba}");
    }
}
