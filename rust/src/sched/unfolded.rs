//! Unfolded scheduling (Fig. 8.d) — SHARP's contribution.
//!
//! Keeps Intergate's output-based tiling (intra-sequence dependency hidden)
//! and additionally *unfolds* the input/hidden MVMs of each step: while the
//! serial cell/hidden tail of step *t* drains, the MAC array computes the
//! input MVM of step *t+1* (which depends only on x_{t+1}); its result
//! waits in the intermediate buffer. Per steady-state step the critical
//! path is `mh + max(mx, tail)` instead of `mx + mh + tail`.

use super::{intergate::Intergate, Schedule, ScheduleKind, StepInputs, StepTiming};

pub struct Unfolded;

impl Schedule for Unfolded {
    fn kind(&self) -> ScheduleKind {
        ScheduleKind::Unfolded
    }

    /// The intra-sequence tail is the same as Intergate's; what changes is
    /// that `step` overlaps it with the next step's input MVM.
    fn tail(&self, s: &StepInputs) -> u64 {
        Intergate.tail(s)
    }

    fn step(&self, s: &StepInputs) -> StepTiming {
        let tail = self.tail(s);
        let overlap_window = s.mx.cycles.max(tail);
        StepTiming {
            cycles: s.mh.cycles + overlap_window,
            mac_busy: s.mh.cycles + s.mx.cycles,
            exposed_tail: tail.saturating_sub(s.mx.cycles),
        }
    }

    /// The first step's input MVM cannot hide behind a previous tail, and
    /// the pipeline must fill once.
    fn sequence_overhead(&self, s: &StepInputs) -> u64 {
        s.red_fill + s.mx.cycles
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::toy_inputs;
    use super::*;

    #[test]
    fn tail_fully_hidden_when_input_mvm_long() {
        // MVM-bound regime (large model / few MACs): tail vanishes into
        // the input MVM and the step cost is just the MVM stream.
        let s = toy_inputs(500, 500, 40);
        let t = Unfolded.step(&s);
        assert_eq!(t.cycles, 500 + 500);
        assert_eq!(t.exposed_tail, 0);
    }

    #[test]
    fn tail_partially_exposed_when_macs_abundant() {
        // Tiny MVMs, long drain: only the overhang beyond mx is exposed.
        let s = toy_inputs(4, 4, 256);
        let t = Unfolded.step(&s);
        let tail = Unfolded.tail(&s);
        assert_eq!(t.cycles, 4 + tail);
        assert_eq!(t.exposed_tail, tail - 4);
    }

    #[test]
    fn never_worse_than_intergate() {
        use super::super::intergate::Intergate;
        for mx in [1u64, 10, 100, 1000] {
            for cu in [4u64, 40, 400] {
                let s = toy_inputs(mx, mx / 2 + 1, cu);
                assert!(Unfolded.step(&s).cycles <= Intergate.step(&s).cycles);
            }
        }
    }

    #[test]
    fn sequence_overhead_charges_first_input_mvm() {
        let s = toy_inputs(123, 50, 10);
        assert_eq!(Unfolded.sequence_overhead(&s), 5 + 123);
    }
}
