//! Sequential scheduling (Fig. 8.a) — the baseline order used by
//! BrainWave/TPU-style pipelines: gates computed one after another, the
//! cell/hidden update strictly after the Output gate.
//!
//! This is the nothing-overlaps baseline WITHIN one layer's step; its
//! cross-layer analog is the runtime's sequential stacked driver
//! (`runtime::kernel::stack::stack_seq_into`, one full-sequence layer
//! at a time — the oracle the inter-layer step pipeline is bit-checked
//! against). Neither claims the model has a single layer: depth is the
//! stack driver's (and `sim::engine`'s layer fold's) job, while this
//! module prices one recurrent step.

use super::{Schedule, ScheduleKind, StepInputs};

pub struct Sequential;

impl Schedule for Sequential {
    fn kind(&self) -> ScheduleKind {
        ScheduleKind::Sequential
    }

    /// The whole serial chain is exposed: reduce fill of the last gate,
    /// its activation, then the full cell-update drain over all H cells.
    fn tail(&self, s: &StepInputs) -> u64 {
        s.red_fill + s.act_fill + s.cu_drain + s.cu_fill
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::toy_inputs;
    use super::*;

    #[test]
    fn tail_is_full_serial_chain() {
        let s = toy_inputs(100, 100, 40);
        assert_eq!(Sequential.tail(&s), 5 + 15 + 40 + 6);
        let t = Sequential.step(&s);
        assert_eq!(t.cycles, 200 + 66);
        assert_eq!(t.mac_busy, 200);
        assert_eq!(t.exposed_tail, 66);
    }
}
