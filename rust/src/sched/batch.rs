//! Batch scheduling (Fig. 8.b) — Sequential's variant that dispatches a
//! row-batch of each gate at a time, letting the accumulate/activate of
//! intermediate gates pipeline under the MVM stream. The cell-update drain
//! and the across-sequence dependency remain serial, which is why the paper
//! measures it "almost similar" to Sequential.

use super::{Schedule, ScheduleKind, StepInputs};

pub struct Batch;

impl Schedule for Batch {
    fn kind(&self) -> ScheduleKind {
        ScheduleKind::Batch
    }

    /// Batching hides the activation fill of the intermediate gates (the
    /// A-MFU works on earlier batches while later ones accumulate); only
    /// the final batch's activation plus the full cell-update drain stay
    /// on the critical path.
    fn tail(&self, s: &StepInputs) -> u64 {
        let act_exposed = s.act_fill.div_ceil(2);
        s.red_fill + act_exposed + s.cu_drain + s.cu_fill
    }
}

#[cfg(test)]
mod tests {
    use super::super::sequential::Sequential;
    use super::super::tests::toy_inputs;
    use super::*;

    #[test]
    fn nearly_sequential() {
        // Paper Fig. 11: Batch ~ Sequential (within a few percent).
        let s = toy_inputs(500, 500, 60);
        let b = Batch.step(&s).cycles as f64;
        let q = Sequential.step(&s).cycles as f64;
        assert!(b <= q);
        assert!(b / q > 0.97, "batch should be within a few % of sequential");
    }

    #[test]
    fn tail_shaves_half_the_activation_fill() {
        let s = toy_inputs(10, 10, 40);
        assert_eq!(Batch.tail(&s), 5 + 8 + 40 + 6);
    }
}
