//! The four LSTM dispatch schedules of paper §5 / Fig. 8.
//!
//! All schedules issue the same MVM work (the gates' input + hidden
//! matrix-vector products); they differ in *what overlaps what*:
//!
//! * `Sequential` (Fig. 8.a) — one gate after another; the cell/hidden
//!   update waits for the entire Output gate, and nothing of the next time
//!   step starts until the hidden vector is written back.
//! * `Batch` (Fig. 8.b) — rotates row-batches of the gates, pipelining the
//!   accumulate/activate of intermediate gates under the MVM stream, but
//!   the cell-update drain and the across-sequence dependency remain
//!   exposed ("Batch and Sequential show almost similar execution").
//! * `Intergate` (Fig. 8.c, E-PUR's schedule) — all four gates issue
//!   together in output-based tiling, so the cell/hidden update streams
//!   alongside and only ~1/4 of its drain remains exposed.
//! * `Unfolded` (Fig. 8.d, SHARP's contribution) — additionally hides the
//!   remaining serial tail of step *t* behind the *input* MVM of step
//!   *t+1*, which has no recurrent dependency.
//!
//! The schedule consumes tile-level MVM costs (`tile::geometry`) and the
//! pipeline fill/drain parameters (`sim::pipeline`) and yields per-step
//! critical-path cycles; `sim::engine` folds these over layers/directions/
//! sequence and accounts utilization + stage activity.
//!
//! These four schedules model overlap WITHIN one layer's recurrent
//! step. Since the stacked-model PR the same hide-the-dependency idea
//! also runs ACROSS layers: multi-layer models overlap layer l+1's
//! step t with layer l's step t+1 in the runtime's inter-layer step
//! pipeline (`runtime::kernel::stack`), whose fill/drain arithmetic
//! lives in `sim::pipeline::stack_pipeline_estimate`. The two compose —
//! each pipelined layer worker still dispatches under one of these
//! per-step schedules.

pub mod batch;
pub mod intergate;
pub mod sequential;
pub mod unfolded;

use crate::tile::MvmCost;

/// Identifies one of the four schedules.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ScheduleKind {
    Sequential,
    Batch,
    Intergate,
    Unfolded,
}

impl ScheduleKind {
    pub const ALL: [ScheduleKind; 4] = [
        ScheduleKind::Sequential,
        ScheduleKind::Batch,
        ScheduleKind::Intergate,
        ScheduleKind::Unfolded,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ScheduleKind::Sequential => "Sequential",
            ScheduleKind::Batch => "Batch",
            ScheduleKind::Intergate => "Intergate",
            ScheduleKind::Unfolded => "Unfolded",
        }
    }

    pub fn schedule(&self) -> &'static dyn Schedule {
        match self {
            ScheduleKind::Sequential => &sequential::Sequential,
            ScheduleKind::Batch => &batch::Batch,
            ScheduleKind::Intergate => &intergate::Intergate,
            ScheduleKind::Unfolded => &unfolded::Unfolded,
        }
    }
}

/// Everything a schedule needs to time one LSTM step.
#[derive(Debug, Clone, Copy)]
pub struct StepInputs {
    /// Tile sweep of the input-part gate matrix (4H x D).
    pub mx: MvmCost,
    /// Tile sweep of the hidden-part gate matrix (4H x H).
    pub mh: MvmCost,
    /// R-Add-Reduce tree fill latency, log2 of column-wise units.
    pub red_fill: u64,
    /// A-MFU pipeline depth (the 29.14 ns tanh chain, staged at 1 cycle).
    pub act_fill: u64,
    /// Cell-Updater drain: ceil(4H / K) cycles at K/4 elements per cycle.
    pub cu_drain: u64,
    /// Cell-Updater pipeline depth.
    pub cu_fill: u64,
}

impl StepInputs {
    /// Total MVM issue cycles of one step.
    pub fn mvm_cycles(&self) -> u64 {
        self.mx.cycles + self.mh.cycles
    }
}

/// Per-step timing split, used for stage-activity accounting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepTiming {
    /// Critical-path cycles this step adds in steady state.
    pub cycles: u64,
    /// Cycles during which the MAC array is issuing tiles.
    pub mac_busy: u64,
    /// Serial-tail cycles NOT overlapped with any MVM issue.
    pub exposed_tail: u64,
}

/// One LSTM dispatch schedule.
pub trait Schedule: Sync {
    fn kind(&self) -> ScheduleKind;

    /// Serial tail exposed after the step's MVMs, before the next step's
    /// *recurrent* work may begin.
    fn tail(&self, s: &StepInputs) -> u64;

    /// Steady-state timing of one step. The default charges
    /// `MVM + tail` serially; `Unfolded` overrides to overlap the tail
    /// with the next step's input MVM.
    fn step(&self, s: &StepInputs) -> StepTiming {
        let tail = self.tail(s);
        StepTiming {
            cycles: s.mvm_cycles() + tail,
            mac_busy: s.mvm_cycles(),
            exposed_tail: tail,
        }
    }

    /// Extra cycles charged once per sequence (pipeline fill, first-step
    /// effects). Default: reduce-tree fill once.
    fn sequence_overhead(&self, s: &StepInputs) -> u64 {
        s.red_fill
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tile::MvmCost;

    pub(crate) fn toy_inputs(mx_cycles: u64, mh_cycles: u64, cu: u64) -> StepInputs {
        let mk = |c: u64| MvmCost {
            cycles: c,
            useful_lane_cycles: c * 100,
            padded_lane_cycles: 0,
            row_segments: 4,
        };
        StepInputs {
            mx: mk(mx_cycles),
            mh: mk(mh_cycles),
            red_fill: 5,
            act_fill: 15,
            cu_drain: cu,
            cu_fill: 6,
        }
    }

    #[test]
    fn schedule_ordering_invariant() {
        // Unfolded <= Intergate <= Batch <= Sequential on every input.
        for mx in [4u64, 64, 512, 4096] {
            for cu in [8u64, 32, 128] {
                let s = toy_inputs(mx, mx, cu);
                let cyc = |k: ScheduleKind| k.schedule().step(&s).cycles;
                let (sq, ba, ig, un) = (
                    cyc(ScheduleKind::Sequential),
                    cyc(ScheduleKind::Batch),
                    cyc(ScheduleKind::Intergate),
                    cyc(ScheduleKind::Unfolded),
                );
                assert!(un <= ig, "unfolded {un} > intergate {ig} (mx={mx} cu={cu})");
                assert!(ig <= ba, "intergate {ig} > batch {ba} (mx={mx} cu={cu})");
                assert!(ba <= sq, "batch {ba} > sequential {sq} (mx={mx} cu={cu})");
            }
        }
    }

    #[test]
    fn names_unique() {
        let names: Vec<_> = ScheduleKind::ALL.iter().map(|k| k.name()).collect();
        let mut dedup = names.clone();
        dedup.dedup();
        assert_eq!(names.len(), dedup.len());
    }
}
