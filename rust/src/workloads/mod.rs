//! Workloads: the paper's benchmark networks live in `config::presets`;
//! this module adds the *serving* side — synthetic request traces with
//! paper-like arrival processes and sequence-length distributions for the
//! coordinator examples (the paper's online-inference scenario: "queries
//! come in one-by-one and have stringent latency SLA").

pub mod traces;

pub use traces::{Request, TraceConfig, TraceKind};
