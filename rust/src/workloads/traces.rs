//! Synthetic request traces for the serving coordinator.
//!
//! Substitution note (DESIGN.md §3): real production traces are not
//! available; these generators produce the same *statistical shape* the
//! paper's online-inference scenario describes — one-by-one arrivals
//! under a latency SLA, with sequence lengths drawn from the benchmark's
//! range (e.g. EESEN's 300-700 frames scaled to the artifact's bucket).

use crate::util::rng::Rng;

/// Arrival process shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    /// Poisson arrivals at a fixed mean rate (steady online service).
    Poisson,
    /// Bursts of back-to-back arrivals separated by idle gaps.
    Bursty,
    /// All requests available at t=0 (offline/batch scenario).
    Closed,
}

/// Trace generator parameters.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub kind: TraceKind,
    /// Number of requests to generate.
    pub n_requests: usize,
    /// Mean arrival rate, requests/second (ignored for Closed).
    pub rate_rps: f64,
    /// Candidate sequence lengths (must match available artifact buckets).
    pub seq_lens: Vec<u64>,
    /// Input feature dimension of generated payloads.
    pub input_dim: u64,
    /// RNG seed (traces are reproducible).
    pub seed: u64,
}

/// One inference request: arrival time plus the input sequence payload.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Arrival time in seconds from trace start.
    pub arrival_s: f64,
    /// Sequence length in time steps.
    pub seq_len: u64,
    /// Flattened input sequence, row-major (seq_len x input_dim).
    pub payload: Vec<f32>,
}

impl TraceConfig {
    /// Generate the full trace (sorted by arrival time).
    pub fn generate(&self) -> Vec<Request> {
        assert!(!self.seq_lens.is_empty(), "need at least one seq-len bucket");
        let mut rng = Rng::new(self.seed);
        let mut t = 0.0f64;
        let mut out = Vec::with_capacity(self.n_requests);
        let mut burst_left = 0usize;
        for id in 0..self.n_requests as u64 {
            match self.kind {
                TraceKind::Poisson => t += rng.exp(1.0 / self.rate_rps),
                TraceKind::Bursty => {
                    if burst_left == 0 {
                        burst_left = rng.range_usize(4, 12);
                        t += rng.exp(f64::from(burst_left as u32) / self.rate_rps);
                    }
                    burst_left -= 1;
                }
                TraceKind::Closed => {}
            }
            let seq_len = *rng.choose(&self.seq_lens);
            let payload = rng.vec_f32((seq_len * self.input_dim) as usize, -1.0, 1.0);
            out.push(Request {
                id,
                arrival_s: t,
                seq_len,
                payload,
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(kind: TraceKind) -> TraceConfig {
        TraceConfig {
            kind,
            n_requests: 200,
            rate_rps: 100.0,
            seq_lens: vec![8, 16],
            input_dim: 4,
            seed: 1234,
        }
    }

    #[test]
    fn poisson_rate_approximately_matches() {
        let trace = cfg(TraceKind::Poisson).generate();
        let span = trace.last().unwrap().arrival_s;
        let rate = trace.len() as f64 / span;
        assert!((rate - 100.0).abs() / 100.0 < 0.35, "rate {rate}");
    }

    #[test]
    fn arrivals_monotone_and_payload_sized() {
        for kind in [TraceKind::Poisson, TraceKind::Bursty, TraceKind::Closed] {
            let trace = cfg(kind).generate();
            assert_eq!(trace.len(), 200);
            let mut prev = 0.0;
            for r in &trace {
                assert!(r.arrival_s >= prev);
                prev = r.arrival_s;
                assert_eq!(r.payload.len() as u64, r.seq_len * 4);
                assert!([8, 16].contains(&r.seq_len));
            }
        }
    }

    #[test]
    fn closed_trace_all_at_zero() {
        let trace = cfg(TraceKind::Closed).generate();
        assert!(trace.iter().all(|r| r.arrival_s == 0.0));
    }

    #[test]
    fn deterministic_for_seed() {
        let a = cfg(TraceKind::Bursty).generate();
        let b = cfg(TraceKind::Bursty).generate();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.payload, y.payload);
        }
    }
}
