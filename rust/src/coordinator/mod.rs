//! The serving coordinator (L3): the paper's online-inference scenario —
//! "queries come in one-by-one and have stringent latency SLA, often in
//! single milliseconds" — realized as a request router + dynamic batcher +
//! session manager over the compiled artifacts, with the cycle simulator
//! attached so every response also carries the accelerator-time estimate
//! SHARP would deliver.
//!
//! Threads + channels (std), no async runtime: one ingress queue, one
//! worker per model variant, bounded FIFOs for backpressure.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod server;
pub mod session;

pub use batcher::{Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use request::{InferenceRequest, InferenceResponse};
pub use server::{Server, ServerConfig};
pub use session::SessionStore;
