//! The serving coordinator (L3): the paper's online-inference scenario —
//! "queries come in one-by-one and have stringent latency SLA, often in
//! single milliseconds" — realized as a dispatcher + worker-pool over the
//! compiled artifacts, with the cycle simulator attached so every
//! response also carries the accelerator-time estimate SHARP would
//! deliver.
//!
//! Threads + channels (std), no async runtime: one dispatcher thread
//! routes requests (session affinity for streaming, round-robin over
//! non-full queues otherwise) across N worker replicas; each worker owns
//! its thread-confined artifact store, per-bucket dynamic batchers tuned
//! by an adaptive controller (`adaptive`, the serving analogue of the
//! paper's §6.2 reconfiguration controller), LRU-bounded session states,
//! and lock-free metrics. Streaming chunks flow through the worker's
//! step-fusion dispatcher: concurrent sessions' chunks batch into one
//! step-major fused kernel run per window (bit-identical to solo
//! execution, DESIGN.md §9), so N live ASR streams share each step's
//! recurrent GEMM instead of paying N memory-bound MVMs. Bounded worker
//! queues give backpressure, never drops. See DESIGN.md §7/§9 for the
//! full architecture.
//!
//! The pool is fault-tolerant (DESIGN.md §11): worker serve loops run
//! under `catch_unwind`, a supervisor watches liveness + heartbeats,
//! dead replicas are respawned with their queues salvaged and session
//! carries restored, and every client wait is bounded — outcomes are
//! typed (`SharpError`), never hangs. Deterministic fault injection
//! (`faults`, `SHARP_FAULTS`) drives the chaos suite.

// The serving layer must never take the process down on a recoverable
// error: unwrap/expect are banned module-wide. The only allowed panics
// are provably-infallible sites, each carrying a scoped
// `#[allow]` + justification:
//   - locks on lock-free metrics don't exist (no Mutex in this tree);
//   - `worker_loop`'s own panics are the *supervised* surface — they
//     are caught by `catch_unwind` and become obituaries, not aborts.
// Tests keep their unwraps via clippy.toml's allow-unwrap-in-tests.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod adaptive;
pub mod batcher;
pub mod faults;
pub mod metrics;
pub mod net;
pub mod request;
pub mod routing;
pub mod server;
pub mod session;
pub mod worker;

pub use adaptive::{AdaptiveConfig, AdaptiveController};
pub use batcher::{Batcher, BatcherConfig};
pub use faults::{FaultKind, FaultPlan, FaultSpec, NetFaultKind, NetFaultSpec};
pub use metrics::Metrics;
pub use net::{Listener, NetClient, NetConfig};
pub use request::{InferenceRequest, InferenceResponse};
pub use server::{OverloadPolicy, Server, ServerConfig};
pub use session::{LaneTable, SessionState, SessionStore};

pub use crate::error::SharpError;
