//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] names exact points in the request stream where a
//! worker should misbehave — panic or stall — so every failure mode the
//! supervisor claims to handle is reproducible in a plain `cargo test`
//! run, with no scheduler luck involved. The grammar (accepted by
//! `--faults` and the `SHARP_FAULTS` env var) is a comma-joined list of:
//!
//! ```text
//! panic@worker<W>:req<N>          panic while handling worker W's N-th request
//! stall@worker<W>:<D>ms:req<N>    sleep D ms before handling worker W's N-th request
//! disconnect@conn<C>:frame<F>     sever connection C before its F-th frame
//! stall@conn<C>:<D>ms[:frame<F>]  delay connection C's F-th frame (every frame if omitted)
//! garble@conn<C>:frame<F>         corrupt connection C's F-th frame before decode
//! ```
//!
//! Worker ordinals are 1-based and count only `WorkerMsg::Request`
//! dequeues on that worker (session control traffic doesn't advance
//! them), so a plan fires at the same spot regardless of how
//! Begin/End/Snapshot messages interleave. Faults are armed only on a
//! worker's **first incarnation** (generation 0): a respawned replica
//! starts with a clean slate, which is exactly what lets the chaos suite
//! assert "the respawned worker serves traffic" without the plan
//! re-killing it at the same ordinal.
//!
//! Connection faults mirror the same determinism one layer up: `conn<C>`
//! is the listener's 1-based accept ordinal, `frame<F>` the 1-based
//! count of frames read on that connection, and the faults fire in the
//! framing layer (`net::conn`) — before decode for `garble`, before
//! delivery for `stall` and `disconnect` — so the whole failure matrix
//! (client dies / worker dies / link stalls) replays identically run to
//! run. A client that reconnects gets a NEW accept ordinal, so a
//! disconnect fault cannot re-kill the resumed connection.

use crate::error::{Context, Result};
use std::time::Duration;

/// What the injected fault does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// `panic!` inside the worker's serve loop (caught by the
    /// supervision wrapper, which turns it into an obituary).
    Panic,
    /// Sleep this long before handling the request — long enough stalls
    /// trip the supervisor's heartbeat watchdog.
    Stall(Duration),
}

/// One scheduled fault: `kind` fires when worker `worker` dequeues its
/// `at_request`-th inference request (1-based).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultSpec {
    pub worker: usize,
    pub at_request: u64,
    pub kind: FaultKind,
}

/// What a connection-level fault does when it fires (in `net::conn`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetFaultKind {
    /// Sever the connection abruptly (no reply, no clean shutdown) —
    /// the "client was killed / link died" case the resume path covers.
    Disconnect,
    /// Delay handling of the frame — models a stalled link or a slow
    /// peer; long enough stalls trip the per-connection read deadline.
    Stall(Duration),
    /// Corrupt the raw frame before decode (`frame::garble`), forcing a
    /// deterministic malformed-frame rejection.
    Garble,
}

/// One scheduled connection fault: `kind` fires when connection `conn`
/// (1-based accept ordinal) reads its `at_frame`-th frame. `at_frame =
/// None` fires on **every** frame (only `stall` accepts that form:
/// `stall@conn1:50ms` models a uniformly slow link).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NetFaultSpec {
    pub conn: u64,
    pub at_frame: Option<u64>,
    pub kind: NetFaultKind,
}

/// A parsed, immutable fault schedule shared by every worker spawn and
/// every accepted connection.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub faults: Vec<FaultSpec>,
    pub net_faults: Vec<NetFaultSpec>,
}

impl FaultPlan {
    /// Parse the `--faults` grammar. Empty input is an error (pass no
    /// flag for "no faults"); unknown verbs, malformed worker/ordinal
    /// fields, and missing pieces all fail loudly so a typo'd chaos run
    /// can't silently test nothing.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                crate::bail!("empty fault entry in '{spec}'");
            }
            match parse_one(part).with_context(|| format!("fault entry '{part}'"))? {
                ParsedFault::Worker(f) => plan.faults.push(f),
                ParsedFault::Net(f) => plan.net_faults.push(f),
            }
        }
        if plan.faults.is_empty() && plan.net_faults.is_empty() {
            crate::bail!("fault plan '{spec}' names no faults");
        }
        Ok(plan)
    }

    /// Read a plan from `SHARP_FAULTS`, if set. `Ok(None)` when unset or
    /// blank; parse failures propagate (a broken env var should stop
    /// startup, not silently disable injection).
    pub fn from_env() -> Result<Option<FaultPlan>> {
        match std::env::var("SHARP_FAULTS") {
            Ok(s) if !s.trim().is_empty() => {
                Ok(Some(FaultPlan::parse(&s).context("parsing SHARP_FAULTS")?))
            }
            _ => Ok(None),
        }
    }

    /// True when any scheduled fault targets `worker`.
    pub fn targets(&self, worker: usize) -> bool {
        self.faults.iter().any(|f| f.worker == worker)
    }

    /// True when any scheduled connection fault targets accept ordinal
    /// `conn`.
    pub fn targets_conn(&self, conn: u64) -> bool {
        self.net_faults.iter().any(|f| f.conn == conn)
    }
}

/// One parsed entry: worker-level or connection-level.
enum ParsedFault {
    Worker(FaultSpec),
    Net(NetFaultSpec),
}

fn parse_one(entry: &str) -> Result<ParsedFault> {
    let (verb, rest) = entry
        .split_once('@')
        .ok_or_else(|| crate::anyhow!("expected '<verb>@worker<W>:...' or '<verb>@conn<C>:...'"))?;
    let mut fields = rest.split(':');
    let target = fields
        .next()
        .ok_or_else(|| crate::anyhow!("expected a target after '@'"))?;
    if let Some(w) = target.strip_prefix("worker") {
        let worker = w
            .parse::<usize>()
            .map_err(|_| crate::anyhow!("bad worker index"))?;
        parse_worker_fault(verb, worker, &mut fields).map(ParsedFault::Worker)
    } else if let Some(c) = target.strip_prefix("conn") {
        let conn = c
            .parse::<u64>()
            .map_err(|_| crate::anyhow!("bad connection ordinal"))?;
        if conn == 0 {
            crate::bail!("connection ordinals are 1-based; conn0 never fires");
        }
        parse_net_fault(verb, conn, &mut fields).map(ParsedFault::Net)
    } else {
        crate::bail!("expected 'worker<W>' or 'conn<C>' after '@'")
    }
}

fn parse_worker_fault<'a>(
    verb: &str,
    worker: usize,
    fields: &mut impl Iterator<Item = &'a str>,
) -> Result<FaultSpec> {
    match verb {
        "panic" => {
            let at_request = parse_req(fields.next())?;
            ensure_done(fields.next())?;
            Ok(FaultSpec {
                worker,
                at_request,
                kind: FaultKind::Panic,
            })
        }
        "stall" => {
            let ms = parse_ms(fields.next())?;
            let at_request = parse_req(fields.next())?;
            ensure_done(fields.next())?;
            Ok(FaultSpec {
                worker,
                at_request,
                kind: FaultKind::Stall(Duration::from_millis(ms)),
            })
        }
        other => crate::bail!("unknown worker fault verb '{other}' (expected 'panic' or 'stall')"),
    }
}

fn parse_net_fault<'a>(
    verb: &str,
    conn: u64,
    fields: &mut impl Iterator<Item = &'a str>,
) -> Result<NetFaultSpec> {
    match verb {
        "disconnect" => {
            let at_frame = parse_frame(fields.next())?;
            ensure_done(fields.next())?;
            Ok(NetFaultSpec {
                conn,
                at_frame: Some(at_frame),
                kind: NetFaultKind::Disconnect,
            })
        }
        "stall" => {
            let ms = parse_ms(fields.next())?;
            // `stall@conn1:50ms` (no frame field) stalls every frame.
            let at_frame = match fields.next() {
                None => None,
                some => Some(parse_frame(some)?),
            };
            ensure_done(fields.next())?;
            Ok(NetFaultSpec {
                conn,
                at_frame,
                kind: NetFaultKind::Stall(Duration::from_millis(ms)),
            })
        }
        "garble" => {
            let at_frame = parse_frame(fields.next())?;
            ensure_done(fields.next())?;
            Ok(NetFaultSpec {
                conn,
                at_frame: Some(at_frame),
                kind: NetFaultKind::Garble,
            })
        }
        other => crate::bail!(
            "unknown connection fault verb '{other}' \
             (expected 'disconnect', 'stall', or 'garble')"
        ),
    }
}

fn parse_ms(field: Option<&str>) -> Result<u64> {
    field
        .and_then(|d| d.strip_suffix("ms"))
        .ok_or_else(|| crate::anyhow!("expected '<D>ms' duration field"))?
        .parse::<u64>()
        .map_err(|_| crate::anyhow!("bad stall duration"))
}

fn parse_req(field: Option<&str>) -> Result<u64> {
    let n = field
        .and_then(|r| r.strip_prefix("req"))
        .ok_or_else(|| crate::anyhow!("expected 'req<N>' ordinal field"))?
        .parse::<u64>()
        .map_err(|_| crate::anyhow!("bad request ordinal"))?;
    if n == 0 {
        crate::bail!("request ordinals are 1-based; req0 never fires");
    }
    Ok(n)
}

fn parse_frame(field: Option<&str>) -> Result<u64> {
    let n = field
        .and_then(|r| r.strip_prefix("frame"))
        .ok_or_else(|| crate::anyhow!("expected 'frame<F>' ordinal field"))?
        .parse::<u64>()
        .map_err(|_| crate::anyhow!("bad frame ordinal"))?;
    if n == 0 {
        crate::bail!("frame ordinals are 1-based; frame0 never fires");
    }
    Ok(n)
}

fn ensure_done(field: Option<&str>) -> Result<()> {
    match field {
        None => Ok(()),
        Some(extra) => crate::bail!("trailing field '{extra}'"),
    }
}

/// Per-worker-incarnation view of a [`FaultPlan`], held inside the serve
/// loop. Counts inference-request dequeues and reports the fault (if
/// any) due at the current ordinal. Generations past 0 never fire.
#[derive(Debug)]
pub struct FaultArm {
    faults: Vec<FaultSpec>,
    ordinal: u64,
}

impl FaultArm {
    /// Arm `plan` for incarnation `generation` of worker `worker`.
    /// Disarmed (empty) when the plan has nothing for this worker or the
    /// worker is a respawn.
    pub fn new(plan: Option<&FaultPlan>, worker: usize, generation: u64) -> FaultArm {
        let faults = match plan {
            Some(p) if generation == 0 => p
                .faults
                .iter()
                .filter(|f| f.worker == worker)
                .copied()
                .collect(),
            _ => Vec::new(),
        };
        FaultArm { faults, ordinal: 0 }
    }

    /// Advance the request ordinal and return the fault scheduled at it,
    /// if any. Call exactly once per `WorkerMsg::Request` dequeue,
    /// before handling the request.
    pub fn on_request(&mut self) -> Option<FaultKind> {
        self.ordinal += 1;
        let at = self.ordinal;
        self.faults
            .iter()
            .find(|f| f.at_request == at)
            .map(|f| f.kind)
    }
}

/// Per-connection view of a [`FaultPlan`]'s connection faults, held by
/// the framing loop. Counts frames read on this connection and reports
/// the faults due at the current ordinal. Unlike [`FaultArm`] there is
/// no generation gate: the gate is the accept ordinal itself (a
/// reconnected client is a NEW connection with a new ordinal, so a
/// disconnect fault never re-fires on the resumed stream).
#[derive(Debug)]
pub struct NetFaultArm {
    faults: Vec<NetFaultSpec>,
    ordinal: u64,
}

impl NetFaultArm {
    /// Arm `plan` for the connection accepted at 1-based ordinal `conn`.
    pub fn new(plan: Option<&FaultPlan>, conn: u64) -> NetFaultArm {
        let faults = match plan {
            Some(p) => p
                .net_faults
                .iter()
                .filter(|f| f.conn == conn)
                .copied()
                .collect(),
            None => Vec::new(),
        };
        NetFaultArm { faults, ordinal: 0 }
    }

    /// Advance the frame ordinal and return the faults due at it, in a
    /// fixed order (stalls, then garble, then disconnect) so a combined
    /// plan always replays identically. Call exactly once per frame
    /// read, before decoding it.
    pub fn on_frame(&mut self) -> Vec<NetFaultKind> {
        self.ordinal += 1;
        let at = self.ordinal;
        let mut due: Vec<NetFaultKind> = self
            .faults
            .iter()
            .filter(|f| f.at_frame.is_none() || f.at_frame == Some(at))
            .map(|f| f.kind)
            .collect();
        due.sort_by_key(|k| match k {
            NetFaultKind::Stall(_) => 0,
            NetFaultKind::Garble => 1,
            NetFaultKind::Disconnect => 2,
        });
        due
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_example() {
        let plan = FaultPlan::parse("panic@worker1:req17,stall@worker0:40ms:req5").unwrap();
        assert_eq!(
            plan.faults,
            vec![
                FaultSpec {
                    worker: 1,
                    at_request: 17,
                    kind: FaultKind::Panic,
                },
                FaultSpec {
                    worker: 0,
                    at_request: 5,
                    kind: FaultKind::Stall(Duration::from_millis(40)),
                },
            ]
        );
        assert!(plan.targets(0));
        assert!(plan.targets(1));
        assert!(!plan.targets(2));
    }

    #[test]
    fn whitespace_between_entries_is_tolerated() {
        let plan = FaultPlan::parse("panic@worker0:req1, stall@worker2:7ms:req3").unwrap();
        assert_eq!(plan.faults.len(), 2);
        assert_eq!(plan.faults[1].worker, 2);
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "",
            "panic",
            "panic@req3",
            "panic@worker1",
            "panic@workerx:req1",
            "panic@worker1:req0",
            "panic@worker1:reqx",
            "panic@worker1:req2:extra",
            "stall@worker0:req5",
            "stall@worker0:40:req5",
            "stall@worker0:40ms",
            "stall@worker0:xms:req5",
            "hiccup@worker0:req5",
            "panic@worker0:req1,,panic@worker1:req2",
            // Connection-fault malformations.
            "disconnect@conn3",
            "disconnect@conn3:framex",
            "disconnect@conn3:frame0",
            "disconnect@conn0:frame1",
            "disconnect@connx:frame1",
            "disconnect@worker3:frame1",
            "disconnect@conn3:frame1:extra",
            "garble@conn2",
            "garble@conn2:50ms",
            "garble@worker2:frame4",
            "stall@conn1:frame4",
            "stall@conn1:50ms:frame4:extra",
            "panic@conn1:frame1",
            "fuzz@conn1:frame1",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn parses_the_connection_fault_examples() {
        // The three forms from the issue, mixed with a worker fault.
        let plan = FaultPlan::parse(
            "disconnect@conn3:frame7,stall@conn1:50ms,garble@conn2:frame4,panic@worker0:req2",
        )
        .unwrap();
        assert_eq!(
            plan.net_faults,
            vec![
                NetFaultSpec {
                    conn: 3,
                    at_frame: Some(7),
                    kind: NetFaultKind::Disconnect,
                },
                NetFaultSpec {
                    conn: 1,
                    at_frame: None,
                    kind: NetFaultKind::Stall(Duration::from_millis(50)),
                },
                NetFaultSpec {
                    conn: 2,
                    at_frame: Some(4),
                    kind: NetFaultKind::Garble,
                },
            ]
        );
        assert_eq!(plan.faults.len(), 1, "worker fault parsed alongside");
        assert!(plan.targets_conn(1));
        assert!(plan.targets_conn(3));
        assert!(!plan.targets_conn(4));
        // A frame-pinned connection stall parses too.
        let plan = FaultPlan::parse("stall@conn5:7ms:frame2").unwrap();
        assert_eq!(
            plan.net_faults,
            vec![NetFaultSpec {
                conn: 5,
                at_frame: Some(2),
                kind: NetFaultKind::Stall(Duration::from_millis(7)),
            }]
        );
    }

    #[test]
    fn net_arm_fires_at_exact_ordinals_and_every_frame_stalls_repeat() {
        let plan =
            FaultPlan::parse("stall@conn1:5ms,garble@conn1:frame2,disconnect@conn1:frame2")
                .unwrap();
        let mut arm = NetFaultArm::new(Some(&plan), 1);
        let stall = NetFaultKind::Stall(Duration::from_millis(5));
        // Frame 1: only the every-frame stall.
        assert_eq!(arm.on_frame(), vec![stall]);
        // Frame 2: stall first, then garble, then disconnect.
        assert_eq!(
            arm.on_frame(),
            vec![stall, NetFaultKind::Garble, NetFaultKind::Disconnect]
        );
        // Frame 3: the every-frame stall keeps firing.
        assert_eq!(arm.on_frame(), vec![stall]);

        // Other connections and fault-free plans are inert.
        let mut other = NetFaultArm::new(Some(&plan), 2);
        assert!(other.on_frame().is_empty());
        let mut none = NetFaultArm::new(None, 1);
        assert!(none.on_frame().is_empty());
    }

    #[test]
    fn arm_fires_at_exact_ordinals_only() {
        let plan = FaultPlan::parse("panic@worker1:req3,stall@worker1:5ms:req1").unwrap();
        let mut arm = FaultArm::new(Some(&plan), 1, 0);
        assert_eq!(
            arm.on_request(),
            Some(FaultKind::Stall(Duration::from_millis(5)))
        );
        assert_eq!(arm.on_request(), None);
        assert_eq!(arm.on_request(), Some(FaultKind::Panic));
        assert_eq!(arm.on_request(), None);
    }

    #[test]
    fn arm_is_inert_for_other_workers_and_respawns() {
        let plan = FaultPlan::parse("panic@worker1:req1").unwrap();
        let mut other = FaultArm::new(Some(&plan), 0, 0);
        assert_eq!(other.on_request(), None);
        // generation 1 = the respawned replica: clean slate.
        let mut respawn = FaultArm::new(Some(&plan), 1, 1);
        assert_eq!(respawn.on_request(), None);
        let mut none = FaultArm::new(None, 1, 0);
        assert_eq!(none.on_request(), None);
    }
}
