//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] names exact points in the request stream where a
//! worker should misbehave — panic or stall — so every failure mode the
//! supervisor claims to handle is reproducible in a plain `cargo test`
//! run, with no scheduler luck involved. The grammar (accepted by
//! `--faults` and the `SHARP_FAULTS` env var) is a comma-joined list of:
//!
//! ```text
//! panic@worker<W>:req<N>          panic while handling worker W's N-th request
//! stall@worker<W>:<D>ms:req<N>    sleep D ms before handling worker W's N-th request
//! ```
//!
//! Ordinals are 1-based and count only `WorkerMsg::Request` dequeues on
//! that worker (session control traffic doesn't advance them), so a plan
//! fires at the same spot regardless of how Begin/End/Snapshot messages
//! interleave. Faults are armed only on a worker's **first incarnation**
//! (generation 0): a respawned replica starts with a clean slate, which
//! is exactly what lets the chaos suite assert "the respawned worker
//! serves traffic" without the plan re-killing it at the same ordinal.

use crate::error::{Context, Result};
use std::time::Duration;

/// What the injected fault does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// `panic!` inside the worker's serve loop (caught by the
    /// supervision wrapper, which turns it into an obituary).
    Panic,
    /// Sleep this long before handling the request — long enough stalls
    /// trip the supervisor's heartbeat watchdog.
    Stall(Duration),
}

/// One scheduled fault: `kind` fires when worker `worker` dequeues its
/// `at_request`-th inference request (1-based).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultSpec {
    pub worker: usize,
    pub at_request: u64,
    pub kind: FaultKind,
}

/// A parsed, immutable fault schedule shared by every worker spawn.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub faults: Vec<FaultSpec>,
}

impl FaultPlan {
    /// Parse the `--faults` grammar. Empty input is an error (pass no
    /// flag for "no faults"); unknown verbs, malformed worker/ordinal
    /// fields, and missing pieces all fail loudly so a typo'd chaos run
    /// can't silently test nothing.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut faults = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                crate::bail!("empty fault entry in '{spec}'");
            }
            faults.push(parse_one(part).with_context(|| format!("fault entry '{part}'"))?);
        }
        if faults.is_empty() {
            crate::bail!("fault plan '{spec}' names no faults");
        }
        Ok(FaultPlan { faults })
    }

    /// Read a plan from `SHARP_FAULTS`, if set. `Ok(None)` when unset or
    /// blank; parse failures propagate (a broken env var should stop
    /// startup, not silently disable injection).
    pub fn from_env() -> Result<Option<FaultPlan>> {
        match std::env::var("SHARP_FAULTS") {
            Ok(s) if !s.trim().is_empty() => {
                Ok(Some(FaultPlan::parse(&s).context("parsing SHARP_FAULTS")?))
            }
            _ => Ok(None),
        }
    }

    /// True when any scheduled fault targets `worker`.
    pub fn targets(&self, worker: usize) -> bool {
        self.faults.iter().any(|f| f.worker == worker)
    }
}

fn parse_one(entry: &str) -> Result<FaultSpec> {
    let (verb, rest) = entry
        .split_once('@')
        .ok_or_else(|| crate::anyhow!("expected '<verb>@worker<W>:...'"))?;
    let mut fields = rest.split(':');
    let worker = fields
        .next()
        .and_then(|w| w.strip_prefix("worker"))
        .ok_or_else(|| crate::anyhow!("expected 'worker<W>' after '@'"))?
        .parse::<usize>()
        .map_err(|_| crate::anyhow!("bad worker index"))?;
    match verb {
        "panic" => {
            let at_request = parse_req(fields.next())?;
            ensure_done(fields.next())?;
            Ok(FaultSpec {
                worker,
                at_request,
                kind: FaultKind::Panic,
            })
        }
        "stall" => {
            let ms = fields
                .next()
                .and_then(|d| d.strip_suffix("ms"))
                .ok_or_else(|| crate::anyhow!("expected '<D>ms' duration field"))?
                .parse::<u64>()
                .map_err(|_| crate::anyhow!("bad stall duration"))?;
            let at_request = parse_req(fields.next())?;
            ensure_done(fields.next())?;
            Ok(FaultSpec {
                worker,
                at_request,
                kind: FaultKind::Stall(Duration::from_millis(ms)),
            })
        }
        other => crate::bail!("unknown fault verb '{other}' (expected 'panic' or 'stall')"),
    }
}

fn parse_req(field: Option<&str>) -> Result<u64> {
    let n = field
        .and_then(|r| r.strip_prefix("req"))
        .ok_or_else(|| crate::anyhow!("expected 'req<N>' ordinal field"))?
        .parse::<u64>()
        .map_err(|_| crate::anyhow!("bad request ordinal"))?;
    if n == 0 {
        crate::bail!("request ordinals are 1-based; req0 never fires");
    }
    Ok(n)
}

fn ensure_done(field: Option<&str>) -> Result<()> {
    match field {
        None => Ok(()),
        Some(extra) => crate::bail!("trailing field '{extra}'"),
    }
}

/// Per-worker-incarnation view of a [`FaultPlan`], held inside the serve
/// loop. Counts inference-request dequeues and reports the fault (if
/// any) due at the current ordinal. Generations past 0 never fire.
#[derive(Debug)]
pub struct FaultArm {
    faults: Vec<FaultSpec>,
    ordinal: u64,
}

impl FaultArm {
    /// Arm `plan` for incarnation `generation` of worker `worker`.
    /// Disarmed (empty) when the plan has nothing for this worker or the
    /// worker is a respawn.
    pub fn new(plan: Option<&FaultPlan>, worker: usize, generation: u64) -> FaultArm {
        let faults = match plan {
            Some(p) if generation == 0 => p
                .faults
                .iter()
                .filter(|f| f.worker == worker)
                .copied()
                .collect(),
            _ => Vec::new(),
        };
        FaultArm { faults, ordinal: 0 }
    }

    /// Advance the request ordinal and return the fault scheduled at it,
    /// if any. Call exactly once per `WorkerMsg::Request` dequeue,
    /// before handling the request.
    pub fn on_request(&mut self) -> Option<FaultKind> {
        self.ordinal += 1;
        let at = self.ordinal;
        self.faults
            .iter()
            .find(|f| f.at_request == at)
            .map(|f| f.kind)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_example() {
        let plan = FaultPlan::parse("panic@worker1:req17,stall@worker0:40ms:req5").unwrap();
        assert_eq!(
            plan.faults,
            vec![
                FaultSpec {
                    worker: 1,
                    at_request: 17,
                    kind: FaultKind::Panic,
                },
                FaultSpec {
                    worker: 0,
                    at_request: 5,
                    kind: FaultKind::Stall(Duration::from_millis(40)),
                },
            ]
        );
        assert!(plan.targets(0));
        assert!(plan.targets(1));
        assert!(!plan.targets(2));
    }

    #[test]
    fn whitespace_between_entries_is_tolerated() {
        let plan = FaultPlan::parse("panic@worker0:req1, stall@worker2:7ms:req3").unwrap();
        assert_eq!(plan.faults.len(), 2);
        assert_eq!(plan.faults[1].worker, 2);
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "",
            "panic",
            "panic@req3",
            "panic@worker1",
            "panic@workerx:req1",
            "panic@worker1:req0",
            "panic@worker1:reqx",
            "panic@worker1:req2:extra",
            "stall@worker0:req5",
            "stall@worker0:40:req5",
            "stall@worker0:40ms",
            "stall@worker0:xms:req5",
            "hiccup@worker0:req5",
            "panic@worker0:req1,,panic@worker1:req2",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn arm_fires_at_exact_ordinals_only() {
        let plan = FaultPlan::parse("panic@worker1:req3,stall@worker1:5ms:req1").unwrap();
        let mut arm = FaultArm::new(Some(&plan), 1, 0);
        assert_eq!(
            arm.on_request(),
            Some(FaultKind::Stall(Duration::from_millis(5)))
        );
        assert_eq!(arm.on_request(), None);
        assert_eq!(arm.on_request(), Some(FaultKind::Panic));
        assert_eq!(arm.on_request(), None);
    }

    #[test]
    fn arm_is_inert_for_other_workers_and_respawns() {
        let plan = FaultPlan::parse("panic@worker1:req1").unwrap();
        let mut other = FaultArm::new(Some(&plan), 0, 0);
        assert_eq!(other.on_request(), None);
        // generation 1 = the respawned replica: clean slate.
        let mut respawn = FaultArm::new(Some(&plan), 1, 1);
        assert_eq!(respawn.on_request(), None);
        let mut none = FaultArm::new(None, 1, 0);
        assert_eq!(none.on_request(), None);
    }
}
