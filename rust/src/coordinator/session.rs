//! Streaming-session state: recurrent (h, c) carried across requests of
//! the same session (the online ASR pattern — frames arrive in chunks and
//! the LSTM state must persist between chunks).

use std::collections::HashMap;

/// Recurrent state of one streaming session.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionState {
    pub h: Vec<f32>,
    pub c: Vec<f32>,
    /// Chunks processed so far.
    pub steps: u64,
}

/// In-memory session store keyed by session id.
#[derive(Debug, Default)]
pub struct SessionStore {
    states: HashMap<u64, SessionState>,
    state_len: usize,
}

impl SessionStore {
    /// `state_len` = B*H of the cell artifact serving this store.
    pub fn new(state_len: usize) -> Self {
        SessionStore {
            states: HashMap::new(),
            state_len,
        }
    }

    /// Fetch (or zero-init) a session's state.
    pub fn get_or_init(&mut self, session: u64) -> SessionState {
        self.states
            .entry(session)
            .or_insert_with(|| SessionState {
                h: vec![0.0; self.state_len],
                c: vec![0.0; self.state_len],
                steps: 0,
            })
            .clone()
    }

    /// Store the post-request state.
    pub fn update(&mut self, session: u64, h: Vec<f32>, c: Vec<f32>) {
        assert_eq!(h.len(), self.state_len);
        assert_eq!(c.len(), self.state_len);
        let entry = self.states.entry(session).or_insert_with(|| SessionState {
            h: vec![0.0; self.state_len],
            c: vec![0.0; self.state_len],
            steps: 0,
        });
        entry.h = h;
        entry.c = c;
        entry.steps += 1;
    }

    /// Drop a finished session; returns whether it existed.
    pub fn end(&mut self, session: u64) -> bool {
        self.states.remove(&session).is_some()
    }

    pub fn len(&self) -> usize {
        self.states.len()
    }

    pub fn is_empty(&self) -> bool {
        self.states.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_init_then_carry() {
        let mut s = SessionStore::new(4);
        let st = s.get_or_init(1);
        assert_eq!(st.h, vec![0.0; 4]);
        assert_eq!(st.steps, 0);
        s.update(1, vec![1.0; 4], vec![2.0; 4]);
        let st = s.get_or_init(1);
        assert_eq!(st.h, vec![1.0; 4]);
        assert_eq!(st.c, vec![2.0; 4]);
        assert_eq!(st.steps, 1);
    }

    #[test]
    fn sessions_isolated() {
        let mut s = SessionStore::new(2);
        s.update(1, vec![1.0; 2], vec![1.0; 2]);
        let st2 = s.get_or_init(2);
        assert_eq!(st2.h, vec![0.0; 2]);
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn end_removes() {
        let mut s = SessionStore::new(2);
        s.get_or_init(9);
        assert!(s.end(9));
        assert!(!s.end(9));
        assert!(s.is_empty());
    }

    #[test]
    #[should_panic]
    fn wrong_length_rejected() {
        let mut s = SessionStore::new(4);
        s.update(1, vec![0.0; 3], vec![0.0; 4]);
    }
}
