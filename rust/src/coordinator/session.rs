//! Streaming-session state: recurrent (h, c) carried across requests of
//! the same session (the online ASR pattern — frames arrive in chunks and
//! the LSTM state must persist between chunks).
//!
//! Each worker owns one store per served hidden dim; session->worker
//! affinity (`routing::session_worker`) guarantees a session's state
//! lives in exactly one store. The store is capacity-bounded with LRU
//! eviction: millions of users abandoning sessions mid-stream must not
//! OOM the worker, so the coldest session is dropped when a new one needs
//! the slot (an evicted session that comes back simply restarts from the
//! zero state).

use std::collections::{HashMap, VecDeque};

/// Recurrent state of one streaming session.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionState {
    pub h: Vec<f32>,
    pub c: Vec<f32>,
    /// Chunks processed so far.
    pub steps: u64,
}

impl SessionState {
    fn zero(state_len: usize) -> Self {
        SessionState {
            h: vec![0.0; state_len],
            c: vec![0.0; state_len],
            steps: 0,
        }
    }
}

#[derive(Debug)]
struct Slot {
    state: SessionState,
    /// Stamp of this session's most recent touch; recency-queue entries
    /// with an older stamp are stale and skipped at eviction time.
    stamp: u64,
}

/// In-memory LRU session store keyed by session id.
#[derive(Debug)]
pub struct SessionStore {
    slots: HashMap<u64, Slot>,
    /// (session, stamp) in touch order; lazily compacted, so entries may
    /// be stale — eviction pops until it finds one matching a live slot.
    recency: VecDeque<(u64, u64)>,
    clock: u64,
    state_len: usize,
    max_sessions: usize,
    evicted: u64,
}

impl SessionStore {
    /// Unbounded store; `state_len` = H of the artifact serving it.
    pub fn new(state_len: usize) -> Self {
        Self::with_capacity(state_len, usize::MAX)
    }

    /// Store holding at most `max_sessions` live sessions (LRU-evicted).
    pub fn with_capacity(state_len: usize, max_sessions: usize) -> Self {
        SessionStore {
            slots: HashMap::new(),
            recency: VecDeque::new(),
            clock: 0,
            state_len,
            max_sessions: max_sessions.max(1),
            evicted: 0,
        }
    }

    fn touch(&mut self, session: u64) {
        self.clock += 1;
        let stamp = self.clock;
        if let Some(slot) = self.slots.get_mut(&session) {
            slot.stamp = stamp;
        }
        self.recency.push_back((session, stamp));
        // Lazy compaction: the queue holds one entry per touch, so bound
        // it against the live set to keep memory proportional to it.
        if self.recency.len() > 8 * self.slots.len().max(8) {
            let slots = &self.slots;
            self.recency
                .retain(|(id, stamp)| slots.get(id).map(|s| s.stamp) == Some(*stamp));
        }
    }

    /// Drop least-recently-used sessions until an insert has room.
    fn evict_for_insert(&mut self) {
        while self.slots.len() >= self.max_sessions {
            match self.recency.pop_front() {
                Some((id, stamp)) => {
                    // Stale entries (re-touched or ended sessions) are
                    // skipped; a match is genuinely the coldest session.
                    if self.slots.get(&id).map(|s| s.stamp) == Some(stamp) {
                        self.slots.remove(&id);
                        self.evicted += 1;
                    }
                }
                None => break, // queue exhausted: nothing evictable
            }
        }
    }

    /// Make sure a slot exists (LRU-evicting for room when it must be
    /// created). The single evict-then-insert path both accessors share.
    fn ensure_slot(&mut self, session: u64) {
        if !self.slots.contains_key(&session) {
            self.evict_for_insert();
            self.slots.insert(
                session,
                Slot {
                    state: SessionState::zero(self.state_len),
                    stamp: 0,
                },
            );
        }
    }

    /// Fetch (or zero-init) a session's state; counts as a use.
    pub fn get_or_init(&mut self, session: u64) -> SessionState {
        self.ensure_slot(session);
        self.touch(session);
        self.slots[&session].state.clone()
    }

    /// [`get_or_init`] without the clone: the fused gather path copies
    /// each lane's carry straight into the batched state block, so
    /// handing out a reference avoids one `(h, c)` allocation per lane
    /// per window. Counts as a use, like `get_or_init`.
    ///
    /// [`get_or_init`]: SessionStore::get_or_init
    pub fn peek_or_init(&mut self, session: u64) -> &SessionState {
        self.ensure_slot(session);
        self.touch(session);
        &self.slots[&session].state
    }

    /// Store the post-request state; counts as a use. Returns the
    /// session's chunk count after this update (1 for a fresh/restarted
    /// carry — how streaming clients detect a mid-stream LRU eviction).
    pub fn update(&mut self, session: u64, h: Vec<f32>, c: Vec<f32>) -> u64 {
        self.ensure_slot(session);
        let prev = self.slots[&session].state.steps;
        self.update_carried(session, h, c, prev)
    }

    /// [`update`] for a carry the caller gathered EARLIER (the fused
    /// window's gather-then-scatter pattern): later gathers in the same
    /// window may LRU-evict this session's slot in between, but the
    /// lane still evolved the real pre-eviction carry, so the chunk
    /// count continues from the gathered state's count instead of
    /// falsely reporting a restart the stream never had.
    ///
    /// [`update`]: SessionStore::update
    pub fn update_carried(
        &mut self,
        session: u64,
        h: Vec<f32>,
        c: Vec<f32>,
        prev_steps: u64,
    ) -> u64 {
        assert_eq!(h.len(), self.state_len);
        assert_eq!(c.len(), self.state_len);
        self.ensure_slot(session);
        // ensure_slot guarantees presence; the fallback re-insert keeps
        // this branch total without an expect (coordinator-wide lint).
        let state_len = self.state_len;
        let slot = self.slots.entry(session).or_insert_with(|| Slot {
            state: SessionState::zero(state_len),
            stamp: 0,
        });
        slot.state.h = h;
        slot.state.c = c;
        slot.state.steps = prev_steps + 1;
        let steps = slot.state.steps;
        self.touch(session);
        steps
    }

    /// Re-seat a carry salvaged from a dead worker incarnation (the
    /// supervisor's recovery path): the state lands verbatim — same
    /// `(h, c, steps)` — so the client's next chunk continues the stream
    /// bit-exactly. A length-mismatched state (wrong store) is dropped;
    /// the session then restarts from zero with the usual `steps == 1`
    /// restart signal, never a silently wrong carry. Counts as a use.
    pub fn restore(&mut self, session: u64, state: SessionState) {
        if state.h.len() != self.state_len || state.c.len() != self.state_len {
            return;
        }
        self.ensure_slot(session);
        if let Some(slot) = self.slots.get_mut(&session) {
            slot.state = state;
        }
        self.touch(session);
    }

    /// Remove every live session and hand the states back — how a
    /// panicking worker's supervision wrapper evacuates its carries into
    /// the obituary for the replacement incarnation.
    pub fn drain_all(&mut self) -> Vec<(u64, SessionState)> {
        self.recency.clear();
        self.slots
            .drain()
            .map(|(id, slot)| (id, slot.state))
            .collect()
    }

    /// Whether a session is currently live in this store (no LRU touch).
    pub fn contains(&self, session: u64) -> bool {
        self.slots.contains_key(&session)
    }

    /// Remove a finished session and hand back its final state.
    pub fn take(&mut self, session: u64) -> Option<SessionState> {
        // Recency entries for it go stale and are skipped lazily.
        self.slots.remove(&session).map(|s| s.state)
    }

    /// Drop a finished session; returns whether it existed.
    pub fn end(&mut self, session: u64) -> bool {
        self.take(session).is_some()
    }

    /// Sessions evicted by the LRU cap so far.
    pub fn evicted(&self) -> u64 {
        self.evicted
    }

    pub fn capacity(&self) -> usize {
        self.max_sessions
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }
}

/// Stable lane assignment for live streaming sessions: a session keeps
/// the same lane index across fuse windows for as long as it lives on
/// this worker, so occupancy attribution (and the gather order at equal
/// chunk lengths) is deterministic window to window. Lanes are recycled
/// lowest-free-first when sessions end; sessions that vanish without an
/// `End` (LRU eviction, abandonment) are reclaimed by [`retain_live`],
/// which the worker runs against its session store before assigning new
/// lanes once the table outgrows the live set.
///
/// [`retain_live`]: LaneTable::retain_live
#[derive(Debug, Default)]
pub struct LaneTable {
    /// Lane index -> occupying session (None = free).
    lanes: Vec<Option<u64>>,
    by_session: HashMap<u64, usize>,
}

impl LaneTable {
    pub fn new() -> LaneTable {
        LaneTable::default()
    }

    /// The session's stable lane, assigning the lowest free lane on
    /// first sight.
    pub fn lane_of(&mut self, session: u64) -> usize {
        if let Some(&lane) = self.by_session.get(&session) {
            return lane;
        }
        let lane = match self.lanes.iter().position(Option::is_none) {
            Some(free) => free,
            None => {
                self.lanes.push(None);
                self.lanes.len() - 1
            }
        };
        self.lanes[lane] = Some(session);
        self.by_session.insert(session, lane);
        lane
    }

    /// Free a finished session's lane (no-op for unknown sessions).
    pub fn release(&mut self, session: u64) {
        if let Some(lane) = self.by_session.remove(&session) {
            self.lanes[lane] = None;
        }
    }

    /// Drop lanes whose session no longer satisfies `live` — the sweep
    /// that reclaims lanes from LRU-evicted or abandoned sessions.
    pub fn retain_live(&mut self, live: impl Fn(u64) -> bool) {
        for lane in &mut self.lanes {
            if let Some(sid) = *lane {
                if !live(sid) {
                    self.by_session.remove(&sid);
                    *lane = None;
                }
            }
        }
    }

    /// Sessions currently holding a lane.
    pub fn occupancy(&self) -> usize {
        self.by_session.len()
    }

    /// Highest lane index ever in use this table's lifetime (capacity).
    pub fn width(&self) -> usize {
        self.lanes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_table_is_stable_and_recycles_lowest_free() {
        let mut t = LaneTable::new();
        assert_eq!(t.lane_of(10), 0);
        assert_eq!(t.lane_of(20), 1);
        assert_eq!(t.lane_of(30), 2);
        // Stable across repeated windows.
        assert_eq!(t.lane_of(20), 1);
        assert_eq!(t.lane_of(10), 0);
        t.release(20);
        assert_eq!(t.occupancy(), 2);
        // Lowest free lane is recycled; survivors keep theirs.
        assert_eq!(t.lane_of(40), 1);
        assert_eq!(t.lane_of(30), 2);
        t.release(99); // unknown: no-op
        assert_eq!(t.width(), 3);
    }

    #[test]
    fn lane_table_retain_reclaims_evicted_sessions() {
        let mut t = LaneTable::new();
        for sid in [1u64, 2, 3, 4] {
            t.lane_of(sid);
        }
        // Only 2 and 4 survived an eviction sweep.
        t.retain_live(|sid| sid % 2 == 0);
        assert_eq!(t.occupancy(), 2);
        assert_eq!(t.lane_of(2), 1, "survivor kept its lane");
        // Freed lanes are reusable, lowest first.
        assert_eq!(t.lane_of(9), 0);
        assert_eq!(t.lane_of(11), 2);
    }

    #[test]
    fn peek_or_init_matches_get_and_counts_as_use() {
        let mut s = SessionStore::with_capacity(2, 2);
        s.update(1, vec![1.0; 2], vec![2.0; 2]);
        let st = s.peek_or_init(1);
        assert_eq!(st.h, vec![1.0; 2]);
        assert_eq!(st.steps, 1);
        // Peeking 1 re-touched it, so a capacity squeeze evicts 2.
        s.get_or_init(2);
        s.peek_or_init(1);
        s.get_or_init(3);
        assert!(s.contains(1), "peek counts as a use");
        assert!(!s.contains(2), "coldest session evicted");
    }

    #[test]
    fn zero_init_then_carry() {
        let mut s = SessionStore::new(4);
        let st = s.get_or_init(1);
        assert_eq!(st.h, vec![0.0; 4]);
        assert_eq!(st.steps, 0);
        s.update(1, vec![1.0; 4], vec![2.0; 4]);
        let st = s.get_or_init(1);
        assert_eq!(st.h, vec![1.0; 4]);
        assert_eq!(st.c, vec![2.0; 4]);
        assert_eq!(st.steps, 1);
    }

    #[test]
    fn sessions_isolated() {
        let mut s = SessionStore::new(2);
        s.update(1, vec![1.0; 2], vec![1.0; 2]);
        let st2 = s.get_or_init(2);
        assert_eq!(st2.h, vec![0.0; 2]);
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn end_removes() {
        let mut s = SessionStore::new(2);
        s.get_or_init(9);
        assert!(s.end(9));
        assert!(!s.end(9));
        assert!(s.is_empty());
    }

    #[test]
    fn take_returns_final_state() {
        let mut s = SessionStore::new(2);
        s.update(3, vec![0.5; 2], vec![0.25; 2]);
        let st = s.take(3).expect("live session");
        assert_eq!(st.h, vec![0.5; 2]);
        assert_eq!(st.steps, 1);
        assert!(s.take(3).is_none());
    }

    #[test]
    #[should_panic]
    fn wrong_length_rejected() {
        let mut s = SessionStore::new(4);
        s.update(1, vec![0.0; 3], vec![0.0; 4]);
    }

    #[test]
    fn lru_evicts_coldest_first() {
        let mut s = SessionStore::with_capacity(1, 2);
        s.get_or_init(1);
        s.get_or_init(2);
        // Re-touch 1: now 2 is the coldest.
        s.get_or_init(1);
        s.get_or_init(3); // forces an eviction
        assert_eq!(s.len(), 2);
        assert_eq!(s.evicted(), 1);
        // 2 is gone (restarts from zero, steps reset)...
        s.update(1, vec![9.0], vec![9.0]);
        assert_eq!(s.get_or_init(2).steps, 0);
        // ...which itself evicted the then-coldest (3).
        assert_eq!(s.evicted(), 2);
        assert_eq!(s.get_or_init(1).h, vec![9.0], "hot session survived");
    }

    #[test]
    fn eviction_order_follows_updates_too() {
        let mut s = SessionStore::with_capacity(1, 3);
        for id in 1..=3 {
            s.get_or_init(id);
        }
        // Touch order now 2, 3, 1: updates count as uses.
        s.update(2, vec![2.0], vec![2.0]);
        s.update(3, vec![3.0], vec![3.0]);
        s.update(1, vec![1.0], vec![1.0]);
        s.get_or_init(4); // evicts 2
        s.get_or_init(5); // evicts 3
        assert_eq!(s.evicted(), 2);
        assert_eq!(s.get_or_init(1).h, vec![1.0], "most-recent survived");
        assert_eq!(s.get_or_init(2).steps, 0, "2 was evicted");
        assert_eq!(s.get_or_init(3).steps, 0, "3 was evicted");
    }

    #[test]
    fn ended_sessions_free_capacity_without_eviction() {
        let mut s = SessionStore::with_capacity(1, 2);
        s.get_or_init(1);
        s.get_or_init(2);
        assert!(s.end(1));
        // Room exists: no eviction needed, and the stale recency entry
        // for 1 must not count against anyone.
        s.get_or_init(3);
        assert_eq!(s.len(), 2);
        assert_eq!(s.evicted(), 0);
        assert_eq!(s.get_or_init(2).steps, 0);
    }

    #[test]
    fn update_carried_survives_intra_window_eviction() {
        // The fused-window hazard: session 1's carry is gathered, THEN
        // a later gather evicts its slot. The post-run update must
        // continue 1's chunk count (the lane evolved the real carry),
        // not report a restart the stream never had.
        let mut s = SessionStore::with_capacity(1, 2);
        assert_eq!(s.update(1, vec![1.0], vec![1.0]), 1);
        let gathered = s.get_or_init(1);
        // Two later gathers squeeze 1 out.
        s.get_or_init(2);
        s.get_or_init(3);
        assert!(!s.contains(1), "session 1 evicted mid-window");
        assert_eq!(
            s.update_carried(1, vec![2.0], vec![2.0], gathered.steps),
            2,
            "carried update continues the gathered count"
        );
        assert_eq!(s.get_or_init(1).steps, 2);
        // Plain update still reports restarts for BETWEEN-window
        // evictions (the gathered state itself was zero then).
        s.get_or_init(2);
        s.get_or_init(3); // evicts 1 again
        assert_eq!(s.update(1, vec![3.0], vec![3.0]), 1, "true restart");
    }

    #[test]
    fn update_reports_restart_after_eviction() {
        let mut s = SessionStore::with_capacity(1, 2);
        assert_eq!(s.update(1, vec![1.0], vec![1.0]), 1);
        assert_eq!(s.update(1, vec![2.0], vec![2.0]), 2);
        // Two newcomers evict 1; its next update restarts at 1, which is
        // the signal a streaming client sees as a lost carry.
        s.get_or_init(2);
        s.get_or_init(3);
        assert_eq!(s.update(1, vec![3.0], vec![3.0]), 1, "restarted carry");
    }

    #[test]
    fn restore_reseats_a_salvaged_carry_verbatim() {
        let mut a = SessionStore::new(2);
        a.update(7, vec![0.5, 0.25], vec![1.5, 2.5]);
        a.update(7, vec![0.75, 0.5], vec![3.0, 4.0]);
        let carried = a.take(7).expect("live session");
        assert_eq!(carried.steps, 2);

        // The replacement incarnation's fresh store receives it intact.
        let mut b = SessionStore::new(2);
        b.restore(7, carried.clone());
        let st = b.get_or_init(7);
        assert_eq!(st, carried, "bit-exact carry, steps included");

        // A mismatched-length state is refused: the session restarts
        // from zero (steps reset → the restart signal), never corrupt.
        let mut c = SessionStore::new(3);
        c.restore(7, carried);
        assert!(!c.contains(7));
        assert_eq!(c.get_or_init(7).steps, 0);
    }

    #[test]
    fn drain_all_evacuates_every_session() {
        let mut s = SessionStore::new(1);
        s.update(1, vec![1.0], vec![1.0]);
        s.update(2, vec![2.0], vec![2.0]);
        let mut drained = s.drain_all();
        drained.sort_by_key(|(id, _)| *id);
        assert_eq!(drained.len(), 2);
        assert_eq!(drained[0].0, 1);
        assert_eq!(drained[1].1.h, vec![2.0]);
        assert!(s.is_empty());
        // The store stays usable after evacuation.
        assert_eq!(s.get_or_init(3).steps, 0);
    }

    #[test]
    fn bounded_store_never_exceeds_capacity() {
        let mut s = SessionStore::with_capacity(1, 8);
        for id in 0..10_000u64 {
            s.update(id % 97, vec![id as f32], vec![0.0]);
            assert!(s.len() <= 8);
        }
        // The recency queue stays proportional to the live set.
        assert!(s.recency.len() <= 8 * 8);
    }
}
