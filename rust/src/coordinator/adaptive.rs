//! Adaptive batching controller: the serving-layer analogue of the
//! paper's runtime reconfiguration controller (§6.2, `tile/reconfig.rs`).
//! The same philosophy applies — observe cheaply, adapt within hard
//! bounds, keep the runtime cost negligible: each arrival updates one
//! EWMA and recomputes a two-field policy in O(1), exactly like the
//! controller's table lookup before each layer.
//!
//! The policy it tunes is the SLA-aware online-inference tradeoff the
//! paper's intro describes: larger batches raise utilization, the latency
//! SLA caps how long a request may wait. At low arrival rates waiting is
//! pure latency loss (the batch will not fill), so the controller shrinks
//! `max_batch` toward 1 and `max_wait` toward its floor; under load the
//! expected arrivals within one SLA window exceed the bucket's B, so the
//! batch grows toward B and the wait stretches only as far as filling it
//! should take — never past the SLA bound.

use std::time::{Duration, Instant};

use super::batcher::BatcherConfig;

/// Bounds and smoothing for the adaptive controller.
#[derive(Debug, Clone)]
pub struct AdaptiveConfig {
    /// Master switch; disabled, the seed policy is used as-is (clamped
    /// to the bucket's B).
    pub enabled: bool,
    /// Hard SLA bound on queueing wait — `max_wait` never exceeds this.
    pub sla_wait: Duration,
    /// Floor for `max_wait` (a closed batch still needs a deadline).
    pub min_wait: Duration,
    /// EWMA smoothing factor for inter-arrival gaps, in (0, 1].
    pub alpha: f64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            enabled: true,
            sla_wait: Duration::from_millis(5),
            min_wait: Duration::from_micros(200),
            alpha: 0.2,
        }
    }
}

/// Per-bucket controller: owns the live `BatcherConfig` for its bucket.
#[derive(Debug)]
pub struct AdaptiveController {
    cfg: AdaptiveConfig,
    /// The bucket's artifact batch capacity B — the hard `max_batch` cap.
    bucket_b: usize,
    policy: BatcherConfig,
    /// The unclamped seed, kept for [`Self::fuse_policy`]: the fuse lane
    /// cap is independent of the artifact B the stateless policy clamps
    /// to, so a cold/disabled fuse window re-clamps from the raw seed.
    seed: BatcherConfig,
    last_arrival: Option<Instant>,
    gap_ewma_s: Option<f64>,
}

impl AdaptiveController {
    /// Seed from the static policy, clamped into the bucket's capacity
    /// and the SLA bound (so even a misconfigured seed cannot overflow a
    /// batch or blow the SLA).
    pub fn new(cfg: AdaptiveConfig, seed: BatcherConfig, bucket_b: usize) -> Self {
        let bucket_b = bucket_b.max(1);
        let policy = BatcherConfig {
            max_batch: seed.max_batch.clamp(1, bucket_b),
            // max(min_wait) second, so a misconfigured min_wait > sla_wait
            // cannot panic the clamp.
            max_wait: seed.max_wait.min(cfg.sla_wait).max(cfg.min_wait),
        };
        AdaptiveController {
            cfg,
            bucket_b,
            policy,
            seed,
            last_arrival: None,
            gap_ewma_s: None,
        }
    }

    /// The current batching policy for this bucket.
    pub fn policy(&self) -> &BatcherConfig {
        &self.policy
    }

    /// Smoothed arrival rate estimate (requests/s), if one exists yet.
    pub fn rate_estimate_rps(&self) -> Option<f64> {
        self.gap_ewma_s.filter(|g| *g > 0.0).map(|g| 1.0 / g)
    }

    /// Feed one arrival timestamp; O(1) — one EWMA update plus the
    /// two-field replan (the §6.2 "negligible runtime cost" contract).
    /// Both stateless requests AND streaming chunk arrivals feed this
    /// rate: the fuse window and the batch bounds must see the bucket's
    /// whole offered load, not just the stateless half (a worker serving
    /// mostly chunks would otherwise plan as if it were idle).
    pub fn observe_arrival(&mut self, now: Instant) {
        if !self.cfg.enabled {
            return;
        }
        if let Some(prev) = self.last_arrival {
            let gap = now.saturating_duration_since(prev).as_secs_f64();
            self.gap_ewma_s = Some(match self.gap_ewma_s {
                Some(e) => (1.0 - self.cfg.alpha) * e + self.cfg.alpha * gap,
                None => gap,
            });
        }
        self.last_arrival = Some(now);
        self.replan();
    }

    fn replan(&mut self) {
        let Some(gap) = self.gap_ewma_s else { return };
        self.policy = derive_policy(gap, self.bucket_b, &self.cfg);
    }

    /// The streaming fuse-window policy: how many distinct live sessions
    /// to wait for (`max_batch` = target lanes) and for how long
    /// (`max_wait` = the fuse window) before a batched step launches.
    /// Derived from the SAME observed arrival rate as the stateless
    /// policy but capped by the dispatcher's lane bound instead of the
    /// artifact's B — fused lanes are kernel rows, not artifact batch
    /// slots. At low rates this collapses to one lane / minimal wait, so
    /// a lone streaming session never queues behind an empty window.
    pub fn fuse_policy(&self, max_lanes: usize) -> BatcherConfig {
        let cap = max_lanes.max(1);
        match self.gap_ewma_s {
            Some(gap) if self.cfg.enabled => derive_policy(gap, cap, &self.cfg),
            // Cold start (adaptive, but no rate observed yet): nothing
            // justifies holding the first chunk hostage to a window
            // that may never fill — run it at once.
            None if self.cfg.enabled => BatcherConfig {
                max_batch: 1,
                max_wait: self.cfg.min_wait,
            },
            // Disabled: the RAW seed re-clamped to the lane cap (the
            // stored policy is clamped to the artifact B, which has
            // nothing to do with how many kernel rows a window may
            // hold).
            _ => BatcherConfig {
                max_batch: self.seed.max_batch.clamp(1, cap),
                max_wait: self.policy.max_wait,
            },
        }
    }
}

/// The shared replan arithmetic: expected arrivals within one SLA window
/// at the observed rate decide the batch target (capped by `cap`), and
/// the wait stretches only as far as filling it should take — never past
/// the SLA bound.
fn derive_policy(gap: f64, cap: usize, cfg: &AdaptiveConfig) -> BatcherConfig {
    let sla_s = cfg.sla_wait.as_secs_f64();
    let expected = if gap > 0.0 { sla_s / gap } else { cap as f64 };
    let max_batch = (expected.floor() as usize).clamp(1, cap);
    let fill_s = gap * max_batch.saturating_sub(1) as f64;
    let min_s = cfg.min_wait.as_secs_f64();
    let max_wait = Duration::from_secs_f64(fill_s.clamp(min_s, sla_s.max(min_s)));
    BatcherConfig {
        max_batch,
        max_wait,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl(bucket_b: usize) -> AdaptiveController {
        AdaptiveController::new(
            AdaptiveConfig::default(),
            BatcherConfig::default(),
            bucket_b,
        )
    }

    fn feed(c: &mut AdaptiveController, t0: Instant, n: usize, gap: Duration) {
        for i in 0..n {
            c.observe_arrival(t0 + gap * i as u32);
        }
    }

    #[test]
    fn low_rate_shrinks_to_singles() {
        // 100 rps (10 ms gaps) against a 5 ms SLA: no batch will ever
        // fill in time, so don't wait at all.
        let mut c = ctl(8);
        feed(&mut c, Instant::now(), 20, Duration::from_millis(10));
        assert_eq!(c.policy().max_batch, 1);
        assert_eq!(c.policy().max_wait, AdaptiveConfig::default().min_wait);
    }

    #[test]
    fn high_rate_grows_toward_bucket_b() {
        // 20k rps (50 us gaps): ~100 arrivals per SLA window, so the
        // batch grows to the bucket's full B and the wait stretches only
        // to the expected fill time (~350 us), far under the SLA.
        let mut c = ctl(8);
        feed(&mut c, Instant::now(), 50, Duration::from_micros(50));
        assert_eq!(c.policy().max_batch, 8);
        assert!(c.policy().max_wait < AdaptiveConfig::default().sla_wait);
        assert!(c.policy().max_wait >= AdaptiveConfig::default().min_wait);
        let rate = c.rate_estimate_rps().expect("rate after arrivals");
        assert!((rate - 20_000.0).abs() / 20_000.0 < 0.05, "rate {rate}");
    }

    #[test]
    fn policy_shifts_when_load_shifts() {
        // The acceptance shape: the same controller moves its policy as
        // the offered load changes, in both directions.
        let mut c = ctl(4);
        let t0 = Instant::now();
        feed(&mut c, t0, 30, Duration::from_micros(100));
        assert_eq!(c.policy().max_batch, 4, "burst should fill the bucket");
        // Then the trace goes quiet: 50 ms gaps.
        feed(
            &mut c,
            t0 + Duration::from_secs(1),
            30,
            Duration::from_millis(50),
        );
        assert_eq!(c.policy().max_batch, 1, "idle tail should stop batching");
    }

    #[test]
    fn policy_always_within_bounds() {
        let cfg = AdaptiveConfig::default();
        let mut c = ctl(4);
        let t0 = Instant::now();
        // Alternate pathological gaps (0 and 20 ms) — bounds must hold
        // after every single arrival.
        for i in 0..40u32 {
            let jitter = if i % 2 == 0 { 0 } else { 20_000 };
            c.observe_arrival(t0 + Duration::from_micros((i * 500 + jitter) as u64));
            let p = c.policy();
            assert!((1..=4).contains(&p.max_batch), "max_batch {}", p.max_batch);
            assert!(p.max_wait >= cfg.min_wait && p.max_wait <= cfg.sla_wait);
        }
    }

    #[test]
    fn fuse_policy_scales_past_bucket_b_under_chunk_load() {
        // The session bucket's artifact B is often 1, but fused lanes
        // are kernel rows: under a heavy chunk rate the fuse window must
        // target the LANE cap, not the artifact batch capacity.
        let mut c = ctl(1); // session bucket with B=1
        feed(&mut c, Instant::now(), 50, Duration::from_micros(50));
        assert_eq!(c.policy().max_batch, 1, "stateless policy stays in B");
        let fuse = c.fuse_policy(64);
        assert_eq!(fuse.max_batch, 64, "fuse window targets the lane cap");
        assert!(fuse.max_wait <= AdaptiveConfig::default().sla_wait);
        assert!(fuse.max_wait >= AdaptiveConfig::default().min_wait);
    }

    #[test]
    fn fuse_policy_collapses_to_solo_at_low_rate_and_when_cold() {
        let mut c = ctl(4);
        // Cold controller: no rate observed yet — the first chunk must
        // not sit in a speculative window.
        let cold = c.fuse_policy(64);
        assert_eq!(cold.max_batch, 1);
        assert_eq!(cold.max_wait, AdaptiveConfig::default().min_wait);
        // Quiet trace: 10 ms gaps against a 5 ms SLA — one lane, floor
        // wait, so a lone streaming session never idles in a window.
        feed(&mut c, Instant::now(), 20, Duration::from_millis(10));
        let fuse = c.fuse_policy(64);
        assert_eq!(fuse.max_batch, 1);
        assert_eq!(fuse.max_wait, AdaptiveConfig::default().min_wait);
        // Degenerate cap clamps, never zero.
        assert_eq!(c.fuse_policy(0).max_batch, 1);
    }

    #[test]
    fn chunk_arrivals_move_the_same_rate_estimate() {
        // The satellite fix: chunk traffic feeds the SAME EWMA, so a
        // stream-only load still produces a live rate estimate.
        let mut c = ctl(8);
        assert!(c.rate_estimate_rps().is_none());
        feed(&mut c, Instant::now(), 30, Duration::from_micros(100));
        let rate = c.rate_estimate_rps().expect("chunks drove the rate");
        assert!((rate - 10_000.0).abs() / 10_000.0 < 0.05, "rate {rate}");
        assert_eq!(c.policy().max_batch, 8, "mixed-load batches grow too");
    }

    #[test]
    fn disabled_controller_is_static_but_clamped() {
        let mut c = AdaptiveController::new(
            AdaptiveConfig {
                enabled: false,
                ..Default::default()
            },
            BatcherConfig {
                max_batch: 100, // misconfigured: larger than the bucket
                max_wait: Duration::from_secs(10),
            },
            4,
        );
        let before = c.policy().clone();
        assert_eq!(before.max_batch, 4, "seed clamped to bucket B");
        assert_eq!(before.max_wait, AdaptiveConfig::default().sla_wait);
        feed(&mut c, Instant::now(), 20, Duration::from_micros(10));
        assert_eq!(c.policy().max_batch, before.max_batch);
        assert_eq!(c.policy().max_wait, before.max_wait);
        // The fuse window clamps the RAW seed to the lane cap — the
        // artifact-B clamp on the stateless policy must not leak in.
        assert_eq!(c.fuse_policy(64).max_batch, 64);
        assert_eq!(c.fuse_policy(8).max_batch, 8);
        assert_eq!(c.fuse_policy(64).max_wait, before.max_wait);
    }
}
