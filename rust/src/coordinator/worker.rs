//! One serving worker: the thread-confined execution half of the
//! coordinator. Each worker opens its **own** `ArtifactStore` (the
//! compile cache is `Rc`-based and `!Send`, like the PJRT handles it
//! stands in for), loads the executables for every served hidden dim,
//! and owns its batchers, adaptive controllers, session states, and
//! metrics outright — nothing it touches per-request is shared, so the
//! hot path takes no lock. Only plain request/response data crosses the
//! channel from the dispatcher.
//!
//! Stateless requests flow through the per-bucket dynamic batcher;
//! session chunks execute solo with the session's (h, c) as the initial
//! state (`LstmExecutable::run_prefix_into`, which stops exactly at the
//! chunk's last frame so the carry stays bit-exact).
//!
//! Each bucket owns a reusable request workspace (packed input, state
//! seeds, kernel output) and every executable owns its `ExecScratch`,
//! so the steady-state execute path allocates nothing per request; the
//! only remaining allocation is the response payload that crosses the
//! reply channel.
//!
//! Execution planning runs **once per bucket executable** at worker
//! startup: binding under the configured `PlanMode` resolves each
//! bucket's (D, H, B, T) to a kernel geometry + schedule (the paper's
//! per-model reconfiguration, §6.2), and the chosen plans are recorded
//! into this worker's metrics so `Server::metrics()` snapshots expose
//! them.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::LstmConfig;
use crate::error::{anyhow, Result};
use crate::experiments::common::sharp_tuned;
use crate::runtime::{ArtifactStore, LstmExecutable, LstmOutput};

use super::adaptive::AdaptiveController;
use super::batcher::Batcher;
use super::metrics::Metrics;
use super::request::{InferenceRequest, InferenceResponse};
use super::routing::{self, BucketShape};
use super::server::ServerConfig;
use super::session::{SessionState, SessionStore};

/// Reply channel for one request.
pub type Reply = Sender<Result<InferenceResponse, String>>;

/// Messages a worker accepts from the dispatcher.
pub enum WorkerMsg {
    Request(InferenceRequest, Reply),
    Begin {
        session: u64,
        hidden: usize,
        reply: Sender<Result<(), String>>,
    },
    End {
        session: u64,
        reply: Sender<Option<SessionState>>,
    },
    Snapshot(Sender<Metrics>),
    Shutdown,
}

/// Dispatcher-side handle to one spawned worker.
pub struct WorkerHandle {
    pub tx: SyncSender<WorkerMsg>,
    /// Requests sent but not yet dequeued by the worker — the queue
    /// depth the dispatcher plans against.
    pub depth: Arc<AtomicUsize>,
    pub join: JoinHandle<()>,
}

/// One (T, B) serving bucket of a model group.
struct Bucket {
    exe: LstmExecutable,
    batcher: Batcher,
    adaptive: AdaptiveController,
    waiters: Vec<Reply>,
    /// SHARP cycle-model estimate for this bucket's T (batch 1).
    accel_s: f64,
    /// Reusable request workspace: packed `(T, B, D)` input, zero-state
    /// seeds, and the kernel output. Together with the executable's own
    /// `ExecScratch` this makes the steady-state execute path
    /// allocation-free — the only per-request allocation left is the
    /// response's `h_t`, which crosses the reply channel and must own
    /// its data.
    xs: Vec<f32>,
    h0: Vec<f32>,
    c0: Vec<f32>,
    out: LstmOutput,
}

/// Everything one worker holds for one hidden dim.
struct ModelGroup {
    hidden: usize,
    buckets: Vec<Bucket>,
    shapes: Vec<BucketShape>,
    /// Index of the bucket streaming sessions pin (see
    /// `Manifest::session_seq` — the single source of that choice).
    session_bucket: usize,
    sessions: SessionStore,
}

/// Spawn a worker serving every hidden dim in `cfg.hidden`. Startup
/// (store open + bucket compiles) happens on the worker thread; the
/// returned receiver reports readiness, so a pool can spawn every
/// worker first and then wait for all of them in parallel.
pub fn spawn(cfg: ServerConfig, index: usize) -> (WorkerHandle, Receiver<Result<(), String>>) {
    let (tx, rx) = mpsc::sync_channel::<WorkerMsg>(cfg.queue_cap.max(1));
    let depth = Arc::new(AtomicUsize::new(0));
    let depth_worker = depth.clone();
    let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
    let join = std::thread::Builder::new()
        .name(format!("sharp-worker-{index}"))
        .spawn(move || match build_groups(&cfg) {
            Ok(groups) => {
                let _ = ready_tx.send(Ok(()));
                worker_loop(rx, groups, depth_worker);
            }
            Err(e) => {
                let _ = ready_tx.send(Err(format!("{e:#}")));
            }
        })
        .expect("spawn serving worker");
    (WorkerHandle { tx, depth, join }, ready_rx)
}

/// Worker-side setup: open this worker's store, compile every bucket of
/// every served hidden dim, precompute the accelerator estimates.
fn build_groups(cfg: &ServerConfig) -> Result<Vec<ModelGroup>> {
    let store = match &cfg.artifact_dir {
        Some(d) => ArtifactStore::open(d)?,
        None => ArtifactStore::open_default()?,
    };
    let mut groups = Vec::new();
    for &hidden in &cfg.hidden {
        if groups.iter().any(|g: &ModelGroup| g.hidden == hidden) {
            continue;
        }
        let names: Vec<String> = store
            .manifest
            .seq_entries(hidden)
            .map(|e| e.name.clone())
            .collect();
        if names.is_empty() {
            return Err(anyhow!("no seq artifacts with H={hidden} in manifest"));
        }
        // Bind with the configured runtime directly: the plan resolves
        // (and, in Calibrated mode, calibrates) once per bucket here,
        // and the weight panels are packed once at the plan's width.
        let mut exes: Vec<LstmExecutable> = names
            .iter()
            .map(|n| LstmExecutable::from_store_goldens_with(&store, n, cfg.runtime.clone()))
            .collect::<Result<_>>()?;
        exes.sort_by_key(|e| {
            routing::bucket_sort_key(&BucketShape {
                t: e.entry.t,
                b: e.entry.b,
            })
        });
        let shapes: Vec<BucketShape> = exes
            .iter()
            .map(|e| BucketShape {
                t: e.entry.t,
                b: e.entry.b,
            })
            .collect();
        let buckets: Vec<Bucket> = exes
            .into_iter()
            .map(|exe| {
                let model =
                    LstmConfig::square(hidden as u64).with_seq_len(exe.entry.t as u64);
                let accel_s = sharp_tuned(cfg.accel_macs, &model).time_s();
                // The controller clamps the seed policy to the bucket's
                // B, so an oversize batch is unrepresentable by
                // construction (no overflow path anywhere downstream).
                let adaptive = AdaptiveController::new(
                    cfg.adaptive.clone(),
                    cfg.batcher.clone(),
                    exe.entry.b,
                );
                let batcher = Batcher::new(adaptive.policy().clone());
                Bucket {
                    exe,
                    batcher,
                    adaptive,
                    waiters: Vec::new(),
                    accel_s,
                    xs: Vec::new(),
                    h0: Vec::new(),
                    c0: Vec::new(),
                    out: LstmOutput::default(),
                }
            })
            .collect();
        let session_name = store
            .manifest
            .session_seq(hidden)
            .map(|e| e.name.clone())
            .expect("seq entries exist (checked above)");
        let session_bucket = buckets
            .iter()
            .position(|b: &Bucket| b.exe.entry.name == session_name)
            .expect("session bucket is one of the compiled buckets");
        groups.push(ModelGroup {
            hidden,
            buckets,
            shapes,
            session_bucket,
            sessions: SessionStore::with_capacity(hidden, cfg.max_sessions),
        });
    }
    Ok(groups)
}

fn worker_loop(rx: Receiver<WorkerMsg>, mut groups: Vec<ModelGroup>, depth: Arc<AtomicUsize>) {
    let served: Vec<usize> = groups.iter().map(|g| g.hidden).collect();
    let mut metrics = Metrics::new();
    // Planning happened once per bucket executable at build time
    // (set_runtime under the configured PlanMode); surface each chosen
    // plan in this worker's metrics so snapshots show the configuration
    // the planner picked for every served shape.
    for g in &groups {
        for b in &g.buckets {
            metrics.record_plan(&b.exe.entry.name, b.exe.plan().describe());
        }
    }
    loop {
        // Park until the earliest batch deadline (or a message arrives).
        let now = Instant::now();
        let park = groups
            .iter()
            .flat_map(|g| g.buckets.iter())
            .filter_map(|b| b.batcher.time_to_deadline(now))
            .min()
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(park) {
            Ok(WorkerMsg::Request(req, reply)) => {
                depth.fetch_sub(1, Ordering::Relaxed);
                handle_request(&mut groups, &served, &mut metrics, req, reply);
            }
            Ok(WorkerMsg::Begin {
                session,
                hidden,
                reply,
            }) => {
                // Every counted message (all but Shutdown) decrements on
                // dequeue, keeping the dispatcher's depth gauge honest.
                depth.fetch_sub(1, Ordering::Relaxed);
                let r = match groups.iter_mut().find(|g| g.hidden == hidden) {
                    Some(g) => {
                        // Begin RESETS: a reused/abandoned id must not
                        // leak a previous stream's carry into this one.
                        let _ = g.sessions.take(session);
                        g.sessions.get_or_init(session);
                        Ok(())
                    }
                    None => Err(format!("hidden dim {hidden} not served (serving {served:?})")),
                };
                let _ = reply.send(r);
            }
            Ok(WorkerMsg::End { session, reply }) => {
                depth.fetch_sub(1, Ordering::Relaxed);
                let state = groups.iter_mut().find_map(|g| g.sessions.take(session));
                let _ = reply.send(state);
            }
            Ok(WorkerMsg::Snapshot(reply)) => {
                depth.fetch_sub(1, Ordering::Relaxed);
                let _ = reply.send(metrics.clone());
            }
            Ok(WorkerMsg::Shutdown) => break,
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
        // Fire any expired time bounds.
        let now = Instant::now();
        for g in &mut groups {
            for b in &mut g.buckets {
                if let Some(batch) = b.batcher.poll(now) {
                    flush(b, batch, &mut metrics);
                }
            }
        }
    }
    // Drain on shutdown.
    for g in &mut groups {
        for b in &mut g.buckets {
            if let Some(batch) = b.batcher.take() {
                flush(b, batch, &mut metrics);
            }
        }
    }
}

fn handle_request(
    groups: &mut [ModelGroup],
    served: &[usize],
    metrics: &mut Metrics,
    req: InferenceRequest,
    reply: Reply,
) {
    // A chunk for a LIVE session belongs to the group that owns the
    // session — never to whatever group the payload width happens to
    // match (a wrong-width chunk must fail inside the owning group, not
    // silently open a duplicate session id in another one). Width-based
    // resolution only decides where an implicit open lands.
    let owner = req
        .session
        .and_then(|sid| groups.iter().position(|g| g.sessions.contains(sid)));
    let hidden = match owner {
        Some(gi) => groups[gi].hidden,
        None => match routing::resolve_hidden(served, req.hidden, req.seq_len, req.payload.len())
        {
            Ok(h) => h,
            Err(msg) => {
                metrics.record_error();
                let _ = reply.send(Err(msg));
                return;
            }
        },
    };
    let group = groups
        .iter_mut()
        .find(|g| g.hidden == hidden)
        .expect("resolve_hidden returned a served dim");
    if req.seq_len == 0 {
        metrics.record_error();
        let _ = reply.send(Err("request has zero frames".into()));
        return;
    }
    if req.session.is_some() {
        // Every chunk of a session must bind the SAME artifact (each
        // artifact carries its own golden weights — switching buckets
        // mid-session would evolve the carry under a different model).
        // Sessions therefore pin the group's largest-T bucket
        // (Manifest::session_seq), which accepts the widest chunk range.
        let i = group.session_bucket;
        if req.seq_len > group.shapes[i].t {
            metrics.record_error();
            let _ = reply.send(Err(format!(
                "chunk of {} frames exceeds the session bucket T={} (H={hidden})",
                req.seq_len, group.shapes[i].t
            )));
            return;
        }
        stream_chunk(group, i, metrics, req, reply);
        return;
    }
    let Some(i) = routing::route(&group.shapes, req.seq_len) else {
        metrics.record_error();
        let _ = reply.send(Err(format!(
            "no bucket fits seq_len {} (H={hidden})",
            req.seq_len
        )));
        return;
    };
    let d = group.buckets[i].exe.entry.d;
    if req.payload.len() != req.seq_len * d {
        metrics.record_error();
        let _ = reply.send(Err(format!(
            "payload {} != seq_len {} x D {d}",
            req.payload.len(),
            req.seq_len
        )));
        return;
    }
    let bucket = &mut group.buckets[i];
    // Adaptive control: one O(1) observation per arrival, then the live
    // policy is handed to the batcher (mirrors §6.2's cheap-lookup rule).
    bucket.adaptive.observe_arrival(Instant::now());
    bucket.batcher.set_cfg(bucket.adaptive.policy().clone());
    bucket.waiters.push(reply);
    if let Some(batch) = bucket.batcher.push(req) {
        flush(bucket, batch, metrics);
    }
}

/// Execute one closed batch on a bucket's executable and answer waiters.
fn flush(bucket: &mut Bucket, batch: Vec<InferenceRequest>, metrics: &mut Metrics) {
    let waiters: Vec<_> = bucket.waiters.drain(..).collect();
    debug_assert_eq!(waiters.len(), batch.len());
    let e = &bucket.exe.entry;
    let (t, b_cap, d) = (e.t, e.b, e.d);
    // max_batch is clamped to the artifact's B at controller-seed time,
    // so a closed batch always fits the bucket.
    debug_assert!(batch.len() <= b_cap, "batch {} > bucket B {b_cap}", batch.len());
    let n = batch.len();

    // Pack (T, B, D) into the bucket's reused buffer: batch element j
    // carries request j's padded sequence.
    bucket.xs.clear();
    bucket.xs.resize(t * b_cap * d, 0.0);
    for (j, req) in batch.iter().enumerate() {
        for step in 0..req.seq_len.min(t) {
            let src = &req.payload[step * d..(step + 1) * d];
            let dst = (step * b_cap + j) * d;
            bucket.xs[dst..dst + d].copy_from_slice(src);
        }
    }
    bucket.h0.clear();
    bucket.h0.resize(b_cap * e.h, 0.0);
    bucket.c0.clear();
    bucket.c0.resize(b_cap * e.h, 0.0);
    let result = bucket.exe.run_into(&bucket.xs, &bucket.h0, &bucket.c0, &mut bucket.out);

    match result {
        Ok(()) => {
            let (out, h) = (&bucket.out, e.h);
            for (j, (req, reply)) in batch.into_iter().zip(waiters).enumerate() {
                // The request's true final hidden state is hs at its own
                // last step (padded steps keep evolving the carry, so we
                // must NOT take h_T for short sequences).
                let step = req.seq_len.min(t).saturating_sub(1);
                let base = (step * b_cap + j) * h;
                let h_t = out.hs[base..base + h].to_vec();
                let latency = req.enqueued_at.elapsed().as_secs_f64();
                metrics.record(latency, bucket.accel_s, n);
                let _ = reply.send(Ok(InferenceResponse {
                    id: req.id,
                    h_t,
                    latency_s: latency,
                    batch_size: n,
                    accel_time_s: bucket.accel_s,
                    session_steps: None,
                }));
            }
        }
        Err(err) => {
            let msg = format!("execution failed: {err:#}");
            for reply in waiters {
                metrics.record_error();
                let _ = reply.send(Err(msg.clone()));
            }
        }
    }
}

/// Execute one streaming chunk solo: the session's (h, c) seeds lane 0,
/// `run_prefix` stops exactly at the chunk's last frame, and the updated
/// carry goes back into the session store. Solo execution (batch 1) is
/// what keeps the carry exact — batching chunks would pad them to a
/// common T and the padded steps would corrupt the recurrent state.
fn stream_chunk(
    group: &mut ModelGroup,
    bucket_idx: usize,
    metrics: &mut Metrics,
    req: InferenceRequest,
    reply: Reply,
) {
    let session = req.session.expect("stream_chunk requires a session");
    let bucket = &mut group.buckets[bucket_idx];
    let e = &bucket.exe.entry;
    let (b_cap, d, h) = (e.b, e.d, e.h);
    let steps = req.seq_len;
    if steps == 0 || req.payload.len() != steps * d {
        metrics.record_error();
        let _ = reply.send(Err(format!(
            "chunk payload {} != seq_len {steps} x D {d}",
            req.payload.len()
        )));
        return;
    }
    let steps_frac = steps as f64 / e.t.max(1) as f64;
    let state = group.sessions.get_or_init(session);
    // Pack the chunk into lane 0 of the reused buffer; other lanes idle
    // on zeros.
    bucket.xs.clear();
    bucket.xs.resize(steps * b_cap * d, 0.0);
    for step in 0..steps {
        let src = &req.payload[step * d..(step + 1) * d];
        let dst = step * b_cap * d;
        bucket.xs[dst..dst + d].copy_from_slice(src);
    }
    bucket.h0.clear();
    bucket.h0.resize(b_cap * h, 0.0);
    bucket.c0.clear();
    bucket.c0.resize(b_cap * h, 0.0);
    bucket.h0[..h].copy_from_slice(&state.h);
    bucket.c0[..h].copy_from_slice(&state.c);
    let result = bucket
        .exe
        .run_prefix_into(&bucket.xs, steps, &bucket.h0, &bucket.c0, &mut bucket.out);
    match result {
        Ok(()) => {
            let out = &bucket.out;
            let h_t = out.h_t[..h].to_vec();
            let c_t = out.c_t[..h].to_vec();
            // steps AFTER this chunk: a mid-stream LRU eviction restarts
            // the count, which is how the client detects the lost carry.
            let steps = group.sessions.update(session, h_t.clone(), c_t);
            let latency = req.enqueued_at.elapsed().as_secs_f64();
            // The bucket estimate covers its full T; a chunk runs only
            // `steps` of them (run_prefix), so scale the modeled time.
            let accel = bucket.accel_s * steps_frac;
            metrics.record(latency, accel, 1);
            let _ = reply.send(Ok(InferenceResponse {
                id: req.id,
                h_t,
                latency_s: latency,
                batch_size: 1,
                accel_time_s: accel,
                session_steps: Some(steps),
            }));
        }
        Err(err) => {
            metrics.record_error();
            let _ = reply.send(Err(format!("chunk execution failed: {err:#}")));
        }
    }
}
