//! One serving worker: the thread-confined execution half of the
//! coordinator. Each worker opens its **own** `ArtifactStore` (the
//! compile cache is `Rc`-based and `!Send`, like the PJRT handles it
//! stands in for), loads the executables for every served hidden dim,
//! and owns its batchers, adaptive controllers, session states, and
//! metrics outright — nothing it touches per-request is shared, so the
//! hot path takes no lock. Only plain request/response data crosses the
//! channel from the dispatcher.
//!
//! Stateless requests flow through the per-bucket dynamic batcher.
//! Session chunks flow through the **step-fusion dispatcher**: arriving
//! chunks queue in a per-group fuse window whose size/time bounds come
//! from the adaptive controller (chunk arrivals feed the same EWMA as
//! stateless traffic), and when the window closes the first pending
//! chunk of every distinct live session is drained into one
//! `LstmExecutable::run_steps_batched_into` call — all lanes advance one
//! step per iteration, sharing each step's recurrent GEMM, with ragged
//! chunk lengths handled by lane retirement. Later chunks of the same
//! session stay queued for the next window (strict per-session FIFO
//! keeps the carry sequential), and a single-session window degenerates
//! to the solo `run_prefix_into` path. Either way every session's carry
//! is bit-identical to solo execution — fusion batches independent dot
//! products, it never reorders one.
//!
//! Stacked artifacts (manifest entries carrying `layers` /
//! `bidirectional` / `P`) are served by name: requests tagged with
//! `InferenceRequest::model` bypass width routing and land on the
//! matching [`StackBucket`], which runs them SOLO — a deep stack
//! spends its thread budget on the inter-layer step pipeline rather
//! than request fusion — and streams chunked sessions through its own
//! session store carrying the full `(L*dirs, H)` per-layer state.
//! Flat depth-1 traffic never sees any of this.
//!
//! Each bucket owns a reusable request workspace (packed input, state
//! seeds, kernel output) and every executable owns its `ExecScratch`,
//! so the steady-state execute path allocates nothing per request; the
//! only remaining allocation is the response payload that crosses the
//! reply channel.
//!
//! Execution planning runs **once per bucket executable** at worker
//! startup: binding under the configured `PlanMode` resolves each
//! bucket's (D, H, B, T) to a kernel geometry + schedule (the paper's
//! per-model reconfiguration, §6.2), and the chosen plans are recorded
//! into this worker's metrics so `Server::metrics()` snapshots expose
//! them.

use std::cmp::Reverse;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::LstmConfig;
use crate::error::{anyhow, Result, SharpError};
use crate::experiments::common::sharp_tuned;
use crate::runtime::{
    ArtifactStore, FusedBatch, LstmExecutable, LstmOutput, StackExecutable, StackOutput,
};

use super::adaptive::AdaptiveController;
use super::batcher::Batcher;
use super::faults::{FaultArm, FaultKind};
use super::metrics::Metrics;
use super::request::{InferenceRequest, InferenceResponse};
use super::routing::{self, BucketShape};
use super::server::ServerConfig;
use super::session::{LaneTable, SessionState, SessionStore};

/// Reply channel for one request. Errors are typed ([`SharpError`]):
/// deadline misses, overload sheds, and worker deaths are protocol, not
/// message strings.
pub type Reply = Sender<Result<InferenceResponse, SharpError>>;

/// Messages a worker accepts from the dispatcher.
pub enum WorkerMsg {
    Request(InferenceRequest, Reply),
    Begin {
        session: u64,
        hidden: usize,
        reply: Sender<Result<(), SharpError>>,
    },
    End {
        session: u64,
        reply: Sender<Option<SessionState>>,
    },
    /// Re-seat a session carry evacuated from this worker's previous
    /// incarnation (the supervisor's recovery path). Targeted at a flat
    /// group (`hidden`) or a stacked bucket (`model`); a target that no
    /// longer exists drops the state silently — the session then
    /// restarts with the usual `steps == 1` signal, never corrupt.
    Restore {
        hidden: Option<usize>,
        model: Option<String>,
        session: u64,
        state: SessionState,
    },
    Snapshot(Sender<Metrics>),
    /// End EVERY live streaming session on this worker — the pool-wide
    /// fence behind `Server::fence_sessions` (drain teardown): parked
    /// fuse chunks execute first (the same fence rule as [`End`]), then
    /// all carries drop. Replies with the number of sessions ended.
    ///
    /// [`End`]: WorkerMsg::End
    FenceAll(Sender<usize>),
    Shutdown,
}

/// Dispatcher-side handle to one spawned worker incarnation.
pub struct WorkerHandle {
    pub tx: SyncSender<WorkerMsg>,
    /// Requests sent but not yet dequeued by the worker — the queue
    /// depth the dispatcher plans against. Shared ACROSS incarnations of
    /// the same slot (the supervisor passes the slot's stable gauge into
    /// every respawn), so parked/salvaged messages keep counting.
    pub depth: Arc<AtomicUsize>,
    /// Cleared by the worker on ANY exit — panic (an obituary follows),
    /// ready failure, or normal shutdown. The supervisor's cheap
    /// liveness poll.
    pub alive: Arc<AtomicBool>,
    /// Watchdog heartbeat: milliseconds since `epoch`, stored by the
    /// serve loop at every wake-up and every handled message. A worker
    /// stuck inside one message (stall fault, livelocked kernel) stops
    /// advancing it, which is what distinguishes "stalled" from "idle"
    /// (an idle worker re-parks at least every 50 ms).
    pub heartbeat: Arc<AtomicU64>,
    /// The instant heartbeat milliseconds are measured from.
    pub epoch: Instant,
    /// Which incarnation of its slot this handle is (0 = original).
    pub generation: u64,
    pub join: JoinHandle<()>,
}

impl WorkerHandle {
    /// How far behind the heartbeat is, as seen from `now`.
    pub fn heartbeat_lag(&self, now: Instant) -> Duration {
        let beat = Duration::from_millis(self.heartbeat.load(Ordering::Acquire));
        now.duration_since(self.epoch).saturating_sub(beat)
    }
}

/// What a panicking worker incarnation leaves behind for the
/// supervisor: everything needed to keep clients whole. Built by the
/// supervision wrapper AFTER `catch_unwind` returns — the wrapper frame
/// (not the poisoned loop) owns the groups and metrics, so it can still
/// walk them.
pub struct Obituary {
    pub index: usize,
    /// Incarnation that died. The supervisor ignores session payloads
    /// from stale generations (a replaced-then-panicked stall victim
    /// must not clobber its successor's live carries).
    pub generation: u64,
    /// The panic message, for the typed `WorkerFailed` refusals.
    pub reason: String,
    /// Final metrics clone — merged into the supervisor's accumulator
    /// so a worker's served-request history survives its death.
    pub metrics: Metrics,
    /// Evacuated flat-group session carries: (hidden, session, state).
    pub flat_sessions: Vec<(usize, u64, SessionState)>,
    /// Evacuated stacked-bucket carries: (artifact name, session, state).
    pub stack_sessions: Vec<(String, u64, SessionState)>,
    /// Messages salvaged from the dead incarnation's queue, in order —
    /// the supervisor re-routes them to the replacement.
    pub salvaged: Vec<WorkerMsg>,
}

/// Best-effort panic payload rendering for obituaries.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "worker panicked (non-string payload)".to_string()
    }
}

/// One (T, B) serving bucket of a model group.
struct Bucket {
    exe: LstmExecutable,
    batcher: Batcher,
    adaptive: AdaptiveController,
    waiters: Vec<Reply>,
    /// SHARP cycle-model estimate for this bucket's T (batch 1).
    accel_s: f64,
    /// Reusable request workspace: packed `(T, B, D)` input, zero-state
    /// seeds, and the kernel output. Together with the executable's own
    /// `ExecScratch` this makes the steady-state execute path
    /// allocation-free — the only per-request allocation left is the
    /// response's `h_t`, which crosses the reply channel and must own
    /// its data.
    xs: Vec<f32>,
    h0: Vec<f32>,
    c0: Vec<f32>,
    out: LstmOutput,
    /// Fused-window gather/scatter workspace (used by the session
    /// bucket only; empty elsewhere). Reused across windows, so the
    /// steady-state fuse path allocates only the reply payloads.
    fused: FusedBatch,
}

/// One stacked (multi-layer / bidirectional / projected) serving
/// bucket. Stacked models are addressed by artifact name
/// (`InferenceRequest::with_model`), run SOLO per request — a deep
/// stack spends its parallelism budget on the inter-layer step
/// pipeline ([`StackExecutable`] routes to it when the runtime has
/// threads), not on request fusion — and stream through their own
/// session store whose state rows are `(L*dirs, H)` concatenated.
/// Flat depth-1 traffic (batched buckets, fused streaming windows) is
/// untouched by any of this.
struct StackBucket {
    exe: StackExecutable,
    /// Sessions streaming THIS stacked model; `state_len` is the full
    /// `L*dirs*H` per-layer carry, so one store per stack.
    sessions: SessionStore,
    /// Reusable solo-request workspace, same discipline as `Bucket`.
    xs: Vec<f32>,
    h0: Vec<f32>,
    c0: Vec<f32>,
    out: StackOutput,
    /// SHARP cycle-model estimate for the full stack at its full T.
    accel_s: f64,
}

/// Everything one worker holds for one hidden dim.
struct ModelGroup {
    hidden: usize,
    buckets: Vec<Bucket>,
    /// Stacked artifacts served at this hidden dim, by manifest name.
    stacks: Vec<StackBucket>,
    shapes: Vec<BucketShape>,
    /// Index of the bucket streaming sessions pin (see
    /// `Manifest::session_seq` — the single source of that choice).
    session_bucket: usize,
    sessions: SessionStore,
    /// Stable session -> lane assignment for the fuse dispatcher.
    lanes: LaneTable,
    /// Chunks awaiting the fuse window, in arrival order. Only the
    /// FIRST pending chunk of each session joins a window — later
    /// chunks wait for the next one (strict per-session FIFO).
    fuse: VecDeque<(InferenceRequest, Reply)>,
    /// Hard bound on lanes per fused window (`ServerConfig::max_fused_lanes`).
    fuse_cap: usize,
}

impl ModelGroup {
    /// Time until the open fuse window must close (None when empty).
    /// The clock is the oldest pending chunk's enqueue instant, so a
    /// chunk that already waited in the worker queue is not made to
    /// wait a full extra window.
    fn fuse_deadline(&self, now: Instant) -> Option<Duration> {
        let (req, _) = self.fuse.front()?;
        let policy = self.buckets[self.session_bucket]
            .adaptive
            .fuse_policy(self.fuse_cap);
        Some(policy.max_wait.saturating_sub(now.duration_since(req.enqueued_at)))
    }

    /// Distinct sessions among the pending chunks (the fuse size gauge).
    /// Only session chunks enter the fuse queue; a session-less entry
    /// (impossible by construction) simply doesn't count.
    fn fuse_distinct(&self) -> usize {
        let mut seen: Vec<u64> = Vec::with_capacity(self.fuse.len().min(64));
        for (req, _) in &self.fuse {
            let Some(sid) = req.session else { continue };
            if !seen.contains(&sid) {
                seen.push(sid);
            }
        }
        seen.len()
    }
}

/// Spawn one worker incarnation serving every hidden dim in
/// `cfg.hidden`. Startup (store open + bucket compiles) happens on the
/// worker thread; the returned receiver reports readiness, so a pool
/// can spawn every worker first and then wait for all of them in
/// parallel. The serve loop runs under `catch_unwind`: a panic anywhere
/// inside it is converted into an [`Obituary`] on `obits` — queue
/// salvage, evacuated session carries, final metrics, typed refusals
/// for every in-flight waiter — instead of stranding clients.
///
/// `depth` is the slot's stable queue gauge (shared across respawns);
/// `generation` is 0 for the original incarnation and increments per
/// respawn (fault injection arms only generation 0). Thread-spawn
/// failure is a `Result`, not a crash.
pub fn spawn(
    cfg: ServerConfig,
    index: usize,
    generation: u64,
    depth: Arc<AtomicUsize>,
    obits: Sender<Obituary>,
) -> Result<(WorkerHandle, Receiver<Result<(), String>>)> {
    let (tx, rx) = mpsc::sync_channel::<WorkerMsg>(cfg.queue_cap.max(1));
    let depth_worker = depth.clone();
    let alive = Arc::new(AtomicBool::new(true));
    let alive_worker = alive.clone();
    let heartbeat = Arc::new(AtomicU64::new(0));
    let heartbeat_worker = heartbeat.clone();
    let epoch = Instant::now();
    let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
    let join = std::thread::Builder::new()
        .name(format!("sharp-worker-{index}"))
        .spawn(move || {
            let mut groups = match build_groups(&cfg) {
                Ok(g) => {
                    let _ = ready_tx.send(Ok(()));
                    g
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    alive_worker.store(false, Ordering::Release);
                    return;
                }
            };
            let mut metrics = Metrics::new();
            record_plans(&groups, &mut metrics);
            let mut faults = FaultArm::new(cfg.faults.as_ref(), index, generation);
            // The loop borrows groups/metrics mutably; the wrapper frame
            // keeps OWNERSHIP, so after a panic unwinds through the
            // loop it can still evacuate sessions and refuse waiters.
            let outcome = catch_unwind(AssertUnwindSafe(|| {
                worker_loop(
                    &rx,
                    &mut groups,
                    &mut metrics,
                    &depth_worker,
                    &heartbeat_worker,
                    epoch,
                    &mut faults,
                );
            }));
            // ORDER MATTERS: the obituary must be in the channel BEFORE
            // `alive` clears. The supervisor acquires `alive == false`
            // and then re-drains obituaries, so this ordering guarantees
            // it finds the death's salvage/sessions under the CURRENT
            // generation — never respawning first and then mistaking the
            // real obituary for a stale one (which would drop carries).
            if let Err(payload) = outcome {
                let reason = panic_message(payload);
                let obit = build_obituary(
                    index,
                    generation,
                    reason,
                    &rx,
                    &mut groups,
                    &mut metrics,
                    &depth_worker,
                );
                let _ = obits.send(obit);
            }
            alive_worker.store(false, Ordering::Release);
        })
        .map_err(|e| anyhow!("spawn thread sharp-worker-{index}: {e}"))?;
    Ok((
        WorkerHandle {
            tx,
            depth,
            alive,
            heartbeat,
            epoch,
            generation,
            join,
        },
        ready_rx,
    ))
}

/// Surface each bucket's chosen execution plan in the worker's metrics
/// (planning itself happened at bind time in `build_groups`).
fn record_plans(groups: &[ModelGroup], metrics: &mut Metrics) {
    for g in groups {
        for b in &g.buckets {
            metrics.record_plan(&b.exe.entry.name, b.exe.plan().describe());
        }
        // Stacked buckets plan per layer; one metrics key per layer so
        // snapshots render `name/layer0: mr4/nr16/unfolded@avx2, ...`.
        for s in &g.stacks {
            for (l, p) in s.exe.layer_plans().iter().enumerate() {
                metrics.record_plan(&format!("{}/layer{l}", s.exe.entry.name), p.describe());
            }
        }
    }
}

/// The post-panic path: salvage the queue, refuse every in-flight
/// waiter with a typed `WorkerFailed`, evacuate all session carries,
/// and package it for the supervisor. Runs on the dying thread, in the
/// wrapper frame that still owns everything.
fn build_obituary(
    index: usize,
    generation: u64,
    reason: String,
    rx: &Receiver<WorkerMsg>,
    groups: &mut [ModelGroup],
    metrics: &mut Metrics,
    depth: &AtomicUsize,
) -> Obituary {
    // Salvage whatever the dispatcher already queued: snapshots answer
    // immediately (a dead worker must not make `Server::metrics` wait
    // out its timeout), everything else goes back for re-routing. Each
    // counted dequeue drops the gauge, exactly like the serve loop.
    let mut salvaged = Vec::new();
    while let Ok(m) = rx.try_recv() {
        match m {
            WorkerMsg::Snapshot(reply) => {
                depth.fetch_sub(1, Ordering::Relaxed);
                let _ = reply.send(metrics.clone());
            }
            WorkerMsg::FenceAll(reply) => {
                // Nothing left to fence here — the evacuation below moves
                // every carry to the supervisor. Answer now so a drain in
                // progress never waits out its patience on a dead worker.
                depth.fetch_sub(1, Ordering::Relaxed);
                let _ = reply.send(0);
            }
            WorkerMsg::Shutdown => {}
            other => {
                depth.fetch_sub(1, Ordering::Relaxed);
                salvaged.push(other);
            }
        }
    }
    // Refuse every waiter parked inside batchers and fuse queues: their
    // requests died with this incarnation, and a typed refusal beats a
    // silently dropped channel.
    let failure = SharpError::WorkerFailed {
        worker: Some(index),
        reason: reason.clone(),
    };
    let mut flat_sessions = Vec::new();
    let mut stack_sessions = Vec::new();
    for g in groups.iter_mut() {
        for b in g.buckets.iter_mut() {
            for reply in b.waiters.drain(..) {
                metrics.record_error();
                let _ = reply.send(Err(failure.clone()));
            }
        }
        for (_, reply) in g.fuse.drain(..) {
            metrics.record_error();
            let _ = reply.send(Err(failure.clone()));
        }
        for (sid, state) in g.sessions.drain_all() {
            flat_sessions.push((g.hidden, sid, state));
        }
        for s in g.stacks.iter_mut() {
            for (sid, state) in s.sessions.drain_all() {
                stack_sessions.push((s.exe.entry.name.clone(), sid, state));
            }
        }
    }
    Obituary {
        index,
        generation,
        reason,
        metrics: metrics.clone(),
        flat_sessions,
        stack_sessions,
        salvaged,
    }
}

/// Worker-side setup: open this worker's store, compile every bucket of
/// every served hidden dim, precompute the accelerator estimates.
fn build_groups(cfg: &ServerConfig) -> Result<Vec<ModelGroup>> {
    let store = match &cfg.artifact_dir {
        Some(d) => ArtifactStore::open(d)?,
        None => ArtifactStore::open_default()?,
    };
    let mut groups = Vec::new();
    for &hidden in &cfg.hidden {
        if groups.iter().any(|g: &ModelGroup| g.hidden == hidden) {
            continue;
        }
        let names: Vec<String> = store
            .manifest
            .seq_entries(hidden)
            .map(|e| e.name.clone())
            .collect();
        if names.is_empty() {
            return Err(anyhow!("no seq artifacts with H={hidden} in manifest"));
        }
        // Bind with the configured runtime directly: the plan resolves
        // (and, in Calibrated mode, calibrates) once per bucket here,
        // and the weight panels are packed once at the plan's width.
        let mut exes: Vec<LstmExecutable> = names
            .iter()
            .map(|n| LstmExecutable::from_store_goldens_with(&store, n, cfg.runtime.clone()))
            .collect::<Result<_>>()?;
        exes.sort_by_key(|e| {
            routing::bucket_sort_key(&BucketShape {
                t: e.entry.t,
                b: e.entry.b,
            })
        });
        let shapes: Vec<BucketShape> = exes
            .iter()
            .map(|e| BucketShape {
                t: e.entry.t,
                b: e.entry.b,
            })
            .collect();
        let buckets: Vec<Bucket> = exes
            .into_iter()
            .map(|exe| {
                let model =
                    LstmConfig::square(hidden as u64).with_seq_len(exe.entry.t as u64);
                let accel_s = sharp_tuned(cfg.accel_macs, &model).time_s();
                // The controller clamps the seed policy to the bucket's
                // B, so an oversize batch is unrepresentable by
                // construction (no overflow path anywhere downstream).
                let adaptive = AdaptiveController::new(
                    cfg.adaptive.clone(),
                    cfg.batcher.clone(),
                    exe.entry.b,
                );
                let batcher = Batcher::new(adaptive.policy().clone());
                Bucket {
                    exe,
                    batcher,
                    adaptive,
                    waiters: Vec::new(),
                    accel_s,
                    xs: Vec::new(),
                    h0: Vec::new(),
                    c0: Vec::new(),
                    out: LstmOutput::default(),
                    fused: FusedBatch::new(),
                }
            })
            .collect();
        let session_name = store
            .manifest
            .session_seq(hidden)
            .map(|e| e.name.clone())
            .ok_or_else(|| anyhow!("no session bucket for H={hidden} (seq entries vanished)"))?;
        let session_bucket = buckets
            .iter()
            .position(|b: &Bucket| b.exe.entry.name == session_name)
            .ok_or_else(|| anyhow!("session bucket {session_name:?} was not compiled"))?;
        // Stacked entries at this dim: one solo-serving bucket each,
        // bound through the stack executable (per-layer plans, the
        // inter-layer pipeline when the runtime has threads) with its
        // own session store sized to the full per-layer carry.
        let stack_names: Vec<String> = store
            .manifest
            .stacked_entries(hidden)
            .map(|e| e.name.clone())
            .collect();
        let stacks: Vec<StackBucket> = stack_names
            .iter()
            .map(|n| -> Result<StackBucket> {
                let exe =
                    StackExecutable::from_store_goldens_with(&store, n, cfg.runtime.clone())?;
                let model = LstmConfig::square(hidden as u64)
                    .with_seq_len(exe.entry.t as u64)
                    .with_layers(exe.entry.layers as u64);
                let accel_s = sharp_tuned(cfg.accel_macs, &model).time_s();
                let state_len = exe.state_rows() * exe.entry.h;
                Ok(StackBucket {
                    exe,
                    sessions: SessionStore::with_capacity(state_len, cfg.max_sessions),
                    xs: Vec::new(),
                    h0: Vec::new(),
                    c0: Vec::new(),
                    out: StackOutput::default(),
                    accel_s,
                })
            })
            .collect::<Result<_>>()?;
        groups.push(ModelGroup {
            hidden,
            buckets,
            shapes,
            stacks,
            session_bucket,
            sessions: SessionStore::with_capacity(hidden, cfg.max_sessions),
            lanes: LaneTable::new(),
            fuse: VecDeque::new(),
            fuse_cap: cfg.max_fused_lanes.max(1),
        });
    }
    Ok(groups)
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    rx: &Receiver<WorkerMsg>,
    groups: &mut [ModelGroup],
    metrics: &mut Metrics,
    depth: &AtomicUsize,
    heartbeat: &AtomicU64,
    epoch: Instant,
    faults: &mut FaultArm,
) {
    let served: Vec<usize> = groups.iter().map(|g| g.hidden).collect();
    // Bound on messages handled per wake-up before deadlines are
    // re-polled, so a sustained flood cannot starve time-bound batches.
    const DRAIN_CAP: usize = 256;
    'outer: loop {
        heartbeat.store(epoch.elapsed().as_millis() as u64, Ordering::Release);
        // Park until the earliest batch OR fuse-window deadline (or a
        // message arrives).
        let now = Instant::now();
        let park = groups
            .iter()
            .flat_map(|g| {
                g.buckets
                    .iter()
                    .filter_map(move |b| b.batcher.time_to_deadline(now))
                    .chain(g.fuse_deadline(now))
            })
            .min()
            .unwrap_or(Duration::from_millis(50));
        // Take the first message, then drain whatever else is already
        // queued before polling deadlines: a backlogged burst of chunks
        // lands in ONE fuse window instead of expiring chunk-by-chunk
        // into solo runs.
        let mut msg = match rx.recv_timeout(park) {
            Ok(m) => Some(m),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => break,
        };
        let mut drained = 0usize;
        while let Some(m) = msg.take() {
            // Per-message beat, not just per-wake: a DRAIN_CAP burst of
            // long batches must not read as a stall.
            heartbeat.store(epoch.elapsed().as_millis() as u64, Ordering::Release);
            match m {
                WorkerMsg::Request(req, reply) => {
                    depth.fetch_sub(1, Ordering::Relaxed);
                    // Deterministic fault injection: fires at this
                    // worker's exact request-dequeue ordinal, before any
                    // handling. The counter lands in metrics FIRST so a
                    // panic's obituary still reports it.
                    match faults.on_request() {
                        Some(FaultKind::Panic) => {
                            metrics.faults_injected += 1;
                            panic!("injected fault: panic at request ordinal (faults.rs)");
                        }
                        Some(FaultKind::Stall(d)) => {
                            metrics.faults_injected += 1;
                            std::thread::sleep(d);
                        }
                        None => {}
                    }
                    // Deadline shed at dequeue: a request that already
                    // blew its budget waiting in the queue is refused
                    // typed instead of burning kernel time on an answer
                    // nobody is waiting for.
                    if req.expired() {
                        let waited_ms = req.enqueued_at.elapsed().as_millis() as u64;
                        metrics.deadline_misses += 1;
                        metrics.record_error();
                        let _ = reply.send(Err(SharpError::DeadlineExceeded { waited_ms }));
                        drained += 1;
                        if drained < DRAIN_CAP {
                            msg = rx.try_recv().ok();
                        }
                        continue;
                    }
                    handle_request(groups, &served, metrics, req, reply);
                }
                WorkerMsg::Begin {
                    session,
                    hidden,
                    reply,
                } => {
                    // Every counted message (all but Shutdown) decrements
                    // on dequeue, keeping the dispatcher's depth gauge
                    // honest.
                    depth.fetch_sub(1, Ordering::Relaxed);
                    let r = match groups.iter_mut().find(|g| g.hidden == hidden) {
                        Some(g) => {
                            // Control messages are FENCES: a chunk of
                            // this session still parked in the fuse
                            // queue belongs to the PREVIOUS stream and
                            // must execute before the reset, not leak
                            // into the new one.
                            drain_session_chunks(g, session, metrics);
                            // Begin RESETS: a reused/abandoned id must not
                            // leak a previous stream's carry into this one.
                            let _ = g.sessions.take(session);
                            g.sessions.get_or_init(session);
                            Ok(())
                        }
                        None => Err(SharpError::Rejected(format!(
                            "hidden dim {hidden} not served (serving {served:?})"
                        ))),
                    };
                    let _ = reply.send(r);
                }
                WorkerMsg::End { session, reply } => {
                    depth.fetch_sub(1, Ordering::Relaxed);
                    let mut state = None;
                    for g in groups.iter_mut() {
                        // Fence: in-flight chunks parked in the fuse
                        // queue execute BEFORE the session ends, so the
                        // returned final carry includes them and no
                        // ghost session is resurrected afterwards.
                        drain_session_chunks(g, session, metrics);
                        // Free the fuse lane everywhere; the state lives
                        // in exactly one group's store.
                        g.lanes.release(session);
                        if state.is_none() {
                            state = g.sessions.take(session);
                        }
                        // Stacked stores too — a session id lives in at
                        // most one store, flat or stacked.
                        for s in g.stacks.iter_mut() {
                            if state.is_none() {
                                state = s.sessions.take(session);
                            }
                        }
                    }
                    let _ = reply.send(state);
                }
                WorkerMsg::Restore {
                    hidden,
                    model,
                    session,
                    state,
                } => {
                    depth.fetch_sub(1, Ordering::Relaxed);
                    restore_session(groups, hidden, model, session, state);
                }
                WorkerMsg::Snapshot(reply) => {
                    depth.fetch_sub(1, Ordering::Relaxed);
                    let _ = reply.send(metrics.clone());
                }
                WorkerMsg::FenceAll(reply) => {
                    depth.fetch_sub(1, Ordering::Relaxed);
                    let mut fenced = 0usize;
                    for g in groups.iter_mut() {
                        // Same fence rule as End, applied wholesale: every
                        // parked fuse chunk executes before its carry is
                        // dropped, so no in-flight step is lost.
                        poll_fuse(g, metrics, Instant::now(), true);
                        for (sid, _) in g.sessions.drain_all() {
                            g.lanes.release(sid);
                            fenced += 1;
                        }
                        for s in g.stacks.iter_mut() {
                            fenced += s.sessions.drain_all().len();
                        }
                    }
                    let _ = reply.send(fenced);
                }
                WorkerMsg::Shutdown => break 'outer,
            }
            drained += 1;
            if drained < DRAIN_CAP {
                msg = rx.try_recv().ok();
            }
        }
        // Fire any expired time bounds — batcher deadlines and fuse
        // windows whose size or age bound was reached.
        let now = Instant::now();
        for g in groups.iter_mut() {
            for b in &mut g.buckets {
                if let Some(batch) = b.batcher.poll(now) {
                    flush(b, batch, metrics);
                }
            }
            poll_fuse(g, metrics, now, false);
        }
    }
    // Drain on shutdown.
    for g in groups.iter_mut() {
        for b in &mut g.buckets {
            if let Some(batch) = b.batcher.take() {
                flush(b, batch, metrics);
            }
        }
        poll_fuse(g, metrics, Instant::now(), true);
    }
}

/// Re-seat one evacuated carry on this incarnation (see
/// [`WorkerMsg::Restore`]). `SessionStore::restore` itself drops
/// length-mismatched states, so every failure path here degrades to the
/// loud `steps == 1` restart signal rather than a corrupt carry.
fn restore_session(
    groups: &mut [ModelGroup],
    hidden: Option<usize>,
    model: Option<String>,
    session: u64,
    state: SessionState,
) {
    if let Some(name) = model {
        for g in groups.iter_mut() {
            if let Some(s) = g.stacks.iter_mut().find(|s| s.exe.entry.name == name) {
                s.sessions.restore(session, state);
                return;
            }
        }
        return;
    }
    if let Some(h) = hidden {
        if let Some(g) = groups.iter_mut().find(|g| g.hidden == h) {
            g.sessions.restore(session, state);
        }
    }
}

/// Execute any still-queued fuse chunks of `session`, in order, before
/// a Begin/End control message takes effect. The fuse queue decouples
/// dequeue from execution, and a control message must not overtake the
/// session's in-flight chunks: End would return a final carry missing
/// them (and their later execution would resurrect the ended session as
/// a ghost), Begin would let old-stream chunks corrupt the reset carry.
fn drain_session_chunks(group: &mut ModelGroup, session: u64, metrics: &mut Metrics) {
    while let Some(pos) = group
        .fuse
        .iter()
        .position(|(r, _)| r.session == Some(session))
    {
        let Some((req, reply)) = group.fuse.remove(pos) else {
            break;
        };
        let idx = group.session_bucket;
        stream_chunk(group, idx, metrics, req, reply);
    }
}

/// Close the fuse window when its size or age bound fires (`force`
/// drains everything at shutdown): each closed window takes the first
/// pending chunk of every distinct session. Looping covers both the
/// forced drain and a backlog where same-session chunks queued behind
/// the head must wait for their own windows.
fn poll_fuse(group: &mut ModelGroup, metrics: &mut Metrics, now: Instant, force: bool) {
    loop {
        if group.fuse.is_empty() {
            return;
        }
        if !force {
            let policy = group.buckets[group.session_bucket]
                .adaptive
                .fuse_policy(group.fuse_cap);
            let expired = group
                .fuse
                .front()
                .is_some_and(|(req, _)| now.duration_since(req.enqueued_at) >= policy.max_wait);
            // The size target cannot exceed the sessions that could
            // actually join: a lone fast-streaming session must not
            // wait out the window hoping for peers that do not exist
            // (live store sessions, or pending distinct ones — implicit
            // opens are not in the store until they first execute).
            let distinct = group.fuse_distinct();
            let target = policy
                .max_batch
                .min(group.sessions.len().max(distinct))
                .max(1);
            if !expired && distinct < target {
                return;
            }
        }
        fuse_flush(group, metrics);
    }
}

fn handle_request(
    groups: &mut [ModelGroup],
    served: &[usize],
    metrics: &mut Metrics,
    req: InferenceRequest,
    reply: Reply,
) {
    // Stacked artifacts are addressed by NAME, before any width or
    // session resolution: a deep stack's input width D is shared with
    // the flat models (and its carry rows are (L*dirs, H), not (H)),
    // so the name is the only unambiguous route.
    if let Some(name) = req.model.clone() {
        for group in groups.iter_mut() {
            if let Some(si) = group.stacks.iter().position(|s| s.exe.entry.name == name) {
                stack_request(group, si, metrics, req, reply);
                return;
            }
        }
        metrics.record_error();
        let _ = reply.send(Err(SharpError::Rejected(format!(
            "no stacked artifact named {name:?} is served"
        ))));
        return;
    }
    // A chunk for a LIVE session belongs to the group that owns the
    // session — never to whatever group the payload width happens to
    // match (a wrong-width chunk must fail inside the owning group, not
    // silently open a duplicate session id in another one). Width-based
    // resolution only decides where an implicit open lands.
    let owner = req
        .session
        .and_then(|sid| groups.iter().position(|g| g.sessions.contains(sid)));
    let hidden = match owner {
        Some(gi) => groups[gi].hidden,
        None => match routing::resolve_hidden(served, req.hidden, req.seq_len, req.payload.len())
        {
            Ok(h) => h,
            Err(msg) => {
                metrics.record_error();
                let _ = reply.send(Err(SharpError::Rejected(msg)));
                return;
            }
        },
    };
    // resolve_hidden only returns served dims, so the find is total in
    // practice; the refusal keeps it total in type too (no expect).
    let Some(group) = groups.iter_mut().find(|g| g.hidden == hidden) else {
        metrics.record_error();
        let _ = reply.send(Err(SharpError::Rejected(format!(
            "hidden dim {hidden} not served (serving {served:?})"
        ))));
        return;
    };
    if req.seq_len == 0 {
        metrics.record_error();
        let _ = reply.send(Err(SharpError::Rejected("request has zero frames".into())));
        return;
    }
    if req.session.is_some() {
        // Every chunk of a session must bind the SAME artifact (each
        // artifact carries its own golden weights — switching buckets
        // mid-session would evolve the carry under a different model).
        // Sessions therefore pin the group's largest-T bucket
        // (Manifest::session_seq), which accepts the widest chunk range.
        let i = group.session_bucket;
        if req.seq_len > group.shapes[i].t {
            metrics.record_error();
            let _ = reply.send(Err(SharpError::Rejected(format!(
                "chunk of {} frames exceeds the session bucket T={} (H={hidden})",
                req.seq_len, group.shapes[i].t
            ))));
            return;
        }
        let bucket = &mut group.buckets[i];
        let d = bucket.exe.entry.d;
        // Validate BEFORE the chunk enters the fuse queue, so a bad
        // chunk errs immediately instead of poisoning a window.
        if req.payload.len() != req.seq_len * d {
            metrics.record_error();
            let _ = reply.send(Err(SharpError::Rejected(format!(
                "chunk payload {} != seq_len {} x D {d}",
                req.payload.len(),
                req.seq_len
            ))));
            return;
        }
        // Chunk arrivals feed the SAME controller as stateless traffic
        // (the arrival-rate fix): the fuse window AND the stateless
        // batch bounds both see the bucket's whole offered load.
        bucket.adaptive.observe_arrival(Instant::now());
        bucket.batcher.set_cfg(bucket.adaptive.policy().clone());
        // Queue for the fuse window; the worker loop's poll closes it
        // when the size or age bound fires (at low rates the bound is
        // one session / the floor wait, so a lone chunk runs at once).
        group.fuse.push_back((req, reply));
        // Bound the fuse queue: past two full windows of backlog, a
        // window closes NOW. The worker then spends its time executing
        // instead of draining its channel, the bounded channel fills,
        // and `Server::submit` blocks — the end-to-end backpressure
        // contract (never drop, never buffer unboundedly) survives the
        // dequeue/execute decoupling fusion introduced.
        if group.fuse.len() >= 2 * group.fuse_cap {
            fuse_flush(group, metrics);
        }
        return;
    }
    let Some(i) = routing::route(&group.shapes, req.seq_len) else {
        metrics.record_error();
        let _ = reply.send(Err(SharpError::Rejected(format!(
            "no bucket fits seq_len {} (H={hidden})",
            req.seq_len
        ))));
        return;
    };
    let d = group.buckets[i].exe.entry.d;
    if req.payload.len() != req.seq_len * d {
        metrics.record_error();
        let _ = reply.send(Err(SharpError::Rejected(format!(
            "payload {} != seq_len {} x D {d}",
            req.payload.len(),
            req.seq_len
        ))));
        return;
    }
    let bucket = &mut group.buckets[i];
    // Adaptive control: one O(1) observation per arrival, then the live
    // policy is handed to the batcher (mirrors §6.2's cheap-lookup rule).
    bucket.adaptive.observe_arrival(Instant::now());
    bucket.batcher.set_cfg(bucket.adaptive.policy().clone());
    bucket.waiters.push(reply);
    if let Some(batch) = bucket.batcher.push(req) {
        flush(bucket, batch, metrics);
    }
}

/// Execute one closed batch on a bucket's executable and answer waiters.
fn flush(bucket: &mut Bucket, batch: Vec<InferenceRequest>, metrics: &mut Metrics) {
    let waiters: Vec<_> = bucket.waiters.drain(..).collect();
    debug_assert_eq!(waiters.len(), batch.len());
    let e = &bucket.exe.entry;
    let (t, b_cap, d) = (e.t, e.b, e.d);
    // max_batch is clamped to the artifact's B at controller-seed time,
    // so a closed batch always fits the bucket.
    debug_assert!(batch.len() <= b_cap, "batch {} > bucket B {b_cap}", batch.len());
    let n = batch.len();

    // Pack (T, B, D) into the bucket's reused buffer: batch element j
    // carries request j's padded sequence.
    bucket.xs.clear();
    bucket.xs.resize(t * b_cap * d, 0.0);
    for (j, req) in batch.iter().enumerate() {
        for step in 0..req.seq_len.min(t) {
            let src = &req.payload[step * d..(step + 1) * d];
            let dst = (step * b_cap + j) * d;
            bucket.xs[dst..dst + d].copy_from_slice(src);
        }
    }
    bucket.h0.clear();
    bucket.h0.resize(b_cap * e.h, 0.0);
    bucket.c0.clear();
    bucket.c0.resize(b_cap * e.h, 0.0);
    let result = bucket.exe.run_into(&bucket.xs, &bucket.h0, &bucket.c0, &mut bucket.out);

    match result {
        Ok(()) => {
            let (out, h) = (&bucket.out, e.h);
            for (j, (req, reply)) in batch.into_iter().zip(waiters).enumerate() {
                // The request's true final hidden state is hs at its own
                // last step (padded steps keep evolving the carry, so we
                // must NOT take h_T for short sequences).
                let step = req.seq_len.min(t).saturating_sub(1);
                let base = (step * b_cap + j) * h;
                let h_t = out.hs[base..base + h].to_vec();
                let latency = req.enqueued_at.elapsed().as_secs_f64();
                metrics.record(latency, bucket.accel_s, n);
                let _ = reply.send(Ok(InferenceResponse {
                    id: req.id,
                    h_t,
                    latency_s: latency,
                    batch_size: n,
                    accel_time_s: bucket.accel_s,
                    session_steps: None,
                }));
            }
        }
        Err(err) => {
            let e = SharpError::ExecFailed(format!("{err:#}"));
            for reply in waiters {
                metrics.record_error();
                let _ = reply.send(Err(e.clone()));
            }
        }
    }
}

/// Close one fuse window: select the first pending chunk of every
/// distinct live session (up to the lane cap), assign stable lanes,
/// gather the carries into the batched state block, advance all lanes
/// with ONE step-major fused run, and scatter each lane's carry back to
/// its session. A single-session window degenerates to the solo
/// `run_prefix` path (same bits, and the hoisted input projection is
/// the better schedule for one lane).
fn fuse_flush(group: &mut ModelGroup, metrics: &mut Metrics) {
    // Selection: first chunk per session, strict arrival order, capped.
    // Each selected entry carries its session id (captured here, so no
    // downstream stage has to re-prove the chunk has one).
    let cap = group.fuse_cap;
    let mut sel: Vec<(usize, u64, InferenceRequest, Reply)> = Vec::with_capacity(cap.min(16));
    {
        let ModelGroup {
            fuse,
            lanes,
            sessions,
            ..
        } = &mut *group;
        // Reclaim lanes of sessions that vanished without an End (LRU
        // eviction / abandonment) once the table outgrows the live set.
        if lanes.width() > 2 * sessions.len().max(cap) {
            lanes.retain_live(|sid| sessions.contains(sid));
        }
        let mut i = 0;
        while i < fuse.len() && sel.len() < cap {
            let Some(sid) = fuse[i].0.session else {
                // Unreachable by construction (only session chunks are
                // queued); refuse defensively rather than fuse garbage.
                if let Some((_, reply)) = fuse.remove(i) {
                    metrics.record_error();
                    let _ = reply.send(Err(SharpError::Rejected(
                        "session-less request in fuse queue".into(),
                    )));
                }
                continue;
            };
            if sel.iter().any(|(_, s, _, _)| *s == sid) {
                i += 1; // later chunk of a selected session: next window
                continue;
            }
            let Some((req, reply)) = fuse.remove(i) else {
                break;
            };
            sel.push((lanes.lane_of(sid), sid, req, reply));
        }
    }
    match sel.len() {
        0 => {}
        1 => {
            if let Some((_, _, req, reply)) = sel.pop() {
                let idx = group.session_bucket;
                stream_chunk(group, idx, metrics, req, reply);
            }
        }
        _ => fuse_execute(group, metrics, sel),
    }
}

/// Execute one multi-lane fused window on the session bucket.
fn fuse_execute(
    group: &mut ModelGroup,
    metrics: &mut Metrics,
    mut sel: Vec<(usize, u64, InferenceRequest, Reply)>,
) {
    // Longest chunk first (the kernel's lane-retirement invariant);
    // stable lanes break ties so the gather order is deterministic
    // window to window.
    sel.sort_by_key(|(lane, _, req, _)| (Reverse(req.seq_len), *lane));
    let ModelGroup {
        buckets,
        sessions,
        session_bucket,
        ..
    } = &mut *group;
    let bucket = &mut buckets[*session_bucket];
    let e = &bucket.exe.entry;
    let (d, h, t) = (e.d, e.h, e.t);
    bucket.fused.begin(d, h);
    // Gathered chunk counts per lane: a LATER gather in this loop may
    // LRU-evict an earlier lane's slot, so the post-run update must
    // continue from the count that belongs to the carry actually used.
    let mut prev_steps: Vec<u64> = Vec::with_capacity(sel.len());
    for (_, sid, req, _) in &sel {
        let state = sessions.peek_or_init(*sid);
        prev_steps.push(state.steps);
        bucket.fused.push_lane(&req.payload, req.seq_len, &state.h, &state.c);
    }
    bucket.fused.finish();
    let result = bucket.exe.run_steps_batched_into(&mut bucket.fused);
    match result {
        Ok(()) => {
            let lanes = sel.len();
            for step in 0..bucket.fused.max_steps() {
                metrics.record_step_occupancy(bucket.fused.active_lanes(step));
            }
            for (i, (_, sid, req, reply)) in sel.into_iter().enumerate() {
                let h_t = bucket.fused.lane_h(i).to_vec();
                let c_t = bucket.fused.lane_c(i).to_vec();
                // Chunk count AFTER this chunk: a between-window LRU
                // eviction restarts it (the gathered state was already
                // zero then), which is how clients detect a lost carry
                // — while an INTRA-window eviction by a later gather
                // continues the count, because this lane evolved the
                // real pre-eviction carry (update_carried).
                let steps = sessions.update_carried(sid, h_t.clone(), c_t, prev_steps[i]);
                let latency = req.enqueued_at.elapsed().as_secs_f64();
                // The bucket estimate covers its full T; this lane ran
                // req.seq_len of them.
                let accel = bucket.accel_s * req.seq_len as f64 / t.max(1) as f64;
                metrics.record(latency, accel, lanes);
                let _ = reply.send(Ok(InferenceResponse {
                    id: req.id,
                    h_t,
                    latency_s: latency,
                    batch_size: lanes,
                    accel_time_s: accel,
                    session_steps: Some(steps),
                }));
            }
        }
        Err(err) => {
            let e = SharpError::ExecFailed(format!("fused chunk: {err:#}"));
            for (_, _, _, reply) in sel {
                metrics.record_error();
                let _ = reply.send(Err(e.clone()));
            }
        }
    }
}

/// Execute one streaming chunk solo: the session's (h, c) seeds lane 0,
/// `run_prefix` stops exactly at the chunk's last frame, and the updated
/// carry goes back into the session store. The degenerate one-session
/// fuse window lands here — solo keeps the hoisted input projection,
/// and its steps count as occupancy-1 in the fusion metrics.
fn stream_chunk(
    group: &mut ModelGroup,
    bucket_idx: usize,
    metrics: &mut Metrics,
    req: InferenceRequest,
    reply: Reply,
) {
    let Some(session) = req.session else {
        metrics.record_error();
        let _ = reply.send(Err(SharpError::Rejected(
            "stream_chunk requires a session".into(),
        )));
        return;
    };
    let bucket = &mut group.buckets[bucket_idx];
    let e = &bucket.exe.entry;
    let (b_cap, d, h) = (e.b, e.d, e.h);
    let steps = req.seq_len;
    if steps == 0 || req.payload.len() != steps * d {
        metrics.record_error();
        let _ = reply.send(Err(SharpError::Rejected(format!(
            "chunk payload {} != seq_len {steps} x D {d}",
            req.payload.len()
        ))));
        return;
    }
    let steps_frac = steps as f64 / e.t.max(1) as f64;
    let state = group.sessions.get_or_init(session);
    // Pack the chunk into lane 0 of the reused buffer; other lanes idle
    // on zeros.
    bucket.xs.clear();
    bucket.xs.resize(steps * b_cap * d, 0.0);
    for step in 0..steps {
        let src = &req.payload[step * d..(step + 1) * d];
        let dst = step * b_cap * d;
        bucket.xs[dst..dst + d].copy_from_slice(src);
    }
    bucket.h0.clear();
    bucket.h0.resize(b_cap * h, 0.0);
    bucket.c0.clear();
    bucket.c0.resize(b_cap * h, 0.0);
    bucket.h0[..h].copy_from_slice(&state.h);
    bucket.c0[..h].copy_from_slice(&state.c);
    let result = bucket
        .exe
        .run_prefix_into(&bucket.xs, steps, &bucket.h0, &bucket.c0, &mut bucket.out);
    match result {
        Ok(()) => {
            // Solo steps are occupancy-1 in the fusion histogram.
            for _ in 0..steps {
                metrics.record_step_occupancy(1);
            }
            let out = &bucket.out;
            let h_t = out.h_t[..h].to_vec();
            let c_t = out.c_t[..h].to_vec();
            // steps AFTER this chunk: a mid-stream LRU eviction restarts
            // the count, which is how the client detects the lost carry.
            let steps = group.sessions.update(session, h_t.clone(), c_t);
            let latency = req.enqueued_at.elapsed().as_secs_f64();
            // The bucket estimate covers its full T; a chunk runs only
            // `steps` of them (run_prefix), so scale the modeled time.
            let accel = bucket.accel_s * steps_frac;
            metrics.record(latency, accel, 1);
            let _ = reply.send(Ok(InferenceResponse {
                id: req.id,
                h_t,
                latency_s: latency,
                batch_size: 1,
                accel_time_s: accel,
                session_steps: Some(steps),
            }));
        }
        Err(err) => {
            metrics.record_error();
            let _ = reply.send(Err(SharpError::ExecFailed(format!("chunk: {err:#}"))));
        }
    }
}

/// Serve one request on a stacked bucket. Stacked models run SOLO —
/// their parallelism budget goes to the inter-layer step pipeline, not
/// request fusion — so the request packs lane 0 of the artifact's batch
/// and runs immediately. Stateless full-T requests take `run_into`
/// (which covers bidirectional stacks); everything else goes through
/// `run_prefix_into`, and session chunks scatter/gather the `(L*dirs,
/// H)` per-layer carry through the stack's own session store.
fn stack_request(
    group: &mut ModelGroup,
    stack_idx: usize,
    metrics: &mut Metrics,
    req: InferenceRequest,
    reply: Reply,
) {
    let stack = &mut group.stacks[stack_idx];
    let e = &stack.exe.entry;
    let (t, b_cap, d, h) = (e.t, e.b, e.d, e.h);
    let steps = req.seq_len;
    if steps == 0 || steps > t {
        metrics.record_error();
        let _ = reply.send(Err(SharpError::Rejected(format!(
            "{}: seq_len {steps} outside 1..={t}",
            e.name
        ))));
        return;
    }
    if req.payload.len() != steps * d {
        metrics.record_error();
        let _ = reply.send(Err(SharpError::Rejected(format!(
            "{}: payload {} != seq_len {steps} x D {d}",
            e.name,
            req.payload.len()
        ))));
        return;
    }
    if req.session.is_some() && e.bidirectional {
        metrics.record_error();
        let _ = reply.send(Err(SharpError::Rejected(format!(
            "{}: bidirectional stacks cannot stream sessions (the reverse \
             direction needs the whole sequence)",
            e.name
        ))));
        return;
    }
    let rows = stack.exe.state_rows();
    let w = stack.exe.out_width();
    // Pack the request into lane 0; other lanes idle on zeros.
    stack.xs.clear();
    stack.xs.resize(steps * b_cap * d, 0.0);
    for step in 0..steps {
        let src = &req.payload[step * d..(step + 1) * d];
        let dst = step * b_cap * d;
        stack.xs[dst..dst + d].copy_from_slice(src);
    }
    stack.h0.clear();
    stack.h0.resize(rows * b_cap * h, 0.0);
    stack.c0.clear();
    stack.c0.resize(rows * b_cap * h, 0.0);
    if let Some(session) = req.session {
        // Scatter the session's concatenated (L*dirs, H) carry into
        // lane 0 of every state row.
        let state = stack.sessions.get_or_init(session);
        for r in 0..rows {
            let dst = r * b_cap * h;
            stack.h0[dst..dst + h].copy_from_slice(&state.h[r * h..(r + 1) * h]);
            stack.c0[dst..dst + h].copy_from_slice(&state.c[r * h..(r + 1) * h]);
        }
    }
    let result = if steps == t && req.session.is_none() {
        stack.exe.run_into(&stack.xs, &stack.h0, &stack.c0, &mut stack.out)
    } else {
        stack
            .exe
            .run_prefix_into(&stack.xs, steps, &stack.h0, &stack.c0, &mut stack.out)
    };
    match result {
        Ok(()) => {
            // Reply with the final layer's last-step output row (width
            // dirs*(P|H)), lane 0.
            let base = (steps - 1) * b_cap * w;
            let h_t = stack.out.out[base..base + w].to_vec();
            let session_steps = req.session.map(|session| {
                // Gather the evolved (L*dirs, B, H) carry back from lane
                // 0 of every state row for the next chunk. GRU stacks
                // mirror h into c (uniform interface), so the blind copy
                // is correct for every cell kind.
                let mut hc = vec![0.0f32; rows * h];
                let mut cc = vec![0.0f32; rows * h];
                for r in 0..rows {
                    let src = r * b_cap * h;
                    hc[r * h..(r + 1) * h].copy_from_slice(&stack.out.h_t[src..src + h]);
                    cc[r * h..(r + 1) * h].copy_from_slice(&stack.out.c_t[src..src + h]);
                }
                stack.sessions.update(session, hc, cc)
            });
            let latency = req.enqueued_at.elapsed().as_secs_f64();
            // The stack estimate covers its full T; this request ran
            // `steps` of them.
            let accel = stack.accel_s * steps as f64 / t.max(1) as f64;
            metrics.record(latency, accel, 1);
            let _ = reply.send(Ok(InferenceResponse {
                id: req.id,
                h_t,
                latency_s: latency,
                batch_size: 1,
                accel_time_s: accel,
                session_steps,
            }));
        }
        Err(err) => {
            metrics.record_error();
            let _ = reply.send(Err(SharpError::ExecFailed(format!(
                "{}: {err:#}",
                e.name
            ))));
        }
    }
}
