//! The serving front door: a dispatcher thread routing requests across a
//! pool of worker threads (the paper's tiled-dispatch philosophy lifted
//! to the serving layer — replicated compute units, one cheap routing
//! decision per request).
//!
//! ```text
//!                    Server::submit / infer / begin / chunk / end
//!                                      |
//!                               [ dispatcher ]
//!                  session? --> affinity hash (owner worker)
//!                  stateless --> round-robin over non-full queues
//!                   /                  |                  \
//!            [ worker 0 ]        [ worker 1 ]  ...   [ worker N-1 ]
//!            store+exes          store+exes          store+exes
//!            batchers            batchers            batchers
//!            sessions            sessions            sessions
//!            metrics             metrics             metrics
//! ```
//!
//! Worker queues are bounded (`queue_cap`); sends into them block —
//! backpressure, never a drop. For stateless traffic the planner avoids
//! full queues, so the dispatcher only stalls when EVERY queue is full.
//! Session-tagged requests always land on `routing::session_worker(id)`
//! (the recurrent (h, c) carry lives on exactly one thread, and strict
//! per-session FIFO ordering is what keeps the carry sequential) — the
//! deliberate cost of that strictness is head-of-line blocking: a chunk
//! for a worker whose queue is full stalls the dispatcher until that
//! owner drains, even if other workers are idle. Each worker is a full
//! replica serving every configured hidden dim, so `workers = N` means
//! N replicas per model variant.

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::error::{anyhow, Result};
use crate::runtime::RuntimeConfig;

use super::adaptive::AdaptiveConfig;
use super::batcher::BatcherConfig;
use super::metrics::Metrics;
use super::request::{InferenceRequest, InferenceResponse};
use super::routing;
use super::session::SessionState;
use super::worker::{self, WorkerHandle, WorkerMsg};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Artifact directory (`artifacts/` by default, or $SHARP_ARTIFACTS).
    pub artifact_dir: Option<PathBuf>,
    /// Hidden dims to serve — every worker replica hosts all of them.
    pub hidden: Vec<usize>,
    /// Worker replicas (each owns its own store, executables, batchers,
    /// sessions, and metrics).
    pub workers: usize,
    /// Bounded per-worker queue: when full, dispatch blocks
    /// (backpressure) instead of dropping.
    pub queue_cap: usize,
    /// Seed batching policy per bucket (the adaptive controller tunes it
    /// from there, within its SLA bounds).
    pub batcher: BatcherConfig,
    /// Adaptive batching bounds (SLA ceiling, wait floor, smoothing).
    pub adaptive: AdaptiveConfig,
    /// MAC budget for the attached SHARP cycle-time estimates.
    pub accel_macs: u64,
    /// LRU cap on live streaming sessions, per worker and hidden dim.
    pub max_sessions: usize,
    /// Hard bound on lanes per fused streaming window (the step-fusion
    /// dispatcher batches up to this many concurrent sessions into one
    /// step-major kernel run; the adaptive controller decides how many
    /// to actually wait for, capped here). Lanes are kernel GEMM rows,
    /// not artifact batch slots, so this may exceed any bucket's B.
    pub max_fused_lanes: usize,
    /// Kernel knobs applied to every executable the workers bind:
    /// per-GEMM thread fan-out plus the plan mode (`--plan
    /// auto|calibrated|fixed`) each bucket resolves its kernel geometry
    /// and schedule with — planning runs once per bucket at worker
    /// startup and the chosen plans surface in `Server::metrics()`.
    /// Default keeps kernels serial — with N worker replicas the pool
    /// already uses N cores; raise `threads` only when cores outnumber
    /// workers and batches are large.
    pub runtime: RuntimeConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            artifact_dir: None,
            hidden: vec![256],
            workers: 1,
            queue_cap: 64,
            batcher: BatcherConfig::default(),
            adaptive: AdaptiveConfig::default(),
            accel_macs: 4096,
            max_sessions: 4096,
            max_fused_lanes: 64,
            runtime: RuntimeConfig::default(),
        }
    }
}

enum Msg {
    Request(InferenceRequest, worker::Reply),
    Begin {
        session: u64,
        hidden: usize,
        reply: Sender<Result<(), String>>,
    },
    End {
        session: u64,
        reply: Sender<Option<SessionState>>,
    },
    Snapshot(Sender<Snapshot>),
    Shutdown,
}

/// A merged metrics snapshot plus how many workers actually reported.
struct Snapshot {
    metrics: Metrics,
    reported: usize,
    total: usize,
}

/// Handle to a running server (dispatcher + worker pool).
pub struct Server {
    tx: SyncSender<Msg>,
    dispatcher: Option<JoinHandle<()>>,
}

impl Server {
    /// Start the pool: spawn every worker (each opens its own store and
    /// compiles its buckets before reporting ready), then the dispatcher.
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        if cfg.workers == 0 {
            return Err(anyhow!("server needs at least one worker"));
        }
        if cfg.hidden.is_empty() {
            return Err(anyhow!("server needs at least one hidden dim"));
        }
        // Spawn every worker first, then wait for all of them: startup
        // (store open + bucket compiles) runs in parallel across the
        // pool instead of serializing per replica.
        let mut handles = Vec::with_capacity(cfg.workers);
        let mut readies = Vec::with_capacity(cfg.workers);
        for i in 0..cfg.workers {
            let (h, ready) = worker::spawn(cfg.clone(), i);
            handles.push(h);
            readies.push(ready);
        }
        for (i, ready) in readies.into_iter().enumerate() {
            let r = ready
                .recv()
                .map_err(|_| anyhow!("worker {i} died during startup"))
                .and_then(|r| r.map_err(|e| anyhow!("worker {i}: {e}")));
            if let Err(e) = r {
                shutdown_workers(&mut handles);
                return Err(e);
            }
        }
        let queue_cap = cfg.queue_cap.max(1);
        // Bounded ingress sized to the pool: when every worker queue is
        // full AND this buffer fills, submit() itself blocks — the
        // backpressure reaches the producer instead of buffering
        // requests without bound.
        let (tx, rx) = mpsc::sync_channel::<Msg>(cfg.workers * queue_cap);
        let dispatcher = std::thread::Builder::new()
            .name("sharp-dispatcher".into())
            .spawn(move || dispatch_loop(rx, handles, queue_cap))
            .expect("spawn dispatcher");
        Ok(Server {
            tx,
            dispatcher: Some(dispatcher),
        })
    }

    /// Submit a request; returns the channel the response arrives on.
    /// Under overload (every worker queue and the ingress buffer full)
    /// this call BLOCKS until the pool makes room — end-to-end
    /// backpressure; requests are never dropped.
    pub fn submit(
        &self,
        req: InferenceRequest,
    ) -> Receiver<Result<InferenceResponse, String>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        // A send failure means the dispatcher is gone; the caller sees
        // it as a closed reply channel.
        let _ = self.tx.send(Msg::Request(req, reply_tx));
        reply_rx
    }

    /// Submit and block for the response.
    pub fn infer(&self, req: InferenceRequest) -> Result<InferenceResponse> {
        let rx = self.submit(req);
        rx.recv()
            .map_err(|_| anyhow!("server terminated"))?
            .map_err(|e| anyhow!(e))
    }

    /// Open a streaming session on a hidden dim: zero (h, c) is staged on
    /// the owning worker. Chunks may also open sessions implicitly; this
    /// validates the dim up front.
    pub fn begin_session(&self, session: u64, hidden: usize) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Begin {
                session,
                hidden,
                reply,
            })
            .map_err(|_| anyhow!("server terminated"))?;
        rx.recv()
            .map_err(|_| anyhow!("server terminated"))?
            .map_err(|e| anyhow!(e))
    }

    /// Stream one chunk through a session: routes to the session's owner
    /// worker, executes with the carried (h, c), persists the new carry.
    /// The response's `h_t` is the state at the chunk's last frame.
    pub fn chunk(
        &self,
        session: u64,
        id: u64,
        seq_len: usize,
        payload: Vec<f32>,
    ) -> Result<InferenceResponse> {
        self.infer(InferenceRequest::new(id, seq_len, payload).with_session(session))
    }

    /// Close a streaming session, returning its final state (None if the
    /// session never existed or was LRU-evicted).
    pub fn end_session(&self, session: u64) -> Result<Option<SessionState>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::End { session, reply })
            .map_err(|_| anyhow!("server terminated"))?;
        rx.recv().map_err(|_| anyhow!("server terminated"))
    }

    /// Merged metrics snapshot across all workers. Each worker clones
    /// its own (lock-free) metrics on request — the only synchronization
    /// is this channel round-trip. Errs (instead of silently returning a
    /// partial count that could read as "traffic went backwards") when
    /// the dispatcher is gone or any worker failed to report.
    pub fn metrics(&self) -> Result<Metrics> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Snapshot(reply))
            .map_err(|_| anyhow!("server terminated"))?;
        let snap = rx.recv().map_err(|_| anyhow!("server terminated"))?;
        if snap.reported < snap.total {
            return Err(anyhow!(
                "metrics snapshot incomplete: {}/{} workers reported",
                snap.reported,
                snap.total
            ));
        }
        Ok(snap.metrics)
    }

    /// Stop the pool, draining pending batches first.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn shutdown_workers(handles: &mut Vec<WorkerHandle>) {
    for h in handles.iter() {
        let _ = h.tx.send(WorkerMsg::Shutdown);
    }
    for h in handles.drain(..) {
        let _ = h.join.join();
    }
}

fn dispatch_loop(rx: Receiver<Msg>, mut handles: Vec<WorkerHandle>, queue_cap: usize) {
    let n = handles.len();
    let mut rr = 0usize;
    // Scratch for queue depths, reused across requests — the routing
    // decision stays allocation-free on the hot path.
    let mut depths = vec![0usize; n];
    loop {
        match rx.recv() {
            Ok(Msg::Request(req, reply)) => {
                let w = match req.session {
                    // Affinity: the owner worker holds the (h, c) carry.
                    Some(sid) => routing::session_worker(sid, n),
                    None => {
                        for (d, h) in depths.iter_mut().zip(&handles) {
                            *d = h.depth.load(Ordering::Relaxed);
                        }
                        let w = routing::plan_dispatch(&depths, queue_cap, rr);
                        rr = (w + 1) % n;
                        w
                    }
                };
                handles[w].depth.fetch_add(1, Ordering::Relaxed);
                // Blocking send into the bounded queue: a full worker
                // backpressures the dispatcher; nothing is ever dropped.
                if handles[w].tx.send(WorkerMsg::Request(req, reply)).is_err() {
                    handles[w].depth.fetch_sub(1, Ordering::Relaxed);
                }
            }
            Ok(Msg::Begin {
                session,
                hidden,
                reply,
            }) => {
                let w = routing::session_worker(session, n);
                // Control messages occupy queue slots too, so they count
                // in the depth gauge plan_dispatch reads.
                handles[w].depth.fetch_add(1, Ordering::Relaxed);
                if handles[w]
                    .tx
                    .send(WorkerMsg::Begin {
                        session,
                        hidden,
                        reply,
                    })
                    .is_err()
                {
                    handles[w].depth.fetch_sub(1, Ordering::Relaxed);
                }
            }
            Ok(Msg::End { session, reply }) => {
                let w = routing::session_worker(session, n);
                handles[w].depth.fetch_add(1, Ordering::Relaxed);
                if handles[w].tx.send(WorkerMsg::End { session, reply }).is_err() {
                    handles[w].depth.fetch_sub(1, Ordering::Relaxed);
                }
            }
            Ok(Msg::Snapshot(reply)) => {
                // Fan out to every worker first, then collect: the wait
                // is the slowest single worker, not the sum of them. A
                // worker that cannot be reached (send failure or
                // timeout) makes the snapshot explicitly partial.
                let total = handles.len();
                let receivers: Vec<_> = handles
                    .iter()
                    .filter_map(|h| {
                        h.depth.fetch_add(1, Ordering::Relaxed);
                        let (tx, rx2) = mpsc::channel();
                        match h.tx.send(WorkerMsg::Snapshot(tx)) {
                            Ok(()) => Some(rx2),
                            Err(_) => {
                                h.depth.fetch_sub(1, Ordering::Relaxed);
                                None
                            }
                        }
                    })
                    .collect();
                let mut merged = Metrics::default();
                let mut reported = 0usize;
                for rx2 in receivers {
                    // Workers park at most 50 ms between messages; the
                    // timeout only guards a crashed worker.
                    if let Ok(m) = rx2.recv_timeout(Duration::from_secs(5)) {
                        merged.merge(&m);
                        reported += 1;
                    }
                }
                let _ = reply.send(Snapshot {
                    metrics: merged,
                    reported,
                    total,
                });
            }
            Ok(Msg::Shutdown) | Err(_) => break,
        }
    }
    shutdown_workers(&mut handles);
}
