//! The serving engine: ingress queue -> dynamic batcher -> artifact
//! execution -> responses, on plain threads + channels. One worker drives
//! all the (T, B) buckets of a hidden dimension; requests route to the
//! smallest bucket that fits (the router half of the coordinator).
//!
//! Thread-confinement: the artifact store's compile cache is `Rc`-based
//! (`!Send`, like the PJRT handles it stands in for), so the worker thread
//! opens the store, loads the executables, and keeps them for its
//! lifetime; only plain request/response data crosses the channels.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{anyhow, Result};

use crate::config::LstmConfig;
use crate::experiments::common::sharp_tuned;
use crate::runtime::{ArtifactStore, LstmExecutable};

use super::batcher::{Batcher, BatcherConfig};
use super::metrics::Metrics;
use super::request::{InferenceRequest, InferenceResponse};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Artifact directory (`artifacts/` by default, or $SHARP_ARTIFACTS).
    pub artifact_dir: Option<PathBuf>,
    /// Hidden dimension to serve (selects artifacts from the manifest).
    pub hidden: usize,
    /// Batching policy per bucket.
    pub batcher: BatcherConfig,
    /// MAC budget for the attached SHARP cycle-time estimates.
    pub accel_macs: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            artifact_dir: None,
            hidden: 256,
            batcher: BatcherConfig::default(),
            accel_macs: 4096,
        }
    }
}

enum Msg {
    Request(InferenceRequest, Sender<Result<InferenceResponse, String>>),
    Shutdown,
}

/// Handle to a running server.
pub struct Server {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    pub metrics: Arc<Mutex<Metrics>>,
}

struct Bucket {
    exe: LstmExecutable,
    batcher: Batcher,
    waiters: Vec<Sender<Result<InferenceResponse, String>>>,
}

impl Server {
    /// Start the server. The worker thread opens the store, compiles
    /// every `seq` artifact with the configured hidden dim, then signals
    /// readiness — compile cost stays off the request path.
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let metrics_worker = metrics.clone();
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let worker = std::thread::Builder::new()
            .name("sharp-server".into())
            .spawn(move || {
                match build_buckets(&cfg) {
                    Ok((buckets, accel_est)) => {
                        let _ = ready_tx.send(Ok(()));
                        worker_loop(rx, buckets, accel_est, metrics_worker);
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                    }
                }
            })
            .expect("spawn server worker");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("server worker died during startup"))?
            .map_err(|e| anyhow!(e))?;
        Ok(Server {
            tx,
            worker: Some(worker),
            metrics,
        })
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(
        &self,
        req: InferenceRequest,
    ) -> Receiver<Result<InferenceResponse, String>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        // A send failure means the worker is gone; the caller sees it as
        // a closed reply channel.
        let _ = self.tx.send(Msg::Request(req, reply_tx));
        reply_rx
    }

    /// Submit and block for the response.
    pub fn infer(&self, req: InferenceRequest) -> Result<InferenceResponse> {
        let rx = self.submit(req);
        rx.recv()
            .map_err(|_| anyhow!("server worker terminated"))?
            .map_err(|e| anyhow!(e))
    }

    /// Stop the worker, draining pending batches first.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Worker-side setup: open store, compile buckets, precompute estimates.
fn build_buckets(cfg: &ServerConfig) -> Result<(Vec<Bucket>, HashMap<usize, f64>)> {
    let store = match &cfg.artifact_dir {
        Some(d) => ArtifactStore::open(d)?,
        None => ArtifactStore::open_default()?,
    };
    let names: Vec<String> = store
        .manifest
        .entries
        .iter()
        .filter(|e| e.kind == "seq" && e.h == cfg.hidden)
        .map(|e| e.name.clone())
        .collect();
    if names.is_empty() {
        return Err(anyhow!("no seq artifacts with H={} in manifest", cfg.hidden));
    }
    let mut buckets: Vec<Bucket> = Vec::new();
    for n in &names {
        buckets.push(Bucket {
            exe: LstmExecutable::from_store_goldens(&store, n)?,
            batcher: Batcher::new(cfg.batcher.clone()),
            waiters: Vec::new(),
        });
    }
    // Routing picks the first fitting bucket: smallest T wins (least
    // padding), and at equal T the widest batch bucket wins (throughput —
    // the dynamic batcher can then actually group requests).
    buckets.sort_by_key(|b| (b.exe.entry.t, std::cmp::Reverse(b.exe.entry.b)));

    // SHARP cycle-model estimate per bucket T (batch 1).
    let accel_est: HashMap<usize, f64> = buckets
        .iter()
        .map(|b| {
            let model =
                LstmConfig::square(cfg.hidden as u64).with_seq_len(b.exe.entry.t as u64);
            (b.exe.entry.t, sharp_tuned(cfg.accel_macs, &model).time_s())
        })
        .collect();
    Ok((buckets, accel_est))
}

fn route(buckets: &[Bucket], seq_len: usize) -> Option<usize> {
    buckets.iter().position(|b| b.exe.entry.t >= seq_len)
}

fn worker_loop(
    rx: Receiver<Msg>,
    mut buckets: Vec<Bucket>,
    accel_est: HashMap<usize, f64>,
    metrics: Arc<Mutex<Metrics>>,
) {
    loop {
        // Park until the earliest batch deadline (or a request arrives).
        let now = Instant::now();
        let park = buckets
            .iter()
            .filter_map(|b| b.batcher.time_to_deadline(now))
            .min()
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(park) {
            Ok(Msg::Request(req, reply)) => match route(&buckets, req.seq_len) {
                Some(i) => {
                    let cap = buckets[i].exe.entry.b;
                    buckets[i].waiters.push(reply);
                    if let Some(batch) = buckets[i].batcher.push(req) {
                        flush(&mut buckets[i], batch, &accel_est, &metrics);
                    } else if buckets[i].batcher.pending_len() >= cap {
                        if let Some(batch) = buckets[i].batcher.take() {
                            flush(&mut buckets[i], batch, &accel_est, &metrics);
                        }
                    }
                }
                None => {
                    metrics.lock().unwrap().record_error();
                    let _ = reply.send(Err(format!("no bucket fits seq_len {}", req.seq_len)));
                }
            },
            Ok(Msg::Shutdown) => break,
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
        // Fire any expired time bounds.
        let now = Instant::now();
        for b in &mut buckets {
            if let Some(batch) = b.batcher.poll(now) {
                flush(b, batch, &accel_est, &metrics);
            }
        }
    }
    // Drain on shutdown.
    for b in &mut buckets {
        if let Some(batch) = b.batcher.take() {
            flush(b, batch, &accel_est, &metrics);
        }
    }
}

/// Execute one closed batch on a bucket's executable and answer waiters.
fn flush(
    bucket: &mut Bucket,
    batch: Vec<InferenceRequest>,
    accel_est: &HashMap<usize, f64>,
    metrics: &Arc<Mutex<Metrics>>,
) {
    let waiters: Vec<_> = bucket.waiters.drain(..).collect();
    debug_assert_eq!(waiters.len(), batch.len());
    let e = &bucket.exe.entry;
    let (t, b_cap, d) = (e.t, e.b, e.d);
    let n = batch.len().min(b_cap);

    // Pack (T, B, D): batch element j carries request j's padded sequence.
    let mut xs = vec![0.0f32; t * b_cap * d];
    for (j, req) in batch.iter().take(n).enumerate() {
        for step in 0..req.seq_len.min(t) {
            let src = &req.payload[step * d..(step + 1) * d];
            let dst = (step * b_cap + j) * d;
            xs[dst..dst + d].copy_from_slice(src);
        }
    }
    let (h0, c0) = bucket.exe.zero_state();
    let result = bucket.exe.run(&xs, &h0, &c0);
    let accel = accel_est.get(&t).copied().unwrap_or(0.0);

    match result {
        Ok(out) => {
            let h = e.h;
            for (j, (req, reply)) in batch.into_iter().zip(waiters).enumerate() {
                if j >= n {
                    let _ = reply.send(Err("batch overflow".into()));
                    continue;
                }
                // The request's true final hidden state is hs at its own
                // last step (padded steps keep evolving the carry, so we
                // must NOT take h_T for short sequences).
                let step = req.seq_len.min(t).saturating_sub(1);
                let base = (step * b_cap + j) * h;
                let h_t = out.hs[base..base + h].to_vec();
                let latency = req.enqueued_at.elapsed().as_secs_f64();
                metrics.lock().unwrap().record(latency, accel, n);
                let _ = reply.send(Ok(InferenceResponse {
                    id: req.id,
                    h_t,
                    latency_s: latency,
                    batch_size: n,
                    accel_time_s: accel,
                }));
            }
        }
        Err(err) => {
            let msg = format!("execution failed: {err:#}");
            for reply in waiters {
                metrics.lock().unwrap().record_error();
                let _ = reply.send(Err(msg.clone()));
            }
        }
    }
}

// Integration tests (require artifacts/) live in rust/tests/coordinator.rs.
