//! The serving front door: a supervising dispatcher thread routing
//! requests across a pool of worker threads (the paper's tiled-dispatch
//! philosophy lifted to the serving layer — replicated compute units,
//! one cheap routing decision per request), now with a fault-tolerance
//! contract: a worker that panics or stalls is detected, its queue is
//! salvaged, its session carries are evacuated, and a fresh incarnation
//! is respawned — while every affected client resolves with a typed
//! [`SharpError`] instead of hanging.
//!
//! ```text
//!            Server::submit / try_infer / begin / chunk / end
//!                               |
//!                    [ dispatcher / supervisor ]
//!          session? --> affinity hash (owner worker slot)
//!          stateless --> round-robin over non-full queues
//!          + per-slot: liveness poll, heartbeat watchdog,
//!            obituary intake, parked-message replay, respawn
//!           /                  |                  \
//!    [ worker 0 ]        [ worker 1 ]  ...   [ worker N-1 ]
//!    store+exes          store+exes          store+exes
//!    (each serve loop under catch_unwind; on panic it emits an
//!     Obituary: salvaged queue + evacuated sessions + metrics)
//! ```
//!
//! **Failure handling.** Each worker slot owns a stable queue-depth
//! gauge and a parked-message queue. When an incarnation dies (panic →
//! `alive` cleared + obituary) or stalls (heartbeat lag ≥ 2× watchdog),
//! the supervisor respawns it: salvaged and newly arriving messages
//! park, the obituary's session carries become `Restore` messages
//! delivered to the replacement right after it signals ready (so a
//! parked chunk finds its carry bit-exact), and parked traffic then
//! replays in order. A slot whose respawn fails three times is declared
//! failed: its traffic is refused with `WorkerFailed`, siblings are
//! untouched. Stalled-but-not-dead incarnations are *detached*, not
//! killed (std threads cannot be killed): the old thread keeps its
//! queue, drains it when it resumes, and exits on disconnect — its
//! sessions restart on the replacement with the loud `steps == 1`
//! signal, never a silently wrong carry.
//!
//! **Backpressure and overload.** Worker queues stay bounded; under
//! `OverloadPolicy::Block` (default) nothing is ever dropped — a full
//! worker parks up to `2 × queue_cap` messages, then the dispatcher
//! holds the head message and stops pulling ingress, so the bounded
//! ingress buffer fills and `submit` itself blocks (the pre-existing
//! head-of-line cost of strict session FIFO, now survivable). Under
//! `OverloadPolicy::Shed`, admission past the queue-depth watermark
//! resolves immediately with `Overloaded` instead of blocking, and
//! request deadlines turn unbounded waits into `DeadlineExceeded`.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{anyhow, Result, SharpError};
use crate::runtime::RuntimeConfig;

use super::adaptive::AdaptiveConfig;
use super::batcher::BatcherConfig;
use super::faults::FaultPlan;
use super::metrics::Metrics;
use super::request::{InferenceRequest, InferenceResponse};
use super::routing;
use super::session::SessionState;
use super::worker::{self, Obituary, WorkerHandle, WorkerMsg};

/// What `submit` does when the pool is saturated.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OverloadPolicy {
    /// Block until the pool makes room (backpressure; never drop).
    /// The pre-fault-tolerance behavior and the default.
    #[default]
    Block,
    /// Shed the newest request with a typed `Overloaded` once queue
    /// depth reaches the watermark (`ServerConfig::shed_watermark`).
    Shed,
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Artifact directory (`artifacts/` by default, or $SHARP_ARTIFACTS).
    pub artifact_dir: Option<PathBuf>,
    /// Hidden dims to serve — every worker replica hosts all of them.
    pub hidden: Vec<usize>,
    /// Worker replicas (each owns its own store, executables, batchers,
    /// sessions, and metrics).
    pub workers: usize,
    /// Bounded per-worker queue: when full, dispatch parks and
    /// ultimately blocks (Block) or sheds (Shed) instead of dropping.
    pub queue_cap: usize,
    /// Seed batching policy per bucket (the adaptive controller tunes it
    /// from there, within its SLA bounds).
    pub batcher: BatcherConfig,
    /// Adaptive batching bounds (SLA ceiling, wait floor, smoothing).
    pub adaptive: AdaptiveConfig,
    /// MAC budget for the attached SHARP cycle-time estimates.
    pub accel_macs: u64,
    /// LRU cap on live streaming sessions, per worker and hidden dim.
    pub max_sessions: usize,
    /// Hard bound on lanes per fused streaming window (the step-fusion
    /// dispatcher batches up to this many concurrent sessions into one
    /// step-major kernel run; the adaptive controller decides how many
    /// to actually wait for, capped here). Lanes are kernel GEMM rows,
    /// not artifact batch slots, so this may exceed any bucket's B.
    pub max_fused_lanes: usize,
    /// Kernel knobs applied to every executable the workers bind:
    /// per-GEMM thread fan-out plus the plan mode (`--plan
    /// auto|calibrated|fixed`) each bucket resolves its kernel geometry
    /// and schedule with — planning runs once per bucket at worker
    /// startup and the chosen plans surface in `Server::metrics()`.
    /// Default keeps kernels serial — with N worker replicas the pool
    /// already uses N cores; raise `threads` only when cores outnumber
    /// workers and batches are large.
    pub runtime: RuntimeConfig,
    /// Saturation behavior of `submit` (`--overload block|shed`).
    pub overload: OverloadPolicy,
    /// Queue-depth watermark for `OverloadPolicy::Shed`; `None` =
    /// `workers * queue_cap` (the pool's total in-queue capacity).
    pub shed_watermark: Option<usize>,
    /// Heartbeat-lag threshold marking a worker `unresponsive`; at 2×
    /// this lag the supervisor gives up on the incarnation and respawns
    /// the slot. Idle workers beat at least every 50 ms, so anything
    /// well above that works; keep it above the longest legitimate
    /// single-batch execution time.
    pub watchdog: Duration,
    /// Deterministic fault-injection schedule (tests / `--faults`).
    /// `None` falls back to the `SHARP_FAULTS` env var at `start`;
    /// production runs leave both unset and pay nothing on the hot path.
    pub faults: Option<FaultPlan>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            artifact_dir: None,
            hidden: vec![256],
            workers: 1,
            queue_cap: 64,
            batcher: BatcherConfig::default(),
            adaptive: AdaptiveConfig::default(),
            accel_macs: 4096,
            max_sessions: 4096,
            max_fused_lanes: 64,
            runtime: RuntimeConfig::default(),
            overload: OverloadPolicy::Block,
            shed_watermark: None,
            watchdog: Duration::from_secs(2),
            faults: None,
        }
    }
}

enum Msg {
    Request(InferenceRequest, worker::Reply),
    Begin {
        session: u64,
        hidden: usize,
        reply: Sender<Result<(), SharpError>>,
    },
    End {
        session: u64,
        reply: Sender<Option<SessionState>>,
    },
    Snapshot(Sender<Metrics>),
    /// Fence every live streaming session on every healthy worker (the
    /// `End` semantics applied pool-wide); replies with the count ended.
    FenceAll(Sender<usize>),
    Shutdown,
}

/// Handle to a running server (supervisor + worker pool).
pub struct Server {
    tx: SyncSender<Msg>,
    dispatcher: Option<JoinHandle<()>>,
    /// Per-slot queue gauges (stable across respawns) — the shed
    /// policy's admission check reads them without a channel hop.
    depths: Vec<Arc<AtomicUsize>>,
    /// Requests shed at admission (client-side; merged into snapshots).
    shed: Arc<AtomicU64>,
    overload: OverloadPolicy,
    watermark: usize,
}

impl Server {
    /// Start the pool: spawn every worker (each opens its own store and
    /// compiles its buckets before reporting ready), then the
    /// supervising dispatcher. Thread-spawn failures and worker build
    /// failures surface as `Err`, never a panic.
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        let mut cfg = cfg;
        if cfg.workers == 0 {
            return Err(anyhow!("server needs at least one worker"));
        }
        if cfg.hidden.is_empty() {
            return Err(anyhow!("server needs at least one hidden dim"));
        }
        if cfg.faults.is_none() {
            cfg.faults = FaultPlan::from_env()?;
        }
        // Spawn every worker first, then wait for all of them: startup
        // (store open + bucket compiles) runs in parallel across the
        // pool instead of serializing per replica.
        let (obit_tx, obit_rx) = mpsc::channel::<Obituary>();
        let mut handles = Vec::with_capacity(cfg.workers);
        let mut readies = Vec::with_capacity(cfg.workers);
        for i in 0..cfg.workers {
            let depth = Arc::new(AtomicUsize::new(0));
            match worker::spawn(cfg.clone(), i, 0, depth, obit_tx.clone()) {
                Ok((h, ready)) => {
                    handles.push(h);
                    readies.push(ready);
                }
                Err(e) => {
                    shutdown_handles(&mut handles);
                    return Err(e.context(format!("spawning worker {i}")));
                }
            }
        }
        for (i, ready) in readies.into_iter().enumerate() {
            let r = ready
                .recv()
                .map_err(|_| anyhow!("worker {i} died during startup"))
                .and_then(|r| r.map_err(|e| anyhow!("worker {i}: {e}")));
            if let Err(e) = r {
                shutdown_handles(&mut handles);
                return Err(e);
            }
        }
        let queue_cap = cfg.queue_cap.max(1);
        let depths: Vec<Arc<AtomicUsize>> = handles.iter().map(|h| h.depth.clone()).collect();
        let overload = cfg.overload;
        let watermark = cfg
            .shed_watermark
            .unwrap_or(cfg.workers * queue_cap)
            .max(1);
        // Bounded ingress sized to the pool: when every worker queue is
        // full AND this buffer fills, submit() itself blocks (Block) or
        // sheds (Shed) — the backpressure reaches the producer instead
        // of buffering requests without bound.
        let (tx, rx) = mpsc::sync_channel::<Msg>(cfg.workers * queue_cap);
        let dispatcher = std::thread::Builder::new()
            .name("sharp-dispatcher".into())
            .spawn(move || dispatch_loop(rx, cfg, handles, obit_tx, obit_rx, queue_cap, watermark))
            .map_err(|e| anyhow!("spawn dispatcher thread: {e}"))?;
        Ok(Server {
            tx,
            dispatcher: Some(dispatcher),
            depths,
            shed: Arc::new(AtomicU64::new(0)),
            overload,
            watermark,
        })
    }

    /// Submit a request; returns the channel the response arrives on.
    /// Every submitted request RESOLVES — a reply, or a typed
    /// [`SharpError`]. Under `OverloadPolicy::Block` a saturated pool
    /// blocks this call (backpressure, never a drop); under `Shed` it
    /// resolves immediately with `Overloaded` once queue depth passes
    /// the watermark or the ingress buffer is full.
    pub fn submit(
        &self,
        req: InferenceRequest,
    ) -> Receiver<Result<InferenceResponse, SharpError>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        if self.overload == OverloadPolicy::Shed {
            let depth: usize = self.depths.iter().map(|d| d.load(Ordering::Relaxed)).sum();
            if depth >= self.watermark {
                self.shed.fetch_add(1, Ordering::Relaxed);
                let _ = reply_tx.send(Err(SharpError::Overloaded {
                    depth,
                    watermark: self.watermark,
                }));
                return reply_rx;
            }
            match self.tx.try_send(Msg::Request(req, reply_tx)) {
                Ok(()) => {}
                Err(TrySendError::Full(Msg::Request(_, tx))) => {
                    self.shed.fetch_add(1, Ordering::Relaxed);
                    let _ = tx.send(Err(SharpError::Overloaded {
                        depth,
                        watermark: self.watermark,
                    }));
                }
                // Disconnected (or a non-Request bounce, which cannot
                // happen): the dropped reply sender closes the channel,
                // which the caller sees as WorkerFailed.
                Err(_) => {}
            }
            return reply_rx;
        }
        // A send failure means the dispatcher is gone; the caller sees
        // it as a closed reply channel.
        let _ = self.tx.send(Msg::Request(req, reply_tx));
        reply_rx
    }

    /// Submit and wait for the typed outcome. Honors the request's
    /// deadline client-side too: if no reply lands within the remaining
    /// budget the wait ends with `DeadlineExceeded` (whatever reply
    /// arrives later is dropped unread). A reply channel that closes
    /// without a verdict — a worker died holding the request and the
    /// salvage missed it — is `WorkerFailed`, not a hang.
    pub fn try_infer(&self, req: InferenceRequest) -> Result<InferenceResponse, SharpError> {
        let enqueued = req.enqueued_at;
        let budget = req.remaining();
        let rx = self.submit(req);
        let closed = || SharpError::WorkerFailed {
            worker: None,
            reason: "reply channel closed before a verdict".into(),
        };
        match budget {
            Some(budget) => match rx.recv_timeout(budget) {
                Ok(r) => r,
                Err(RecvTimeoutError::Timeout) => Err(SharpError::DeadlineExceeded {
                    waited_ms: enqueued.elapsed().as_millis() as u64,
                }),
                Err(RecvTimeoutError::Disconnected) => Err(closed()),
            },
            None => match rx.recv() {
                Ok(r) => r,
                Err(_) => Err(closed()),
            },
        }
    }

    /// Submit and block for the response ([`Self::try_infer`] flattened
    /// into the crate-wide `Result` for operator-facing callers).
    pub fn infer(&self, req: InferenceRequest) -> Result<InferenceResponse> {
        Ok(self.try_infer(req)?)
    }

    /// Open a streaming session on a hidden dim: zero (h, c) is staged on
    /// the owning worker. Chunks may also open sessions implicitly; this
    /// validates the dim up front.
    pub fn begin_session(&self, session: u64, hidden: usize) -> Result<()> {
        Ok(self.try_begin_session(session, hidden)?)
    }

    /// [`Self::begin_session`] with the typed verdict preserved — the TCP
    /// front-end maps `SharpError` variants onto wire error codes, so it
    /// must not lose them to a stringly error.
    pub fn try_begin_session(&self, session: u64, hidden: usize) -> Result<(), SharpError> {
        let closed = || SharpError::WorkerFailed {
            worker: None,
            reason: "server terminated".into(),
        };
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Begin {
                session,
                hidden,
                reply,
            })
            .map_err(|_| closed())?;
        rx.recv().map_err(|_| closed())?
    }

    /// Stream one chunk through a session: routes to the session's owner
    /// worker, executes with the carried (h, c), persists the new carry.
    /// The response's `h_t` is the state at the chunk's last frame.
    pub fn chunk(
        &self,
        session: u64,
        id: u64,
        seq_len: usize,
        payload: Vec<f32>,
    ) -> Result<InferenceResponse> {
        self.infer(InferenceRequest::new(id, seq_len, payload).with_session(session))
    }

    /// Close a streaming session, returning its final state (None if the
    /// session never existed or was LRU-evicted).
    pub fn end_session(&self, session: u64) -> Result<Option<SessionState>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::End { session, reply })
            .map_err(|_| anyhow!("server terminated"))?;
        rx.recv().map_err(|_| anyhow!("server terminated"))
    }

    /// Merged metrics snapshot across all workers, plus the
    /// supervisor's per-replica health gauge (`worker_health`) and
    /// fault/recovery counters. A replica that cannot report — dead,
    /// respawning, or heartbeat-stalled — is marked (`"dead"` /
    /// `"respawning"` / `"unresponsive"`) instead of silently shrinking
    /// the counts, and its last known metrics (captured in its
    /// obituary) are already folded in.
    pub fn metrics(&self) -> Result<Metrics> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Snapshot(reply))
            .map_err(|_| anyhow!("server terminated"))?;
        let mut m = rx.recv().map_err(|_| anyhow!("server terminated"))?;
        m.shed += self.shed.load(Ordering::Relaxed);
        Ok(m)
    }

    /// Fence every live streaming session across the pool: each worker
    /// first executes any chunks already parked in its fuse queues (the
    /// `End` fence semantics from the streaming PR), then drops the
    /// session carries. Returns how many sessions were ended. This is
    /// the single "sessions fence" step both teardown paths share —
    /// [`Self::shutdown`] and the TCP listener's graceful drain — so an
    /// in-process exit and a control-plane drain cannot diverge.
    pub fn fence_sessions(&self) -> Result<usize> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::FenceAll(reply))
            .map_err(|_| anyhow!("server terminated"))?;
        rx.recv().map_err(|_| anyhow!("server terminated"))
    }

    /// Stop the pool: fence live streaming sessions, then drain pending
    /// batches and join every thread. The same ordered teardown the TCP
    /// listener's drain uses (stop accepting → fence sessions → pool
    /// shutdown); here the "stop accepting" step is the caller giving up
    /// ownership of the handle.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if self.dispatcher.is_some() {
            // Shared ordered-teardown step: fence sessions BEFORE the
            // pool stops, exactly like the listener drain path. Ignore
            // the count (and a dispatcher that already exited).
            let _ = self.fence_sessions();
        }
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn shutdown_handles(handles: &mut Vec<WorkerHandle>) {
    for h in handles.iter() {
        let _ = h.tx.send(WorkerMsg::Shutdown);
    }
    for h in handles.drain(..) {
        let _ = h.join.join();
    }
}

/// Slot health as the supervisor sees it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Health {
    /// Live incarnation, heartbeat fresh (or merely `stalled`-flagged).
    Healthy,
    /// Incarnation died or was detached; a replacement is building.
    Respawning,
    /// Respawn permanently failed (attempt cap); traffic is refused.
    Failed,
}

/// One worker slot: the stable identity (index, depth gauge, parked
/// traffic) that survives incarnation deaths.
struct WorkerSlot {
    index: usize,
    /// Stable queue gauge, shared with every incarnation.
    depth: Arc<AtomicUsize>,
    handle: Option<WorkerHandle>,
    health: Health,
    /// Heartbeat lag crossed `watchdog` (but not yet 2×): reported as
    /// `unresponsive`, excluded from snapshot waits.
    stalled: bool,
    /// Messages awaiting this slot, in order: salvage from a dead
    /// incarnation (front), then everything routed here while the
    /// replacement builds or the live queue is full.
    parked: VecDeque<WorkerMsg>,
    /// Evacuated session carries to re-seat right after the next ready,
    /// BEFORE any parked traffic replays.
    restores: Vec<WorkerMsg>,
    /// Readiness channel of a building incarnation.
    ready: Option<Receiver<std::result::Result<(), String>>>,
    /// Consecutive failed respawn attempts (reset on ready).
    attempts: u32,
    generation: u64,
}

/// Consecutive respawn failures before a slot is declared Failed.
const RESPAWN_ATTEMPTS: u32 = 3;

impl WorkerSlot {
    fn effective_depth(&self, queue_cap: usize) -> usize {
        match self.health {
            Health::Failed => usize::MAX,
            // Saturating: parked is bounded (2*queue_cap) so this never
            // actually saturates, but stay total.
            _ => self
                .depth
                .load(Ordering::Relaxed)
                .saturating_add(self.parked.len())
                .saturating_add(if self.stalled { queue_cap } else { 0 }),
        }
    }

    /// Deliver or park. Returns the message back only when it cannot
    /// even be parked (parked queue at cap) — the caller then blocks
    /// ingress (Block) or sheds typed (Shed).
    fn try_deliver(&mut self, msg: WorkerMsg, park_cap: usize) -> Option<WorkerMsg> {
        // Order preservation: while anything is parked, new messages
        // queue behind it; direct sends resume once parked drains.
        if self.health != Health::Healthy || !self.parked.is_empty() || self.handle.is_none() {
            if self.parked.len() >= park_cap {
                return Some(msg);
            }
            self.parked.push_back(msg);
            return None;
        }
        let Some(h) = &self.handle else {
            return Some(msg);
        };
        self.depth.fetch_add(1, Ordering::Relaxed);
        match h.tx.try_send(msg) {
            Ok(()) => None,
            Err(TrySendError::Full(m)) | Err(TrySendError::Disconnected(m)) => {
                // Full: worker busy — park instead of blocking the
                // supervisor. Disconnected: incarnation died; the
                // liveness poll respawns it and replays parked.
                self.depth.fetch_sub(1, Ordering::Relaxed);
                if self.parked.len() >= park_cap {
                    return Some(m);
                }
                self.parked.push_back(m);
                None
            }
        }
    }

    /// Replay parked messages into the live incarnation, in order,
    /// until the queue fills again.
    fn flush_parked(&mut self) {
        if self.health != Health::Healthy {
            return;
        }
        while let Some(msg) = self.parked.pop_front() {
            let Some(h) = &self.handle else {
                self.parked.push_front(msg);
                return;
            };
            self.depth.fetch_add(1, Ordering::Relaxed);
            match h.tx.try_send(msg) {
                Ok(()) => {}
                Err(TrySendError::Full(m)) | Err(TrySendError::Disconnected(m)) => {
                    self.depth.fetch_sub(1, Ordering::Relaxed);
                    self.parked.push_front(m);
                    return;
                }
            }
        }
    }

    /// Refuse everything parked (typed), for a Failed slot.
    fn fail_parked(&mut self) {
        let reason = format!("worker {} permanently failed", self.index);
        for msg in self.parked.drain(..) {
            refuse(msg, Some(self.index), &reason);
        }
        self.restores.clear();
    }

    fn health_label(&self) -> &'static str {
        match self.health {
            Health::Failed => "dead",
            Health::Respawning => "respawning",
            Health::Healthy if self.stalled => "unresponsive",
            Health::Healthy => "ok",
        }
    }
}

/// Resolve an undeliverable message with a typed refusal instead of
/// dropping its reply channel cold.
fn refuse(msg: WorkerMsg, worker: Option<usize>, reason: &str) {
    let failure = || SharpError::WorkerFailed {
        worker,
        reason: reason.to_string(),
    };
    match msg {
        WorkerMsg::Request(_, reply) => {
            let _ = reply.send(Err(failure()));
        }
        WorkerMsg::Begin { reply, .. } => {
            let _ = reply.send(Err(failure()));
        }
        WorkerMsg::End { reply, .. } => {
            let _ = reply.send(None);
        }
        WorkerMsg::FenceAll(reply) => {
            // A refused fence ended nothing on this worker.
            let _ = reply.send(0);
        }
        WorkerMsg::Restore { .. } | WorkerMsg::Snapshot(_) | WorkerMsg::Shutdown => {}
    }
}

/// Begin a replacement incarnation for a slot (or declare it Failed
/// once the attempt budget is spent).
fn start_respawn(slot: &mut WorkerSlot, cfg: &ServerConfig, obit_tx: &Sender<Obituary>) {
    if slot.attempts >= RESPAWN_ATTEMPTS {
        slot.health = Health::Failed;
        slot.fail_parked();
        return;
    }
    slot.attempts += 1;
    slot.generation += 1;
    match worker::spawn(
        cfg.clone(),
        slot.index,
        slot.generation,
        slot.depth.clone(),
        obit_tx.clone(),
    ) {
        Ok((h, ready)) => {
            slot.handle = Some(h);
            slot.ready = Some(ready);
            slot.health = Health::Respawning;
            slot.stalled = false;
        }
        Err(_) => {
            // Thread spawn itself failed (resource exhaustion): count
            // the attempt and let the next supervision pass retry.
            slot.handle = None;
            slot.ready = None;
            slot.health = Health::Respawning;
        }
    }
}

/// Intake one obituary: fold the dead incarnation's metrics into the
/// accumulator; for the CURRENT generation also reclaim its salvaged
/// queue (replayed before anything parked later) and convert its
/// evacuated carries into Restore messages. Stale generations — a
/// detached stall victim that panicked after replacement — contribute
/// metrics only: their session payloads are outdated and must not
/// clobber the successor's live carries (those sessions already
/// restarted, loudly).
fn handle_obituary(slot: &mut WorkerSlot, lost: &mut Metrics, obit: Obituary) {
    lost.merge(&obit.metrics);
    if obit.generation != slot.generation {
        for msg in obit.salvaged {
            refuse(
                msg,
                Some(slot.index),
                "worker incarnation was already replaced",
            );
        }
        return;
    }
    // Salvage goes to the FRONT: it was in flight before anything that
    // parked after the death. Bounded by queue_cap, so no runaway.
    for msg in obit.salvaged.into_iter().rev() {
        slot.parked.push_front(msg);
    }
    for (hidden, session, state) in obit.flat_sessions {
        lost.recovered_sessions += 1;
        slot.restores.push(WorkerMsg::Restore {
            hidden: Some(hidden),
            model: None,
            session,
            state,
        });
    }
    for (name, session, state) in obit.stack_sessions {
        lost.recovered_sessions += 1;
        slot.restores.push(WorkerMsg::Restore {
            hidden: None,
            model: Some(name),
            session,
            state,
        });
    }
}

fn drain_obits(obit_rx: &Receiver<Obituary>, slots: &mut [WorkerSlot], lost: &mut Metrics) {
    while let Ok(obit) = obit_rx.try_recv() {
        let idx = obit.index;
        if idx < slots.len() {
            handle_obituary(&mut slots[idx], lost, obit);
        }
    }
}

/// One supervision pass over a slot: liveness flag, heartbeat watchdog,
/// respawn kickoff, ready polling, restore + parked replay.
fn supervise_slot(
    slot: &mut WorkerSlot,
    cfg: &ServerConfig,
    obit_tx: &Sender<Obituary>,
    lost: &mut Metrics,
    now: Instant,
) {
    match slot.health {
        Health::Failed => return,
        Health::Respawning => {
            // A respawn whose thread-spawn itself failed retries here.
            if slot.handle.is_none() && slot.ready.is_none() {
                start_respawn(slot, cfg, obit_tx);
                return;
            }
            let outcome = match &slot.ready {
                Some(ready) => match ready.try_recv() {
                    Ok(r) => Some(r),
                    Err(mpsc::TryRecvError::Empty) => None,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        Some(Err("worker died before reporting ready".to_string()))
                    }
                },
                None => None,
            };
            match outcome {
                None => {}
                Some(Ok(())) => {
                    slot.ready = None;
                    slot.health = Health::Healthy;
                    slot.attempts = 0;
                    lost.respawns += 1;
                    // Re-seat evacuated carries FIRST (blocking send:
                    // the incarnation just signaled ready and its
                    // queue is empty), then replay parked traffic so a
                    // parked chunk finds its carry in place.
                    let restores: Vec<WorkerMsg> = slot.restores.drain(..).collect();
                    for msg in restores {
                        if let Some(h) = &slot.handle {
                            slot.depth.fetch_add(1, Ordering::Relaxed);
                            if h.tx.send(msg).is_err() {
                                slot.depth.fetch_sub(1, Ordering::Relaxed);
                            }
                        }
                    }
                    slot.flush_parked();
                }
                Some(Err(_)) => {
                    slot.ready = None;
                    if let Some(h) = slot.handle.take() {
                        let _ = h.join.join();
                    }
                    start_respawn(slot, cfg, obit_tx);
                }
            }
        }
        Health::Healthy => {
            let Some(h) = &slot.handle else {
                start_respawn(slot, cfg, obit_tx);
                return;
            };
            if !h.alive.load(Ordering::Acquire) {
                // Death is handled by the dispatch loop's liveness scan
                // (it re-drains obituaries first so the generation check
                // sees the death as current); nothing to do here.
                return;
            }
            let lag = h.heartbeat_lag(now);
            if lag >= cfg.watchdog.saturating_mul(2) {
                // Stalled past patience: DETACH the incarnation (std
                // threads cannot be killed) and rebuild the slot. The
                // old thread still owns its queue; when (if) it
                // resumes it drains those messages, replies, and exits
                // on disconnect. Its sessions restart on the
                // replacement — the loud steps==1 signal, never a
                // silently wrong carry.
                slot.handle = None;
                start_respawn(slot, cfg, obit_tx);
            } else {
                slot.stalled = lag >= cfg.watchdog;
            }
            // Replay anything parked by a transiently full queue.
            if !slot.parked.is_empty() {
                slot.flush_parked();
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn dispatch_loop(
    rx: Receiver<Msg>,
    cfg: ServerConfig,
    handles: Vec<WorkerHandle>,
    obit_tx: Sender<Obituary>,
    obit_rx: Receiver<Obituary>,
    queue_cap: usize,
    watermark: usize,
) {
    let n = handles.len();
    let mut slots: Vec<WorkerSlot> = handles
        .into_iter()
        .enumerate()
        .map(|(index, h)| WorkerSlot {
            index,
            depth: h.depth.clone(),
            handle: Some(h),
            health: Health::Healthy,
            stalled: false,
            parked: VecDeque::new(),
            restores: Vec::new(),
            ready: None,
            attempts: 0,
            generation: 0,
        })
        .collect();
    let mut rr = 0usize;
    // Scratch for queue depths, reused across requests — the routing
    // decision stays allocation-free on the hot path.
    let mut depths = vec![0usize; n];
    // Dead-worker residue: obituary metrics, respawn/recovery/shed
    // counters. Cloned as the base of every snapshot.
    let mut lost = Metrics::default();
    // Cap on parked messages per slot; past it, Block holds ingress
    // (bounded memory + backpressure) and Shed refuses typed.
    let park_cap = 2 * queue_cap;
    // Supervision runs on a short cadence, not per message: the no-fault
    // hot path pays one Instant compare per ingress message.
    let mut last_supervise = Instant::now();
    let supervise_every = Duration::from_millis(10);
    // Block-policy head-of-line holdback: a message whose slot cannot
    // even park it. While held, ingress is not pulled.
    let mut held: Option<(usize, WorkerMsg)> = None;
    loop {
        // Obituaries first: a dead incarnation's salvage must land in
        // the parked queue before any later traffic is routed.
        drain_obits(&obit_rx, &mut slots, &mut lost);
        let now = Instant::now();
        if held.is_some() || now.duration_since(last_supervise) >= supervise_every {
            last_supervise = now;
            // Liveness scan. A worker that exited without Shutdown
            // panicked, and it sent its obituary BEFORE clearing
            // `alive` (worker.rs) — so after acquiring a false flag,
            // one more drain is guaranteed to retrieve that obituary
            // under the CURRENT generation. Only then respawn (which
            // bumps the generation and would otherwise misread the
            // pending obituary as stale, dropping its carries).
            let any_dead = slots.iter().any(|s| {
                s.health == Health::Healthy
                    && s.handle
                        .as_ref()
                        .is_some_and(|h| !h.alive.load(Ordering::Acquire))
            });
            if any_dead {
                drain_obits(&obit_rx, &mut slots, &mut lost);
                for slot in slots.iter_mut() {
                    let dead = slot.health == Health::Healthy
                        && slot
                            .handle
                            .as_ref()
                            .is_some_and(|h| !h.alive.load(Ordering::Acquire));
                    if dead {
                        if let Some(h) = slot.handle.take() {
                            let _ = h.join.join();
                        }
                        start_respawn(slot, &cfg, &obit_tx);
                    }
                }
            }
            for slot in slots.iter_mut() {
                supervise_slot(slot, &cfg, &obit_tx, &mut lost, now);
            }
        }
        // Retry the held message before pulling anything new.
        if let Some((w, msg)) = held.take() {
            if slots[w].health == Health::Failed {
                refuse(msg, Some(w), "worker permanently failed");
            } else if let Some(msg) = slots[w].try_deliver(msg, park_cap) {
                held = Some((w, msg));
                // Still stuck: let the worker drain / the respawn
                // finish instead of spinning.
                std::thread::park_timeout(Duration::from_millis(1));
                continue;
            }
        }
        // Ingress. The timeout doubles as the supervision tick when
        // traffic is idle.
        let msg = match rx.recv_timeout(supervise_every) {
            Ok(m) => m,
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => break,
        };
        match msg {
            Msg::Request(req, reply) => {
                let w = match req.session {
                    // Affinity: the owner worker holds the (h, c) carry.
                    Some(sid) => routing::session_worker(sid, n),
                    None => {
                        for (d, s) in depths.iter_mut().zip(&slots) {
                            *d = s.effective_depth(queue_cap);
                        }
                        let w = routing::plan_dispatch(&depths, queue_cap, rr);
                        rr = (w + 1) % n;
                        w
                    }
                };
                if slots[w].health == Health::Failed {
                    // Stateless traffic can fail over to a sibling;
                    // session traffic cannot leave its owner (the carry
                    // lived there) and is refused typed.
                    let fallback = req.session.is_none() && !all_failed(&slots);
                    if fallback {
                        for (d, s) in depths.iter_mut().zip(&slots) {
                            *d = s.effective_depth(queue_cap);
                        }
                        let w2 = routing::plan_dispatch(&depths, queue_cap, rr);
                        rr = (w2 + 1) % n;
                        deliver_or_hold(
                            &mut slots,
                            w2,
                            WorkerMsg::Request(req, reply),
                            park_cap,
                            cfg.overload,
                            watermark,
                            queue_cap,
                            &mut lost,
                            &mut held,
                        );
                    } else {
                        refuse(
                            WorkerMsg::Request(req, reply),
                            Some(w),
                            "worker permanently failed",
                        );
                    }
                } else {
                    deliver_or_hold(
                        &mut slots,
                        w,
                        WorkerMsg::Request(req, reply),
                        park_cap,
                        cfg.overload,
                        watermark,
                        queue_cap,
                        &mut lost,
                        &mut held,
                    );
                }
            }
            Msg::Begin {
                session,
                hidden,
                reply,
            } => {
                let w = routing::session_worker(session, n);
                let msg = WorkerMsg::Begin {
                    session,
                    hidden,
                    reply,
                };
                if slots[w].health == Health::Failed {
                    refuse(msg, Some(w), "worker permanently failed");
                } else {
                    deliver_or_hold(
                        &mut slots,
                        w,
                        msg,
                        park_cap,
                        cfg.overload,
                        watermark,
                        queue_cap,
                        &mut lost,
                        &mut held,
                    );
                }
            }
            Msg::End { session, reply } => {
                let w = routing::session_worker(session, n);
                let msg = WorkerMsg::End { session, reply };
                if slots[w].health == Health::Failed {
                    refuse(msg, Some(w), "worker permanently failed");
                } else {
                    deliver_or_hold(
                        &mut slots,
                        w,
                        msg,
                        park_cap,
                        cfg.overload,
                        watermark,
                        queue_cap,
                        &mut lost,
                        &mut held,
                    );
                }
            }
            Msg::Snapshot(reply) => {
                let merged = snapshot(&slots, &lost, &cfg);
                let _ = reply.send(merged);
            }
            Msg::FenceAll(reply) => {
                let fenced = fence_all(&slots, &cfg);
                let _ = reply.send(fenced);
            }
            Msg::Shutdown => break,
        }
    }
    // Shutdown: replay what can still be delivered (blocking — workers
    // are draining toward exit), refuse the rest typed, then stop the
    // pool.
    for slot in slots.iter_mut() {
        if slot.health == Health::Healthy {
            if let Some(h) = &slot.handle {
                for msg in slot.parked.drain(..) {
                    slot.depth.fetch_add(1, Ordering::Relaxed);
                    if h.tx.send(msg).is_err() {
                        slot.depth.fetch_sub(1, Ordering::Relaxed);
                    }
                }
            }
        } else {
            slot.fail_parked();
        }
    }
    let mut handles: Vec<WorkerHandle> = slots.iter_mut().filter_map(|s| s.handle.take()).collect();
    shutdown_handles(&mut handles);
}

fn all_failed(slots: &[WorkerSlot]) -> bool {
    slots.iter().all(|s| s.health == Health::Failed)
}

/// Deliver to a (non-Failed) slot, parking as needed. A message the
/// slot cannot even park becomes backpressure (Block: held, ingress
/// pauses) or a typed shed (Shed).
#[allow(clippy::too_many_arguments)]
fn deliver_or_hold(
    slots: &mut [WorkerSlot],
    w: usize,
    msg: WorkerMsg,
    park_cap: usize,
    overload: OverloadPolicy,
    watermark: usize,
    queue_cap: usize,
    lost: &mut Metrics,
    held: &mut Option<(usize, WorkerMsg)>,
) {
    if let Some(msg) = slots[w].try_deliver(msg, park_cap) {
        match overload {
            OverloadPolicy::Shed => {
                lost.shed += 1;
                let depth = slots[w].effective_depth(queue_cap);
                match msg {
                    WorkerMsg::Request(_, reply) => {
                        let _ = reply.send(Err(SharpError::Overloaded { depth, watermark }));
                    }
                    other => refuse(other, Some(w), "worker queue saturated"),
                }
            }
            OverloadPolicy::Block => {
                *held = Some((w, msg));
            }
        }
    }
}

/// Assemble one snapshot: the lost-metrics base (dead incarnations'
/// history + supervisor counters), live workers' metrics, and the
/// per-slot health gauge. Only fresh-heartbeat Healthy workers are
/// polled; anything that cannot report is LABELED, never silently
/// omitted (the old 5s-timeout-then-partial behavior).
fn snapshot(slots: &[WorkerSlot], lost: &Metrics, cfg: &ServerConfig) -> Metrics {
    let mut merged = lost.clone();
    let mut receivers: Vec<(usize, Receiver<Metrics>)> = Vec::with_capacity(slots.len());
    for slot in slots {
        merged
            .worker_health
            .insert(format!("worker{}", slot.index), slot.health_label().into());
        if slot.health == Health::Healthy && !slot.stalled && slot.parked.is_empty() {
            if let Some(h) = &slot.handle {
                let (tx2, rx2) = mpsc::channel();
                slot.depth.fetch_add(1, Ordering::Relaxed);
                match h.tx.send(WorkerMsg::Snapshot(tx2)) {
                    Ok(()) => receivers.push((slot.index, rx2)),
                    Err(_) => {
                        slot.depth.fetch_sub(1, Ordering::Relaxed);
                        merged
                            .worker_health
                            .insert(format!("worker{}", slot.index), "unresponsive".into());
                    }
                }
            }
        }
    }
    // Workers park at most 50 ms between messages; the timeout guards a
    // worker that stalls AFTER the health check above.
    let patience = cfg.watchdog.clamp(Duration::from_millis(100), Duration::from_secs(5));
    for (index, rx2) in receivers {
        match rx2.recv_timeout(patience) {
            Ok(m) => merged.merge(&m),
            Err(_) => {
                merged
                    .worker_health
                    .insert(format!("worker{index}"), "unresponsive".into());
            }
        }
    }
    merged
}

/// Fence live sessions on every worker that can take the message (the
/// same eligibility rule as [`snapshot`]: Healthy, fresh heartbeat,
/// nothing parked in front that would reorder the fence). Workers that
/// cannot be fenced are respawning or failed — their sessions restart
/// loudly anyway (`steps == 1`), which is the documented lost-carry
/// signal, never a silent corruption.
fn fence_all(slots: &[WorkerSlot], cfg: &ServerConfig) -> usize {
    let mut receivers: Vec<Receiver<usize>> = Vec::with_capacity(slots.len());
    for slot in slots {
        if slot.health == Health::Healthy && !slot.stalled && slot.parked.is_empty() {
            if let Some(h) = &slot.handle {
                let (tx2, rx2) = mpsc::channel();
                slot.depth.fetch_add(1, Ordering::Relaxed);
                match h.tx.send(WorkerMsg::FenceAll(tx2)) {
                    Ok(()) => receivers.push(rx2),
                    Err(_) => {
                        slot.depth.fetch_sub(1, Ordering::Relaxed);
                    }
                }
            }
        }
    }
    let patience = cfg.watchdog.clamp(Duration::from_millis(100), Duration::from_secs(5));
    receivers
        .into_iter()
        .map(|rx2| rx2.recv_timeout(patience).unwrap_or(0))
        .sum()
}
