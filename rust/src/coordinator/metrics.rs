//! Serving metrics: latency distribution, throughput, batch-size mix.

use std::time::Instant;

use crate::util::stats::Samples;

/// Aggregated serving metrics for one run.
#[derive(Debug)]
pub struct Metrics {
    pub latency_s: Samples,
    pub accel_time_s: Samples,
    pub batch_sizes: Samples,
    pub completed: u64,
    pub errors: u64,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            latency_s: Samples::new(),
            accel_time_s: Samples::new(),
            batch_sizes: Samples::new(),
            completed: 0,
            errors: 0,
            started: Instant::now(),
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, latency_s: f64, accel_time_s: f64, batch: usize) {
        self.latency_s.push(latency_s);
        self.accel_time_s.push(accel_time_s);
        self.batch_sizes.push(batch as f64);
        self.completed += 1;
    }

    pub fn record_error(&mut self) {
        self.errors += 1;
    }

    /// Requests/second since construction.
    pub fn throughput_rps(&self) -> f64 {
        let dt = self.started.elapsed().as_secs_f64();
        if dt <= 0.0 {
            0.0
        } else {
            self.completed as f64 / dt
        }
    }

    /// Render the standard serving report block.
    pub fn render(&mut self) -> String {
        format!(
            "requests={} errors={} throughput={:.1} rps\n\
             latency  p50={:.2}ms p95={:.2}ms p99={:.2}ms mean={:.2}ms\n\
             accel-est p50={:.1}us (SHARP cycle model)\n\
             batch    mean={:.2} max={:.0}",
            self.completed,
            self.errors,
            self.throughput_rps(),
            self.latency_s.p50() * 1e3,
            self.latency_s.p95() * 1e3,
            self.latency_s.p99() * 1e3,
            self.latency_s.mean() * 1e3,
            self.accel_time_s.p50() * 1e6,
            self.batch_sizes.mean(),
            self.batch_sizes.max(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_renders() {
        let mut m = Metrics::new();
        for i in 0..10 {
            m.record(0.001 * (i + 1) as f64, 1e-6, 4);
        }
        m.record_error();
        assert_eq!(m.completed, 10);
        assert_eq!(m.errors, 1);
        let s = m.render();
        assert!(s.contains("requests=10"));
        assert!(s.contains("p95"));
    }

    #[test]
    fn throughput_positive_after_work() {
        let mut m = Metrics::new();
        m.record(0.001, 1e-6, 1);
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(m.throughput_rps() > 0.0);
    }
}
