//! Serving metrics: latency distribution, throughput, batch-size mix.
//!
//! Each worker owns a `Metrics` outright — no lock on the record path.
//! The dispatcher merges per-worker clones into one snapshot on demand
//! (`Server::metrics`), so the only synchronization cost is a channel
//! round-trip when somebody actually asks.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::Samples;

/// Cap on retained samples per distribution: beyond it, new samples
/// overwrite the oldest (sliding window), so a long-lived worker holds
/// bounded memory and snapshot clones stay O(window) no matter how many
/// requests it has served. Counters (`completed`, `errors`) are exact.
pub const SAMPLE_WINDOW: usize = 1 << 16;

/// Aggregated serving metrics for one run (or one worker's share of it).
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub latency_s: Samples,
    pub accel_time_s: Samples,
    pub batch_sizes: Samples,
    pub completed: u64,
    pub errors: u64,
    /// Execution plan chosen per bucket executable (artifact name ->
    /// compact plan description, e.g. `mr4/nr16/unfolded`), recorded
    /// once at worker startup so a snapshot shows which configuration
    /// the planner picked for every served shape. Workers are replicas
    /// planning deterministically (Auto) or near-identically
    /// (Calibrated), so merge keeps the first description per bucket.
    pub plans: BTreeMap<String, String>,
    /// Recurrent steps executed inside a fused multi-lane window
    /// (per-step live occupancy > 1): the steps where the step-fusion
    /// dispatcher actually amortized the packed weight panels across
    /// sessions.
    pub fused_steps: u64,
    /// Recurrent steps executed at occupancy 1 — solo chunks and
    /// degenerate single-session windows.
    pub solo_steps: u64,
    /// Per-step fused-lane occupancy histogram: one sample per executed
    /// streaming step, value = live lanes at that step (1 = solo).
    /// Bounded by the same sliding window as the latency samples.
    pub lane_occupancy: Samples,
    /// Faults the injection harness fired (`coordinator/faults.rs`):
    /// panics + stalls actually triggered on workers. 0 in production.
    pub faults_injected: u64,
    /// Requests resolved with `DeadlineExceeded` (shed at worker dequeue
    /// or abandoned client-side past their budget).
    pub deadline_misses: u64,
    /// Requests refused at admission by the shed overload policy.
    pub shed: u64,
    /// Worker incarnations the supervisor respawned after a death.
    pub respawns: u64,
    /// Session carries evacuated from a dead worker and re-seated
    /// verbatim on its replacement (bit-exact stream continuations).
    pub recovered_sessions: u64,
    /// Supervisor's per-replica health gauge: `"worker<i>"` ->
    /// `"ok" | "respawning" | "unresponsive" | "dead"`. Written only by
    /// the supervisor at snapshot time, so merge overrides by key
    /// (worker-local metrics never carry health entries).
    pub worker_health: BTreeMap<String, String>,
    /// Connections the TCP listener accepted (v4; 0 when serving
    /// in-process only).
    pub conns_accepted: u64,
    /// Connections refused at accept by the connection cap (each one
    /// also answered with a retryable `Overloaded` wire verdict).
    pub conns_rejected: u64,
    /// Connections killed by the per-connection read/write deadline
    /// (slowloris peers, stalled links).
    pub conns_timed_out: u64,
    /// Connections closed by a graceful drain after their in-flight
    /// work was flushed.
    pub conns_drained: u64,
    /// Frames rejected as malformed (unknown tag, truncated or garbled
    /// body, oversized declaration).
    pub frames_malformed: u64,
    /// Client retry attempts observed on the wire (requests arriving
    /// with `attempt > 0` — the backoff pressure the fleet absorbed).
    pub retries_observed: u64,
    /// First/last recorded completion: throughput is measured over the
    /// span actually serving requests, not from construction (which
    /// would fold compile/startup time and any idle tail into the rate).
    first_record: Option<Instant>,
    last_record: Option<Instant>,
    /// Ring cursor once the sample window is full.
    cursor: usize,
    /// Ring cursor for the occupancy histogram (its own, because steps
    /// and requests are recorded at different rates).
    occ_cursor: usize,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, latency_s: f64, accel_time_s: f64, batch: usize) {
        let now = Instant::now();
        self.first_record.get_or_insert(now);
        self.last_record = Some(now);
        if self.latency_s.len() < SAMPLE_WINDOW {
            self.latency_s.push(latency_s);
            self.accel_time_s.push(accel_time_s);
            self.batch_sizes.push(batch as f64);
        } else {
            // Window full: overwrite in ring order. Percentiles then
            // describe (approximately — an interleaved percentile query
            // re-sorts the buffer, shuffling which slot is oldest) the
            // most recent SAMPLE_WINDOW requests; the memory bound is
            // exact either way.
            self.latency_s.replace(self.cursor, latency_s);
            self.accel_time_s.replace(self.cursor, accel_time_s);
            self.batch_sizes.replace(self.cursor, batch as f64);
            self.cursor = (self.cursor + 1) % SAMPLE_WINDOW;
        }
        self.completed += 1;
    }

    pub fn record_error(&mut self) {
        self.errors += 1;
    }

    /// Record one executed streaming step at `lanes` live occupancy
    /// (counter + histogram sample). Occupancy 1 counts as a solo step —
    /// the solo chunk path and single-session windows both land there,
    /// so `fused_steps + solo_steps` is every streaming step served.
    pub fn record_step_occupancy(&mut self, lanes: usize) {
        if lanes > 1 {
            self.fused_steps += 1;
        } else {
            self.solo_steps += 1;
        }
        if self.lane_occupancy.len() < SAMPLE_WINDOW {
            self.lane_occupancy.push(lanes as f64);
        } else {
            self.lane_occupancy.replace(self.occ_cursor, lanes as f64);
            self.occ_cursor = (self.occ_cursor + 1) % SAMPLE_WINDOW;
        }
    }

    /// Record the execution plan a bucket executable resolved (worker
    /// startup; one entry per artifact name).
    pub fn record_plan(&mut self, bucket: &str, plan: String) {
        self.plans.insert(bucket.to_string(), plan);
    }

    /// Clear everything, including the throughput clock — the next
    /// recorded request starts a fresh measurement window.
    pub fn reset(&mut self) {
        *self = Metrics::default();
    }

    /// Fold another worker's metrics into this one (snapshot merge).
    pub fn merge(&mut self, other: &Metrics) {
        self.latency_s.extend_from(&other.latency_s);
        self.accel_time_s.extend_from(&other.accel_time_s);
        self.batch_sizes.extend_from(&other.batch_sizes);
        self.lane_occupancy.extend_from(&other.lane_occupancy);
        self.completed += other.completed;
        self.errors += other.errors;
        self.fused_steps += other.fused_steps;
        self.solo_steps += other.solo_steps;
        self.faults_injected += other.faults_injected;
        self.deadline_misses += other.deadline_misses;
        self.shed += other.shed;
        self.respawns += other.respawns;
        self.recovered_sessions += other.recovered_sessions;
        self.conns_accepted += other.conns_accepted;
        self.conns_rejected += other.conns_rejected;
        self.conns_timed_out += other.conns_timed_out;
        self.conns_drained += other.conns_drained;
        self.frames_malformed += other.frames_malformed;
        self.retries_observed += other.retries_observed;
        for (worker, health) in &other.worker_health {
            self.worker_health.insert(worker.clone(), health.clone());
        }
        for (bucket, plan) in &other.plans {
            self.plans
                .entry(bucket.clone())
                .or_insert_with(|| plan.clone());
        }
        self.first_record = match (self.first_record, other.first_record) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        self.last_record = match (self.last_record, other.last_record) {
            (Some(a), Some(b)) => Some(a.max(b)),
            (a, b) => a.or(b),
        };
    }

    /// Requests/second over the active window (first to last recorded
    /// request). With fewer than two completions there is no span yet, so
    /// the rate falls back to "since the first record".
    pub fn throughput_rps(&self) -> f64 {
        let Some(first) = self.first_record else {
            return 0.0;
        };
        let span = match self.last_record {
            Some(last) if last > first => last.duration_since(first).as_secs_f64(),
            _ => first.elapsed().as_secs_f64(),
        };
        if span <= 0.0 {
            0.0
        } else {
            self.completed as f64 / span
        }
    }

    /// Render the standard serving report block.
    pub fn render(&mut self) -> String {
        let mut out = format!(
            "requests={} errors={} throughput={:.1} rps\n\
             latency  p50={:.2}ms p95={:.2}ms p99={:.2}ms mean={:.2}ms\n\
             accel-est p50={:.1}us (SHARP cycle model)\n\
             batch    mean={:.2} max={:.0}",
            self.completed,
            self.errors,
            self.throughput_rps(),
            self.latency_s.p50() * 1e3,
            self.latency_s.p95() * 1e3,
            self.latency_s.p99() * 1e3,
            self.latency_s.mean() * 1e3,
            self.accel_time_s.p50() * 1e6,
            self.batch_sizes.mean(),
            self.batch_sizes.max(),
        );
        if self.fused_steps + self.solo_steps > 0 {
            let (p50, max) = (self.lane_occupancy.p50(), self.lane_occupancy.max());
            out.push_str(&format!(
                "\nstream   fused_steps={} solo_steps={} occupancy p50={:.0} max={:.0} lanes",
                self.fused_steps, self.solo_steps, p50, max
            ));
        }
        if self.faults_injected + self.deadline_misses + self.shed + self.respawns > 0 {
            out.push_str(&format!(
                "\nfaults   injected={} deadline_misses={} shed={} respawns={} recovered_sessions={}",
                self.faults_injected,
                self.deadline_misses,
                self.shed,
                self.respawns,
                self.recovered_sessions
            ));
        }
        let net_total = self.conns_accepted
            + self.conns_rejected
            + self.conns_timed_out
            + self.conns_drained
            + self.frames_malformed
            + self.retries_observed;
        if net_total > 0 {
            out.push_str(&format!(
                "\nnet      conns accepted={} rejected={} timed_out={} drained={} \
                 frames_malformed={} retries_observed={}",
                self.conns_accepted,
                self.conns_rejected,
                self.conns_timed_out,
                self.conns_drained,
                self.frames_malformed,
                self.retries_observed
            ));
        }
        if !self.worker_health.is_empty() {
            let health: Vec<String> = self
                .worker_health
                .iter()
                .map(|(w, h)| format!("{w}={h}"))
                .collect();
            out.push_str(&format!("\nhealth   {}", health.join(" ")));
        }
        if !self.plans.is_empty() {
            let plans: Vec<String> = self
                .plans
                .iter()
                .map(|(b, p)| format!("{b}={p}"))
                .collect();
            out.push_str(&format!("\nplans    {}", plans.join(" ")));
        }
        out
    }

    /// Machine-readable snapshot (the `sharp serve --json` surface):
    /// exact counters plus distribution summaries, including the fused
    /// streaming block.
    pub fn snapshot_json(&mut self) -> Json {
        let mut root = BTreeMap::new();
        // v2: adds the "faults" and "health" blocks (fault-tolerance PR).
        // v3: plan rows carry the weight dtype (mr/nr/sched@isa/dtype),
        // so a snapshot shows dtype and ISA side by side per bucket.
        // v4: adds the "net" block (TCP front-end connection counters),
        // always present and zeroed for in-process-only servers.
        root.insert("schema".into(), Json::Str("sharp-serve-metrics/v4".into()));
        root.insert("requests".into(), Json::Num(self.completed as f64));
        root.insert("errors".into(), Json::Num(self.errors as f64));
        root.insert("throughput_rps".into(), Json::Num(self.throughput_rps()));
        let mut lat = BTreeMap::new();
        lat.insert("p50_s".into(), Json::Num(self.latency_s.p50()));
        lat.insert("p95_s".into(), Json::Num(self.latency_s.p95()));
        lat.insert("p99_s".into(), Json::Num(self.latency_s.p99()));
        lat.insert("mean_s".into(), Json::Num(self.latency_s.mean()));
        root.insert("latency".into(), Json::Obj(lat));
        let mut batch = BTreeMap::new();
        batch.insert("mean".into(), Json::Num(self.batch_sizes.mean()));
        batch.insert(
            "max".into(),
            Json::Num(if self.batch_sizes.is_empty() {
                0.0
            } else {
                self.batch_sizes.max()
            }),
        );
        root.insert("batch".into(), Json::Obj(batch));
        let mut stream = BTreeMap::new();
        stream.insert("fused_steps".into(), Json::Num(self.fused_steps as f64));
        stream.insert("solo_steps".into(), Json::Num(self.solo_steps as f64));
        let mut occ = BTreeMap::new();
        occ.insert("p50".into(), Json::Num(self.lane_occupancy.p50()));
        occ.insert("p95".into(), Json::Num(self.lane_occupancy.p95()));
        occ.insert("mean".into(), Json::Num(self.lane_occupancy.mean()));
        occ.insert(
            "max".into(),
            Json::Num(if self.lane_occupancy.is_empty() {
                0.0
            } else {
                self.lane_occupancy.max()
            }),
        );
        stream.insert("occupancy".into(), Json::Obj(occ));
        root.insert("streaming".into(), Json::Obj(stream));
        let mut faults = BTreeMap::new();
        faults.insert("injected".into(), Json::Num(self.faults_injected as f64));
        faults.insert(
            "deadline_misses".into(),
            Json::Num(self.deadline_misses as f64),
        );
        faults.insert("shed".into(), Json::Num(self.shed as f64));
        faults.insert("respawns".into(), Json::Num(self.respawns as f64));
        faults.insert(
            "recovered_sessions".into(),
            Json::Num(self.recovered_sessions as f64),
        );
        root.insert("faults".into(), Json::Obj(faults));
        let mut net = BTreeMap::new();
        net.insert(
            "conns_accepted".into(),
            Json::Num(self.conns_accepted as f64),
        );
        net.insert(
            "conns_rejected".into(),
            Json::Num(self.conns_rejected as f64),
        );
        net.insert(
            "conns_timed_out".into(),
            Json::Num(self.conns_timed_out as f64),
        );
        net.insert("conns_drained".into(), Json::Num(self.conns_drained as f64));
        net.insert(
            "frames_malformed".into(),
            Json::Num(self.frames_malformed as f64),
        );
        net.insert(
            "retries_observed".into(),
            Json::Num(self.retries_observed as f64),
        );
        root.insert("net".into(), Json::Obj(net));
        let health = self
            .worker_health
            .iter()
            .map(|(w, h)| (w.clone(), Json::Str(h.clone())))
            .collect();
        root.insert("health".into(), Json::Obj(health));
        let plans = self
            .plans
            .iter()
            .map(|(b, p)| (b.clone(), Json::Str(p.clone())))
            .collect();
        root.insert("plans".into(), Json::Obj(plans));
        Json::Obj(root)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_renders() {
        let mut m = Metrics::new();
        for i in 0..10 {
            m.record(0.001 * (i + 1) as f64, 1e-6, 4);
        }
        m.record_error();
        assert_eq!(m.completed, 10);
        assert_eq!(m.errors, 1);
        let s = m.render();
        assert!(s.contains("requests=10"));
        assert!(s.contains("p95"));
    }

    #[test]
    fn throughput_positive_after_work() {
        let mut m = Metrics::new();
        m.record(0.001, 1e-6, 1);
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(m.throughput_rps() > 0.0);
    }

    #[test]
    fn throughput_clock_starts_at_first_record() {
        let m = Metrics::new();
        // Idle server: no requests, no rate — construction time must not
        // leak into the measurement.
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert_eq!(m.throughput_rps(), 0.0);

        let mut m = Metrics::new();
        std::thread::sleep(std::time::Duration::from_millis(40));
        m.record(0.001, 1e-6, 1);
        m.record(0.001, 1e-6, 1);
        // Two records microseconds apart: a construction-based clock
        // would report ~2/0.04 = 50 rps; the record-span clock reports a
        // far higher rate because the 40 ms of startup is excluded.
        assert!(
            m.throughput_rps() > 100.0,
            "startup leaked into throughput: {} rps",
            m.throughput_rps()
        );
    }

    #[test]
    fn sample_window_bounds_memory_counters_stay_exact() {
        let mut m = Metrics::new();
        let n = SAMPLE_WINDOW as u64 + 1000;
        for i in 0..n {
            m.record(i as f64, 1e-6, 1);
        }
        assert_eq!(m.completed, n, "counters are exact");
        assert_eq!(m.latency_s.len(), SAMPLE_WINDOW, "samples are bounded");
        // The retained window is the recent tail: its max is the last
        // recorded value, and the evicted head (0..1000) is gone.
        assert_eq!(m.latency_s.max(), (n - 1) as f64);
        assert!(m.latency_s.min() >= 1000.0);
    }

    #[test]
    fn reset_clears_counts_and_clock() {
        let mut m = Metrics::new();
        m.record(0.001, 1e-6, 2);
        m.record_error();
        m.reset();
        assert_eq!(m.completed, 0);
        assert_eq!(m.errors, 0);
        assert!(m.latency_s.is_empty());
        assert_eq!(m.throughput_rps(), 0.0);
    }

    #[test]
    fn plans_survive_merge_and_render() {
        let mut a = Metrics::new();
        a.record_plan("seq_h256_t16_b4", "mr4/nr16/unfolded".into());
        let mut b = Metrics::new();
        b.record_plan("seq_h256_t16_b4", "mr4/nr16/unfolded".into());
        b.record_plan("seq_h512_t32_b4", "mr4/nr16/unfolded".into());
        a.merge(&b);
        assert_eq!(a.plans.len(), 2, "replica duplicates collapse");
        let s = a.render();
        assert!(s.contains("plans"));
        assert!(s.contains("seq_h512_t32_b4=mr4/nr16/unfolded"));
        // No plans recorded -> no plans line.
        assert!(!Metrics::new().render().contains("plans"));
    }

    #[test]
    fn step_occupancy_counters_and_histogram() {
        let mut m = Metrics::new();
        // A 3-lane window of lens [3, 2, 1]: occupancies 3, 2, 2.
        for occ in [3usize, 2, 2] {
            m.record_step_occupancy(occ);
        }
        // A solo chunk of 4 steps.
        for _ in 0..4 {
            m.record_step_occupancy(1);
        }
        assert_eq!(m.fused_steps, 3);
        assert_eq!(m.solo_steps, 4);
        assert_eq!(m.lane_occupancy.len(), 7);
        assert_eq!(m.lane_occupancy.max(), 3.0);
        let s = m.render();
        assert!(s.contains("fused_steps=3"), "{s}");
        assert!(s.contains("solo_steps=4"), "{s}");
        // No streaming traffic -> no stream line.
        assert!(!Metrics::new().render().contains("fused_steps"));

        let mut other = Metrics::new();
        other.record_step_occupancy(5);
        m.merge(&other);
        assert_eq!(m.fused_steps, 4);
        assert_eq!(m.lane_occupancy.max(), 5.0);
    }

    #[test]
    fn occupancy_window_is_bounded() {
        let mut m = Metrics::new();
        for i in 0..(SAMPLE_WINDOW + 100) {
            m.record_step_occupancy(2 + (i % 3));
        }
        assert_eq!(m.lane_occupancy.len(), SAMPLE_WINDOW, "histogram bounded");
        assert_eq!(m.fused_steps, (SAMPLE_WINDOW + 100) as u64, "counter exact");
    }

    #[test]
    fn json_snapshot_has_streaming_block() {
        let mut m = Metrics::new();
        m.record(0.002, 1e-6, 2);
        m.record_step_occupancy(4);
        m.record_step_occupancy(1);
        m.record_plan("seq_h256_t16_b4", "mr4/nr16/unfolded@scalar/f32".into());
        let s = crate::util::json::write(&m.snapshot_json());
        assert!(s.contains("\"schema\":\"sharp-serve-metrics/v4\""), "{s}");
        assert!(s.contains("\"fused_steps\":1"), "{s}");
        assert!(s.contains("\"solo_steps\":1"), "{s}");
        assert!(s.contains("\"occupancy\""), "{s}");
        assert!(s.contains("seq_h256_t16_b4"), "{s}");
        // An idle server's snapshot is still valid JSON with finite
        // numbers (no -inf max from empty sample sets).
        let empty = crate::util::json::write(&Metrics::new().snapshot_json());
        assert!(empty.contains("\"max\":0"), "{empty}");
    }

    #[test]
    fn fault_counters_render_and_merge() {
        let mut m = Metrics::new();
        // Healthy run: no faults line, no health line, but the JSON
        // blocks are always present (zeroed) for stable consumers.
        assert!(!m.render().contains("faults"));
        let s = crate::util::json::write(&m.snapshot_json());
        assert!(s.contains("\"faults\""), "{s}");
        assert!(s.contains("\"injected\":0"), "{s}");
        assert!(s.contains("\"health\""), "{s}");

        m.faults_injected = 2;
        m.deadline_misses = 3;
        m.shed = 1;
        let mut sup = Metrics::new();
        sup.respawns = 1;
        sup.recovered_sessions = 4;
        sup.worker_health
            .insert("worker0".into(), "respawning".into());
        sup.worker_health.insert("worker1".into(), "ok".into());
        m.merge(&sup);
        assert_eq!(m.faults_injected, 2);
        assert_eq!(m.respawns, 1);
        assert_eq!(m.recovered_sessions, 4);
        let r = m.render();
        assert!(r.contains("injected=2"), "{r}");
        assert!(r.contains("deadline_misses=3"), "{r}");
        assert!(r.contains("shed=1"), "{r}");
        assert!(r.contains("respawns=1"), "{r}");
        assert!(r.contains("worker0=respawning"), "{r}");
        assert!(r.contains("worker1=ok"), "{r}");
        let s = crate::util::json::write(&m.snapshot_json());
        assert!(s.contains("\"recovered_sessions\":4"), "{s}");
        assert!(s.contains("\"worker0\":\"respawning\""), "{s}");
    }

    #[test]
    fn net_counters_render_merge_and_snapshot() {
        // In-process-only server: no net line, but the JSON block is
        // always present (zeroed) so consumers never branch on absence.
        let mut m = Metrics::new();
        assert!(!m.render().contains("net "), "{}", m.render());
        let s = crate::util::json::write(&m.snapshot_json());
        assert!(s.contains("\"net\""), "{s}");
        assert!(s.contains("\"conns_accepted\":0"), "{s}");

        m.conns_accepted = 5;
        m.conns_rejected = 2;
        m.frames_malformed = 1;
        let mut listener = Metrics::new();
        listener.conns_timed_out = 1;
        listener.conns_drained = 3;
        listener.retries_observed = 4;
        m.merge(&listener);
        let r = m.render();
        assert!(r.contains("accepted=5"), "{r}");
        assert!(r.contains("rejected=2"), "{r}");
        assert!(r.contains("timed_out=1"), "{r}");
        assert!(r.contains("drained=3"), "{r}");
        assert!(r.contains("frames_malformed=1"), "{r}");
        assert!(r.contains("retries_observed=4"), "{r}");
        let s = crate::util::json::write(&m.snapshot_json());
        assert!(s.contains("\"conns_drained\":3"), "{s}");
        assert!(s.contains("\"retries_observed\":4"), "{s}");
    }

    #[test]
    fn merge_folds_counts_and_samples() {
        let mut a = Metrics::new();
        a.record(0.001, 1e-6, 1);
        a.record_error();
        let mut b = Metrics::new();
        b.record(0.003, 2e-6, 4);
        b.record(0.005, 3e-6, 4);
        a.merge(&b);
        assert_eq!(a.completed, 3);
        assert_eq!(a.errors, 1);
        assert_eq!(a.latency_s.len(), 3);
        assert_eq!(a.batch_sizes.max(), 4.0);
        assert!(a.throughput_rps() > 0.0);
    }
}
